//! `fstitch` — FusionStitching command-line driver.
//!
//! Subcommands (hand-rolled parsing; clap is unavailable offline):
//!
//! * `fstitch optimize --model <name>`  — run TF/XLA/FS on one workload
//!   and print the Table-2 style breakdown comparison.
//! * `fstitch inspect --model <name> [--dot]` — print the FS fusion plan
//!   (and optionally DOT with fusion clusters / kernel pseudocode).
//! * `fstitch serve --model <name> --iters N` — run the JIT service with
//!   async compilation and report before/after-swap latency.
//! * `fstitch report` — the whole Figure-7 speedup table.
//! * `fstitch list` — list available workloads.
//! * `fstitch hlo --file <p.hlo.txt> [--explore]` — parse an AOT HLO
//!   artifact, print its op census, and (for straight-line modules) run
//!   the fusion explorer against the XLA baseline on the real HLO.
//! * `fstitch trace --model <name> --tech <tf|xla|fs> --out <t.json>` —
//!   write a chrome://tracing timeline of the simulated iteration.
//! * `fstitch emit --model <name> --out <m.hlo.txt> [--run]` — export a
//!   workload graph as executable HLO text (and optionally compile +
//!   run it on the PJRT CPU client as a smoke test).
//! * `fstitch fleet [--v100 N] [--t4 N] [--capacity C] [--workers K]
//!   [--tasks N] [--rate MS] [--templates T] [--seed S] [--out FILE]
//!   [--executor virtual|wallclock] [--threads N]
//!   [--compile-shards S] [--calibrate] [--drift-bound R]
//!   [--dynamic-shapes] [--tenants N] [--churn] [--inject-faults]` —
//!   replay a deterministic task trace through
//!   the multi-device fleet service (§7.2) and print the fleet-wide
//!   report; `wallclock` runs compile workers and per-device serving
//!   slots on real OS threads, `--compile-shards` fans a multi-region
//!   graph's exploration out as parallel region sub-jobs with a join
//!   barrier, `--calibrate` turns on the online cost-model calibration
//!   loop (fit per-class corrections from served traffic; re-explore
//!   graphs whose measured/predicted ratio drifts past
//!   `--drift-bound`, default 1.4, publishing only strictly-better
//!   plans), and `--dynamic-shapes` draws a (batch, seq) per task from
//!   seeded per-template shape distributions, serving sibling shapes
//!   through the plan store's power-of-two bucket tier (launch-dim
//!   retune instead of per-shape re-exploration). `--observe` turns on
//!   the flight recorder (per-task lifecycle spans, stage-attributed
//!   latency, lock-contention profile in the report) and
//!   `--trace FILE` additionally exports the spans as Chrome
//!   trace-event JSON for Perfetto / chrome://tracing. `--shards N`
//!   splits the control plane into N structure-key-sharded dispatchers
//!   (each owning a slice of the device registry and its own
//!   epoch-published plan store; tasks route by their graph's
//!   shape-erased structure key) and prints the per-shard rollup with
//!   decision digests, and `--admission-tick MS` batches each
//!   dispatcher's admission pending-compile sampling per tick instead
//!   of per task (0 = legacy per-task sampling). `--tenants N` spreads
//!   the trace across N tenants (skewed seeded mix) mapped to priority
//!   tiers with SLA-aware tiered admission, adding a per-tenant QoS
//!   table to the report; `--churn` drains and rejoins devices
//!   mid-trace on a seeded schedule, migrating in-flight sessions to
//!   survivors through the plan port/reshape feasibility ladder; and
//!   `--inject-faults` (implies churn) also kills one device outright
//!   mid-serve, delivered to the wall-clock serving thread as a real
//!   kill marker.

use fusion_stitching::coordinator::{JitService, ServiceOptions};
use fusion_stitching::fleet;
use fusion_stitching::explorer::ExploreOptions;
use fusion_stitching::gpu::DeviceSpec;
use fusion_stitching::pipeline::{self, Tech};
use fusion_stitching::util::Table;
use fusion_stitching::workloads::{self, Workload};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let get_flag = |name: &str| -> Option<String> {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1))
            .cloned()
    };
    let has_flag = |name: &str| args.iter().any(|a| a == name);

    match cmd {
        "list" => {
            for w in workloads::catalog() {
                println!(
                    "{:<20} {:<20} {:<10} batch={:<5} ops={}",
                    w.key(),
                    w.field,
                    format!("{}", w.mode),
                    w.batch,
                    w.graph.len()
                );
            }
        }
        "optimize" => {
            let model = get_flag("--model").unwrap_or_else(|| "BERT-infer".to_string());
            let w = find_workload(&model);
            let device = pick_device(get_flag("--device"));
            println!("== {} on {} ==", w.key(), device.name);
            let rows = pipeline::table2_rows(&w, &device, &ExploreOptions::default());
            let mut t = Table::new(vec![
                "tech", "CPU ms", "Math ms", "Mem ms", "Cpy ms", "E2E ms", "#Math", "#Mem", "#Cpy",
            ]);
            for r in &rows {
                let b = &r.breakdown;
                t.row(vec![
                    r.tech.name().to_string(),
                    format!("{:.2}", b.cpu_ms),
                    format!("{:.2}", b.math_ms),
                    format!("{:.2}", b.mem_ms),
                    format!("{:.2}", b.cpy_ms),
                    format!("{:.2}", b.e2e_ms()),
                    b.math_calls.to_string(),
                    b.mem_calls.to_string(),
                    b.cpy_calls.to_string(),
                ]);
            }
            println!("{}", t.render());
        }
        "inspect" => {
            let model = get_flag("--model").unwrap_or_else(|| "BERT-infer".to_string());
            let w = find_workload(&model);
            let device = pick_device(get_flag("--device"));
            let plan = pipeline::plan_for(&w.graph, &device, Tech::Fs, &ExploreOptions::default());
            println!(
                "{}: {} ops, {} fusion patterns, {} kernels",
                w.key(),
                w.graph.len(),
                plan.patterns.len(),
                plan.kernels(&w.graph).len()
            );
            if has_flag("--dot") {
                let clusters: Vec<(String, Vec<fusion_stitching::NodeId>)> = plan
                    .patterns
                    .iter()
                    .enumerate()
                    .map(|(i, p)| (format!("fusion.{i}"), p.nodes().to_vec()))
                    .collect();
                println!("{}", fusion_stitching::graph::to_dot(&w.graph, &clusters));
            }
        }
        "serve" => {
            let model = get_flag("--model").unwrap_or_else(|| "BERT-infer".to_string());
            let iters: usize = get_flag("--iters")
                .and_then(|s| s.parse().ok())
                .unwrap_or(20);
            let w = find_workload(&model);
            let svc = JitService::new(ServiceOptions {
                // --persist <path>: tuned plans survive restarts (the
                // warm-start path is exercised by re-running serve).
                plan_store: get_flag("--persist").map(std::path::PathBuf::from),
                ..Default::default()
            });
            let mut session = svc.submit(&w);
            for i in 0..iters {
                let b = svc.run_iteration(&session);
                if i == 0 || i + 1 == iters {
                    let opt = session.is_optimized();
                    println!("iter {:>3}: {:.3} ms (optimized={opt})", i, b.e2e_ms());
                }
            }
            session.wait_optimized();
            let b = svc.run_iteration(&session);
            println!("post-swap: {:.3} ms", b.e2e_ms());
            // One sort serves the whole percentile batch.
            if let Some(ps) = session.metrics.latency_percentiles(&[0.5, 0.95, 0.99]) {
                println!(
                    "latency p50/p95/p99: {:.3} / {:.3} / {:.3} ms",
                    ps[0], ps[1], ps[2]
                );
            }
            println!("{}", session.metrics.to_json().to_pretty());
        }
        "report" => {
            let device = pick_device(get_flag("--device"));
            let mut t = Table::new(vec!["workload", "TF ms", "XLA ms", "FS ms", "FS/TF", "FS/XLA"]);
            for w in workloads::catalog() {
                let rows = pipeline::table2_rows(&w, &device, &ExploreOptions::default());
                let e2e = |tech: Tech| {
                    rows.iter()
                        .find(|r| r.tech == tech)
                        .unwrap()
                        .breakdown
                        .e2e_ms()
                };
                let (tf, xla, fs) = (e2e(Tech::Tf), e2e(Tech::Xla), e2e(Tech::Fs));
                t.row(vec![
                    w.key(),
                    format!("{tf:.2}"),
                    format!("{xla:.2}"),
                    format!("{fs:.2}"),
                    format!("{:.2}x", tf / fs),
                    format!("{:.2}x", xla / fs),
                ]);
            }
            println!("{}", t.render());
        }
        "hlo" => {
            let file = get_flag("--file").unwrap_or_else(|| {
                eprintln!("hlo: --file <path.hlo.txt> required");
                std::process::exit(2);
            });
            let module = fusion_stitching::hlo::parse_file(&file).unwrap_or_else(|e| {
                eprintln!("{e}");
                std::process::exit(1);
            });
            let stats = fusion_stitching::hlo::module_stats(&module);
            println!(
                "{}: {} computations, {} instructions ({} memory-intensive, {} compute-intensive)",
                module.name, stats.computations, stats.instructions,
                stats.memory_intensive, stats.compute_intensive,
            );
            let mut t = Table::new(vec!["opcode", "count"]);
            for (op, n) in stats.opcode_histogram.iter().take(16) {
                t.row(vec![op.clone(), n.to_string()]);
            }
            println!("{}", t.render());
            if has_flag("--explore") {
                match fusion_stitching::hlo::to_graph(&module) {
                    Ok(g) => {
                        let device = pick_device(get_flag("--device"));
                        let xla = fusion_stitching::baselines::xla::plan(&g);
                        let fs = fusion_stitching::explorer::explore(
                            &g,
                            &device,
                            &ExploreOptions::default(),
                        );
                        println!(
                            "fusion on real HLO: XLA → {} kernels, FusionStitching → {} kernels",
                            xla.kernels(&g).len(),
                            fs.kernels(&g).len()
                        );
                    }
                    Err(e) => println!("not explorable (control flow): {e}"),
                }
            }
        }
        "trace" => {
            let model = get_flag("--model").unwrap_or_else(|| "BERT-infer".to_string());
            let tech = match get_flag("--tech").as_deref() {
                Some("tf") => Tech::Tf,
                Some("xla") => Tech::Xla,
                _ => Tech::Fs,
            };
            let out = get_flag("--out").unwrap_or_else(|| "trace.json".to_string());
            let w = find_workload(&model);
            let device = pick_device(get_flag("--device"));
            let prog = pipeline::optimize(&w, &device, tech, &ExploreOptions::default());
            let sim_cfg = match tech {
                Tech::Tf => fusion_stitching::gpu::SimConfig::tensorflow(),
                _ => fusion_stitching::gpu::SimConfig::xla_runtime(),
            };
            let sim = fusion_stitching::gpu::Simulator::new(device, sim_cfg);
            let trace = sim.run_traced(&prog.kernels, w.loop_kind);
            std::fs::write(&out, trace.to_chrome_json().to_pretty()).unwrap_or_else(|e| {
                eprintln!("write {out}: {e}");
                std::process::exit(1);
            });
            println!(
                "{} [{}]: {} device slices, span {:.2} ms, device utilization {:.1}% → {out}",
                w.key(),
                tech.name(),
                trace.device_slices(),
                trace.span_us() / 1e3,
                trace.device_utilization() * 100.0
            );
        }
        "emit" => {
            let model = get_flag("--model").unwrap_or_else(|| "BERT-infer".to_string());
            let out = get_flag("--out").unwrap_or_else(|| format!("{model}.hlo.txt"));
            let w = find_workload(&model);
            match fusion_stitching::hlo::emit_module(&w.graph) {
                Ok(text) => {
                    std::fs::write(&out, &text).unwrap_or_else(|e| {
                        eprintln!("write {out}: {e}");
                        std::process::exit(1);
                    });
                    println!(
                        "{}: {} ops → {} ({} chars)",
                        w.key(),
                        w.graph.len(),
                        out,
                        text.len()
                    );
                    if has_flag("--run") {
                        match fusion_stitching::runtime::RuntimeClient::cpu()
                            .and_then(|c| c.load_hlo_text(std::path::Path::new(&out)))
                        {
                            Ok(_) => println!("PJRT compile: OK"),
                            Err(e) => {
                                eprintln!("PJRT compile failed: {e}");
                                std::process::exit(1);
                            }
                        }
                    }
                }
                Err(e) => {
                    eprintln!("{e}");
                    std::process::exit(1);
                }
            }
        }
        "fleet" => {
            fn bad_flag(name: &str, problem: &str) -> ! {
                eprintln!("fleet: invalid value for {name}: {problem}");
                std::process::exit(2);
            }
            let num = |name: &str, default: usize| -> usize {
                match get_flag(name) {
                    None => default,
                    Some(s) => s.parse().unwrap_or_else(|_| bad_flag(name, &s)),
                }
            };
            // Seeds print as hex ({:#x}); accept both 0x-hex and decimal
            // so a printed seed can be pasted back for replay.
            let seed = match get_flag("--seed") {
                None => 0xF1EE7,
                Some(s) => {
                    let parsed = match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
                        Some(hex) => u64::from_str_radix(hex, 16).ok(),
                        None => s.parse().ok(),
                    };
                    parsed.unwrap_or_else(|| bad_flag("--seed", &s))
                }
            };
            let rate: f64 = match get_flag("--rate") {
                None => 1.5,
                Some(s) => s.parse().unwrap_or_else(|_| bad_flag("--rate", &s)),
            };
            if !(rate > 0.0) {
                bad_flag("--rate", "must be a positive inter-arrival gap in ms");
            }
            let templates = num("--templates", 12);
            if templates == 0 {
                bad_flag("--templates", "need at least one template");
            }
            // --dynamic-shapes: shape-polymorphic traffic — every task
            // draws (batch, seq) from its template's seeded shape
            // distribution and sibling shapes reuse plans through the
            // store's power-of-two bucket tier.
            let dynamic_shapes = has_flag("--dynamic-shapes");
            // --tenants N: multi-tenant traffic — each task carries a
            // tenant drawn from a skewed seeded mix, and tenants map to
            // priority tiers (premium / standard / best_effort) with
            // SLA-aware tiered admission at the dispatcher. The report
            // gains a per-tenant QoS table.
            let tenants = num("--tenants", 0);
            // --churn: devices leave/rejoin mid-trace on a seeded
            // schedule and in-flight sessions migrate to survivors.
            // --inject-faults additionally kills one device outright
            // mid-serve (implies churn).
            let churn = has_flag("--churn");
            let inject_faults = has_flag("--inject-faults");
            let traffic = fleet::TrafficConfig {
                tasks: num("--tasks", 400),
                templates,
                seed,
                mean_interarrival_ms: rate,
                dynamic_shapes,
                tenants,
                ..Default::default()
            };
            let (v100s, t4s) = (num("--v100", 2), num("--t4", 2));
            if v100s + t4s == 0 {
                bad_flag("--v100/--t4", "fleet needs at least one device");
            }
            if (churn || inject_faults) && v100s + t4s < 2 {
                bad_flag("--churn", "churn needs at least two devices (device 0 never leaves)");
            }
            let capacity = num("--capacity", 2);
            if capacity == 0 {
                bad_flag("--capacity", "device capacity must be positive");
            }
            let workers = num("--workers", 2);
            if workers == 0 {
                bad_flag("--workers", "compile pool needs at least one worker");
            }
            // --compile-shards S: fan each multi-region exploration out
            // as up to S region sub-jobs with a join barrier (1 =
            // monolithic compile jobs).
            let compile_shards = num("--compile-shards", 1);
            if compile_shards == 0 {
                bad_flag("--compile-shards", "need at least one shard");
            }
            // --executor wallclock [--threads N]: real OS threads for
            // compile workers and per-device serving slots; decisions
            // converge to the virtual replay's. --threads alone
            // implies wallclock.
            let threads_flag = get_flag("--threads");
            let threads = match &threads_flag {
                None => workers,
                Some(s) => s.parse().unwrap_or_else(|_| bad_flag("--threads", s)),
            };
            if threads == 0 {
                bad_flag("--threads", "need at least one compile thread");
            }
            let executor = match get_flag("--executor").as_deref() {
                Some("wallclock") => fleet::ExecutorKind::WallClock { threads },
                None if threads_flag.is_some() => fleet::ExecutorKind::WallClock { threads },
                Some("virtual") | None => fleet::ExecutorKind::VirtualTime,
                Some(other) => bad_flag("--executor", other),
            };
            // --calibrate [--drift-bound R]: online cost-model
            // calibration + drift-triggered re-exploration.
            let calibrate = has_flag("--calibrate");
            let drift_bound: f64 = match get_flag("--drift-bound") {
                None => 1.4,
                Some(s) => s.parse().unwrap_or_else(|_| bad_flag("--drift-bound", &s)),
            };
            if !(drift_bound >= 1.0) {
                bad_flag("--drift-bound", "must be a ratio >= 1.0");
            }
            // --trace FILE: export the run's flight-recorder events as
            // Chrome trace-event JSON (open in Perfetto or
            // chrome://tracing). --observe alone folds the
            // observability section (stage latency + lock contention)
            // into the report without writing the export.
            let trace_out = get_flag("--trace");
            let observe = has_flag("--observe") || trace_out.is_some();
            // --shards N: split the control plane into N structure-key-
            // sharded dispatchers; --admission-tick MS batches each
            // dispatcher's pending-compile sampling per tick.
            let shards = num("--shards", 1);
            if shards == 0 {
                bad_flag("--shards", "need at least one dispatcher shard");
            }
            if shards > v100s + t4s {
                bad_flag("--shards", "more dispatcher shards than devices");
            }
            let admission_tick: f64 = match get_flag("--admission-tick") {
                None => 0.0,
                Some(s) => s.parse().unwrap_or_else(|_| bad_flag("--admission-tick", &s)),
            };
            if !(admission_tick >= 0.0) {
                bad_flag("--admission-tick", "must be a non-negative window in ms");
            }
            let opts = fleet::FleetOptions {
                registry: fleet::DeviceRegistry::mixed(v100s, t4s, capacity),
                compile_workers: workers,
                compile_shards,
                executor,
                calibrate,
                drift_bound,
                observe,
                shards,
                admission_tick_ms: admission_tick,
                churn,
                inject_faults,
                ..Default::default()
            };
            println!(
                "== fleet: {} tasks over {} templates on {} devices ({} slots), \
                 seed {:#x}, executor {}, compile shards {}, shapes {}, \
                 tenants {}, churn {} ==\n",
                traffic.tasks,
                traffic.templates,
                opts.registry.len(),
                opts.registry.total_capacity(),
                traffic.seed,
                executor.name(),
                compile_shards,
                if dynamic_shapes { "dynamic" } else { "static" },
                traffic.tenants.max(1),
                match (churn, inject_faults) {
                    (_, true) => "on+faults",
                    (true, false) => "on",
                    (false, false) => "off",
                }
            );
            let families = fleet::build_template_families(&traffic);
            let trace = fleet::generate_trace(&traffic);
            if shards > 1 {
                if trace_out.is_some() {
                    bad_flag("--trace", "flight-recorder export is per-dispatcher; drop --shards");
                }
                let mut svc = fleet::ShardedFleetService::with_families(opts, families);
                let cr = svc.run_trace(&trace);
                println!("{}", cr.render());
                println!(
                    "\ncluster: {} tasks across {} shards in {:.1} ms \
                     ({:.0} tasks/s); FS regressions: {}",
                    cr.tasks(),
                    cr.shards.len(),
                    cr.elapsed_ms,
                    cr.tasks_per_sec(),
                    cr.regressions()
                );
                if let Some(out) = get_flag("--out") {
                    match std::fs::write(&out, cr.to_json().to_pretty()) {
                        Ok(()) => println!("wrote {out}"),
                        Err(e) => {
                            eprintln!("write {out}: {e}");
                            std::process::exit(1);
                        }
                    }
                }
                return;
            }
            let mut svc = fleet::FleetService::with_families(opts, families);
            let report = svc.run_trace(&trace);
            println!("{}", report.render());
            println!(
                "\nGPU time saved vs fallback-only: {:.1} ms ({:.1}%); \
                 cross-device plan-portability hits: {}; FS regressions: {}",
                report.saved_gpu_ms(),
                report.saved_frac() * 100.0,
                report.port_hits,
                report.regressions
            );
            if traffic.tenants > 0 || churn || inject_faults {
                println!(
                    "qos: {} sheds, {} SLA violations; churn {} events, {} faults, \
                     {} migrations ({} degraded)",
                    report.sheds,
                    report.sla_violations,
                    report.churn_events,
                    report.faults,
                    report.migrations,
                    report.migrations_degraded
                );
            }
            if dynamic_shapes {
                println!(
                    "dynamic shapes: {} distinct graphs in {} buckets; {} bucket hits \
                     ({} shape retunes, {} fell back to full exploration); \
                     {} full explorations",
                    report.distinct_shapes,
                    report.distinct_buckets,
                    report.bucket_hits,
                    report.bucket_retunes,
                    report.bucket_failures,
                    report.explore_jobs
                );
            }
            if report.shard_jobs > 0 {
                println!(
                    "region-sharded compile: {} sub-jobs across {} explorations; \
                     compile latency p50/p99 {:.1}/{:.1} ms",
                    report.shard_jobs,
                    report.explore_jobs,
                    report.compile.p50,
                    report.compile.p99
                );
            }
            if report.calibration_samples > 0 {
                println!(
                    "calibration: {} kernel samples; drift {:.4} -> {:.4}; \
                     {} re-explorations ({} improved, {} rejected by the no-worse gate)",
                    report.calibration_samples,
                    report.drift_before,
                    report.drift_after,
                    report.reexplore_jobs,
                    report.reexplore_improved,
                    report.reexplore_rejected
                );
            }
            if report.wall_elapsed_ms > 0.0 {
                println!(
                    "wall-clock executor: {} compile threads finished the trace in {:.1} ms",
                    threads, report.wall_elapsed_ms
                );
            }
            if let Some(out) = get_flag("--out") {
                match std::fs::write(&out, report.to_json().to_pretty()) {
                    Ok(()) => println!("wrote {out}"),
                    Err(e) => {
                        eprintln!("write {out}: {e}");
                        std::process::exit(1);
                    }
                }
            }
            if let Some(path) = trace_out {
                match svc.trace_dump() {
                    None => {
                        eprintln!("--trace: binary built without the `obs` feature; no trace");
                        std::process::exit(1);
                    }
                    Some(dump) => {
                        let json = fusion_stitching::obs::chrome_trace(&dump).to_pretty();
                        match std::fs::write(&path, json) {
                            Ok(()) => {
                                println!("wrote Chrome trace {path} ({} events)", dump.events.len())
                            }
                            Err(e) => {
                                eprintln!("write {path}: {e}");
                                std::process::exit(1);
                            }
                        }
                    }
                }
            }
        }
        _ => {
            println!("fstitch — FusionStitching (Zheng et al., 2020) reproduction");
            println!(
                "usage: fstitch <list|optimize|inspect|serve|report|hlo|trace|emit|fleet> \
                 [--model NAME] [--device v100|t4] [--iters N] [--dot] [--file HLO] \
                 [--explore] [--tech tf|xla|fs] [--out FILE] [--run] [--v100 N] [--t4 N] \
                 [--capacity C] [--workers K] [--tasks N] [--rate MS] [--templates T] \
                 [--seed S] [--executor virtual|wallclock] [--threads N] [--compile-shards S] \
                 [--calibrate] [--drift-bound R] [--dynamic-shapes] [--observe] [--trace FILE] \
                 [--shards N] [--admission-tick MS] [--tenants N] [--churn] [--inject-faults]"
            );
        }
    }
}

fn find_workload(name: &str) -> Workload {
    workloads::catalog()
        .into_iter()
        .find(|w| w.key().eq_ignore_ascii_case(name))
        .unwrap_or_else(|| {
            eprintln!("unknown model {name}; try `fstitch list`");
            std::process::exit(2);
        })
}

fn pick_device(name: Option<String>) -> DeviceSpec {
    match name.as_deref() {
        Some("t4") | Some("T4") => DeviceSpec::t4(),
        Some("a100") | Some("A100") => DeviceSpec::a100(),
        _ => DeviceSpec::v100(),
    }
}
