//! XLA baseline: the rule-based greedy loop fusion the paper describes
//! (§2.1) and improves upon.
//!
//! Behavioral rules, straight from the paper's characterization:
//!
//! 1. Only **thread composition** — a fused kernel can pass values
//!    between ops only within one thread; no intermediate-value reuse
//!    across threads.
//! 2. Therefore **expensive ops (reductions, transcendentals) may only
//!    appear at the tail of a fusion** (its root); fusing them as
//!    producers would force redundant recomputation per consuming
//!    thread ("XLA avoids re-computation overhead by only allowing
//!    expensive ops appear in the tail of a fusion").
//! 3. Greedy producer-into-consumer merging in reverse topological
//!    order (XLA's instruction-fusion pass), with cycle rejection.
//!
//! This is exactly the behaviour that yields the 4-kernel split of
//! Figure 1 for layer normalization — verified in the tests below.

use crate::explorer::{FusionPattern, FusionPlan};
use crate::graph::{Graph, NodeId};

/// Maximum ops per XLA fusion (XLA caps fusion size; generous here).
const MAX_FUSION_SIZE: usize = 64;

/// Effective fusion-size limit inside while_loop bodies: TF-XLA
/// auto-clustering cuts clusters at loop-carried dependencies and
/// TensorArray accesses, so recurrent models fuse only tiny runs — the
/// mechanism behind Table 2's DIEN rows, where XLA shrinks kernel calls
/// by merely 1.4–1.5× and ends up *slower* than TF once its heavier
/// per-cluster dispatch and extra memcpys are paid.
const RECURRENT_FUSION_SIZE: usize = 2;

/// Run the rule-based greedy fusion pass as the TF-XLA runtime would:
/// clustering is crippled on recurrent (while_loop) graphs.
pub fn plan_for_runtime(graph: &Graph, recurrent: bool) -> FusionPlan {
    plan_with_limit(
        graph,
        if recurrent { RECURRENT_FUSION_SIZE } else { MAX_FUSION_SIZE },
    )
}

/// Run the rule-based greedy fusion pass with the default size cap
/// (what FusionStitching sees as its XLA substrate, §6 — FS's own pass
/// is not subject to the auto-clustering loop limitation).
pub fn plan(graph: &Graph) -> FusionPlan {
    plan_with_limit(graph, MAX_FUSION_SIZE)
}

/// Greedy fusion with an explicit per-fusion op cap.
pub fn plan_with_limit(graph: &Graph, max_fusion_size: usize) -> FusionPlan {
    // fusion_of[node] = index into `fusions` or usize::MAX.
    let mut fusion_of: Vec<usize> = vec![usize::MAX; graph.len()];
    let mut fusions: Vec<Vec<NodeId>> = Vec::new();

    // Walk in reverse topological order; try to merge each node into the
    // fusion of its consumer(s).
    for &id in graph.post_order().iter() {
        let node = graph.node(id);
        if !node.kind.is_fusible()
            || matches!(node.kind, crate::graph::OpKind::Reshape | crate::graph::OpKind::Copy)
        {
            continue;
        }
        // Consumers that are fusible and already in fusions.
        let consumer_fusions: Vec<usize> = graph
            .consumers(id)
            .iter()
            .filter_map(|&c| {
                let f = fusion_of[c.idx()];
                (f != usize::MAX).then_some(f)
            })
            .collect();

        // Rule 2: expensive producers never merge upward — they start
        // their own fusion (they may only be a root).
        let mergeable = !node.kind.is_expensive_producer() && !consumer_fusions.is_empty();

        if mergeable {
            // Merge into the first consumer fusion that accepts the op
            // without creating a cycle. (Real XLA would *duplicate* a
            // light producer into every consumer fusion; merging into
            // one and materializing the output for the others is
            // traffic-equivalent for accounting and keeps plans
            // disjoint.)
            let mut targets = consumer_fusions.clone();
            targets.sort_unstable();
            targets.dedup();
            let mut merged = false;
            for &f in &targets {
                if fusions[f].len() >= max_fusion_size {
                    continue;
                }
                let mut candidate = fusions[f].clone();
                candidate.push(id);
                if graph.fusion_creates_cycle(&candidate) {
                    continue;
                }
                fusion_of[id.idx()] = f;
                fusions[f].push(id);
                merged = true;
                break;
            }
            if merged {
                continue;
            }
        }
        // Start a new fusion rooted here.
        fusion_of[id.idx()] = fusions.len();
        fusions.push(vec![id]);
    }

    let patterns = fusions
        .into_iter()
        .filter(|f| f.len() > 1)
        .map(FusionPattern::new)
        .collect();
    // Baseline personalities never absorb anchors: cut behavior stays
    // bit-stable.
    FusionPlan { patterns, ..Default::default() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, OpClass, OpKind, Shape};
    use crate::workloads::blocks;

    /// The §7.4 / Figure 1 case: XLA must split layer-norm into 4
    /// kernels (two ending in reductions, one ending at the expensive
    /// rsqrt, one tail).
    #[test]
    fn layernorm_splits_into_four_kernels_like_fig1() {
        let mut g = Graph::new("ln");
        let x = g.param(Shape::new(vec![4096, 768]), DType::F32, "x");
        let _ = blocks::layer_norm(&mut g, x, "ln");
        let kernels = plan(&g).kernels(&g);
        assert_eq!(kernels.len(), 4, "kernels: {kernels:?}");
        // No kernel contains a reduction or expensive op as a non-root.
        for k in &kernels {
            for &id in k.nodes() {
                let node = g.node(id);
                if node.kind.is_expensive_producer() {
                    let internal = g.consumers(id).iter().any(|c| k.contains(*c));
                    assert!(!internal, "{} is a mid-kernel expensive producer", node.name);
                }
            }
        }
    }

    #[test]
    fn plain_elementwise_chain_fuses_fully() {
        let mut g = Graph::new("c");
        let p = g.param(Shape::new(vec![1024]), DType::F32, "p");
        let a = g.unary(OpKind::Relu, p, "a");
        let b = g.unary(OpKind::Neg, a, "b");
        let c = g.unary(OpKind::Abs, b, "c");
        let _ = c;
        let kernels = plan(&g).kernels(&g);
        assert_eq!(kernels.len(), 1);
        assert_eq!(kernels[0].len(), 3);
    }

    #[test]
    fn softmax_splits_at_reductions() {
        let mut g = Graph::new("sm");
        let x = g.param(Shape::new(vec![256, 1024]), DType::F32, "x");
        let _ = blocks::softmax(&mut g, x, "sm");
        let kernels = plan(&g).kernels(&g);
        // max-reduce | sub+exp? exp is expensive: exp may not be a
        // producer, so: [max], [sub ... exp], [sum], [div] → 3-4 kernels.
        assert!(kernels.len() >= 3, "got {}", kernels.len());
        let plan_ = plan(&g);
        assert!(plan_.is_disjoint());
    }

    #[test]
    fn fused_patterns_never_contain_gemm() {
        let mut g = Graph::new("mm");
        let a = g.param(Shape::new(vec![64, 64]), DType::F32, "a");
        let b = g.param(Shape::new(vec![64, 64]), DType::F32, "b");
        let c = g.matmul(a, b, "c");
        let r = g.unary(OpKind::Relu, c, "r");
        let s = g.unary(OpKind::Neg, r, "s");
        let _ = s;
        for k in plan(&g).kernels(&g) {
            for &id in k.nodes() {
                assert_ne!(g.node(id).kind.class(), OpClass::ComputeIntensive);
            }
        }
    }

    #[test]
    fn plans_are_valid_on_real_workloads() {
        let w = crate::workloads::models::bert(crate::workloads::Mode::Infer);
        let p = plan(&w.graph);
        assert!(p.is_disjoint());
        for pat in &p.patterns {
            assert!(!w.graph.fusion_creates_cycle(pat.nodes()));
        }
        // Fusion reduces kernel count well below one-per-op.
        let tf_kernels = crate::baselines::tf::plan(&w.graph).kernels(&w.graph).len();
        let xla_kernels = p.kernels(&w.graph).len();
        assert!(
            (xla_kernels as f64) < 0.8 * tf_kernels as f64,
            "xla {xla_kernels} vs tf {tf_kernels}"
        );
    }
}
