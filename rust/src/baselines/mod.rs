//! Baseline execution strategies the paper compares against (§7):
//! TF (kernel-per-op) and XLA (rule-based greedy fusion).

pub mod tf;
pub mod xla;
