//! Stock-TensorFlow baseline: no fusion at all — every memory-intensive
//! op is its own kernel launch. This is the `TF` column of Table 2 and
//! the normalization baseline of Figure 7.

use crate::explorer::FusionPlan;
use crate::graph::Graph;

/// The TF plan: an empty pattern set; `FusionPlan::kernels` then yields
/// one singleton kernel per fusible op.
pub fn plan(_graph: &Graph) -> FusionPlan {
    FusionPlan::default()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, OpKind, Shape};

    #[test]
    fn tf_launches_one_kernel_per_memory_op() {
        let mut g = Graph::new("t");
        let p = g.param(Shape::new(vec![64, 64]), DType::F32, "p");
        let a = g.unary(OpKind::Exp, p, "a");
        let b = g.unary(OpKind::Neg, a, "b");
        let w = g.param(Shape::new(vec![64, 64]), DType::F32, "w");
        let _c = g.matmul(b, w, "c");
        let kernels = plan(&g).kernels(&g);
        assert_eq!(kernels.len(), 2); // exp, neg — matmul is a library call
        assert!(kernels.iter().all(|k| k.len() == 1));
    }
}
