//! PJRT client wrapper: HLO text → compiled executable → execution.
//!
//! Interchange is HLO *text*, not serialized `HloModuleProto`: jax ≥ 0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids and round-trips cleanly (see
//! /opt/xla-example/README.md).

use anyhow::{Context, Result};
use std::path::Path;

/// A compiled HLO module ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Human-readable identity (artifact stem).
    pub name: String,
}

/// Thin wrapper over the PJRT CPU client.
pub struct RuntimeClient {
    client: xla::PjRtClient,
}

impl RuntimeClient {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(RuntimeClient { client })
    }

    /// Platform diagnostic string.
    pub fn platform(&self) -> String {
        format!(
            "{} ({} devices)",
            self.client.platform_name(),
            self.client.device_count()
        )
    }

    /// Load an HLO text file and compile it.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            name: path
                .file_stem()
                .and_then(|s| s.to_str())
                .unwrap_or("module")
                .to_string(),
        })
    }
}

impl Executable {
    /// Execute with f32 buffers of the given shapes; returns the flat f32
    /// outputs of the (tuple) result.
    pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims_i64)
                .context("reshaping input literal")?;
            literals.push(lit);
        }
        let mut result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .context("executing")?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        let result = &mut result;
        // aot.py lowers with return_tuple=True: decompose the tuple.
        let elems = result.decompose_tuple().context("decomposing tuple")?;
        let mut out = Vec::with_capacity(elems.len());
        for e in elems {
            out.push(e.to_vec::<f32>().context("reading f32 output")?);
        }
        Ok(out)
    }
}

// Tests that need real artifacts live in rust/tests/runtime_pjrt.rs
// (they require `make artifacts` to have run).
