//! PJRT client wrapper: HLO text → compiled executable → execution.
//!
//! Interchange is HLO *text*, not serialized `HloModuleProto`: jax ≥ 0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids and round-trips cleanly (see
//! /opt/xla-example/README.md).
//!
//! Two builds of this module exist:
//!
//! * `RUSTFLAGS="--cfg fstitch_pjrt"` (with the vendored `xla` +
//!   `anyhow` crates added to `[dependencies]`) — the real client over
//!   PJRT. A custom cfg rather than a cargo feature: a feature would
//!   need those crates declared as optional dependencies, and even
//!   unactivated optional deps must resolve, which the offline build
//!   cannot do.
//! * default — an API-compatible stub: constructors return an error
//!   explaining how to enable the real backend. Tests and examples all
//!   gate on [`super::artifacts_available`] and skip before touching it.

#[cfg(fstitch_pjrt)]
mod real {
    use anyhow::{Context, Result};
    use std::path::Path;

    /// A compiled HLO module ready to execute.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        /// Human-readable identity (artifact stem).
        pub name: String,
    }

    /// Thin wrapper over the PJRT CPU client.
    pub struct RuntimeClient {
        client: xla::PjRtClient,
    }

    impl RuntimeClient {
        /// Create a CPU PJRT client.
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(RuntimeClient { client })
        }

        /// Platform diagnostic string.
        pub fn platform(&self) -> String {
            format!(
                "{} ({} devices)",
                self.client.platform_name(),
                self.client.device_count()
            )
        }

        /// Load an HLO text file and compile it.
        pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            Ok(Executable {
                exe,
                name: path
                    .file_stem()
                    .and_then(|s| s.to_str())
                    .unwrap_or("module")
                    .to_string(),
            })
        }
    }

    impl Executable {
        /// Execute with f32 buffers of the given shapes; returns the flat
        /// f32 outputs of the (tuple) result.
        pub fn run_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, dims) in inputs {
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(data)
                    .reshape(&dims_i64)
                    .context("reshaping input literal")?;
                literals.push(lit);
            }
            let mut result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .context("executing")?[0][0]
                .to_literal_sync()
                .context("fetching result")?;
            let result = &mut result;
            // aot.py lowers with return_tuple=True: decompose the tuple.
            let elems = result.decompose_tuple().context("decomposing tuple")?;
            let mut out = Vec::with_capacity(elems.len());
            for e in elems {
                out.push(e.to_vec::<f32>().context("reading f32 output")?);
            }
            Ok(out)
        }
    }
}

#[cfg(fstitch_pjrt)]
pub use real::{Executable, RuntimeClient};

#[cfg(not(fstitch_pjrt))]
mod stub {
    use super::super::{RuntimeError, RuntimeResult};
    use std::path::Path;

    const UNAVAILABLE: &str = "PJRT backend unavailable: this build has no `xla` crate \
         (offline vendored set). Add the vendored `xla`/`anyhow` deps and rebuild with \
         RUSTFLAGS=\"--cfg fstitch_pjrt\" to execute HLO artifacts";

    /// A compiled HLO module ready to execute (stub: never constructed).
    pub struct Executable {
        /// Human-readable identity (artifact stem).
        pub name: String,
        _private: (),
    }

    /// Thin wrapper over the PJRT CPU client (stub).
    pub struct RuntimeClient {
        _private: (),
    }

    impl RuntimeClient {
        /// Create a CPU PJRT client. Always fails in the offline build.
        pub fn cpu() -> RuntimeResult<Self> {
            Err(RuntimeError(UNAVAILABLE.to_string()))
        }

        /// Platform diagnostic string.
        pub fn platform(&self) -> String {
            "pjrt-stub (0 devices)".to_string()
        }

        /// Load an HLO text file and compile it. Unreachable in practice
        /// (no client can be constructed), kept for API parity.
        pub fn load_hlo_text(&self, _path: &Path) -> RuntimeResult<Executable> {
            Err(RuntimeError(UNAVAILABLE.to_string()))
        }
    }

    impl Executable {
        /// Execute with f32 buffers of the given shapes (stub).
        pub fn run_f32(&self, _inputs: &[(&[f32], &[usize])]) -> RuntimeResult<Vec<Vec<f32>>> {
            Err(RuntimeError(UNAVAILABLE.to_string()))
        }
    }
}

#[cfg(not(fstitch_pjrt))]
pub use stub::{Executable, RuntimeClient};

// Tests that need real artifacts live in rust/tests/runtime_pjrt.rs
// (they require `make artifacts` to have run).

#[cfg(test)]
mod tests {
    #[cfg(not(fstitch_pjrt))]
    #[test]
    fn stub_client_reports_unavailable() {
        let err = super::RuntimeClient::cpu().err().expect("stub must error");
        assert!(err.0.contains("pjrt"), "{err}");
    }
}
