//! PJRT runtime: load AOT-lowered HLO text artifacts and execute them.
//!
//! This is the numeric half of the reproduction: `python/compile/aot.py`
//! lowers the JAX/Pallas workloads (fused and unfused layer-norm,
//! softmax, MLP) to HLO **text** once at build time (`make artifacts`);
//! the functions here compile and run them on the PJRT CPU client from
//! the `xla` crate — Python never executes on the request path.
//!
//! The `xla` crate (and its `anyhow` error glue) is not part of the
//! offline vendored set, so the real client lives behind the custom
//! `fstitch_pjrt` cfg (see `rust/Cargo.toml` for why it is not a cargo
//! feature and how to enable it). The default build ships an
//! API-compatible stub whose constructors return a descriptive error;
//! every test and example checks [`artifacts_available`] first and
//! skips gracefully, so the crate builds and tests end-to-end without
//! PJRT.

pub mod artifacts;
pub mod client;

pub use artifacts::{artifact_path, artifacts_available, ArtifactSet};
pub use client::{Executable, RuntimeClient};

/// Runtime-layer error: a plain message (the offline build has no
/// `anyhow`; the `pjrt` build converts foreign errors into this).
#[derive(Debug, Clone)]
pub struct RuntimeError(pub String);

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

/// Result alias used by the runtime layer.
pub type RuntimeResult<T> = std::result::Result<T, RuntimeError>;
