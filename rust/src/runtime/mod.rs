//! PJRT runtime: load AOT-lowered HLO text artifacts and execute them.
//!
//! This is the numeric half of the reproduction: `python/compile/aot.py`
//! lowers the JAX/Pallas workloads (fused and unfused layer-norm,
//! softmax, MLP) to HLO **text** once at build time (`make artifacts`);
//! the functions here compile and run them on the PJRT CPU client from
//! the `xla` crate — Python never executes on the request path.

pub mod artifacts;
pub mod client;

pub use artifacts::{artifact_path, artifacts_available, ArtifactSet};
pub use client::{Executable, RuntimeClient};
