//! Artifact discovery: the HLO text files `make artifacts` produces.

use std::path::{Path, PathBuf};

/// Default artifacts directory: `$FSTITCH_ARTIFACTS` or `artifacts/`
/// relative to the workspace root.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("FSTITCH_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    // Walk up from CWD looking for an `artifacts` directory (tests run
    // from the workspace root; examples may run elsewhere).
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = cur.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !cur.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

/// Path of one artifact by stem, e.g. `ln_fused` →
/// `artifacts/ln_fused.hlo.txt`.
pub fn artifact_path(stem: &str) -> PathBuf {
    artifacts_dir().join(format!("{stem}.hlo.txt"))
}

/// True when the given artifact stems all exist (used by tests/examples
/// to skip gracefully before `make artifacts`).
pub fn artifacts_available(stems: &[&str]) -> bool {
    stems.iter().all(|s| artifact_path(s).is_file())
}

/// The artifact set the serving example and benches rely on.
#[derive(Debug, Clone)]
pub struct ArtifactSet;

impl ArtifactSet {
    /// Fused layer-norm (FusionStitching outcome: one module).
    pub const LN_FUSED: &str = "ln_fused";
    /// Pure-jnp oracle module for parity checks.
    pub const LN_REFERENCE: &str = "ln_reference";
    /// The 4-kernel XLA partition of Fig. 1, one module per kernel.
    pub const LN_PART1: &str = "ln_part1_sum";
    pub const LN_PART2: &str = "ln_part2_var";
    pub const LN_PART3: &str = "ln_part3_rsqrt";
    pub const LN_PART4: &str = "ln_part4_scale";
    /// Fused softmax.
    pub const SOFTMAX_FUSED: &str = "softmax_fused";
    /// MLP block (GEMM + bias + GELU + layer-norm).
    pub const MLP_BLOCK: &str = "mlp_block";
    /// Transformer encoder layer forward.
    pub const ENCODER_LAYER: &str = "encoder_layer";
    /// Stitched bias+GELU kernel.
    pub const GELU_BIAS_FUSED: &str = "gelu_bias_fused";
    /// Stitched softmax cross-entropy head (FS outcome: one kernel).
    pub const XENT_FUSED: &str = "softmax_xent_fused";
    /// The same loss head lowered as straight jnp (XLA-style splits).
    pub const XENT_UNFUSED: &str = "softmax_xent_unfused";
    /// Stitched residual-add + layer-norm epilogue.
    pub const RESIDUAL_LN_FUSED: &str = "residual_ln_fused";
    /// Stitched per-head attention (MXU/VPU block composition).
    pub const ATTENTION_FUSED: &str = "attention_fused";

    /// All stems, for availability checks.
    pub fn all() -> Vec<&'static str> {
        vec![
            Self::LN_FUSED,
            Self::LN_REFERENCE,
            Self::LN_PART1,
            Self::LN_PART2,
            Self::LN_PART3,
            Self::LN_PART4,
            Self::SOFTMAX_FUSED,
            Self::MLP_BLOCK,
            Self::ENCODER_LAYER,
            Self::GELU_BIAS_FUSED,
            Self::XENT_FUSED,
            Self::XENT_UNFUSED,
            Self::RESIDUAL_LN_FUSED,
            Self::ATTENTION_FUSED,
        ]
    }
}

/// Check a specific path exists (helper for error messages).
pub fn require(path: &Path) -> super::RuntimeResult<()> {
    if path.is_file() {
        Ok(())
    } else {
        Err(super::RuntimeError(format!(
            "artifact {} missing — run `make artifacts` first",
            path.display()
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_path_shape() {
        let p = artifact_path("ln_fused");
        assert!(p.to_string_lossy().ends_with("artifacts/ln_fused.hlo.txt"));
    }

    #[test]
    fn all_stems_unique() {
        let all = ArtifactSet::all();
        let mut dedup = all.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(all.len(), dedup.len());
    }

    #[test]
    fn availability_false_for_missing() {
        assert!(!artifacts_available(&["definitely_not_a_real_artifact"]));
    }
}
