//! The flight-recorder core: per-thread event rings with typed span
//! events keyed by task id.
//!
//! Hot-path cost is one relaxed atomic index bump plus one array slot
//! write — no allocation, no locking, no clock read beyond what the
//! caller already has. Each [`Ring`] has exactly one writer thread
//! (enforced by protocol, see [`Recorder::ring`]); readers drain only
//! after every writer has quiesced (the fleet drains after the
//! wall-clock pool has joined, or from the single dispatcher thread in
//! virtual mode). With the `obs` cargo feature disabled, [`ENABLED`] is
//! `false` and [`TrackHandle::record`] compiles to a no-op.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::lock_recover;

/// Compile-time switch: `false` when built with `--no-default-features`
/// (the recorder's hot-path stores fold away entirely).
pub const ENABLED: bool = cfg!(feature = "obs");

/// Process lane for the virtual timeline (identical across executors).
pub const VIRTUAL_PID: u32 = 1;
/// Process lane for wall-clock measurements (threads, barrier stalls).
pub const WALL_PID: u32 = 2;

/// A typed flight-recorder event. Spans carry a nonzero `dur_us`;
/// instants and counters carry zero.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// Instant: the admission decision for a task (tenant-tagged).
    TaskAdmitted { decision: &'static str, tenant: u32 },
    /// Span: task arrival → serving-slot start.
    QueueWait,
    /// Instant: an exploration sub-job entered the compile schedule.
    ExploreStart { shard: u32, shards: u32 },
    /// Instant: that sub-job finished.
    ExploreEnd { shard: u32, shards: u32 },
    /// Span: a launch-dim-only retune ("port" or "bucket").
    Retune { tier: &'static str },
    /// Span: a drift-triggered re-exploration.
    Reexplore,
    /// Instant: a plan (or pinned fallback) was published.
    Publish,
    /// Span: the dispatcher stalled on the publication barrier
    /// (wall-clock executor only — virtual time never blocks).
    BarrierWait,
    /// Instant: a serving session hot-swapped to a published plan.
    HotSwap,
    /// Span: a task's serving window on its device.
    Serve { device: u32 },
    /// Instant: an in-flight session migrated off a departing device
    /// (churn Leave or an injected Kill).
    Migrate { from: u32, to: u32 },
    /// Counter: a calibration measured/predicted drift-ratio sample.
    DriftSample { ratio: f64 },
}

impl EventKind {
    /// Stable display name (Chrome trace event name).
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::TaskAdmitted { .. } => "TaskAdmitted",
            EventKind::QueueWait => "QueueWait",
            EventKind::ExploreStart { .. } | EventKind::ExploreEnd { .. } => "Explore",
            EventKind::Retune { .. } => "Retune",
            EventKind::Reexplore => "Reexplore",
            EventKind::Publish => "Publish",
            EventKind::BarrierWait => "BarrierWait",
            EventKind::HotSwap => "HotSwap",
            EventKind::Serve { .. } => "Serve",
            EventKind::Migrate { .. } => "Migrate",
            EventKind::DriftSample { .. } => "drift_ratio",
        }
    }
}

/// One recorded event on a logical track.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    /// Logical lane (see [`Recorder::add_track`]).
    pub track: u32,
    /// Task id for lifecycle events, graph key for compile-side events.
    pub id: u64,
    pub kind: EventKind,
    /// Start timestamp in microseconds (virtual-timeline events use
    /// virtual ms × 1000; wall events use µs since the pool epoch).
    pub ts_us: f64,
    /// Span duration in microseconds; 0 for instants and counters.
    pub dur_us: f64,
}

/// A fixed-capacity single-writer ring of events. Overwrites the oldest
/// entries when full (flight-recorder semantics: the tail of the run is
/// always retained).
struct Ring {
    slots: Box<[Slot]>,
    head: AtomicUsize,
}

struct Slot(UnsafeCell<Option<Event>>);

// SAFETY: slots are written by exactly one thread (the ring's owner, by
// the `Recorder::ring` protocol) and read only after that writer has
// quiesced, so there is never a concurrent read/write on the same cell.
unsafe impl Sync for Slot {}

impl Ring {
    fn new(cap: usize) -> Ring {
        let cap = cap.max(1);
        let mut slots = Vec::with_capacity(cap);
        slots.resize_with(cap, || Slot(UnsafeCell::new(None)));
        Ring { slots: slots.into_boxed_slice(), head: AtomicUsize::new(0) }
    }

    #[inline]
    fn record(&self, ev: Event) {
        let i = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[i % self.slots.len()];
        // SAFETY: single-writer protocol (see `Slot`).
        unsafe { *slot.0.get() = Some(ev) };
    }

    /// Events in write order (oldest retained first). Caller must
    /// guarantee the writer has quiesced.
    fn drain(&self) -> (Vec<Event>, usize, usize) {
        let head = self.head.load(Ordering::Acquire);
        let cap = self.slots.len();
        let read = |i: usize| -> Option<Event> {
            // SAFETY: the writer has quiesced (drain protocol).
            unsafe { *self.slots[i % cap].0.get() }
        };
        let (first, count) = if head <= cap { (0, head) } else { (head - cap, cap) };
        let events: Vec<Event> = (first..first + count).filter_map(read).collect();
        (events, head, head.saturating_sub(cap))
    }
}

/// A cheap cloneable writer handle bound to one ring. Clones share the
/// ring, so all clones must stay on the owning thread.
#[derive(Clone)]
pub struct TrackHandle {
    ring: Arc<Ring>,
}

impl TrackHandle {
    /// Record one event: one relaxed atomic bump + one slot write.
    #[inline]
    pub fn record(&self, ev: Event) {
        if !ENABLED {
            return;
        }
        self.ring.record(ev);
    }
}

impl std::fmt::Debug for TrackHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TrackHandle").finish()
    }
}

/// A named logical lane events are attributed to (one per compile
/// worker / serving thread / device / dispatcher).
#[derive(Debug, Clone)]
pub struct TrackInfo {
    pub name: String,
    /// [`VIRTUAL_PID`] or [`WALL_PID`].
    pub pid: u32,
}

/// The drained recorder state, ready for export.
#[derive(Debug, Clone)]
pub struct TraceDump {
    pub tracks: Vec<TrackInfo>,
    /// Ring contents concatenated in ring-registration order, each ring
    /// in write order.
    pub events: Vec<Event>,
    /// Events ever recorded (before ring wraparound losses).
    pub recorded: usize,
    /// Events lost to wraparound.
    pub dropped: usize,
}

/// The flight recorder: a registry of tracks plus per-thread rings.
///
/// Track registration and ring creation take a mutex (cold path, done
/// at startup); recording itself never does.
#[derive(Debug)]
pub struct Recorder {
    ring_cap: usize,
    state: Mutex<RecorderState>,
}

#[derive(Debug, Default)]
struct RecorderState {
    tracks: Vec<TrackInfo>,
    rings: Vec<Arc<Ring>>,
}

impl std::fmt::Debug for Ring {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Ring").field("cap", &self.slots.len()).finish()
    }
}

impl Recorder {
    /// `ring_cap` = events retained per ring before the oldest are
    /// overwritten.
    pub fn new(ring_cap: usize) -> Recorder {
        Recorder { ring_cap, state: Mutex::new(RecorderState::default()) }
    }

    /// Register a logical track; returns its id (the Chrome `tid`).
    pub fn add_track(&self, name: impl Into<String>, pid: u32) -> u32 {
        let mut st = lock_recover(&self.state);
        st.tracks.push(TrackInfo { name: name.into(), pid });
        (st.tracks.len() - 1) as u32
    }

    /// Create a ring and hand back its writer handle. Protocol: the
    /// handle (and its clones) must only be used from one thread, and
    /// [`Recorder::drain`] must only run after all writers quiesced.
    pub fn ring(&self) -> TrackHandle {
        let ring = Arc::new(Ring::new(self.ring_cap));
        lock_recover(&self.state).rings.push(Arc::clone(&ring));
        TrackHandle { ring }
    }

    /// Collect every ring's events. Caller must guarantee all writer
    /// threads have quiesced (in the fleet: after pool shutdown).
    pub fn drain(&self) -> TraceDump {
        let st = lock_recover(&self.state);
        let mut events = Vec::new();
        let (mut recorded, mut dropped) = (0usize, 0usize);
        for ring in &st.rings {
            let (evs, rec, drop) = ring.drain();
            events.extend(evs);
            recorded += rec;
            dropped += drop;
        }
        TraceDump { tracks: st.tracks.clone(), events, recorded, dropped }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(id: u64, ts: f64) -> Event {
        Event { track: 0, id, kind: EventKind::Publish, ts_us: ts, dur_us: 0.0 }
    }

    #[test]
    fn records_in_order_and_counts() {
        let r = Recorder::new(8);
        let t = r.add_track("dispatcher", VIRTUAL_PID);
        assert_eq!(t, 0);
        let h = r.ring();
        for i in 0..5 {
            h.record(ev(i, i as f64));
        }
        let d = r.drain();
        if ENABLED {
            assert_eq!(d.recorded, 5);
            assert_eq!(d.dropped, 0);
            assert_eq!(d.events.len(), 5);
            assert!(d.events.windows(2).all(|w| w[0].id < w[1].id));
        } else {
            assert_eq!(d.recorded, 0);
        }
        assert_eq!(d.tracks.len(), 1);
        assert_eq!(d.tracks[0].pid, VIRTUAL_PID);
    }

    #[test]
    fn wraparound_keeps_tail_and_counts_drops() {
        if !ENABLED {
            return;
        }
        let r = Recorder::new(4);
        let h = r.ring();
        for i in 0..10u64 {
            h.record(ev(i, i as f64));
        }
        let d = r.drain();
        assert_eq!(d.recorded, 10);
        assert_eq!(d.dropped, 6);
        let ids: Vec<u64> = d.events.iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![6, 7, 8, 9], "tail of the run is retained");
    }

    #[test]
    fn per_thread_rings_merge_on_drain() {
        if !ENABLED {
            return;
        }
        let r = Arc::new(Recorder::new(64));
        let handles: Vec<_> = (0..4u64)
            .map(|w| {
                let h = r.ring();
                std::thread::spawn(move || {
                    for i in 0..16u64 {
                        h.record(ev(w * 100 + i, i as f64));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let d = r.drain();
        assert_eq!(d.recorded, 64);
        assert_eq!(d.dropped, 0);
        assert_eq!(d.events.len(), 64);
    }

    #[test]
    fn identical_write_sequences_drain_identically() {
        // The byte-identical-replay property rests on this: same events
        // in, same dump out.
        let run = || {
            let r = Recorder::new(16);
            r.add_track("d", VIRTUAL_PID);
            let h = r.ring();
            for i in 0..20u64 {
                h.record(ev(i, i as f64 * 1.5));
            }
            r.drain()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.events, b.events);
        assert_eq!((a.recorded, a.dropped), (b.recorded, b.dropped));
    }
}
