//! Stage attribution: each task's timeline decomposed into
//! admission → queue → compile (explore / port / bucket-retune /
//! re-explore) → publication-barrier stall → serve, summarized as
//! per-stage p50/p99 plus a per-device serving timeline.
//!
//! All stage samples except `barrier` come from virtual-time
//! bookkeeping, so they are identical across executors and across
//! replays; `barrier` is the wall-clock dispatcher stall and is zero
//! under the virtual executor by construction.

use crate::obs::contention::LockSnapshot;
use crate::util::{summarize_owned, JsonValue, Summary, Table};

/// Compile-stage tiers (matching the plan store's reuse tiers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompileStage {
    Explore,
    Port,
    Bucket,
    Reexplore,
}

/// Accumulates per-stage latency samples while a trace replays.
#[derive(Debug, Default)]
pub struct StageAccum {
    queue: Vec<f64>,
    serve: Vec<f64>,
    e2e: Vec<f64>,
    explore: Vec<f64>,
    port: Vec<f64>,
    bucket: Vec<f64>,
    reexplore: Vec<f64>,
    barrier: Vec<f64>,
    device_serve: Vec<Vec<f64>>,
    device_first: Vec<f64>,
    device_last: Vec<f64>,
}

impl StageAccum {
    pub fn new(devices: usize) -> StageAccum {
        StageAccum {
            device_serve: vec![Vec::new(); devices],
            device_first: vec![f64::INFINITY; devices],
            device_last: vec![0.0; devices],
            ..Default::default()
        }
    }

    /// Record one admitted task's timeline: queue wait, serving span on
    /// `device` from `start_ms` to `end_ms`, and end-to-end latency
    /// (arrival → completion). `queue + serve == e2e` by construction
    /// of the virtual bookkeeping; the report re-checks it.
    pub fn task(&mut self, device: usize, queue_ms: f64, start_ms: f64, end_ms: f64) {
        let serve = end_ms - start_ms;
        self.queue.push(queue_ms);
        self.serve.push(serve);
        self.e2e.push(queue_ms + serve);
        if let Some(d) = self.device_serve.get_mut(device) {
            d.push(serve);
            self.device_first[device] = self.device_first[device].min(start_ms);
            self.device_last[device] = self.device_last[device].max(end_ms);
        }
    }

    /// Record one compile job's enqueue→ready latency by tier.
    pub fn compile(&mut self, stage: CompileStage, ms: f64) {
        match stage {
            CompileStage::Explore => self.explore.push(ms),
            CompileStage::Port => self.port.push(ms),
            CompileStage::Bucket => self.bucket.push(ms),
            CompileStage::Reexplore => self.reexplore.push(ms),
        }
    }

    /// Record one dispatcher publication-barrier stall (wall-clock
    /// executor only).
    pub fn barrier_wait(&mut self, ms: f64) {
        self.barrier.push(ms);
    }

    /// Summarize into a report. `locks` is the contention profile,
    /// `recorded`/`dropped` come from the event recorder.
    pub fn report(&self, locks: Vec<LockSnapshot>, recorded: usize, dropped: usize) -> ObsReport {
        let row = |name: &'static str, samples: &[f64]| StageRow {
            name,
            total_ms: samples.iter().sum(),
            summary: summarize_owned(samples.to_vec()),
        };
        let per_device = self
            .device_serve
            .iter()
            .enumerate()
            .map(|(d, serves)| DeviceLane {
                device: d,
                first_start_ms: if serves.is_empty() { 0.0 } else { self.device_first[d] },
                last_end_ms: self.device_last[d],
                serve: summarize_owned(serves.clone()),
            })
            .collect();
        ObsReport {
            stages: vec![
                row("queue", &self.queue),
                row("compile_explore", &self.explore),
                row("compile_port", &self.port),
                row("compile_bucket", &self.bucket),
                row("compile_reexplore", &self.reexplore),
                row("barrier", &self.barrier),
                row("serve", &self.serve),
                row("e2e", &self.e2e),
            ],
            per_device,
            locks,
            events_recorded: recorded,
            events_dropped: dropped,
        }
    }
}

/// One stage's latency attribution.
#[derive(Debug, Clone)]
pub struct StageRow {
    pub name: &'static str,
    pub total_ms: f64,
    pub summary: Summary,
}

/// One device's serving timeline.
#[derive(Debug, Clone)]
pub struct DeviceLane {
    pub device: usize,
    pub first_start_ms: f64,
    pub last_end_ms: f64,
    pub serve: Summary,
}

/// The observability section of a fleet report: stage attribution,
/// per-device timelines, the lock-contention profile, and recorder
/// accounting.
#[derive(Debug, Clone)]
pub struct ObsReport {
    pub stages: Vec<StageRow>,
    pub per_device: Vec<DeviceLane>,
    pub locks: Vec<LockSnapshot>,
    pub events_recorded: usize,
    pub events_dropped: usize,
}

impl ObsReport {
    pub fn stage(&self, name: &str) -> Option<&StageRow> {
        self.stages.iter().find(|s| s.name == name)
    }

    pub fn lock(&self, name: &str) -> Option<&LockSnapshot> {
        self.locks.iter().find(|l| l.name == name)
    }

    pub fn to_json(&self) -> JsonValue {
        let mut stages = JsonValue::obj();
        for s in &self.stages {
            let mut o = JsonValue::obj();
            o.set("count", s.summary.n)
                .set("total_ms", s.total_ms)
                .set("p50_ms", s.summary.p50)
                .set("p99_ms", s.summary.p99)
                .set("max_ms", s.summary.max);
            stages.set(s.name, o);
        }
        let mut locks = JsonValue::obj();
        for l in &self.locks {
            locks.set(l.name, l.to_json());
        }
        let devices: Vec<JsonValue> = self
            .per_device
            .iter()
            .map(|d| {
                let mut o = JsonValue::obj();
                o.set("device", d.device)
                    .set("first_start_ms", d.first_start_ms)
                    .set("last_end_ms", d.last_end_ms)
                    .set("serve_count", d.serve.n)
                    .set("serve_p50_ms", d.serve.p50)
                    .set("serve_p99_ms", d.serve.p99);
                o
            })
            .collect();
        let mut events = JsonValue::obj();
        events.set("recorded", self.events_recorded).set("dropped", self.events_dropped);
        let mut o = JsonValue::obj();
        o.set("stages", stages)
            .set("per_device", JsonValue::Arr(devices))
            .set("locks", locks)
            .set("events", events);
        o
    }

    /// The stage-attribution + lock-contention tables for terminal
    /// reports.
    pub fn render(&self) -> String {
        let mut t = Table::new(vec!["stage", "count", "total ms", "p50 ms", "p99 ms"]);
        for s in &self.stages {
            t.row(vec![
                s.name.to_string(),
                s.summary.n.to_string(),
                format!("{:.1}", s.total_ms),
                format!("{:.3}", s.summary.p50),
                format!("{:.3}", s.summary.p99),
            ]);
        }
        let mut l = Table::new(vec!["lock", "acquisitions", "contended", "blocked ms"]);
        for s in &self.locks {
            l.row(vec![
                s.name.to_string(),
                s.acquisitions.to_string(),
                s.contended.to_string(),
                format!("{:.3}", s.blocked_ms),
            ]);
        }
        format!(
            "stage attribution ({} events, {} dropped):\n{}\nlock contention:\n{}",
            self.events_recorded,
            self.events_dropped,
            t.render(),
            l.render()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_plus_serve_equals_e2e() {
        let mut a = StageAccum::new(2);
        a.task(0, 2.0, 12.0, 20.0);
        a.task(1, 0.0, 5.0, 9.5);
        a.task(0, 1.5, 21.0, 30.0);
        a.compile(CompileStage::Explore, 40.0);
        a.compile(CompileStage::Port, 4.0);
        let r = a.report(vec![LockSnapshot::zero("plan_store")], 10, 0);
        let total = |n: &str| r.stage(n).unwrap().total_ms;
        assert!((total("queue") + total("serve") - total("e2e")).abs() < 1e-9);
        assert_eq!(r.stage("queue").unwrap().summary.n, 3);
        assert_eq!(r.stage("compile_explore").unwrap().summary.n, 1);
        assert_eq!(r.stage("barrier").unwrap().summary.n, 0);
        assert_eq!(r.per_device.len(), 2);
        assert_eq!(r.per_device[0].serve.n, 2);
        assert_eq!(r.per_device[0].first_start_ms, 12.0);
        assert_eq!(r.per_device[0].last_end_ms, 30.0);
        assert_eq!(r.lock("plan_store").unwrap().acquisitions, 0);
    }

    #[test]
    fn json_and_render_cover_all_stages() {
        let mut a = StageAccum::new(1);
        a.task(0, 1.0, 3.0, 7.0);
        a.barrier_wait(0.5);
        let r = a.report(vec![LockSnapshot::zero("work_queue")], 4, 1);
        let j = r.to_json().to_string();
        for key in [
            "queue",
            "compile_explore",
            "compile_port",
            "compile_bucket",
            "compile_reexplore",
            "barrier",
            "serve",
            "e2e",
            "p50_ms",
            "p99_ms",
            "work_queue",
            "blocked_ms",
            "per_device",
            "recorded",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        let rendered = r.render();
        assert!(rendered.contains("stage attribution"));
        assert!(rendered.contains("lock contention"));
        assert!(rendered.contains("work_queue"));
    }

    #[test]
    fn empty_accum_reports_zero_rows() {
        let r = StageAccum::new(0).report(Vec::new(), 0, 0);
        assert_eq!(r.stage("e2e").unwrap().summary.n, 0);
        assert_eq!(r.stage("e2e").unwrap().total_ms, 0.0);
        assert!(r.per_device.is_empty());
    }
}
