//! Chrome trace-event JSON export for flight-recorder dumps —
//! loadable in Perfetto (<https://ui.perfetto.dev>) or
//! `chrome://tracing`, same array-of-events format as
//! [`crate::gpu::trace`]'s kernel timelines.
//!
//! Two process lanes: pid 1 is the **virtual timeline** (recorded by
//! the dispatcher from virtual timestamps — identical across executors
//! and replays), pid 2 is the **wall clock** (compile worker / serving
//! threads, dispatcher barrier stalls). Spans are `ph:"X"`, explore
//! sub-jobs are `ph:"B"`/`"E"` pairs, publications and hot-swaps are
//! instants, drift samples are `ph:"C"` counters.

use crate::obs::recorder::{EventKind, TraceDump, VIRTUAL_PID, WALL_PID};
use crate::util::JsonValue;

/// Build the Chrome trace-event array for a drained recorder.
pub fn chrome_trace(dump: &TraceDump) -> JsonValue {
    let mut events: Vec<JsonValue> = Vec::with_capacity(dump.events.len() + dump.tracks.len() + 2);

    for (pid, name) in [
        (VIRTUAL_PID, "fleet (virtual timeline)"),
        (WALL_PID, "fleet (wall clock)"),
    ] {
        let mut args = JsonValue::obj();
        args.set("name", name);
        let mut meta = JsonValue::obj();
        meta.set("name", "process_name").set("ph", "M").set("pid", pid as i64).set("args", args);
        events.push(meta);
    }
    for (tid, track) in dump.tracks.iter().enumerate() {
        let mut args = JsonValue::obj();
        args.set("name", track.name.clone());
        let mut meta = JsonValue::obj();
        meta.set("name", "thread_name")
            .set("ph", "M")
            .set("pid", track.pid as i64)
            .set("tid", tid as i64)
            .set("args", args);
        events.push(meta);
    }

    for ev in &dump.events {
        let pid = dump.tracks.get(ev.track as usize).map(|t| t.pid).unwrap_or(VIRTUAL_PID);
        let mut args = JsonValue::obj();
        args.set("id", ev.id as i64);
        let ph = match ev.kind {
            EventKind::TaskAdmitted { decision, tenant } => {
                args.set("decision", decision).set("tenant", tenant as i64);
                "i"
            }
            EventKind::Migrate { from, to } => {
                args.set("from", from as i64).set("to", to as i64);
                "i"
            }
            EventKind::ExploreStart { shard, shards } => {
                args.set("shard", shard as i64).set("shards", shards as i64);
                "B"
            }
            EventKind::ExploreEnd { shard, shards } => {
                args.set("shard", shard as i64).set("shards", shards as i64);
                "E"
            }
            EventKind::Retune { tier } => {
                args.set("tier", tier);
                "X"
            }
            EventKind::Serve { device } => {
                args.set("device", device as i64);
                "X"
            }
            EventKind::DriftSample { ratio } => {
                args = JsonValue::obj();
                args.set("ratio", ratio);
                "C"
            }
            EventKind::QueueWait | EventKind::Reexplore | EventKind::BarrierWait => "X",
            EventKind::Publish | EventKind::HotSwap => "i",
        };
        let mut o = JsonValue::obj();
        o.set("name", ev.kind.name())
            .set("ph", ph)
            .set("pid", pid as i64)
            .set("tid", ev.track as i64)
            .set("ts", ev.ts_us)
            .set("args", args);
        if ph == "X" {
            o.set("dur", ev.dur_us);
        }
        if ph == "i" {
            // Thread-scoped instant marker.
            o.set("s", "t");
        }
        events.push(o);
    }

    JsonValue::Arr(events)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::recorder::{Event, Recorder};

    #[test]
    fn chrome_export_has_metadata_spans_and_counters() {
        if !crate::obs::recorder::ENABLED {
            return;
        }
        let r = Recorder::new(32);
        let disp = r.add_track("dispatcher", VIRTUAL_PID);
        let dev = r.add_track("device-0", VIRTUAL_PID);
        let h = r.ring();
        let ev = |track, kind, ts_us, dur_us| Event { track, id: 1, kind, ts_us, dur_us };
        h.record(ev(disp, EventKind::TaskAdmitted { decision: "admit", tenant: 0 }, 0.0, 0.0));
        h.record(ev(dev, EventKind::QueueWait, 0.0, 500.0));
        h.record(ev(disp, EventKind::ExploreStart { shard: 0, shards: 2 }, 10.0, 0.0));
        h.record(ev(disp, EventKind::ExploreEnd { shard: 0, shards: 2 }, 900.0, 0.0));
        h.record(ev(disp, EventKind::Publish, 900.0, 0.0));
        h.record(ev(dev, EventKind::Serve { device: 0 }, 500.0, 4000.0));
        h.record(ev(disp, EventKind::DriftSample { ratio: 1.2 }, 4500.0, 0.0));
        let json = chrome_trace(&r.drain());
        let s = json.to_string();
        assert!(s.starts_with('['));
        for needle in [
            "\"process_name\"",
            "\"thread_name\"",
            "\"TaskAdmitted\"",
            "\"QueueWait\"",
            "\"Explore\"",
            "\"Publish\"",
            "\"Serve\"",
            "\"drift_ratio\"",
            "\"ph\":\"B\"",
            "\"ph\":\"E\"",
            "\"ph\":\"X\"",
            "\"ph\":\"C\"",
        ] {
            assert!(s.contains(needle), "missing {needle} in {s}");
        }
        // Structurally parseable by our own reader (a stand-in for the
        // jq gate in CI).
        let parsed = JsonValue::parse(&s).expect("chrome trace must round-trip");
        match parsed {
            JsonValue::Arr(items) => assert!(items.len() >= 9),
            other => panic!("expected array, got {other:?}"),
        }
    }

    #[test]
    fn identical_dumps_export_identical_json() {
        if !crate::obs::recorder::ENABLED {
            return;
        }
        let run = || {
            let r = Recorder::new(16);
            let t = r.add_track("dispatcher", VIRTUAL_PID);
            let h = r.ring();
            for i in 0..8u64 {
                h.record(Event {
                    track: t,
                    id: i,
                    kind: EventKind::Publish,
                    ts_us: i as f64 * 3.0,
                    dur_us: 0.0,
                });
            }
            chrome_trace(&r.drain()).to_string()
        };
        assert_eq!(run(), run());
    }
}
