//! Flight-recorder observability for the serving fleet (§7's
//! production claim needs attribution, not just aggregates).
//!
//! Four pieces:
//!
//! - [`recorder`] — per-thread event rings with typed span events
//!   (`TaskAdmitted`, `QueueWait`, `ExploreStart/End`, `Retune`,
//!   `Publish`, `BarrierWait`, `HotSwap`, `Serve`, drift counters)
//!   keyed by task id. Hot path: one relaxed atomic bump + one slot
//!   write; compiled to a no-op without the `obs` cargo feature.
//! - [`stages`] — each task's timeline decomposed into admission →
//!   queue → compile (per tier) → publication-barrier stall → serve,
//!   with per-stage p50/p99 and a per-device timeline folded into
//!   `fleet::FleetReport` and `BENCH_fleet.json`'s `observability`
//!   section.
//! - [`contention`] — acquisition counts and blocked wall time for the
//!   fleet's hot locks (plan store, work-stealing deques, publication
//!   barrier, `ServiceMetrics`) — the profile the dispatcher-sharding
//!   roadmap item needs.
//! - [`chrome`] — Chrome trace-event JSON export
//!   (`fstitch fleet --trace out.json`, Perfetto-loadable), one track
//!   per compile worker / serving thread / device.
//!
//! Recording never perturbs scheduling decisions: every virtual-
//! timeline event is derived from bookkeeping the dispatcher already
//! computes, and wall-clock measurement happens only where virtual
//! time never looks (barrier stalls, lock contention, pool threads).
//! The virtual/wall-clock decision-equivalence tests run with tracing
//! enabled to pin that property.

pub mod chrome;
pub mod contention;
pub mod recorder;
pub mod stages;

pub use chrome::chrome_trace;
pub use contention::{LockSnapshot, LockStats};
pub use recorder::{Event, EventKind, Recorder, TraceDump, TrackHandle, VIRTUAL_PID, WALL_PID};
pub use stages::{CompileStage, ObsReport, StageAccum};

/// True when the crate was built with the `obs` feature (default): the
/// recorder's hot path is live. When false, `FleetOptions::observe` is
/// ignored and no observability section is produced.
pub const fn enabled() -> bool {
    recorder::ENABLED
}
