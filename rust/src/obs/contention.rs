//! Contention profiling for the fleet's hot locks: acquisition counts,
//! contended-acquisition counts, and blocked wall time per lock.
//!
//! The profiled path is `try_lock` first — the clock is read only when
//! the fast path fails, so an uncontended acquisition costs two relaxed
//! atomic bumps and the single-threaded virtual executor reports
//! exactly zero contended acquisitions and zero blocked time on every
//! run (keeping byte-identical replays). Poisoned locks are recovered,
//! matching [`crate::util::lock_recover`].

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, TryLockError};
use std::time::{Duration, Instant};

use crate::util::JsonValue;

/// Counters for one named lock (or barrier).
#[derive(Debug)]
pub struct LockStats {
    name: &'static str,
    acquisitions: AtomicUsize,
    contended: AtomicUsize,
    blocked_ns: AtomicU64,
}

impl LockStats {
    pub const fn new(name: &'static str) -> LockStats {
        LockStats {
            name,
            acquisitions: AtomicUsize::new(0),
            contended: AtomicUsize::new(0),
            blocked_ns: AtomicU64::new(0),
        }
    }

    /// Lock `m` through the profile: `try_lock` fast path, and only on
    /// contention read the clock and time the blocking acquisition.
    pub fn lock<'a, T>(&self, m: &'a Mutex<T>) -> MutexGuard<'a, T> {
        self.acquisitions.fetch_add(1, Ordering::Relaxed);
        match m.try_lock() {
            Ok(g) => g,
            Err(TryLockError::Poisoned(p)) => p.into_inner(),
            Err(TryLockError::WouldBlock) => {
                let t0 = Instant::now();
                let g = m.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                self.block(t0.elapsed());
                g
            }
        }
    }

    /// Count one acquisition without timing (for barrier-style waits
    /// whose blocked time is measured by the caller around a condvar).
    pub fn acquire(&self) {
        self.acquisitions.fetch_add(1, Ordering::Relaxed);
    }

    /// Account externally measured blocked time (condvar waits).
    pub fn block(&self, blocked: Duration) {
        self.contended.fetch_add(1, Ordering::Relaxed);
        self.blocked_ns.fetch_add(blocked.as_nanos() as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> LockSnapshot {
        LockSnapshot {
            name: self.name,
            acquisitions: self.acquisitions.load(Ordering::Relaxed),
            contended: self.contended.load(Ordering::Relaxed),
            blocked_ms: self.blocked_ns.load(Ordering::Relaxed) as f64 / 1e6,
        }
    }
}

/// A point-in-time reading of one lock's counters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LockSnapshot {
    pub name: &'static str,
    pub acquisitions: usize,
    pub contended: usize,
    pub blocked_ms: f64,
}

impl LockSnapshot {
    pub fn zero(name: &'static str) -> LockSnapshot {
        LockSnapshot { name, acquisitions: 0, contended: 0, blocked_ms: 0.0 }
    }

    /// Fold another snapshot of the same logical lock into this one
    /// (per-device `ServiceMetrics` profiles merge into one row).
    pub fn merge(&mut self, other: &LockSnapshot) {
        self.acquisitions += other.acquisitions;
        self.contended += other.contended;
        self.blocked_ms += other.blocked_ms;
    }

    /// The same counters under a new label. Per-shard rollups tag each
    /// dispatcher's rows with its shard (e.g. `plan_store[3]`) before
    /// folding them into one cluster-wide table.
    pub fn relabel(mut self, name: &'static str) -> LockSnapshot {
        self.name = name;
        self
    }

    pub fn to_json(&self) -> JsonValue {
        let mut o = JsonValue::obj();
        o.set("acquisitions", self.acquisitions)
            .set("contended", self.contended)
            .set("blocked_ms", self.blocked_ms);
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn uncontended_lock_counts_without_blocked_time() {
        let stats = LockStats::new("t");
        let m = Mutex::new(0u32);
        for _ in 0..5 {
            *stats.lock(&m) += 1;
        }
        let s = stats.snapshot();
        assert_eq!(s.name, "t");
        assert_eq!(s.acquisitions, 5);
        assert_eq!(s.contended, 0, "single-threaded use must never contend");
        assert_eq!(s.blocked_ms, 0.0);
        assert_eq!(*stats.lock(&m), 5);
    }

    #[test]
    fn contended_lock_measures_blocked_time() {
        let stats = Arc::new(LockStats::new("t"));
        let m = Arc::new(Mutex::new(()));
        let g = m.lock().unwrap();
        let (m2, s2) = (Arc::clone(&m), Arc::clone(&stats));
        let h = std::thread::spawn(move || {
            let _g = s2.lock(&m2); // blocks until the main thread releases
        });
        std::thread::sleep(Duration::from_millis(20));
        drop(g);
        h.join().unwrap();
        let s = stats.snapshot();
        assert_eq!(s.acquisitions, 1);
        assert_eq!(s.contended, 1);
        assert!(s.blocked_ms > 1.0, "blocked {} ms", s.blocked_ms);
    }

    #[test]
    fn recovers_poisoned_mutex_on_both_paths() {
        let stats = Arc::new(LockStats::new("t"));
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*stats.lock(&m), 7);
        assert_eq!(stats.snapshot().acquisitions, 1);
    }

    #[test]
    fn barrier_style_accounting_merges() {
        let stats = LockStats::new("barrier");
        stats.acquire();
        stats.acquire();
        stats.block(Duration::from_millis(3));
        let mut a = stats.snapshot();
        let b = stats.snapshot();
        a.merge(&b);
        assert_eq!(a.acquisitions, 4);
        assert_eq!(a.contended, 2);
        assert!(a.blocked_ms >= 5.9);
        let j = a.to_json().to_string();
        assert!(j.contains("blocked_ms"));
        let relabeled = a.relabel("barrier[2]");
        assert_eq!(relabeled.name, "barrier[2]");
        assert_eq!(relabeled.acquisitions, 4);
    }
}
