//! Workload graph builders — the paper's evaluation set (Table 1).
//!
//! The paper evaluates on TensorFlow implementations of BERT, DIEN,
//! Transformer, ASR (listen-attend-spell style) and CRNN. We cannot run
//! those binaries; what the fusion compiler actually consumes is the *op
//! graph*, so this module reconstructs graphs with the same structure
//! (attention, layer-norm, GRU/LSTM recurrence unrolled per step, conv
//! backbones) and the same op-count scale as the paper's Table 2 `#`
//! columns. See DESIGN.md §1 (Substitutions).
//!
//! `blocks` holds reusable sub-graph builders (layer-norm is exactly the
//! Figure 1 pattern); `models` assembles them into the seven evaluation
//! workloads; `synthetic` generates random op graphs for property tests
//! and the production-fleet bench.

pub mod blocks;
pub mod models;
pub mod synthetic;

pub use models::{catalog, LoopKind, Mode, Workload};
