//! The seven evaluation workloads of Table 1 / Table 2.
//!
//! Each builder reconstructs the *op-graph structure* of the paper's
//! TensorFlow models (attention encoders, GRU/LSTM recurrences unrolled
//! step-by-step the way TF's `while_loop` execution issues kernels, conv
//! backbones) at a scale calibrated so the **TF-baseline kernel counts
//! land near the paper's Table 2 `#` columns** (the `Mem`/`Math`/`Cpy`
//! populations). Layer/sequence constants below are the calibration
//! knobs; `rust/tests/integration.rs::table2_population_scale` checks the
//! counts stay in band.
//!
//! Training graphs get a **structural backward pass** (`append_backward`):
//! each forward op is mirrored by the gradient ops a tape-based autodiff
//! would emit (matmul → two matmuls, reduce → broadcast, expensive
//! element-wise → derivative chain, ...). This reproduces the fwd/bwd op
//! mix that fusion actually sees during training, rather than scaling
//! counts by a fudge factor.

use super::blocks;
use crate::graph::{DType, Graph, NodeId, OpClass, OpKind, ReduceOp, Shape};

/// Train or inference mode (Table 1's `Mode` column).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mode {
    Train,
    Infer,
}

impl std::fmt::Display for Mode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Mode::Train => f.write_str("Training"),
            Mode::Infer => f.write_str("Inference"),
        }
    }
}

/// How a model's recurrence executes — this drives host-overhead and
/// XLA-clustering behaviour in the simulator:
///
/// * `None` — feed-forward (BERT, Transformer).
/// * `StaticUnrolled` — the recurrence is unrolled in the graph
///   (ASR/CRNN): per-step loop glue exists, but XLA clusters freely.
/// * `DynamicLoop` — a TF `while_loop` executes step kernels one
///   iteration at a time (DIEN): highest host overhead, and XLA
///   auto-clustering is crippled inside the loop body — the mechanism
///   behind the paper's "XLA regresses DIEN" observation (§7.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LoopKind {
    None,
    StaticUnrolled,
    DynamicLoop,
}

/// A built workload: the graph plus Table-1 metadata.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: &'static str,
    pub field: &'static str,
    pub mode: Mode,
    pub batch: usize,
    /// Recurrence execution style (see [`LoopKind`]).
    pub loop_kind: LoopKind,
    pub graph: Graph,
}

impl Workload {
    /// Key used in reports, e.g. `BERT-train`.
    /// True for any recurrent model (static or dynamic loop).
    pub fn recurrent(&self) -> bool {
        self.loop_kind != LoopKind::None
    }

    pub fn key(&self) -> String {
        format!(
            "{}-{}",
            self.name,
            match self.mode {
                Mode::Train => "train",
                Mode::Infer => "infer",
            }
        )
    }
}

/// The full evaluation catalog in Table 1/Table 2 order.
pub fn catalog() -> Vec<Workload> {
    vec![
        bert(Mode::Train),
        bert(Mode::Infer),
        dien(Mode::Train),
        dien(Mode::Infer),
        transformer(),
        asr(),
        crnn(),
    ]
}

// ---------------------------------------------------------------------
// BERT (NLP, both modes, batch 32)
// ---------------------------------------------------------------------

/// BERT encoder stack at the paper's Table-1 shapes. Calibration: 4
/// encoder layers for training (fwd + structural bwd ≈ 560
/// memory-intensive ops ≈ Table 2's 561), 6 layers + embedding/pooler
/// for inference (≈ 365). The inference variant is a distilled/small
/// deployment config (Table 2's BERT-infer row shows Math ≈ 2.5 ms vs
/// 42 ms for training — clearly not the same width).
pub fn bert(mode: Mode) -> Workload {
    match mode {
        Mode::Train => bert_with(mode, 32, 128),
        Mode::Infer => bert_with(mode, 32, 64),
    }
}

/// [`bert`] parameterized over (batch, seq): the op-graph *structure*
/// (layer count, op kinds, edges) is invariant to both — only shapes
/// change — so instantiations at different (batch, seq) are structure
/// siblings the fleet's shape-bucketed plan store can generalize
/// across.
pub fn bert_with(mode: Mode, batch: usize, seq: usize) -> Workload {
    let (hidden, heads) = match mode {
        Mode::Train => (768, 12),
        Mode::Infer => (256, 8),
    };
    let layers = match mode {
        Mode::Train => 4,
        Mode::Infer => 6,
    };
    let mut g = Graph::new(format!("BERT-{mode:?}"));
    let rows = batch * seq;
    let shape = Shape::new(vec![batch, seq, hidden]);

    // Embedding sum + LN front-end.
    let tok = blocks::embedding_lookup(
        &mut g,
        Shape::new(vec![batch, seq]),
        hidden,
        false,
        "emb/tok",
    );
    let pos = g.param(shape.clone(), DType::F32, "emb/pos");
    let mut x = g.binary(OpKind::Add, tok, pos, "emb/add");
    x = blocks::layer_norm(&mut g, x, "emb/ln");

    for l in 0..layers {
        let p = format!("enc{l}");
        let attn = blocks::attention(&mut g, x, batch, seq, hidden, heads, &format!("{p}/attn"));
        let attn = if mode == Mode::Train {
            blocks::dropout(&mut g, attn, &format!("{p}/attn_do"))
        } else {
            attn
        };
        let res1 = g.binary(OpKind::Add, x, attn, format!("{p}/res1"));
        let ln1 = blocks::layer_norm(&mut g, res1, &format!("{p}/ln1"));
        let ff = blocks::ffn(&mut g, ln1, rows, hidden, 4 * hidden, &format!("{p}/ffn"));
        let ff3 = g.add(
            OpKind::Reshape,
            DType::F32,
            shape.clone(),
            vec![ff],
            format!("{p}/ffn_r"),
        );
        let ff3 = if mode == Mode::Train {
            blocks::dropout(&mut g, ff3, &format!("{p}/ffn_do"))
        } else {
            ff3
        };
        let res2 = g.binary(OpKind::Add, ln1, ff3, format!("{p}/res2"));
        x = blocks::layer_norm(&mut g, res2, &format!("{p}/ln2"));
    }

    match mode {
        Mode::Train => {
            // MLM head logits + softmax-xent loss, then backward.
            let wv = g.param(Shape::new(vec![hidden, hidden]), DType::F32, "head/w");
            let flat = g.add(
                OpKind::Reshape,
                DType::F32,
                Shape::new(vec![rows, hidden]),
                vec![x],
                "head/flat",
            );
            let logits = g.matmul(flat, wv, "head/logits");
            let probs = blocks::softmax(&mut g, logits, "head/softmax");
            let labels = g.param(Shape::new(vec![rows, hidden]), DType::F32, "head/labels");
            let logp = g.unary(OpKind::Log, probs, "head/logp");
            let xent = g.binary(OpKind::Mul, labels, logp, "head/xent");
            let per_row = g.reduce(ReduceOp::Sum, xent, vec![1], "head/rowsum");
            let loss = g.reduce(ReduceOp::Mean, per_row, vec![0], "head/loss");
            append_backward(&mut g, loss);
        }
        Mode::Infer => {
            // Pooler: first-token slice → dense → tanh → classifier.
            let first = g.add(
                OpKind::Slice,
                DType::F32,
                Shape::new(vec![batch, hidden]),
                vec![x],
                "pool/first",
            );
            let w = g.param(Shape::new(vec![hidden, hidden]), DType::F32, "pool/w");
            let d = g.matmul(first, w, "pool/dense");
            let t = g.unary(OpKind::Tanh, d, "pool/tanh");
            let wc = g.param(Shape::new(vec![hidden, 2]), DType::F32, "cls/w");
            let logits = g.matmul(t, wc, "cls/logits");
            let _ = blocks::softmax(&mut g, logits, "cls/softmax");
        }
    }

    feed_fetch_copies(&mut g, 100);
    Workload {
        name: "BERT",
        field: "NLP",
        mode,
        batch,
        loop_kind: LoopKind::None,
        graph: g,
    }
}

// ---------------------------------------------------------------------
// DIEN (recommendation, both modes, batch 256)
// ---------------------------------------------------------------------

/// DIEN: embedding lookups → interest-extractor GRU over the behaviour
/// sequence → attention-weighted AUGRU → MLP head; training adds the
/// per-step auxiliary-loss network (the reason DIEN-train's op count
/// nearly triples in Table 2).
pub fn dien(mode: Mode) -> Workload {
    dien_with(mode, 256, 100)
}

/// [`dien`] parameterized over (batch, seq_len). Batch variation is
/// shape-polymorphic (structure invariant); `seq_len` changes the
/// unrolled recurrence *depth* and therefore the structure — sibling
/// instances for bucket generalization must share it.
pub fn dien_with(mode: Mode, batch: usize, seq_len: usize) -> Workload {
    let (emb, hidden) = (32, 64);
    let mut g = Graph::new(format!("DIEN-{mode:?}"));

    // Behaviour/candidate embeddings.
    let behav = blocks::embedding_lookup(
        &mut g,
        Shape::new(vec![batch, seq_len]),
        emb,
        false,
        "emb/behav",
    );
    let cand = blocks::embedding_lookup(
        &mut g,
        Shape::new(vec![batch]),
        emb,
        false,
        "emb/cand",
    );

    // Interest extractor GRU, unrolled per step (TF while_loop issues
    // kernels per iteration, plus TensorArray read/write copies).
    let mut h = g.param(Shape::new(vec![batch, hidden]), DType::F32, "gru1/h0");
    let mut states: Vec<NodeId> = Vec::new();
    for t in 0..seq_len {
        let xt = g.add(
            OpKind::Slice,
            DType::F32,
            Shape::new(vec![batch, emb]),
            vec![behav],
            format!("gru1/x{t}"),
        );
        h = blocks::gru_cell(&mut g, xt, h, hidden, &format!("gru1/s{t}"));
        // TensorArray write (loop glue the Cpy column counts).
        let st = g.unary(OpKind::Copy, h, format!("gru1/ta{t}"));
        states.push(st);
        // Additional per-step stack traffic: TF training stacks every
        // loop-carried intermediate for the backward pass; inference
        // keeps one extra state stack. Calibrated to Table 2's Cpy
        // populations (DIEN-train 1391, DIEN-infer 225).
        let extra_copies = if mode == Mode::Train { 12 } else { 1 };
        for e in 0..extra_copies {
            let _ = g.unary(OpKind::Copy, h, format!("gru1/stack{t}_{e}"));
        }

        if mode == Mode::Train {
            // Auxiliary loss net per step: sigmoid(MLP(h, next_click)).
            let nxt = g.add(
                OpKind::Slice,
                DType::F32,
                Shape::new(vec![batch, emb]),
                vec![behav],
                format!("aux/x{t}"),
            );
            let wa = g.param(Shape::new(vec![emb, hidden]), DType::F32, format!("aux/w{t}"));
            let proj = g.matmul(nxt, wa, format!("aux/mm{t}"));
            let dot = g.binary(OpKind::Mul, st, proj, format!("aux/dot{t}"));
            let s = g.reduce(ReduceOp::Sum, dot, vec![1], format!("aux/sum{t}"));
            let _p = g.unary(OpKind::Sigmoid, s, format!("aux/p{t}"));
        }
    }

    // Attention scores of candidate vs each state + AUGRU pass.
    let wc = g.param(Shape::new(vec![emb, hidden]), DType::F32, "attn/wc");
    let cand_h = g.matmul(cand, wc, "attn/cand_proj");
    let mut h2 = g.param(Shape::new(vec![batch, hidden]), DType::F32, "augru/h0");
    for (t, &st) in states.iter().enumerate() {
        let dot = g.binary(OpKind::Mul, st, cand_h, format!("attn/dot{t}"));
        let score = g.reduce(ReduceOp::Sum, dot, vec![1], format!("attn/s{t}"));
        let a = g.unary(OpKind::Sigmoid, score, format!("attn/a{t}"));
        let a_b = g.broadcast(a, Shape::new(vec![batch, hidden]), format!("attn/ab{t}"));
        let weighted = g.binary(OpKind::Mul, st, a_b, format!("attn/w{t}"));
        h2 = blocks::gru_cell(&mut g, weighted, h2, hidden, &format!("augru/s{t}"));
    }

    // MLP head over [final interest ; candidate].
    let wcat = g.param(Shape::new(vec![hidden, hidden]), DType::F32, "head/w0");
    let m0 = g.matmul(h2, wcat, "head/mm0");
    let r0 = g.unary(OpKind::Relu, m0, "head/relu0");
    let w1 = g.param(Shape::new(vec![hidden, 2]), DType::F32, "head/w1");
    let logits = g.matmul(r0, w1, "head/mm1");
    let probs = blocks::softmax(&mut g, logits, "head/softmax");

    if mode == Mode::Train {
        let labels = g.param(Shape::new(vec![batch, 2]), DType::F32, "loss/labels");
        let logp = g.unary(OpKind::Log, probs, "loss/logp");
        let x = g.binary(OpKind::Mul, labels, logp, "loss/xent");
        let pr = g.reduce(ReduceOp::Sum, x, vec![1], "loss/rowsum");
        let loss = g.reduce(ReduceOp::Mean, pr, vec![0], "loss/mean");
        append_backward(&mut g, loss);
    }

    feed_fetch_copies(&mut g, 8);
    Workload {
        name: "DIEN",
        field: "Recommendation",
        mode,
        batch,
        loop_kind: LoopKind::DynamicLoop,
        graph: g,
    }
}

// ---------------------------------------------------------------------
// Transformer (NLP, training, batch 4096 tokens)
// ---------------------------------------------------------------------

/// Transformer NMT (training): 6 encoder + 6 decoder layers at the
/// standard base width, label-smoothed cross-entropy, structural bwd.
pub fn transformer() -> Workload {
    transformer_with(64, 64) // 4096 tokens
}

/// [`transformer`] parameterized over (batch, seq); structure is
/// invariant to both (fixed 6+6 layer stack), so instantiations are
/// shape siblings.
pub fn transformer_with(batch: usize, seq: usize) -> Workload {
    let (hidden, heads) = (512, 8);
    let layers = 6; // Transformer-base depth; calibrates Table 2's 2497/399 populations
    let mut g = Graph::new("Transformer-train");
    let shape = Shape::new(vec![batch, seq, hidden]);
    let rows = batch * seq;

    let src = g.param(shape.clone(), DType::F32, "src/emb");
    let pos = g.param(shape.clone(), DType::F32, "src/pos");
    let mut x = g.binary(OpKind::Add, src, pos, "src/add");
    for l in 0..layers {
        let p = format!("enc{l}");
        let attn = blocks::attention(&mut g, x, batch, seq, hidden, heads, &format!("{p}/attn"));
        let r1 = g.binary(OpKind::Add, x, attn, format!("{p}/res1"));
        let ln1 = blocks::layer_norm(&mut g, r1, &format!("{p}/ln1"));
        let ff = blocks::ffn(&mut g, ln1, rows, hidden, 4 * hidden, &format!("{p}/ffn"));
        let ff3 = g.add(OpKind::Reshape, DType::F32, shape.clone(), vec![ff], format!("{p}/ffr"));
        let r2 = g.binary(OpKind::Add, ln1, ff3, format!("{p}/res2"));
        x = blocks::layer_norm(&mut g, r2, &format!("{p}/ln2"));
    }
    let memory = x;

    let tgt = g.param(shape.clone(), DType::F32, "tgt/emb");
    let tpos = g.param(shape.clone(), DType::F32, "tgt/pos");
    let mut y = g.binary(OpKind::Add, tgt, tpos, "tgt/add");
    for l in 0..layers {
        let p = format!("dec{l}");
        let self_a = blocks::attention(&mut g, y, batch, seq, hidden, heads, &format!("{p}/self"));
        let r1 = g.binary(OpKind::Add, y, self_a, format!("{p}/res1"));
        let ln1 = blocks::layer_norm(&mut g, r1, &format!("{p}/ln1"));
        // Cross-attention (reuse the attention block over memory+query mix;
        // structurally identical op mix).
        let mix = g.binary(OpKind::Add, ln1, memory, format!("{p}/mix"));
        let cross =
            blocks::attention(&mut g, mix, batch, seq, hidden, heads, &format!("{p}/cross"));
        let r2 = g.binary(OpKind::Add, ln1, cross, format!("{p}/res2"));
        let ln2 = blocks::layer_norm(&mut g, r2, &format!("{p}/ln2"));
        let ff = blocks::ffn(&mut g, ln2, rows, hidden, 4 * hidden, &format!("{p}/ffn"));
        let ff3 = g.add(OpKind::Reshape, DType::F32, shape.clone(), vec![ff], format!("{p}/ffr"));
        let r3 = g.binary(OpKind::Add, ln2, ff3, format!("{p}/res3"));
        y = blocks::layer_norm(&mut g, r3, &format!("{p}/ln3"));
    }

    // Vocabulary projection + label-smoothed cross entropy.
    let vocab = 1024;
    let flat = g.add(
        OpKind::Reshape,
        DType::F32,
        Shape::new(vec![rows, hidden]),
        vec![y],
        "head/flat",
    );
    let wv = g.param(Shape::new(vec![hidden, vocab]), DType::F32, "head/w");
    let logits = g.matmul(flat, wv, "head/logits");
    let probs = blocks::softmax(&mut g, logits, "head/softmax");
    let labels = g.param(Shape::new(vec![rows, vocab]), DType::F32, "loss/labels");
    let logp = g.unary(OpKind::Log, probs, "loss/logp");
    let sm = g.binary(OpKind::Mul, labels, logp, "loss/xent");
    let pr = g.reduce(ReduceOp::Sum, sm, vec![1], "loss/rowsum");
    let loss = g.reduce(ReduceOp::Mean, pr, vec![0], "loss/mean");
    append_backward(&mut g, loss);

    feed_fetch_copies(&mut g, 520);
    Workload {
        name: "Transformer",
        field: "NLP",
        mode: Mode::Train,
        batch: rows,
        loop_kind: LoopKind::None,
        graph: g,
    }
}

// ---------------------------------------------------------------------
// ASR (speech recognition, inference, batch 8)
// ---------------------------------------------------------------------

/// Listen-attend-spell style ASR inference: 2 bidirectional LSTM encoder
/// layers unrolled over 20 frames (TF `BasicLSTMCell` concatenates
/// [x; h] into a single GEMM per step), attention + greedy decoder.
pub fn asr() -> Workload {
    asr_with(8, 20)
}

/// [`asr`] parameterized over (batch, frames). Batch variation keeps
/// the structure; `frames` changes the unrolled LSTM depth (structure).
pub fn asr_with(batch: usize, frames: usize) -> Workload {
    let (feat, hidden) = (80, 256);
    let mut g = Graph::new("ASR-infer");
    let feats = g.param(Shape::new(vec![batch, frames, feat]), DType::F32, "feats");

    let mut layer_in_dim = feat;
    let mut layer_in = feats;
    for l in 0..2 {
        for dir in 0..2 {
            let mut h =
                g.param(Shape::new(vec![batch, hidden]), DType::F32, format!("l{l}d{dir}/h0"));
            let mut c =
                g.param(Shape::new(vec![batch, hidden]), DType::F32, format!("l{l}d{dir}/c0"));
            for t in 0..frames {
                let xt = g.add(
                    OpKind::Slice,
                    DType::F32,
                    Shape::new(vec![batch, layer_in_dim]),
                    vec![layer_in],
                    format!("l{l}d{dir}/x{t}"),
                );
                let (h2, c2) =
                    lstm_cell_fused(&mut g, xt, h, c, hidden, &format!("l{l}d{dir}/s{t}"));
                h = h2;
                c = c2;
                // TensorArray write + frame staging copies (Table 2 ASR
                // Cpy ≈ 439 over 80 cells ⇒ ~5 per step).
                for e in 0..5 {
                    let _ = g.unary(OpKind::Copy, h, format!("l{l}d{dir}/ta{t}_{e}"));
                }
            }
        }
        // Stack directions back into a sequence tensor for the next layer.
        layer_in = g.param(
            Shape::new(vec![batch, frames, 2 * hidden]),
            DType::F32,
            format!("l{l}/stacked"),
        );
        layer_in_dim = 2 * hidden;
    }

    // Attention context + a small greedy decode loop.
    for t in 0..8 {
        let q = g.param(Shape::new(vec![batch, 2 * hidden]), DType::F32, format!("dec/q{t}"));
        let kt = g.add(
            OpKind::Slice,
            DType::F32,
            Shape::new(vec![batch, 2 * hidden]),
            vec![layer_in],
            format!("dec/k{t}"),
        );
        let dot = g.binary(OpKind::Mul, q, kt, format!("dec/dot{t}"));
        let score = g.reduce(ReduceOp::Sum, dot, vec![1], format!("dec/s{t}"));
        let w = g.unary(OpKind::Sigmoid, score, format!("dec/a{t}"));
        let w_b = g.broadcast(w, Shape::new(vec![batch, 2 * hidden]), format!("dec/ab{t}"));
        let ctx = g.binary(OpKind::Mul, kt, w_b, format!("dec/ctx{t}"));
        let wv = g.param(Shape::new(vec![2 * hidden, 64]), DType::F32, format!("dec/w{t}"));
        let logits = g.matmul(ctx, wv, format!("dec/logit{t}"));
        let _ = blocks::softmax(&mut g, logits, &format!("dec/sm{t}"));
    }

    feed_fetch_copies(&mut g, 12);
    Workload {
        name: "ASR",
        field: "Speech Recognition",
        mode: Mode::Infer,
        batch,
        loop_kind: LoopKind::StaticUnrolled,
        graph: g,
    }
}

// ---------------------------------------------------------------------
// CRNN (OCR, inference, batch 8)
// ---------------------------------------------------------------------

/// CRNN OCR inference: conv/BN/ReLU backbone, column-wise bidirectional
/// LSTM over the feature width, per-column softmax (CTC front).
pub fn crnn() -> Workload {
    crnn_with(8, 64)
}

/// [`crnn`] parameterized over (batch, width). Batch variation keeps
/// the structure; `width` changes the column recurrence depth
/// (structure).
pub fn crnn_with(batch: usize, width: usize) -> Workload {
    let height = 32;
    let mut g = Graph::new("CRNN-infer");
    let mut x = g.param(Shape::new(vec![batch, height, width * 2, 1]), DType::F32, "img");

    // Backbone: 8 conv blocks with pooling-style reshapes between.
    let chans = [64, 64, 128, 128, 256, 256, 512, 512];
    for (i, &ch) in chans.iter().enumerate() {
        let out = Shape::new(vec![batch, height.max(4), width, ch.min(128)]);
        x = blocks::conv_bn_relu(&mut g, x, out, &format!("conv{i}"));
        if i % 2 == 1 {
            let pooled = Shape::new(vec![batch, (height / 2).max(4), width, ch.min(128)]);
            x = g.add(OpKind::Reshape, DType::F32, pooled, vec![x], format!("pool{i}"));
        }
    }

    // Column features -> BiLSTM over width.
    let featdim = 128;
    let seq_feats = g.add(
        OpKind::Reshape,
        DType::F32,
        Shape::new(vec![batch, width, featdim]),
        vec![x],
        "to_seq",
    );
    let hidden = 128;
    let mut layer_in = seq_feats;
    let mut in_dim = featdim;
    for l in 0..2 {
        for dir in 0..2 {
            let mut h =
                g.param(Shape::new(vec![batch, hidden]), DType::F32, format!("rnn{l}d{dir}/h0"));
            let mut c =
                g.param(Shape::new(vec![batch, hidden]), DType::F32, format!("rnn{l}d{dir}/c0"));
            for t in 0..width {
                let xt = g.add(
                    OpKind::Slice,
                    DType::F32,
                    Shape::new(vec![batch, in_dim]),
                    vec![layer_in],
                    format!("rnn{l}d{dir}/x{t}"),
                );
                let (h2, c2) =
                    lstm_cell_fused(&mut g, xt, h, c, hidden, &format!("rnn{l}d{dir}/s{t}"));
                h = h2;
                c = c2;
                // TensorArray + column staging copies (Table 2 CRNN Cpy
                // ≈ 890 over 256 cells ⇒ ~3 per step).
                for e in 0..3 {
                    let _ = g.unary(OpKind::Copy, h, format!("rnn{l}d{dir}/ta{t}_{e}"));
                }
            }
        }
        layer_in = g.param(
            Shape::new(vec![batch, width, 2 * hidden]),
            DType::F32,
            format!("rnn{l}/stacked"),
        );
        in_dim = 2 * hidden;
    }

    // CTC front: per-column projection + softmax.
    for t in 0..width {
        let col = g.add(
            OpKind::Slice,
            DType::F32,
            Shape::new(vec![batch, 2 * hidden]),
            vec![layer_in],
            format!("ctc/col{t}"),
        );
        let w = g.param(Shape::new(vec![2 * hidden, 96]), DType::F32, format!("ctc/w{t}"));
        let logits = g.matmul(col, w, format!("ctc/logits{t}"));
        let _ = blocks::softmax(&mut g, logits, &format!("ctc/sm{t}"));
    }

    feed_fetch_copies(&mut g, 10);
    Workload {
        name: "CRNN",
        field: "OCR",
        mode: Mode::Infer,
        batch,
        loop_kind: LoopKind::StaticUnrolled,
        graph: g,
    }
}

// ---------------------------------------------------------------------
// Shared helpers
// ---------------------------------------------------------------------

/// LSTM cell in the TF `BasicLSTMCell` formulation: concat([x, h]) feeds
/// a single GEMM (this keeps `Math` kernel counts near Table 2's — the
/// paper's models hit cuDNN-style fused projections, not 2 GEMMs/step).
fn lstm_cell_fused(
    g: &mut Graph,
    x: NodeId,
    h_prev: NodeId,
    c_prev: NodeId,
    hidden: usize,
    prefix: &str,
) -> (NodeId, NodeId) {
    let dtype = g.node(x).dtype;
    let batch = g.node(x).shape.dims()[0];
    let xdim = g.node(x).shape.dims()[1];
    let cat = g.add(
        OpKind::Concat,
        dtype,
        Shape::new(vec![batch, xdim + hidden]),
        vec![x, h_prev],
        format!("{prefix}/cat"),
    );
    let w = g.param(
        Shape::new(vec![xdim + hidden, 4 * hidden]),
        dtype,
        format!("{prefix}/w"),
    );
    let gates = g.matmul(cat, w, format!("{prefix}/gemm"));
    let hshape = Shape::new(vec![batch, hidden]);
    let i_pre = g.add(OpKind::Slice, dtype, hshape.clone(), vec![gates], format!("{prefix}/i_pre"));
    let f_pre = g.add(OpKind::Slice, dtype, hshape.clone(), vec![gates], format!("{prefix}/f_pre"));
    let o_pre = g.add(OpKind::Slice, dtype, hshape.clone(), vec![gates], format!("{prefix}/o_pre"));
    let c_pre = g.add(OpKind::Slice, dtype, hshape.clone(), vec![gates], format!("{prefix}/c_pre"));
    let i = g.unary(OpKind::Sigmoid, i_pre, format!("{prefix}/i"));
    let f = g.unary(OpKind::Sigmoid, f_pre, format!("{prefix}/f"));
    let o = g.unary(OpKind::Sigmoid, o_pre, format!("{prefix}/o"));
    let cc = g.unary(OpKind::Tanh, c_pre, format!("{prefix}/cc"));
    let fc = g.binary(OpKind::Mul, f, c_prev, format!("{prefix}/fc"));
    let ic = g.binary(OpKind::Mul, i, cc, format!("{prefix}/ic"));
    let c = g.binary(OpKind::Add, fc, ic, format!("{prefix}/c"));
    let ct = g.unary(OpKind::Tanh, c, format!("{prefix}/ct"));
    let h = g.binary(OpKind::Mul, o, ct, format!("{prefix}/h"));
    (h, c)
}

/// Append a structural backward pass seeded at `loss`, mirroring what a
/// tape autodiff emits per forward op. This makes training graphs carry
/// the fwd+bwd op mix Table 2 profiles.
pub fn append_backward(g: &mut Graph, loss: NodeId) {
    let fwd_count = g.len();
    // Gradient seed.
    let seed = g.constant(g.node(loss).shape.clone(), g.node(loss).dtype, "grad/seed");
    let mut grads: Vec<Option<NodeId>> = vec![None; fwd_count];
    grads[loss.idx()] = Some(seed);

    // Walk forward nodes in reverse creation order (a reverse topological
    // order by construction).
    for idx in (0..fwd_count).rev() {
        let id = NodeId(idx as u32);
        let Some(gout) = grads[idx] else { continue };
        let node = g.node(id).clone();
        match node.kind.class() {
            OpClass::Source => {}
            OpClass::ComputeIntensive => {
                // d(A@B): dA = dC @ B^T, dB = A^T @ dC — two more GEMMs.
                if node.inputs.len() >= 2 {
                    let a = node.inputs[0];
                    let b = node.inputs[1];
                    let ga = g.add(
                        node.kind.clone(),
                        node.dtype,
                        g.node(a).shape.clone(),
                        vec![gout, b],
                        format!("grad/{}/da", node.name),
                    );
                    let gb = g.add(
                        node.kind.clone(),
                        node.dtype,
                        g.node(b).shape.clone(),
                        vec![a, gout],
                        format!("grad/{}/db", node.name),
                    );
                    accumulate(&mut grads, g, a, ga);
                    accumulate(&mut grads, g, b, gb);
                }
            }
            OpClass::Reduction => {
                // d(reduce) broadcasts the gradient back up.
                let x = node.inputs[0];
                let gb =
                    g.broadcast(gout, g.node(x).shape.clone(), format!("grad/{}/bcast", node.name));
                accumulate(&mut grads, g, x, gb);
            }
            OpClass::DataMovement => {
                let x = node.inputs[0];
                // Inverse movement: broadcast<->reduce, others mirror 1:1.
                let gx = match &node.kind {
                    OpKind::Broadcast => {
                        // Gradient of broadcast reduces over expanded axes;
                        // model as a sum-reduce producing the input shape.
                        let in_shape = g.node(x).shape.clone();
                        g.add(
                            OpKind::Reduce {
                                op: ReduceOp::Sum,
                                axes: vec![node.shape.rank().saturating_sub(1)],
                            },
                            node.dtype,
                            in_shape,
                            vec![gout],
                            format!("grad/{}/reduce", node.name),
                        )
                    }
                    k => g.add(
                        k.clone(),
                        node.dtype,
                        g.node(x).shape.clone(),
                        vec![gout],
                        format!("grad/{}/mirror", node.name),
                    ),
                };
                accumulate(&mut grads, g, x, gx);
            }
            OpClass::LightElementwise => match node.kind {
                OpKind::Select => {
                    // d select(mask, a, b): grads flow to the data
                    // branches (masked), never to the predicate.
                    for &inp in node.inputs.iter().skip(1) {
                        if g.node(inp).shape == node.shape {
                            let gx = g.add(
                                OpKind::Select,
                                node.dtype,
                                node.shape.clone(),
                                vec![node.inputs[0], gout, gout],
                                format!("grad/{}/dsel", node.name),
                            );
                            accumulate(&mut grads, g, inp, gx);
                        }
                    }
                }
                OpKind::Compare => {}
                OpKind::Add | OpKind::Sub => {
                    for &inp in node.inputs.iter().take(2) {
                        if g.node(inp).shape == node.shape {
                            accumulate(&mut grads, g, inp, gout);
                        }
                    }
                }
                OpKind::Mul => {
                    // d(a*b): da = dy*b, db = dy*a. Propagate to every
                    // operand whose shape matches the output — a scalar
                    // co-operand (dropout scale, attention 1/√dk) still
                    // lets gradient flow through the tensor side, exactly
                    // as tf.gradients emits Mul(dy, scalar).
                    if node.inputs.len() == 2 {
                        let (a, b) = (node.inputs[0], node.inputs[1]);
                        if g.node(a).shape == node.shape {
                            let ga =
                                g.binary(OpKind::Mul, gout, b, format!("grad/{}/da", node.name));
                            accumulate(&mut grads, g, a, ga);
                        }
                        if g.node(b).shape == node.shape {
                            let gb =
                                g.binary(OpKind::Mul, gout, a, format!("grad/{}/db", node.name));
                            accumulate(&mut grads, g, b, gb);
                        }
                    }
                }
                _ => {
                    // Generic: one mask/one mul worth of gradient work.
                    let x = node.inputs[0];
                    if g.node(x).shape == node.shape {
                        let gx = g.binary(OpKind::Mul, gout, x, format!("grad/{}/dx", node.name));
                        accumulate(&mut grads, g, x, gx);
                    }
                }
            },
            OpClass::ExpensiveElementwise => {
                // d f(x) = f'(x) * dy; f' itself is expensive (e.g.
                // tanh' = 1 - tanh², sigmoid' = s(1-s)) — 2 ops.
                let x = node.inputs[0];
                let d = g.unary(node.kind.clone(), x, format!("grad/{}/fprime", node.name));
                let gx = g.binary(OpKind::Mul, gout, d, format!("grad/{}/dx", node.name));
                accumulate(&mut grads, g, x, gx);
            }
        }
    }
}

/// Accumulate gradient `gnew` into the slot for `target`, adding an
/// explicit Add node when a gradient already exists (fan-out in fwd =
/// fan-in of grads).
fn accumulate(grads: &mut [Option<NodeId>], g: &mut Graph, target: NodeId, gnew: NodeId) {
    if target.idx() >= grads.len() {
        return; // gradient of a node created during backward: ignore
    }
    match grads[target.idx()] {
        None => grads[target.idx()] = Some(gnew),
        Some(prev) => {
            if g.node(prev).shape == g.node(gnew).shape {
                let s = g.binary(OpKind::Add, prev, gnew, "grad/acc");
                grads[target.idx()] = Some(s);
            }
        }
    }
}

/// Model the per-iteration host<->device feed/fetch memcpys TF issues
/// (`Cpy` column): `n` explicit Copy nodes on fresh params.
fn feed_fetch_copies(g: &mut Graph, n: usize) {
    for i in 0..n {
        let p = g.param(Shape::new(vec![64]), DType::F32, format!("io/feed{i}"));
        let _ = g.unary(OpKind::Copy, p, format!("io/cpy{i}"));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_has_seven_workloads() {
        let all = catalog();
        assert_eq!(all.len(), 7);
        let keys: Vec<String> = all.iter().map(|w| w.key()).collect();
        assert!(keys.contains(&"BERT-train".to_string()));
        assert!(keys.contains(&"DIEN-infer".to_string()));
        assert!(keys.contains(&"CRNN-infer".to_string()));
        for w in &all {
            w.graph.validate().unwrap();
            assert!(w.graph.len() > 50, "{} too small", w.key());
        }
    }

    #[test]
    fn training_graphs_are_larger_than_inference() {
        // BERT-train mirrors Table 2's 561-vs-365 op-count relation
        // (train is a wider model at fewer layers + a backward pass).
        let bt = bert(Mode::Train).graph.num_memory_intensive();
        let bi = bert(Mode::Infer).graph.num_memory_intensive();
        assert!(bt as f64 > bi as f64 * 1.1, "train {bt} vs infer {bi}");
        let dt = dien(Mode::Train).graph.num_memory_intensive();
        let di = dien(Mode::Infer).graph.num_memory_intensive();
        assert!(dt as f64 > di as f64 * 2.0, "train {dt} vs infer {di}");
    }

    #[test]
    fn recurrent_flags() {
        assert!(!bert(Mode::Train).recurrent());
        assert_eq!(dien(Mode::Infer).loop_kind, LoopKind::DynamicLoop);
        assert_eq!(asr().loop_kind, LoopKind::StaticUnrolled);
        assert_eq!(crnn().loop_kind, LoopKind::StaticUnrolled);
        assert!(asr().recurrent() && crnn().recurrent());
    }

    #[test]
    fn sized_builders_are_structure_invariant_in_batch_and_seq() {
        // The shape-polymorphic contract: instantiations of one builder
        // at different (batch, seq) share op kinds, edges and ranks —
        // only dimension values move. (For the recurrent builders this
        // holds for batch; their seq/frames/width change the unrolled
        // depth and are therefore structural.)
        let structurally_equal = |a: &Workload, b: &Workload| {
            assert_eq!(a.graph.len(), b.graph.len(), "{} op count", a.key());
            for (x, y) in a.graph.nodes().iter().zip(b.graph.nodes()) {
                assert_eq!(x.kind, y.kind, "{} kind at {}", a.key(), x.id);
                assert_eq!(x.inputs, y.inputs, "{} edges at {}", a.key(), x.id);
                assert_eq!(x.shape.rank(), y.shape.rank(), "{} rank at {}", a.key(), x.id);
            }
        };
        structurally_equal(&bert_with(Mode::Infer, 8, 32), &bert_with(Mode::Infer, 16, 48));
        structurally_equal(&bert_with(Mode::Train, 8, 32), &bert_with(Mode::Train, 4, 64));
        structurally_equal(&transformer_with(8, 16), &transformer_with(16, 32));
        structurally_equal(&dien_with(Mode::Infer, 64, 10), &dien_with(Mode::Infer, 128, 10));
        structurally_equal(&asr_with(4, 5), &asr_with(16, 5));
        structurally_equal(&crnn_with(4, 8), &crnn_with(16, 8));
        // And the shapes really differ (not a no-op parameterization).
        let (a, b) = (bert_with(Mode::Infer, 8, 32), bert_with(Mode::Infer, 16, 48));
        assert!(a
            .graph
            .nodes()
            .iter()
            .zip(b.graph.nodes())
            .any(|(x, y)| x.shape != y.shape));
    }

    #[test]
    fn default_builders_match_their_sized_forms() {
        let pairs = [
            (bert(Mode::Train), bert_with(Mode::Train, 32, 128)),
            (bert(Mode::Infer), bert_with(Mode::Infer, 32, 64)),
            (dien(Mode::Infer), dien_with(Mode::Infer, 256, 100)),
            (transformer(), transformer_with(64, 64)),
            (asr(), asr_with(8, 20)),
            (crnn(), crnn_with(8, 64)),
        ];
        for (d, s) in &pairs {
            assert_eq!(d.graph.len(), s.graph.len());
            assert_eq!(d.batch, s.batch);
            for (x, y) in d.graph.nodes().iter().zip(s.graph.nodes()) {
                assert_eq!(x.kind, y.kind);
                assert_eq!(x.shape, y.shape);
            }
        }
        assert_eq!(transformer().batch, 4096, "Table-1 token count preserved");
    }

    #[test]
    fn backward_adds_gradient_ops() {
        let mut g = Graph::new("t");
        let x = g.param(Shape::new(vec![8, 16]), DType::F32, "x");
        let w = g.param(Shape::new(vec![16, 4]), DType::F32, "w");
        let y = g.matmul(x, w, "y");
        let t = g.unary(OpKind::Tanh, y, "t");
        let l = g.reduce(ReduceOp::Sum, t, vec![0, 1], "l");
        let before = g.len();
        append_backward(&mut g, l);
        g.validate().unwrap();
        assert!(g.len() > before + 4);
        // matmul grads present
        let extra_mm = g
            .nodes()
            .iter()
            .skip(before)
            .filter(|n| n.kind == OpKind::MatMul)
            .count();
        assert_eq!(extra_mm, 2);
    }
}
