//! Random op-graph generator for property tests and the production-fleet
//! benchmark (§7.2's "30,000 tasks per month" claim is exercised by
//! sampling many graphs from this generator and checking FusionStitching
//! never regresses below the baseline).

use crate::graph::{DType, Graph, NodeId, OpKind, ReduceOp, Shape};
use crate::util::Prng;

/// Tuning knobs for the generator.
#[derive(Debug, Clone)]
pub struct SyntheticConfig {
    /// Number of non-parameter ops to generate.
    pub num_ops: usize,
    /// Number of root parameters.
    pub num_params: usize,
    /// Probability that a generated op is a reduction.
    pub p_reduce: f64,
    /// Probability that a generated op is expensive element-wise.
    pub p_expensive: f64,
    /// Probability that a generated op is a GEMM (compute-intensive).
    pub p_gemm: f64,
    /// Base row/col sizes drawn for parameter shapes.
    pub dim_choices: Vec<usize>,
}

impl Default for SyntheticConfig {
    fn default() -> Self {
        Self {
            num_ops: 120,
            num_params: 6,
            p_reduce: 0.10,
            p_expensive: 0.15,
            p_gemm: 0.05,
            dim_choices: vec![64, 128, 256, 512, 1024],
        }
    }
}

/// Generate a random valid graph. All ops are well-shaped by
/// construction: binary ops only combine equal shapes; reductions reduce
/// the last axis; broadcasts re-expand reduced values.
pub fn generate(cfg: &SyntheticConfig, prng: &mut Prng) -> Graph {
    generate_inner(cfg, prng, None)
}

/// Like [`generate`] but every parameter uses `rows` as its leading
/// dimension (columns still drawn from `dim_choices`). The PRNG draw
/// sequence and every structural decision are independent of `rows`
/// (requires `rows >= 2` so reducibility checks cannot flip), so two
/// calls with the same seed produce graphs of **identical structure**
/// whose shapes differ only in the leading dimension — the contract the
/// fleet's shape-scalable template families
/// ([`crate::fleet::TemplateFamily`]) and the shape-bucketed plan store
/// rely on.
pub fn generate_scaled(cfg: &SyntheticConfig, prng: &mut Prng, rows: usize) -> Graph {
    assert!(rows >= 2, "scaled graphs need rows >= 2 for structure invariance");
    generate_inner(cfg, prng, Some(rows))
}

fn generate_inner(cfg: &SyntheticConfig, prng: &mut Prng, fixed_rows: Option<usize>) -> Graph {
    let mut g = Graph::new("synthetic");
    // Pools of live values indexed by shape so binaries can find matches.
    let mut values: Vec<NodeId> = Vec::new();

    for i in 0..cfg.num_params {
        let rows = match fixed_rows {
            Some(r) => r,
            None => *prng.pick(&cfg.dim_choices),
        };
        let cols = *prng.pick(&cfg.dim_choices);
        values.push(g.param(Shape::new(vec![rows, cols]), DType::F32, format!("p{i}")));
    }

    const LIGHT: [OpKind; 6] = [
        OpKind::Add,
        OpKind::Sub,
        OpKind::Mul,
        OpKind::Maximum,
        OpKind::Minimum,
        OpKind::Relu,
    ];
    const EXPENSIVE: [OpKind; 5] = [
        OpKind::Exp,
        OpKind::Tanh,
        OpKind::Sigmoid,
        OpKind::Rsqrt,
        OpKind::Log,
    ];

    for i in 0..cfg.num_ops {
        let x = values[prng.below(values.len())];
        let roll = prng.f64();
        let reducible = g.node(x).shape.rank() >= 1 && g.node(x).shape.num_elements() > 1;
        let id = if roll < cfg.p_reduce && reducible {
            let last = g.node(x).shape.rank() - 1;
            let r = g.reduce(ReduceOp::Sum, x, vec![last], format!("red{i}"));
            // Re-broadcast half the time so downstream binaries have mates.
            if prng.chance(0.5) {
                g.broadcast(r, g.node(x).shape.clone(), format!("bc{i}"))
            } else {
                r
            }
        } else if roll < cfg.p_reduce + cfg.p_expensive {
            g.unary(EXPENSIVE[prng.below(EXPENSIVE.len())].clone(), x, format!("e{i}"))
        } else if roll < cfg.p_reduce + cfg.p_expensive + cfg.p_gemm
            && g.node(x).shape.rank() == 2
        {
            let k = g.node(x).shape.dims()[1];
            let n = *prng.pick(&cfg.dim_choices);
            let w = g.param(Shape::new(vec![k, n]), DType::F32, format!("w{i}"));
            g.matmul(x, w, format!("mm{i}"))
        } else {
            // Light element-wise: binary with a shape-mate when one
            // exists, unary otherwise.
            let mates: Vec<NodeId> = values
                .iter()
                .copied()
                .filter(|&v| v != x && g.node(v).shape == g.node(x).shape)
                .collect();
            if !mates.is_empty() && prng.chance(0.7) {
                let y = mates[prng.below(mates.len())];
                g.binary(LIGHT[prng.below(4)].clone(), x, y, format!("b{i}"))
            } else {
                g.unary(LIGHT[prng.below(LIGHT.len())].clone(), x, format!("u{i}"))
            }
        };
        values.push(id);
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generated_graphs_validate() {
        let mut prng = Prng::new(1234);
        for seed in 0..20 {
            let mut p = Prng::new(seed * 7 + 1);
            let cfg = SyntheticConfig {
                num_ops: 30 + prng.below(100),
                ..Default::default()
            };
            let g = generate(&cfg, &mut p);
            g.validate().unwrap();
            assert!(g.len() >= cfg.num_ops);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = SyntheticConfig::default();
        let g1 = generate(&cfg, &mut Prng::new(99));
        let g2 = generate(&cfg, &mut Prng::new(99));
        assert_eq!(g1.len(), g2.len());
        for (a, b) in g1.nodes().iter().zip(g2.nodes()) {
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.shape, b.shape);
        }
    }

    #[test]
    fn scaled_graphs_share_structure_across_rows() {
        // One seed, many row counts: identical op kinds and edges, only
        // the leading dimension moves — the shape-family contract.
        let cfg = SyntheticConfig { num_ops: 80, ..Default::default() };
        let at = |rows: usize| generate_scaled(&cfg, &mut Prng::new(4242), rows);
        let base = at(64);
        base.validate().unwrap();
        for rows in [2usize, 48, 63, 65, 100, 1024] {
            let g = at(rows);
            g.validate().unwrap();
            assert_eq!(g.len(), base.len(), "rows={rows}");
            for (a, b) in base.nodes().iter().zip(g.nodes()) {
                assert_eq!(a.kind, b.kind, "rows={rows} node {}", a.id);
                assert_eq!(a.inputs, b.inputs, "rows={rows} node {}", a.id);
                assert_eq!(a.shape.rank(), b.shape.rank(), "rows={rows} node {}", a.id);
            }
        }
        // The shapes really scale (params carry the requested rows).
        let g100 = at(100);
        let scaled_param = g100.nodes().iter().find(|n| n.kind == OpKind::Parameter).unwrap();
        assert_eq!(scaled_param.shape.dims()[0], 100);
    }

    #[test]
    fn op_mix_contains_all_classes() {
        let cfg = SyntheticConfig {
            num_ops: 400,
            ..Default::default()
        };
        let g = generate(&cfg, &mut Prng::new(5));
        use crate::graph::OpClass;
        let count = |c: OpClass| g.nodes().iter().filter(|n| n.kind.class() == c).count();
        assert!(count(OpClass::LightElementwise) > 0);
        assert!(count(OpClass::ExpensiveElementwise) > 0);
        assert!(count(OpClass::Reduction) > 0);
    }
}
