//! Reusable sub-graph builders: the building blocks Table 1 lists
//! ("perceptron, attention, convolution, RNN and a broad range of memory
//! intensive operators").
//!
//! Each builder appends HLO-like nodes to a [`Graph`] and returns the id
//! of the block output. Broadcasts are explicit (as in HLO), which is
//! what creates the shrink/broaden shape traffic §3.1 identifies as the
//! reuse opportunity.

use crate::graph::{DType, Graph, NodeId, OpKind, ReduceOp, Shape};

/// Layer normalization over the last axis — **exactly the Figure 1
/// pattern**: two reductions (mean, variance), an expensive rsqrt, and a
/// tail of light element-wise ops. XLA splits this into 4 kernels; the
/// paper's Fig. 1/§7.4 case study fuses it into one.
pub fn layer_norm(g: &mut Graph, x: NodeId, prefix: &str) -> NodeId {
    let shape = g.node(x).shape.clone();
    let dtype = g.node(x).dtype;
    let last = shape.rank() - 1;
    let n = shape.dims()[last];
    let red_shape = shape.reduce(&[last]);

    // mean = sum(x) / n
    let sum = g.reduce(ReduceOp::Sum, x, vec![last], format!("{prefix}/sum"));
    let n_c = g.constant(Shape::scalar(), dtype, format!("{prefix}/n"));
    let mean = g.binary(OpKind::Div, sum, n_c, format!("{prefix}/mean"));

    // centered = x - broadcast(mean)
    let mean_b = g.broadcast(mean, shape.clone(), format!("{prefix}/mean_b"));
    let centered = g.binary(OpKind::Sub, x, mean_b, format!("{prefix}/center"));

    // var = sum(centered^2) / n
    let sq = g.binary(OpKind::Mul, centered, centered, format!("{prefix}/sq"));
    let var_sum = g.reduce(ReduceOp::Sum, sq, vec![last], format!("{prefix}/var_sum"));
    let var = g.binary(OpKind::Div, var_sum, n_c, format!("{prefix}/var"));

    // inv = rsqrt(var + eps)  — the "expensive op with small tensor shape"
    // that §7.4 says keeps XLA from fusing further (xla-fusion.2).
    let eps = g.constant(Shape::scalar(), dtype, format!("{prefix}/eps"));
    let var_eps = g.binary(OpKind::Add, var, eps, format!("{prefix}/var_eps"));
    let inv = g.unary(OpKind::Rsqrt, var_eps, format!("{prefix}/rsqrt"));

    // y = centered * broadcast(inv) * gamma + beta
    let inv_b = g.broadcast(inv, shape.clone(), format!("{prefix}/inv_b"));
    let norm = g.binary(OpKind::Mul, centered, inv_b, format!("{prefix}/norm"));
    let gamma = g.param(Shape::new(vec![n]), dtype, format!("{prefix}/gamma"));
    let gamma_b = g.broadcast(gamma, shape.clone(), format!("{prefix}/gamma_b"));
    let scaled = g.binary(OpKind::Mul, norm, gamma_b, format!("{prefix}/scale"));
    let beta = g.param(Shape::new(vec![n]), dtype, format!("{prefix}/beta"));
    let beta_b = g.broadcast(beta, shape, format!("{prefix}/beta_b"));
    let _ = red_shape;
    g.binary(OpKind::Add, scaled, beta_b, format!("{prefix}/out"))
}

/// Numerically-stable softmax over the last axis: max-reduce, subtract,
/// exp (expensive mid-kernel producer!), sum-reduce, divide.
pub fn softmax(g: &mut Graph, x: NodeId, prefix: &str) -> NodeId {
    let shape = g.node(x).shape.clone();
    let last = shape.rank() - 1;
    let mx = g.reduce(ReduceOp::Max, x, vec![last], format!("{prefix}/max"));
    let mx_b = g.broadcast(mx, shape.clone(), format!("{prefix}/max_b"));
    let shifted = g.binary(OpKind::Sub, x, mx_b, format!("{prefix}/shift"));
    let e = g.unary(OpKind::Exp, shifted, format!("{prefix}/exp"));
    let s = g.reduce(ReduceOp::Sum, e, vec![last], format!("{prefix}/sum"));
    let s_b = g.broadcast(s, shape, format!("{prefix}/sum_b"));
    g.binary(OpKind::Div, e, s_b, format!("{prefix}/out"))
}

/// GELU activation (erf formulation), as used by BERT's FFN.
pub fn gelu(g: &mut Graph, x: NodeId, prefix: &str) -> NodeId {
    g.unary(OpKind::Gelu, x, format!("{prefix}/gelu"))
}

/// Dropout modeled at inference-off / training-on fidelity: a mask
/// compare + select + scale (3 memory-intensive ops).
pub fn dropout(g: &mut Graph, x: NodeId, prefix: &str) -> NodeId {
    let shape = g.node(x).shape.clone();
    let dtype = g.node(x).dtype;
    let noise = g.param(shape.clone(), dtype, format!("{prefix}/noise"));
    let thresh = g.constant(Shape::scalar(), dtype, format!("{prefix}/p"));
    let mask = g.binary(OpKind::Compare, noise, thresh, format!("{prefix}/mask"));
    let zero = g.constant(Shape::scalar(), dtype, format!("{prefix}/zero"));
    let zero_b = g.broadcast(zero, shape.clone(), format!("{prefix}/zero_b"));
    let sel = {
        let id = g.add(
            OpKind::Select,
            dtype,
            shape.clone(),
            vec![mask, x, zero_b],
            format!("{prefix}/sel"),
        );
        id
    };
    let scale = g.constant(Shape::scalar(), dtype, format!("{prefix}/scale"));
    g.binary(OpKind::Mul, sel, scale, format!("{prefix}/out"))
}

/// Multi-head self-attention: QKV projections (GEMMs), scaled scores,
/// softmax, context GEMM, output projection. `hidden` must be divisible
/// by `heads`.
pub fn attention(
    g: &mut Graph,
    x: NodeId,
    batch: usize,
    seq: usize,
    hidden: usize,
    heads: usize,
    prefix: &str,
) -> NodeId {
    let dtype = g.node(x).dtype;
    let dk = hidden / heads;
    let flat = Shape::new(vec![batch * seq, hidden]);
    let xf = g.add(OpKind::Reshape, dtype, flat.clone(), vec![x], format!("{prefix}/flat"));

    let proj = |g: &mut Graph, name: &str| -> NodeId {
        let w = g.param(Shape::new(vec![hidden, hidden]), dtype, format!("{prefix}/{name}_w"));
        let y = g.matmul(xf, w, format!("{prefix}/{name}_mm"));
        let b = g.param(Shape::new(vec![hidden]), dtype, format!("{prefix}/{name}_b"));
        let b_b = g.broadcast(b, flat.clone(), format!("{prefix}/{name}_bb"));
        let y = g.binary(OpKind::Add, y, b_b, format!("{prefix}/{name}_add"));
        // [B*S,H] -> [B,h,S,dk]
        let r = g.add(
            OpKind::Reshape,
            dtype,
            Shape::new(vec![batch, seq, heads, dk]),
            vec![y],
            format!("{prefix}/{name}_r"),
        );
        g.add(
            OpKind::Transpose { perm: vec![0, 2, 1, 3] },
            dtype,
            Shape::new(vec![batch, heads, seq, dk]),
            vec![r],
            format!("{prefix}/{name}_t"),
        )
    };
    let q = proj(g, "q");
    let k = proj(g, "k");
    let v = proj(g, "v");

    // scores = q @ k^T / sqrt(dk)
    let kt = g.add(
        OpKind::Transpose { perm: vec![0, 1, 3, 2] },
        dtype,
        Shape::new(vec![batch, heads, dk, seq]),
        vec![k],
        format!("{prefix}/k_t"),
    );
    let scores = g.matmul(q, kt, format!("{prefix}/scores"));
    let scale = g.constant(Shape::scalar(), dtype, format!("{prefix}/scale"));
    let scaled = g.binary(OpKind::Mul, scores, scale, format!("{prefix}/scaled"));
    let probs = softmax(g, scaled, &format!("{prefix}/softmax"));

    // context = probs @ v, then merge heads + output projection
    let ctx = g.matmul(probs, v, format!("{prefix}/ctx"));
    let ctx_t = g.add(
        OpKind::Transpose { perm: vec![0, 2, 1, 3] },
        dtype,
        Shape::new(vec![batch, seq, heads, dk]),
        vec![ctx],
        format!("{prefix}/ctx_t"),
    );
    let ctx_f = g.add(
        OpKind::Reshape,
        dtype,
        flat.clone(),
        vec![ctx_t],
        format!("{prefix}/ctx_f"),
    );
    let wo = g.param(Shape::new(vec![hidden, hidden]), dtype, format!("{prefix}/o_w"));
    let out = g.matmul(ctx_f, wo, format!("{prefix}/o_mm"));
    let bo = g.param(Shape::new(vec![hidden]), dtype, format!("{prefix}/o_b"));
    let bo_b = g.broadcast(bo, flat, format!("{prefix}/o_bb"));
    let out = g.binary(OpKind::Add, out, bo_b, format!("{prefix}/o_add"));
    g.add(
        OpKind::Reshape,
        dtype,
        Shape::new(vec![batch, seq, hidden]),
        vec![out],
        format!("{prefix}/out"),
    )
}

/// Transformer feed-forward block: Linear → GELU → Linear.
pub fn ffn(
    g: &mut Graph,
    x: NodeId,
    rows: usize,
    hidden: usize,
    inner: usize,
    prefix: &str,
) -> NodeId {
    let dtype = g.node(x).dtype;
    let flat = Shape::new(vec![rows, hidden]);
    let xf = g.add(OpKind::Reshape, dtype, flat.clone(), vec![x], format!("{prefix}/flat"));
    let w1 = g.param(Shape::new(vec![hidden, inner]), dtype, format!("{prefix}/w1"));
    let h = g.matmul(xf, w1, format!("{prefix}/mm1"));
    let b1 = g.param(Shape::new(vec![inner]), dtype, format!("{prefix}/b1"));
    let b1_b = g.broadcast(b1, Shape::new(vec![rows, inner]), format!("{prefix}/b1b"));
    let h = g.binary(OpKind::Add, h, b1_b, format!("{prefix}/add1"));
    let h = gelu(g, h, prefix);
    let w2 = g.param(Shape::new(vec![inner, hidden]), dtype, format!("{prefix}/w2"));
    let o = g.matmul(h, w2, format!("{prefix}/mm2"));
    let b2 = g.param(Shape::new(vec![hidden]), dtype, format!("{prefix}/b2"));
    let b2_b = g.broadcast(b2, flat, format!("{prefix}/b2b"));
    g.binary(OpKind::Add, o, b2_b, format!("{prefix}/add2"))
}

/// One unrolled GRU cell step (DIEN's recurrence). Produces ~13
/// memory-intensive ops + 2 GEMMs per step, matching the op-call
/// explosion Table 2 shows for DIEN.
pub fn gru_cell(
    g: &mut Graph,
    x: NodeId,
    h_prev: NodeId,
    hidden: usize,
    prefix: &str,
) -> NodeId {
    let dtype = g.node(x).dtype;
    let batch = g.node(x).shape.dims()[0];
    let hshape = Shape::new(vec![batch, hidden]);
    let gshape = Shape::new(vec![batch, 3 * hidden]);

    let wx = g.param(
        Shape::new(vec![g.node(x).shape.dims()[1], 3 * hidden]),
        dtype,
        format!("{prefix}/wx"),
    );
    let gx = g.matmul(x, wx, format!("{prefix}/gx"));
    let wh = g.param(Shape::new(vec![hidden, 3 * hidden]), dtype, format!("{prefix}/wh"));
    let gh = g.matmul(h_prev, wh, format!("{prefix}/gh"));
    let b = g.param(Shape::new(vec![3 * hidden]), dtype, format!("{prefix}/b"));
    let b_b = g.broadcast(b, gshape.clone(), format!("{prefix}/bb"));
    let gsum = g.binary(OpKind::Add, gx, gh, format!("{prefix}/gsum"));
    let gates = g.binary(OpKind::Add, gsum, b_b, format!("{prefix}/gates"));

    // slice out r, z, n gates
    let r_pre = g.add(OpKind::Slice, dtype, hshape.clone(), vec![gates], format!("{prefix}/r_pre"));
    let z_pre = g.add(OpKind::Slice, dtype, hshape.clone(), vec![gates], format!("{prefix}/z_pre"));
    let n_pre = g.add(OpKind::Slice, dtype, hshape.clone(), vec![gates], format!("{prefix}/n_pre"));
    let r = g.unary(OpKind::Sigmoid, r_pre, format!("{prefix}/r"));
    let z = g.unary(OpKind::Sigmoid, z_pre, format!("{prefix}/z"));
    let rn = g.binary(OpKind::Mul, r, n_pre, format!("{prefix}/rn"));
    let n = g.unary(OpKind::Tanh, rn, format!("{prefix}/n"));

    // h = (1-z)*n + z*h_prev
    let one = g.constant(Shape::scalar(), dtype, format!("{prefix}/one"));
    let one_b = g.broadcast(one, hshape.clone(), format!("{prefix}/one_b"));
    let zi = g.binary(OpKind::Sub, one_b, z, format!("{prefix}/zi"));
    let a = g.binary(OpKind::Mul, zi, n, format!("{prefix}/a"));
    let bterm = g.binary(OpKind::Mul, z, h_prev, format!("{prefix}/bt"));
    g.binary(OpKind::Add, a, bterm, format!("{prefix}/h"))
}

/// One unrolled LSTM cell step (ASR/CRNN recurrence): ~16 memory-
/// intensive ops + 2 GEMMs.
pub fn lstm_cell(
    g: &mut Graph,
    x: NodeId,
    h_prev: NodeId,
    c_prev: NodeId,
    hidden: usize,
    prefix: &str,
) -> (NodeId, NodeId) {
    let dtype = g.node(x).dtype;
    let batch = g.node(x).shape.dims()[0];
    let hshape = Shape::new(vec![batch, hidden]);
    let gshape = Shape::new(vec![batch, 4 * hidden]);

    let wx = g.param(
        Shape::new(vec![g.node(x).shape.dims()[1], 4 * hidden]),
        dtype,
        format!("{prefix}/wx"),
    );
    let gx = g.matmul(x, wx, format!("{prefix}/gx"));
    let wh = g.param(Shape::new(vec![hidden, 4 * hidden]), dtype, format!("{prefix}/wh"));
    let gh = g.matmul(h_prev, wh, format!("{prefix}/gh"));
    let b = g.param(Shape::new(vec![4 * hidden]), dtype, format!("{prefix}/b"));
    let b_b = g.broadcast(b, gshape.clone(), format!("{prefix}/bb"));
    let s = g.binary(OpKind::Add, gx, gh, format!("{prefix}/s"));
    let gates = g.binary(OpKind::Add, s, b_b, format!("{prefix}/gates"));

    let i_pre = g.add(OpKind::Slice, dtype, hshape.clone(), vec![gates], format!("{prefix}/i_pre"));
    let f_pre = g.add(OpKind::Slice, dtype, hshape.clone(), vec![gates], format!("{prefix}/f_pre"));
    let o_pre = g.add(OpKind::Slice, dtype, hshape.clone(), vec![gates], format!("{prefix}/o_pre"));
    let c_pre = g.add(OpKind::Slice, dtype, hshape.clone(), vec![gates], format!("{prefix}/c_pre"));
    let i = g.unary(OpKind::Sigmoid, i_pre, format!("{prefix}/i"));
    let f = g.unary(OpKind::Sigmoid, f_pre, format!("{prefix}/f"));
    let o = g.unary(OpKind::Sigmoid, o_pre, format!("{prefix}/o"));
    let cc = g.unary(OpKind::Tanh, c_pre, format!("{prefix}/cc"));

    let fc = g.binary(OpKind::Mul, f, c_prev, format!("{prefix}/fc"));
    let ic = g.binary(OpKind::Mul, i, cc, format!("{prefix}/ic"));
    let c = g.binary(OpKind::Add, fc, ic, format!("{prefix}/c"));
    let ct = g.unary(OpKind::Tanh, c, format!("{prefix}/ct"));
    let h = g.binary(OpKind::Mul, o, ct, format!("{prefix}/h"));
    (h, c)
}

/// Conv → BatchNorm(inference form) → ReLU block for the CRNN backbone.
/// BN at inference is scale+shift: 4 memory-intensive ops + the conv.
pub fn conv_bn_relu(
    g: &mut Graph,
    x: NodeId,
    out_shape: Shape,
    prefix: &str,
) -> NodeId {
    let dtype = g.node(x).dtype;
    let w = g.param(Shape::new(vec![3, 3]), dtype, format!("{prefix}/w"));
    let conv = g.add(OpKind::Conv, dtype, out_shape.clone(), vec![x, w], format!("{prefix}/conv"));
    let ch = *out_shape.dims().last().unwrap();
    let scale = g.param(Shape::new(vec![ch]), dtype, format!("{prefix}/bn_s"));
    let scale_b = g.broadcast(scale, out_shape.clone(), format!("{prefix}/bn_sb"));
    let scaled = g.binary(OpKind::Mul, conv, scale_b, format!("{prefix}/bn_mul"));
    let shift = g.param(Shape::new(vec![ch]), dtype, format!("{prefix}/bn_t"));
    let shift_b = g.broadcast(shift, out_shape.clone(), format!("{prefix}/bn_tb"));
    let shifted = g.binary(OpKind::Add, scaled, shift_b, format!("{prefix}/bn_add"));
    g.unary(OpKind::Relu, shifted, format!("{prefix}/relu"))
}

/// Embedding lookup: gather + (optionally) sum-pool over the id axis.
pub fn embedding_lookup(
    g: &mut Graph,
    ids_shape: Shape,
    dim: usize,
    pool: bool,
    prefix: &str,
) -> NodeId {
    let ids = g.param(ids_shape.clone(), DType::I32, format!("{prefix}/ids"));
    let table = g.param(Shape::new(vec![100_000, dim]), DType::F32, format!("{prefix}/table"));
    let mut dims = ids_shape.dims().to_vec();
    dims.push(dim);
    let gathered = g.add(
        OpKind::Gather,
        DType::F32,
        Shape::new(dims.clone()),
        vec![table, ids],
        format!("{prefix}/gather"),
    );
    if pool && dims.len() >= 2 {
        let axis = dims.len() - 2;
        g.reduce(ReduceOp::Sum, gathered, vec![axis], format!("{prefix}/pool"))
    } else {
        gathered
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpClass;

    fn base(batch: usize, seq: usize, hidden: usize) -> (Graph, NodeId) {
        let mut g = Graph::new("t");
        let x = g.param(Shape::new(vec![batch, seq, hidden]), DType::F32, "x");
        (g, x)
    }

    #[test]
    fn layer_norm_matches_fig1_op_mix() {
        let (mut g, x) = base(32, 128, 768);
        let out = layer_norm(&mut g, x, "ln");
        g.validate().unwrap();
        assert_eq!(g.node(out).shape, Shape::new(vec![32, 128, 768]));
        // Exactly two reductions (mean path + variance path)...
        let reds = g
            .nodes()
            .iter()
            .filter(|n| n.kind.class() == OpClass::Reduction)
            .count();
        assert_eq!(reds, 2);
        // ...and one expensive element-wise op (rsqrt).
        let exp = g
            .nodes()
            .iter()
            .filter(|n| n.kind.class() == OpClass::ExpensiveElementwise)
            .count();
        assert_eq!(exp, 1);
    }

    #[test]
    fn softmax_has_exp_between_reductions() {
        let (mut g, x) = base(4, 8, 64);
        let out = softmax(&mut g, x, "sm");
        g.validate().unwrap();
        assert_eq!(g.node(out).shape, g.node(x).shape);
        // exp must be a *producer* of the sum reduction — the exact
        // "expensive op in the middle" XLA refuses to fuse (§2.1).
        let exp_node = g.nodes().iter().find(|n| n.kind == OpKind::Exp).unwrap();
        assert!(!g.consumers(exp_node.id).is_empty());
    }

    #[test]
    fn attention_shapes() {
        let (mut g, x) = base(2, 16, 64);
        let out = attention(&mut g, x, 2, 16, 64, 4, "attn");
        g.validate().unwrap();
        assert_eq!(g.node(out).shape, Shape::new(vec![2, 16, 64]));
        assert!(g.num_compute_intensive() >= 6); // 4 proj + 2 batched
    }

    #[test]
    fn ffn_shapes() {
        let (mut g, x) = base(2, 16, 64);
        let out = ffn(&mut g, x, 32, 64, 256, "ffn");
        g.validate().unwrap();
        assert_eq!(g.node(out).shape, Shape::new(vec![32, 64]));
    }

    #[test]
    fn gru_cell_recurrence() {
        let mut g = Graph::new("gru");
        let x = g.param(Shape::new(vec![8, 32]), DType::F32, "x");
        let h0 = g.param(Shape::new(vec![8, 16]), DType::F32, "h0");
        let h1 = gru_cell(&mut g, x, h0, 16, "s0");
        g.validate().unwrap();
        assert_eq!(g.node(h1).shape, Shape::new(vec![8, 16]));
        let mem = g.num_memory_intensive();
        assert!((10..=18).contains(&mem), "gru mem ops = {mem}");
    }

    #[test]
    fn lstm_cell_recurrence() {
        let mut g = Graph::new("lstm");
        let x = g.param(Shape::new(vec![8, 32]), DType::F32, "x");
        let h0 = g.param(Shape::new(vec![8, 16]), DType::F32, "h0");
        let c0 = g.param(Shape::new(vec![8, 16]), DType::F32, "c0");
        let (h1, c1) = lstm_cell(&mut g, x, h0, c0, 16, "s0");
        g.validate().unwrap();
        assert_eq!(g.node(h1).shape, Shape::new(vec![8, 16]));
        assert_eq!(g.node(c1).shape, Shape::new(vec![8, 16]));
    }

    #[test]
    fn conv_bn_relu_block() {
        let mut g = Graph::new("cnn");
        let x = g.param(Shape::new(vec![8, 32, 100, 3]), DType::F32, "x");
        let y = conv_bn_relu(&mut g, x, Shape::new(vec![8, 32, 100, 64]), "c0");
        g.validate().unwrap();
        assert_eq!(g.node(y).shape, Shape::new(vec![8, 32, 100, 64]));
        assert_eq!(g.num_compute_intensive(), 1);
    }

    #[test]
    fn embedding_pools() {
        let mut g = Graph::new("emb");
        let out = embedding_lookup(&mut g, Shape::new(vec![256, 50]), 32, true, "e");
        g.validate().unwrap();
        assert_eq!(g.node(out).shape, Shape::new(vec![256, 32]));
    }

    #[test]
    fn dropout_three_memops_plus_mask() {
        let (mut g, x) = base(2, 4, 8);
        let before = g.len();
        let _ = dropout(&mut g, x, "do");
        g.validate().unwrap();
        assert!(g.len() - before >= 5);
    }
}
