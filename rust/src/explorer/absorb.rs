//! Anchored-region absorption: stitch fusion patterns across the
//! compute-intensive boundary (cross-GEMM stitching).
//!
//! The classic cut rule severs every fusible region at GEMM/conv nodes,
//! so each epilogue (bias+GELU, residual chains) and prologue pays an
//! HBM round-trip plus a kernel launch against the anchor. This pass
//! runs *after* the cut-based plan is final (beam → backfill → remote
//! fusion) and lets each anchor ([`crate::graph::Fusibility::Anchor`])
//! claim at most one adjacent epilogue pattern and one prologue pattern,
//! lowered through the [`crate::codegen::CompositionScheme::GemmEpilogue`]
//! shared-memory hand-off.
//!
//! Decisions are a pure function of (graph, device, options): the pass
//! never mutates the pattern set, only annotates the plan — so sharded
//! exploration, plan porting, and the per-shard decision digests all see
//! identical outcomes, and lowering can always fall back to the cut form
//! when the hand-off is infeasible at a different device or shape.

use super::candidates::ExploreOptions;
use super::delta::DeltaModel;
use super::pattern::{AbsorbedAnchor, FusionPattern, FusionPlan};
use crate::gpu::DeviceSpec;
use crate::graph::{Graph, NodeId};

/// Annotate `plan` with the GEMM boundaries worth absorbing.
///
/// For every anchor in id order: the **epilogue** candidate is the plan
/// pattern containing a direct consumer of the anchor whose row space
/// matches the anchor output; the **prologue** candidate is a pattern
/// feeding the anchor whose every output is consumed only by the anchor
/// (otherwise its result must reach HBM anyway). A boundary is absorbed
/// iff the delta model's [`DeltaModel::absorb_gain_us`] is positive —
/// saved launch + saved intermediate round-trip beating the staging
/// tile's occupancy pressure — and the stitched node set stays acyclic.
/// Each pattern is claimed by at most one anchor.
pub fn absorb_anchors(
    graph: &Graph,
    device: &DeviceSpec,
    mut plan: FusionPlan,
    opts: &ExploreOptions,
) -> FusionPlan {
    plan.absorbed.clear();
    if !opts.absorb_anchors {
        return plan;
    }
    let model = DeltaModel::with_params(graph, device.clone(), opts.cost);

    // node -> owning pattern index.
    let mut owner: Vec<Option<usize>> = vec![None; graph.len()];
    for (pi, p) in plan.patterns.iter().enumerate() {
        for &id in p.nodes() {
            owner[id.idx()] = Some(pi);
        }
    }
    let mut claimed = vec![false; plan.patterns.len()];

    for node in graph.nodes() {
        if !node.kind.is_anchor() {
            continue;
        }
        let anchor = node.id;

        let epilogue = if model.absorb_gain_us(anchor) > 0.0 {
            claim_epilogue(graph, &plan, &owner, &mut claimed, anchor)
        } else {
            None
        };
        let prologue = claim_prologue(graph, &model, &plan, &owner, &mut claimed, anchor);

        if epilogue.is_some() || prologue.is_some() {
            plan.absorbed.push(AbsorbedAnchor { anchor, epilogue, prologue });
        }
    }
    plan
}

/// The subset of `plan.absorbed` whose staging hand-off is feasible on
/// `device` at `graph`'s shapes — the boundaries lowering will actually
/// merge. Re-derives the hard-feasibility half of
/// [`DeltaModel::absorb_gain_us`] (staging fits the per-block cap and
/// the anchor still launches) without cost parameters, so lowering and
/// plan porting get one deterministic answer: an absorbed boundary
/// either folds into its anchor's library kernel here or the caller
/// falls back to the cut form / re-explores. Sides referencing
/// out-of-range ids or patterns missing from the plan are dropped
/// (foreign-plan defense, mirroring `retune_plan`).
pub fn applied_absorptions(
    graph: &Graph,
    plan: &FusionPlan,
    device: &DeviceSpec,
) -> Vec<AbsorbedAnchor> {
    let mut out = Vec::new();
    for a in &plan.absorbed {
        if a.anchor.idx() >= graph.len() || !graph.node(a.anchor).kind.is_anchor() {
            continue;
        }
        let keep = |side: Option<NodeId>, is_epilogue: bool| -> Option<NodeId> {
            let mid = side?;
            let p = plan.patterns.iter().find(|p| p.min_id() == mid)?;
            if p.nodes().iter().any(|n| n.idx() >= graph.len()) {
                return None;
            }
            let node = graph.node(boundary_node(graph, a.anchor, p, is_epilogue)?);
            let staging = crate::codegen::shmem::epilogue_staging_bytes(
                node.shape.inner_dim(),
                node.dtype.size_bytes(),
            );
            crate::codegen::shmem::epilogue_feasible(device, staging).then_some(mid)
        };
        let applied = AbsorbedAnchor {
            anchor: a.anchor,
            epilogue: keep(a.epilogue, true),
            prologue: keep(a.prologue, false),
        };
        if applied.boundaries() > 0 {
            out.push(applied);
        }
    }
    out
}

/// The staged boundary tensor of one absorbed side: the anchor output
/// for an epilogue, the pattern output feeding the anchor for a
/// prologue.
pub fn boundary_node(
    graph: &Graph,
    anchor: NodeId,
    pattern: &FusionPattern,
    is_epilogue: bool,
) -> Option<NodeId> {
    if is_epilogue {
        Some(anchor)
    } else {
        graph
            .node(anchor)
            .inputs
            .iter()
            .copied()
            .find(|&i| pattern.contains(i))
    }
}

/// The epilogue pattern for `anchor`: smallest-`min_id` unclaimed plan
/// pattern that directly consumes the anchor output over the same row
/// space, with an acyclic union. Returns the pattern's `min_id`.
fn claim_epilogue(
    graph: &Graph,
    plan: &FusionPlan,
    owner: &[Option<usize>],
    claimed: &mut [bool],
    anchor: NodeId,
) -> Option<NodeId> {
    let rows = graph.node(anchor).shape.outer_elements();
    let mut cands: Vec<usize> = graph
        .consumers(anchor)
        .iter()
        .filter_map(|c| owner[c.idx()])
        .collect();
    cands.sort_unstable();
    cands.dedup();
    for pi in cands {
        if claimed[pi] {
            continue;
        }
        let p = &plan.patterns[pi];
        // The hand-off streams anchor-output rows; a pattern iterating a
        // different row space cannot consume the staged tile.
        if crate::codegen::latency::pattern_rows(graph, p.nodes()).0 != rows {
            continue;
        }
        let mut union: Vec<NodeId> = p.nodes().to_vec();
        union.push(anchor);
        if graph.fusion_creates_cycle(&union) {
            continue;
        }
        claimed[pi] = true;
        return Some(p.min_id());
    }
    None
}

/// The prologue pattern for `anchor`: an unclaimed pattern producing one
/// of the anchor's direct inputs, whose every pattern output flows only
/// into this anchor, with positive gain on that boundary tensor.
fn claim_prologue(
    graph: &Graph,
    model: &DeltaModel,
    plan: &FusionPlan,
    owner: &[Option<usize>],
    claimed: &mut [bool],
    anchor: NodeId,
) -> Option<NodeId> {
    for &inp in &graph.node(anchor).inputs {
        let Some(pi) = owner[inp.idx()] else { continue };
        if claimed[pi] {
            continue;
        }
        let p = &plan.patterns[pi];
        let outputs = graph.pattern_outputs(p.nodes());
        let only_feeds_anchor = outputs.iter().all(|&o| {
            graph.consumers(o).iter().all(|&c| c == anchor || p.contains(c))
        });
        if !only_feeds_anchor {
            continue;
        }
        let mut union: Vec<NodeId> = p.nodes().to_vec();
        union.push(anchor);
        if graph.fusion_creates_cycle(&union) {
            continue;
        }
        if model.absorb_gain_us(inp) <= 0.0 {
            continue;
        }
        claimed[pi] = true;
        return Some(p.min_id());
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explorer::pattern::FusionPattern;
    use crate::graph::{DType, OpKind, Shape};

    /// matmul [rows,64]×[64,cols] followed by broadcast-bias + add +
    /// relu, with the epilogue chain pre-fused into one pattern.
    fn gemm_with_epilogue(rows: usize, cols: usize) -> (Graph, NodeId, FusionPlan) {
        let mut g = Graph::new("ge");
        let x = g.param(Shape::new(vec![rows, 64]), DType::F32, "x");
        let w = g.param(Shape::new(vec![64, cols]), DType::F32, "w");
        let mm = g.add(
            OpKind::MatMul,
            DType::F32,
            Shape::new(vec![rows, cols]),
            vec![x, w],
            "mm",
        );
        let b = g.param(Shape::new(vec![cols]), DType::F32, "b");
        let bb = g.add(
            OpKind::Broadcast,
            DType::F32,
            Shape::new(vec![rows, cols]),
            vec![b],
            "bb",
        );
        let add = g.binary(OpKind::Add, mm, bb, "add");
        let relu = g.unary(OpKind::Relu, add, "relu");
        let plan = FusionPlan {
            patterns: vec![FusionPattern::new(vec![bb, add, relu])],
            ..Default::default()
        };
        (g, mm, plan)
    }

    /// The ISSUE-pinned accept/reject pair: absorption happens when the
    /// saved launch + round-trip beats the staging occupancy pressure,
    /// and is rejected when the epilogue's shmem/occupancy cost wins.
    #[test]
    fn absorption_accepts_profitable_boundary_and_rejects_occupancy_pressure() {
        let device = DeviceSpec::v100();
        let opts = ExploreOptions::default();

        // Accept: 256-wide rows stage 8 KB — full occupancy, the saved
        // launch + round-trip is pure profit.
        let (g, mm, plan) = gemm_with_epilogue(512, 256);
        let out = absorb_anchors(&g, &device, plan, &opts);
        assert_eq!(out.absorbed.len(), 1, "expected the boundary absorbed");
        assert_eq!(out.absorbed[0].anchor, mm);
        assert!(out.absorbed[0].epilogue.is_some());

        // Reject (economics): 1500-wide rows stage ~47 KB, crushing the
        // anchor kernel to 0.25 occupancy; with only 32 rows the saved
        // round-trip is far too small to pay for that.
        let (g, _, plan) = gemm_with_epilogue(32, 1500);
        let out = absorb_anchors(&g, &device, plan, &opts);
        assert!(out.absorbed.is_empty(), "occupancy pressure must reject");

        // Reject (hard infeasibility): 2048-wide rows need 64 KB of
        // staging — over the per-block cap, unlaunchable.
        let (g, _, plan) = gemm_with_epilogue(512, 2048);
        let out = absorb_anchors(&g, &device, plan, &opts);
        assert!(out.absorbed.is_empty(), "infeasible staging must reject");
    }

    #[test]
    fn applied_set_drops_boundaries_that_no_longer_stage() {
        // Absorb at 256 columns, then re-check the same plan against a
        // sibling graph at 2048 columns: the 64 KB staging tile is over
        // the per-block cap there, so the applied set is empty —
        // lowering falls back to the cut form and plan porting
        // re-explores.
        let device = DeviceSpec::v100();
        let (g, _, plan) = gemm_with_epilogue(512, 256);
        let plan = absorb_anchors(&g, &device, plan, &ExploreOptions::default());
        assert_eq!(plan.absorbed_boundaries(), 1);
        assert_eq!(applied_absorptions(&g, &plan, &device), plan.absorbed);
        let (wide, _, _) = gemm_with_epilogue(512, 2048);
        assert!(applied_absorptions(&wide, &plan, &device).is_empty());
    }

    #[test]
    fn absorption_is_off_for_baseline_style_options() {
        let device = DeviceSpec::v100();
        let opts = ExploreOptions { absorb_anchors: false, ..Default::default() };
        let (g, _, plan) = gemm_with_epilogue(512, 256);
        let out = absorb_anchors(&g, &device, plan, &opts);
        assert!(out.absorbed.is_empty());
    }

    #[test]
    fn prologue_requires_sole_consumption_by_the_anchor() {
        let mut g = Graph::new("pro");
        let x = g.param(Shape::new(vec![512, 256]), DType::F32, "x");
        let e = g.unary(OpKind::Exp, x, "e");
        let n = g.unary(OpKind::Neg, e, "n");
        let w = g.param(Shape::new(vec![256, 256]), DType::F32, "w");
        let mm = g.add(
            OpKind::MatMul,
            DType::F32,
            Shape::new(vec![512, 256]),
            vec![n, w],
            "mm",
        );
        let _ = mm;
        let plan = FusionPlan {
            patterns: vec![FusionPattern::new(vec![e, n])],
            ..Default::default()
        };
        let device = DeviceSpec::v100();
        let opts = ExploreOptions::default();
        // n feeds only the anchor: the prologue is absorbed.
        let out = absorb_anchors(&g, &device, plan.clone(), &opts);
        assert_eq!(out.absorbed.len(), 1);
        assert!(out.absorbed[0].prologue.is_some());
        assert!(out.absorbed[0].epilogue.is_none());

        // A second consumer of n outside the anchor blocks absorption.
        let mut g2 = Graph::new("pro2");
        let x = g2.param(Shape::new(vec![512, 256]), DType::F32, "x");
        let e = g2.unary(OpKind::Exp, x, "e");
        let n = g2.unary(OpKind::Neg, e, "n");
        let w = g2.param(Shape::new(vec![256, 256]), DType::F32, "w");
        let _mm = g2.add(
            OpKind::MatMul,
            DType::F32,
            Shape::new(vec![512, 256]),
            vec![n, w],
            "mm",
        );
        let _leak = g2.unary(OpKind::Abs, n, "leak");
        let plan2 = FusionPlan {
            patterns: vec![FusionPattern::new(vec![e, n])],
            ..Default::default()
        };
        let out2 = absorb_anchors(&g2, &device, plan2, &opts);
        assert!(out2.absorbed.is_empty());
    }

    #[test]
    fn each_pattern_is_claimed_at_most_once() {
        // One epilogue chain sandwiched between two matmuls: it can be
        // mm1's epilogue or mm2's prologue, never both.
        let mut g = Graph::new("sandwich");
        let x = g.param(Shape::new(vec![512, 256]), DType::F32, "x");
        let w1 = g.param(Shape::new(vec![256, 256]), DType::F32, "w1");
        let mm1 = g.add(
            OpKind::MatMul,
            DType::F32,
            Shape::new(vec![512, 256]),
            vec![x, w1],
            "mm1",
        );
        let gelu = g.unary(OpKind::Gelu, mm1, "gelu");
        let neg = g.unary(OpKind::Neg, gelu, "neg");
        let w2 = g.param(Shape::new(vec![256, 256]), DType::F32, "w2");
        let _mm2 = g.add(
            OpKind::MatMul,
            DType::F32,
            Shape::new(vec![512, 256]),
            vec![neg, w2],
            "mm2",
        );
        let plan = FusionPlan {
            patterns: vec![FusionPattern::new(vec![gelu, neg])],
            ..Default::default()
        };
        let out = absorb_anchors(&g, &DeviceSpec::v100(), plan, &ExploreOptions::default());
        let boundaries = out.absorbed_boundaries();
        assert_eq!(boundaries, 1, "one pattern, one claim: {:?}", out.absorbed);
        assert_eq!(out.absorbed[0].anchor, mm1, "anchor id order wins");
    }
}
