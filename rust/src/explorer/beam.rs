//! Fusion-plan composition by beam search (§5.3).
//!
//! All per-vertex candidate patterns (which may overlap) form the pool
//! `E`; the goal is a set of non-overlapping patterns maximizing Σf.
//! FusionStitching keeps 3 *buffer sets* (beam width 3), traverses
//! vertices producer→consumer, tries to append each candidate of each
//! vertex into each buffer set when it does not overlap, keeps the best
//! 3 sets per step by accumulated score, and finally picks among the 3
//! finished plans with the accurate latency-evaluator.

use super::candidates::CandidateSets;
use super::delta::DeltaModel;
use super::pattern::{FusionPattern, FusionPlan};
use crate::gpu::DeviceSpec;
use crate::graph::Graph;
use std::rc::Rc;

/// Beam-search knobs (paper default: width 3).
#[derive(Debug, Clone)]
pub struct BeamOptions {
    pub width: usize,
    /// Cost constants for the final accurate-model selection among the
    /// finished beams.
    pub cost: crate::gpu::CostParams,
    /// Defense-in-depth footprint filter (default on, mirroring
    /// [`super::candidates::ExploreOptions::footprint_prune`]): a
    /// candidate whose intermediate-footprint bound cannot launch never
    /// expands a beam state, and each rejection is counted on the
    /// resulting plan. With DP-level pruning on this filters nothing —
    /// the candidate sets are already clean — but it keeps the beam
    /// sound for callers feeding it hand-built candidate sets.
    pub footprint_prune: bool,
}

impl Default for BeamOptions {
    fn default() -> Self {
        BeamOptions {
            width: 3,
            cost: crate::gpu::CostParams::default(),
            footprint_prune: true,
        }
    }
}

/// Persistent (structurally shared) list of chosen patterns. Beam
/// states fork at every vertex; a naive `Vec<FusionPattern>` clone made
/// the search O(V·P) in pattern copies (1.5 s on DIEN-train's 13k-op
/// graph — see EXPERIMENTS.md §Perf). Sharing the tail via `Rc` makes
/// a beam clone O(bitset) instead.
#[derive(Debug)]
struct Chosen {
    pattern: FusionPattern,
    score: f64,
    prev: Option<Rc<Chosen>>,
}

impl Drop for Chosen {
    fn drop(&mut self) {
        // Unlink iteratively: the default recursive drop would recurse
        // once per chosen pattern, overflowing the stack on plans with
        // tens of thousands of patterns (fleet-scale graphs).
        let mut cur = self.prev.take();
        while let Some(rc) = cur {
            match Rc::try_unwrap(rc) {
                Ok(mut link) => cur = link.prev.take(),
                Err(_) => break, // shared tail: another beam still owns it
            }
        }
    }
}

/// One in-flight buffer set: chosen patterns + coverage bitset + score.
#[derive(Debug, Clone)]
struct BufferSet {
    chosen: Option<Rc<Chosen>>,
    covered: Vec<u64>,
    score: f64,
}

impl BufferSet {
    fn new(n_nodes: usize) -> Self {
        BufferSet {
            chosen: None,
            covered: vec![0u64; n_nodes.div_ceil(64)],
            score: 0.0,
        }
    }

    fn overlaps(&self, p: &FusionPattern) -> bool {
        p.nodes()
            .iter()
            .any(|id| self.covered[id.idx() / 64] >> (id.idx() % 64) & 1 == 1)
    }

    fn push(&mut self, p: FusionPattern, score: f64) {
        for id in p.nodes() {
            self.covered[id.idx() / 64] |= 1 << (id.idx() % 64);
        }
        self.chosen = Some(Rc::new(Chosen {
            pattern: p,
            score,
            prev: self.chosen.take(),
        }));
        self.score += score;
    }

    /// Materialize the chosen patterns (end of search only).
    fn into_patterns(self) -> Vec<FusionPattern> {
        let mut out = Vec::new();
        let mut cur = self.chosen;
        while let Some(link) = cur {
            out.push(link.pattern.clone());
            let _ = link.score;
            cur = link.prev.clone();
        }
        out.reverse();
        out
    }
}

/// Drop beam states whose coverage set already has a better-scoring
/// representative, leaving the survivors in score order. A plain
/// score-sort + `Vec::dedup_by` removed *adjacent* duplicates only —
/// two states covering the same nodes through different pattern splits
/// accumulate different scores, so they need not sort adjacently, and
/// the surviving duplicates crowded genuinely diverse states out of the
/// width-k window. Grouping by coverage first makes duplicates adjacent
/// without hashing (or cloning) the per-state bitsets; both sorts are
/// stable so full ties keep insertion order and replays stay
/// byte-identical.
fn dedup_by_coverage(states: &mut Vec<BufferSet>) {
    let by_score = |a: &BufferSet, b: &BufferSet| {
        b.score.partial_cmp(&a.score).unwrap_or(std::cmp::Ordering::Equal)
    };
    states.sort_by(|a, b| a.covered.cmp(&b.covered).then(by_score(a, b)));
    states.dedup_by(|next, prev| next.covered == prev.covered);
    states.sort_by(by_score);
}

/// Compose the final plan from candidate sets.
pub fn compose_plan(
    graph: &Graph,
    device: &DeviceSpec,
    candidates: &CandidateSets,
    opts: &BeamOptions,
) -> FusionPlan {
    let mut beams = vec![BufferSet::new(graph.len())];
    // Capacity enforcement tracks the prune flag so the unpruned
    // ablation's final selection stays optimistic end-to-end (an
    // over-cap pattern must not be vetoed here either — that happens at
    // accurate-model pruning time in that world).
    let model = DeltaModel::with_params(graph, device.clone(), opts.cost)
        .with_capacity_enforcement(opts.footprint_prune);
    let mut footprint_pruned = 0usize;

    // Producer→consumer order = forward topological order.
    for &v in graph.topo_order().iter() {
        let cands = &candidates[v.idx()];
        if cands.is_empty() {
            continue;
        }
        // Defense-in-depth footprint filter, applied once per vertex
        // (not per beam fork, which would over-count): a candidate the
        // DP should already have pruned never expands a state.
        let admitted: Vec<&super::candidates::ScoredPattern> = cands
            .iter()
            .filter(|sc| {
                // Only multi-op, positive-score patterns improve a plan.
                if sc.pattern.len() < 2 || sc.score <= 0.0 {
                    return false;
                }
                if opts.footprint_prune
                    && !model.pattern_footprint_feasible(sc.pattern.nodes())
                {
                    footprint_pruned += 1;
                    return false;
                }
                true
            })
            .collect();
        if admitted.is_empty() {
            continue;
        }
        // Move the current beams in as the "skip this vertex" option —
        // appends fork from them by (cheap, structurally-shared) clone.
        let mut next: Vec<BufferSet> = std::mem::take(&mut beams);
        let skip_count = next.len();
        for bi in 0..skip_count {
            for sc in &admitted {
                if next[bi].overlaps(&sc.pattern) {
                    continue;
                }
                let mut nb = next[bi].clone();
                nb.push(sc.pattern.clone(), sc.score);
                next.push(nb);
            }
        }
        // Dedup identical coverage keeping the best score, ending in
        // score order (beam diversity: one slot per node set).
        dedup_by_coverage(&mut next);
        next.truncate(opts.width.max(1));
        beams = next;
    }

    // Final selection among the beam's plans with the accurate model:
    // total simplified kernel time over the *whole* kernel list (the
    // paper's latency-evaluator pass over candidate plans).
    let mut best = beams
        .into_iter()
        .map(|b| FusionPlan { patterns: b.into_patterns(), ..Default::default() })
        .min_by(|a, b| {
            let ta = model.plan_time_us(&a.kernels(graph));
            let tb = model.plan_time_us(&b.kernels(graph));
            ta.partial_cmp(&tb).unwrap_or(std::cmp::Ordering::Equal)
        })
        .unwrap_or_default();
    best.footprint_pruned = footprint_pruned;
    debug_assert!(best.is_disjoint());
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explorer::candidates::{candidate_patterns, ExploreOptions};
    use crate::graph::{DType, Shape};
    use crate::workloads::blocks;

    #[test]
    fn layernorm_composes_into_one_kernel() {
        let mut g = Graph::new("ln");
        let x = g.param(Shape::new(vec![4096, 768]), DType::F32, "x");
        let _ = blocks::layer_norm(&mut g, x, "ln");
        let device = DeviceSpec::v100();
        let opts = ExploreOptions::default();
        let cands = candidate_patterns(&g, &device, &opts);
        let plan = compose_plan(&g, &device, &cands, &BeamOptions::default());
        assert!(plan.is_disjoint());
        // Beam alone leaves sibling producers (gamma/beta broadcasts)
        // out; the absorption pass closes them into the main pattern.
        let plan = crate::explorer::absorb_producers(&g, plan, &opts);
        let kernels = plan.kernels(&g);
        // FusionStitching's Fig. 1 claim: one kernel for the whole LN
        // (XLA needs 4).
        assert!(
            kernels.len() <= 2,
            "expected ≤2 kernels, got {}: {kernels:?}",
            kernels.len()
        );
        let biggest = kernels.iter().map(|k| k.len()).max().unwrap();
        assert!(biggest >= 14, "main kernel only {biggest} ops");
    }

    #[test]
    fn plans_never_overlap_on_random_graphs() {
        use crate::util::Prng;
        use crate::workloads::synthetic::{generate, SyntheticConfig};
        let device = DeviceSpec::v100();
        for seed in 0..6 {
            let g = generate(
                &SyntheticConfig { num_ops: 60, ..Default::default() },
                &mut Prng::new(seed + 1),
            );
            let cands = candidate_patterns(&g, &device, &ExploreOptions::default());
            let plan = compose_plan(&g, &device, &cands, &BeamOptions::default());
            assert!(plan.is_disjoint(), "seed {seed}");
            for p in &plan.patterns {
                assert!(p.is_valid(&g), "invalid pattern in plan, seed {seed}");
            }
        }
    }

    #[test]
    fn coverage_dedup_is_not_adjacent_only() {
        // Four states sorted by score. States 0 and 2 cover the same
        // nodes through different pattern splits (so their accumulated
        // scores differ) and are separated by state 1 — exactly the
        // shape `Vec::dedup_by` cannot see. With the old adjacent-only
        // dedup the duplicate survived and truncation to the beam
        // width (3) dropped the *distinct* state 3: a lost plan.
        let mk = |cov: u64, score: f64| BufferSet {
            chosen: None,
            covered: vec![cov],
            score,
        };
        let states =
            vec![mk(0b0011, 4.0), mk(0b0111, 3.5), mk(0b0011, 3.0), mk(0b1000, 2.0)];

        let mut adjacent_only = states.clone();
        adjacent_only.dedup_by(|a, b| a.covered == b.covered);
        adjacent_only.truncate(3);
        assert!(
            !adjacent_only.iter().any(|s| s.covered == vec![0b1000]),
            "premise: adjacent-only dedup demonstrably loses the diverse state"
        );

        let mut fixed = states;
        dedup_by_coverage(&mut fixed);
        fixed.truncate(3);
        assert_eq!(fixed.len(), 3);
        assert!(
            fixed.iter().any(|s| s.covered == vec![0b1000]),
            "coverage dedup must keep the diverse state in the window"
        );
        // Exactly one survivor per coverage set, and it is the best one.
        assert_eq!(fixed.iter().filter(|s| s.covered == vec![0b0011]).count(), 1);
        assert!(fixed.iter().any(|s| s.covered == vec![0b0011] && s.score == 4.0));
    }

    /// Defense-in-depth: even when a hand-built candidate set smuggles
    /// an over-cap pattern past the DP, the beam refuses to expand with
    /// it and counts the rejection on the plan.
    #[test]
    fn beam_filters_infeasible_candidates_and_counts() {
        use crate::explorer::candidates::ScoredPattern;
        use crate::graph::ReduceOp;
        let mut g = Graph::new("wide");
        let x = g.param(Shape::new(vec![64, 16384]), DType::F32, "x");
        let e = g.unary(crate::graph::OpKind::Exp, x, "e");
        let r = g.reduce(ReduceOp::Sum, e, vec![1], "r");
        let device = DeviceSpec::v100();
        // Hand the beam an over-cap pattern with a falsely great score.
        let mut cands: CandidateSets = vec![Vec::new(); g.len()];
        cands[e.idx()].push(ScoredPattern {
            pattern: FusionPattern::new(vec![e, r]),
            score: 100.0,
        });
        let plan = compose_plan(&g, &device, &cands, &BeamOptions::default());
        assert!(plan.patterns.is_empty(), "over-cap pattern must not compose");
        assert_eq!(plan.footprint_pruned, 1);
        // With the filter off (unpruned ablation) the pattern composes.
        let open = BeamOptions { footprint_prune: false, ..Default::default() };
        let plan = compose_plan(&g, &device, &cands, &open);
        assert_eq!(plan.patterns.len(), 1);
        assert_eq!(plan.footprint_pruned, 0);
    }

    #[test]
    fn wider_beam_never_worse() {
        let mut g = Graph::new("ln2");
        let x = g.param(Shape::new(vec![1024, 512]), DType::F32, "x");
        let h = blocks::layer_norm(&mut g, x, "ln_a");
        let _ = blocks::softmax(&mut g, h, "sm");
        let device = DeviceSpec::v100();
        let cands = candidate_patterns(&g, &device, &ExploreOptions::default());
        let model = DeltaModel::new(&g, device.clone());
        let narrow =
            compose_plan(&g, &device, &cands, &BeamOptions { width: 1, ..Default::default() });
        let wide =
            compose_plan(&g, &device, &cands, &BeamOptions { width: 3, ..Default::default() });
        let t_narrow = model.plan_time_us(&narrow.kernels(&g));
        let t_wide = model.plan_time_us(&wide.kernels(&g));
        assert!(t_wide <= t_narrow * 1.001, "wide {t_wide} vs narrow {t_narrow}");
    }
}
