//! The delta-evaluator (§5.4) — the fast, less-accurate cost model that
//! scores fusion patterns during exploration.
//!
//! `f = T_reduced_mem + T_reduced_calls − T_penalty` (Eq. 3).
//!
//! We realize the three terms as the difference between executing the
//! pattern's ops as separate kernels and executing the fused kernel
//! under a *simplified* latency estimate (fixed 16 registers, max-single
//! shared-memory request, no lifetime analysis — exactly the
//! simplifications §5.4 lists). A positive score means fusing saves
//! time; the explorer only keeps positive-score patterns.

use crate::codegen::shmem;
use crate::gpu::{CostParams, DeviceSpec};
use crate::graph::{Graph, Node, NodeId, OpClass, OpKind};

/// The fast cost model. Construct once per (graph, device) exploration;
/// per-op times are cached.
#[derive(Debug)]
pub struct DeltaModel<'g> {
    graph: &'g Graph,
    device: DeviceSpec,
    /// Cost constants (launch overhead, bandwidth knee, calibrated
    /// corrections) this model scores with.
    params: CostParams,
    /// Cached standalone time per node, µs.
    op_time_cache: Vec<f64>,
    /// When true (the default), a pattern whose intermediate-footprint
    /// bound cannot launch scores `INFINITY` (the hard capacity pin).
    /// The unpruned ablation turns this off: footprint is clamped to
    /// the per-block cap for the occupancy estimate, modeling the
    /// pre-footprint-first world where infeasibility was only
    /// discovered at tuning time.
    enforce_capacity: bool,
}

impl<'g> DeltaModel<'g> {
    /// Model with the default (uncalibrated) cost constants.
    pub fn new(graph: &'g Graph, device: DeviceSpec) -> Self {
        Self::with_params(graph, device, CostParams::default())
    }

    /// Model under explicit cost parameters — the calibrated-exploration
    /// entry point ([`crate::codegen::calibrate`]).
    pub fn with_params(graph: &'g Graph, device: DeviceSpec, params: CostParams) -> Self {
        let op_time_cache = graph
            .nodes()
            .iter()
            .map(|n| standalone_op_time_us(graph, n, &device, &params))
            .collect();
        DeltaModel { graph, device, params, op_time_cache, enforce_capacity: true }
    }

    /// Toggle the hard intermediate-footprint pin (on by default). With
    /// enforcement off the model scores over-cap patterns optimistically
    /// — the unpruned-exploration ablation the `explorer_perf` bench
    /// compares against.
    pub fn with_capacity_enforcement(mut self, on: bool) -> Self {
        self.enforce_capacity = on;
        self
    }

    /// Host + device cost of one extra kernel launch, µs
    /// (`T_reduced_calls`'s fixed per-call constant).
    pub fn launch_overhead_us(&self) -> f64 {
        self.params.launch_overhead_us
    }

    /// Standalone (unfused) execution time of one op, µs.
    pub fn op_time_us(&self, id: NodeId) -> f64 {
        self.op_time_cache[id.idx()]
    }

    /// Eq. 3 score for a pattern, µs saved. Higher is better.
    pub fn score(&self, pattern: &[NodeId]) -> f64 {
        if pattern.len() < 2 {
            return 0.0;
        }
        let unfused: f64 = pattern.iter().map(|&id| self.op_time_us(id)).sum();
        let calls_saved = (pattern.len() - 1) as f64 * self.launch_overhead_us();
        let fused = self.pattern_time_us(pattern);
        unfused + calls_saved - fused - self.launch_overhead_us_of_fused()
    }

    fn launch_overhead_us_of_fused(&self) -> f64 {
        0.0 // the fused kernel's own launch is included in `unfused - saved`
    }

    /// Simplified fused-kernel time (the `T_penalty`-bearing term):
    /// boundary traffic over occupancy-scaled bandwidth, with the §5.4
    /// shortcuts: registers fixed at 16, shared memory = the maximum
    /// single request (no dataflow sharing), no lifetime analysis.
    pub fn pattern_time_us(&self, pattern: &[NodeId]) -> f64 {
        let g = self.graph;
        let (rows, _len) = crate::codegen::latency::pattern_rows(g, pattern);

        // Boundary traffic.
        let bytes_read: usize = g
            .pattern_inputs(pattern)
            .iter()
            .map(|&i| g.node(i).output_bytes())
            .sum();
        let bytes_written: usize = g
            .pattern_outputs(pattern)
            .iter()
            .map(|&o| g.node(o).output_bytes())
            .sum();

        // Pattern membership as a node-id bitset: the consumer check
        // below runs per node, and `pattern.contains` made it O(n²) on
        // large regions (the exploration hot path).
        let member = crate::util::IdMask::from_ids(g.len(), pattern.iter().map(|id| id.idx()));

        // Shared-memory estimate through the footprint engine: max over
        // per-row staging requests of reused sub-roots (assume block
        // composition for every internal expensive/reduction producer —
        // conservative), same §5.4 shortcut as before but now the same
        // accounting the tuner and the absorption pass consult.
        let fp = shmem::pattern_footprint(g, pattern, rows, &member);
        let shmem_bytes = if self.enforce_capacity {
            fp.max_request_bytes
        } else {
            fp.max_request_bytes.min(shmem::block_cap(&self.device))
        };
        let mut alu_work = 0f64;
        for &id in pattern {
            let node = g.node(id);
            let work_items = match &node.kind {
                OpKind::Reduce { .. } => g.node(node.inputs[0]).num_elements(),
                _ => node.num_elements(),
            } as f64;
            alu_work += work_items * node.kind.instructions_per_element();
        }
        let occ = self.device.occupancy(256, 16, shmem_bytes);
        if occ == 0.0 {
            return f64::INFINITY;
        }
        let bw = self.device.effective_bandwidth_at(occ, self.params.bandwidth_knee);
        let t_mem = (bytes_read + bytes_written) as f64 / (bw * 1e3);
        // ALU side at full device throughput scaled by occupancy.
        // instr/µs
        let ips = self.device.num_sms as f64 * 64.0 * self.device.clock_ghz * 1e3 * occ;
        let t_alu = alu_work / ips;
        // Soft footprint pressure: summed staging requests crowding the
        // per-block budget cost occupancy headroom the max-single-
        // request occupancy shortcut above cannot see. Zero below the
        // knee, so lightly-staged patterns price exactly as before.
        let pressure = self
            .params
            .footprint_pressure_charge_us(fp.staged_sum_bytes, shmem::block_cap(&self.device));
        (t_mem.max(t_alu) * self.params.time_scale).max(self.device.kernel_floor_us) + pressure
    }

    /// Intermediate-footprint bound of a pattern, bytes: the largest
    /// single per-row staging request under the same §5.4 shortcuts
    /// [`Self::pattern_time_us`] prices with. Cheap enough to gate
    /// every DP combination before scoring.
    pub fn pattern_footprint_bytes(&self, pattern: &[NodeId]) -> usize {
        let g = self.graph;
        let (rows, _len) = crate::codegen::latency::pattern_rows(g, pattern);
        let member = crate::util::IdMask::from_ids(g.len(), pattern.iter().map(|id| id.idx()));
        shmem::pattern_footprint(g, pattern, rows, &member).max_request_bytes
    }

    /// Hard feasibility of a pattern's footprint bound at the delta
    /// evaluator's fixed launch shape (256 threads, 16 registers) — the
    /// exploration-side pruning predicate. Equivalent to the old
    /// "occupancy zero ⇒ score `INFINITY` ⇒ filtered" path, applied
    /// before any scoring work is spent.
    pub fn pattern_footprint_feasible(&self, pattern: &[NodeId]) -> bool {
        shmem::footprint_feasible(&self.device, 256, 16, self.pattern_footprint_bytes(pattern))
    }

    /// Modeled gain, µs, of absorbing one compute boundary whose
    /// hand-off tensor is `boundary`'s output (the anchor's result for
    /// an epilogue, the prologue root's result for a prologue).
    ///
    /// Gain = saved kernel launch + saved HBM round-trip of the boundary
    /// tensor (it was written by one kernel and re-read by the next; the
    /// `GemmEpilogue` hand-off keeps it in shared memory), minus the
    /// occupancy pressure the staging tile puts on the anchor kernel.
    /// `NEG_INFINITY` when the staged tile cannot launch at all — the
    /// hard shmem-feasibility cut.
    pub fn absorb_gain_us(&self, boundary: NodeId) -> f64 {
        let node = self.graph.node(boundary);
        let staging = crate::codegen::shmem::epilogue_staging_bytes(
            node.shape.inner_dim(),
            node.dtype.size_bytes(),
        );
        if !crate::codegen::shmem::epilogue_feasible(&self.device, staging) {
            return f64::NEG_INFINITY;
        }
        // Occupancy of the combined kernel at the scheme's fixed
        // 256-thread block vs. the same kernel without staging; the
        // register estimate (32) covers the anchor tile + epilogue temps.
        let occ = self.device.occupancy(256, 32, staging);
        let occ_free = self.device.occupancy(256, 32, 0);
        if occ == 0.0 || occ_free == 0.0 {
            return f64::NEG_INFINITY;
        }
        let bw = self.device.effective_bandwidth_at(occ_free, self.params.bandwidth_knee);
        let round_trip_us = 2.0 * node.output_bytes() as f64 / (bw * 1e3);
        let saved = self.params.launch_overhead_us
            + round_trip_us * self.params.time_scale * self.params.absorb_traffic_scale;
        let occ_lost = ((occ_free - occ) / occ_free).max(0.0);
        saved - self.params.absorb_occupancy_penalty_us * occ_lost
    }

    /// Total simplified plan time: Σ kernel times + per-kernel launch
    /// overhead. Used by beam search to rank buffer sets cheaply.
    pub fn plan_time_us(&self, kernels: &[crate::explorer::FusionPattern]) -> f64 {
        kernels
            .iter()
            .map(|k| {
                let t = if k.len() == 1 {
                    self.op_time_us(k.nodes()[0])
                } else {
                    self.pattern_time_us(k.nodes())
                };
                t + self.launch_overhead_us()
            })
            .sum()
    }
}

/// Standalone time of one op as its own kernel: traffic/bandwidth with a
/// launch floor (memory-intensive ops are bandwidth- or latency-bound).
fn standalone_op_time_us(
    graph: &Graph,
    node: &Node,
    device: &DeviceSpec,
    params: &CostParams,
) -> f64 {
    if node.kind.class() == OpClass::Source || !node.kind.is_fusible() {
        return 0.0;
    }
    let in_bytes: usize = node
        .inputs
        .iter()
        .map(|&i| graph.node(i).output_bytes())
        .sum();
    let bytes = in_bytes + node.output_bytes();
    let t_mem = bytes as f64 / (device.hbm_gbps * 1e3);
    (t_mem * params.time_scale).max(device.kernel_floor_us)
}

/// Convenience free function matching the paper's `f(P_i)` notation.
pub fn delta_score(model: &DeltaModel, pattern: &[NodeId]) -> f64 {
    model.score(pattern)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, Shape};
    use crate::workloads::blocks;

    fn ln() -> (Graph, Vec<NodeId>) {
        let mut g = Graph::new("ln");
        let x = g.param(Shape::new(vec![4096, 768]), DType::F32, "x");
        let _ = blocks::layer_norm(&mut g, x, "ln");
        let p: Vec<NodeId> = g
            .nodes()
            .iter()
            .filter(|n| n.kind.is_fusible())
            .map(|n| n.id)
            .collect();
        (g, p)
    }

    #[test]
    fn fusing_layernorm_scores_positive() {
        let (g, p) = ln();
        let model = DeltaModel::new(&g, DeviceSpec::v100());
        let s = model.score(&p);
        assert!(s > 0.0, "score={s}");
    }

    #[test]
    fn singletons_score_zero() {
        let (g, p) = ln();
        let model = DeltaModel::new(&g, DeviceSpec::v100());
        assert_eq!(model.score(&p[..1]), 0.0);
    }

    #[test]
    fn bigger_fusions_of_tiny_ops_save_more_launches() {
        // 8 chained tiny ops: fusing all should beat fusing two.
        let mut g = Graph::new("chain");
        let x = g.param(Shape::new(vec![256]), DType::F32, "x");
        let mut cur = x;
        let mut ids = Vec::new();
        for i in 0..8 {
            cur = g.unary(crate::graph::OpKind::Relu, cur, format!("r{i}"));
            ids.push(cur);
        }
        let model = DeltaModel::new(&g, DeviceSpec::v100());
        let all = model.score(&ids);
        let two = model.score(&ids[..2]);
        assert!(all > two, "all={all} two={two}");
    }

    #[test]
    fn op_times_are_bandwidth_or_floor_bound() {
        let mut g = Graph::new("t");
        let big = g.param(Shape::new(vec![4096, 4096]), DType::F32, "big");
        let small = g.param(Shape::new(vec![16]), DType::F32, "small");
        let b = g.unary(crate::graph::OpKind::Relu, big, "b");
        let s = g.unary(crate::graph::OpKind::Relu, small, "s");
        let model = DeltaModel::new(&g, DeviceSpec::v100());
        assert!(model.op_time_us(b) > model.op_time_us(s));
        assert_eq!(model.op_time_us(s), DeviceSpec::v100().kernel_floor_us);
    }

    #[test]
    fn calibrated_params_flow_into_scores() {
        let (g, p) = ln();
        let base = DeltaModel::new(&g, DeviceSpec::v100());
        // A 2× time-scale correction scales the (bandwidth-bound,
        // above-floor) fused LN time by 2×.
        let scaled = DeltaModel::with_params(
            &g,
            DeviceSpec::v100(),
            CostParams { time_scale: 2.0, ..Default::default() },
        );
        let (t0, t1) = (base.pattern_time_us(&p), scaled.pattern_time_us(&p));
        assert!(t1 > t0 * 1.99, "base {t0} scaled {t1}");
        // A cheaper calibrated launch overhead shrinks the call-saving
        // term of Eq. 3, so the same fusion scores lower.
        let cheap = DeltaModel::with_params(
            &g,
            DeviceSpec::v100(),
            CostParams { launch_overhead_us: 1.0, ..Default::default() },
        );
        assert_eq!(cheap.launch_overhead_us(), 1.0);
        assert!(cheap.score(&p) < base.score(&p));
    }

    #[test]
    fn pattern_over_shmem_block_cap_is_unlaunchable() {
        // One row of 16384 f32 = 64 KB of per-row staging for the
        // internal reduction producer: over the 48 KB/block cap, so the
        // delta evaluator must score the fusion unlaunchable (the bug
        // this PR fixes let it through at occupancy 1.0).
        let mut g = Graph::new("wide");
        let x = g.param(Shape::new(vec![64, 16384]), DType::F32, "x");
        let e = g.unary(crate::graph::OpKind::Exp, x, "e");
        let r = g.reduce(crate::graph::ReduceOp::Sum, e, vec![1], "r");
        let model = DeltaModel::new(&g, DeviceSpec::v100());
        assert_eq!(model.pattern_time_us(&[e, r]), f64::INFINITY);
        assert!(model.score(&[e, r]) < 0.0);
        // The footprint bound sees the same 64 KB before scoring — the
        // exploration-side pruning predicate fires without paying for a
        // full pattern_time_us evaluation.
        assert_eq!(model.pattern_footprint_bytes(&[e, r]), 64 * 1024);
        assert!(!model.pattern_footprint_feasible(&[e, r]));
    }

    #[test]
    fn capacity_toggle_models_the_unpruned_world() {
        // Same over-cap pattern as above: with capacity enforcement off
        // (the unpruned ablation) the model clamps the footprint to the
        // cap and scores the fusion finitely — exactly the optimistic
        // pre-refactor behavior whose infeasibility only tuning caught.
        let mut g = Graph::new("wide");
        let x = g.param(Shape::new(vec![64, 16384]), DType::F32, "x");
        let e = g.unary(crate::graph::OpKind::Exp, x, "e");
        let r = g.reduce(crate::graph::ReduceOp::Sum, e, vec![1], "r");
        let optimistic =
            DeltaModel::new(&g, DeviceSpec::v100()).with_capacity_enforcement(false);
        let t = optimistic.pattern_time_us(&[e, r]);
        assert!(t.is_finite(), "optimistic model must score over-cap patterns");
        // The footprint bound itself is mode-independent: still 64 KB,
        // still infeasible — only the *pricing* is optimistic.
        assert!(!optimistic.pattern_footprint_feasible(&[e, r]));
    }

    #[test]
    fn footprint_pressure_prices_staged_crowding() {
        // A pattern whose summed staging requests land above the knee
        // must price worse under a higher footprint_pressure_us, while
        // a lightly-staged pattern (layer-norm) is untouched — the
        // "defaults don't perturb tier-1 plans" invariant.
        let mut g = Graph::new("crowd");
        // 64 rows × 12288 f32 = 48 KB per-row staging for exp — at the
        // cap (feasible) and far above the 24 KB knee.
        let x = g.param(Shape::new(vec![64, 12288]), DType::F32, "x");
        let e = g.unary(crate::graph::OpKind::Exp, x, "e");
        let r = g.reduce(crate::graph::ReduceOp::Sum, e, vec![1], "r");
        let base = DeltaModel::new(&g, DeviceSpec::v100());
        let hot = DeltaModel::with_params(
            &g,
            DeviceSpec::v100(),
            CostParams { footprint_pressure_us: 40.0, ..Default::default() },
        );
        let (t0, t1) = (base.pattern_time_us(&[e, r]), hot.pattern_time_us(&[e, r]));
        assert!(t0.is_finite() && t1 > t0, "base {t0} hot {t1}");

        let (g2, p) = ln();
        let base_ln = DeltaModel::new(&g2, DeviceSpec::v100());
        let hot_ln = DeltaModel::with_params(
            &g2,
            DeviceSpec::v100(),
            CostParams { footprint_pressure_us: 40.0, ..Default::default() },
        );
        assert_eq!(
            base_ln.pattern_time_us(&p),
            hot_ln.pattern_time_us(&p),
            "below-knee patterns must be pressure-free"
        );
    }

    #[test]
    fn plan_time_accounts_launches() {
        let (g, p) = ln();
        let model = DeltaModel::new(&g, DeviceSpec::v100());
        use crate::explorer::FusionPattern;
        let fused = vec![FusionPattern::new(p.clone())];
        let split: Vec<FusionPattern> =
            p.iter().map(|&id| FusionPattern::single(id)).collect();
        assert!(model.plan_time_us(&fused) < model.plan_time_us(&split));
    }
}
