//! Fusion patterns and plans (§5.1).
//!
//! A **fusion pattern** `P_i = (V_i, E_i)` is a subgraph scheduled into
//! one kernel; a **fusion plan** `S = {P_0..P_k-1}` is a set of disjoint
//! patterns covering (part of) the graph. These types are shared by the
//! explorer, the baselines, and the pipeline: every technique produces a
//! `FusionPlan`, so downstream emission and simulation are uniform.

use crate::graph::{Graph, NodeId};

/// A candidate or final fusion pattern: a sorted, deduplicated node set.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct FusionPattern {
    nodes: Vec<NodeId>,
}

impl FusionPattern {
    /// Build from any node list (sorts + dedups).
    pub fn new(mut nodes: Vec<NodeId>) -> Self {
        nodes.sort_unstable();
        nodes.dedup();
        FusionPattern { nodes }
    }

    /// Singleton pattern.
    pub fn single(id: NodeId) -> Self {
        FusionPattern { nodes: vec![id] }
    }

    /// Union of two patterns.
    pub fn union(&self, other: &FusionPattern) -> FusionPattern {
        let mut nodes = self.nodes.clone();
        nodes.extend_from_slice(&other.nodes);
        FusionPattern::new(nodes)
    }

    /// Sorted member nodes.
    pub fn nodes(&self) -> &[NodeId] {
        &self.nodes
    }

    /// Member count.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True when the pattern has no nodes.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Membership test (binary search on the sorted set).
    pub fn contains(&self, id: NodeId) -> bool {
        self.nodes.binary_search(&id).is_ok()
    }

    /// True when the two patterns share any node.
    pub fn overlaps(&self, other: &FusionPattern) -> bool {
        // Merge-walk over the two sorted lists.
        let (mut i, mut j) = (0, 0);
        while i < self.nodes.len() && j < other.nodes.len() {
            match self.nodes[i].cmp(&other.nodes[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => return true,
            }
        }
        false
    }

    /// Smallest node id — used as the pattern's stable identity in
    /// reports.
    pub fn min_id(&self) -> NodeId {
        self.nodes[0]
    }

    /// Validity: non-empty, all fusible, introduces no cyclic dependence
    /// (Fig. 6), and is schedulable by the code generator.
    pub fn is_valid(&self, graph: &Graph) -> bool {
        !self.nodes.is_empty()
            && self
                .nodes
                .iter()
                .all(|&id| graph.node(id).kind.is_fusible())
            && !graph.fusion_creates_cycle(&self.nodes)
            && crate::codegen::latency::pattern_supported(graph, &self.nodes)
    }
}

/// One GEMM/conv anchor with the boundaries it absorbed (§ cross-GEMM
/// stitching). Patterns referenced here stay in `FusionPlan::patterns`
/// untouched — lowering merges them into the anchor's library kernel via
/// the `GemmEpilogue` hand-off, falling back to the cut form when the
/// staging buffer does not fit at the target device/shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct AbsorbedAnchor {
    /// The compute-intensive node that anchors the stitched region.
    pub anchor: NodeId,
    /// `min_id` of the plan pattern stitched after the anchor (consumes
    /// its output), if any.
    pub epilogue: Option<NodeId>,
    /// `min_id` of the plan pattern stitched before the anchor (feeds
    /// only the anchor), if any.
    pub prologue: Option<NodeId>,
}

impl AbsorbedAnchor {
    /// Number of compute boundaries this anchor absorbed (0..=2).
    pub fn boundaries(&self) -> usize {
        usize::from(self.epilogue.is_some()) + usize::from(self.prologue.is_some())
    }
}

/// A fusion plan: disjoint patterns + every fusible node not covered by
/// any pattern executes as its own single-op kernel.
#[derive(Debug, Clone, Default)]
pub struct FusionPlan {
    pub patterns: Vec<FusionPattern>,
    /// GEMM boundaries absorbed by the anchored-region pass. Always empty
    /// for the XLA/TF baseline personalities (their cut behavior is
    /// bit-stable); sorted by anchor id for determinism.
    pub absorbed: Vec<AbsorbedAnchor>,
    /// Candidates discarded during exploration because their
    /// intermediate-footprint bound could not launch (the footprint-
    /// first hard prune: DP combinations plus the beam's defense
    /// filter). A pure function of (graph, device, options) — never of
    /// which executor or worker explored — so the fleet can publish it
    /// as an executor-invariant counter. Zero for restored/baseline
    /// plans, which carry no exploration trace.
    pub footprint_pruned: usize,
}

impl FusionPlan {
    /// Total absorbed compute boundaries across all anchors.
    pub fn absorbed_boundaries(&self) -> usize {
        self.absorbed.iter().map(|a| a.boundaries()).sum()
    }

    /// Kernels this plan launches for the memory-intensive population:
    /// the multi-op patterns plus singletons for uncovered fusible ops
    /// (excluding zero-cost reshapes, which no framework launches).
    pub fn kernels(&self, graph: &Graph) -> Vec<FusionPattern> {
        let mut covered = vec![false; graph.len()];
        for p in &self.patterns {
            for &id in p.nodes() {
                covered[id.idx()] = true;
            }
        }
        let mut out = self.patterns.clone();
        for node in graph.nodes() {
            if covered[node.id.idx()] || !node.kind.is_fusible() {
                continue;
            }
            if matches!(node.kind, crate::graph::OpKind::Reshape) {
                continue; // layout no-op: never a kernel
            }
            if matches!(node.kind, crate::graph::OpKind::Copy) {
                continue; // memcpy activity: accounted in the Cpy column
            }
            out.push(FusionPattern::single(node.id));
        }
        out
    }

    /// Check plan invariant: patterns are pairwise disjoint.
    pub fn is_disjoint(&self) -> bool {
        for (i, a) in self.patterns.iter().enumerate() {
            for b in &self.patterns[i + 1..] {
                if a.overlaps(b) {
                    return false;
                }
            }
        }
        true
    }

    /// Total nodes covered by multi-op patterns.
    pub fn covered_nodes(&self) -> usize {
        self.patterns.iter().map(|p| p.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, Graph, OpKind, Shape};

    fn chain() -> (Graph, Vec<NodeId>) {
        let mut g = Graph::new("c");
        let p = g.param(Shape::new(vec![8]), DType::F32, "p");
        let a = g.unary(OpKind::Exp, p, "a");
        let b = g.unary(OpKind::Neg, a, "b");
        let c = g.unary(OpKind::Abs, b, "c");
        (g, vec![a, b, c])
    }

    #[test]
    fn new_sorts_and_dedups() {
        let p = FusionPattern::new(vec![NodeId(3), NodeId(1), NodeId(3)]);
        assert_eq!(p.nodes(), &[NodeId(1), NodeId(3)]);
        assert_eq!(p.len(), 2);
    }

    #[test]
    fn overlap_detection() {
        let a = FusionPattern::new(vec![NodeId(1), NodeId(2)]);
        let b = FusionPattern::new(vec![NodeId(2), NodeId(3)]);
        let c = FusionPattern::new(vec![NodeId(4)]);
        assert!(a.overlaps(&b));
        assert!(!a.overlaps(&c));
        assert!(a.union(&b).contains(NodeId(3)));
    }

    #[test]
    fn validity_rejects_param_and_cycles() {
        let (g, ids) = chain();
        assert!(FusionPattern::new(ids.clone()).is_valid(&g));
        assert!(!FusionPattern::new(vec![NodeId(0)]).is_valid(&g)); // param
        // {a, c} leaves b outside on a re-entering path ⇒ invalid.
        assert!(!FusionPattern::new(vec![ids[0], ids[2]]).is_valid(&g));
    }

    #[test]
    fn kernels_add_singletons_for_uncovered() {
        let (g, ids) = chain();
        let plan = FusionPlan {
            patterns: vec![FusionPattern::new(vec![ids[0], ids[1]])],
            ..Default::default()
        };
        let kernels = plan.kernels(&g);
        // one fused kernel + singleton for c (param excluded)
        assert_eq!(kernels.len(), 2);
        assert!(plan.is_disjoint());
    }

    #[test]
    fn reshape_and_copy_are_not_kernels() {
        let mut g = Graph::new("r");
        let p = g.param(Shape::new(vec![4, 2]), DType::F32, "p");
        let r = g.add(OpKind::Reshape, DType::F32, Shape::new(vec![8]), vec![p], "r");
        let c = g.unary(OpKind::Copy, r, "cpy");
        let _ = c;
        let plan = FusionPlan::default();
        let kernels = plan.kernels(&g);
        assert!(kernels.is_empty());
    }
}
