//! Region partitioning for parallel fusion exploration.
//!
//! Fusion decisions never cross unfusible boundaries: a candidate
//! pattern only ever contains fusible ops connected through fusible
//! producer→consumer edges, so GEMM/conv, explicit copies and graph
//! sources cut the graph into independent *fusible regions* (connected
//! components of the fusible subgraph). Candidate generation, beam
//! composition, producer absorption and accurate-model pruning are all
//! local to one region, which makes exploration embarrassingly parallel
//! per region — the fleet fans a large graph's compile job out as one
//! sub-job per region group and joins at a barrier (dynamic-loop
//! boundaries stay enforced through the capped
//! [`ExploreOptions`] the pipeline derives for `while_loop` bodies:
//! patterns inside a region are still clipped to the loop-body budget).
//!
//! Only the two *global* passes stay outside the regions: the XLA
//! backfill (coverage is a whole-graph property) and Fig. 5 remote
//! fusion (kernel packing deliberately bundles kernels from unrelated
//! regions into one launch).

use super::beam::{compose_plan, BeamOptions};
use super::candidates::{candidate_patterns_with_stats, CandidateSets, ExploreOptions};
use super::pattern::FusionPlan;
use crate::gpu::DeviceSpec;
use crate::graph::{Graph, NodeId, OpKind};

/// One independent fusible region: a sorted, deduplicated node set
/// closed under fusible adjacency.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Region {
    nodes: Vec<NodeId>,
}

impl Region {
    /// Sorted member nodes.
    pub fn nodes(&self) -> &[NodeId] {
        &self.nodes
    }

    /// Member count.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True when the region has no nodes (never produced by
    /// [`partition`]).
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Smallest node id — the region's stable identity.
    pub fn min_id(&self) -> NodeId {
        self.nodes[0]
    }

    /// Membership bitmap over `n` graph nodes.
    fn mask(&self, n: usize) -> Vec<bool> {
        let mut m = vec![false; n];
        for &id in &self.nodes {
            m[id.idx()] = true;
        }
        m
    }
}

/// True when `kind` participates in fusion regions — the same filter
/// candidate generation applies per vertex (copies are memcpy activity,
/// never fused).
fn participates(kind: &OpKind) -> bool {
    kind.is_fusible() && !matches!(kind, OpKind::Copy)
}

/// Split a graph into its independent fusible regions: connected
/// components of the fusible subgraph, cut at GEMM/conv/copy and source
/// boundaries. Deterministic: regions are ordered by their smallest
/// node id and every region's node list is sorted.
pub fn partition(graph: &Graph) -> Vec<Region> {
    let n = graph.len();
    let mut visited = vec![false; n];
    let mut out = Vec::new();
    for start in graph.nodes() {
        if visited[start.id.idx()] || !participates(&start.kind) {
            continue;
        }
        visited[start.id.idx()] = true;
        let mut stack = vec![start.id];
        let mut nodes = Vec::new();
        while let Some(id) = stack.pop() {
            nodes.push(id);
            let node = graph.node(id);
            for &nb in node.inputs.iter().chain(graph.consumers(id).iter()) {
                if !visited[nb.idx()] && participates(&graph.node(nb).kind) {
                    visited[nb.idx()] = true;
                    stack.push(nb);
                }
            }
        }
        nodes.sort_unstable();
        out.push(Region { nodes });
    }
    out
}

/// Group regions into at most `shards` balanced compile sub-jobs
/// (greedy longest-processing-time binning by region op count).
/// Deterministic: ties break toward the smaller region id / lower bin
/// index, and empty groups are dropped.
pub fn shard_regions(mut regions: Vec<Region>, shards: usize) -> Vec<Vec<Region>> {
    let bins_wanted = shards.max(1).min(regions.len().max(1));
    regions.sort_by(|a, b| b.len().cmp(&a.len()).then(a.min_id().cmp(&b.min_id())));
    let mut bins: Vec<(usize, Vec<Region>)> = vec![(0, Vec::new()); bins_wanted];
    for r in regions {
        // First-minimum selection keeps the binning deterministic.
        let mut lightest = 0;
        for i in 1..bins.len() {
            if bins[i].0 < bins[lightest].0 {
                lightest = i;
            }
        }
        bins[lightest].0 += r.len();
        bins[lightest].1.push(r);
    }
    bins.into_iter()
        .map(|(_, group)| group)
        .filter(|g| !g.is_empty())
        .collect()
}

/// Explore one region: candidate generation, beam composition, producer
/// absorption and accurate-model pruning, all restricted to the
/// region's nodes. The absorption and pruning passes are region-local
/// by construction (a fusible producer's fusible consumers live in the
/// same connected component), so reusing the global passes on the
/// region plan is exact.
pub fn explore_region(
    graph: &Graph,
    device: &DeviceSpec,
    opts: &ExploreOptions,
    region: &Region,
) -> FusionPlan {
    if region.len() < 2 {
        return FusionPlan::default(); // a single op never fuses
    }
    let mask = region.mask(graph.len());
    let (cands, stats) = candidate_patterns_with_stats(graph, device, opts, Some(&mask));
    let mut plan = compose_absorb_prune(graph, device, opts, &cands);
    plan.footprint_pruned += stats.footprint_pruned;
    plan
}

/// Beam composition + producer absorption + accurate-model pruning over
/// one region's candidate sets (the per-region half shared by
/// [`explore_region`] and [`explore_shard`]).
fn compose_absorb_prune(
    graph: &Graph,
    device: &DeviceSpec,
    opts: &ExploreOptions,
    cands: &CandidateSets,
) -> FusionPlan {
    let mut plan = compose_plan(
        graph,
        device,
        cands,
        &BeamOptions {
            width: opts.beam_width,
            cost: opts.cost,
            footprint_prune: opts.footprint_prune,
        },
    );
    plan = super::absorb_producers(graph, plan, opts);
    plan = super::prune_bad_patterns(graph, device, plan, opts);
    plan
}

/// Explore a group of regions (one compile sub-job) and merge their
/// plans. Candidate generation runs ONCE over the whole group — regions
/// are disjoint and closed under fusible adjacency, so the per-vertex
/// candidate sets of a group-masked DP are identical to per-region runs
/// while paying a single cost-model build and graph walk instead of one
/// per region; only beam/absorb/prune (whose state is genuinely
/// per-region) then run per region, on that region's slice of the
/// shared sets. Pure and deterministic: the result depends only on
/// (graph, device, opts, regions), never on which worker runs it.
pub fn explore_shard(
    graph: &Graph,
    device: &DeviceSpec,
    opts: &ExploreOptions,
    group: &[Region],
) -> FusionPlan {
    let mut mask = vec![false; graph.len()];
    for region in group {
        if region.len() < 2 {
            continue; // singletons never fuse; skip their DP work too
        }
        for &id in region.nodes() {
            mask[id.idx()] = true;
        }
    }
    let (mut cands, stats) = candidate_patterns_with_stats(graph, device, opts, Some(&mask));
    let mut plan = FusionPlan::default();
    // The group-wide DP's prune tally belongs to this shard's plan; the
    // dispatcher sums shard partials when it joins them.
    plan.footprint_pruned = stats.footprint_pruned;
    let mut region_cands: CandidateSets = vec![Vec::new(); graph.len()];
    for region in group {
        if region.len() < 2 {
            continue;
        }
        for &id in region.nodes() {
            region_cands[id.idx()] = std::mem::take(&mut cands[id.idx()]);
        }
        let rplan = compose_absorb_prune(graph, device, opts, &region_cands);
        plan.patterns.extend(rplan.patterns);
        plan.footprint_pruned += rplan.footprint_pruned;
        for &id in region.nodes() {
            region_cands[id.idx()] = Vec::new();
        }
    }
    plan
}

/// The global tail of a partitioned exploration: canonicalize the
/// merged per-region patterns (so the result is independent of how the
/// regions were grouped into shards), backfill uncovered nodes with
/// XLA's rule-based fusions, and run Fig. 5 remote kernel packing.
pub fn finish_partitioned(
    graph: &Graph,
    device: &DeviceSpec,
    opts: &ExploreOptions,
    mut merged: FusionPlan,
) -> FusionPlan {
    merged.patterns.sort_by_key(|p| p.min_id());
    let mut plan = super::backfill_with_xla(graph, merged);
    if opts.enable_remote_fusion {
        plan = super::remote_fusion(graph, device, plan, opts);
    }
    // Anchored-region absorption is part of the global tail: it runs
    // over the finished whole-graph pattern set, so sharded and
    // monolithic exploration annotate the same boundaries.
    plan = super::absorb::absorb_anchors(graph, device, plan, opts);
    debug_assert!(plan.is_disjoint());
    plan
}

/// End-to-end region-partitioned exploration: the drop-in sibling of
/// [`super::explore`] that runs the per-region pipeline over every
/// region and then the global tail. Same plan quality (each region gets
/// the beam's full attention instead of sharing it graph-wide), and the
/// per-region work units are what the fleet schedules in parallel.
pub fn explore_partitioned(
    graph: &Graph,
    device: &DeviceSpec,
    opts: &ExploreOptions,
) -> FusionPlan {
    let regions = partition(graph);
    let merged = explore_shard(graph, device, opts, &regions);
    finish_partitioned(graph, device, opts, merged)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explorer::{explore, DeltaModel};
    use crate::graph::{DType, Shape};
    use crate::workloads::blocks;

    /// ln → matmul → ln: two fusible regions split by the GEMM.
    fn two_region_graph() -> Graph {
        let mut g = Graph::new("2reg");
        let x = g.param(Shape::new(vec![512, 256]), DType::F32, "x");
        let h = blocks::layer_norm(&mut g, x, "ln0");
        let w = g.param(Shape::new(vec![256, 256]), DType::F32, "w");
        let mm = g.matmul(h, w, "mm");
        let _ = blocks::layer_norm(&mut g, mm, "ln1");
        g
    }

    #[test]
    fn partition_cuts_at_gemm_boundaries() {
        let g = two_region_graph();
        let regions = partition(&g);
        assert_eq!(regions.len(), 2, "GEMM must split the fusible subgraph");
        // Regions are ordered by min id, disjoint, and cover every
        // fusible non-copy node exactly once.
        assert!(regions[0].min_id() < regions[1].min_id());
        let mut covered = vec![0usize; g.len()];
        for r in &regions {
            assert!(r.len() >= 2);
            for &id in r.nodes() {
                covered[id.idx()] += 1;
            }
        }
        for node in g.nodes() {
            let expect = usize::from(participates(&node.kind));
            assert_eq!(covered[node.id.idx()], expect, "node {}", node.name);
        }
    }

    #[test]
    fn partition_is_deterministic() {
        let g = two_region_graph();
        assert_eq!(partition(&g), partition(&g));
    }

    #[test]
    fn shard_regions_balances_and_preserves() {
        let g = two_region_graph();
        let regions = partition(&g);
        let total: usize = regions.iter().map(|r| r.len()).sum();
        // More shards than regions: one group per region.
        let groups = shard_regions(regions.clone(), 8);
        assert_eq!(groups.len(), 2);
        assert_eq!(groups.iter().flatten().map(|r| r.len()).sum::<usize>(), total);
        // One shard: everything in a single group.
        let one = shard_regions(regions, 1);
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].len(), 2);
    }

    #[test]
    fn shared_group_dp_matches_per_region_exploration() {
        // explore_shard's one-DP-per-group optimization must be exact:
        // exploring each region on its own masked DP (explore_region)
        // and exploring the whole group with the shared DP must yield
        // the same patterns.
        let g = two_region_graph();
        let device = DeviceSpec::v100();
        let opts = ExploreOptions::default();
        let regions = partition(&g);
        let mut per_region = FusionPlan::default();
        for r in &regions {
            per_region
                .patterns
                .extend(explore_region(&g, &device, &opts, r).patterns);
        }
        let shard = explore_shard(&g, &device, &opts, &regions);
        let norm = |plan: &FusionPlan| {
            let mut v: Vec<Vec<NodeId>> =
                plan.patterns.iter().map(|p| p.nodes().to_vec()).collect();
            v.sort();
            v
        };
        assert_eq!(norm(&per_region), norm(&shard));
    }

    #[test]
    fn single_region_partitioned_explore_matches_monolithic() {
        // Layer-norm is one connected fusible region, so the
        // partitioned pipeline must reproduce the monolithic plan
        // pattern-for-pattern.
        let mut g = Graph::new("ln");
        let x = g.param(Shape::new(vec![2048, 512]), DType::F32, "x");
        let _ = blocks::layer_norm(&mut g, x, "ln");
        let device = DeviceSpec::v100();
        let opts = ExploreOptions::default();
        assert_eq!(partition(&g).len(), 1);
        let mono = explore(&g, &device, &opts);
        let part = explore_partitioned(&g, &device, &opts);
        let norm = |plan: &FusionPlan| {
            let mut v: Vec<Vec<NodeId>> =
                plan.patterns.iter().map(|p| p.nodes().to_vec()).collect();
            v.sort();
            v
        };
        assert_eq!(norm(&mono), norm(&part));
    }

    #[test]
    fn partitioned_explore_no_worse_across_gemm_boundaries() {
        let g = two_region_graph();
        let device = DeviceSpec::v100();
        let opts = ExploreOptions::default();
        let mono = explore(&g, &device, &opts);
        let part = explore_partitioned(&g, &device, &opts);
        assert!(part.is_disjoint());
        for p in &part.patterns {
            assert!(p.is_valid(&g));
        }
        let model = DeltaModel::new(&g, device.clone());
        let t_mono = model.plan_time_us(&mono.kernels(&g));
        let t_part = model.plan_time_us(&part.kernels(&g));
        assert!(
            t_part <= t_mono * 1.001 + 1e-9,
            "partitioned {t_part} vs monolithic {t_mono}"
        );
    }

    #[test]
    fn both_exploration_paths_absorb_bert_gemm_boundaries() {
        use crate::workloads::{models, Mode};
        let device = DeviceSpec::v100();
        let opts = ExploreOptions::default();
        let w = models::bert(Mode::Infer);
        let mono = explore(&w.graph, &device, &opts);
        let part = explore_partitioned(&w.graph, &device, &opts);
        assert!(
            mono.absorbed_boundaries() > 0,
            "monolithic bert exploration must absorb a GEMM boundary"
        );
        assert!(
            part.absorbed_boundaries() > 0,
            "partitioned bert exploration must absorb a GEMM boundary"
        );
        // The pass is a pure function of the finished plan: running the
        // same plan through it twice reproduces the annotations exactly.
        let again = crate::explorer::absorb_anchors(&w.graph, &device, part.clone(), &opts);
        assert_eq!(part.absorbed, again.absorbed);
    }

    #[test]
    fn partitioned_explore_no_worse_on_real_workloads() {
        use crate::workloads::{models, Mode};
        let device = DeviceSpec::v100();
        let opts = ExploreOptions::default();
        for w in [models::bert(Mode::Infer), models::asr()] {
            let mono = explore(&w.graph, &device, &opts);
            let part = explore_partitioned(&w.graph, &device, &opts);
            assert!(part.is_disjoint(), "{}", w.key());
            let model = DeltaModel::new(&w.graph, device.clone());
            let t_mono = model.plan_time_us(&mono.kernels(&w.graph));
            let t_part = model.plan_time_us(&part.kernels(&w.graph));
            assert!(
                t_part <= t_mono * 1.01 + 1e-9,
                "{}: partitioned {t_part} vs monolithic {t_mono}",
                w.key()
            );
        }
    }
}
