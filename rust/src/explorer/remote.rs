//! Remote fusion (§5.2, Fig. 5): merge kernels that are *not adjacent*
//! in the graph to cut launch counts further.
//!
//! The paper adds a virtual producer vertex `h` feeding every vertex and
//! runs PatternReduction on it, which amounts to packing independent
//! kernels together (the result is *kernel packing* — no data exchange,
//! just one launch). We implement the same effect directly: greedily
//! pack latency-bound kernels whose union stays acyclic and within
//! resource bounds.

use super::candidates::ExploreOptions;
use super::delta::DeltaModel;
use super::pattern::{FusionPattern, FusionPlan};
use crate::gpu::DeviceSpec;
use crate::graph::Graph;

/// Pack small kernels of `plan` into fewer launches.
pub fn remote_fusion(
    graph: &Graph,
    device: &DeviceSpec,
    plan: FusionPlan,
    opts: &ExploreOptions,
) -> FusionPlan {
    let model = DeltaModel::with_params(graph, device.clone(), opts.cost);
    let kernels = plan.kernels(graph);

    // Partition into "small" (latency-floor-bound) and "large".
    let floor = device.kernel_floor_us * 2.0;
    let mut small: Vec<FusionPattern> = Vec::new();
    let mut out: Vec<FusionPattern> = Vec::new();
    for k in kernels {
        let t = if k.len() == 1 {
            model.op_time_us(k.nodes()[0])
        } else {
            model.pattern_time_us(k.nodes())
        };
        if t <= floor && k.len() < opts.max_pattern_size {
            small.push(k);
        } else {
            out.push(k);
        }
    }

    // Greedy packing: keep a current bundle; add the next small kernel
    // when the union stays valid (acyclic, schedulable) and within the
    // size cap. Packing unrelated ops cannot create reuse hazards — only
    // cycles matter.
    small.sort_by_key(|k| k.min_id());
    let mut bundle: Option<FusionPattern> = None;
    let mut bundle_parts = 0usize;
    for k in small {
        match bundle.take() {
            None => {
                bundle = Some(k);
                bundle_parts = 1;
            }
            Some(b) => {
                let u = b.union(&k);
                if bundle_parts < opts.max_pack_bundle
                    && u.len() <= opts.max_pattern_size
                    && u.is_valid(graph)
                {
                    bundle = Some(u);
                    bundle_parts += 1;
                } else {
                    out.push(b);
                    bundle = Some(k);
                    bundle_parts = 1;
                }
            }
        }
    }
    if let Some(b) = bundle {
        out.push(b);
    }

    // Multi-op patterns go into the plan; singletons remain implicit.
    // The exploration-time footprint-prune count rides through: remote
    // packing reshapes kernels, not the exploration trace.
    FusionPlan {
        patterns: out.into_iter().filter(|p| p.len() > 1).collect(),
        absorbed: plan.absorbed,
        footprint_pruned: plan.footprint_pruned,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, OpKind, Shape};

    /// Fig. 5 shape: several disjoint tiny chains, no adjacency between
    /// them — remote fusion should pack them into far fewer launches.
    #[test]
    fn disjoint_tiny_kernels_get_packed() {
        let mut g = Graph::new("fig5");
        for i in 0..12 {
            let p = g.param(Shape::new(vec![64]), DType::F32, format!("p{i}"));
            let a = g.unary(OpKind::Relu, p, format!("a{i}"));
            let _ = g.unary(OpKind::Neg, a, format!("b{i}"));
        }
        let device = DeviceSpec::v100();
        let plan = FusionPlan::default(); // 24 singleton kernels
        let before = plan.kernels(&g).len();
        let packed = remote_fusion(&g, &device, plan, &ExploreOptions::default());
        let after = packed.kernels(&g).len();
        assert!(after < before / 3, "before {before}, after {after}");
        assert!(packed.is_disjoint());
        for p in &packed.patterns {
            assert!(p.is_valid(&g));
        }
    }

    #[test]
    fn large_kernels_left_alone() {
        let mut g = Graph::new("big");
        let p = g.param(Shape::new(vec![4096, 4096]), DType::F32, "p");
        let a = g.unary(OpKind::Relu, p, "a");
        let b = g.unary(OpKind::Neg, a, "b");
        let device = DeviceSpec::v100();
        let plan = FusionPlan {
            patterns: vec![FusionPattern::new(vec![a, b])],
            ..Default::default()
        };
        let packed = remote_fusion(&g, &device, plan.clone(), &ExploreOptions::default());
        assert_eq!(packed.kernels(&g).len(), plan.kernels(&g).len());
    }

    #[test]
    fn packing_respects_size_cap() {
        let mut g = Graph::new("cap");
        for i in 0..40 {
            let p = g.param(Shape::new(vec![16]), DType::F32, format!("p{i}"));
            let _ = g.unary(OpKind::Relu, p, format!("a{i}"));
        }
        let device = DeviceSpec::v100();
        let opts = ExploreOptions { max_pattern_size: 10, ..Default::default() };
        let packed = remote_fusion(&g, &device, FusionPlan::default(), &opts);
        for p in &packed.patterns {
            assert!(p.len() <= 10);
        }
    }
}
