//! Fusion exploration (§5): find the fusion plan for a graph.
//!
//! Pipeline: [`candidates`] generates per-vertex *candidate patterns*
//! with the PatternReduction approximate dynamic program (top-k per
//! vertex, scored by the [`delta`] evaluator); [`beam`] composes
//! non-overlapping candidates into whole-graph plans with beam search
//! (width 3) and picks the winner with the accurate latency-evaluator;
//! [`remote`] then packs residual small kernels that are not adjacent in
//! the graph (Fig. 5) to cut launch counts further. [`regions`] splits
//! the graph into independent fusible regions (cut at GEMM/conv/copy
//! boundaries) so candidates+beam+absorption+pruning run per region —
//! the work units the fleet's compile pool parallelizes within a graph
//! (see [`explore_partitioned`]).

pub mod absorb;
pub mod beam;
pub mod candidates;
pub mod delta;
pub mod pattern;
pub mod regions;
pub mod remote;

pub use absorb::{absorb_anchors, applied_absorptions};
pub use beam::{compose_plan, BeamOptions};
pub use candidates::{
    candidate_patterns, candidate_patterns_with_stats, CandidateStats, ExploreOptions,
};
pub use delta::{delta_score, DeltaModel};
pub use pattern::{AbsorbedAnchor, FusionPattern, FusionPlan};
pub use regions::{explore_partitioned, Region};
pub use remote::remote_fusion;

use crate::gpu::DeviceSpec;
use crate::graph::Graph;

/// End-to-end exploration: candidates → beam → producer absorption →
/// latency-evaluator validation → XLA-fusion backfill → remote fusion.
/// This is "fusion explorer" in Fig. 2, with the §6 layering: Fusion-
/// Stitching runs *on top of* XLA's basic fusion, and "basic fusions not
/// merged into larger fusions by FusionStitching finally go through the
/// basic compilation pass of XLA" — which also delivers the production
/// never-negative property of §7.2.
pub fn explore(graph: &Graph, device: &DeviceSpec, opts: &ExploreOptions) -> FusionPlan {
    let (cands, stats) = candidates::candidate_patterns_with_stats(graph, device, opts, None);
    let mut plan = compose_plan(
        graph,
        device,
        &cands,
        &BeamOptions {
            width: opts.beam_width,
            cost: opts.cost,
            footprint_prune: opts.footprint_prune,
        },
    );
    // The plan carries the whole exploration's footprint-prune tally:
    // DP combinations discarded before scoring plus the beam's
    // defense-filter rejections (already on the plan).
    plan.footprint_pruned += stats.footprint_pruned;
    plan = absorb_producers(graph, plan, opts);
    plan = prune_bad_patterns(graph, device, plan, opts);
    plan = backfill_with_xla(graph, plan);
    if opts.enable_remote_fusion {
        plan = remote_fusion(graph, device, plan, opts);
    }
    // Anchored-region absorption runs last, over the final pattern set,
    // so its decisions are identical for monolithic and sharded
    // exploration (both funnel through the same finished plan shape).
    plan = absorb::absorb_anchors(graph, device, plan, opts);
    debug_assert!(plan.is_disjoint());
    plan
}

/// Accurate-model validation: re-cost every pattern with the full
/// latency-evaluator (the code generator's tuner) and drop any whose
/// fused time is not better than launching its ops separately. The
/// delta-evaluator is fast but optimistic (it assumes reuse schedules
/// are available); patterns whose locality constraints force
/// thread-composition recompute are caught here.
pub fn prune_bad_patterns(
    graph: &Graph,
    device: &DeviceSpec,
    mut plan: FusionPlan,
    opts: &ExploreOptions,
) -> FusionPlan {
    let model = DeltaModel::with_params(graph, device.clone(), opts.cost);
    let tuner_opts = crate::codegen::TunerOptions::fusion_stitching_with(opts.cost);
    plan.patterns.retain(|p| {
        match crate::codegen::tune_pattern(graph, p.nodes(), device, &tuner_opts) {
            None => false,
            Some(t) => {
                let unfused: f64 = p
                    .nodes()
                    .iter()
                    .map(|&id| model.op_time_us(id) + model.launch_overhead_us())
                    .sum();
                t.estimate.time_us + model.launch_overhead_us() < unfused
            }
        }
    });
    plan
}

/// Fill regions FusionStitching did not claim with XLA's rule-based
/// basic fusions (§6: the FS pass runs over XLA's fusion results; what
/// it does not merge keeps its XLA grouping). Coverage is tracked with
/// a node bitset — the pairwise pattern-overlap scan was O(|plans|²)
/// and dominated large recurrent graphs (EXPERIMENTS.md §Perf).
pub fn backfill_with_xla(graph: &Graph, mut plan: FusionPlan) -> FusionPlan {
    let mut covered = vec![0u64; graph.len().div_ceil(64)];
    for p in &plan.patterns {
        for id in p.nodes() {
            covered[id.idx() / 64] |= 1 << (id.idx() % 64);
        }
    }
    let xla = crate::baselines::xla::plan(graph);
    for xp in xla.patterns {
        let free = xp
            .nodes()
            .iter()
            .all(|id| covered[id.idx() / 64] >> (id.idx() % 64) & 1 == 0);
        if free {
            for id in xp.nodes() {
                covered[id.idx() / 64] |= 1 << (id.idx() % 64);
            }
            plan.patterns.push(xp);
        }
    }
    plan
}

/// Sink leftover producers into the unique pattern that consumes them.
///
/// PatternReduction grows patterns along consumer chains, so *sibling*
/// producers (e.g. the gamma/beta broadcasts feeding layer-norm's tail)
/// can be left outside a pattern that consumes all their output. Any
/// fusible op whose every consumer lives inside one pattern is absorbed
/// into it when the union stays valid — the closure that makes LN one
/// kernel end-to-end (Fig. 1).
pub fn absorb_producers(
    graph: &Graph,
    mut plan: FusionPlan,
    opts: &ExploreOptions,
) -> FusionPlan {
    use crate::graph::OpKind;
    // Iterate to a fixpoint: absorbing one producer can expose another.
    for _round in 0..8 {
        // node -> owning pattern index
        let mut owner: Vec<Option<usize>> = vec![None; graph.len()];
        for (pi, p) in plan.patterns.iter().enumerate() {
            for &id in p.nodes() {
                owner[id.idx()] = Some(pi);
            }
        }
        let mut changed = false;
        for node in graph.nodes() {
            if owner[node.id.idx()].is_some()
                || !node.kind.is_fusible()
                || matches!(node.kind, OpKind::Copy)
            {
                continue;
            }
            let consumers = graph.consumers(node.id);
            if consumers.is_empty() {
                continue;
            }
            let homes: Vec<Option<usize>> =
                consumers.iter().map(|c| owner[c.idx()]).collect();
            let first = homes[0];
            if first.is_none() || homes.iter().any(|h| *h != first) {
                continue;
            }
            let pi = first.unwrap();
            let cand = plan.patterns[pi].union(&FusionPattern::single(node.id));
            if cand.len() <= opts.max_pattern_size && cand.is_valid(graph) {
                plan.patterns[pi] = cand;
                owner[node.id.idx()] = Some(pi);
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::NodeId;
    use crate::workloads::{models, Mode};

    /// Canonical kernel shape of a plan: per-pattern sorted node ids,
    /// patterns sorted — plan identity independent of discovery order.
    fn canon(plan: &FusionPlan) -> Vec<Vec<NodeId>> {
        let mut v: Vec<Vec<NodeId>> = plan
            .patterns
            .iter()
            .map(|p| {
                let mut n = p.nodes().to_vec();
                n.sort_unstable();
                n
            })
            .collect();
        v.sort();
        v
    }

    /// Satellite property: over the tier-1 model builders, footprint-
    /// first pruning is plan-preserving whenever nothing had to be
    /// pruned (the hard bound is exactly the old occupancy-zero score
    /// filter, applied before the beam instead of inside it), and when
    /// pruning does fire every surviving pattern is feasible and the
    /// modeled plan latency does not regress beyond composition noise
    /// (pruning a small over-cap union can keep a *larger* feasible
    /// union from being discovered, costing at most a launch).
    #[test]
    fn pruned_exploration_is_plan_preserving_on_feasible_workloads() {
        let device = DeviceSpec::v100();
        let on = ExploreOptions::default();
        let off = ExploreOptions { footprint_prune: false, ..Default::default() };
        let mut identity_cases = 0usize;
        for w in [
            models::bert(Mode::Infer),
            models::bert(Mode::Train),
            models::asr(),
            models::bert_with(Mode::Train, 32, 512),
        ] {
            let p_on = explore(&w.graph, &device, &on);
            let p_off = explore(&w.graph, &device, &off);
            let model = DeltaModel::new(&w.graph, device.clone());
            for p in &p_on.patterns {
                assert!(
                    model.pattern_footprint_feasible(p.nodes()),
                    "{}: infeasible pattern in pruned plan: {:?}",
                    w.key(),
                    p
                );
            }
            if p_on.footprint_pruned == 0 {
                // Nothing was discarded: the DP, beam, and every later
                // pass saw identical inputs — the plans must match.
                assert_eq!(canon(&p_on), canon(&p_off), "{}", w.key());
                assert_eq!(p_on.absorbed.len(), p_off.absorbed.len(), "{}", w.key());
                identity_cases += 1;
            } else {
                let t_on = model.plan_time_us(&p_on.kernels(&w.graph));
                let t_off = model.plan_time_us(&p_off.kernels(&w.graph));
                assert!(
                    t_on <= t_off * 1.02 + 1e-9,
                    "{}: pruned plan {t_on:.2} µs regressed vs unpruned {t_off:.2} µs",
                    w.key()
                );
            }
        }
        assert!(identity_cases > 0, "no workload exercised the identity branch");
        // The long-sequence BERT stages 64 KB for its 1-D loss tail —
        // pruning must actually fire somewhere in the sweep.
        let big = explore(
            &models::bert_with(Mode::Train, 32, 512).graph,
            &device,
            &on,
        );
        assert!(big.footprint_pruned > 0, "the 64 KB loss tail must be pruned");
    }
}
