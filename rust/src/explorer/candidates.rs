//! Candidate-pattern generation (§5.2): the approximate dynamic
//! programming pass.
//!
//! Walking vertices in post-order (last to first), each vertex's
//! *candidate-patterns* — the top-k fusion patterns having that vertex
//! as producer — are built from its consumers' candidate sets by
//! **PatternReduction**: consumers are split into groups of two, each
//! group's option combinations are enumerated and reduced to the top k,
//! and group results are combined pairwise (Fig. 4's divide-and-conquer,
//! which bounds the combinatorics that a naive cross-product of consumer
//! candidates would explode into). Patterns that would create cyclic
//! dependences (Fig. 6), exceed the size cap, or that the code
//! generator cannot schedule are discarded during the search.

use super::delta::DeltaModel;
use super::pattern::FusionPattern;
use crate::gpu::{CostParams, DeviceSpec};
use crate::graph::{Graph, NodeId, OpKind};

/// Exploration knobs (paper defaults: k = 3).
#[derive(Debug, Clone)]
pub struct ExploreOptions {
    /// Candidate patterns kept per vertex (the paper's top-k = 3).
    pub top_k: usize,
    /// Hard cap on ops per pattern.
    pub max_pattern_size: usize,
    /// Run the Fig. 5 remote-fusion pass after beam search.
    pub enable_remote_fusion: bool,
    /// Max kernels packed into one remote-fusion bundle. Packing is
    /// bounded in practice by launch-configuration compatibility of the
    /// packed parts; unbounded packing over-states the §7.3 call-count
    /// reductions (paper: FS mem calls are 28–48% of XLA's, not 15%).
    pub max_pack_bundle: usize,
    /// Use the full latency-evaluator instead of the delta-evaluator for
    /// scoring (the §7.5 ablation: much slower, no better plans).
    pub full_cost_model: bool,
    /// Beam width for plan composition (§5.3; the paper keeps 3
    /// buffer sets).
    pub beam_width: usize,
    /// Run the anchored-region absorption pass after remote fusion:
    /// GEMM/conv anchors may absorb the adjacent epilogue/prologue
    /// patterns across the compute boundary when the saved launch +
    /// intermediate round-trip beats the staging occupancy pressure.
    /// Off for dynamic-loop bodies (the per-iteration re-dispatch defeats
    /// the hand-off) and for the baseline personalities.
    pub absorb_anchors: bool,
    /// Footprint-first hard pruning (default on): DP combinations whose
    /// intermediate-footprint bound cannot launch are discarded *before*
    /// scoring (and counted), and the beam re-checks every candidate it
    /// admits. Off = the unpruned ablation: the delta model scores
    /// over-cap patterns optimistically and their infeasibility is only
    /// discovered by the accurate-model pruning at tune time — the
    /// pre-refactor world `explorer_perf`'s footprint section measures
    /// against. Plan-equivalent when on: the hard bound is exactly the
    /// old occupancy-zero score filter, applied earlier.
    pub footprint_prune: bool,
    /// Cost-model constants every scoring pass of this exploration uses
    /// (delta evaluator, beam selection, accurate-model pruning, launch
    /// tuning at lowering). Defaults reproduce the historical hard-coded
    /// values; the fleet's calibration loop threads fitted
    /// per-device-class corrections through here.
    pub cost: CostParams,
}

impl Default for ExploreOptions {
    fn default() -> Self {
        ExploreOptions {
            top_k: 3,
            max_pattern_size: 48,
            enable_remote_fusion: true,
            max_pack_bundle: 4,
            full_cost_model: false,
            beam_width: 3,
            absorb_anchors: true,
            footprint_prune: true,
            cost: CostParams::default(),
        }
    }
}

/// Tally of candidate generation: how many DP combinations the
/// footprint-first hard bound discarded before scoring, and how many
/// were scored. Deterministic per (graph, device, opts, mask).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CandidateStats {
    /// Combinations whose footprint bound could not launch.
    pub footprint_pruned: usize,
    /// Combinations that reached the delta (or full) scorer.
    pub scored: usize,
}

/// A pattern with its delta-evaluator score.
#[derive(Debug, Clone)]
pub struct ScoredPattern {
    pub pattern: FusionPattern,
    pub score: f64,
}

/// Per-vertex candidate sets, indexed by node id.
pub type CandidateSets = Vec<Vec<ScoredPattern>>;

/// Generate candidate patterns for every vertex.
pub fn candidate_patterns(
    graph: &Graph,
    device: &DeviceSpec,
    opts: &ExploreOptions,
) -> CandidateSets {
    candidate_patterns_in(graph, device, opts, None)
}

/// Masked candidate generation: vertices with `mask[id] == false`
/// neither seed candidates nor contribute consumer options (their sets
/// stay empty, which the DP and the beam both already treat as "skip").
/// The region partitioner ([`super::regions`]) uses this to run the DP
/// over one fusible region at a time; `None` means the whole graph.
pub fn candidate_patterns_in(
    graph: &Graph,
    device: &DeviceSpec,
    opts: &ExploreOptions,
    mask: Option<&[bool]>,
) -> CandidateSets {
    candidate_patterns_with_stats(graph, device, opts, mask).0
}

/// [`candidate_patterns_in`] plus the [`CandidateStats`] tally — the
/// entry point exploration uses so the footprint-prune count can ride
/// the finished [`super::FusionPlan`] up to the fleet's counters.
pub fn candidate_patterns_with_stats(
    graph: &Graph,
    device: &DeviceSpec,
    opts: &ExploreOptions,
    mask: Option<&[bool]>,
) -> (CandidateSets, CandidateStats) {
    // In unpruned ablation mode the delta model prices over-cap
    // patterns optimistically (capacity clamped), so they survive into
    // the candidate sets and the beam — infeasibility then surfaces at
    // accurate-model pruning time, as it did before footprint-first.
    let model = DeltaModel::with_params(graph, device.clone(), opts.cost)
        .with_capacity_enforcement(opts.footprint_prune);
    let scorer = Scorer {
        model,
        graph,
        device: device.clone(),
        full: opts.full_cost_model,
        cost: opts.cost,
        prune: opts.footprint_prune,
        stats: std::cell::Cell::new(CandidateStats::default()),
    };
    let mut cands: CandidateSets = vec![Vec::new(); graph.len()];

    for &v in graph.post_order().iter() {
        if let Some(m) = mask {
            if !m[v.idx()] {
                continue;
            }
        }
        let node = graph.node(v);
        // Copy nodes are memcpy activity (the Cpy column), never fused.
        // Reshape *does* participate: jax-lowered HLO sandwiches
        // zero-cost reshapes between every fusible op, and excluding
        // them would break every producer→consumer chain the DP walks
        // (a reshape inside a kernel is just an index remap).
        if !node.kind.is_fusible() || matches!(node.kind, OpKind::Copy) {
            continue;
        }
        // Options per fusible consumer: that consumer's candidate set.
        let consumer_sets: Vec<&[ScoredPattern]> = graph
            .consumers(v)
            .iter()
            .filter(|&&c| !cands[c.idx()].is_empty())
            .map(|&c| cands[c.idx()].as_slice())
            .collect();

        let mut results = pattern_reduction(graph, &scorer, v, &consumer_sets, opts);
        // The bare producer is always a (zero-score) candidate so that
        // upstream vertices can still seed from it.
        results.push(ScoredPattern { pattern: FusionPattern::single(v), score: 0.0 });
        dedup_top_k(&mut results, opts.top_k);
        cands[v.idx()] = results;
    }
    let stats = scorer.stats.get();
    (cands, stats)
}

/// Scoring indirection: delta-evaluator by default; the full
/// latency-evaluator when the §7.5 ablation asks for it.
struct Scorer<'g> {
    model: DeltaModel<'g>,
    graph: &'g Graph,
    device: DeviceSpec,
    full: bool,
    cost: CostParams,
    /// Footprint-first hard pruning on/off (mirrors
    /// [`ExploreOptions::footprint_prune`]).
    prune: bool,
    /// Running tally of pruned/scored combinations (interior mutability:
    /// the DP threads `&Scorer` everywhere).
    stats: std::cell::Cell<CandidateStats>,
}

impl Scorer<'_> {
    /// Gate + score one DP combination: `None` when the footprint-first
    /// hard bound discards it before scoring (counted), otherwise the
    /// pattern's score (callers still filter non-finite scores — the
    /// defense that keeps unprunable infeasibilities out).
    fn admit(&self, pattern: &FusionPattern) -> Option<f64> {
        let mut stats = self.stats.get();
        if self.prune && !self.model.pattern_footprint_feasible(pattern.nodes()) {
            stats.footprint_pruned += 1;
            self.stats.set(stats);
            return None;
        }
        stats.scored += 1;
        self.stats.set(stats);
        Some(self.score(pattern))
    }

    fn score(&self, pattern: &FusionPattern) -> f64 {
        if !self.full {
            return self.model.score(pattern.nodes());
        }
        // Ablation path: tune the pattern with the accurate model and
        // score as (unfused sum + launches saved) − tuned time.
        let unfused: f64 = pattern
            .nodes()
            .iter()
            .map(|&id| self.model.op_time_us(id))
            .sum();
        let calls_saved = (pattern.len() - 1) as f64 * self.model.launch_overhead_us();
        match crate::codegen::tune_pattern(
            self.graph,
            pattern.nodes(),
            &self.device,
            &crate::codegen::TunerOptions::fusion_stitching_with(self.cost),
        ) {
            Some(t) => unfused + calls_saved - t.estimate.time_us,
            None => f64::NEG_INFINITY,
        }
    }
}

/// PatternReduction for one vertex: divide consumers into groups of two,
/// enumerate in-group combinations, reduce group results pairwise.
fn pattern_reduction(
    graph: &Graph,
    scorer: &Scorer,
    v: NodeId,
    consumer_sets: &[&[ScoredPattern]],
    opts: &ExploreOptions,
) -> Vec<ScoredPattern> {
    if consumer_sets.is_empty() {
        return Vec::new();
    }
    // Recursive binary reduction over the consumer list.
    reduce_range(graph, scorer, v, consumer_sets, opts)
}

fn reduce_range(
    graph: &Graph,
    scorer: &Scorer,
    v: NodeId,
    sets: &[&[ScoredPattern]],
    opts: &ExploreOptions,
) -> Vec<ScoredPattern> {
    match sets.len() {
        0 => Vec::new(),
        1 => combine_pair(graph, scorer, v, sets[0], &[], opts),
        2 => combine_pair(graph, scorer, v, sets[0], sets[1], opts),
        n => {
            // Divide: reduce halves independently, then combine their
            // results (each half's results already contain v, so the
            // combine step unions them).
            let (a, b) = sets.split_at(n / 2);
            let ra = reduce_range(graph, scorer, v, a, opts);
            let rb = reduce_range(graph, scorer, v, b, opts);
            merge_results(graph, scorer, v, &ra, &rb, opts)
        }
    }
}

/// Enumerate {empty ∪ candidates(A)} × {empty ∪ candidates(B)}, append
/// v, validate, score, keep top-k.
fn combine_pair(
    graph: &Graph,
    scorer: &Scorer,
    v: NodeId,
    a: &[ScoredPattern],
    b: &[ScoredPattern],
    opts: &ExploreOptions,
) -> Vec<ScoredPattern> {
    let mut out = Vec::new();
    let a_opts: Vec<Option<&FusionPattern>> =
        std::iter::once(None).chain(a.iter().map(|s| Some(&s.pattern))).collect();
    let b_opts: Vec<Option<&FusionPattern>> =
        std::iter::once(None).chain(b.iter().map(|s| Some(&s.pattern))).collect();
    for pa in &a_opts {
        for pb in &b_opts {
            let mut nodes = vec![v];
            if let Some(p) = pa {
                nodes.extend_from_slice(p.nodes());
            }
            if let Some(p) = pb {
                nodes.extend_from_slice(p.nodes());
            }
            if nodes.len() < 2 {
                continue; // bare v is added by the caller
            }
            let pat = FusionPattern::new(nodes);
            if pat.len() > opts.max_pattern_size || !pat.is_valid(graph) {
                continue;
            }
            if let Some(score) = scorer.admit(&pat) {
                if score.is_finite() {
                    out.push(ScoredPattern { pattern: pat, score });
                }
            }
        }
    }
    dedup_top_k(&mut out, opts.top_k);
    out
}

/// Combine two group results (each pattern already contains v).
fn merge_results(
    graph: &Graph,
    scorer: &Scorer,
    _v: NodeId,
    a: &[ScoredPattern],
    b: &[ScoredPattern],
    opts: &ExploreOptions,
) -> Vec<ScoredPattern> {
    let mut out: Vec<ScoredPattern> = Vec::new();
    out.extend_from_slice(a);
    out.extend_from_slice(b);
    for sa in a {
        for sb in b {
            let u = sa.pattern.union(&sb.pattern);
            if u.len() > opts.max_pattern_size || !u.is_valid(graph) {
                continue;
            }
            if let Some(score) = scorer.admit(&u) {
                if score.is_finite() {
                    out.push(ScoredPattern { pattern: u, score });
                }
            }
        }
    }
    dedup_top_k(&mut out, opts.top_k);
    out
}

/// Sort by score descending, drop duplicates, truncate to k.
fn dedup_top_k(items: &mut Vec<ScoredPattern>, k: usize) {
    items.sort_by(|x, y| y.score.partial_cmp(&x.score).unwrap_or(std::cmp::Ordering::Equal));
    let mut seen: Vec<FusionPattern> = Vec::new();
    items.retain(|s| {
        if seen.contains(&s.pattern) {
            false
        } else {
            seen.push(s.pattern.clone());
            true
        }
    });
    items.truncate(k);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, ReduceOp, Shape};
    use crate::workloads::blocks;

    #[test]
    fn layernorm_producer_candidate_covers_whole_pattern() {
        let mut g = Graph::new("ln");
        let x = g.param(Shape::new(vec![4096, 768]), DType::F32, "x");
        let _ = blocks::layer_norm(&mut g, x, "ln");
        let device = DeviceSpec::v100();
        let cands = candidate_patterns(&g, &device, &ExploreOptions::default());
        // The earliest fusible op (the first reduce's producer cone
        // starts at the 'sum' node, id 1) should have a candidate
        // spanning most of the LN body.
        let first_fusible = g
            .nodes()
            .iter()
            .find(|n| n.kind.is_fusible())
            .unwrap()
            .id;
        let best = &cands[first_fusible.idx()][0];
        assert!(
            best.pattern.len() >= 10,
            "best pattern only {} ops: {:?}",
            best.pattern.len(),
            best.pattern
        );
        assert!(best.score > 0.0);
    }

    /// The Fig. 4 workbench: v8 with consumers v5, v6, v7 whose
    /// candidate sets exist; PatternReduction must produce ≤ k patterns
    /// all containing v8 and all valid.
    #[test]
    fn fig4_pattern_reduction_shape() {
        let mut g = Graph::new("fig4");
        let p = g.param(Shape::new(vec![1024]), DType::F32, "p");
        let v8 = g.unary(OpKind::Relu, p, "v8");
        let v5 = g.unary(OpKind::Neg, v8, "v5");
        let v6 = g.unary(OpKind::Abs, v8, "v6");
        let v7 = g.unary(OpKind::Relu, v8, "v7");
        let v2 = g.binary(OpKind::Add, v5, v6, "v2");
        let v1 = g.unary(OpKind::Neg, v7, "v1");
        let v0 = g.binary(OpKind::Add, v2, v1, "v0");
        let _ = v0;
        let device = DeviceSpec::v100();
        let opts = ExploreOptions::default();
        let cands = candidate_patterns(&g, &device, &opts);
        let v8_cands = &cands[v8.idx()];
        assert!(!v8_cands.is_empty());
        assert!(v8_cands.len() <= opts.top_k);
        for c in v8_cands {
            assert!(c.pattern.contains(v8), "candidate must contain producer");
            assert!(c.pattern.is_valid(&g));
        }
        // The whole 7-op body is fusible; the best candidate should
        // swallow several consumers.
        assert!(v8_cands[0].pattern.len() >= 4);
    }

    use crate::graph::OpKind;

    #[test]
    fn cyclic_combinations_are_rejected() {
        // A -> B -> C, A -> C: candidates of A must never contain {A, C}
        // without B.
        let mut g = Graph::new("cyc");
        let p = g.param(Shape::new(vec![64]), DType::F32, "p");
        let a = g.unary(OpKind::Relu, p, "A");
        let b = g.reduce(ReduceOp::Sum, a, vec![0], "B"); // reduce keeps B out of fusions upward
        let bb = g.broadcast(b, Shape::new(vec![64]), "Bb");
        let c = g.binary(OpKind::Add, a, bb, "C");
        let _ = c;
        let device = DeviceSpec::v100();
        let cands = candidate_patterns(&g, &device, &ExploreOptions::default());
        for s in &cands[a.idx()] {
            if s.pattern.contains(c) && !s.pattern.contains(bb) {
                panic!("cyclic candidate survived: {:?}", s.pattern);
            }
        }
    }

    /// Satellite regression: a pattern exceeding the per-block cap is
    /// discarded by the DP before scoring — it never appears in any
    /// candidate set (so it can never reach the beam) and the stats
    /// count the discard. The unpruned ablation admits the same
    /// combination and counts nothing.
    #[test]
    fn over_cap_combinations_never_enter_candidate_sets() {
        // exp → reduce at [64, 16384]: 64 KB per-row staging for the
        // internal exp producer — over the 48 KB per-block cap.
        let mut g = Graph::new("wide");
        let x = g.param(Shape::new(vec![64, 16384]), DType::F32, "x");
        let e = g.unary(OpKind::Exp, x, "e");
        let r = g.reduce(ReduceOp::Sum, e, vec![1], "r");
        let device = DeviceSpec::v100();

        let opts = ExploreOptions::default();
        assert!(opts.footprint_prune, "footprint-first is the default");
        let (cands, stats) = candidate_patterns_with_stats(&g, &device, &opts, None);
        assert!(stats.footprint_pruned > 0, "the over-cap union must be counted");
        let model = DeltaModel::new(&g, device.clone());
        for per_vertex in &cands {
            for s in per_vertex {
                if s.pattern.len() >= 2 {
                    assert!(
                        model.pattern_footprint_feasible(s.pattern.nodes()),
                        "infeasible candidate survived: {:?}",
                        s.pattern
                    );
                }
            }
        }
        assert!(
            !cands[e.idx()].iter().any(|s| s.pattern.contains(r)),
            "{{e, r}} must never become a candidate under pruning"
        );

        // Ablation: with pruning off the optimistic model admits it.
        let unpruned = ExploreOptions { footprint_prune: false, ..Default::default() };
        let (cands_off, stats_off) =
            candidate_patterns_with_stats(&g, &device, &unpruned, None);
        assert_eq!(stats_off.footprint_pruned, 0);
        assert!(
            cands_off[e.idx()].iter().any(|s| s.pattern.contains(r)),
            "the unpruned ablation must admit the over-cap union"
        );
    }

    /// On a workload where every combination fits, pruning changes
    /// nothing: identical candidate sets, identical scores, zero prune
    /// count — the plan-equivalence guarantee at the DP level.
    #[test]
    fn pruning_is_identity_when_everything_fits() {
        let mut g = Graph::new("ln");
        let x = g.param(Shape::new(vec![4096, 768]), DType::F32, "x");
        let _ = blocks::layer_norm(&mut g, x, "ln");
        let device = DeviceSpec::v100();
        let on = ExploreOptions::default();
        let off = ExploreOptions { footprint_prune: false, ..Default::default() };
        let (c_on, s_on) = candidate_patterns_with_stats(&g, &device, &on, None);
        let (c_off, s_off) = candidate_patterns_with_stats(&g, &device, &off, None);
        assert_eq!(s_on.footprint_pruned, 0);
        assert_eq!(s_on.scored, s_off.scored);
        assert_eq!(c_on.len(), c_off.len());
        for (a, b) in c_on.iter().zip(&c_off) {
            assert_eq!(a.len(), b.len());
            for (sa, sb) in a.iter().zip(b) {
                assert_eq!(sa.pattern, sb.pattern);
                assert_eq!(sa.score, sb.score);
            }
        }
    }

    #[test]
    fn candidates_respect_size_cap() {
        let mut g = Graph::new("chain");
        let x = g.param(Shape::new(vec![256]), DType::F32, "x");
        let mut cur = x;
        for i in 0..30 {
            cur = g.unary(OpKind::Relu, cur, format!("r{i}"));
        }
        let device = DeviceSpec::v100();
        let opts = ExploreOptions { max_pattern_size: 8, ..Default::default() };
        let cands = candidate_patterns(&g, &device, &opts);
        for per_vertex in &cands {
            for s in per_vertex {
                assert!(s.pattern.len() <= 8);
            }
        }
    }
}
