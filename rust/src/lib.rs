//! # FusionStitching
//!
//! A from-scratch reproduction of *FusionStitching: Boosting Memory
//! Intensive Computations for Deep Learning Workloads* (Zheng et al.,
//! Alibaba Group, 2020) as a three-layer Rust + JAX + Pallas system.
//!
//! The paper's contribution is a just-in-time fusion compiler for
//! memory-intensive operators: it widens the fusion search space beyond
//! XLA by allowing intermediate-value *reuse* (via register shuffle and
//! shared memory on GPUs; via VMEM staging in our Pallas exemplars), and
//! it replaces XLA's rule-based greedy fusion with a cost-model-guided
//! search (approximate dynamic programming + beam search).
//!
//! ## Crate layout
//!
//! * [`graph`] — the HLO-like operator IR the compiler works on.
//! * [`workloads`] — builders for the paper's evaluation graphs
//!   (LayerNorm, BERT, DIEN, Transformer, ASR, CRNN) plus a synthetic
//!   random-graph generator.
//! * [`gpu`] — the device model and timing simulator substrate (V100 and
//!   T4 specs; occupancy, memory traffic, kernel launch accounting).
//! * [`codegen`] — the paper's §4: composition schemes, schedule
//!   templates, sub-root grouping, launch-dim tuning, the
//!   latency-evaluator, shared-memory dataflow reuse, index CSE, and
//!   kernel emission.
//! * [`explorer`] — the paper's §5: candidate-pattern generation via
//!   PatternReduction, cycle rejection, remote fusion, the
//!   delta-evaluator, and beam-search fusion-plan composition.
//! * [`baselines`] — the TF (kernel-per-op) and XLA (rule-based greedy
//!   fusion) strategies the paper compares against.
//! * [`pipeline`] — end-to-end `optimize()` + per-technique breakdown
//!   reports (the rows of the paper's Table 2).
//! * [`hlo`] — HLO-text parser + converter into the fusion IR, so the
//!   explorer can analyze the same jax-lowered artifacts the runtime
//!   executes.
//! * [`runtime`] — PJRT client wrapper loading AOT-lowered HLO text from
//!   `artifacts/` and executing it on the CPU client.
//! * [`coordinator`] — the JIT service: sessions, a compilation cache,
//!   async-compilation with hot swap (§6), and serving metrics.
//! * [`fleet`] — the §7.2 production layer over the coordinator: a
//!   mixed-device registry, a bounded compile-worker pool with a
//!   work-stealing queue, a shared cross-device plan store (plans port
//!   between device classes by re-running only the launch-dim tuner),
//!   admission control/backpressure, and a deterministic discrete-event
//!   traffic simulator reporting fleet-wide GPU-hours saved.
//! * [`obs`] — the fleet's flight recorder: per-thread event rings with
//!   typed lifecycle spans, stage-attributed latency, a lock-contention
//!   profiler, and Chrome trace-event export (Perfetto-loadable).
//! * [`util`] — deterministic PRNG, tiny JSON writer, table formatting,
//!   percentile helpers, and a micro-bench timer (the environment has
//!   no criterion/serde).

pub mod baselines;
pub mod codegen;
pub mod coordinator;
pub mod explorer;
pub mod fleet;
pub mod gpu;
pub mod graph;
pub mod hlo;
pub mod obs;
pub mod pipeline;
pub mod runtime;
pub mod util;
pub mod workloads;

pub use graph::{DType, Graph, Node, NodeId, OpClass, OpKind, Shape};
