//! GPU substrate: device models and the timing simulator.
//!
//! The paper's evaluation ran on real V100/T4 silicon; this environment
//! has neither, so we implement the **machine model the paper itself
//! reasons with** (§4.3, Eq. 1): kernels execute in waves of warps whose
//! count is set by occupancy, warp latency is an instruction-count × CPI
//! product, and memory-bound kernels are limited by HBM bandwidth scaled
//! by an occupancy-dependent efficiency. Every Table 2 / Figure 7 number
//! in our benches is produced by this substrate. See DESIGN.md §1.

pub mod cost;
pub mod device;
pub mod kernel;
pub mod simulator;
pub mod trace;

pub use cost::CostParams;
pub use device::DeviceSpec;
pub use kernel::{KernelClass, KernelSpec, LaunchDims};
pub use simulator::{Breakdown, SimConfig, Simulator};
pub use trace::{Trace, TraceEvent};
