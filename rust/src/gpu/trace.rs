//! Execution-trace export: replay a kernel sequence through the
//! [`Simulator`] timing model and emit a Chrome-tracing (`chrome://
//! tracing` / Perfetto) JSON timeline.
//!
//! This is the reproduction's stand-in for the nvprof timelines the
//! paper's breakdown analysis (§7.3) is built from: one lane for the
//! host (launch/scheduling/loop-glue slices), one lane per device
//! engine (compute kernels, memory-intensive kernels, memcpys), with
//! the same serialization the TF executor exhibits — host dispatch
//! precedes each device slice, device engines run back-to-back.

use super::{KernelClass, KernelSpec, Simulator};
use crate::util::json::JsonValue;
use crate::workloads::LoopKind;

/// One timeline slice (a kernel execution or a host interval).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub name: String,
    /// Trace lane: "host", "math", "mem", or "cpy".
    pub lane: &'static str,
    /// Start, µs from iteration begin.
    pub start_us: f64,
    pub duration_us: f64,
    /// Bytes of global-memory traffic (0 for host slices).
    pub bytes: usize,
}

/// A full single-iteration trace.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
}

impl Trace {
    /// Total span (end of the last event), µs.
    pub fn span_us(&self) -> f64 {
        self.events
            .iter()
            .map(|e| e.start_us + e.duration_us)
            .fold(0.0, f64::max)
    }

    /// Sum of device-lane busy time, µs.
    pub fn device_busy_us(&self) -> f64 {
        self.events
            .iter()
            .filter(|e| e.lane != "host")
            .map(|e| e.duration_us)
            .sum()
    }

    /// Device utilization: busy / span (the launch-gap visualization of
    /// §2.2 — many tiny kernels ⇒ low utilization).
    pub fn device_utilization(&self) -> f64 {
        let span = self.span_us();
        if span == 0.0 {
            0.0
        } else {
            self.device_busy_us() / span
        }
    }

    /// Number of device slices.
    pub fn device_slices(&self) -> usize {
        self.events.iter().filter(|e| e.lane != "host").count()
    }

    /// Serialize to the Chrome-tracing JSON array-of-events format.
    /// Lanes map to `tid`s within one `pid`.
    pub fn to_chrome_json(&self) -> JsonValue {
        let tid = |lane: &str| -> i64 {
            match lane {
                "host" => 0,
                "math" => 1,
                "mem" => 2,
                _ => 3,
            }
        };
        let mut events = Vec::with_capacity(self.events.len() + 4);
        for (lane, tname) in [
            ("host", "CPU (launch+sched)"),
            ("math", "GPU compute"),
            ("mem", "GPU mem-intensive"),
            ("cpy", "memcpy"),
        ] {
            let mut meta = JsonValue::obj();
            let mut args = JsonValue::obj();
            args.set("name", tname);
            meta.set("name", "thread_name")
                .set("ph", "M")
                .set("pid", 1i64)
                .set("tid", tid(lane))
                .set("args", args);
            events.push(meta);
        }
        for e in &self.events {
            let mut args = JsonValue::obj();
            args.set("bytes", e.bytes);
            let mut ev = JsonValue::obj();
            ev.set("name", e.name.as_str())
                .set("ph", "X")
                .set("pid", 1i64)
                .set("tid", tid(e.lane))
                .set("ts", e.start_us)
                .set("dur", e.duration_us)
                .set("args", args);
            events.push(ev);
        }
        JsonValue::Arr(events)
    }
}

impl Simulator {
    /// Run a kernel sequence and record the timeline. Timing semantics
    /// match [`Simulator::run`]: host dispatch cost precedes each device
    /// slice; the device executes serially (the single-stream behaviour
    /// Table 2's per-component times add up under).
    pub fn run_traced(&self, kernels: &[KernelSpec], loop_kind: LoopKind) -> Trace {
        let mut t = Trace::default();
        let mut clock = 0.0f64;
        // Iteration-setup slice (host_base).
        t.events.push(TraceEvent {
            name: "iteration setup".into(),
            lane: "host",
            start_us: 0.0,
            duration_us: self.config.host_base_us,
            bytes: 0,
        });
        clock += self.config.host_base_us;
        for k in kernels {
            let lane = match k.class {
                KernelClass::Memcpy => "cpy",
                KernelClass::ComputeIntensive { .. } => "math",
                KernelClass::MemoryIntensive => "mem",
            };
            let host_us = self.config.host_charge_us(&k.class, loop_kind);
            t.events.push(TraceEvent {
                name: format!("launch {}", k.name),
                lane: "host",
                start_us: clock,
                duration_us: host_us,
                bytes: 0,
            });
            clock += host_us;
            let dev_us = self.kernel_time_us(k);
            t.events.push(TraceEvent {
                name: k.name.clone(),
                lane,
                start_us: clock,
                duration_us: dev_us,
                bytes: k.total_bytes(),
            });
            clock += dev_us;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::{DeviceSpec, LaunchDims, SimConfig};

    fn kernels() -> Vec<KernelSpec> {
        vec![
            KernelSpec {
                name: "fused.0".into(),
                class: KernelClass::MemoryIntensive,
                launch: LaunchDims { grid_blocks: 512, block_threads: 256 },
                regs_per_thread: 16,
                shmem_per_block: 0,
                bytes_read: 8 << 20,
                bytes_written: 8 << 20,
                instrs_per_thread: 16.0,
                avg_cpi: 4.0,
            },
            KernelSpec::library("gemm", 1_000_000_000, 12 << 20),
            KernelSpec::memcpy("h2d", 1 << 20),
        ]
    }

    #[test]
    fn trace_matches_run_breakdown() {
        let sim = Simulator::new(DeviceSpec::v100(), SimConfig::tensorflow());
        let ks = kernels();
        let b = sim.run(&ks, LoopKind::None);
        let t = sim.run_traced(&ks, LoopKind::None);
        // Same device slice count...
        assert_eq!(t.device_slices(), b.total_calls());
        // ...and the same total time (host + device).
        let total_ms = t.span_us() / 1e3;
        assert!((total_ms - b.e2e_ms()).abs() < 1e-9, "{total_ms} vs {}", b.e2e_ms());
    }

    #[test]
    fn events_are_serialized_and_non_overlapping() {
        let sim = Simulator::new(DeviceSpec::v100(), SimConfig::tensorflow());
        let t = sim.run_traced(&kernels(), LoopKind::None);
        let mut end = 0.0;
        for e in &t.events {
            assert!(e.start_us >= end - 1e-9, "overlap at {}", e.name);
            end = e.start_us + e.duration_us;
        }
    }

    #[test]
    fn utilization_improves_with_fewer_kernels() {
        // 100 tiny kernels vs the same work in 10: utilization rises —
        // the launch-gap pathology the paper's Figure-1 case removes.
        let sim = Simulator::new(DeviceSpec::v100(), SimConfig::tensorflow());
        let tiny: Vec<KernelSpec> = (0..100)
            .map(|i| KernelSpec {
                name: format!("t{i}"),
                class: KernelClass::MemoryIntensive,
                launch: LaunchDims { grid_blocks: 64, block_threads: 256 },
                regs_per_thread: 16,
                shmem_per_block: 0,
                bytes_read: 1 << 20,
                bytes_written: 1 << 20,
                instrs_per_thread: 4.0,
                avg_cpi: 4.0,
            })
            .collect();
        let mut fused = Vec::new();
        for i in 0..10 {
            let mut k = tiny[0].clone();
            k.name = format!("f{i}");
            k.bytes_read = 10 << 20;
            k.bytes_written = 10 << 20;
            k.launch.grid_blocks = 640;
            fused.push(k);
        }
        let u_tiny = sim.run_traced(&tiny, LoopKind::None).device_utilization();
        let u_fused = sim.run_traced(&fused, LoopKind::None).device_utilization();
        assert!(u_fused > u_tiny, "fused {u_fused:.3} vs tiny {u_tiny:.3}");
    }

    #[test]
    fn chrome_json_shape() {
        let sim = Simulator::new(DeviceSpec::v100(), SimConfig::tensorflow());
        let t = sim.run_traced(&kernels(), LoopKind::None);
        let json = t.to_chrome_json().to_pretty();
        assert!(json.contains("\"ph\": \"X\""));
        assert!(json.contains("thread_name"));
        assert!(json.contains("gemm"));
        // Valid-ish JSON: balanced brackets.
        assert_eq!(json.matches('[').count(), json.matches(']').count());
        assert_eq!(json.matches('{').count(), json.matches('}').count());
    }

    #[test]
    fn dynamic_loop_inflates_host_lane() {
        let sim = Simulator::new(DeviceSpec::v100(), SimConfig::tensorflow());
        let ks = kernels();
        let t_static = sim.run_traced(&ks, LoopKind::None);
        let t_dyn = sim.run_traced(&ks, LoopKind::DynamicLoop);
        let host = |t: &Trace| -> f64 {
            t.events.iter().filter(|e| e.lane == "host").map(|e| e.duration_us).sum()
        };
        assert!(host(&t_dyn) > host(&t_static));
    }
}
