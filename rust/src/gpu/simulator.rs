//! The timing simulator: executes a kernel sequence and produces the
//! Table-2 breakdown (CPU / Math / Mem / Cpy device times + call counts).
//!
//! Kernel device time follows the paper's own latency-evaluator form
//! (Eq. 1) for the ALU side and a bandwidth model for the memory side:
//!
//! ```text
//! T_alu  = N_wave × L_warp / clock,  N_wave = ceil(N_warp / (occ × slots))
//! T_mem  = bytes / BW_eff(occ)
//! T      = max(T_alu, T_mem, kernel_floor)
//! ```
//!
//! Host-side (CPU) time models TF's per-kernel scheduling and launch
//! cost, which Table 2 shows dominating recurrent workloads — the
//! "severe context switch overhead" observation of §2.2.

use super::{DeviceSpec, KernelClass, KernelSpec};
use crate::workloads::LoopKind;

/// Host-runtime cost model knobs. Calibrated per framework family:
/// stock TF dispatches kernels cheaply but pays per-op; the XLA runtime
/// (which FusionStitching rides on, §6) pays more per launched cluster.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Host scheduling + launch cost per kernel, µs (static graphs).
    pub host_per_kernel_us: f64,
    /// Host scheduling cost per kernel for recurrent (while_loop) models,
    /// µs — loop-condition evaluation and TensorArray glue included.
    pub host_per_kernel_recurrent_us: f64,
    /// Extra host cost per memcpy call, µs.
    pub host_per_memcpy_us: f64,
    /// Per-loop-step host glue on recurrent models (while_loop condition
    /// evaluation, TensorArray bookkeeping), µs — charged per memcpy
    /// activity (≈ one TensorArray write per step) on recurrent graphs.
    /// No fusion technique removes this, which is why the paper's CPU
    /// savings on DIEN/ASR/CRNN are large but bounded (§7.3).
    pub loop_glue_us: f64,
    /// Fixed per-iteration host cost, µs.
    pub host_base_us: f64,
    /// Efficiency factor for library GEMM/conv calls (fraction of peak).
    pub library_efficiency: f64,
    /// Floor for a library call, µs.
    pub library_floor_us: f64,
    /// Floor for a memcpy call, µs.
    pub memcpy_floor_us: f64,
}

impl SimConfig {
    /// Stock TensorFlow executor.
    pub fn tensorflow() -> Self {
        SimConfig {
            host_per_kernel_us: 2.0,
            host_per_kernel_recurrent_us: 6.5,
            host_per_memcpy_us: 4.0,
            loop_glue_us: 12.0,
            host_base_us: 150.0,
            library_efficiency: 0.62,
            library_floor_us: 4.5,
            memcpy_floor_us: 3.0,
        }
    }

    /// XLA runtime (also hosts FusionStitching, §6): heavier per-cluster
    /// dispatch, same library path.
    pub fn xla_runtime() -> Self {
        SimConfig {
            host_per_kernel_us: 4.5,
            host_per_kernel_recurrent_us: 11.0,
            host_per_memcpy_us: 4.5,
            loop_glue_us: 12.0,
            host_base_us: 250.0,
            library_efficiency: 0.62,
            library_floor_us: 4.5,
            memcpy_floor_us: 3.0,
        }
    }

    /// Host-runtime dispatch charge for one kernel — the ONE copy of
    /// the per-kernel host accounting, shared by [`Simulator::run`] and
    /// the calibration ground truth ([`crate::codegen::calibrate`]).
    /// `host_base_us` is charged once per iteration, not here.
    pub fn host_charge_us(&self, class: &KernelClass, loop_kind: LoopKind) -> f64 {
        match class {
            KernelClass::Memcpy => {
                let glue = if loop_kind != LoopKind::None { self.loop_glue_us } else { 0.0 };
                self.host_per_memcpy_us + glue
            }
            _ if loop_kind == LoopKind::DynamicLoop => self.host_per_kernel_recurrent_us,
            _ => self.host_per_kernel_us,
        }
    }
}

/// Per-iteration execution breakdown — one Table 2 row.
#[derive(Debug, Clone, Default)]
pub struct Breakdown {
    pub cpu_ms: f64,
    pub math_ms: f64,
    pub mem_ms: f64,
    pub cpy_ms: f64,
    pub math_calls: usize,
    pub mem_calls: usize,
    pub cpy_calls: usize,
    /// Total global-memory traffic of memory-intensive kernels (bytes) —
    /// the §7.3 CRNN "667.6 MB → 225.8 MB" style metric.
    pub mem_traffic_bytes: usize,
}

impl Breakdown {
    /// End-to-end iteration time. Table 2's E2E column is the sum of the
    /// four components (the paper profiles them separately; verified:
    /// every row sums exactly).
    pub fn e2e_ms(&self) -> f64 {
        self.cpu_ms + self.math_ms + self.mem_ms + self.cpy_ms
    }

    /// Total kernel + memcpy calls (the `#` totals column).
    pub fn total_calls(&self) -> usize {
        self.math_calls + self.mem_calls + self.cpy_calls
    }
}

/// The simulator: a device spec + host-runtime config.
#[derive(Debug, Clone)]
pub struct Simulator {
    pub device: DeviceSpec,
    pub config: SimConfig,
}

impl Simulator {
    pub fn new(device: DeviceSpec, config: SimConfig) -> Self {
        Simulator { device, config }
    }

    /// Device time of one kernel in µs.
    pub fn kernel_time_us(&self, k: &KernelSpec) -> f64 {
        match k.class {
            KernelClass::Memcpy => {
                // bytes/GBps → µs·1e-3
                let t = k.bytes_read as f64 / (self.device.hbm_gbps * 1e3);
                (t / 1e0).max(self.config.memcpy_floor_us)
            }
            KernelClass::ComputeIntensive { flops } => {
                let t_us =
                    flops as f64 / (self.device.fp32_tflops * self.config.library_efficiency * 1e6);
                t_us.max(self.config.library_floor_us)
            }
            KernelClass::MemoryIntensive => {
                let occ = self.device.occupancy(
                    k.launch.block_threads,
                    k.regs_per_thread,
                    k.shmem_per_block,
                );
                if occ == 0.0 {
                    // Unlaunchable kernels are given an effectively
                    // infinite cost so tuners never pick them.
                    return 1e12;
                }
                // Memory side: bytes / effective bandwidth.
                let bw = self.device.effective_bandwidth_gbps(occ); // GB/s
                // bytes / (GB/s) = ns → /1e3 µs
                let t_mem_us = k.total_bytes() as f64 / (bw * 1e3);
                // ALU side: Eq. 1 wave model.
                let n_warp = k.launch.total_warps(self.device.warp_size);
                let slots = (self.device.total_warp_slots() as f64 * occ).max(1.0);
                let n_wave = (n_warp as f64 / slots).ceil().max(1.0);
                let l_warp_cycles = k.instrs_per_thread * k.avg_cpi;
                let t_alu_us = n_wave * l_warp_cycles / (self.device.clock_ghz * 1e3);
                t_mem_us.max(t_alu_us).max(self.device.kernel_floor_us)
            }
        }
    }

    /// Execute a kernel sequence (one iteration); `loop_kind` selects
    /// the host-overhead regime: dynamic while_loops pay per-iteration
    /// dispatch on every kernel; any recurrence pays per-step loop glue
    /// on its TensorArray copies.
    pub fn run(&self, kernels: &[KernelSpec], loop_kind: LoopKind) -> Breakdown {
        let mut b = Breakdown::default();
        let mut host_us = self.config.host_base_us;
        for k in kernels {
            let t_us = self.kernel_time_us(k);
            host_us += self.config.host_charge_us(&k.class, loop_kind);
            match k.class {
                KernelClass::Memcpy => {
                    b.cpy_ms += t_us / 1e3;
                    b.cpy_calls += 1;
                }
                KernelClass::ComputeIntensive { .. } => {
                    b.math_ms += t_us / 1e3;
                    b.math_calls += 1;
                }
                KernelClass::MemoryIntensive => {
                    b.mem_ms += t_us / 1e3;
                    b.mem_calls += 1;
                    b.mem_traffic_bytes += k.total_bytes();
                }
            }
        }
        b.cpu_ms = host_us / 1e3;
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::LaunchDims;

    fn mem_kernel(bytes: usize, threads: usize) -> KernelSpec {
        KernelSpec {
            name: "k".into(),
            class: KernelClass::MemoryIntensive,
            launch: LaunchDims {
                grid_blocks: (threads / 256).max(1),
                block_threads: 256,
            },
            regs_per_thread: 16,
            shmem_per_block: 0,
            bytes_read: bytes / 2,
            bytes_written: bytes / 2,
            instrs_per_thread: 8.0,
            avg_cpi: 4.0,
        }
    }

    #[test]
    fn large_kernels_are_bandwidth_bound() {
        let sim = Simulator::new(DeviceSpec::v100(), SimConfig::tensorflow());
        // 38 MB of traffic at ~740 GB/s ≈ 51 µs.
        let k = mem_kernel(38 << 20, 1 << 20);
        let t = sim.kernel_time_us(&k);
        assert!((40.0..75.0).contains(&t), "t={t}µs");
    }

    #[test]
    fn tiny_kernels_hit_the_floor() {
        let sim = Simulator::new(DeviceSpec::v100(), SimConfig::tensorflow());
        let k = mem_kernel(64 << 10, 4096);
        let t = sim.kernel_time_us(&k);
        assert_eq!(t, sim.device.kernel_floor_us);
    }

    #[test]
    fn recompute_heavy_kernels_become_alu_bound() {
        let sim = Simulator::new(DeviceSpec::v100(), SimConfig::tensorflow());
        let mut k = mem_kernel(1 << 20, 1 << 20);
        let t_before = sim.kernel_time_us(&k);
        // Blow up per-thread instructions (recompute of a 768-wide
        // reduction under thread composition).
        k.instrs_per_thread = 768.0 * 2.0;
        k.avg_cpi = 4.0;
        let t_after = sim.kernel_time_us(&k);
        assert!(t_after > t_before * 2.0, "{t_before} → {t_after}");
    }

    #[test]
    fn unlaunchable_kernel_is_poisoned() {
        let sim = Simulator::new(DeviceSpec::v100(), SimConfig::tensorflow());
        let mut k = mem_kernel(1 << 20, 4096);
        k.shmem_per_block = 1 << 20; // 1 MB: cannot launch
        assert!(sim.kernel_time_us(&k) > 1e9);
    }

    #[test]
    fn breakdown_components_and_e2e_sum() {
        let sim = Simulator::new(DeviceSpec::v100(), SimConfig::tensorflow());
        let kernels = vec![
            mem_kernel(38 << 20, 1 << 20),
            KernelSpec::library("mm", 4_800_000_000, 10 << 20),
            KernelSpec::memcpy("cpy", 1 << 20),
        ];
        let b = sim.run(&kernels, LoopKind::None);
        assert_eq!(b.mem_calls, 1);
        assert_eq!(b.math_calls, 1);
        assert_eq!(b.cpy_calls, 1);
        let sum = b.cpu_ms + b.math_ms + b.mem_ms + b.cpy_ms;
        assert!((b.e2e_ms() - sum).abs() < 1e-12);
        assert!(b.cpu_ms > 0.0);
    }

    #[test]
    fn recurrent_host_overhead_dominates_many_tiny_kernels() {
        let sim = Simulator::new(DeviceSpec::v100(), SimConfig::tensorflow());
        let kernels: Vec<KernelSpec> = (0..10_000).map(|_| mem_kernel(64 << 10, 4096)).collect();
        let b = sim.run(&kernels, LoopKind::DynamicLoop);
        // 10k kernels × 6.5 µs ≈ 65 ms of host time vs 30 ms device —
        // the DIEN-shaped pathology of §2.2.
        assert!(b.cpu_ms > b.mem_ms, "cpu {} vs mem {}", b.cpu_ms, b.mem_ms);
    }

    #[test]
    fn library_time_scales_with_flops() {
        let sim = Simulator::new(DeviceSpec::v100(), SimConfig::tensorflow());
        let small = KernelSpec::library("s", 10_000_000, 1 << 20);
        let big = KernelSpec::library("b", 4_800_000_000, 10 << 20);
        assert!(sim.kernel_time_us(&big) > sim.kernel_time_us(&small) * 50.0);
        // BERT-sized projection ≈ 400–600 µs (Table 2: 41.69 ms / 98).
        let t = sim.kernel_time_us(&big);
        assert!((300.0..800.0).contains(&t), "t={t}");
    }
}
