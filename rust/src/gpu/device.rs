//! Device specifications for the GPUs the paper evaluates on.
//!
//! Numbers follow the public architecture documents and the Volta/Turing
//! microbenchmark papers the paper cites ([21] Jia et al. 2019 for T4,
//! [22] Jia et al. 2018 for V100).

/// Static resources and throughput limits of one GPU.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// Streaming multiprocessor count.
    pub num_sms: usize,
    /// Max resident warps per SM (occupancy denominator).
    pub max_warps_per_sm: usize,
    /// Threads per warp.
    pub warp_size: usize,
    /// Max threads per block.
    pub max_threads_per_block: usize,
    /// 32-bit registers per SM.
    pub regs_per_sm: usize,
    /// Shared memory per SM in bytes.
    pub shmem_per_sm: usize,
    /// Max shared memory a single block may claim.
    pub shmem_per_block: usize,
    /// Core clock in GHz.
    pub clock_ghz: f64,
    /// HBM/GDDR bandwidth in GB/s (achievable, not theoretical peak).
    pub hbm_gbps: f64,
    /// FP32 peak in TFLOP/s (for the compute-intensive library model).
    pub fp32_tflops: f64,
    /// Minimum wall-clock of any kernel, µs (launch/drain latency floor —
    /// why thousands of tiny kernels cost milliseconds even when their
    /// memory traffic is trivial; the effect Table 2's DIEN rows show).
    pub kernel_floor_us: f64,
}

impl DeviceSpec {
    /// NVIDIA V100 (SXM2 16 GB) — the paper's main evaluation device.
    pub fn v100() -> Self {
        DeviceSpec {
            name: "V100",
            num_sms: 80,
            max_warps_per_sm: 64,
            warp_size: 32,
            max_threads_per_block: 1024,
            regs_per_sm: 65_536,
            shmem_per_sm: 96 * 1024,
            shmem_per_block: 48 * 1024,
            clock_ghz: 1.53,
            hbm_gbps: 900.0 * 0.82, // ~740 GB/s achievable (Jia et al.)
            fp32_tflops: 15.7,
            kernel_floor_us: 3.0,
        }
    }

    /// NVIDIA T4 — the paper's secondary inference device (§7.2 "similar
    /// speedup on T4").
    pub fn t4() -> Self {
        DeviceSpec {
            name: "T4",
            num_sms: 40,
            max_warps_per_sm: 32,
            warp_size: 32,
            max_threads_per_block: 1024,
            regs_per_sm: 65_536,
            shmem_per_sm: 64 * 1024,
            shmem_per_block: 48 * 1024,
            clock_ghz: 1.59,
            hbm_gbps: 320.0 * 0.82,
            fp32_tflops: 8.1,
            kernel_floor_us: 3.0,
        }
    }

    /// NVIDIA A100 (SXM4 40 GB) — not in the paper's evaluation, kept
    /// as the forward-portability check: the fusion decisions depend
    /// only on the machine model's *shape*, so the orderings of
    /// Figure 7 must survive an architecture generation (tested in
    /// `integration.rs`).
    pub fn a100() -> Self {
        DeviceSpec {
            name: "A100",
            num_sms: 108,
            max_warps_per_sm: 64,
            warp_size: 32,
            max_threads_per_block: 1024,
            regs_per_sm: 65_536,
            shmem_per_sm: 164 * 1024,
            shmem_per_block: 48 * 1024,
            clock_ghz: 1.41,
            hbm_gbps: 1555.0 * 0.85, // HBM2e, ~1.3 TB/s achievable
            fp32_tflops: 19.5,
            kernel_floor_us: 2.5,
        }
    }

    /// Total resident-warp capacity of the device.
    pub fn total_warp_slots(&self) -> usize {
        self.num_sms * self.max_warps_per_sm
    }

    /// Occupancy for a kernel using `threads_per_block` threads,
    /// `regs_per_thread` registers and `shmem_per_block` bytes of shared
    /// memory: the fraction of max resident warps each SM can keep in
    /// flight (§4.3's `Occupancy` term).
    pub fn occupancy(
        &self,
        threads_per_block: usize,
        regs_per_thread: usize,
        shmem_per_block: usize,
    ) -> f64 {
        if threads_per_block == 0 {
            return 0.0;
        }
        // Hardware cap: a single block may not claim more shared memory
        // than `shmem_per_block` (48 KB on every spec here), even when
        // the SM's total (`shmem_per_sm`) could fit it. Without this
        // check a 64 KB request on V100 (96 KB/SM) reported occupancy
        // > 0 for a kernel the driver would refuse to launch.
        if shmem_per_block > self.shmem_per_block {
            return 0.0;
        }
        let threads_per_block = threads_per_block.min(self.max_threads_per_block);
        // Blocks per SM limited by each resource.
        let by_threads = (self.max_warps_per_sm * self.warp_size) / threads_per_block;
        let by_regs = if regs_per_thread == 0 {
            usize::MAX
        } else {
            self.regs_per_sm / (regs_per_thread * threads_per_block)
        };
        let by_shmem = if shmem_per_block == 0 {
            usize::MAX
        } else {
            self.shmem_per_sm / shmem_per_block
        };
        let blocks = by_threads.min(by_regs).min(by_shmem);
        if blocks == 0 {
            return 0.0; // kernel cannot launch (over-budget block)
        }
        let warps_per_block = threads_per_block.div_ceil(self.warp_size);
        let resident = (blocks * warps_per_block).min(self.max_warps_per_sm);
        resident as f64 / self.max_warps_per_sm as f64
    }

    /// Effective memory bandwidth at a given occupancy: a kernel needs
    /// enough warps in flight to cover HBM latency; below the knee
    /// occupancy, bandwidth scales roughly linearly (the
    /// memory-level-parallelism knee reported by the microbenchmark
    /// papers). The default knee is [`CostParams::default`]'s 0.4; the
    /// calibration loop may thread a corrected value through
    /// [`Self::effective_bandwidth_at`].
    pub fn effective_bandwidth_gbps(&self, occupancy: f64) -> f64 {
        self.effective_bandwidth_at(occupancy, super::CostParams::default().bandwidth_knee)
    }

    /// [`Self::effective_bandwidth_gbps`] with an explicit knee — the
    /// cost-model entry point ([`super::CostParams::bandwidth_knee`]).
    pub fn effective_bandwidth_at(&self, occupancy: f64, knee: f64) -> f64 {
        let eff = (occupancy / knee.max(1e-6)).min(1.0).max(0.05);
        self.hbm_gbps * eff
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v100_full_occupancy_with_light_kernel() {
        let d = DeviceSpec::v100();
        // 256 threads, 16 regs, no shmem: classic fully-occupant config.
        let occ = d.occupancy(256, 16, 0);
        assert!((occ - 1.0).abs() < 1e-9, "occ={occ}");
    }

    #[test]
    fn registers_limit_occupancy() {
        let d = DeviceSpec::v100();
        // 256 threads × 128 regs = 32768 regs/block; 65536/32768 = 2
        // blocks → 16 warps resident of 64.
        let occ = d.occupancy(256, 128, 0);
        assert!((occ - 0.25).abs() < 1e-9, "occ={occ}");
    }

    #[test]
    fn shared_memory_limits_occupancy() {
        let d = DeviceSpec::v100();
        // 48KB/block → 2 blocks/SM on 96KB: 256 threads = 8 warps × 2 =
        // 16 of 64 → 0.25.
        let occ = d.occupancy(256, 16, 48 * 1024);
        assert!((occ - 0.25).abs() < 1e-9, "occ={occ}");
    }

    #[test]
    fn oversized_block_cannot_launch() {
        let d = DeviceSpec::v100();
        let occ = d.occupancy(256, 16, 200 * 1024);
        assert_eq!(occ, 0.0);
    }

    #[test]
    fn per_block_shmem_cap_is_enforced() {
        // Regression: 64 KB/block on V100 fits the 96 KB SM (the old
        // `shmem_per_sm`-only check reported occupancy > 0) but exceeds
        // the 48 KB per-block hardware cap — the kernel cannot launch.
        let d = DeviceSpec::v100();
        assert_eq!(d.occupancy(256, 16, 64 * 1024), 0.0);
        // One byte over the cap is already unlaunchable...
        assert_eq!(d.occupancy(256, 16, 48 * 1024 + 1), 0.0);
        // ...while exactly at the cap still launches (2 blocks on 96 KB).
        assert!(d.occupancy(256, 16, 48 * 1024) > 0.0);
        // Same cap on T4 (64 KB SM, 48 KB/block).
        assert_eq!(DeviceSpec::t4().occupancy(256, 16, 56 * 1024), 0.0);
    }

    #[test]
    fn bandwidth_knee_is_parameterized() {
        let d = DeviceSpec::v100();
        // Default delegates to the CostParams knee of 0.4.
        assert_eq!(d.effective_bandwidth_gbps(0.2), d.effective_bandwidth_at(0.2, 0.4));
        // A lower knee saturates earlier.
        assert!(d.effective_bandwidth_at(0.2, 0.2) > d.effective_bandwidth_at(0.2, 0.4));
        assert_eq!(d.effective_bandwidth_at(0.2, 0.2), d.hbm_gbps);
    }

    #[test]
    fn bandwidth_saturates_at_high_occupancy() {
        let d = DeviceSpec::v100();
        assert!(d.effective_bandwidth_gbps(1.0) > d.effective_bandwidth_gbps(0.1));
        assert_eq!(
            d.effective_bandwidth_gbps(0.5),
            d.effective_bandwidth_gbps(1.0)
        );
    }

    #[test]
    fn t4_is_smaller_than_v100() {
        let (v, t) = (DeviceSpec::v100(), DeviceSpec::t4());
        assert!(t.num_sms < v.num_sms);
        assert!(t.hbm_gbps < v.hbm_gbps);
        assert!(t.total_warp_slots() < v.total_warp_slots());
    }
}
