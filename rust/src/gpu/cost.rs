//! Tunable cost-model parameters shared by the delta evaluator (§5.4)
//! and the latency evaluator (§4.3).
//!
//! Both cost models used to hard-code their constants (`7.0` µs launch
//! overhead in `explorer::delta`, `CPI = 4.0` and the shuffle/shared-
//! memory instruction equivalents in `codegen::latency`, the 0.4
//! occupancy knee of the bandwidth model in `gpu::device`). Fusion
//! decisions are only as good as these numbers, and the earlier
//! FusionStitching paper frames scheme tuning explicitly as cost-model
//! search — so the constants live here as one value-typed parameter
//! block that can be threaded through exploration, tuning and lowering,
//! and *corrected online* from simulator ground truth
//! ([`crate::codegen::calibrate`]).

/// The knobs of both cost models. `Default` reproduces the historical
/// hard-coded constants exactly; the calibration loop fits per-device-
/// class corrections (`launch_overhead_us`, `time_scale`,
/// `iter_overhead_us`) from (predicted, measured) pairs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostParams {
    /// Host + device cost of one extra kernel launch, µs
    /// (`T_reduced_calls`' fixed per-call constant; calibrated to the
    /// runtime's real per-kernel dispatch charge).
    pub launch_overhead_us: f64,
    /// Base ALU cycles per instruction-equivalent (Eq. 1's CPI).
    pub cpi: f64,
    /// Extra instruction-equivalents per warp-shuffle exchange.
    pub shuffle_cost: f64,
    /// Extra instruction-equivalents per shared-memory access.
    pub shmem_access_cost: f64,
    /// Occupancy at which effective memory bandwidth saturates (the
    /// memory-level-parallelism knee of the microbenchmark papers).
    pub bandwidth_knee: f64,
    /// Calibrated multiplicative correction on modeled kernel device
    /// time (1.0 = trust the analytic model).
    pub time_scale: f64,
    /// Calibrated fixed per-iteration overhead, µs — the host-runtime
    /// base cost the per-kernel model cannot see. Used only when
    /// predicting whole-iteration times (drift detection), never inside
    /// per-kernel tuning.
    pub iter_overhead_us: f64,
    /// Multiplier on the saved intermediate round-trip traffic when a
    /// GEMM boundary is absorbed (the epilogue/prologue no longer writes
    /// + re-reads the anchor-side tensor through HBM). 1.0 = trust the
    /// bandwidth model.
    pub absorb_traffic_scale: f64,
    /// Occupancy-pressure penalty of an absorbed boundary, µs at fully
    /// crushed occupancy. The `GemmEpilogue` hand-off stages a row tile
    /// of the boundary tensor in shared memory; the penalty charged is
    /// this constant scaled by the fraction of anchor-kernel occupancy
    /// that staging buffer costs.
    pub absorb_occupancy_penalty_us: f64,
    /// Soft footprint-pressure penalty, µs per unit of staged-footprint
    /// excess over the knee. The delta evaluator charges
    /// `footprint_pressure_us × max(0, staged_sum/cap − footprint_knee)`
    /// on a pattern's fused time: patterns whose summed staging requests
    /// crowd the per-block budget lose occupancy headroom the max-
    /// single-request occupancy shortcut cannot see. Calibration refits
    /// this per device class from above-knee residuals.
    pub footprint_pressure_us: f64,
    /// Fraction of the per-block shared-memory cap below which staged
    /// footprint is free (the pressure term's knee). 0.5 = pressure only
    /// starts past 24 KB of the 48 KB cap, which keeps every tier-1
    /// default-shape pattern unpenalized.
    pub footprint_knee: f64,
}

impl Default for CostParams {
    fn default() -> Self {
        CostParams {
            launch_overhead_us: 7.0, // ~launch floor + host dispatch
            cpi: 4.0,
            shuffle_cost: 8.0,
            shmem_access_cost: 6.0,
            bandwidth_knee: 0.4,
            time_scale: 1.0,
            iter_overhead_us: 0.0,
            absorb_traffic_scale: 1.0,
            absorb_occupancy_penalty_us: 12.0,
            footprint_pressure_us: 4.0,
            footprint_knee: 0.5,
        }
    }
}

impl CostParams {
    /// Soft footprint-pressure charge, µs, for `staged_bytes` of summed
    /// staging requests against a `cap_bytes` per-block budget (the
    /// delta evaluator's pricing of intermediate-buffer crowding).
    pub fn footprint_pressure_charge_us(&self, staged_bytes: usize, cap_bytes: usize) -> f64 {
        if cap_bytes == 0 {
            return 0.0;
        }
        let frac = staged_bytes as f64 / cap_bytes as f64;
        self.footprint_pressure_us * (frac - self.footprint_knee).max(0.0)
    }
}

impl CostParams {
    /// Warp-cooperative reduction combine per row (5 shuffle stages).
    pub fn warp_combine(&self) -> f64 {
        5.0 * self.shuffle_cost
    }

    /// Block-cooperative reduction combine per row (warp stage + smem
    /// stage + barrier).
    pub fn block_combine(&self) -> f64 {
        self.warp_combine() + 32.0 + 30.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_historical_constants() {
        let p = CostParams::default();
        assert_eq!(p.launch_overhead_us, 7.0);
        assert_eq!(p.cpi, 4.0);
        assert_eq!(p.shuffle_cost, 8.0);
        assert_eq!(p.shmem_access_cost, 6.0);
        assert_eq!(p.bandwidth_knee, 0.4);
        assert_eq!(p.time_scale, 1.0);
        assert_eq!(p.iter_overhead_us, 0.0);
        assert_eq!(p.footprint_pressure_us, 4.0);
        assert_eq!(p.footprint_knee, 0.5);
        assert_eq!(p.warp_combine(), 40.0);
        assert_eq!(p.block_combine(), 102.0);
    }

    #[test]
    fn footprint_pressure_is_zero_below_knee_and_linear_above() {
        let p = CostParams::default();
        let cap = 48 * 1024;
        // At and below the knee (24 KB of 48 KB): free.
        assert_eq!(p.footprint_pressure_charge_us(0, cap), 0.0);
        assert_eq!(p.footprint_pressure_charge_us(cap / 2, cap), 0.0);
        // At the full cap: half a unit of excess → pressure_us × 0.5.
        assert!((p.footprint_pressure_charge_us(cap, cap) - 2.0).abs() < 1e-12);
        // Past the cap keeps growing linearly (the unpruned ablation
        // scores such patterns; the hard filter normally removes them).
        assert!(
            p.footprint_pressure_charge_us(2 * cap, cap)
                > p.footprint_pressure_charge_us(cap, cap)
        );
        // Degenerate cap: no charge, no division by zero.
        assert_eq!(p.footprint_pressure_charge_us(1024, 0), 0.0);
    }

    /// Golden pin of every `CostParams::default()` field. The exhaustive
    /// destructuring makes adding a field a compile error here, so new
    /// cost terms (like the absorption pair) can never silently shift
    /// the XLA/TF personality fallbacks or the calibrated-fit base.
    #[test]
    fn golden_default_pins_every_field() {
        let CostParams {
            launch_overhead_us,
            cpi,
            shuffle_cost,
            shmem_access_cost,
            bandwidth_knee,
            time_scale,
            iter_overhead_us,
            absorb_traffic_scale,
            absorb_occupancy_penalty_us,
            footprint_pressure_us,
            footprint_knee,
        } = CostParams::default();
        assert_eq!(launch_overhead_us, 7.0);
        assert_eq!(cpi, 4.0);
        assert_eq!(shuffle_cost, 8.0);
        assert_eq!(shmem_access_cost, 6.0);
        assert_eq!(bandwidth_knee, 0.4);
        assert_eq!(time_scale, 1.0);
        assert_eq!(iter_overhead_us, 0.0);
        assert_eq!(absorb_traffic_scale, 1.0);
        assert_eq!(absorb_occupancy_penalty_us, 12.0);
        assert_eq!(footprint_pressure_us, 4.0);
        assert_eq!(footprint_knee, 0.5);
    }
}
