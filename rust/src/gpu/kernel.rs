//! The executable unit the simulator times: one launched GPU kernel.
//!
//! `codegen::emit` lowers each fusion pattern to a [`KernelSpec`];
//! the TF/XLA baselines produce the same structure through their own
//! (more restricted) emission paths, so all three techniques are timed
//! by one mechanism.

/// Grid/block launch configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaunchDims {
    pub grid_blocks: usize,
    pub block_threads: usize,
}

impl LaunchDims {
    /// Total threads across the launch.
    pub fn total_threads(&self) -> usize {
        self.grid_blocks * self.block_threads
    }

    /// Total warps across the launch (§4.3's `N_warp`).
    pub fn total_warps(&self, warp_size: usize) -> usize {
        self.grid_blocks * self.block_threads.div_ceil(warp_size)
    }
}

/// What kind of device activity this kernel represents — maps 1:1 onto
/// the columns of Table 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelClass {
    /// Generated (fused or single-op) memory-intensive kernel → `Mem`.
    MemoryIntensive,
    /// GEMM/conv library call → `Math`. Carries its FLOP count.
    ComputeIntensive { flops: u64 },
    /// cudaMemcpy/Memset activity → `Cpy`.
    Memcpy,
}

/// A fully-specified kernel launch.
#[derive(Debug, Clone)]
pub struct KernelSpec {
    /// Diagnostic name, e.g. `fusion.3` or `enc0/attn/scores`.
    pub name: String,
    pub class: KernelClass,
    pub launch: LaunchDims,
    /// Estimated registers per thread (lifetime analysis in codegen;
    /// fixed defaults in the baselines).
    pub regs_per_thread: usize,
    /// Shared memory bytes per block (after the §4.4 reuse optimization).
    pub shmem_per_block: usize,
    /// Global-memory bytes read (includes re-reads caused by
    /// recomputation duplication).
    pub bytes_read: usize,
    /// Global-memory bytes written.
    pub bytes_written: usize,
    /// Dynamic instructions executed per thread (includes recompute
    /// multipliers — the §2.1 cost XLA pays for thread composition of
    /// expensive producers).
    pub instrs_per_thread: f64,
    /// Average CPI across the instruction mix (from the microbenchmark
    /// tables; codegen computes a weighted value).
    pub avg_cpi: f64,
}

impl KernelSpec {
    /// Convenience constructor for a memcpy activity of `bytes`.
    pub fn memcpy(name: impl Into<String>, bytes: usize) -> Self {
        KernelSpec {
            name: name.into(),
            class: KernelClass::Memcpy,
            launch: LaunchDims { grid_blocks: 1, block_threads: 1 },
            regs_per_thread: 0,
            shmem_per_block: 0,
            bytes_read: bytes,
            bytes_written: bytes,
            instrs_per_thread: 0.0,
            avg_cpi: 1.0,
        }
    }

    /// Convenience constructor for a library GEMM/conv call.
    pub fn library(name: impl Into<String>, flops: u64, bytes: usize) -> Self {
        KernelSpec {
            name: name.into(),
            class: KernelClass::ComputeIntensive { flops },
            launch: LaunchDims { grid_blocks: 0, block_threads: 0 },
            regs_per_thread: 0,
            shmem_per_block: 0,
            bytes_read: bytes,
            bytes_written: bytes / 3,
            instrs_per_thread: 0.0,
            avg_cpi: 1.0,
        }
    }

    /// Total global traffic in bytes.
    pub fn total_bytes(&self) -> usize {
        self.bytes_read + self.bytes_written
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn launch_dims_totals() {
        let l = LaunchDims { grid_blocks: 10, block_threads: 256 };
        assert_eq!(l.total_threads(), 2560);
        assert_eq!(l.total_warps(32), 80);
        // Non-multiple block size rounds warps up.
        let l2 = LaunchDims { grid_blocks: 2, block_threads: 48 };
        assert_eq!(l2.total_warps(32), 4);
    }

    #[test]
    fn memcpy_constructor() {
        let k = KernelSpec::memcpy("cpy", 1024);
        assert_eq!(k.class, KernelClass::Memcpy);
        assert_eq!(k.total_bytes(), 2048);
    }

    #[test]
    fn library_constructor_carries_flops() {
        let k = KernelSpec::library("mm", 1_000_000, 4096);
        match k.class {
            KernelClass::ComputeIntensive { flops } => assert_eq!(flops, 1_000_000),
            _ => panic!("wrong class"),
        }
    }
}
