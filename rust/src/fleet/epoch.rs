//! Epoch/RCU-style publication cell: lock-free reads, copy-on-write
//! publication.
//!
//! The fleet's plan store and published-latency table are read on every
//! serving iteration by up to a thousand serve threads, but written only
//! when a compile worker publishes a plan — a classic read-mostly
//! workload where even an uncontended `Mutex` acquisition per read shows
//! up in the flight recorder at cluster scale. `EpochCell` replaces the
//! mutex with an epoch-validated snapshot pointer:
//!
//! - **Readers** announce themselves in a bounded slot array (one CAS),
//!   validate that no publication raced the announcement, then
//!   dereference the current snapshot with no lock held. The common case
//!   is one CAS + two loads + one store per read.
//! - **Writers** serialize on a poison-recovering writer mutex, clone
//!   the current snapshot, apply the mutation closure, swap the pointer
//!   in one atomic store, and bump the epoch. The displaced snapshot is
//!   *retired*, not freed: it is reclaimed only once every announced
//!   reader stamp is newer than its retirement tag (readers drain).
//!
//! Safety argument (all operations are `SeqCst`, so a single total
//! order exists): a reader stamps its slot with epoch `e` *before*
//! validating `epoch == e`, and a writer bumps the epoch *before*
//! scanning reader slots. If the reader's validation succeeds, every
//! publication that could retire the pointer it is about to load bumps
//! the epoch after that validation, hence scans the slots after the
//! stamp is visible, hence observes stamp `e <= tag` and defers the
//! free. If validation fails, the reader backs out without having
//! dereferenced anything. When no free slot is available or validation
//! keeps failing, readers fall back to holding the writer mutex, under
//! which no publication (and therefore no reclamation) can run.

use std::cell::Cell;
use std::sync::atomic::{AtomicPtr, AtomicU64, AtomicUsize, Ordering::SeqCst};
use std::sync::Mutex;

use crate::util::sync::lock_recover;

/// Default reader-slot capacity. Sized for the cluster-scale fleet: a
/// 1000-device wall-clock run pins at most one slot per serve thread
/// plus a handful of dispatcher/compile threads; overflow readers are
/// still correct, they just take the writer-mutex slow path.
const DEFAULT_SLOTS: usize = 1024;

/// Fast-path retries before a reader gives up and takes the slow path.
const PIN_RETRIES: usize = 8;

static NEXT_HINT: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Each thread probes from its own preferred slot so steady-state
    /// reads claim the same uncontended slot every time.
    static SLOT_HINT: Cell<usize> = Cell::new(NEXT_HINT.fetch_add(1, SeqCst));
}

pub struct EpochCell<T: Clone> {
    /// The currently published snapshot. Never null.
    current: AtomicPtr<T>,
    /// Monotonic publication epoch. Starts at 1 so a stamp of 0 always
    /// means "slot quiescent".
    epoch: AtomicU64,
    /// Reader announcement slots: 0 = free, otherwise the epoch the
    /// occupying reader validated against.
    slots: Box<[AtomicU64]>,
    /// Serializes publications (and backs the reader slow path).
    writer: Mutex<()>,
    /// Displaced snapshots awaiting reader drain: (retirement tag, ptr).
    retired: Mutex<Vec<(u64, *mut T)>>,
}

// The raw pointers in `current`/`retired` are owned by the cell and
// only dereferenced under the epoch protocol above; they represent a
// `T` that itself crosses threads, hence the `Send + Sync` bounds.
unsafe impl<T: Clone + Send + Sync> Send for EpochCell<T> {}
unsafe impl<T: Clone + Send + Sync> Sync for EpochCell<T> {}

/// Releases a reader slot even if the read closure panics.
struct Unpin<'a>(&'a AtomicU64);

impl Drop for Unpin<'_> {
    fn drop(&mut self) {
        self.0.store(0, SeqCst);
    }
}

impl<T: Clone> EpochCell<T> {
    pub fn new(value: T) -> Self {
        Self::with_slots(value, DEFAULT_SLOTS)
    }

    /// Build a cell with an explicit reader-slot capacity (tests use a
    /// tiny capacity to force the slow path; correctness never depends
    /// on the count).
    pub fn with_slots(value: T, slots: usize) -> Self {
        assert!(slots >= 1, "epoch cell needs at least one reader slot");
        let slots = (0..slots)
            .map(|_| AtomicU64::new(0))
            .collect::<Vec<_>>()
            .into_boxed_slice();
        EpochCell {
            current: AtomicPtr::new(Box::into_raw(Box::new(value))),
            epoch: AtomicU64::new(1),
            slots,
            writer: Mutex::new(()),
            retired: Mutex::new(Vec::new()),
        }
    }

    /// Claim a reader slot stamped with the current epoch, validating
    /// that no publication raced the stamp. `None` means "retry or take
    /// the slow path" — never an unsafe success.
    fn pin(&self, hint: usize) -> Option<usize> {
        let n = self.slots.len();
        for probe in 0..n {
            let i = (hint + probe) % n;
            let e = self.epoch.load(SeqCst);
            if self.slots[i].compare_exchange(0, e, SeqCst, SeqCst).is_ok() {
                if self.epoch.load(SeqCst) == e {
                    return Some(i);
                }
                // A publication bumped the epoch between stamp and
                // validation; back out without dereferencing.
                self.slots[i].store(0, SeqCst);
                return None;
            }
        }
        None
    }

    /// Read the current snapshot without taking any lock on the fast
    /// path. The closure must not call back into this cell's `publish`
    /// (it would deadlock only on the slow path, so don't rely on it).
    pub fn read<R>(&self, f: impl FnOnce(&T) -> R) -> R {
        let hint = SLOT_HINT.with(Cell::get) % self.slots.len();
        for _ in 0..PIN_RETRIES {
            if let Some(slot) = self.pin(hint) {
                let _unpin = Unpin(&self.slots[slot]);
                let p = self.current.load(SeqCst);
                // Safe: our validated stamp keeps every retirement tag
                // >= stamp alive, and `current` can only be retired
                // with a tag >= the stamp we validated against.
                return f(unsafe { &*p });
            }
        }
        // Slow path: no free slot (or heavy publication churn). Holding
        // the writer mutex excludes publication and reclamation.
        let _writer = lock_recover(&self.writer);
        let p = self.current.load(SeqCst);
        f(unsafe { &*p })
    }

    /// Clone of the current snapshot.
    pub fn snapshot(&self) -> T {
        self.read(T::clone)
    }

    /// Publish a new snapshot: clone the current one, apply `f`, swap
    /// it in atomically, and retire the displaced snapshot until all
    /// readers that might hold it have drained. Publications serialize
    /// on a poison-recovering writer mutex, so a panicking mutation
    /// closure discards its half-built clone and leaves the published
    /// snapshot untouched.
    pub fn publish<R>(&self, f: impl FnOnce(&mut T) -> R) -> R {
        let _writer = lock_recover(&self.writer);
        let cur = self.current.load(SeqCst);
        // Safe: the writer mutex excludes reclamation of `current`.
        let mut next = unsafe { (*cur).clone() };
        let out = f(&mut next);
        let fresh = Box::into_raw(Box::new(next));
        let old = self.current.swap(fresh, SeqCst);
        let tag = self.epoch.fetch_add(1, SeqCst);
        let mut retired = lock_recover(&self.retired);
        retired.push((tag, old));
        // Reclaim every retired snapshot older than the oldest active
        // reader stamp. With no active readers, everything retired is
        // reclaimable: a reader arriving now validates against the
        // bumped epoch and can only observe `fresh` or newer.
        let min_active = self
            .slots
            .iter()
            .map(|s| s.load(SeqCst))
            .filter(|&v| v != 0)
            .min();
        retired.retain(|&(t, p)| {
            let drain = match min_active {
                None => true,
                Some(m) => t < m,
            };
            if drain {
                // Safe: no active reader stamp protects tag `t`, and
                // `p` left `current` at retirement, so no new reader
                // can reach it.
                unsafe { drop(Box::from_raw(p)) };
            }
            !drain
        });
        out
    }

    /// Number of retired snapshots still awaiting reader drain
    /// (observability + tests).
    pub fn retired_len(&self) -> usize {
        lock_recover(&self.retired).len()
    }

    /// Number of publications so far.
    pub fn publications(&self) -> u64 {
        self.epoch.load(SeqCst) - 1
    }
}

impl<T: Clone> std::fmt::Debug for EpochCell<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EpochCell")
            .field("publications", &self.publications())
            .field("retired", &self.retired_len())
            .finish()
    }
}

impl<T: Clone> Drop for EpochCell<T> {
    fn drop(&mut self) {
        // `&mut self` proves no readers or writers remain.
        let cur = *self.current.get_mut();
        unsafe { drop(Box::from_raw(cur)) };
        let retired = self
            .retired
            .get_mut()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        for (_, p) in retired.drain(..) {
            unsafe { drop(Box::from_raw(p)) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::sync::Arc;

    #[test]
    fn read_sees_latest_publication() {
        let cell = EpochCell::new(vec![1u64]);
        assert_eq!(cell.snapshot(), vec![1]);
        cell.publish(|v| v.push(2));
        cell.publish(|v| v.push(3));
        assert_eq!(cell.snapshot(), vec![1, 2, 3]);
        assert_eq!(cell.publications(), 2);
        // No reader was active at either publication: nothing retired.
        assert_eq!(cell.retired_len(), 0);
    }

    #[test]
    fn retired_snapshot_survives_until_reader_drains() {
        let cell = Arc::new(EpochCell::new(String::from("v0")));
        let (entered_tx, entered_rx) = mpsc::channel();
        let (release_tx, release_rx) = mpsc::channel::<()>();
        let reader = {
            let cell = Arc::clone(&cell);
            std::thread::spawn(move || {
                cell.read(|s| {
                    entered_tx.send(s.clone()).unwrap();
                    release_rx.recv().unwrap();
                    s.clone()
                })
            })
        };
        // Reader is pinned inside the closure on the old snapshot.
        assert_eq!(entered_rx.recv().unwrap(), "v0");
        cell.publish(|s| *s = String::from("v1"));
        cell.publish(|s| *s = String::from("v2"));
        // Both displaced snapshots must wait for the pinned reader.
        assert_eq!(cell.retired_len(), 2);
        assert_eq!(cell.snapshot(), "v2");
        release_tx.send(()).unwrap();
        assert_eq!(reader.join().unwrap(), "v0", "pinned read stays on its epoch");
        // The next publication reclaims the drained epochs.
        cell.publish(|s| *s = String::from("v3"));
        assert_eq!(cell.retired_len(), 0);
    }

    #[test]
    fn slot_exhaustion_falls_back_to_the_slow_path() {
        let cell = Arc::new(EpochCell::with_slots(7u64, 1));
        let (entered_tx, entered_rx) = mpsc::channel();
        let (release_tx, release_rx) = mpsc::channel::<()>();
        let reader = {
            let cell = Arc::clone(&cell);
            std::thread::spawn(move || {
                cell.read(|&v| {
                    entered_tx.send(()).unwrap();
                    release_rx.recv().unwrap();
                    v
                })
            })
        };
        entered_rx.recv().unwrap();
        // The only slot is pinned: this read must still succeed.
        assert_eq!(cell.read(|&v| v), 7);
        release_tx.send(()).unwrap();
        assert_eq!(reader.join().unwrap(), 7);
    }

    #[test]
    fn panicking_publication_is_discarded_and_writer_recovers() {
        let cell = Arc::new(EpochCell::new(vec![1u64, 2]));
        let poisoner = {
            let cell = Arc::clone(&cell);
            std::thread::spawn(move || {
                cell.publish(|v| {
                    v.push(99);
                    panic!("shard worker dies mid-publication");
                });
            })
        };
        assert!(poisoner.join().is_err());
        // The half-built clone is discarded, the writer mutex recovers.
        assert_eq!(cell.snapshot(), vec![1, 2]);
        cell.publish(|v| v.push(3));
        assert_eq!(cell.snapshot(), vec![1, 2, 3]);
    }

    #[test]
    fn concurrent_readers_and_writers_never_tear() {
        // Every published snapshot is (n, n): readers must never
        // observe a torn pair, and the final value must be the last
        // publication.
        const WRITES: u64 = 200;
        let cell = Arc::new(EpochCell::with_slots((0u64, 0u64), 8));
        std::thread::scope(|scope| {
            for _ in 0..2 {
                let cell = &cell;
                scope.spawn(move || {
                    for _ in 0..WRITES {
                        cell.publish(|(a, b)| {
                            *a += 1;
                            *b += 1;
                        });
                    }
                });
            }
            for _ in 0..6 {
                let cell = &cell;
                scope.spawn(move || {
                    for _ in 0..2000 {
                        let (a, b) = cell.read(|&pair| pair);
                        assert_eq!(a, b, "snapshot must never tear");
                    }
                });
            }
        });
        assert_eq!(cell.snapshot(), (2 * WRITES, 2 * WRITES));
        // All readers drained: the retirement list must be bounded by
        // what the final publication could not yet reclaim.
        cell.publish(|_| {});
        assert_eq!(cell.retired_len(), 0);
    }
}
