//! Admission control and compile backpressure.
//!
//! A production fleet protects itself in two tiers:
//!
//! * **Serving admission** — a task whose placed device already has a
//!   queue delay beyond the bound is rejected outright (the cluster
//!   scheduler retries it elsewhere/later; this layer just refuses to
//!   let one device's backlog grow without bound).
//! * **Compile backpressure** — when the bounded compile-worker pool is
//!   saturated, new graphs are still *served* (the XLA fallback needs no
//!   exploration) but skip FusionStitching compilation. Optimization
//!   yields to serving under overload — the fleet-wide version of §6's
//!   "serve the fallback while tuning runs in background".
//!
//! Multi-tenant traffic adds a third axis: each task's [`TenantTier`]
//! carries a queue-delay SLA, and [`AdmissionController::decide_tiered`]
//! *sheds* (rather than FIFO-queues) work whose tier cannot absorb the
//! current backpressure — Premium keeps the full single-tenant
//! semantics, Standard degrades to the fallback under compile
//! saturation, BestEffort sheds. Decisions use only virtual-time inputs
//! (the placed queue delay and the [`AdmissionTick`]-sampled pending
//! count), so they are byte-identical across executors.

use crate::fleet::sim::TenantTier;

/// Admission-control knobs.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Reject a task when the queue delay at its placed device would
    /// exceed this bound (ms).
    pub max_queue_delay_ms: f64,
    /// Skip FS compilation (fallback-only admission) when more compile
    /// jobs than this are pending fleet-wide.
    pub max_pending_compiles: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            max_queue_delay_ms: 250.0,
            max_pending_compiles: 16,
        }
    }
}

/// Outcome of one admission decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitDecision {
    /// Serve, and compile/port when the plan store misses.
    Admit,
    /// Serve on the fallback only; no compile job is enqueued.
    AdmitFallbackOnly,
    /// Refuse the task (device backlog beyond the bound).
    Reject,
    /// Drop the task because its tier's SLA cannot absorb the current
    /// backpressure — QoS load-shedding, distinct from [`Reject`]
    /// (which is the tier-blind hard backlog bound).
    ///
    /// [`Reject`]: AdmitDecision::Reject
    Shed,
}

/// Stateful admission controller with decision accounting.
#[derive(Debug, Clone, Default)]
pub struct AdmissionController {
    config: AdmissionConfig,
    admitted: usize,
    fallback_only: usize,
    rejected: usize,
    shed: usize,
}

impl AdmissionController {
    pub fn new(config: AdmissionConfig) -> Self {
        AdmissionController { config, admitted: 0, fallback_only: 0, rejected: 0, shed: 0 }
    }

    /// Decide one task given its placed queue delay, the pending
    /// compile-job count, and whether serving it optimized would need a
    /// new compile/port job (plan-store hits need none, so compile
    /// backpressure never degrades them).
    pub fn decide(
        &mut self,
        queue_delay_ms: f64,
        pending_compiles: usize,
        needs_compile: bool,
    ) -> AdmitDecision {
        if queue_delay_ms > self.config.max_queue_delay_ms {
            self.rejected += 1;
            return AdmitDecision::Reject;
        }
        if needs_compile && pending_compiles >= self.config.max_pending_compiles {
            self.fallback_only += 1;
            return AdmitDecision::AdmitFallbackOnly;
        }
        self.admitted += 1;
        AdmitDecision::Admit
    }

    /// Decide one task under its tenant tier's SLA. Premium is exactly
    /// the tier-blind [`AdmissionController::decide`] (so all-Premium
    /// traffic — every pre-tenant trace — decides byte-for-byte like
    /// the single-tenant fleet). Lower tiers shed when the placed queue
    /// delay already blows their SLA, and under compile saturation
    /// Standard degrades to the fallback while BestEffort sheds.
    pub fn decide_tiered(
        &mut self,
        tier: TenantTier,
        queue_delay_ms: f64,
        pending_compiles: usize,
        needs_compile: bool,
    ) -> AdmitDecision {
        if tier == TenantTier::Premium {
            return self.decide(queue_delay_ms, pending_compiles, needs_compile);
        }
        // A tier's effective queue bound never exceeds the hard
        // backlog bound — a lax SLA cannot smuggle work past it.
        let bound = tier.sla_ms().min(self.config.max_queue_delay_ms);
        if queue_delay_ms > bound {
            self.shed += 1;
            return AdmitDecision::Shed;
        }
        if needs_compile && pending_compiles >= self.config.max_pending_compiles {
            if tier == TenantTier::Standard {
                self.fallback_only += 1;
                return AdmitDecision::AdmitFallbackOnly;
            }
            self.shed += 1;
            return AdmitDecision::Shed;
        }
        self.admitted += 1;
        AdmitDecision::Admit
    }

    /// (admitted, fallback_only, rejected) counts so far.
    pub fn counts(&self) -> (usize, usize, usize) {
        (self.admitted, self.fallback_only, self.rejected)
    }

    /// Tasks shed by QoS load-shedding so far.
    pub fn shed_count(&self) -> usize {
        self.shed
    }

    /// The active configuration.
    pub fn config(&self) -> &AdmissionConfig {
        &self.config
    }
}

/// Batched backpressure sampling for a shard dispatcher: instead of
/// recomputing the fleet-wide pending-compile count for *every* task
/// (an O(pending) retain-and-count on the dispatcher's hot path), the
/// shard samples it once per tick of virtual time and every admission
/// decision inside the tick reuses the sample. Per-task queue-delay
/// rejection is untouched — it is a per-device property that costs
/// nothing to read.
///
/// Determinism: ticks are cut on *virtual* arrival timestamps and the
/// pending count is virtual bookkeeping in both executors, so a batched
/// shard makes byte-identical decisions under the virtual and
/// wall-clock executors — the per-shard equivalence invariant. A tick
/// of `0.0` disables batching (every task resamples), which reproduces
/// the unbatched dispatcher exactly.
#[derive(Debug, Clone, Default)]
pub struct AdmissionTick {
    tick_ms: f64,
    /// Start of the current tick, once the first sample has been taken.
    started: Option<f64>,
    pending: usize,
}

impl AdmissionTick {
    pub fn new(tick_ms: f64) -> Self {
        assert!(tick_ms >= 0.0, "admission tick must be non-negative");
        AdmissionTick { tick_ms, started: None, pending: 0 }
    }

    /// The pending-compile count admission decisions at virtual time
    /// `now` should use: the tick's cached sample, refreshed via
    /// `sample` when `now` has left the tick window (or batching is
    /// off).
    pub fn pending(&mut self, now: f64, sample: impl FnOnce() -> usize) -> usize {
        let stale = match self.started {
            None => true,
            Some(t0) => self.tick_ms == 0.0 || now >= t0 + self.tick_ms,
        };
        if stale {
            self.started = Some(now);
            self.pending = sample();
        }
        self.pending
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normal_load_admits() {
        let mut ac = AdmissionController::new(AdmissionConfig::default());
        assert_eq!(ac.decide(0.0, 0, true), AdmitDecision::Admit);
        assert_eq!(ac.counts(), (1, 0, 0));
    }

    #[test]
    fn deep_backlog_rejects() {
        let mut ac = AdmissionController::new(AdmissionConfig {
            max_queue_delay_ms: 100.0,
            ..Default::default()
        });
        assert_eq!(ac.decide(100.1, 0, true), AdmitDecision::Reject);
        assert_eq!(ac.decide(99.9, 0, true), AdmitDecision::Admit);
        assert_eq!(ac.counts(), (1, 0, 1));
    }

    #[test]
    fn compile_saturation_degrades_to_fallback_only() {
        let mut ac = AdmissionController::new(AdmissionConfig {
            max_pending_compiles: 4,
            ..Default::default()
        });
        assert_eq!(ac.decide(0.0, 4, true), AdmitDecision::AdmitFallbackOnly);
        assert_eq!(ac.decide(0.0, 3, true), AdmitDecision::Admit);
        // Plan-store hits need no compile: backpressure never degrades
        // them.
        assert_eq!(ac.decide(0.0, 100, false), AdmitDecision::Admit);
        // Rejection takes precedence over backpressure.
        assert_eq!(ac.decide(1e9, 100, true), AdmitDecision::Reject);
        assert_eq!(ac.counts(), (2, 1, 1));
    }

    #[test]
    fn tiered_backpressure_admits_high_priority_while_low_sheds() {
        // The same backpressure sample, three tiers: compile saturation
        // keeps Premium on the legacy FIFO path (fallback-only),
        // degrades Standard the same way, and sheds BestEffort.
        let mut ac = AdmissionController::new(AdmissionConfig {
            max_pending_compiles: 4,
            ..Default::default()
        });
        let d = |ac: &mut AdmissionController, tier| ac.decide_tiered(tier, 0.0, 4, true);
        assert_eq!(d(&mut ac, TenantTier::Premium), AdmitDecision::AdmitFallbackOnly);
        assert_eq!(d(&mut ac, TenantTier::Standard), AdmitDecision::AdmitFallbackOnly);
        assert_eq!(d(&mut ac, TenantTier::BestEffort), AdmitDecision::Shed);
        assert_eq!(ac.counts(), (0, 2, 0));
        assert_eq!(ac.shed_count(), 1);
        // Under the saturation bound everyone is admitted.
        assert_eq!(ac.decide_tiered(TenantTier::BestEffort, 0.0, 3, true), AdmitDecision::Admit);
        // Plan-store hits need no compile: saturation never sheds them.
        assert_eq!(ac.decide_tiered(TenantTier::BestEffort, 0.0, 100, false), AdmitDecision::Admit);
    }

    #[test]
    fn blown_sla_sheds_lower_tiers_before_the_hard_bound() {
        // Queue delay 150 ms: inside Premium's 250 ms bound, beyond
        // Standard's 100 ms and BestEffort's 25 ms SLAs.
        let mut ac = AdmissionController::new(AdmissionConfig::default());
        assert_eq!(ac.decide_tiered(TenantTier::Premium, 150.0, 0, true), AdmitDecision::Admit);
        assert_eq!(ac.decide_tiered(TenantTier::Standard, 150.0, 0, true), AdmitDecision::Shed);
        assert_eq!(ac.decide_tiered(TenantTier::BestEffort, 30.0, 0, true), AdmitDecision::Shed);
        assert_eq!(ac.decide_tiered(TenantTier::BestEffort, 20.0, 0, true), AdmitDecision::Admit);
        // Premium keeps the tier-blind semantics exactly: past the hard
        // bound it is a Reject, not a Shed.
        assert_eq!(ac.decide_tiered(TenantTier::Premium, 250.1, 0, true), AdmitDecision::Reject);
        assert_eq!(ac.counts(), (2, 0, 1));
        assert_eq!(ac.shed_count(), 2);
    }

    #[test]
    fn shed_decisions_cut_on_the_admission_tick_boundary() {
        // The shed decision must be arrival-cut deterministic: every
        // task inside one tick window sees the same pending sample, so
        // whether a BestEffort task sheds depends only on its virtual
        // arrival time — never on live (executor-dependent) queue state.
        let mut ac = AdmissionController::new(AdmissionConfig {
            max_pending_compiles: 4,
            ..Default::default()
        });
        let mut tick = AdmissionTick::new(10.0);
        // t=0: the window samples 6 pending (saturated).
        let p0 = tick.pending(0.0, || 6);
        assert_eq!(ac.decide_tiered(TenantTier::BestEffort, 0.0, p0, true), AdmitDecision::Shed);
        // t=5: the live count has drained to 0, but the tick still
        // serves the cached sample — same window, same shed decision.
        let p1 = tick.pending(5.0, || 0);
        assert_eq!(p1, 6);
        assert_eq!(ac.decide_tiered(TenantTier::BestEffort, 5.0, p1, true), AdmitDecision::Shed);
        // t=10: the boundary resamples; the drained pool admits.
        let p2 = tick.pending(10.0, || 0);
        assert_eq!(p2, 0);
        assert_eq!(ac.decide_tiered(TenantTier::BestEffort, 10.0, p2, true), AdmitDecision::Admit);
        assert_eq!(ac.shed_count(), 2);
    }

    #[test]
    fn admission_tick_batches_pending_samples_per_window() {
        let mut tick = AdmissionTick::new(10.0);
        let mut samples = 0usize;
        let mut sample = |v: usize| {
            samples += 1;
            v
        };
        // First call samples; the rest of the window reuses the value
        // even though the live count moved.
        assert_eq!(tick.pending(0.0, || sample(3)), 3);
        assert_eq!(tick.pending(4.0, || sample(7)), 3);
        assert_eq!(tick.pending(9.9, || sample(7)), 3);
        // Crossing the tick boundary resamples and opens a new window.
        assert_eq!(tick.pending(10.0, || sample(7)), 7);
        assert_eq!(tick.pending(19.9, || sample(1)), 7);
        assert_eq!(samples, 2);

        // A zero tick is the unbatched dispatcher: every decision
        // resamples.
        let mut legacy = AdmissionTick::new(0.0);
        assert_eq!(legacy.pending(0.0, || 1), 1);
        assert_eq!(legacy.pending(0.0, || 2), 2);
        assert_eq!(legacy.pending(0.0, || 3), 3);
    }
}
