//! Admission control and compile backpressure.
//!
//! A production fleet protects itself in two tiers:
//!
//! * **Serving admission** — a task whose placed device already has a
//!   queue delay beyond the bound is rejected outright (the cluster
//!   scheduler retries it elsewhere/later; this layer just refuses to
//!   let one device's backlog grow without bound).
//! * **Compile backpressure** — when the bounded compile-worker pool is
//!   saturated, new graphs are still *served* (the XLA fallback needs no
//!   exploration) but skip FusionStitching compilation. Optimization
//!   yields to serving under overload — the fleet-wide version of §6's
//!   "serve the fallback while tuning runs in background".

/// Admission-control knobs.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Reject a task when the queue delay at its placed device would
    /// exceed this bound (ms).
    pub max_queue_delay_ms: f64,
    /// Skip FS compilation (fallback-only admission) when more compile
    /// jobs than this are pending fleet-wide.
    pub max_pending_compiles: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            max_queue_delay_ms: 250.0,
            max_pending_compiles: 16,
        }
    }
}

/// Outcome of one admission decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitDecision {
    /// Serve, and compile/port when the plan store misses.
    Admit,
    /// Serve on the fallback only; no compile job is enqueued.
    AdmitFallbackOnly,
    /// Refuse the task (device backlog beyond the bound).
    Reject,
}

/// Stateful admission controller with decision accounting.
#[derive(Debug, Clone, Default)]
pub struct AdmissionController {
    config: AdmissionConfig,
    admitted: usize,
    fallback_only: usize,
    rejected: usize,
}

impl AdmissionController {
    pub fn new(config: AdmissionConfig) -> Self {
        AdmissionController { config, admitted: 0, fallback_only: 0, rejected: 0 }
    }

    /// Decide one task given its placed queue delay, the pending
    /// compile-job count, and whether serving it optimized would need a
    /// new compile/port job (plan-store hits need none, so compile
    /// backpressure never degrades them).
    pub fn decide(
        &mut self,
        queue_delay_ms: f64,
        pending_compiles: usize,
        needs_compile: bool,
    ) -> AdmitDecision {
        if queue_delay_ms > self.config.max_queue_delay_ms {
            self.rejected += 1;
            return AdmitDecision::Reject;
        }
        if needs_compile && pending_compiles >= self.config.max_pending_compiles {
            self.fallback_only += 1;
            return AdmitDecision::AdmitFallbackOnly;
        }
        self.admitted += 1;
        AdmitDecision::Admit
    }

    /// (admitted, fallback_only, rejected) counts so far.
    pub fn counts(&self) -> (usize, usize, usize) {
        (self.admitted, self.fallback_only, self.rejected)
    }

    /// The active configuration.
    pub fn config(&self) -> &AdmissionConfig {
        &self.config
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normal_load_admits() {
        let mut ac = AdmissionController::new(AdmissionConfig::default());
        assert_eq!(ac.decide(0.0, 0, true), AdmitDecision::Admit);
        assert_eq!(ac.counts(), (1, 0, 0));
    }

    #[test]
    fn deep_backlog_rejects() {
        let mut ac = AdmissionController::new(AdmissionConfig {
            max_queue_delay_ms: 100.0,
            ..Default::default()
        });
        assert_eq!(ac.decide(100.1, 0, true), AdmitDecision::Reject);
        assert_eq!(ac.decide(99.9, 0, true), AdmitDecision::Admit);
        assert_eq!(ac.counts(), (1, 0, 1));
    }

    #[test]
    fn compile_saturation_degrades_to_fallback_only() {
        let mut ac = AdmissionController::new(AdmissionConfig {
            max_pending_compiles: 4,
            ..Default::default()
        });
        assert_eq!(ac.decide(0.0, 4, true), AdmitDecision::AdmitFallbackOnly);
        assert_eq!(ac.decide(0.0, 3, true), AdmitDecision::Admit);
        // Plan-store hits need no compile: backpressure never degrades
        // them.
        assert_eq!(ac.decide(0.0, 100, false), AdmitDecision::Admit);
        // Rejection takes precedence over backpressure.
        assert_eq!(ac.decide(1e9, 100, true), AdmitDecision::Reject);
        assert_eq!(ac.counts(), (2, 1, 1));
    }
}
