//! Admission control and compile backpressure.
//!
//! A production fleet protects itself in two tiers:
//!
//! * **Serving admission** — a task whose placed device already has a
//!   queue delay beyond the bound is rejected outright (the cluster
//!   scheduler retries it elsewhere/later; this layer just refuses to
//!   let one device's backlog grow without bound).
//! * **Compile backpressure** — when the bounded compile-worker pool is
//!   saturated, new graphs are still *served* (the XLA fallback needs no
//!   exploration) but skip FusionStitching compilation. Optimization
//!   yields to serving under overload — the fleet-wide version of §6's
//!   "serve the fallback while tuning runs in background".

/// Admission-control knobs.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Reject a task when the queue delay at its placed device would
    /// exceed this bound (ms).
    pub max_queue_delay_ms: f64,
    /// Skip FS compilation (fallback-only admission) when more compile
    /// jobs than this are pending fleet-wide.
    pub max_pending_compiles: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            max_queue_delay_ms: 250.0,
            max_pending_compiles: 16,
        }
    }
}

/// Outcome of one admission decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitDecision {
    /// Serve, and compile/port when the plan store misses.
    Admit,
    /// Serve on the fallback only; no compile job is enqueued.
    AdmitFallbackOnly,
    /// Refuse the task (device backlog beyond the bound).
    Reject,
}

/// Stateful admission controller with decision accounting.
#[derive(Debug, Clone, Default)]
pub struct AdmissionController {
    config: AdmissionConfig,
    admitted: usize,
    fallback_only: usize,
    rejected: usize,
}

impl AdmissionController {
    pub fn new(config: AdmissionConfig) -> Self {
        AdmissionController { config, admitted: 0, fallback_only: 0, rejected: 0 }
    }

    /// Decide one task given its placed queue delay, the pending
    /// compile-job count, and whether serving it optimized would need a
    /// new compile/port job (plan-store hits need none, so compile
    /// backpressure never degrades them).
    pub fn decide(
        &mut self,
        queue_delay_ms: f64,
        pending_compiles: usize,
        needs_compile: bool,
    ) -> AdmitDecision {
        if queue_delay_ms > self.config.max_queue_delay_ms {
            self.rejected += 1;
            return AdmitDecision::Reject;
        }
        if needs_compile && pending_compiles >= self.config.max_pending_compiles {
            self.fallback_only += 1;
            return AdmitDecision::AdmitFallbackOnly;
        }
        self.admitted += 1;
        AdmitDecision::Admit
    }

    /// (admitted, fallback_only, rejected) counts so far.
    pub fn counts(&self) -> (usize, usize, usize) {
        (self.admitted, self.fallback_only, self.rejected)
    }

    /// The active configuration.
    pub fn config(&self) -> &AdmissionConfig {
        &self.config
    }
}

/// Batched backpressure sampling for a shard dispatcher: instead of
/// recomputing the fleet-wide pending-compile count for *every* task
/// (an O(pending) retain-and-count on the dispatcher's hot path), the
/// shard samples it once per tick of virtual time and every admission
/// decision inside the tick reuses the sample. Per-task queue-delay
/// rejection is untouched — it is a per-device property that costs
/// nothing to read.
///
/// Determinism: ticks are cut on *virtual* arrival timestamps and the
/// pending count is virtual bookkeeping in both executors, so a batched
/// shard makes byte-identical decisions under the virtual and
/// wall-clock executors — the per-shard equivalence invariant. A tick
/// of `0.0` disables batching (every task resamples), which reproduces
/// the unbatched dispatcher exactly.
#[derive(Debug, Clone, Default)]
pub struct AdmissionTick {
    tick_ms: f64,
    /// Start of the current tick, once the first sample has been taken.
    started: Option<f64>,
    pending: usize,
}

impl AdmissionTick {
    pub fn new(tick_ms: f64) -> Self {
        assert!(tick_ms >= 0.0, "admission tick must be non-negative");
        AdmissionTick { tick_ms, started: None, pending: 0 }
    }

    /// The pending-compile count admission decisions at virtual time
    /// `now` should use: the tick's cached sample, refreshed via
    /// `sample` when `now` has left the tick window (or batching is
    /// off).
    pub fn pending(&mut self, now: f64, sample: impl FnOnce() -> usize) -> usize {
        let stale = match self.started {
            None => true,
            Some(t0) => self.tick_ms == 0.0 || now >= t0 + self.tick_ms,
        };
        if stale {
            self.started = Some(now);
            self.pending = sample();
        }
        self.pending
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normal_load_admits() {
        let mut ac = AdmissionController::new(AdmissionConfig::default());
        assert_eq!(ac.decide(0.0, 0, true), AdmitDecision::Admit);
        assert_eq!(ac.counts(), (1, 0, 0));
    }

    #[test]
    fn deep_backlog_rejects() {
        let mut ac = AdmissionController::new(AdmissionConfig {
            max_queue_delay_ms: 100.0,
            ..Default::default()
        });
        assert_eq!(ac.decide(100.1, 0, true), AdmitDecision::Reject);
        assert_eq!(ac.decide(99.9, 0, true), AdmitDecision::Admit);
        assert_eq!(ac.counts(), (1, 0, 1));
    }

    #[test]
    fn compile_saturation_degrades_to_fallback_only() {
        let mut ac = AdmissionController::new(AdmissionConfig {
            max_pending_compiles: 4,
            ..Default::default()
        });
        assert_eq!(ac.decide(0.0, 4, true), AdmitDecision::AdmitFallbackOnly);
        assert_eq!(ac.decide(0.0, 3, true), AdmitDecision::Admit);
        // Plan-store hits need no compile: backpressure never degrades
        // them.
        assert_eq!(ac.decide(0.0, 100, false), AdmitDecision::Admit);
        // Rejection takes precedence over backpressure.
        assert_eq!(ac.decide(1e9, 100, true), AdmitDecision::Reject);
        assert_eq!(ac.counts(), (2, 1, 1));
    }

    #[test]
    fn admission_tick_batches_pending_samples_per_window() {
        let mut tick = AdmissionTick::new(10.0);
        let mut samples = 0usize;
        let mut sample = |v: usize| {
            samples += 1;
            v
        };
        // First call samples; the rest of the window reuses the value
        // even though the live count moved.
        assert_eq!(tick.pending(0.0, || sample(3)), 3);
        assert_eq!(tick.pending(4.0, || sample(7)), 3);
        assert_eq!(tick.pending(9.9, || sample(7)), 3);
        // Crossing the tick boundary resamples and opens a new window.
        assert_eq!(tick.pending(10.0, || sample(7)), 7);
        assert_eq!(tick.pending(19.9, || sample(1)), 7);
        assert_eq!(samples, 2);

        // A zero tick is the unbatched dispatcher: every decision
        // resamples.
        let mut legacy = AdmissionTick::new(0.0);
        assert_eq!(legacy.pending(0.0, || 1), 1);
        assert_eq!(legacy.pending(0.0, || 2), 2);
        assert_eq!(legacy.pending(0.0, || 3), 3);
    }
}
