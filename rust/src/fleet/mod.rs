//! Fleet serving (§6–§7.2 at cluster scale): multi-device, multi-tenant
//! JIT-optimized serving with cross-device plan portability.
//!
//! The paper's production deployment ran FusionStitching on "a
//! production cluster [with] thousands of GPUs" serving "~30,000 tasks
//! per month", saving "~7,000 GPU hours" with *zero* negative
//! optimizations. This subsystem makes that claim executable:
//!
//! * [`registry`] — the mixed V100/T4 device population with per-device
//!   serving capacity.
//! * [`queue`] — the shareable work-stealing deque set under the
//!   bounded compile-worker pool that throttles FS exploration. With
//!   `compile_shards > 1` a multi-region graph's exploration fans out
//!   as one queue sub-job per region group with a join barrier, so the
//!   pool parallelizes *within* one graph
//!   ([`crate::explorer::regions`]).
//! * [`store`] — the shared cross-device, shape-polymorphic plan store
//!   with three reuse tiers: exact hit, cross-class *port* (re-run only
//!   the §4.2 launch-dimension tuner on the new device,
//!   [`crate::pipeline::port_program`]), and same-class *bucket hit* —
//!   a plan explored at a sibling shape inside the same power-of-two
//!   shape bucket is re-lowered at the new shape
//!   ([`crate::pipeline::reshape_program`]).
//! * [`admission`] — admission control (backlog rejection) and compile
//!   backpressure (serve fallback-only under saturation).
//! * [`sim`] — deterministic seeded traffic traces at the paper's task
//!   scale; with [`TrafficConfig::dynamic_shapes`] every task draws a
//!   (batch, seq) from its template's seeded shape distribution and the
//!   template population becomes shape-scalable [`TemplateFamily`]s.
//! * [`service`] — [`FleetService`]: replays a trace through the real
//!   optimization pipeline on either executor.
//! * [`executor`] — the [`ExecutorKind`] seam: the deterministic
//!   virtual-time replay (test harness) or the wall-clock pool, where
//!   compile workers and per-device serving slots run on real OS
//!   threads and hot-swap published plans mid-task; both reach the
//!   same plan/admission decisions.
//! * [`metrics`] — the fleet-wide report: GPU hours saved, regression
//!   counts (must be zero), cache/portability hit rates, queue-latency
//!   percentiles, cost-model drift before/after calibration.
//! * [`epoch`] — the RCU-style [`EpochCell`] publication primitive:
//!   serve threads read published plans through a lock-free epoch
//!   snapshot (one atomic pointer load per read), writers publish by
//!   cloning, swapping and retiring the old snapshot after readers
//!   drain. Backs the plan store's and latency table's hot read paths.
//! * [`cluster`] — [`ShardedFleetService`]: the cluster-scale control
//!   plane. Tasks route to one of `shards` complete dispatchers by
//!   their graph's structure key ([`queue::shard_of`]); per-shard
//!   admission is batched per tick; the decision-equivalence invariant
//!   holds *per shard* ([`ClusterReport::decision_digests`]).
//!
//! With [`FleetOptions::calibrate`] the fleet also closes the
//! predicted-vs-measured loop ([`crate::codegen::calibrate`]): served
//! programs yield per-kernel (modeled, measured) pairs, per-device-
//! class [`crate::gpu::CostParams`] corrections are fitted with a
//! robust regression, and graphs whose measured/predicted ratio drifts
//! past [`FleetOptions::drift_bound`] are re-explored once under the
//! calibrated params — published only when strictly faster, hot-swapped
//! into in-flight sessions, and decided entirely on the dispatcher so
//! both executors stay decision-identical.
//!
//! Multi-tenant QoS under churn: traffic can carry per-task tenants
//! ([`TrafficConfig::tenants`]) with priority tiers ([`TenantTier`]) —
//! Premium admits exactly like the tier-blind fleet while Standard and
//! BestEffort shed or degrade under pressure at the dispatcher, so the
//! per-shard decision digests stay executor-invariant. A seeded
//! [`registry::ChurnPlan`] takes devices away mid-trace (and with
//! [`FleetOptions::inject_faults`] kills one outright, delivered to the
//! wall-clock serving thread as a real kill marker); in-flight sessions
//! migrate to survivors with their plan following through the
//! port/reshape feasibility ladder. The report's `qos` section carries
//! per-tenant p50/p99, shed/violation counts and churn/migration
//! counters, gated by `ci/check_bench.sh`.

pub mod admission;
pub mod cluster;
pub mod epoch;
pub mod executor;
pub mod metrics;
pub mod queue;
pub mod registry;
pub mod service;
pub mod sim;
pub mod store;

pub use admission::{AdmissionConfig, AdmissionController, AdmissionTick, AdmitDecision};
pub use cluster::ShardedFleetService;
pub use epoch::EpochCell;
pub use executor::ExecutorKind;
pub use metrics::{ClusterReport, DeviceUtilization, FleetReport, ShardRollup, TenantQos};
pub use queue::{owner_hash, shard_of, QueueStats, WorkStealingQueue};
pub use registry::{
    ChurnEvent, ChurnEventKind, ChurnPlan, DeviceId, DeviceRegistry, RegisteredDevice,
};
pub use service::{FleetOptions, FleetService};
pub use sim::{
    build_template_families, build_templates, generate_trace, FleetTask, ModelFamily, ShapeDist,
    TaskShape, TemplateFamily, TenantTier, TrafficConfig,
};
pub use store::{PlanKey, PlanLookup, SharedPlanStore, StoreStats};

// Poison-recovering mutex lock (now shared crate-wide from
// `util::sync`). Every critical section behind these locks is a single
// collection operation that cannot be observed half-done, so the data
// stays consistent and recovery is sound. Without this, one poisoned
// lock cascades: other compile workers panic on `unwrap()`, stop
// draining the queue, and the dispatcher's publication-barrier wait
// never releases — a silent deadlock instead of a surfaced error
// (worker panics are collected and re-raised on the dispatcher at
// shutdown; see [`executor`]).
pub(crate) use crate::util::sync::lock_recover;
