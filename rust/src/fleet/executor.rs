//! Wall-clock executor: real OS threads under [`super::service`].
//!
//! PR 1's fleet replayed §7.2 in *virtual* time only — compile "workers"
//! were timestamp arithmetic and the work-stealing deques were drained
//! single-threaded. This module adds the second half of the paper's
//! async-compilation story (§6, and the execution-efficiency companion
//! work): a **thread-per-worker compile pool** draining the shared
//! [`WorkStealingQueue`] while **per-device serving threads** keep
//! serving the XLA fallback, hot-swapping each task's
//! [`crate::coordinator::Session`] the moment the pool publishes a
//! finished plan to the [`SharedPlanStore`] — mid-stream, exactly like
//! production's "serve the fallback while tuning runs in background".
//!
//! # Determinism seam
//!
//! [`ExecutorKind`] selects the execution substrate; the *decision*
//! plane is shared. The dispatcher (the trace loop in
//! [`super::FleetService`]) always runs the virtual-time model —
//! placement, admission, plan lookup, compile-cost bookkeeping — in
//! arrival order, because trace arrivals are virtual timestamps in both
//! modes. Under [`ExecutorKind::WallClock`] only the *expensive* work
//! moves onto threads: full explorations and port guards run on the
//! compile pool, iteration serving runs on device threads. Two rules
//! keep the wall-clock run convergent with the virtual replay:
//!
//! 1. **Publication barrier** — before the dispatcher looks up a graph
//!    in the plan store, it waits for any in-flight compile of that
//!    same graph *or of a sibling shape in its (structure, bucket)
//!    class* ([`WallClockPool::await_plan`]), so the lookup sees
//!    exactly the store state — including shape-port representatives —
//!    the virtual replay would have seen. Jobs for unrelated graphs
//!    overlap freely.
//! 2. **Virtual bookkeeping parity** — the dispatcher still advances
//!    the virtual slot clocks past every admitted task, lazily waiting
//!    for a published latency only when a task's virtual serving window
//!    actually crosses its compile's virtual ready time (rare: most
//!    tasks finish on the fallback first, which is the §6 premise).
//!
//! Plan decisions, store hits/buckets/ports/misses and the
//! never-negative guarantee are therefore identical across executors
//! (asserted by the equivalence tests in `super::service`); wall-clock
//! latency fields (`served_gpu_ms`, iteration percentiles, elapsed
//! time) reflect the real thread race and legitimately differ.
//!
//! # Failure containment
//!
//! A panicking compile worker must not wedge the fleet: every job's
//! publication-barrier release lives in a drop guard, the shared locks
//! recover from poisoning ([`super::lock_recover`] — each critical
//! section is a single collection op), and [`compile_loop`] catches the
//! panic, records it, and keeps the worker draining the queue. The
//! collected panics are returned in [`WallTotals::errors`] and
//! re-raised as one deterministic dispatcher-side error at shutdown —
//! a surfaced failure instead of a silent join-barrier deadlock.

use super::epoch::EpochCell;
use super::lock_recover;
use super::queue::{owner_hash, QueueStats, WorkStealingQueue};
use super::store::{PlanKey, PlanLookup, SharedPlanStore};
use crate::coordinator::{guard_never_negative, tune_with_guards, ServiceOptions, Session};
use crate::obs::{Event, EventKind, LockSnapshot, LockStats, Recorder, TrackHandle, WALL_PID};
use crate::explorer::{regions, ExploreOptions, FusionPlan};
use crate::gpu::{DeviceSpec, SimConfig, Simulator};
use crate::pipeline::{self, OptimizedProgram, Tech};
use crate::workloads::{LoopKind, Workload};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Which substrate executes compiles and serving.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecutorKind {
    /// Deterministic single-threaded replay in virtual time (the test
    /// harness; byte-identical across runs of one seed).
    #[default]
    VirtualTime,
    /// Real OS threads: `threads` compile workers drain the shared
    /// work-stealing queue and every registered device serves on its
    /// own thread. `threads` is independent of the virtual admission
    /// model's `compile_workers` — decisions converge for any count.
    WallClock { threads: usize },
}

impl ExecutorKind {
    /// Stable name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            ExecutorKind::VirtualTime => "virtual",
            ExecutorKind::WallClock { .. } => "wallclock",
        }
    }
}

/// One (graph, class) entry of the shared latency map: the published
/// per-iteration ms, plus an optional strictly-better drift-triggered
/// re-publication that only takes effect (in virtual bookkeeping) at
/// its re-exploration's virtual compile-finish time — a re-explored
/// plan must not be credited before the compile that produced it could
/// have finished.
#[derive(Debug, Clone, Copy)]
pub(crate) struct PublishedLatency {
    /// Per-iteration ms of the originally published program.
    pub ms: f64,
    /// `(ms, effective_from_ms)` of a re-published improvement.
    pub improved: Option<(f64, f64)>,
}

impl PublishedLatency {
    pub(crate) fn first(ms: f64) -> Self {
        PublishedLatency { ms, improved: None }
    }

    /// Value the virtual bookkeeping serves at virtual time `t`.
    pub(crate) fn at(&self, t: f64) -> f64 {
        match self.improved {
            Some((m, from)) if t >= from => m,
            _ => self.ms,
        }
    }

    /// Latest published value (what wall-clock serving converges to).
    pub(crate) fn latest(&self) -> f64 {
        self.improved.map(|(m, _)| m).unwrap_or(self.ms)
    }
}

/// (graph key, device class) → published latency of the served
/// program. Shared between the dispatcher, compile workers and serving
/// threads; publication of an entry *is* the wall-clock ready signal.
/// Backed by an [`EpochCell`]: serve threads poll the table every
/// iteration, so reads are lock-free epoch-validated snapshots, while
/// compile workers publish copy-on-write (the table holds one small
/// `Copy` entry per (graph, class), so a clone per publication is
/// cheap and publications are rare — one per compile).
#[derive(Debug)]
pub(crate) struct LatencyTable {
    cell: EpochCell<HashMap<(u64, &'static str), PublishedLatency>>,
}

impl LatencyTable {
    /// A fresh shared table (one per shard dispatcher).
    pub(crate) fn shared() -> LatencyMap {
        Arc::new(LatencyTable { cell: EpochCell::new(HashMap::new()) })
    }

    /// Lock-free epoch read — the serve-thread per-iteration poll.
    pub(crate) fn get(&self, k: &(u64, &'static str)) -> Option<PublishedLatency> {
        self.cell.read(|m| m.get(k).copied())
    }

    /// Publish one entry (copy-on-write epoch swap).
    pub(crate) fn insert(&self, k: (u64, &'static str), v: PublishedLatency) {
        self.cell.publish(|m| {
            m.insert(k, v);
        });
    }

    /// Read-modify-write publication under the epoch writer lock (the
    /// re-exploration improvement path).
    pub(crate) fn update<R>(
        &self,
        f: impl FnOnce(&mut HashMap<(u64, &'static str), PublishedLatency>) -> R,
    ) -> R {
        self.cell.publish(f)
    }
}

pub(crate) type LatencyMap = Arc<LatencyTable>;

/// Outcome counters shared across the dispatcher and the compile pool
/// (the virtual path bumps the same atomics inline, so reports read one
/// source of truth in either mode).
#[derive(Debug, Default)]
pub(crate) struct FleetCounters {
    pub explore_jobs: AtomicUsize,
    pub port_jobs: AtomicUsize,
    pub port_failures: AtomicUsize,
    /// Same-class shape retunes (the `BucketHit` tier's compile jobs).
    pub bucket_jobs: AtomicUsize,
    /// Bucket retunes whose sibling plan could not schedule at the new
    /// shape (the task fell back to a full exploration).
    pub bucket_failures: AtomicUsize,
    pub fs_vetoes: AtomicUsize,
    /// Region-shard compile sub-jobs fanned out by sharded explorations
    /// (each counts toward queue traffic but not `explore_jobs`, which
    /// stays one per graph exploration).
    pub shard_jobs: AtomicUsize,
    /// Drift-triggered re-exploration compile jobs (calibration loop).
    pub reexplore_jobs: AtomicUsize,
    /// Re-explorations whose plan beat the incumbent and was hot-swapped
    /// in (the only way a re-exploration may change what a class serves
    /// — the plan-quality no-worse gate).
    pub reexplore_improved: AtomicUsize,
    /// Re-explorations rejected by the gate (crashed, vetoed, or not
    /// better than the incumbent); the incumbent keeps serving.
    pub reexplore_rejected: AtomicUsize,
    /// GEMM boundaries absorbed across every published plan (cross-GEMM
    /// stitching): counted at the single publication path, so virtual
    /// and wall-clock executors agree by construction.
    pub gemm_absorbed: AtomicUsize,
    /// Candidate patterns discarded by the footprint bound across every
    /// published plan's exploration (DP combinations plus beam defense
    /// rejections). Counted at the same single publication path as
    /// `gemm_absorbed`: the tally is a pure function of (graph, device,
    /// options), so virtual and wall-clock executors agree by
    /// construction.
    pub footprint_pruned: AtomicUsize,
}

/// Per-iteration simulated latency of a program on a device.
pub(crate) fn iter_ms(spec: &DeviceSpec, prog: &OptimizedProgram, loop_kind: LoopKind) -> f64 {
    Simulator::new(spec.clone(), SimConfig::xla_runtime())
        .run(&prog.kernels, loop_kind)
        .e2e_ms()
}

/// Produce the guarded compile candidate for one job: a full FS
/// exploration behind the coordinator's crash/veto guards, or the
/// never-negative check on an already-lowered port/shape-retune. The
/// tuning/guard half of the publication path, shared verbatim by the
/// virtual inline compiles and the wall-clock workers (see
/// [`guard_and_publish`] for the other half) so both executors decide
/// identically by construction.
pub(crate) fn produce_candidate(
    w: &Workload,
    spec: &DeviceSpec,
    explore: &ExploreOptions,
    never_negative: bool,
    fallback: &Arc<OptimizedProgram>,
    kind: WallJobKind,
) -> Option<Arc<OptimizedProgram>> {
    match kind {
        WallJobKind::Explore => {
            let opts = ServiceOptions {
                device: spec.clone(),
                explore: explore.clone(),
                async_compile: false,
                never_negative,
                inject_compile_failure: false,
                plan_store: None,
            };
            tune_with_guards(w, &opts, fallback)
        }
        WallJobKind::ExploreShard { .. } => {
            unreachable!("sharded explorations publish through their join barrier")
        }
        WallJobKind::Reexplore { .. } => {
            unreachable!("re-explorations publish through publish_reexplored")
        }
        WallJobKind::GuardPort { ported, .. } => {
            if never_negative {
                guard_never_negative(w, spec, ported, fallback)
            } else {
                Some(Arc::new(ported))
            }
        }
    }
}

/// Publish a compile outcome: an accepted candidate serves (store +
/// latency map), a veto/crash (`None`) pins the fallback and bumps the
/// veto counter. The ONE publication path shared by the virtual-mode
/// inline compiles and the wall-clock workers — the executors' decision
/// equivalence rests on both publishing identically, so it is enforced
/// here by construction. Returns the published per-iteration ms.
#[allow(clippy::too_many_arguments)]
pub(crate) fn guard_and_publish(
    w: &Workload,
    spec: &DeviceSpec,
    key: PlanKey,
    candidate: Option<Arc<OptimizedProgram>>,
    fallback: &Arc<OptimizedProgram>,
    fb_ms: f64,
    ready_ms: f64,
    store: &SharedPlanStore,
    latency: &LatencyMap,
    counters: &FleetCounters,
) -> f64 {
    match candidate {
        Some(prog) => {
            let ms = iter_ms(spec, &prog, w.loop_kind);
            counters
                .gemm_absorbed
                .fetch_add(prog.plan.absorbed_boundaries(), Ordering::Relaxed);
            counters.footprint_pruned.fetch_add(prog.plan.footprint_pruned, Ordering::Relaxed);
            store.insert(key, spec.name, prog, ready_ms);
            latency.insert((key.exact.0, spec.name), PublishedLatency::first(ms));
            ms
        }
        None => {
            counters.fs_vetoes.fetch_add(1, Ordering::Relaxed);
            store.insert(key, spec.name, Arc::clone(fallback), ready_ms);
            latency.insert((key.exact.0, spec.name), PublishedLatency::first(fb_ms));
            fb_ms
        }
    }
}

/// Produce a drift-triggered re-exploration candidate: a full FS
/// exploration under the dispatcher's calibrated `explore` snapshot,
/// behind the usual crash/veto guards. Shared by the virtual inline
/// path and the wall-clock workers.
pub(crate) fn produce_reexplored(
    w: &Workload,
    spec: &DeviceSpec,
    explore: &ExploreOptions,
    never_negative: bool,
    fallback: &Arc<OptimizedProgram>,
) -> Option<Arc<OptimizedProgram>> {
    let opts = ServiceOptions {
        device: spec.clone(),
        explore: explore.clone(),
        async_compile: false,
        never_negative,
        inject_compile_failure: false,
        plan_store: None,
    };
    tune_with_guards(w, &opts, fallback)
}

/// Publish a re-exploration outcome behind the plan-quality no-worse
/// gate: the candidate replaces the served plan (store + latency map —
/// in-flight wall-clock sessions hot-swap to it on their next
/// iteration) only when its simulator-measured iteration time strictly
/// beats the incumbent's. The incumbent's store `ready_ms` is preserved
/// (the graph has been continuously served by the incumbent), while the
/// improved *latency* only takes effect in virtual bookkeeping from
/// `effective_ms` — the re-exploration's virtual compile-finish — so
/// the charged compile time genuinely delays the win. The ONE
/// re-publication path shared by both executors, like
/// [`guard_and_publish`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn publish_reexplored(
    w: &Workload,
    spec: &DeviceSpec,
    key: PlanKey,
    candidate: Option<Arc<OptimizedProgram>>,
    effective_ms: f64,
    store: &SharedPlanStore,
    latency: &LatencyMap,
    counters: &FleetCounters,
) {
    let incumbent_ready = match store.lookup(key, spec.name) {
        PlanLookup::Hit { ready_ms, .. } => ready_ms,
        // No incumbent means the trigger raced ahead of publication —
        // impossible by construction (re-explores are only enqueued for
        // served hits), but never publish into that state.
        _ => {
            counters.reexplore_rejected.fetch_add(1, Ordering::Relaxed);
            return;
        }
    };
    let Some(prog) = candidate else {
        counters.reexplore_rejected.fetch_add(1, Ordering::Relaxed);
        return;
    };
    let new_ms = iter_ms(spec, &prog, w.loop_kind);
    let old_ms = latency
        .get(&(key.exact.0, spec.name))
        .map(|p| p.latest())
        .unwrap_or(f64::INFINITY);
    if new_ms < old_ms - 1e-12 {
        store.insert(key, spec.name, prog, incumbent_ready);
        latency.update(|map| {
            if let Some(entry) = map.get_mut(&(key.exact.0, spec.name)) {
                entry.improved = Some((new_ms, effective_ms));
            }
        });
        counters.reexplore_improved.fetch_add(1, Ordering::Relaxed);
    } else {
        counters.reexplore_rejected.fetch_add(1, Ordering::Relaxed);
    }
}

/// What a compile worker does for one queued job.
#[derive(Debug)]
pub(crate) enum WallJobKind {
    /// Full FS exploration with the production guards.
    Explore,
    /// One region group of a sharded exploration. Whichever shard
    /// completes the join barrier runs the global tail (backfill +
    /// remote fusion + lowering), guards and publishes for the whole
    /// graph.
    ExploreShard { join: Arc<ShardJoin>, index: usize },
    /// A cross-class port or same-class shape retune already lowered by
    /// the dispatcher (the launch-dim re-tune is the cheap ~10% and
    /// must stay on the deterministic decision path); the worker runs
    /// the §7.2 never-negative guard and publishes the verdict.
    GuardPort { ported: OptimizedProgram, tier: &'static str },
    /// Drift-triggered re-exploration under calibrated cost parameters
    /// (carried inside `explore.cost` — a snapshot the dispatcher took
    /// at trigger time, so both executors explore under identical
    /// params). Publishes through [`publish_reexplored`]: the incumbent
    /// plan is replaced only when the candidate measures strictly
    /// faster.
    Reexplore { explore: ExploreOptions },
}

/// Join barrier for one graph's region-sharded exploration: shard
/// workers deposit their partial plans here; the last one to finish
/// takes them all and publishes. The groups are index-aligned with the
/// queued shard jobs.
#[derive(Debug)]
pub(crate) struct ShardJoin {
    pub groups: Vec<Vec<regions::Region>>,
    state: Mutex<ShardState>,
}

#[derive(Debug)]
struct ShardState {
    partials: Vec<Option<FusionPlan>>,
    done: usize,
}

impl ShardJoin {
    pub(crate) fn new(groups: Vec<Vec<regions::Region>>) -> Self {
        let n = groups.len();
        ShardJoin {
            groups,
            state: Mutex::new(ShardState { partials: vec![None; n], done: 0 }),
        }
    }

    /// Deposit shard `index`'s partial plan (`None` = the shard
    /// crashed). Returns every partial exactly once — to whichever
    /// caller completes the join.
    fn complete(
        &self,
        index: usize,
        partial: Option<FusionPlan>,
    ) -> Option<Vec<Option<FusionPlan>>> {
        let mut st = lock_recover(&self.state);
        st.partials[index] = partial;
        st.done += 1;
        if st.done == self.groups.len() {
            Some(std::mem::take(&mut st.partials))
        } else {
            None
        }
    }
}

/// One shard's crash-contained partial exploration: per-region
/// candidates + beam + absorption + pruning over the shard's region
/// group. Pure — both executors compute byte-identical partials, which
/// is what keeps sharded plan decisions executor-invariant.
pub(crate) fn shard_partial(
    w: &Workload,
    spec: &DeviceSpec,
    explore: &ExploreOptions,
    group: &[regions::Region],
) -> Option<FusionPlan> {
    let opts = pipeline::runtime_explore_opts(explore, w.loop_kind);
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        regions::explore_shard(&w.graph, spec, &opts, group)
    }))
    .ok()
}

/// Merge shard partials and run the global tail (canonical-order merge,
/// XLA backfill, remote fusion, lowering) with the production guards: a
/// crashed shard (`None` partial) or a panicking tail yields `None`,
/// which [`guard_and_publish`] turns into the pinned-fallback veto path
/// — exactly like a crashed monolithic exploration.
pub(crate) fn produce_sharded_candidate(
    w: &Workload,
    spec: &DeviceSpec,
    explore: &ExploreOptions,
    never_negative: bool,
    fallback: &Arc<OptimizedProgram>,
    partials: Vec<Option<FusionPlan>>,
) -> Option<Arc<OptimizedProgram>> {
    let mut merged = FusionPlan::default();
    for p in partials {
        let p = p?;
        merged.footprint_pruned += p.footprint_pruned;
        merged.patterns.extend(p.patterns);
    }
    let opts = pipeline::runtime_explore_opts(explore, w.loop_kind);
    let prog = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let plan = regions::finish_partitioned(&w.graph, spec, &opts, merged);
        let kernels =
            pipeline::lower_with_cost(&w.graph, &plan, spec, Tech::Fs, w.loop_kind, &opts.cost);
        OptimizedProgram { tech: Tech::Fs, plan, kernels }
    }))
    .ok()?;
    if never_negative {
        guard_never_negative(w, spec, prog, fallback)
    } else {
        Some(Arc::new(prog))
    }
}

/// One unit of background compilation. Carries the workload instance
/// itself (shape-polymorphic traffic instantiates templates per shape,
/// so a bare template index no longer identifies the graph).
#[derive(Debug)]
pub(crate) struct WallJob {
    pub w: Arc<Workload>,
    pub key: PlanKey,
    pub spec: DeviceSpec,
    pub fallback: Arc<OptimizedProgram>,
    pub fb_ms: f64,
    /// Virtual completion time of this compile — stored alongside the
    /// published plan so store contents match the virtual replay.
    pub ready_ms: f64,
    pub kind: WallJobKind,
}

/// One admitted task handed to its device's serving thread.
pub(crate) struct ServeJob {
    /// Fallback-serving session, hot-swapped mid-stream on publication.
    pub session: Session,
    pub device: usize,
    pub iterations: usize,
    pub fb_ms: f64,
    /// Plan identity to poll for, when the task has one in flight or
    /// already published (`None` for fallback-only admissions).
    pub fs: Option<(PlanKey, &'static str)>,
    /// Originating task id — the flight-recorder span key.
    pub task: usize,
}

/// Wall-clock accumulators owned by the serving threads.
#[derive(Debug)]
struct ServeTotals {
    served_gpu_ms: f64,
    device_busy_ms: Vec<f64>,
    regressions: usize,
}

/// Everything the pool hands back at teardown.
#[derive(Debug, Clone)]
pub(crate) struct WallTotals {
    pub served_gpu_ms: f64,
    pub device_busy_ms: Vec<f64>,
    pub regressions: usize,
    pub queue: QueueStats,
    /// Contention profile of the work-stealing deques, snapshotted at
    /// teardown.
    pub queue_lock: LockSnapshot,
    /// Publication-barrier profile: dispatcher stalls (await_plan /
    /// await_key) plus the shutdown quiesce, wall-measured.
    pub barrier: LockSnapshot,
    pub elapsed_ms: f64,
    /// Panics caught on compile workers, in observation order. The
    /// dispatcher re-raises them as one error after teardown.
    pub errors: Vec<String>,
}

/// Publication-barrier accounting: unpublished compile jobs per exact
/// graph key and per (structure, bucket) shape class. The bucket count
/// exists because a sibling shape's lookup outcome (`BucketHit`)
/// depends on whether this class already published *anything* in the
/// bucket — the dispatcher must not race a sibling's in-flight compile.
#[derive(Debug, Default)]
struct Inflight {
    exact: HashMap<u64, usize>,
    buckets: HashMap<(u64, u64), usize>,
}

/// State shared by the dispatcher, compile workers and serving threads.
struct Shared {
    queue: WorkStealingQueue<WallJob>,
    work_lock: Mutex<()>,
    work_cv: Condvar,
    shutdown: AtomicBool,
    /// The publication barrier the dispatcher waits on before a
    /// same-graph or same-bucket lookup.
    inflight: Mutex<Inflight>,
    inflight_cv: Condvar,
    store: Arc<SharedPlanStore>,
    latency: LatencyMap,
    explore: ExploreOptions,
    never_negative: bool,
    /// True when the calibration loop may re-publish improved plans —
    /// only then do serving threads keep polling after the first
    /// publication (the mid-stream hot-swap path).
    reexplore_live: bool,
    counters: Arc<FleetCounters>,
    /// Compile-worker panics, surfaced on the dispatcher at shutdown.
    errors: Mutex<Vec<String>>,
    /// Pool start time — the epoch wall-track event timestamps count
    /// from.
    epoch: Instant,
    /// Publication-barrier contention profile. Blocked time is measured
    /// by the waiters around the condvar loops, barrier-style.
    barrier: LockStats,
}

/// Microseconds since the pool epoch (wall-track event timestamps).
fn epoch_us(s: &Shared) -> f64 {
    s.epoch.elapsed().as_secs_f64() * 1e6
}

/// Flight-recorder span shape for one compile job: start event, end
/// event (`None` = the start kind is a closed X span), and whether the
/// job records a Publish instant on completion. Explores emit B/E
/// pairs; retunes and re-explorations emit one span; shard partials do
/// not publish (the join's final shard publishes for the graph).
fn compile_span(kind: &WallJobKind) -> (EventKind, Option<EventKind>, bool) {
    match kind {
        WallJobKind::Explore => (
            EventKind::ExploreStart { shard: 0, shards: 1 },
            Some(EventKind::ExploreEnd { shard: 0, shards: 1 }),
            true,
        ),
        WallJobKind::ExploreShard { join, index } => {
            let (shard, shards) = (*index as u32, join.groups.len() as u32);
            (
                EventKind::ExploreStart { shard, shards },
                Some(EventKind::ExploreEnd { shard, shards }),
                false,
            )
        }
        WallJobKind::GuardPort { tier, .. } => (EventKind::Retune { tier }, None, true),
        WallJobKind::Reexplore { .. } => (EventKind::Reexplore, None, true),
    }
}

/// A message on a device's serving channel: a task to serve, or the
/// fault-injection kill marker. `Kill` makes the serving thread exit
/// after draining everything queued before it — FIFO channel order is
/// what guarantees pre-kill work completes and the dispatcher's
/// placement exclusion guarantees nothing is sent after it.
pub(crate) enum ServeMsg {
    Job(ServeJob),
    Kill,
}

/// The running wall-clock substrate: compile workers + serving threads.
pub(crate) struct WallClockPool {
    shared: Arc<Shared>,
    serve_txs: Vec<mpsc::Sender<ServeMsg>>,
    compile_handles: Vec<JoinHandle<()>>,
    serve_handles: Vec<JoinHandle<()>>,
    totals: Arc<Mutex<ServeTotals>>,
}

impl WallClockPool {
    /// Spawn `threads` compile workers and one serving thread per
    /// registered device.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn start(
        threads: usize,
        devices: usize,
        store: Arc<SharedPlanStore>,
        latency: LatencyMap,
        counters: Arc<FleetCounters>,
        explore: ExploreOptions,
        never_negative: bool,
        reexplore_live: bool,
        recorder: Option<Arc<Recorder>>,
    ) -> WallClockPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: WorkStealingQueue::new(threads),
            work_lock: Mutex::new(()),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            inflight: Mutex::new(Inflight::default()),
            inflight_cv: Condvar::new(),
            store,
            latency,
            explore,
            never_negative,
            reexplore_live,
            counters,
            errors: Mutex::new(Vec::new()),
            epoch: Instant::now(),
            barrier: LockStats::new("publication_barrier"),
        });
        let compile_handles = (0..threads)
            .map(|i| {
                let s = Arc::clone(&shared);
                let obs = recorder
                    .as_ref()
                    .map(|r| (r.ring(), r.add_track(format!("compile-{i}"), WALL_PID)));
                std::thread::Builder::new()
                    .name(format!("fstitch-compile-{i}"))
                    .spawn(move || compile_loop(i, &s, obs))
                    .expect("spawn compile worker")
            })
            .collect();
        let totals = Arc::new(Mutex::new(ServeTotals {
            served_gpu_ms: 0.0,
            device_busy_ms: vec![0.0; devices],
            regressions: 0,
        }));
        let mut serve_txs = Vec::with_capacity(devices);
        let serve_handles = (0..devices)
            .map(|d| {
                let (tx, rx) = mpsc::channel::<ServeMsg>();
                serve_txs.push(tx);
                let s = Arc::clone(&shared);
                let t = Arc::clone(&totals);
                let obs = recorder
                    .as_ref()
                    .map(|r| (r.ring(), r.add_track(format!("serve-{d}"), WALL_PID)));
                std::thread::Builder::new()
                    .name(format!("fstitch-serve-{d}"))
                    .spawn(move || serve_loop(rx, &s, &t, obs))
                    .expect("spawn serving thread")
            })
            .collect();
        WallClockPool { shared, serve_txs, compile_handles, serve_handles, totals }
    }

    /// Microseconds since the pool epoch — timestamps for dispatcher-
    /// side wall-track events (barrier stalls).
    pub(crate) fn elapsed_us(&self) -> f64 {
        epoch_us(&self.shared)
    }

    /// Block until no compile for this exact graph is in flight — the
    /// narrow barrier used when a task's virtual serving window crosses
    /// its own compile's virtual ready time.
    pub(crate) fn await_key(&self, key: u64) {
        self.shared.barrier.acquire();
        let mut waited: Option<Instant> = None;
        let mut inflight = lock_recover(&self.shared.inflight);
        while inflight.exact.get(&key).copied().unwrap_or(0) > 0 {
            waited.get_or_insert_with(Instant::now);
            inflight = self
                .shared
                .inflight_cv
                .wait(inflight)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
        drop(inflight);
        if let Some(t0) = waited {
            self.shared.barrier.block(t0.elapsed());
        }
    }

    /// Block until no compile for this exact graph *or any sibling
    /// shape in its (structure, bucket) class* is in flight — the
    /// publication barrier that keeps wall-clock plan decisions
    /// (including the `BucketHit` tier) identical to the virtual
    /// replay's.
    pub(crate) fn await_plan(&self, key: PlanKey) {
        let bucket = (key.shape.structure, key.shape.bucket);
        self.shared.barrier.acquire();
        let mut waited: Option<Instant> = None;
        let mut inflight = lock_recover(&self.shared.inflight);
        while inflight.exact.get(&key.exact.0).copied().unwrap_or(0) > 0
            || inflight.buckets.get(&bucket).copied().unwrap_or(0) > 0
        {
            waited.get_or_insert_with(Instant::now);
            inflight = self
                .shared
                .inflight_cv
                .wait(inflight)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
        drop(inflight);
        if let Some(t0) = waited {
            self.shared.barrier.block(t0.elapsed());
        }
    }

    /// Route a compile job to its FNV-chosen owner deque and wake the
    /// pool; idle workers steal it FIFO-from-longest if the owner is
    /// busy.
    pub(crate) fn enqueue_compile(&self, job: WallJob) {
        {
            let mut inflight = lock_recover(&self.shared.inflight);
            *inflight.exact.entry(job.key.exact.0).or_insert(0) += 1;
            *inflight
                .buckets
                .entry((job.key.shape.structure, job.key.shape.bucket))
                .or_insert(0) += 1;
        }
        let workers = self.shared.queue.workers() as u64;
        let owner = (owner_hash(job.key.exact.0, job.spec.name) % workers) as usize;
        self.shared.queue.push(owner, job);
        let _guard = lock_recover(&self.shared.work_lock);
        self.shared.work_cv.notify_all();
    }

    /// Snapshot of the compile-worker panics caught so far — lets the
    /// dispatcher attribute a missing publication mid-trace to its real
    /// cause instead of failing a publication invariant.
    pub(crate) fn errors(&self) -> Vec<String> {
        lock_recover(&self.shared.errors).clone()
    }

    /// Hand an admitted task to its device's serving thread.
    pub(crate) fn send_serve(&self, job: ServeJob) {
        self.serve_txs[job.device]
            .send(ServeMsg::Job(job))
            .expect("serving thread alive until pool shutdown");
    }

    /// Deliver the fault-injection kill marker to a device's serving
    /// thread. Queued work ahead of the marker still drains (FIFO); the
    /// thread then exits, modelling a device dying mid-serve. A closed
    /// channel (thread already gone) is fine — kills are idempotent.
    pub(crate) fn send_kill(&self, device: usize) {
        let _ = self.serve_txs[device].send(ServeMsg::Kill);
    }

    /// Quiesce and tear down: wait for every compile to publish, stop
    /// the workers, close the serving channels, join everything, and
    /// return the wall-clock totals (including any caught worker
    /// panics, for the dispatcher to surface).
    pub(crate) fn shutdown(self) -> WallTotals {
        {
            self.shared.barrier.acquire();
            let mut waited: Option<Instant> = None;
            let mut inflight = lock_recover(&self.shared.inflight);
            while !inflight.exact.is_empty() || !inflight.buckets.is_empty() {
                waited.get_or_insert_with(Instant::now);
                inflight = self
                    .shared
                    .inflight_cv
                    .wait(inflight)
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
            }
            drop(inflight);
            if let Some(t0) = waited {
                self.shared.barrier.block(t0.elapsed());
            }
        }
        self.shared.shutdown.store(true, Ordering::Release);
        {
            let _guard = lock_recover(&self.shared.work_lock);
        }
        self.shared.work_cv.notify_all();
        for h in self.compile_handles {
            h.join().expect("compile worker exited cleanly");
        }
        drop(self.serve_txs); // closes the channels; threads drain + exit
        for h in self.serve_handles {
            h.join().expect("serving thread exited cleanly");
        }
        let totals = lock_recover(&self.totals);
        WallTotals {
            served_gpu_ms: totals.served_gpu_ms,
            device_busy_ms: totals.device_busy_ms.clone(),
            regressions: totals.regressions,
            queue: self.shared.queue.stats(),
            queue_lock: self.shared.queue.lock_profile(),
            barrier: self.shared.barrier.snapshot(),
            elapsed_ms: self.shared.epoch.elapsed().as_secs_f64() * 1e3,
            errors: lock_recover(&self.shared.errors).clone(),
        }
    }
}

/// Compile-worker thread body: drain own-LIFO, steal FIFO-from-longest,
/// park briefly when the fleet is quiet. A panicking job is caught and
/// recorded — the worker keeps draining, so the publication barrier and
/// the shutdown quiesce always complete; the dispatcher raises the
/// recorded panics as one loud error at teardown.
fn compile_loop(worker: usize, s: &Shared, obs: Option<(TrackHandle, u32)>) {
    loop {
        if let Some(job) = s.queue.pop(worker) {
            let key = job.key;
            let span = obs.as_ref().map(|_| compile_span(&job.kind));
            let t0_us = obs.as_ref().map(|_| epoch_us(s));
            let outcome =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run_compile(s, job)));
            let failed = outcome.is_err();
            if let Err(panic) = outcome {
                let msg = panic_text(&panic);
                lock_recover(&s.errors).push(format!(
                    "compile worker {worker} panicked on graph {:#x}: {msg}",
                    key.exact.0
                ));
            }
            if let (Some((ring, track)), Some((start, end, publishes))) = (obs.as_ref(), span) {
                let t0 = t0_us.unwrap_or(0.0);
                let t1 = epoch_us(s);
                let (track, id) = (*track, key.exact.0);
                match end {
                    Some(end) => {
                        ring.record(Event { track, id, kind: start, ts_us: t0, dur_us: 0.0 });
                        ring.record(Event { track, id, kind: end, ts_us: t1, dur_us: 0.0 });
                    }
                    None => {
                        let dur_us = t1 - t0;
                        ring.record(Event { track, id, kind: start, ts_us: t0, dur_us });
                    }
                }
                if publishes && !failed {
                    let kind = EventKind::Publish;
                    ring.record(Event { track, id, kind, ts_us: t1, dur_us: 0.0 });
                }
            }
            continue;
        }
        if s.shutdown.load(Ordering::Acquire) {
            return; // queue observed empty after shutdown
        }
        let guard = lock_recover(&s.work_lock);
        if s.queue.is_empty() && !s.shutdown.load(Ordering::Acquire) {
            let _ = s
                .work_cv
                .wait_timeout(guard, Duration::from_millis(2))
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }
}

/// Best-effort panic payload rendering for the surfaced error report.
fn panic_text(panic: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = panic.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Releases one inflight count (exact + bucket) for a graph when
/// dropped — on the normal path *and* during unwinding, so a panicking
/// compile turns into a surfaced error instead of wedging every
/// dispatcher wait on its graph or bucket forever.
struct InflightRelease<'a> {
    s: &'a Shared,
    key: PlanKey,
}

impl Drop for InflightRelease<'_> {
    fn drop(&mut self) {
        // Recover the map even if a previous panic poisoned the lock:
        // the count decrement must always happen.
        let mut inflight = lock_recover(&self.s.inflight);
        let bucket = (self.key.shape.structure, self.key.shape.bucket);
        match inflight.exact.get_mut(&self.key.exact.0) {
            Some(n) if *n > 1 => *n -= 1,
            _ => {
                inflight.exact.remove(&self.key.exact.0);
            }
        }
        match inflight.buckets.get_mut(&bucket) {
            Some(n) if *n > 1 => *n -= 1,
            _ => {
                inflight.buckets.remove(&bucket);
            }
        }
        drop(inflight);
        self.s.inflight_cv.notify_all();
    }
}

/// Execute one compile job and publish its outcome (plan + latency into
/// the shared store/map, veto counters, publication-barrier release).
fn run_compile(s: &Shared, job: WallJob) {
    let WallJob { w, key, spec, fallback, fb_ms, ready_ms, kind } = job;
    // Publication-barrier release happens in this guard's Drop, even if
    // the pipeline below panics.
    let _release = InflightRelease { s, key };
    let kind = match kind {
        WallJobKind::ExploreShard { join, index } => {
            // Shard jobs publish once, from whichever worker completes
            // the join; the other shards only deposit partials (their
            // inflight count still releases via the guard above, so the
            // dispatcher's publication barrier holds until the join
            // publishes).
            let partial = shard_partial(&w, &spec, &s.explore, &join.groups[index]);
            if let Some(partials) = join.complete(index, partial) {
                let candidate = produce_sharded_candidate(
                    &w,
                    &spec,
                    &s.explore,
                    s.never_negative,
                    &fallback,
                    partials,
                );
                guard_and_publish(
                    &w,
                    &spec,
                    key,
                    candidate,
                    &fallback,
                    fb_ms,
                    ready_ms,
                    &s.store,
                    &s.latency,
                    &s.counters,
                );
            }
            return;
        }
        WallJobKind::Reexplore { explore } => {
            let candidate = produce_reexplored(&w, &spec, &explore, s.never_negative, &fallback);
            publish_reexplored(
                &w,
                &spec,
                key,
                candidate,
                ready_ms,
                &s.store,
                &s.latency,
                &s.counters,
            );
            return;
        }
        other => other,
    };
    let candidate = produce_candidate(&w, &spec, &s.explore, s.never_negative, &fallback, kind);
    guard_and_publish(
        &w,
        &spec,
        key,
        candidate,
        &fallback,
        fb_ms,
        ready_ms,
        &s.store,
        &s.latency,
        &s.counters,
    );
}

/// Serving-thread body for one device: serve each task's iterations on
/// the session's current program, hot-swapping the moment the compile
/// pool publishes the plan this task is waiting on.
fn serve_loop(
    rx: mpsc::Receiver<ServeMsg>,
    s: &Shared,
    totals: &Mutex<ServeTotals>,
    obs: Option<(TrackHandle, u32)>,
) {
    while let Ok(msg) = rx.recv() {
        let job = match msg {
            ServeMsg::Job(job) => job,
            // Injected fault: the device dies. Everything queued before
            // the marker has already drained; the dispatcher never
            // routes to this device after the kill time.
            ServeMsg::Kill => break,
        };
        let t0_us = obs.as_ref().map(|_| epoch_us(s));
        let mut swapped_us: Option<f64> = None;
        let mut fs_ms: Option<f64> = None;
        // True once this task's latency entry can no longer change:
        // immediately after the first publication when the calibration
        // loop is off (nothing re-publishes — the serving threads stay
        // off the shared lock, as before), or once the single allowed
        // drift-triggered improvement has been observed.
        let mut settled = job.fs.is_none();
        let mut served = 0.0f64;
        for _ in 0..job.iterations {
            if !settled {
                if let Some((key, class)) = job.fs {
                    // Lock-free epoch reads: the per-iteration poll and
                    // the hot-swap lookup never touch a mutex — the
                    // `plan_store_read` profile row proves it per run.
                    let published = s.latency.get(&(key.exact.0, class));
                    if let Some(pl) = published {
                        let current = pl.latest();
                        if fs_ms != Some(current) {
                            if let PlanLookup::Hit { prog, .. } = s.store.lookup_serve(key, class) {
                                // A vetoed compile publishes the pinned
                                // fallback — the session keeps serving
                                // it and must not report itself
                                // optimized.
                                if prog.tech == Tech::Fs {
                                    job.session.hot_swap(prog);
                                    if obs.is_some() && swapped_us.is_none() {
                                        swapped_us = Some(epoch_us(s));
                                    }
                                }
                            }
                            fs_ms = Some(current);
                        }
                        // One re-exploration per (graph, class): after
                        // an improvement lands the entry is final.
                        settled = !s.reexplore_live || pl.improved.is_some();
                    }
                }
            }
            let iter = fs_ms.unwrap_or(job.fb_ms);
            job.session.metrics.record_iteration(iter);
            served += iter;
        }
        if let Some((ring, track)) = obs.as_ref() {
            let (track, id) = (*track, job.task as u64);
            if let Some(ts_us) = swapped_us {
                let kind = EventKind::HotSwap;
                ring.record(Event { track, id, kind, ts_us, dur_us: 0.0 });
            }
            let kind = EventKind::Serve { device: job.device as u32 };
            let ts_us = t0_us.unwrap_or(0.0);
            let dur_us = epoch_us(s) - ts_us;
            ring.record(Event { track, id, kind, ts_us, dur_us });
        }
        let fb_total = job.fb_ms * job.iterations as f64;
        let mut t = lock_recover(totals);
        t.served_gpu_ms += served;
        t.device_busy_ms[job.device] += served;
        if served > fb_total + 1e-9 {
            t.regressions += 1; // the guard must make this unreachable
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ServiceMetrics;
    use crate::graph::{DType, Graph, Shape};
    use crate::pipeline::{optimize, Tech};
    use crate::workloads::{blocks, Mode};

    fn ln_workload() -> Workload {
        let mut g = Graph::new("LN");
        let x = g.param(Shape::new(vec![1024, 256]), DType::F32, "x");
        let _ = blocks::layer_norm(&mut g, x, "ln");
        Workload {
            name: "LN",
            field: "micro",
            mode: Mode::Infer,
            batch: 1,
            loop_kind: LoopKind::None,
            graph: g,
        }
    }

    #[test]
    fn executor_kind_defaults_to_virtual() {
        assert_eq!(ExecutorKind::default(), ExecutorKind::VirtualTime);
        assert_eq!(ExecutorKind::VirtualTime.name(), "virtual");
        assert_eq!(ExecutorKind::WallClock { threads: 2 }.name(), "wallclock");
    }

    #[test]
    fn pool_explores_publishes_and_serves_with_hot_swap() {
        let w = ln_workload();
        let key = PlanKey::of(&w.graph);
        let spec = DeviceSpec::v100();
        let explore = ExploreOptions::default();
        let fallback = Arc::new(optimize(&w, &spec, Tech::Xla, &explore));
        let fb_ms = iter_ms(&spec, &fallback, w.loop_kind);

        let store = Arc::new(SharedPlanStore::new());
        let latency: LatencyMap = LatencyTable::shared();
        let counters = Arc::new(FleetCounters::default());
        let pool = WallClockPool::start(
            2,
            1,
            Arc::clone(&store),
            Arc::clone(&latency),
            Arc::clone(&counters),
            explore,
            true,
            false,
            None,
        );

        pool.enqueue_compile(WallJob {
            w: Arc::new(w.clone()),
            key,
            spec: spec.clone(),
            fallback: Arc::clone(&fallback),
            fb_ms,
            ready_ms: 42.0,
            kind: WallJobKind::Explore,
        });
        // The publication barrier blocks until the worker thread has
        // inserted the plan and its latency — both the exact-key and
        // the bucket-level waits must release.
        pool.await_plan(key);
        pool.await_key(key.exact.0);
        let pl = latency.get(&(key.exact.0, spec.name));
        let ms = pl.expect("latency published").latest();
        match store.lookup(key, spec.name) {
            PlanLookup::Hit { ready_ms, .. } => assert_eq!(ready_ms, 42.0),
            other => panic!("expected published hit, got {other:?}"),
        }

        // Serve a task against the published plan: the serving thread
        // must hot-swap the session away from the fallback.
        let metrics = Arc::new(ServiceMetrics::new());
        let session = Session::serving_fallback(
            Arc::clone(&fallback),
            Arc::clone(&metrics),
            w.loop_kind,
        );
        pool.send_serve(ServeJob {
            session,
            device: 0,
            iterations: 5,
            fb_ms,
            fs: Some((key, spec.name)),
            task: 0,
        });
        let totals = pool.shutdown();
        assert_eq!(metrics.iterations(), 5);
        assert!((totals.served_gpu_ms - 5.0 * ms).abs() < 1e-9, "all 5 iterations optimized");
        assert_eq!(totals.regressions, 0);
        assert_eq!(totals.device_busy_ms.len(), 1);
        assert!(totals.elapsed_ms > 0.0);
        assert!(totals.errors.is_empty(), "no worker panicked: {:?}", totals.errors);
        // The explore ran on a real worker thread through the queue.
        let q = totals.queue;
        assert_eq!(q.pushes, 1);
        assert_eq!(q.local_pops + q.steals, 1);
        // Lock profiles are snapshotted at teardown: the barrier was
        // acquired by await_plan, await_key and the shutdown quiesce.
        assert_eq!(totals.barrier.name, "publication_barrier");
        assert!(totals.barrier.acquisitions >= 3, "{:?}", totals.barrier);
        assert_eq!(totals.queue_lock.name, "work_queue");
        assert!(totals.queue_lock.acquisitions > 0);
    }

    #[test]
    fn panicking_compile_job_surfaces_instead_of_deadlocking() {
        // A compile worker that panics mid-job must release the
        // publication barrier (no dispatcher deadlock), keep the pool
        // alive, and surface the panic in the teardown totals. The
        // ExploreShard kind with an out-of-range group index panics
        // deterministically inside run_compile.
        let w = ln_workload();
        let key = PlanKey::of(&w.graph);
        let spec = DeviceSpec::v100();
        let explore = ExploreOptions::default();
        let fallback = Arc::new(optimize(&w, &spec, Tech::Xla, &explore));
        let fb_ms = iter_ms(&spec, &fallback, w.loop_kind);

        let store = Arc::new(SharedPlanStore::new());
        let latency: LatencyMap = LatencyTable::shared();
        let counters = Arc::new(FleetCounters::default());
        let pool = WallClockPool::start(
            2,
            1,
            Arc::clone(&store),
            Arc::clone(&latency),
            Arc::clone(&counters),
            explore,
            true,
            false,
            None,
        );
        let join = Arc::new(ShardJoin::new(vec![]));
        pool.enqueue_compile(WallJob {
            w: Arc::new(w.clone()),
            key,
            spec: spec.clone(),
            fallback: Arc::clone(&fallback),
            fb_ms,
            ready_ms: 1.0,
            kind: WallJobKind::ExploreShard { join, index: 0 },
        });
        // The barrier must release even though the job panicked...
        pool.await_plan(key);
        // ...and the pool still runs follow-up work to completion.
        pool.enqueue_compile(WallJob {
            w: Arc::new(w.clone()),
            key,
            spec: spec.clone(),
            fallback: Arc::clone(&fallback),
            fb_ms,
            ready_ms: 2.0,
            kind: WallJobKind::Explore,
        });
        pool.await_plan(key);
        assert!(matches!(store.lookup(key, spec.name), PlanLookup::Hit { .. }));
        let totals = pool.shutdown();
        assert_eq!(totals.errors.len(), 1, "the panic must be recorded: {:?}", totals.errors);
        assert!(totals.errors[0].contains("panicked"), "{:?}", totals.errors);
    }

    #[test]
    fn killed_shard_worker_drains_other_shards() {
        // Cluster-scale failure containment: every shard dispatcher
        // owns its own pool (workers, publication barrier, epoch
        // store), so killing one shard's compile worker mid-trace — a
        // deterministic panic via an out-of-range shard-join index —
        // must leave the other shard's pipeline untouched: it still
        // explores, publishes, hot-swaps and drains to completion, and
        // only the dead shard reports the panic. All the per-shard
        // structures recover through `lock_recover`, so the poisoned
        // shard itself also quiesces instead of wedging its barrier.
        let w = ln_workload();
        let key = PlanKey::of(&w.graph);
        let spec = DeviceSpec::v100();
        let explore = ExploreOptions::default();
        let fallback = Arc::new(optimize(&w, &spec, Tech::Xla, &explore));
        let fb_ms = iter_ms(&spec, &fallback, w.loop_kind);

        let shards: Vec<WallClockPool> = (0..2)
            .map(|_| {
                WallClockPool::start(
                    1,
                    1,
                    Arc::new(SharedPlanStore::new()),
                    LatencyTable::shared(),
                    Arc::new(FleetCounters::default()),
                    explore.clone(),
                    true,
                    false,
                    None,
                )
            })
            .collect();

        // Shard 0's only worker dies on this job.
        let join = Arc::new(ShardJoin::new(vec![]));
        shards[0].enqueue_compile(WallJob {
            w: Arc::new(w.clone()),
            key,
            spec: spec.clone(),
            fallback: Arc::clone(&fallback),
            fb_ms,
            ready_ms: 1.0,
            kind: WallJobKind::ExploreShard { join, index: 0 },
        });
        // Shard 1 keeps taking healthy traffic end to end.
        shards[1].enqueue_compile(WallJob {
            w: Arc::new(w.clone()),
            key,
            spec: spec.clone(),
            fallback: Arc::clone(&fallback),
            fb_ms,
            ready_ms: 3.0,
            kind: WallJobKind::Explore,
        });
        shards[1].await_plan(key);
        let metrics = Arc::new(ServiceMetrics::new());
        let session = Session::serving_fallback(
            Arc::clone(&fallback),
            Arc::clone(&metrics),
            w.loop_kind,
        );
        shards[1].send_serve(ServeJob {
            session,
            device: 0,
            iterations: 4,
            fb_ms,
            fs: Some((key, spec.name)),
            task: 0,
        });

        let mut totals = Vec::new();
        for shard in shards {
            totals.push(shard.shutdown());
        }
        assert_eq!(totals[0].errors.len(), 1, "dead shard surfaces its panic");
        assert!(totals[0].errors[0].contains("panicked"), "{:?}", totals[0].errors);
        assert!(totals[1].errors.is_empty(), "healthy shard untouched: {:?}", totals[1].errors);
        assert_eq!(metrics.iterations(), 4, "healthy shard drained its serve queue");
        assert_eq!(totals[1].regressions, 0);
        let q = &totals[1].queue;
        assert!(q.pushes == 1 && q.local_pops + q.steals == 1);
    }
}
