//! The fleet service: multi-device, multi-tenant serving on top of the
//! coordinator's primitives.
//!
//! One [`FleetService`] owns a [`DeviceRegistry`], a bounded
//! compile-worker pool fed through a [`WorkStealingQueue`], a
//! [`SharedPlanStore`] making plans portable across device classes *and*
//! across sibling shapes, and an [`AdmissionController`]. A seeded task
//! trace (see [`super::sim`]) is replayed through one of two executors
//! (see [`ExecutorKind`] and [`super::executor`]):
//!
//! * **Virtual time** (default): serving latencies come from the
//!   per-device timing simulator, compile latencies from a
//!   deterministic cost model, so two replays of the same trace are
//!   byte-identical — the test harness.
//! * **Wall clock**: the same trace with the same decision plane, but
//!   full explorations and port guards run on real compile-worker
//!   threads draining the shared work-stealing queue, and every device
//!   serves tasks on its own thread, hot-swapping to plans the moment
//!   they are published (§6's async compilation on actual hardware
//!   parallelism). Plan decisions and store traffic converge to the
//!   virtual replay's; measured latency fields differ.
//!
//! Either way, every *program* on the path (fallbacks, explored plans,
//! ported plans, shape-retuned plans) is produced by the real pipeline:
//! `baselines::xla`, `explorer::explore`, `codegen::tuner`,
//! `pipeline::port_program`, `pipeline::reshape_program`, and the
//! coordinator's never-negative guard.
//!
//! Per task the flow mirrors §6/§7.2 at fleet scale:
//!
//! 1. **Instantiate** the task's template at its requested
//!    (batch, seq) — shape-polymorphic traffic makes this a distinct
//!    graph per shape ([`TemplateFamily`]).
//! 2. **Place** on the least-loaded serving slot (mixed V100/T4).
//! 3. **Admit** — reject on deep backlog; under compile saturation
//!    serve the fallback without enqueueing new optimization work.
//! 4. **Resolve a plan** through the store's three reuse tiers — exact
//!    hit (serve optimized, possibly hot-swapping when the producing
//!    compile finishes mid-task), a cross-class *port* or same-class
//!    shape-bucket *retune* (launch-dim re-tune only, ~10% of a
//!    compile), or a full exploration on the worker pool.
//! 5. **Serve** iterations, fallback until the plan is ready,
//!    optimized after — never-negative guarded, so a task can never
//!    regress past its fallback.

use super::admission::{AdmissionConfig, AdmissionController, AdmissionTick, AdmitDecision};
use super::executor::{
    guard_and_publish, iter_ms, produce_candidate, produce_reexplored, produce_sharded_candidate,
    publish_reexplored, shard_partial, ExecutorKind, FleetCounters, LatencyMap, LatencyTable,
    PublishedLatency, ServeJob, ShardJoin, WallClockPool, WallJob, WallJobKind,
};
use super::metrics::{DeviceUtilization, FleetReport, TenantQos};
use super::queue::{owner_hash, QueueStats, WorkStealingQueue};
use super::registry::{ChurnPlan, DeviceRegistry};
use super::sim::{FleetTask, TaskShape, TemplateFamily, TenantTier};
use super::store::{PlanKey, PlanLookup, SharedPlanStore};
use crate::codegen::calibrate::{self, Calibrator};
use crate::coordinator::{ServiceMetrics, Session};
use crate::explorer::{regions, ExploreOptions};
use crate::gpu::DeviceSpec;
use crate::obs::{
    CompileStage, Event, EventKind, LockSnapshot, Recorder, StageAccum, TraceDump, TrackHandle,
    VIRTUAL_PID, WALL_PID,
};
use crate::pipeline::{self, OptimizedProgram, Tech};
use crate::util::hash::{fnv1a_u64, FNV_OFFSET};
use crate::util::summarize;
use crate::workloads::Workload;
use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Fleet configuration.
#[derive(Debug, Clone)]
pub struct FleetOptions {
    pub registry: DeviceRegistry,
    /// Bounded compile pool size (the throttle on FS exploration) in
    /// the *virtual admission model*; the wall-clock executor's real
    /// thread count is chosen separately by [`ExecutorKind::WallClock`]
    /// so decisions stay executor-independent.
    pub compile_workers: usize,
    pub admission: AdmissionConfig,
    /// Batch admission backpressure per dispatcher tick: the pending
    /// compile count is sampled once per this many ms of virtual time
    /// and reused for every decision inside the window ([`AdmissionTick`]).
    /// `0.0` samples on every task — the unbatched behavior.
    pub admission_tick_ms: f64,
    /// Control-plane fan-out for [`super::cluster::ShardedFleetService`]:
    /// tasks route to one of `shards` independent dispatchers by their
    /// graph's structure key. A plain [`FleetService`] ignores the
    /// field — it *is* the one-shard case.
    pub shards: usize,
    pub explore: ExploreOptions,
    /// §7.2 production guard: never swap in a plan estimated slower
    /// than the fallback on its device.
    pub never_negative: bool,
    /// Virtual compile-cost model: a full exploration costs
    /// `base + per_op × |V|` ms of worker time.
    pub explore_cost_base_ms: f64,
    pub explore_cost_per_op_ms: f64,
    /// A launch-dimension-only retune — cross-class port or same-class
    /// shape-bucket retune — costs this fraction of the full
    /// exploration.
    pub port_cost_frac: f64,
    /// Region-shard fan-out for full explorations: a graph whose
    /// fusible subgraph splits into multiple independent regions is
    /// compiled as up to this many queue sub-jobs joined at a barrier,
    /// so the worker pool parallelizes *within* one graph. `1` keeps
    /// the monolithic compile jobs (one exploration = one queue item).
    pub compile_shards: usize,
    /// Execution substrate for [`FleetService::run_trace`].
    pub executor: ExecutorKind,
    /// Close the predicted-vs-measured loop: record (modeled, measured)
    /// kernel-time pairs as the fleet serves, fit per-device-class
    /// [`crate::gpu::CostParams`] corrections, and re-explore graphs
    /// whose measured/predicted ratio drifts past `drift_bound` under
    /// the calibrated params (publishing only strictly-better plans).
    pub calibrate: bool,
    /// Re-exploration trigger: fire when measured/predicted leaves
    /// `[1/drift_bound, drift_bound]` (must be ≥ 1).
    pub drift_bound: f64,
    /// Kernel samples a device class needs before its fit is trusted.
    pub min_calibration_samples: usize,
    /// Flight-recorder tracing: per-task lifecycle spans, stage
    /// attribution and lock-contention profiling folded into the
    /// report's `observability` section (exportable as a Chrome trace
    /// via [`FleetService::trace_dump`]). Recording never perturbs
    /// scheduling decisions; forced off without the `obs` cargo
    /// feature.
    pub observe: bool,
    /// Device churn: synthesize a seeded [`ChurnPlan`] for the trace —
    /// devices leave mid-trace and later rejoin, and in-flight sessions
    /// migrate off departing devices (plan following the session
    /// through the port/reshape feasibility ladder, degrading to the
    /// destination fallback when infeasible).
    pub churn: bool,
    /// Explicit churn schedule — takes precedence over the synthesized
    /// plan, so tests and replays can pin exact departure times.
    pub churn_plan: Option<ChurnPlan>,
    /// Fault injection: the synthesized churn plan also kills one
    /// device mid-serve (no rejoin), and the wall-clock executor
    /// delivers a real kill marker to that device's serving thread.
    pub inject_faults: bool,
}

impl Default for FleetOptions {
    fn default() -> Self {
        FleetOptions {
            registry: DeviceRegistry::mixed(2, 2, 2),
            compile_workers: 2,
            admission: AdmissionConfig::default(),
            admission_tick_ms: 0.0,
            shards: 1,
            explore: ExploreOptions::default(),
            never_negative: true,
            explore_cost_base_ms: 10.0,
            explore_cost_per_op_ms: 1.0,
            port_cost_frac: 0.1,
            compile_shards: 1,
            executor: ExecutorKind::VirtualTime,
            calibrate: false,
            drift_bound: 1.4,
            min_calibration_samples: 8,
            observe: false,
            churn: false,
            churn_plan: None,
            inject_faults: false,
        }
    }
}

/// A queued compile/port job (identity used for routing + debugging).
#[derive(Debug, Clone, Copy)]
struct CompileJob {
    key: u64,
    class: &'static str,
}

/// Per-iteration latency of a task's FS plan: known immediately (store
/// hit, or a virtual-mode inline compile) or pending publication by a
/// wall-clock compile worker. A known entry carries the full
/// [`PublishedLatency`] so a drift-triggered improvement applies from
/// its virtual effective time, not retroactively.
enum FsLatency {
    Known(PublishedLatency),
    Pending { key: u64, class: &'static str },
}

/// Dispatcher-side record of one in-flight session migration (churn
/// Leave or injected Kill on its device): where the session landed and
/// the split point for the virtual busy/fallback accounting.
struct Migration {
    to_d: usize,
    to_s: usize,
    /// Virtual time the session left the source device.
    at_ms: f64,
    /// Iterations completed on the source before the move.
    iters_before: usize,
    /// Virtual GPU-ms served on the source before the move.
    served_before: f64,
    /// Destination-class fallback (the wall-clock executor's migrated
    /// session serves it until — unless — the plan followed).
    fallback: Arc<OptimizedProgram>,
    fb_ms: f64,
}

/// Per-tenant QoS ledger (virtual bookkeeping, dispatcher-only writes —
/// identical across executors by construction).
#[derive(Debug, Default)]
struct TenantAccum {
    tasks: usize,
    served: usize,
    shed: usize,
    rejected: usize,
    sla_violations: usize,
    e2e_ms: Vec<f64>,
}

/// One instantiated (template, shape): the workload the fleet serves
/// plus its two-level plan-store identity.
#[derive(Clone)]
struct Instance {
    w: Arc<Workload>,
    key: PlanKey,
}

/// Which launch-dimension-only reuse tier a retune job belongs to. The
/// two tiers share one compile path ([`FleetService::run_retune`]) and
/// differ only in the lowering entry point and the counters they feed.
#[derive(Debug, Clone, Copy)]
enum RetuneTier {
    /// Cross-class port of the exact graph
    /// ([`pipeline::port_program`]).
    Port,
    /// Same-structure sibling-shape retune inside one power-of-two
    /// bucket ([`pipeline::reshape_program`]).
    Bucket,
}

impl RetuneTier {
    /// The dispatcher-side lowering for this tier (launch dims only;
    /// feasibility re-checked on the target class/shape).
    fn lower(
        self,
        w: &Workload,
        source: &OptimizedProgram,
        spec: &DeviceSpec,
    ) -> Option<OptimizedProgram> {
        match self {
            RetuneTier::Port => pipeline::port_program(&w.graph, source, spec, w.loop_kind),
            RetuneTier::Bucket => pipeline::reshape_program(&w.graph, source, spec, w.loop_kind),
        }
    }

    /// (jobs, failures) counters this tier reports into.
    fn counters(self, c: &FleetCounters) -> (&AtomicUsize, &AtomicUsize) {
        match self {
            RetuneTier::Port => (&c.port_jobs, &c.port_failures),
            RetuneTier::Bucket => (&c.bucket_jobs, &c.bucket_failures),
        }
    }

    /// Flight-recorder span label for this tier's retune events.
    fn name(self) -> &'static str {
        match self {
            RetuneTier::Port => "port",
            RetuneTier::Bucket => "bucket",
        }
    }

    /// The stage this tier's compile latency attributes to.
    fn stage(self) -> CompileStage {
        match self {
            RetuneTier::Port => CompileStage::Port,
            RetuneTier::Bucket => CompileStage::Bucket,
        }
    }
}

/// Events retained per flight-recorder ring before the oldest are
/// overwritten (per writer thread; overflow is counted, not grown).
const OBS_RING_CAP: usize = 1 << 16;

/// Flight-recorder state for one fleet run: the shared [`Recorder`]
/// plus the track ids the dispatcher records on. Virtual tracks carry
/// decision-plane spans derived from the virtual clocks — identical
/// across executors and replays; the barrier track carries
/// wall-measured dispatcher stalls ([`WALL_PID`]).
struct FleetObs {
    recorder: Arc<Recorder>,
    /// The dispatcher thread's ring (all dispatcher-side tracks).
    ring: TrackHandle,
    /// Admission / publication / drift events (virtual timeline).
    dispatcher: u32,
    /// Per device instance: queue-wait and serve spans.
    devices: Vec<u32>,
    /// Per *virtual* compile worker: explore/retune spans on the
    /// virtual timeline (wall workers record their own wall tracks).
    compile: Vec<u32>,
    /// Dispatcher publication-barrier stalls, wall clock.
    barrier: u32,
    /// Stage-attributed latency accumulator for the report.
    stages: StageAccum,
}

/// Build the run's flight recorder when tracing is requested (and the
/// `obs` feature is compiled in): one virtual track per device and per
/// virtual compile worker, a dispatcher track, and a wall-clock lane
/// for dispatcher barrier stalls.
fn build_fleet_obs(opts: &FleetOptions, n_dev: usize) -> Option<FleetObs> {
    if !opts.observe || !crate::obs::recorder::ENABLED {
        return None;
    }
    let recorder = Arc::new(Recorder::new(OBS_RING_CAP));
    let dispatcher = recorder.add_track("dispatcher", VIRTUAL_PID);
    let devices = (0..n_dev)
        .map(|d| recorder.add_track(format!("device-{d}"), VIRTUAL_PID))
        .collect();
    let compile = (0..opts.compile_workers)
        .map(|w| recorder.add_track(format!("compile-{w}"), VIRTUAL_PID))
        .collect();
    let barrier = recorder.add_track("dispatcher-barrier", WALL_PID);
    Some(FleetObs {
        ring: recorder.ring(),
        recorder,
        dispatcher,
        devices,
        compile,
        barrier,
        stages: StageAccum::new(n_dev),
    })
}

/// The multi-device serving layer.
pub struct FleetService {
    opts: FleetOptions,
    families: Vec<TemplateFamily>,
    /// (template, shape) → instantiated workload + plan key, built
    /// lazily on first arrival and reused for every sibling task.
    instances: HashMap<(usize, TaskShape), Instance>,
    store: Arc<SharedPlanStore>,
    admission: AdmissionController,
    /// Per-tick pending-compile sampling for batched admission.
    admission_tick: AdmissionTick,
    /// FNV-1a fold of the arrival-ordered decision stream (task id,
    /// admission verdict, placement, reuse tier, wait bits). Everything
    /// folded is virtual bookkeeping, so the digest is
    /// executor-invariant — the cluster layer pins it per shard.
    decision_digest: u64,
    queue: WorkStealingQueue<CompileJob>,
    /// Virtual time each compile worker frees up.
    worker_free_ms: Vec<f64>,
    /// Virtual finish time of every compile job ever scheduled (pending
    /// count = finishes still in the future).
    compile_finishes: Vec<f64>,
    /// Per device instance: serving slots' next-free times.
    slots: Vec<Vec<f64>>,
    device_tasks: Vec<usize>,
    device_busy_ms: Vec<f64>,
    /// Per device instance: iteration latencies (coordinator metrics,
    /// aggregated fleet-wide in the report). `Arc` so wall-clock
    /// serving sessions can record into them from their device thread.
    device_metrics: Vec<Arc<ServiceMetrics>>,
    /// Exact graph key + class → fallback program + per-iteration ms.
    fallbacks: HashMap<(u64, &'static str), (Arc<OptimizedProgram>, f64)>,
    /// (graph key, class) → per-iteration ms of the stored program;
    /// shared with the wall-clock pool, where an entry's appearance is
    /// the publication signal.
    latency: LatencyMap,
    /// Explore/port/retune/veto accounting shared with the compile pool.
    counters: Arc<FleetCounters>,
    /// Online cost-model calibration state. Written only by the
    /// dispatcher — in arrival order, at per-graph publication barriers
    /// — so fits and the drift decisions they gate are byte-identical
    /// across executors.
    calibrator: Calibrator,
    /// (graph key, class) whose published program has been sampled.
    sampled: HashSet<(u64, &'static str)>,
    /// (graph key, class) flagged drifted at first observation but not
    /// yet re-explored — deferred by compile backpressure or an
    /// unfitted class, retried on this graph's later hits.
    drift_pending: HashSet<(u64, &'static str)>,
    /// (graph key, class) already re-explored (one drift-triggered
    /// recompile per pair — the loop must terminate).
    reexplored: HashSet<(u64, &'static str)>,
    /// The run's churn schedule (empty ⇒ churn-free, the default).
    churn: ChurnPlan,
    /// Wall-clock only: whether each device's kill marker has been
    /// delivered to its serving thread.
    kill_signaled: Vec<bool>,
    /// Live wall-clock substrate during a `run_trace` (None ⇒ virtual).
    pool: Option<WallClockPool>,
    /// Flight recorder + stage accumulator (None ⇒ tracing off — the
    /// default, and forced off without the `obs` cargo feature).
    obs: Option<FleetObs>,
    // Accumulators.
    submitted: usize,
    regressions: usize,
    /// In-flight session migrations forced by churn/faults.
    migrations: usize,
    /// Migrations whose plan could not follow the session (port or
    /// reshape infeasible on the destination) and degraded to fallback.
    migrations_degraded: usize,
    /// Served tasks whose queue wait blew their tenant tier's SLA.
    sla_violations: usize,
    /// Per-tenant QoS ledgers (BTreeMap: reports iterate in tenant id
    /// order, deterministically).
    tenant_qos: BTreeMap<u32, TenantAccum>,
    served_gpu_ms: f64,
    fallback_gpu_ms: f64,
    waits_ms: Vec<f64>,
    /// Per compile job (explore, port or shape retune): enqueue →
    /// virtual ready, join barrier included for sharded explorations.
    /// Virtual bookkeeping in both executors, so the reported
    /// percentiles are executor-invariant.
    compile_ms: Vec<f64>,
    /// Distinct exact graphs the trace touched (arrivals, pre-admission
    /// — deterministic across executors).
    seen_graphs: HashSet<u64>,
    /// Distinct (structure, bucket) classes the trace touched.
    seen_buckets: HashSet<(u64, u64)>,
    makespan_ms: f64,
    /// Queue accounting of the torn-down wall-clock pool, when one ran.
    wall_queue: Option<QueueStats>,
    /// Deque + publication-barrier contention profiles of the torn-down
    /// pool, when one ran (a virtual replay reports its own zeros).
    wall_queue_lock: Option<LockSnapshot>,
    wall_barrier: Option<LockSnapshot>,
    wall_elapsed_ms: f64,
}

impl FleetService {
    /// Build a fleet over a fixed-shape template population (tasks
    /// reference templates by index; see [`super::sim::build_templates`]).
    pub fn new(opts: FleetOptions, templates: Vec<Workload>) -> Self {
        Self::with_families(opts, templates.into_iter().map(TemplateFamily::Fixed).collect())
    }

    /// Build a fleet over a (possibly shape-polymorphic) template
    /// family population (see [`super::sim::build_template_families`]).
    pub fn with_families(opts: FleetOptions, families: Vec<TemplateFamily>) -> Self {
        assert!(!opts.registry.is_empty(), "fleet needs at least one device");
        assert!(opts.compile_workers >= 1, "fleet needs at least one compile worker");
        assert!(opts.compile_shards >= 1, "compile fan-out needs at least one shard");
        assert!(!families.is_empty(), "fleet needs at least one template");
        let slots = opts
            .registry
            .devices()
            .iter()
            .map(|d| vec![0.0f64; d.capacity])
            .collect();
        let n_dev = opts.registry.len();
        let obs = build_fleet_obs(&opts, n_dev);
        FleetService {
            admission: AdmissionController::new(opts.admission.clone()),
            admission_tick: AdmissionTick::new(opts.admission_tick_ms),
            decision_digest: FNV_OFFSET,
            queue: WorkStealingQueue::new(opts.compile_workers),
            worker_free_ms: vec![0.0; opts.compile_workers],
            compile_finishes: Vec::new(),
            slots,
            device_tasks: vec![0; n_dev],
            device_busy_ms: vec![0.0; n_dev],
            device_metrics: (0..n_dev).map(|_| Arc::new(ServiceMetrics::new())).collect(),
            fallbacks: HashMap::new(),
            latency: LatencyTable::shared(),
            counters: Arc::new(FleetCounters::default()),
            calibrator: Calibrator::new(opts.min_calibration_samples, 4096),
            sampled: HashSet::new(),
            drift_pending: HashSet::new(),
            reexplored: HashSet::new(),
            churn: ChurnPlan::default(),
            kill_signaled: vec![false; n_dev],
            pool: None,
            obs,
            submitted: 0,
            regressions: 0,
            migrations: 0,
            migrations_degraded: 0,
            sla_violations: 0,
            tenant_qos: BTreeMap::new(),
            served_gpu_ms: 0.0,
            fallback_gpu_ms: 0.0,
            waits_ms: Vec::new(),
            compile_ms: Vec::new(),
            seen_graphs: HashSet::new(),
            seen_buckets: HashSet::new(),
            makespan_ms: 0.0,
            wall_queue: None,
            wall_queue_lock: None,
            wall_barrier: None,
            wall_elapsed_ms: 0.0,
            instances: HashMap::new(),
            families,
            store: Arc::new(SharedPlanStore::new()),
            opts,
        }
    }

    /// Replay a trace (must be sorted by arrival) and report. Under
    /// [`ExecutorKind::WallClock`] this spins up the compile-worker and
    /// per-device serving threads for the duration of the trace and
    /// quiesces them before reporting; any compile-worker panic caught
    /// during the run is surfaced here as one dispatcher-side error.
    pub fn run_trace(&mut self, trace: &[FleetTask]) -> FleetReport {
        // Resolve the churn schedule up front. The synthesized plan
        // seeds from trace length and spans the arrival horizon — both
        // virtual quantities, so every executor (and every replay of
        // the same trace) resolves the identical schedule.
        self.churn = match (&self.opts.churn_plan, self.opts.churn || self.opts.inject_faults) {
            (Some(plan), _) => plan.clone(),
            (None, true) => {
                let horizon = trace.last().map(|t| t.arrival_ms).unwrap_or(0.0);
                ChurnPlan::seeded(
                    self.opts.registry.len(),
                    horizon,
                    trace.len() as u64,
                    self.opts.inject_faults,
                )
            }
            (None, false) => ChurnPlan::default(),
        };
        if let ExecutorKind::WallClock { threads } = self.opts.executor {
            self.pool = Some(WallClockPool::start(
                threads,
                self.opts.registry.len(),
                Arc::clone(&self.store),
                Arc::clone(&self.latency),
                Arc::clone(&self.counters),
                self.opts.explore.clone(),
                self.opts.never_negative,
                self.opts.calibrate,
                self.obs.as_ref().map(|o| Arc::clone(&o.recorder)),
            ));
        }
        let mut last = 0.0f64;
        for task in trace {
            assert!(
                task.arrival_ms >= last,
                "trace must be sorted by arrival time"
            );
            last = task.arrival_ms;
            self.submit(task);
        }
        if let Some(pool) = self.pool.take() {
            let totals = pool.shutdown();
            assert!(
                totals.errors.is_empty(),
                "wall-clock compile workers panicked: {}",
                totals.errors.join("; ")
            );
            self.served_gpu_ms = totals.served_gpu_ms;
            self.device_busy_ms = totals.device_busy_ms;
            self.regressions = totals.regressions;
            self.wall_queue = Some(totals.queue);
            self.wall_queue_lock = Some(totals.queue_lock);
            self.wall_barrier = Some(totals.barrier);
            self.wall_elapsed_ms = totals.elapsed_ms;
        }
        self.report()
    }

    /// Shared plan store (inspection).
    pub fn store(&self) -> &SharedPlanStore {
        &self.store
    }

    /// FNV-1a digest of the arrival-ordered decision stream: admission
    /// verdicts, placements, reuse tiers and queue waits, all virtual
    /// bookkeeping. Two runs of the same (sub)trace agree iff their
    /// dispatchers made byte-identical decisions — the cluster layer
    /// compares this per shard across executors.
    pub fn decision_digest(&self) -> u64 {
        self.decision_digest
    }

    /// The run's lock-contention rows — plan-store dispatcher and
    /// serve-read paths, compile queue, publication barrier, service
    /// metrics. The same rows the observability report carries, but
    /// available without tracing so per-shard rollups can fold them.
    pub fn lock_rows(&self) -> Vec<LockSnapshot> {
        let mut sm = LockSnapshot::zero("service_metrics");
        for m in &self.device_metrics {
            sm.merge(&m.lock_profile());
        }
        vec![
            self.store.lock_profile(),
            self.store.read_profile(),
            self.wall_queue_lock.unwrap_or_else(|| self.queue.lock_profile()),
            self.wall_barrier.unwrap_or_else(|| LockSnapshot::zero("publication_barrier")),
            sm,
        ]
    }

    /// The drained flight recorder (None when tracing was off).
    /// Non-destructive — the rings retain their events — so it can be
    /// called after [`Self::run_trace`] has already built a report.
    pub fn trace_dump(&self) -> Option<TraceDump> {
        self.obs.as_ref().map(|o| o.recorder.drain())
    }

    /// Instantiate (or fetch the cached instance of) a template at a
    /// shape. Deterministic per (template, shape), so both executors
    /// resolve identical graphs and keys.
    fn instance(&mut self, template: usize, shape: TaskShape) -> Instance {
        if let Some(inst) = self.instances.get(&(template, shape)) {
            return inst.clone();
        }
        let w = Arc::new(self.families[template].instantiate(shape));
        let key = PlanKey::of(&w.graph);
        let inst = Instance { w, key };
        self.instances.insert((template, shape), inst.clone());
        inst
    }

    fn explore_cost_ms(&self, w: &Workload) -> f64 {
        self.opts.explore_cost_base_ms + self.opts.explore_cost_per_op_ms * w.graph.len() as f64
    }

    /// XLA fallback program + per-iteration ms for (graph, class) —
    /// computed once, shared by every instance of the class.
    fn fallback_for(
        &mut self,
        w: &Arc<Workload>,
        key: PlanKey,
        spec: &DeviceSpec,
    ) -> (Arc<OptimizedProgram>, f64) {
        if let Some(v) = self.fallbacks.get(&(key.exact.0, spec.name)) {
            return v.clone();
        }
        let prog = Arc::new(pipeline::optimize(w, spec, Tech::Xla, &self.opts.explore));
        let ms = iter_ms(spec, &prog, w.loop_kind);
        self.fallbacks.insert((key.exact.0, spec.name), (Arc::clone(&prog), ms));
        (prog, ms)
    }

    /// Advance the virtual compile clocks for one job and return its
    /// (virtual finish time, virtual worker index — the flight
    /// recorder's compile-track key). Jobs arrive in time order and
    /// assignment is
    /// a pure timestamp computation: the earliest-free virtual worker
    /// takes the job, backlog manifests as worker `free_ms` beyond
    /// `enqueue_at`, and (virtual mode) the queue's steal counter
    /// records owner-affinity misses (worker != FNV-chosen owner). In
    /// wall-clock mode the real job is routed through the pool's own
    /// shared queue instead, so the local queue is left untouched.
    fn schedule_compile(
        &mut self,
        enqueue_at: f64,
        key: PlanKey,
        class: &'static str,
        cost_ms: f64,
    ) -> (f64, usize) {
        if self.pool.is_none() {
            let owner =
                (owner_hash(key.exact.0, class) % self.opts.compile_workers as u64) as usize;
            self.queue.push(owner, CompileJob { key: key.exact.0, class });
        }
        let mut w = 0;
        for i in 1..self.worker_free_ms.len() {
            if self.worker_free_ms[i] < self.worker_free_ms[w] {
                w = i;
            }
        }
        if self.pool.is_none() {
            let job = self.queue.pop(w).expect("job just queued");
            debug_assert_eq!((job.key, job.class), (key.exact.0, class));
        }
        let start = enqueue_at.max(self.worker_free_ms[w]);
        let finish = start + cost_ms;
        self.worker_free_ms[w] = finish;
        self.compile_finishes.push(finish);
        (finish, w)
    }

    /// Full exploration on the worker pool: real FS optimization with
    /// the coordinator's guards; the store records what the class will
    /// serve (FS plan, or the fallback when vetoed). With
    /// `compile_shards > 1` and a multi-region graph the exploration
    /// fans out as one queue sub-job per region group with a join
    /// barrier ([`Self::run_explore_sharded`]). Returns (virtual ready
    /// time, per-iteration latency — pending publication when the
    /// exploration was handed to a wall-clock worker).
    fn run_explore(
        &mut self,
        w: &Arc<Workload>,
        spec: &DeviceSpec,
        key: PlanKey,
        fallback: &Arc<OptimizedProgram>,
        fb_ms: f64,
        enqueue_at: f64,
    ) -> (f64, FsLatency) {
        if self.opts.compile_shards > 1 {
            let groups =
                regions::shard_regions(regions::partition(&w.graph), self.opts.compile_shards);
            if groups.len() > 1 {
                return self.run_explore_sharded(w, spec, key, fallback, fb_ms, enqueue_at, groups);
            }
        }
        let cost = self.explore_cost_ms(w);
        let (ready, worker) = self.schedule_compile(enqueue_at, key, spec.name, cost);
        self.compile_ms.push(ready - enqueue_at);
        self.counters.explore_jobs.fetch_add(1, Ordering::Relaxed);
        self.record_compile_span(
            worker,
            key.exact.0,
            ready - cost,
            ready,
            EventKind::ExploreStart { shard: 0, shards: 1 },
            Some(EventKind::ExploreEnd { shard: 0, shards: 1 }),
        );
        self.record_compile_stage(CompileStage::Explore, ready - enqueue_at);
        self.record_publish(key.exact.0, ready);
        if let Some(pool) = self.pool.as_ref() {
            pool.enqueue_compile(WallJob {
                w: Arc::clone(w),
                key,
                spec: spec.clone(),
                fallback: Arc::clone(fallback),
                fb_ms,
                ready_ms: ready,
                kind: WallJobKind::Explore,
            });
            return (ready, FsLatency::Pending { key: key.exact.0, class: spec.name });
        }
        // Vetoed/crashed compiles (None) pin the fallback for this
        // class so later tasks skip the re-tuning attempt; either way
        // the outcome goes through the produce/publish path shared with
        // the wall-clock workers.
        let candidate = produce_candidate(
            w,
            spec,
            &self.opts.explore,
            self.opts.never_negative,
            fallback,
            WallJobKind::Explore,
        );
        let ms = guard_and_publish(
            w,
            spec,
            key,
            candidate,
            fallback,
            fb_ms,
            ready,
            &self.store,
            &self.latency,
            &self.counters,
        );
        (ready, FsLatency::Known(PublishedLatency::first(ms)))
    }

    /// Region-sharded exploration: one queue sub-job per region group,
    /// each costed by its own op count, joined at a barrier (the
    /// compile is ready when the slowest shard finishes). Decisions
    /// stay executor-invariant because the partial plans are pure
    /// functions of (graph, device, options) and publication goes
    /// through the same `produce_sharded_candidate`/`guard_and_publish`
    /// pair in both executors.
    #[allow(clippy::too_many_arguments)]
    fn run_explore_sharded(
        &mut self,
        w: &Arc<Workload>,
        spec: &DeviceSpec,
        key: PlanKey,
        fallback: &Arc<OptimizedProgram>,
        fb_ms: f64,
        enqueue_at: f64,
        groups: Vec<Vec<regions::Region>>,
    ) -> (f64, FsLatency) {
        // Apportion the monolithic cost basis (base + per_op × |V|, the
        // same basis `explore_cost_ms` charges) across the shards by
        // their region-op share: sharding parallelizes the modeled
        // work — it must not delete the non-region share of it — and
        // each sub-job pays its own fixed base.
        let total_region_ops: usize = groups.iter().flatten().map(|r| r.len()).sum();
        let shards = groups.len() as u32;
        let mut ready = enqueue_at;
        for (index, group) in groups.iter().enumerate() {
            let ops: usize = group.iter().map(|r| r.len()).sum();
            let frac = ops as f64 / total_region_ops as f64;
            let cost = self.opts.explore_cost_base_ms
                + self.opts.explore_cost_per_op_ms * w.graph.len() as f64 * frac;
            let (finish, worker) = self.schedule_compile(enqueue_at, key, spec.name, cost);
            let shard = index as u32;
            self.record_compile_span(
                worker,
                key.exact.0,
                finish - cost,
                finish,
                EventKind::ExploreStart { shard, shards },
                Some(EventKind::ExploreEnd { shard, shards }),
            );
            ready = ready.max(finish);
        }
        self.compile_ms.push(ready - enqueue_at);
        self.counters.explore_jobs.fetch_add(1, Ordering::Relaxed);
        self.counters.shard_jobs.fetch_add(groups.len(), Ordering::Relaxed);
        self.record_compile_stage(CompileStage::Explore, ready - enqueue_at);
        self.record_publish(key.exact.0, ready);
        if let Some(pool) = self.pool.as_ref() {
            let join = Arc::new(ShardJoin::new(groups));
            for index in 0..join.groups.len() {
                pool.enqueue_compile(WallJob {
                    w: Arc::clone(w),
                    key,
                    spec: spec.clone(),
                    fallback: Arc::clone(fallback),
                    fb_ms,
                    ready_ms: ready,
                    kind: WallJobKind::ExploreShard { join: Arc::clone(&join), index },
                });
            }
            return (ready, FsLatency::Pending { key: key.exact.0, class: spec.name });
        }
        let partials = groups
            .iter()
            .map(|group| shard_partial(w, spec, &self.opts.explore, group))
            .collect();
        let candidate = produce_sharded_candidate(
            w,
            spec,
            &self.opts.explore,
            self.opts.never_negative,
            fallback,
            partials,
        );
        let ms = guard_and_publish(
            w,
            spec,
            key,
            candidate,
            fallback,
            fb_ms,
            ready,
            &self.store,
            &self.latency,
            &self.counters,
        );
        (ready, FsLatency::Known(PublishedLatency::first(ms)))
    }

    /// Shared tail of the two launch-dimension-only retune paths
    /// (cross-class port and same-class shape retune): the dispatcher
    /// already lowered `ported`; the §7.2 never-negative guard +
    /// publication run on a compile worker under wall clock, inline
    /// under virtual time — identically either way.
    #[allow(clippy::too_many_arguments)]
    fn finish_retune(
        &mut self,
        w: &Arc<Workload>,
        spec: &DeviceSpec,
        key: PlanKey,
        ported: OptimizedProgram,
        fallback: &Arc<OptimizedProgram>,
        fb_ms: f64,
        ready: f64,
        tier: &'static str,
    ) -> (f64, FsLatency) {
        if let Some(pool) = self.pool.as_ref() {
            pool.enqueue_compile(WallJob {
                w: Arc::clone(w),
                key,
                spec: spec.clone(),
                fallback: Arc::clone(fallback),
                fb_ms,
                ready_ms: ready,
                kind: WallJobKind::GuardPort { ported, tier },
            });
            return (ready, FsLatency::Pending { key: key.exact.0, class: spec.name });
        }
        let accepted = produce_candidate(
            w,
            spec,
            &self.opts.explore,
            self.opts.never_negative,
            fallback,
            WallJobKind::GuardPort { ported, tier },
        );
        let ms = guard_and_publish(
            w,
            spec,
            key,
            accepted,
            fallback,
            fb_ms,
            ready,
            &self.store,
            &self.latency,
            &self.counters,
        );
        (ready, FsLatency::Known(PublishedLatency::first(ms)))
    }

    /// One launch-dimension-only retune — cross-class port or
    /// same-class shape retune, selected by `tier` — for a fraction of
    /// the exploration cost: lower on the dispatcher (the cheap ~10%
    /// whose outcome steers the decision stream), then guard + publish
    /// through [`Self::finish_retune`] (on a compile worker under wall
    /// clock). Falls back to a full exploration when the source plan
    /// cannot schedule on the target class/shape.
    #[allow(clippy::too_many_arguments)]
    fn run_retune(
        &mut self,
        tier: RetuneTier,
        w: &Arc<Workload>,
        spec: &DeviceSpec,
        key: PlanKey,
        source: &Arc<OptimizedProgram>,
        available_ms: f64,
        fallback: &Arc<OptimizedProgram>,
        fb_ms: f64,
        now: f64,
    ) -> (f64, FsLatency) {
        let cost = self.explore_cost_ms(w) * self.opts.port_cost_frac;
        let enqueue_at = now.max(available_ms);
        let (ready, worker) = self.schedule_compile(enqueue_at, key, spec.name, cost);
        self.compile_ms.push(ready - enqueue_at);
        let span = EventKind::Retune { tier: tier.name() };
        self.record_compile_span(worker, key.exact.0, ready - cost, ready, span, None);
        self.record_compile_stage(tier.stage(), ready - enqueue_at);
        let counters = Arc::clone(&self.counters);
        let (jobs, failures) = tier.counters(&counters);
        jobs.fetch_add(1, Ordering::Relaxed);
        match tier.lower(w, source, spec) {
            Some(ported) => {
                self.record_publish(key.exact.0, ready);
                self.finish_retune(w, spec, key, ported, fallback, fb_ms, ready, tier.name())
            }
            None => {
                // Unschedulable on the target: pay the full exploration,
                // starting where the failed retune left off.
                failures.fetch_add(1, Ordering::Relaxed);
                self.run_explore(w, spec, key, fallback, fb_ms, ready)
            }
        }
    }

    /// Calibration step for one served store hit. Sampling and the
    /// drift verdict happen on the first hit per (graph, class); a
    /// drifted pair whose re-exploration is deferred (backpressure,
    /// unfitted class) stays pending and retries on later hits. Runs on
    /// the dispatcher in both executors — after the per-graph
    /// publication barrier, in arrival order — so the sample stream,
    /// the fitted params and the drift decisions are executor-invariant
    /// by construction.
    ///
    /// Order matters and is deliberate: drift is judged against the
    /// class params as of *previous* publications (did our current
    /// model predict this graph well?); only then do this graph's
    /// samples refine the fit, and a drifted graph is re-explored under
    /// the freshly calibrated snapshot.
    #[allow(clippy::too_many_arguments)]
    fn calibrate_on_hit(
        &mut self,
        w: &Arc<Workload>,
        spec: &DeviceSpec,
        key: PlanKey,
        prog: &Arc<OptimizedProgram>,
        measured_ms: f64,
        fallback: &Arc<OptimizedProgram>,
        fb_ms: f64,
        now: f64,
    ) {
        let id = (key.exact.0, spec.name);
        if self.sampled.insert(id) {
            // First observation of this served program: judge drift
            // under the class params as of previous publications, then
            // fold its samples into the fit.
            let params = self.calibrator.params_for(spec.name);
            let predicted_ms = calibrate::predict_iter_ms(spec, prog, &params);
            let (ratio, drifted) =
                calibrate::drift_verdict(measured_ms, predicted_ms, self.opts.drift_bound);
            if let Some(obs) = self.obs.as_ref() {
                let (track, gid) = (obs.dispatcher, key.exact.0);
                let kind = EventKind::DriftSample { ratio };
                obs.ring.record(Event { track, id: gid, kind, ts_us: now * 1e3, dur_us: 0.0 });
            }
            if drifted {
                self.drift_pending.insert(id);
            }
            let samples = calibrate::program_samples(spec, prog, w.loop_kind);
            self.calibrator.record(spec.name, samples, measured_ms);
        }
        if !self.drift_pending.contains(&id)
            || self.reexplored.contains(&id)
            || !self.calibrator.is_fitted(spec.name)
        {
            return;
        }
        // Admission accounting: a re-exploration is a compile job like
        // any other — under compile saturation it yields to serving.
        // The pending flag survives, so a deferred trigger fires on
        // this graph's next hit once the backlog drains (or once the
        // class accumulates enough samples to be fitted).
        if self.compile_finishes.len() >= self.opts.admission.max_pending_compiles {
            return;
        }
        self.drift_pending.remove(&id);
        self.reexplored.insert(id);
        self.run_reexplore(w, spec, key, fallback, fb_ms, now);
    }

    /// Drift-triggered re-exploration: a full compile job under the
    /// calibrated [`crate::gpu::CostParams`] snapshot taken at trigger
    /// time. Publication goes through the plan-quality no-worse gate
    /// ([`publish_reexplored`]): only a strictly faster plan replaces
    /// the incumbent, hot-swapping into in-flight wall-clock sessions
    /// via the serving threads' publication poll, and its improved
    /// latency takes effect at the job's virtual finish.
    ///
    /// Deliberately monolithic (no region-shard fan-out): unlike a
    /// first-touch compile, the graph keeps serving its incumbent plan
    /// throughout, so time-to-swap is a background-quality concern and
    /// one queue slot per re-exploration keeps the accounting simple.
    fn run_reexplore(
        &mut self,
        w: &Arc<Workload>,
        spec: &DeviceSpec,
        key: PlanKey,
        fallback: &Arc<OptimizedProgram>,
        fb_ms: f64,
        now: f64,
    ) {
        let mut explore = self.opts.explore.clone();
        explore.cost = self.calibrator.params_for(spec.name);
        let cost_ms = self.explore_cost_ms(w);
        let (ready, worker) = self.schedule_compile(now, key, spec.name, cost_ms);
        self.compile_ms.push(ready - now);
        self.counters.reexplore_jobs.fetch_add(1, Ordering::Relaxed);
        let span = EventKind::Reexplore;
        self.record_compile_span(worker, key.exact.0, ready - cost_ms, ready, span, None);
        self.record_compile_stage(CompileStage::Reexplore, ready - now);
        self.record_publish(key.exact.0, ready);
        if let Some(pool) = self.pool.as_ref() {
            pool.enqueue_compile(WallJob {
                w: Arc::clone(w),
                key,
                spec: spec.clone(),
                fallback: Arc::clone(fallback),
                fb_ms,
                ready_ms: ready,
                kind: WallJobKind::Reexplore { explore },
            });
            return;
        }
        let candidate = produce_reexplored(w, spec, &explore, self.opts.never_negative, fallback);
        publish_reexplored(
            w,
            spec,
            key,
            candidate,
            ready,
            &self.store,
            &self.latency,
            &self.counters,
        );
    }

    /// Record one compile job's span on its virtual worker's track
    /// (virtual timeline, so identical across executors and replays):
    /// a B/E pair when `end_kind` is given, a closed X span otherwise.
    fn record_compile_span(
        &mut self,
        worker: usize,
        id: u64,
        start_ms: f64,
        end_ms: f64,
        kind: EventKind,
        end_kind: Option<EventKind>,
    ) {
        if let Some(obs) = self.obs.as_ref() {
            let track = obs.compile[worker];
            let (ts_us, end_us) = (start_ms * 1e3, end_ms * 1e3);
            match end_kind {
                Some(end) => {
                    obs.ring.record(Event { track, id, kind, ts_us, dur_us: 0.0 });
                    obs.ring.record(Event { track, id, kind: end, ts_us: end_us, dur_us: 0.0 });
                }
                None => {
                    obs.ring.record(Event { track, id, kind, ts_us, dur_us: end_us - ts_us });
                }
            }
        }
    }

    /// Attribute one compile job's enqueue→ready latency to its stage.
    fn record_compile_stage(&mut self, stage: CompileStage, span_ms: f64) {
        if let Some(obs) = self.obs.as_mut() {
            obs.stages.compile(stage, span_ms);
        }
    }

    /// Record a publication instant (virtual ready time) on the
    /// dispatcher track.
    fn record_publish(&mut self, id: u64, ready_ms: f64) {
        if let Some(obs) = self.obs.as_ref() {
            let (track, kind) = (obs.dispatcher, EventKind::Publish);
            obs.ring.record(Event { track, id, kind, ts_us: ready_ms * 1e3, dur_us: 0.0 });
        }
    }

    /// Run a publication-barrier wait against the live pool (no-op
    /// under virtual time), timing the stall into the barrier stage
    /// and the wall-side barrier track.
    fn barrier_wait(&mut self, task_id: usize, wait: impl FnOnce(&WallClockPool)) {
        let (ts_us, t0) = match (self.pool.as_ref(), self.obs.is_some()) {
            (None, _) => return,
            (Some(pool), false) => {
                wait(pool);
                return;
            }
            (Some(pool), true) => {
                let ts_us = pool.elapsed_us();
                let t0 = Instant::now();
                wait(pool);
                (ts_us, t0)
            }
        };
        let waited_ms = t0.elapsed().as_secs_f64() * 1e3;
        if let Some(obs) = self.obs.as_mut() {
            obs.stages.barrier_wait(waited_ms);
            let (track, id) = (obs.barrier, task_id as u64);
            let kind = EventKind::BarrierWait;
            obs.ring.record(Event { track, id, kind, ts_us, dur_us: waited_ms * 1e3 });
        }
    }

    /// Move an in-flight session off a departing device (churn Leave or
    /// injected Kill). Destination = least-loaded surviving slot; the
    /// plan follows the session through the same feasibility ladder the
    /// store's reuse tiers run — same class keeps it, a published
    /// destination-class entry is adopted, a portable/bucket source is
    /// re-lowered via [`pipeline::port_program`] /
    /// [`pipeline::reshape_program`] (re-checking occupancy and
    /// shared-memory staging on the destination class), and anything
    /// else degrades to the destination fallback. Session-local by
    /// design: nothing publishes to the store and no retune counters
    /// move — a migration is not a compile. Returns (device, slot,
    /// destination fallback, destination fallback ms).
    fn migrate_session(
        &mut self,
        task_id: usize,
        w: &Arc<Workload>,
        key: PlanKey,
        from_d: usize,
        at_ms: f64,
        fs_state: &mut Option<(FsLatency, f64)>,
        src_class: &'static str,
    ) -> (usize, usize, Arc<OptimizedProgram>, f64) {
        // Destination: least-loaded active slot, source excluded. The
        // churn anchor (device 0 never leaves) guarantees a survivor —
        // a departing device is never device 0.
        let (mut to_d, mut to_s) = (usize::MAX, 0usize);
        for (d, slots) in self.slots.iter().enumerate() {
            if d == from_d || (d != 0 && !self.churn.active(d, at_ms)) {
                continue;
            }
            for (s, &free) in slots.iter().enumerate() {
                if to_d == usize::MAX || free < self.slots[to_d][to_s] {
                    (to_d, to_s) = (d, s);
                }
            }
        }
        assert!(to_d != usize::MAX, "churn anchor guarantees a surviving device");
        let dest_spec = self.opts.registry.devices()[to_d].spec.clone();
        let (dest_fallback, dest_fb_ms) = self.fallback_for(w, key, &dest_spec);
        self.migrations += 1;

        // Resolve what the migrated session serves (codes fold into the
        // decision digest; every input is virtual bookkeeping).
        let resolution: u64 = if dest_spec.name == src_class {
            1 // same class: plan and latency carry over untouched
        } else if fs_state.is_none() {
            5 // was serving pure fallback; still is
        } else {
            // Cross-class with an optimized plan in flight: quiesce any
            // in-flight compile of this graph/bucket first so the store
            // and latency lookups below see exactly what the virtual
            // replay's would.
            self.barrier_wait(task_id, |pool| pool.await_plan(key));
            if let Some(pl) = self.latency.get(&(key.exact.0, dest_spec.name)) {
                *fs_state = Some((FsLatency::Known(pl), at_ms));
                2 // destination class already published this graph
            } else {
                let ported = match self.store.lookup(key, dest_spec.name) {
                    PlanLookup::Portable { source, .. } => {
                        pipeline::port_program(&w.graph, &source, &dest_spec, w.loop_kind)
                    }
                    PlanLookup::BucketHit { source, .. } => {
                        pipeline::reshape_program(&w.graph, &source, &dest_spec, w.loop_kind)
                    }
                    _ => None,
                };
                let adopted = ported.and_then(|prog| {
                    let ms = iter_ms(&dest_spec, &prog, w.loop_kind);
                    (!self.opts.never_negative || ms <= dest_fb_ms).then_some(ms)
                });
                match adopted {
                    Some(ms) => {
                        let lat = FsLatency::Known(PublishedLatency::first(ms));
                        *fs_state = Some((lat, at_ms));
                        3 // the plan ported with the session
                    }
                    None => {
                        *fs_state = None;
                        self.migrations_degraded += 1;
                        4 // infeasible (or slower) on the destination
                    }
                }
            }
        };
        for v in [task_id as u64, 6, from_d as u64, to_d as u64, resolution] {
            self.decision_digest = fnv1a_u64(self.decision_digest, v);
        }
        if let Some(obs) = self.obs.as_ref() {
            let kind = EventKind::Migrate { from: from_d as u32, to: to_d as u32 };
            let (track, id) = (obs.dispatcher, task_id as u64);
            obs.ring.record(Event { track, id, kind, ts_us: at_ms * 1e3, dur_us: 0.0 });
        }
        (to_d, to_s, dest_fallback, dest_fb_ms)
    }

    /// Process one task arrival.
    fn submit(&mut self, task: &FleetTask) {
        let now = task.arrival_ms;
        self.submitted += 1;
        let tier = task.tier();
        self.tenant_qos.entry(task.tenant).or_default().tasks += 1;

        // Fault injection (wall clock): deliver the kill marker to any
        // device whose kill time has passed. FIFO channel order drains
        // everything queued before the marker, and the placement
        // exclusion below guarantees nothing is routed to the device
        // after its kill time — so the marker is always last.
        if !self.churn.is_empty() {
            if let Some(pool) = self.pool.as_ref() {
                for d in 0..self.kill_signaled.len() {
                    if !self.kill_signaled[d]
                        && matches!(self.churn.kill_time(d), Some(t) if t <= now)
                    {
                        pool.send_kill(d);
                        self.kill_signaled[d] = true;
                    }
                }
            }
        }

        // 1. Instantiate the template at the task's requested shape
        // (cached per (template, shape); static traffic always resolves
        // the one fixed instance) and account the distinct-shape /
        // distinct-bucket census on every arrival — pre-admission, so
        // it is executor-invariant by construction.
        let inst = self.instance(task.template, task.shape);
        let key = inst.key;
        self.seen_graphs.insert(key.exact.0);
        self.seen_buckets.insert((key.shape.structure, key.shape.bucket));

        // 2. Place: least-loaded serving slot fleet-wide (earliest
        // free; ties resolve to the lowest device/slot index). Both
        // executors place on the virtual slot clocks — trace arrivals
        // are virtual timestamps either way, which is what makes the
        // wall-clock run converge to the virtual replay's decisions.
        // Churned-out devices are excluded; device 0 is the churn
        // anchor (never in a plan), so a candidate always exists and
        // churn-free runs place exactly as before.
        let (mut best_d, mut best_s) = (0usize, 0usize);
        for (d, slots) in self.slots.iter().enumerate() {
            if d != 0 && !self.churn.active(d, now) {
                continue;
            }
            for (s, &free) in slots.iter().enumerate() {
                if free < self.slots[best_d][best_s] {
                    (best_d, best_s) = (d, s);
                }
            }
        }
        let start = now.max(self.slots[best_d][best_s]);
        let wait = start - now;
        let spec = self.opts.registry.devices()[best_d].spec.clone();

        // Wall clock: publication barrier — wait out any in-flight
        // compile of this same graph *or a bucket sibling* so the store
        // lookup below sees exactly what the virtual replay would
        // (including shape-port representatives).
        self.barrier_wait(task.id, |pool| pool.await_plan(key));

        // 3. Resolve plan availability + admission. Arrivals are
        // monotone, so finished compiles can be dropped as we go
        // (keeps the pending count O(pending), not O(all jobs ever)).
        // Under a nonzero admission tick the retain-and-count runs once
        // per tick window and the sample is reused for every decision
        // inside it — ticks are cut on virtual arrival time, so both
        // executors batch identically.
        let lookup = self.store.lookup(key, spec.name);
        let tick = &mut self.admission_tick;
        let finishes = &mut self.compile_finishes;
        let pending = tick.pending(now, || {
            finishes.retain(|&f| f > now);
            finishes.len()
        });
        let needs_compile = !matches!(&lookup, PlanLookup::Hit { .. });
        let decision = self.admission.decide_tiered(tier, wait, pending, needs_compile);
        // Fold the decision tuple into the per-dispatcher digest —
        // everything here derives from virtual bookkeeping, never from
        // wall-clock measurement.
        let reuse_tier = match &lookup {
            PlanLookup::Hit { .. } => 1u64,
            PlanLookup::Portable { .. } => 2,
            PlanLookup::BucketHit { .. } => 3,
            PlanLookup::Miss => 4,
        };
        let verdict_code = match decision {
            AdmitDecision::Admit => 1u64,
            AdmitDecision::AdmitFallbackOnly => 2,
            AdmitDecision::Reject => 3,
            AdmitDecision::Shed => 4,
        };
        for v in [
            task.id as u64,
            task.tenant as u64,
            verdict_code,
            reuse_tier,
            best_d as u64,
            best_s as u64,
        ] {
            self.decision_digest = fnv1a_u64(self.decision_digest, v);
        }
        self.decision_digest = fnv1a_u64(self.decision_digest, wait.to_bits());
        if let Some(obs) = self.obs.as_ref() {
            let verdict = match decision {
                AdmitDecision::Admit => "admit",
                AdmitDecision::AdmitFallbackOnly => "fallback_only",
                AdmitDecision::Reject => "reject",
                AdmitDecision::Shed => "shed",
            };
            let (track, id) = (obs.dispatcher, task.id as u64);
            let kind = EventKind::TaskAdmitted { decision: verdict, tenant: task.tenant };
            obs.ring.record(Event { track, id, kind, ts_us: now * 1e3, dur_us: 0.0 });
        }
        match decision {
            AdmitDecision::Reject => {
                self.tenant_qos.entry(task.tenant).or_default().rejected += 1;
                return;
            }
            AdmitDecision::Shed => {
                self.tenant_qos.entry(task.tenant).or_default().shed += 1;
                return;
            }
            AdmitDecision::Admit | AdmitDecision::AdmitFallbackOnly => {}
        }

        let w = Arc::clone(&inst.w);
        let (fallback, fb_ms) = self.fallback_for(&w, key, &spec);

        // 4. FS availability: per-iteration latency + virtual ready
        // time. Store accounting records *acted-on* outcomes only: a
        // backpressured task that merely looked does not count.
        let fs: Option<(FsLatency, f64)> = match lookup {
            PlanLookup::Hit { ready_ms, prog } => {
                self.store.note_exact_hit();
                // Every store insert goes through `guard_and_publish`,
                // which pairs it with a latency entry — a miss here is
                // a broken publication invariant, not a cache miss.
                let known = self.latency.get(&(key.exact.0, spec.name));
                let pl = known.expect("store hit must have a published latency");
                if self.opts.calibrate {
                    // Past the per-graph publication barrier, in
                    // arrival (virtual-time measurement) order: sample
                    // the served program, refit the class params, and
                    // re-explore on drift — identically on both
                    // executors.
                    self.calibrate_on_hit(
                        &w,
                        &spec,
                        key,
                        &prog,
                        pl.at(now),
                        &fallback,
                        fb_ms,
                        now,
                    );
                }
                Some((FsLatency::Known(pl), ready_ms))
            }
            PlanLookup::Portable { source, available_ms, .. }
                if decision == AdmitDecision::Admit =>
            {
                self.store.note_port_hit();
                let (ready, lat) = self.run_retune(
                    RetuneTier::Port,
                    &w,
                    &spec,
                    key,
                    &source,
                    available_ms,
                    &fallback,
                    fb_ms,
                    now,
                );
                Some((lat, ready))
            }
            PlanLookup::BucketHit { source, available_ms, .. }
                if decision == AdmitDecision::Admit =>
            {
                self.store.note_bucket_hit();
                let (ready, lat) = self.run_retune(
                    RetuneTier::Bucket,
                    &w,
                    &spec,
                    key,
                    &source,
                    available_ms,
                    &fallback,
                    fb_ms,
                    now,
                );
                Some((lat, ready))
            }
            PlanLookup::Miss if decision == AdmitDecision::Admit => {
                self.store.note_miss();
                let (ready, lat) = self.run_explore(&w, &spec, key, &fallback, fb_ms, now);
                Some((lat, ready))
            }
            // Compile backpressure: serve the fallback for the whole
            // task; no optimization work is enqueued.
            _ => None,
        };

        // Churn: the chosen device's first departure (Leave or Kill)
        // after `now`, if any. None on churn-free runs — everything
        // below then reduces to the pre-churn path, byte for byte.
        let boundary = if self.churn.is_empty() {
            None
        } else {
            self.churn.next_departure(best_d, now)
        };
        let had_fs = fs.is_some();

        // Wall clock: hand the task to its device's serving thread
        // *before* advancing the virtual clocks, so real serving
        // overlaps any publication wait the bookkeeping below incurs.
        // The session crosses the thread boundary serving the fallback
        // and is hot-swapped there when the plan publishes (§6). With a
        // departure pending on this device the send is deferred until
        // the virtual loop below resolves whether (and where) the
        // session migrates.
        if boundary.is_none() {
            if let Some(pool) = self.pool.as_ref() {
                let session = Session::serving_fallback(
                    Arc::clone(&fallback),
                    Arc::clone(&self.device_metrics[best_d]),
                    w.loop_kind,
                );
                pool.send_serve(ServeJob {
                    session,
                    device: best_d,
                    iterations: task.iterations,
                    fb_ms,
                    fs: fs.as_ref().map(|_| (key, spec.name)),
                    task: task.id,
                });
            }
        }

        // 5. Advance the virtual clocks through the task's iterations,
        // hot-swapping to the FS latency once its compile finishes in
        // virtual time (§6 at fleet scale). Both executors run this —
        // placement, waits and makespan all derive from it — but only
        // the virtual executor also records metrics here (the
        // wall-clock executor's serving threads measure for real). A
        // pending departure on the placed device migrates the session
        // the first iteration the virtual cursor crosses it.
        let mut fs_state = fs;
        let mut cursor = start;
        let mut served = 0.0f64;
        let mut cur_fb = fb_ms;
        let mut migrated: Option<Migration> = None;
        for it in 0..task.iterations {
            if migrated.is_none() && matches!(boundary, Some(b) if cursor >= b) {
                let (to_d, to_s, dest_fallback, dest_fb_ms) = self.migrate_session(
                    task.id,
                    &w,
                    key,
                    best_d,
                    cursor,
                    &mut fs_state,
                    spec.name,
                );
                migrated = Some(Migration {
                    to_d,
                    to_s,
                    at_ms: cursor,
                    iters_before: it,
                    served_before: served,
                    fallback: dest_fallback,
                    fb_ms: dest_fb_ms,
                });
                cur_fb = dest_fb_ms;
            }
            let iter = match &mut fs_state {
                Some((lat, ready)) if cursor >= *ready => match lat {
                    FsLatency::Known(pl) => pl.at(cursor),
                    FsLatency::Pending { key, class } => {
                        // The task's virtual serving window crossed its
                        // compile's virtual finish: the bookkeeping
                        // needs the published latency now (rare — most
                        // tasks drain on the fallback first).
                        self.barrier_wait(task.id, |pool| pool.await_key(*key));
                        let got = self.latency.get(&(*key, *class));
                        let pl = got.unwrap_or_else(|| {
                            // A quiesced compile with no published
                            // latency means its worker panicked —
                            // surface the recorded cause now rather
                            // than a bare invariant failure.
                            let pool = self.pool.as_ref().expect("wall-clock pool");
                            panic!(
                                "compile for graph {:#x} on {} never published; \
                                 worker errors: {:?}",
                                key,
                                class,
                                pool.errors()
                            )
                        });
                        *lat = FsLatency::Known(pl);
                        pl.at(cursor)
                    }
                },
                _ => cur_fb,
            };
            if self.pool.is_none() {
                let dev = migrated.as_ref().map_or(best_d, |m| m.to_d);
                self.device_metrics[dev].record_iteration(iter);
            }
            cursor += iter;
            served += iter;
        }

        // The never-negative baseline is what the task would have cost
        // on fallback *on the devices it actually ran on* — a migration
        // to a slower class must not read as a regression.
        let fb_total = match &migrated {
            Some(m) => {
                fb_ms * m.iters_before as f64 + m.fb_ms * (task.iterations - m.iters_before) as f64
            }
            None => fb_ms * task.iterations as f64,
        };

        // Wall clock, deferred send: the migration (if any) is resolved,
        // so hand the serving thread(s) their split of the iterations.
        // Both sends happen before any later arrival can deliver this
        // device's kill marker, preserving FIFO drain order.
        if boundary.is_some() {
            if let Some(pool) = self.pool.as_ref() {
                let src_iters = migrated.as_ref().map_or(task.iterations, |m| m.iters_before);
                if src_iters > 0 {
                    let session = Session::serving_fallback(
                        Arc::clone(&fallback),
                        Arc::clone(&self.device_metrics[best_d]),
                        w.loop_kind,
                    );
                    pool.send_serve(ServeJob {
                        session,
                        device: best_d,
                        iterations: src_iters,
                        fb_ms,
                        fs: had_fs.then_some((key, spec.name)),
                        task: task.id,
                    });
                }
                if let Some(m) = &migrated {
                    let dest_class = self.opts.registry.devices()[m.to_d].spec.name;
                    let session = Session::serving_fallback(
                        Arc::clone(&m.fallback),
                        Arc::clone(&self.device_metrics[m.to_d]),
                        w.loop_kind,
                    );
                    pool.send_serve(ServeJob {
                        session,
                        device: m.to_d,
                        iterations: task.iterations - m.iters_before,
                        fb_ms: m.fb_ms,
                        fs: fs_state.as_ref().map(|_| (key, dest_class)),
                        task: task.id,
                    });
                }
            }
        }

        if self.pool.is_none() {
            if served > fb_total + 1e-9 {
                self.regressions += 1; // the guard must make this unreachable
            }
            match &migrated {
                Some(m) => {
                    self.device_busy_ms[best_d] += m.served_before;
                    self.device_busy_ms[m.to_d] += served - m.served_before;
                }
                None => self.device_busy_ms[best_d] += served,
            }
            self.served_gpu_ms += served;
        }
        match &migrated {
            Some(m) => {
                self.slots[best_d][best_s] = m.at_ms;
                self.slots[m.to_d][m.to_s] = cursor;
                self.device_tasks[m.to_d] += 1;
            }
            None => {
                self.slots[best_d][best_s] = cursor;
                self.device_tasks[best_d] += 1;
            }
        }
        self.fallback_gpu_ms += fb_total;
        self.waits_ms.push(wait);
        self.makespan_ms = self.makespan_ms.max(cursor);

        // Per-tenant QoS ledger: end-to-end latency and the SLA verdict
        // (the placed queue wait judged against the tier's bound —
        // tier-aware admission sheds anything that would violate, so a
        // nonzero count here is a policy bug the CI rail catches).
        let acc = self.tenant_qos.entry(task.tenant).or_default();
        acc.served += 1;
        acc.e2e_ms.push(cursor - now);
        if wait > tier.sla_ms() {
            acc.sla_violations += 1;
            self.sla_violations += 1;
        }
        if let Some(obs) = self.obs.as_mut() {
            obs.stages.task(best_d, wait, start, cursor);
            let (track, id) = (obs.devices[best_d], task.id as u64);
            let kind = EventKind::QueueWait;
            obs.ring.record(Event { track, id, kind, ts_us: now * 1e3, dur_us: wait * 1e3 });
            let kind = EventKind::Serve { device: best_d as u32 };
            let (ts_us, dur_us) = (start * 1e3, (cursor - start) * 1e3);
            obs.ring.record(Event { track, id, kind, ts_us, dur_us });
        }
    }

    /// Assemble the fleet-wide report.
    pub fn report(&self) -> FleetReport {
        let (admitted, fallback_only, rejected) = self.admission.counts();
        let store = self.store.stats();
        let drift = self.calibrator.drift();
        let qstats = self.wall_queue.unwrap_or_else(|| self.queue.stats());
        let iter_summary =
            ServiceMetrics::merged_summary(self.device_metrics.iter().map(|m| &**m));
        let per_device = self
            .opts
            .registry
            .devices()
            .iter()
            .map(|d| {
                let i = d.id.0;
                let span = self.makespan_ms * d.capacity as f64;
                DeviceUtilization {
                    id: i,
                    class: d.class(),
                    tasks: self.device_tasks[i],
                    busy_ms: self.device_busy_ms[i],
                    utilization: if span > 0.0 { self.device_busy_ms[i] / span } else { 0.0 },
                }
            })
            .collect();
        let observability = self.obs.as_ref().map(|obs| {
            let dump = obs.recorder.drain();
            obs.stages.report(self.lock_rows(), dump.recorded, dump.dropped)
        });
        // BTreeMap iteration → tenant rows come out in id order,
        // deterministically, on every executor.
        let tenants = self
            .tenant_qos
            .iter()
            .map(|(&tenant, acc)| {
                let tier = TenantTier::of(tenant);
                TenantQos {
                    tenant,
                    tier: tier.name(),
                    sla_ms: tier.sla_ms(),
                    tasks: acc.tasks,
                    served: acc.served,
                    shed: acc.shed,
                    rejected: acc.rejected,
                    sla_violations: acc.sla_violations,
                    e2e: summarize(&acc.e2e_ms),
                }
            })
            .collect();
        let (churn_events, faults) = self.churn.counts();
        FleetReport {
            executor: self.opts.executor.name(),
            tasks: self.submitted,
            admitted,
            fallback_only,
            rejected,
            exact_hits: store.exact_hits,
            port_hits: store.port_hits,
            bucket_hits: store.bucket_hits,
            misses: store.misses,
            distinct_shapes: self.seen_graphs.len(),
            distinct_buckets: self.seen_buckets.len(),
            explore_jobs: self.counters.explore_jobs.load(Ordering::Relaxed),
            port_jobs: self.counters.port_jobs.load(Ordering::Relaxed),
            port_failures: self.counters.port_failures.load(Ordering::Relaxed),
            bucket_retunes: self.counters.bucket_jobs.load(Ordering::Relaxed),
            bucket_failures: self.counters.bucket_failures.load(Ordering::Relaxed),
            fs_vetoes: self.counters.fs_vetoes.load(Ordering::Relaxed),
            shard_jobs: self.counters.shard_jobs.load(Ordering::Relaxed),
            reexplore_jobs: self.counters.reexplore_jobs.load(Ordering::Relaxed),
            reexplore_improved: self.counters.reexplore_improved.load(Ordering::Relaxed),
            reexplore_rejected: self.counters.reexplore_rejected.load(Ordering::Relaxed),
            gemm_absorbed: self.counters.gemm_absorbed.load(Ordering::Relaxed),
            footprint_pruned: self.counters.footprint_pruned.load(Ordering::Relaxed),
            calibration_samples: drift.samples,
            drift_before: drift.before,
            drift_after: drift.after,
            compile: summarize(&self.compile_ms),
            regressions: self.regressions,
            compile_owner_runs: qstats.local_pops,
            compile_affinity_misses: qstats.steals,
            served_gpu_ms: self.served_gpu_ms,
            fallback_gpu_ms: self.fallback_gpu_ms,
            wait: summarize(&self.waits_ms),
            iter_p50_ms: iter_summary.p50,
            iter_p99_ms: iter_summary.p99,
            makespan_ms: self.makespan_ms,
            wall_elapsed_ms: self.wall_elapsed_ms,
            sheds: self.admission.shed_count(),
            sla_violations: self.sla_violations,
            migrations: self.migrations,
            migrations_degraded: self.migrations_degraded,
            churn_events,
            faults,
            tenants,
            per_device,
            observability,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::registry::{ChurnEvent, ChurnEventKind};
    use crate::fleet::sim::{
        build_template_families, build_templates, generate_trace, ModelFamily, TrafficConfig,
    };

    fn small_traffic() -> TrafficConfig {
        TrafficConfig {
            tasks: 80,
            templates: 4,
            mean_interarrival_ms: 1.0,
            min_ops: 20,
            max_ops: 40,
            ..Default::default()
        }
    }

    #[test]
    fn mixed_fleet_is_deterministic_never_negative_and_ports_plans() {
        let traffic = small_traffic();
        let templates = build_templates(&traffic);
        let trace = generate_trace(&traffic);
        let run = || {
            let opts = FleetOptions {
                registry: DeviceRegistry::mixed(1, 1, 2),
                compile_workers: 2,
                ..Default::default()
            };
            let mut svc = FleetService::new(opts, templates.clone());
            svc.run_trace(&trace)
        };
        let a = run();
        let b = run();
        // Byte-identical reports across replays of the same seed.
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert_eq!(a.tasks, 80);
        assert_eq!(a.regressions, 0, "never-negative must hold fleet-wide");
        let snapshot = a.to_json().to_string();
        assert!(a.port_hits >= 1, "mixed classes must port plans: {snapshot}");
        assert!(a.exact_hits >= 1, "hot templates must hit the store");
        assert_eq!(a.bucket_hits, 0, "fixed shapes never bucket-hit");
        assert!(
            a.misses <= a.distinct_shapes && a.distinct_shapes <= 4,
            "static traffic sees at most one graph per template: {snapshot}"
        );
        assert!(a.served_gpu_ms > 0.0);
        assert!(a.saved_gpu_ms() >= 0.0, "guard keeps savings non-negative");
        assert!(a.wait.p99 >= a.wait.p50);
        assert!(a.iter_p99_ms >= a.iter_p50_ms);
        assert!(a.iter_p50_ms > 0.0);
        // Accounting closes: every task is admitted some way or rejected.
        assert_eq!(a.admitted + a.fallback_only + a.rejected, a.tasks);
    }

    #[test]
    fn overload_triggers_admission_rejection() {
        let traffic = TrafficConfig {
            tasks: 40,
            templates: 2,
            mean_interarrival_ms: 0.01,
            min_iterations: 20,
            max_iterations: 30,
            min_ops: 20,
            max_ops: 30,
            ..Default::default()
        };
        let templates = build_templates(&traffic);
        let trace = generate_trace(&traffic);
        let opts = FleetOptions {
            registry: DeviceRegistry::mixed(1, 0, 1),
            admission: AdmissionConfig { max_queue_delay_ms: 5.0, ..Default::default() },
            ..Default::default()
        };
        let mut svc = FleetService::new(opts, templates);
        let r = svc.run_trace(&trace);
        assert!(r.rejected > 0, "overload must reject: {:?}", r.to_json().to_string());
        assert_eq!(r.admitted + r.fallback_only + r.rejected, r.tasks);
        assert_eq!(r.regressions, 0);
    }

    #[test]
    fn compile_backpressure_serves_fallback_without_optimizing() {
        let traffic = small_traffic();
        let templates = build_templates(&traffic);
        let trace = generate_trace(&traffic);
        let opts = FleetOptions {
            registry: DeviceRegistry::mixed(1, 1, 2),
            admission: AdmissionConfig { max_pending_compiles: 0, ..Default::default() },
            ..Default::default()
        };
        let mut svc = FleetService::new(opts, templates);
        let r = svc.run_trace(&trace);
        assert_eq!(r.explore_jobs, 0);
        assert_eq!(r.port_jobs, 0);
        assert_eq!(r.bucket_retunes, 0);
        assert_eq!(r.admitted, 0);
        assert!(r.fallback_only > 0);
        assert_eq!(r.saved_gpu_ms(), 0.0, "no optimization, no savings");
        assert!(svc.store().is_empty());
    }

    #[test]
    fn work_stealing_pool_balances_compiles() {
        // Single-class fleet with many templates: all explorations, no
        // ports; with >1 workers the steal counter must move (owner
        // affinity is hash-based, the earliest-free worker takes jobs).
        let traffic = TrafficConfig {
            tasks: 30,
            templates: 8,
            mean_interarrival_ms: 0.5,
            min_ops: 20,
            max_ops: 30,
            ..Default::default()
        };
        let templates = build_templates(&traffic);
        let trace = generate_trace(&traffic);
        let opts = FleetOptions {
            registry: DeviceRegistry::mixed(2, 0, 2),
            compile_workers: 2,
            ..Default::default()
        };
        let mut svc = FleetService::new(opts, templates);
        let r = svc.run_trace(&trace);
        // One exploration per distinct template the trace touched.
        assert_eq!(r.explore_jobs, r.misses, "every miss explores exactly once");
        assert!((1..=8).contains(&r.explore_jobs));
        assert_eq!(r.port_hits, 0, "single class never ports");
        assert_eq!(r.port_jobs, 0);
        assert_eq!(r.compile_owner_runs + r.compile_affinity_misses, r.explore_jobs);
    }

    #[test]
    fn wallclock_executor_converges_to_virtual_decisions() {
        // The tentpole equivalence claim: the same trace through real
        // OS threads reaches the same plan and admission decisions as
        // the deterministic virtual replay. Latency *measurements*
        // (served GPU ms, iteration percentiles, elapsed wall time) are
        // real and may differ; decisions may not.
        let traffic = small_traffic();
        let templates = build_templates(&traffic);
        let trace = generate_trace(&traffic);
        let base = FleetOptions {
            registry: DeviceRegistry::mixed(1, 1, 2),
            compile_workers: 2,
            observe: true,
            ..Default::default()
        };
        let (virt, virt_digest) = {
            let mut svc = FleetService::new(base.clone(), templates.clone());
            let r = svc.run_trace(&trace);
            (r, svc.decision_digest())
        };
        // Three real compile threads against a two-worker virtual
        // admission model: decisions must converge for any thread count.
        let (wall, wall_digest) = {
            let opts = FleetOptions {
                executor: ExecutorKind::WallClock { threads: 3 },
                ..base
            };
            let mut svc = FleetService::new(opts, templates.clone());
            let r = svc.run_trace(&trace);
            (r, svc.decision_digest())
        };
        assert_eq!(wall_digest, virt_digest, "decision digests must agree across executors");
        assert_eq!(wall.executor, "wallclock");
        assert_eq!(virt.executor, "virtual");
        // Plan decisions, admission decisions and store traffic are
        // executor-independent...
        assert_eq!(wall.tasks, virt.tasks);
        assert_eq!(wall.admitted, virt.admitted);
        assert_eq!(wall.fallback_only, virt.fallback_only);
        assert_eq!(wall.rejected, virt.rejected);
        assert_eq!(wall.exact_hits, virt.exact_hits);
        assert_eq!(wall.port_hits, virt.port_hits);
        assert_eq!(wall.bucket_hits, virt.bucket_hits);
        assert_eq!(wall.misses, virt.misses);
        assert_eq!(wall.explore_jobs, virt.explore_jobs);
        assert_eq!(wall.port_jobs, virt.port_jobs);
        assert_eq!(wall.port_failures, virt.port_failures);
        assert_eq!(wall.fs_vetoes, virt.fs_vetoes);
        // ...as are the virtual placement clocks feeding them...
        assert_eq!(wall.wait.p50, virt.wait.p50);
        assert_eq!(wall.wait.p99, virt.wait.p99);
        assert_eq!(wall.makespan_ms, virt.makespan_ms);
        assert_eq!(wall.fallback_gpu_ms, virt.fallback_gpu_ms);
        // ...and the compile-latency telemetry (virtual bookkeeping in
        // both executors).
        assert_eq!(wall.shard_jobs, virt.shard_jobs);
        assert_eq!(wall.compile.p50, virt.compile.p50);
        assert_eq!(wall.compile.p99, virt.compile.p99);
        assert!(virt.compile.p50 > 0.0, "explorations ran, so compile latency is nonzero");
        // ...and the zero-regression guarantee holds on real threads.
        assert_eq!(virt.regressions, 0);
        assert_eq!(wall.regressions, 0);
        assert!(wall.wall_elapsed_ms > 0.0, "wall run must measure elapsed time");
        assert_eq!(virt.wall_elapsed_ms, 0.0);
        // Wall-clock serving is a real measurement, not a replay — but
        // the guard still caps it at fallback-only cost.
        assert!(wall.served_gpu_ms > 0.0);
        assert!(wall.served_gpu_ms <= wall.fallback_gpu_ms + 1e-6);
        // Tracing was on for both runs — the equivalence assertions
        // above double as the recording-never-perturbs-decisions claim
        // — and the wall report carries the pool's real lock profiles.
        if crate::obs::recorder::ENABLED {
            let wobs = wall.observability.as_ref().expect("tracing was on");
            assert!(wobs.lock("work_queue").unwrap().acquisitions > 0);
            assert!(wobs.lock("publication_barrier").unwrap().acquisitions > 0);
            // The serve threads' plan reads go through the epoch
            // snapshot: profiled, never contended.
            let read = wobs.lock("plan_store_read").unwrap();
            assert!(read.acquisitions > 0, "served hits must hot-swap through the read path");
            assert_eq!(read.contended, 0, "the epoch read path must never block");
            let vobs = virt.observability.as_ref().expect("tracing was on");
            assert_eq!(vobs.lock("publication_barrier").unwrap().acquisitions, 0);
            assert_eq!(vobs.stage("barrier").unwrap().summary.n, 0);
        }
    }

    #[test]
    fn calibration_closes_the_drift_loop_deterministically() {
        let traffic = small_traffic();
        let templates = build_templates(&traffic);
        let trace = generate_trace(&traffic);
        let run = |calibrate: bool| {
            let opts = FleetOptions {
                registry: DeviceRegistry::mixed(1, 1, 2),
                compile_workers: 2,
                calibrate,
                ..Default::default()
            };
            let mut svc = FleetService::new(opts, templates.clone());
            svc.run_trace(&trace)
        };
        let a = run(true);
        let b = run(true);
        // Calibration is dispatcher-driven state: replays stay
        // byte-identical with the loop on.
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert!(a.calibration_samples > 0, "served hits must be sampled");
        assert!(a.drift_before > 0.0, "uncalibrated cost model must show drift");
        assert!(
            a.drift_after < a.drift_before,
            "calibration must shrink drift: {} -> {}",
            a.drift_before,
            a.drift_after
        );
        assert!(a.reexplore_jobs >= 1, "drifted graphs must re-explore: {a:?}");
        // Every re-exploration resolves through the no-worse gate.
        assert_eq!(a.reexplore_improved + a.reexplore_rejected, a.reexplore_jobs);
        assert_eq!(a.regressions, 0, "never-negative holds under calibration");
        assert_eq!(a.admitted + a.fallback_only + a.rejected, a.tasks);
        // With the loop off, nothing is sampled and nothing re-explores.
        let off = run(false);
        assert_eq!(off.calibration_samples, 0);
        assert_eq!(off.reexplore_jobs, 0);
        assert_eq!(off.drift_before, 0.0);
        assert_eq!(off.drift_after, 0.0);
    }

    #[test]
    fn calibrated_trace_converges_across_executors() {
        // The equivalence invariant extended to the calibration loop:
        // sampling, fitting, drift triggers and gated re-publication
        // all happen on the dispatcher (virtual-time measurement order,
        // per-graph publication barriers), so a calibrated wall-clock
        // run must reach the calibrated virtual replay's decisions —
        // including the re-exploration stream — exactly.
        let traffic = small_traffic();
        let templates = build_templates(&traffic);
        let trace = generate_trace(&traffic);
        let base = FleetOptions {
            registry: DeviceRegistry::mixed(1, 1, 2),
            compile_workers: 2,
            calibrate: true,
            ..Default::default()
        };
        let virt = {
            let mut svc = FleetService::new(base.clone(), templates.clone());
            svc.run_trace(&trace)
        };
        let wall = {
            let opts = FleetOptions {
                executor: ExecutorKind::WallClock { threads: 3 },
                ..base
            };
            let mut svc = FleetService::new(opts, templates.clone());
            svc.run_trace(&trace)
        };
        assert_eq!(wall.tasks, virt.tasks);
        assert_eq!(wall.admitted, virt.admitted);
        assert_eq!(wall.fallback_only, virt.fallback_only);
        assert_eq!(wall.rejected, virt.rejected);
        assert_eq!(wall.exact_hits, virt.exact_hits);
        assert_eq!(wall.port_hits, virt.port_hits);
        assert_eq!(wall.misses, virt.misses);
        assert_eq!(wall.explore_jobs, virt.explore_jobs);
        assert_eq!(wall.port_jobs, virt.port_jobs);
        assert_eq!(wall.fs_vetoes, virt.fs_vetoes);
        // The calibration decision stream is executor-invariant...
        assert_eq!(wall.reexplore_jobs, virt.reexplore_jobs);
        assert_eq!(wall.reexplore_improved, virt.reexplore_improved);
        assert_eq!(wall.reexplore_rejected, virt.reexplore_rejected);
        assert_eq!(wall.calibration_samples, virt.calibration_samples);
        assert_eq!(wall.drift_before, virt.drift_before);
        assert_eq!(wall.drift_after, virt.drift_after);
        // ...as is the virtual bookkeeping the re-explore jobs feed.
        assert_eq!(wall.compile.p50, virt.compile.p50);
        assert_eq!(wall.compile.p99, virt.compile.p99);
        assert_eq!(wall.makespan_ms, virt.makespan_ms);
        assert!(virt.reexplore_jobs >= 1, "loop must actually fire: {virt:?}");
        assert_eq!(virt.regressions, 0);
        assert_eq!(wall.regressions, 0);
    }

    /// ln → matmul → ln: two fusible regions split by the GEMM, so a
    /// sharded exploration genuinely fans out.
    fn two_region_template(rows: usize) -> Workload {
        use crate::graph::{DType, Graph, Shape};
        use crate::workloads::{blocks, LoopKind, Mode};
        let mut g = Graph::new("2reg");
        let x = g.param(Shape::new(vec![rows, 256]), DType::F32, "x");
        let h = blocks::layer_norm(&mut g, x, "ln0");
        let wgt = g.param(Shape::new(vec![256, 256]), DType::F32, "w");
        let mm = g.matmul(h, wgt, "mm");
        let _ = blocks::layer_norm(&mut g, mm, "ln1");
        Workload {
            name: "2reg",
            field: "test",
            mode: Mode::Infer,
            batch: 1,
            loop_kind: LoopKind::None,
            graph: g,
        }
    }

    #[test]
    fn sharded_compile_fans_out_and_cuts_time_to_optimized_plan() {
        // One task, one multi-region template, idle 4-worker pool: the
        // sharded exploration must split into >= 2 queue sub-jobs whose
        // join barrier finishes strictly earlier than the monolithic
        // compile (each shard pays only its own region's op cost).
        let template = two_region_template(512);
        let trace = vec![FleetTask {
            id: 0,
            arrival_ms: 0.0,
            template: 0,
            iterations: 8,
            shape: TaskShape::default(),
            tenant: 0,
        }];
        let run = |executor: ExecutorKind, shards: usize| {
            let opts = FleetOptions {
                registry: DeviceRegistry::mixed(1, 0, 2),
                compile_workers: 4,
                compile_shards: shards,
                executor,
                ..Default::default()
            };
            let mut svc = FleetService::new(opts, vec![template.clone()]);
            svc.run_trace(&trace)
        };
        let mono = run(ExecutorKind::VirtualTime, 1);
        let virt = run(ExecutorKind::VirtualTime, 4);
        let wall = run(ExecutorKind::WallClock { threads: 4 }, 4);

        assert_eq!(mono.shard_jobs, 0, "monolithic compiles never shard");
        assert_eq!(virt.explore_jobs, 1);
        assert!(virt.shard_jobs >= 2, "expected region fan-out, got {}", virt.shard_jobs);
        assert!(
            virt.compile.p99 < mono.compile.p99,
            "sharded compile {} must beat monolithic {} on an idle pool",
            virt.compile.p99,
            mono.compile.p99
        );
        // The virtual/wall-clock decision equivalence holds for the
        // sharded jobs and their join barrier too.
        assert_eq!(wall.explore_jobs, virt.explore_jobs);
        assert_eq!(wall.shard_jobs, virt.shard_jobs);
        assert_eq!(wall.misses, virt.misses);
        assert_eq!(wall.fs_vetoes, virt.fs_vetoes);
        assert_eq!(wall.compile.p50, virt.compile.p50);
        assert_eq!(wall.compile.p99, virt.compile.p99);
        assert_eq!(virt.regressions, 0);
        assert_eq!(wall.regressions, 0);
    }

    #[test]
    fn sharded_trace_converges_across_executors() {
        // A full trace over multi-region templates with a mixed
        // registry: sharded explorations, ports and store hits all
        // interleave, and the wall-clock run must still reach the
        // virtual replay's decisions exactly.
        let templates = vec![two_region_template(256), two_region_template(384)];
        let traffic = TrafficConfig {
            tasks: 60,
            templates: 2,
            mean_interarrival_ms: 1.0,
            ..Default::default()
        };
        let trace = generate_trace(&traffic);
        let base = FleetOptions {
            registry: DeviceRegistry::mixed(1, 1, 2),
            compile_workers: 3,
            compile_shards: 3,
            ..Default::default()
        };
        let virt = {
            let mut svc = FleetService::new(base.clone(), templates.clone());
            svc.run_trace(&trace)
        };
        let wall = {
            let opts = FleetOptions {
                executor: ExecutorKind::WallClock { threads: 2 },
                ..base
            };
            let mut svc = FleetService::new(opts, templates);
            svc.run_trace(&trace)
        };
        // One sharded exploration per template (the second class ports
        // instead of exploring), each fanning out per region.
        assert!(virt.shard_jobs >= 4, "two 2-region explorations fan out: {}", virt.shard_jobs);
        assert_eq!(wall.tasks, virt.tasks);
        assert_eq!(wall.admitted, virt.admitted);
        assert_eq!(wall.fallback_only, virt.fallback_only);
        assert_eq!(wall.rejected, virt.rejected);
        assert_eq!(wall.exact_hits, virt.exact_hits);
        assert_eq!(wall.port_hits, virt.port_hits);
        assert_eq!(wall.misses, virt.misses);
        assert_eq!(wall.explore_jobs, virt.explore_jobs);
        assert_eq!(wall.port_jobs, virt.port_jobs);
        assert_eq!(wall.shard_jobs, virt.shard_jobs);
        assert_eq!(wall.compile.p50, virt.compile.p50);
        assert_eq!(wall.compile.p99, virt.compile.p99);
        assert_eq!(wall.makespan_ms, virt.makespan_ms);
        assert_eq!(virt.regressions, 0);
        assert_eq!(wall.regressions, 0);
    }

    #[test]
    fn bucket_hits_reserve_sibling_shapes_without_reexploring() {
        // The BucketHit tier end-to-end on a hand-built trace: one
        // layer-norm family, a single V100, three arrivals — rows 64
        // (explore), rows 48 (sibling bucket: launch-dim retune only),
        // rows 48 again (exact hit on the retuned program).
        let families = vec![TemplateFamily::Model(ModelFamily::LayerNorm)];
        let task = |id: usize, arrival_ms: f64, seq: usize| FleetTask {
            id,
            arrival_ms,
            template: 0,
            iterations: 6,
            shape: TaskShape { batch: 1, seq },
            tenant: 0,
        };
        let trace = vec![task(0, 0.0, 64), task(1, 200.0, 48), task(2, 400.0, 48)];
        let run = |executor: ExecutorKind| {
            let opts = FleetOptions {
                registry: DeviceRegistry::mixed(1, 0, 2),
                compile_workers: 2,
                executor,
                ..Default::default()
            };
            let mut svc = FleetService::with_families(opts, families.clone());
            svc.run_trace(&trace)
        };
        let r = run(ExecutorKind::VirtualTime);
        assert_eq!(r.misses, 1, "only the first shape explores: {:?}", r.to_json().to_string());
        assert_eq!(r.explore_jobs, 1);
        assert_eq!(r.bucket_hits, 1, "rows 48 reuses the rows-64 plan");
        assert_eq!(r.bucket_retunes, 1);
        assert_eq!(r.bucket_failures, 0);
        assert_eq!(r.exact_hits, 1, "the third task hits the retuned program");
        assert_eq!(r.port_hits, 0, "single class never cross-class ports");
        assert_eq!(r.distinct_shapes, 2);
        assert_eq!(r.distinct_buckets, 1);
        assert_eq!(r.regressions, 0);
        // The same decisions on real threads (publication barrier must
        // cover bucket siblings, not just exact keys).
        let wall = run(ExecutorKind::WallClock { threads: 2 });
        assert_eq!(wall.misses, r.misses);
        assert_eq!(wall.explore_jobs, r.explore_jobs);
        assert_eq!(wall.bucket_hits, r.bucket_hits);
        assert_eq!(wall.bucket_retunes, r.bucket_retunes);
        assert_eq!(wall.exact_hits, r.exact_hits);
        assert_eq!(wall.regressions, 0);
    }

    #[test]
    fn bucket_retune_fails_over_when_absorption_cannot_restage() {
        // Cross-GEMM stitching meets the bucket tier: seq 33 explores
        // and absorbs its epilogue (the ~33 KB staging tile fits);
        // seq 64 lands in the same pow2 bucket (cols 1056 and 2048 both
        // round to 2048) but needs 64 KB of staging — over the
        // per-block cap — so the launch-dim-only retune must refuse to
        // silently serve the cut form and instead fail over to a full
        // exploration, which re-decides absorption at the new shape.
        let families = vec![TemplateFamily::Model(ModelFamily::GemmEpilogueProbe)];
        let task = |id: usize, arrival_ms: f64, seq: usize| FleetTask {
            id,
            arrival_ms,
            template: 0,
            iterations: 6,
            shape: TaskShape { batch: 1, seq },
            tenant: 0,
        };
        let trace = vec![task(0, 0.0, 33), task(1, 200.0, 64)];
        let run = |executor: ExecutorKind| {
            let opts = FleetOptions {
                registry: DeviceRegistry::mixed(1, 0, 2),
                compile_workers: 2,
                executor,
                ..Default::default()
            };
            let mut svc = FleetService::with_families(opts, families.clone());
            svc.run_trace(&trace)
        };
        let r = run(ExecutorKind::VirtualTime);
        assert_eq!(r.misses, 1, "{}", r.to_json().to_string());
        assert_eq!(r.bucket_hits, 1, "seq 64 shares seq 33's pow2 bucket");
        assert_eq!(r.bucket_retunes, 1);
        assert_eq!(r.bucket_failures, 1, "the absorbed plan must refuse to restage");
        assert_eq!(r.explore_jobs, 2, "the failure pays a full exploration");
        assert!(r.gemm_absorbed >= 1, "the seq-33 exploration absorbs its epilogue");
        assert_eq!(r.regressions, 0, "the fail-over still serves");
        // The same decisions on real threads.
        let wall = run(ExecutorKind::WallClock { threads: 2 });
        assert_eq!(wall.bucket_hits, r.bucket_hits);
        assert_eq!(wall.bucket_failures, r.bucket_failures);
        assert_eq!(wall.explore_jobs, r.explore_jobs);
        assert_eq!(wall.gemm_absorbed, r.gemm_absorbed);
        assert_eq!(
            wall.footprint_pruned, r.footprint_pruned,
            "the prune tally is a pure function of (graph, device, options)"
        );
        assert_eq!(wall.regressions, 0);
    }

    fn dynamic_traffic() -> TrafficConfig {
        TrafficConfig {
            tasks: 150,
            templates: 4,
            mean_interarrival_ms: 1.0,
            min_ops: 20,
            max_ops: 40,
            dynamic_shapes: true,
            ..Default::default()
        }
    }

    #[test]
    fn dynamic_shape_fleet_amortizes_explorations_across_buckets() {
        let traffic = dynamic_traffic();
        let families = build_template_families(&traffic);
        let trace = generate_trace(&traffic);
        let run = || {
            let opts = FleetOptions {
                registry: DeviceRegistry::mixed(1, 1, 2),
                compile_workers: 2,
                ..Default::default()
            };
            let mut svc = FleetService::with_families(opts, families.clone());
            svc.run_trace(&trace)
        };
        let a = run();
        let b = run();
        // Shape-polymorphic replays stay byte-identical.
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        let snapshot = a.to_json().to_string();
        assert_eq!(a.regressions, 0, "never-negative holds under dynamic shapes");
        assert!(
            a.distinct_shapes > traffic.templates,
            "shape-varying traffic must produce many distinct graphs: {snapshot}"
        );
        assert!(
            a.distinct_buckets < a.distinct_shapes,
            "power-of-two bucketing must coalesce sibling shapes: {snapshot}"
        );
        assert!(a.bucket_hits >= 1, "sibling shapes must reuse plans: {snapshot}");
        assert_eq!(
            a.bucket_retunes,
            a.bucket_hits,
            "every acted-on bucket hit runs one retune job: {snapshot}"
        );
        // The amortization claim: full explorations are strictly
        // sublinear in distinct shapes — the bucket tier (plus the
        // cross-class port tier) absorbs the rest.
        assert!(
            a.explore_jobs < a.distinct_shapes,
            "explorations must be sublinear in distinct shapes: {snapshot}"
        );
        assert_eq!(a.admitted + a.fallback_only + a.rejected, a.tasks);
    }

    #[test]
    fn dynamic_shape_trace_converges_across_executors() {
        // Decision equivalence under shape-varying traffic: the bucket
        // tier's lookups depend on publication order of sibling shapes,
        // so the wall-clock publication barrier must cover buckets —
        // this is the test that catches it racing.
        let traffic = dynamic_traffic();
        let families = build_template_families(&traffic);
        let trace = generate_trace(&traffic);
        let base = FleetOptions {
            registry: DeviceRegistry::mixed(1, 1, 2),
            compile_workers: 2,
            ..Default::default()
        };
        let virt = {
            let mut svc = FleetService::with_families(base.clone(), families.clone());
            svc.run_trace(&trace)
        };
        let wall = {
            let opts = FleetOptions {
                executor: ExecutorKind::WallClock { threads: 3 },
                ..base
            };
            let mut svc = FleetService::with_families(opts, families.clone());
            svc.run_trace(&trace)
        };
        assert_eq!(wall.tasks, virt.tasks);
        assert_eq!(wall.admitted, virt.admitted);
        assert_eq!(wall.fallback_only, virt.fallback_only);
        assert_eq!(wall.rejected, virt.rejected);
        assert_eq!(wall.exact_hits, virt.exact_hits);
        assert_eq!(wall.port_hits, virt.port_hits);
        assert_eq!(wall.bucket_hits, virt.bucket_hits);
        assert_eq!(wall.misses, virt.misses);
        assert_eq!(wall.explore_jobs, virt.explore_jobs);
        assert_eq!(wall.port_jobs, virt.port_jobs);
        assert_eq!(wall.bucket_retunes, virt.bucket_retunes);
        assert_eq!(wall.bucket_failures, virt.bucket_failures);
        assert_eq!(wall.port_failures, virt.port_failures);
        assert_eq!(wall.fs_vetoes, virt.fs_vetoes);
        assert_eq!(wall.distinct_shapes, virt.distinct_shapes);
        assert_eq!(wall.distinct_buckets, virt.distinct_buckets);
        assert_eq!(wall.compile.p50, virt.compile.p50);
        assert_eq!(wall.compile.p99, virt.compile.p99);
        assert_eq!(wall.makespan_ms, virt.makespan_ms);
        assert!(virt.bucket_hits >= 1, "the bucket tier must fire: {virt:?}");
        assert_eq!(virt.regressions, 0);
        assert_eq!(wall.regressions, 0);
    }

    #[test]
    fn tracing_does_not_perturb_decisions() {
        // The flight recorder must be a pure observer: a traced run and
        // an untraced run of the same trace produce identical reports
        // once the observability section itself is stripped.
        let traffic = small_traffic();
        let templates = build_templates(&traffic);
        let trace = generate_trace(&traffic);
        let run = |observe: bool| {
            let opts = FleetOptions {
                registry: DeviceRegistry::mixed(1, 1, 2),
                compile_workers: 2,
                calibrate: true,
                observe,
                ..Default::default()
            };
            let mut svc = FleetService::new(opts, templates.clone());
            let mut r = svc.run_trace(&trace);
            r.observability = None;
            r
        };
        assert_eq!(run(true).to_json().to_string(), run(false).to_json().to_string());
    }

    #[test]
    fn virtual_tracing_replays_are_byte_identical() {
        if !crate::obs::recorder::ENABLED {
            return;
        }
        // Every virtual-timeline event derives from the deterministic
        // bookkeeping, so two traced replays must agree event-for-event
        // — and so must their Chrome trace exports.
        let traffic = small_traffic();
        let templates = build_templates(&traffic);
        let trace = generate_trace(&traffic);
        let run = || {
            let opts = FleetOptions {
                registry: DeviceRegistry::mixed(1, 1, 2),
                compile_workers: 2,
                observe: true,
                ..Default::default()
            };
            let mut svc = FleetService::new(opts, templates.clone());
            let report = svc.run_trace(&trace);
            let dump = svc.trace_dump().expect("tracing was on");
            (report, dump)
        };
        let (ra, da) = run();
        let (rb, db) = run();
        assert_eq!(ra.to_json().to_string(), rb.to_json().to_string());
        assert!(!da.events.is_empty(), "a traced run must record events");
        assert_eq!(da.events, db.events);
        assert_eq!(
            crate::obs::chrome_trace(&da).to_string(),
            crate::obs::chrome_trace(&db).to_string()
        );
        // Stage identities: queue + serve == e2e by construction, and
        // virtual time never stalls on the publication barrier.
        let obs = ra.observability.as_ref().expect("observe folds into the report");
        let total = |n: &str| obs.stage(n).unwrap().total_ms;
        assert!((total("queue") + total("serve") - total("e2e")).abs() < 1e-6);
        assert_eq!(obs.stage("barrier").unwrap().summary.n, 0);
        assert_eq!(obs.lock("publication_barrier").unwrap().acquisitions, 0);
        assert!(obs.lock("plan_store").unwrap().acquisitions > 0);
        assert_eq!(obs.lock("plan_store").unwrap().contended, 0);
        assert!(obs.events_recorded > 0);
        assert_eq!(obs.events_dropped, 0, "the ring must hold a small trace");
    }

    #[test]
    fn killed_device_queued_work_drains_on_survivors() {
        // Fault injection end to end on a hand-built backlog: four
        // early arrivals fill both devices' slots, four more stack up
        // behind them, and four late arrivals land after device 1 is
        // killed mid-serve. The wall-clock run must complete (work
        // queued ahead of the kill marker drains in FIFO order — the
        // marker is always last on the channel), every session device 1
        // was serving must migrate to the survivor, post-kill work must
        // never route to the dead device, and none of it may perturb
        // the decision stream.
        let families = vec![
            TemplateFamily::Model(ModelFamily::LayerNorm),
            TemplateFamily::Model(ModelFamily::GemmEpilogueProbe),
        ];
        let task = |id: usize, arrival_ms: f64| FleetTask {
            id,
            arrival_ms,
            template: id % 2,
            iterations: 400,
            shape: TaskShape { batch: 1, seq: 33 },
            tenant: 0,
        };
        let mut trace: Vec<FleetTask> = (0..8).map(|id| task(id, 0.1 * id as f64)).collect();
        trace.extend((8..12).map(|id| task(id, 2.0 + 0.2 * (id - 8) as f64)));
        // Device 1's two slots pick up sessions at ~0.2/0.3 ms that run
        // for at least 400 iterations x the 3 us kernel floor, so a
        // kill at 1.0 ms lands mid-serve by construction.
        let plan = ChurnPlan::from_events(vec![ChurnEvent {
            at_ms: 1.0,
            device: 1,
            kind: ChurnEventKind::Kill,
        }]);
        let run = |executor: ExecutorKind| {
            let opts = FleetOptions {
                registry: DeviceRegistry::mixed(2, 0, 2),
                compile_workers: 2,
                churn_plan: Some(plan.clone()),
                executor,
                ..Default::default()
            };
            let mut svc = FleetService::with_families(opts, families.clone());
            let r = svc.run_trace(&trace);
            (r, svc.decision_digest())
        };
        let (virt, vd) = run(ExecutorKind::VirtualTime);
        let (wall, wd) = run(ExecutorKind::WallClock { threads: 2 });
        assert_eq!(wd, vd, "the kill must not perturb placement or admission");
        for r in [&virt, &wall] {
            let snapshot = r.to_json().to_string();
            assert_eq!(r.faults, 1, "{snapshot}");
            assert_eq!(r.churn_events, 0, "an explicit kill plan has no drains");
            assert!(r.migrations >= 2, "both of device 1's sessions span the kill: {snapshot}");
            assert_eq!(r.regressions, 0, "{snapshot}");
            assert_eq!(r.rejected, 0, "the backlog never nears the premium bound");
            assert_eq!(r.sheds, 0, "single-tenant traffic is all premium");
            assert_eq!(r.admitted + r.fallback_only + r.rejected + r.sheds, r.tasks);
        }
        assert_eq!(virt.migrations, wall.migrations);
        // Placement and migration accounting are virtual bookkeeping,
        // identical across executors — and every session the dead
        // device started (plus everything queued or arriving after the
        // kill) completes on the survivor.
        for d in 0..2 {
            assert_eq!(virt.per_device[d].tasks, wall.per_device[d].tasks);
        }
        assert_eq!(virt.per_device[1].tasks, 0, "no session may complete on the dead device");
        assert_eq!(virt.per_device[0].tasks, 12, "all queued work drains on the survivor");
        assert_eq!(virt.makespan_ms, wall.makespan_ms);
    }

    #[test]
    fn migration_rechecks_plan_feasibility_on_the_destination_class() {
        // A mid-serve kill forces a cross-class migration, and the
        // destination must re-check the plan's shared-memory/occupancy
        // feasibility: the seq-33 GEMM-epilogue plan stages ~33 KB, so
        // it ports to a stock T4 (48 KB per-block cap) but must degrade
        // to the destination fallback on a 16 KB-cap class rather than
        // silently serve a cut form of the absorbed plan.
        let families = vec![
            TemplateFamily::Model(ModelFamily::LayerNorm),
            TemplateFamily::Model(ModelFamily::GemmEpilogueProbe),
        ];
        // Task 0 pins the anchor V100 with a long layer-norm session so
        // the migration's least-loaded choice is the third device; task
        // 1 is the victim session on the to-be-killed V100.
        let task = |id: usize, arrival_ms: f64, template: usize, iters: usize, shape| FleetTask {
            id,
            arrival_ms,
            template,
            iterations: iters,
            shape,
            tenant: 0,
        };
        let trace = vec![
            task(0, 0.0, 0, 2000, TaskShape { batch: 64, seq: 64 }),
            task(1, 0.1, 1, 400, TaskShape { batch: 1, seq: 33 }),
        ];
        let plan = ChurnPlan::from_events(vec![ChurnEvent {
            at_ms: 1.0,
            device: 1,
            kind: ChurnEventKind::Kill,
        }]);
        let run = |dest: DeviceSpec, executor: ExecutorKind| {
            let mut registry = DeviceRegistry::new();
            registry.register(DeviceSpec::v100(), 1);
            registry.register(DeviceSpec::v100(), 1);
            registry.register(dest, 1);
            let opts = FleetOptions {
                registry,
                compile_workers: 2,
                churn_plan: Some(plan.clone()),
                executor,
                ..Default::default()
            };
            let mut svc = FleetService::with_families(opts, families.clone());
            let r = svc.run_trace(&trace);
            (r, svc.decision_digest())
        };
        // Feasible destination: the plan follows the session.
        let (ported, pd) = run(DeviceSpec::t4(), ExecutorKind::VirtualTime);
        let snapshot = ported.to_json().to_string();
        assert_eq!(ported.faults, 1, "{snapshot}");
        assert_eq!(ported.migrations, 1, "{snapshot}");
        assert_eq!(ported.migrations_degraded, 0, "33 KB staging fits the stock 48 KB cap");
        assert_eq!(ported.regressions, 0, "{snapshot}");
        // The migrated session is accounted on its destination.
        assert_eq!(ported.per_device[1].tasks, 0);
        assert_eq!(ported.per_device[2].tasks, 1);
        let (pw, pwd) = run(DeviceSpec::t4(), ExecutorKind::WallClock { threads: 2 });
        assert_eq!(pwd, pd, "the migration resolution folds into the digest");
        assert_eq!(pw.migrations, 1);
        assert_eq!(pw.migrations_degraded, 0);
        // Infeasible destination: same kill, same plan, but a 16 KB
        // per-block cap cannot restage the absorbed epilogue.
        let small = DeviceSpec {
            name: "T4-16K",
            shmem_per_sm: 16 * 1024,
            shmem_per_block: 16 * 1024,
            ..DeviceSpec::t4()
        };
        let (degraded, dd) = run(small.clone(), ExecutorKind::VirtualTime);
        let snapshot = degraded.to_json().to_string();
        assert_eq!(degraded.faults, 1, "{snapshot}");
        assert_eq!(degraded.migrations, 1, "{snapshot}");
        assert_eq!(degraded.migrations_degraded, 1, "{snapshot}");
        assert_eq!(degraded.regressions, 0, "degrading to fallback is not a regression");
        let (dw, dwd) = run(small, ExecutorKind::WallClock { threads: 2 });
        assert_eq!(dwd, dd, "the degrade verdict folds into the digest");
        assert_eq!(dw.migrations_degraded, 1);
        assert_ne!(pd, dd, "feasibility flips the migration resolution code");
    }
}
