//! Device registry: the mixed-hardware population a fleet serves on.
//!
//! The paper's production cluster (§7.2) spans "thousands of GPUs of
//! different architecture generations"; the registry models that as a
//! set of device *instances*, each carrying a [`DeviceSpec`] (its
//! class — V100, T4, ...) and a serving capacity (concurrent session
//! slots). Plans are tuned per device *class* and shared across
//! instances of that class (see [`super::store`]).
//!
//! A [`ChurnPlan`] makes the population *elastic*: devices leave and
//! rejoin mid-trace, and fault injection kills one mid-serve. The plan
//! is pure virtual-time data seeded from the trace, so both executors
//! see the identical membership timeline — placement exclusion and
//! session migration stay decision-deterministic.

use crate::gpu::DeviceSpec;
use crate::util::Prng;

/// Index of a registered device instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DeviceId(pub usize);

/// One physical device in the fleet.
#[derive(Debug, Clone)]
pub struct RegisteredDevice {
    pub id: DeviceId,
    pub spec: DeviceSpec,
    /// Concurrent serving slots (sessions this device serves at once).
    pub capacity: usize,
}

impl RegisteredDevice {
    /// Device class used for plan sharing (the spec name).
    pub fn class(&self) -> &'static str {
        self.spec.name
    }
}

/// The fleet's device population.
#[derive(Debug, Clone, Default)]
pub struct DeviceRegistry {
    devices: Vec<RegisteredDevice>,
}

impl DeviceRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register one device instance; returns its id.
    pub fn register(&mut self, spec: DeviceSpec, capacity: usize) -> DeviceId {
        assert!(capacity > 0, "device capacity must be positive");
        let id = DeviceId(self.devices.len());
        self.devices.push(RegisteredDevice { id, spec, capacity });
        id
    }

    /// The paper's mixed population: `v100s` V100 instances followed by
    /// `t4s` T4 instances, all with the same per-device capacity.
    pub fn mixed(v100s: usize, t4s: usize, capacity: usize) -> Self {
        let mut reg = Self::new();
        for _ in 0..v100s {
            reg.register(DeviceSpec::v100(), capacity);
        }
        for _ in 0..t4s {
            reg.register(DeviceSpec::t4(), capacity);
        }
        reg
    }

    /// Fetch one device by id.
    pub fn device(&self, id: DeviceId) -> &RegisteredDevice {
        &self.devices[id.0]
    }

    /// All registered devices in registration order.
    pub fn devices(&self) -> &[RegisteredDevice] {
        &self.devices
    }

    /// Number of device instances.
    pub fn len(&self) -> usize {
        self.devices.len()
    }

    /// True when no device is registered.
    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Total serving slots across the fleet.
    pub fn total_capacity(&self) -> usize {
        self.devices.iter().map(|d| d.capacity).sum()
    }

    /// Distinct device classes in registration order.
    pub fn classes(&self) -> Vec<&'static str> {
        let mut out: Vec<&'static str> = Vec::new();
        for d in &self.devices {
            if !out.contains(&d.class()) {
                out.push(d.class());
            }
        }
        out
    }

    /// Split the population into `shards` disjoint sub-registries,
    /// dealing device instances round-robin in registration order so
    /// every shard gets a representative class mix (the usual
    /// registration order groups classes in runs, and round-robin cuts
    /// across the runs). Ids are re-assigned per shard — a shard's
    /// dispatcher is a self-contained fleet.
    pub fn partition(&self, shards: usize) -> Vec<DeviceRegistry> {
        assert!(shards > 0, "partition needs at least one shard");
        assert!(
            self.devices.len() >= shards,
            "cannot spread {} devices over {} shards",
            self.devices.len(),
            shards
        );
        let mut out = vec![Self::new(); shards];
        for (i, d) in self.devices.iter().enumerate() {
            out[i % shards].register(d.spec.clone(), d.capacity);
        }
        out
    }
}

/// What happens to a device at a churn event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnEventKind {
    /// The device drains out of the placement pool (maintenance,
    /// preemption); it may rejoin later.
    Leave,
    /// The device rejoins the placement pool.
    Join,
    /// Fault injection: the device dies mid-serve and never returns;
    /// its queued work redistributes to survivors.
    Kill,
}

/// One membership change at a virtual timestamp.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChurnEvent {
    pub at_ms: f64,
    pub device: usize,
    pub kind: ChurnEventKind,
}

/// A deterministic membership timeline for one dispatcher's registry.
/// Events are sorted by time; devices start active. Device 0 never
/// churns, so placement always has at least one live target.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ChurnPlan {
    events: Vec<ChurnEvent>,
}

impl ChurnPlan {
    /// A plan from explicit events (test traces). Sorted by time.
    pub fn from_events(mut events: Vec<ChurnEvent>) -> ChurnPlan {
        assert!(
            events.iter().all(|e| e.device != 0),
            "device 0 is the churn-free placement anchor"
        );
        events.sort_by(|a, b| a.at_ms.total_cmp(&b.at_ms));
        ChurnPlan { events }
    }

    /// The seeded plan a fleet builds from its own trace: roughly a
    /// third of the non-anchor devices leave mid-trace and rejoin
    /// later, and with `inject_faults` one device is killed at 60% of
    /// the horizon. Inputs are all virtual (device count, the trace's
    /// last arrival, a trace-derived seed), so the plan — like every
    /// admission/placement decision built on it — is executor-invariant.
    pub fn seeded(devices: usize, horizon_ms: f64, seed: u64, inject_faults: bool) -> ChurnPlan {
        let mut events = Vec::new();
        if devices >= 2 && horizon_ms > 0.0 {
            let mut prng = Prng::new(seed ^ 0xC4E1_D1ED);
            let victim = if inject_faults { 1 + prng.below(devices - 1) } else { 0 };
            for device in 1..devices {
                if device == victim {
                    continue;
                }
                // ~1 in 3 devices churns: leave in the middle third of
                // the trace, rejoin in the final third.
                if prng.below(3) == 0 {
                    let leave = horizon_ms * (0.3 + 0.3 * prng.f64());
                    let join = horizon_ms * (0.7 + 0.2 * prng.f64());
                    events.push(ChurnEvent { at_ms: leave, device, kind: ChurnEventKind::Leave });
                    events.push(ChurnEvent { at_ms: join, device, kind: ChurnEventKind::Join });
                }
            }
            if inject_faults {
                events.push(ChurnEvent {
                    at_ms: horizon_ms * 0.6,
                    device: victim,
                    kind: ChurnEventKind::Kill,
                });
            }
        }
        ChurnPlan::from_events(events)
    }

    /// Is `device` in the placement pool at virtual time `t`?
    pub fn active(&self, device: usize, t: f64) -> bool {
        let mut active = true;
        for e in &self.events {
            if e.at_ms > t {
                break;
            }
            if e.device == device {
                active = matches!(e.kind, ChurnEventKind::Join);
            }
        }
        active
    }

    /// The kill timestamp of `device`, when fault injection targets it.
    pub fn kill_time(&self, device: usize) -> Option<f64> {
        self.events
            .iter()
            .find(|e| e.device == device && e.kind == ChurnEventKind::Kill)
            .map(|e| e.at_ms)
    }

    /// The first Leave/Kill boundary for `device` strictly after `t`,
    /// if any — the point an in-flight session on it must migrate.
    pub fn next_departure(&self, device: usize, t: f64) -> Option<f64> {
        self.events
            .iter()
            .find(|e| {
                e.device == device
                    && e.at_ms > t
                    && matches!(e.kind, ChurnEventKind::Leave | ChurnEventKind::Kill)
            })
            .map(|e| e.at_ms)
    }

    /// All events, sorted by time.
    pub fn events(&self) -> &[ChurnEvent] {
        &self.events
    }

    /// (join/leave churn events, kill faults) in the plan.
    pub fn counts(&self) -> (usize, usize) {
        let faults = self.events.iter().filter(|e| e.kind == ChurnEventKind::Kill).count();
        (self.events.len() - faults, faults)
    }

    /// True when the timeline is static (no churn, no faults).
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixed_population_shape() {
        let reg = DeviceRegistry::mixed(3, 2, 4);
        assert_eq!(reg.len(), 5);
        assert_eq!(reg.total_capacity(), 20);
        assert_eq!(reg.device(DeviceId(0)).class(), "V100");
        assert_eq!(reg.device(DeviceId(4)).class(), "T4");
        assert_eq!(reg.classes(), vec!["V100", "T4"]);
    }

    #[test]
    fn ids_are_sequential() {
        let mut reg = DeviceRegistry::new();
        let a = reg.register(DeviceSpec::v100(), 1);
        let b = reg.register(DeviceSpec::t4(), 2);
        assert_eq!(a, DeviceId(0));
        assert_eq!(b, DeviceId(1));
        assert_eq!(reg.device(b).capacity, 2);
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn zero_capacity_rejected() {
        DeviceRegistry::new().register(DeviceSpec::v100(), 0);
    }

    #[test]
    fn partition_deals_classes_round_robin() {
        let reg = DeviceRegistry::mixed(4, 4, 2);
        let shards = reg.partition(4);
        assert_eq!(shards.len(), 4);
        for shard in &shards {
            assert_eq!(shard.len(), 2);
            assert_eq!(shard.total_capacity(), 4);
            // Round-robin over [V100 x4, T4 x4] gives every shard one
            // of each class.
            assert_eq!(shard.classes(), vec!["V100", "T4"]);
            assert_eq!(shard.device(DeviceId(0)).id, DeviceId(0));
        }
    }

    #[test]
    #[should_panic(expected = "cannot spread")]
    fn partition_rejects_more_shards_than_devices() {
        DeviceRegistry::mixed(1, 1, 1).partition(3);
    }

    #[test]
    fn churn_plan_tracks_membership_over_time() {
        let plan = ChurnPlan::from_events(vec![
            ChurnEvent { at_ms: 100.0, device: 1, kind: ChurnEventKind::Leave },
            ChurnEvent { at_ms: 300.0, device: 1, kind: ChurnEventKind::Join },
            ChurnEvent { at_ms: 200.0, device: 2, kind: ChurnEventKind::Kill },
        ]);
        assert!(plan.active(1, 0.0) && plan.active(2, 0.0));
        assert!(!plan.active(1, 100.0), "leave takes effect at its timestamp");
        assert!(plan.active(1, 300.0), "join restores membership");
        assert!(!plan.active(2, 250.0) && !plan.active(2, 1e9), "a kill is permanent");
        assert!(plan.active(0, 150.0), "the anchor device never churns");
        assert_eq!(plan.kill_time(2), Some(200.0));
        assert_eq!(plan.kill_time(1), None);
        assert_eq!(plan.next_departure(1, 0.0), Some(100.0));
        assert_eq!(plan.next_departure(1, 100.0), None, "already departed");
        assert_eq!(plan.counts(), (2, 1));
    }

    #[test]
    fn seeded_churn_plans_are_deterministic_and_spare_the_anchor() {
        let a = ChurnPlan::seeded(8, 1000.0, 42, true);
        assert_eq!(a, ChurnPlan::seeded(8, 1000.0, 42, true), "plan must be seeded");
        assert_ne!(a, ChurnPlan::seeded(8, 1000.0, 43, true));
        let (churn, faults) = a.counts();
        assert_eq!(faults, 1, "fault injection kills exactly one device");
        assert!(churn >= 2, "an 8-device plan churns at least one device: {a:?}");
        assert!(a.events().iter().all(|e| e.device != 0 && e.device < 8));
        assert!(a.events().iter().all(|e| e.at_ms > 0.0 && e.at_ms < 1000.0));
        assert!(a.events().windows(2).all(|w| w[0].at_ms <= w[1].at_ms), "sorted by time");
        // Without faults there is no kill, and a 1-device fleet (or an
        // empty horizon) never churns at all.
        let (_, f2) = ChurnPlan::seeded(8, 1000.0, 42, false).counts();
        assert_eq!(f2, 0);
        assert!(ChurnPlan::seeded(1, 1000.0, 42, true).is_empty());
        assert!(ChurnPlan::seeded(8, 0.0, 42, true).is_empty());
    }

    #[test]
    #[should_panic(expected = "anchor")]
    fn churn_plan_rejects_events_on_the_anchor_device() {
        ChurnPlan::from_events(vec![ChurnEvent {
            at_ms: 1.0,
            device: 0,
            kind: ChurnEventKind::Leave,
        }]);
    }
}
