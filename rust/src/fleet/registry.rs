//! Device registry: the mixed-hardware population a fleet serves on.
//!
//! The paper's production cluster (§7.2) spans "thousands of GPUs of
//! different architecture generations"; the registry models that as a
//! set of device *instances*, each carrying a [`DeviceSpec`] (its
//! class — V100, T4, ...) and a serving capacity (concurrent session
//! slots). Plans are tuned per device *class* and shared across
//! instances of that class (see [`super::store`]).

use crate::gpu::DeviceSpec;

/// Index of a registered device instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DeviceId(pub usize);

/// One physical device in the fleet.
#[derive(Debug, Clone)]
pub struct RegisteredDevice {
    pub id: DeviceId,
    pub spec: DeviceSpec,
    /// Concurrent serving slots (sessions this device serves at once).
    pub capacity: usize,
}

impl RegisteredDevice {
    /// Device class used for plan sharing (the spec name).
    pub fn class(&self) -> &'static str {
        self.spec.name
    }
}

/// The fleet's device population.
#[derive(Debug, Clone, Default)]
pub struct DeviceRegistry {
    devices: Vec<RegisteredDevice>,
}

impl DeviceRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register one device instance; returns its id.
    pub fn register(&mut self, spec: DeviceSpec, capacity: usize) -> DeviceId {
        assert!(capacity > 0, "device capacity must be positive");
        let id = DeviceId(self.devices.len());
        self.devices.push(RegisteredDevice { id, spec, capacity });
        id
    }

    /// The paper's mixed population: `v100s` V100 instances followed by
    /// `t4s` T4 instances, all with the same per-device capacity.
    pub fn mixed(v100s: usize, t4s: usize, capacity: usize) -> Self {
        let mut reg = Self::new();
        for _ in 0..v100s {
            reg.register(DeviceSpec::v100(), capacity);
        }
        for _ in 0..t4s {
            reg.register(DeviceSpec::t4(), capacity);
        }
        reg
    }

    /// Fetch one device by id.
    pub fn device(&self, id: DeviceId) -> &RegisteredDevice {
        &self.devices[id.0]
    }

    /// All registered devices in registration order.
    pub fn devices(&self) -> &[RegisteredDevice] {
        &self.devices
    }

    /// Number of device instances.
    pub fn len(&self) -> usize {
        self.devices.len()
    }

    /// True when no device is registered.
    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Total serving slots across the fleet.
    pub fn total_capacity(&self) -> usize {
        self.devices.iter().map(|d| d.capacity).sum()
    }

    /// Distinct device classes in registration order.
    pub fn classes(&self) -> Vec<&'static str> {
        let mut out: Vec<&'static str> = Vec::new();
        for d in &self.devices {
            if !out.contains(&d.class()) {
                out.push(d.class());
            }
        }
        out
    }

    /// Split the population into `shards` disjoint sub-registries,
    /// dealing device instances round-robin in registration order so
    /// every shard gets a representative class mix (the usual
    /// registration order groups classes in runs, and round-robin cuts
    /// across the runs). Ids are re-assigned per shard — a shard's
    /// dispatcher is a self-contained fleet.
    pub fn partition(&self, shards: usize) -> Vec<DeviceRegistry> {
        assert!(shards > 0, "partition needs at least one shard");
        assert!(
            self.devices.len() >= shards,
            "cannot spread {} devices over {} shards",
            self.devices.len(),
            shards
        );
        let mut out = vec![Self::new(); shards];
        for (i, d) in self.devices.iter().enumerate() {
            out[i % shards].register(d.spec.clone(), d.capacity);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixed_population_shape() {
        let reg = DeviceRegistry::mixed(3, 2, 4);
        assert_eq!(reg.len(), 5);
        assert_eq!(reg.total_capacity(), 20);
        assert_eq!(reg.device(DeviceId(0)).class(), "V100");
        assert_eq!(reg.device(DeviceId(4)).class(), "T4");
        assert_eq!(reg.classes(), vec!["V100", "T4"]);
    }

    #[test]
    fn ids_are_sequential() {
        let mut reg = DeviceRegistry::new();
        let a = reg.register(DeviceSpec::v100(), 1);
        let b = reg.register(DeviceSpec::t4(), 2);
        assert_eq!(a, DeviceId(0));
        assert_eq!(b, DeviceId(1));
        assert_eq!(reg.device(b).capacity, 2);
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn zero_capacity_rejected() {
        DeviceRegistry::new().register(DeviceSpec::v100(), 0);
    }

    #[test]
    fn partition_deals_classes_round_robin() {
        let reg = DeviceRegistry::mixed(4, 4, 2);
        let shards = reg.partition(4);
        assert_eq!(shards.len(), 4);
        for shard in &shards {
            assert_eq!(shard.len(), 2);
            assert_eq!(shard.total_capacity(), 4);
            // Round-robin over [V100 x4, T4 x4] gives every shard one
            // of each class.
            assert_eq!(shard.classes(), vec!["V100", "T4"]);
            assert_eq!(shard.device(DeviceId(0)).id, DeviceId(0));
        }
    }

    #[test]
    #[should_panic(expected = "cannot spread")]
    fn partition_rejects_more_shards_than_devices() {
        DeviceRegistry::mixed(1, 1, 1).partition(3);
    }
}
