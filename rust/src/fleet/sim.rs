//! Deterministic traffic generation for the fleet simulator.
//!
//! §7.2's scale claim ("~30,000 tasks per month") is replayed as a
//! seeded discrete-event trace: exponential inter-arrivals, a skewed
//! template popularity (production fleets serve a few hot models and a
//! long tail), and a bounded iteration count per task. Everything is
//! driven by [`crate::util::Prng`], so a (seed, config) pair always
//! produces byte-identical traces — the reproducibility the bench
//! asserts.
//!
//! With [`TrafficConfig::dynamic_shapes`] the population becomes
//! *shape-polymorphic*: each template is a [`TemplateFamily`] — a
//! builder parameterized over (batch, seq) rather than one fixed graph
//! — and every task additionally draws a [`TaskShape`] from its
//! template's seeded [`ShapeDist`]. Real serving traffic varies batch
//! size and sequence length per request; this is what makes the plan
//! store's power-of-two shape buckets (and the `BucketHit` reuse tier)
//! do actual work instead of one-exploration-per-distinct-shape.

use crate::util::Prng;
use crate::workloads::models;
use crate::workloads::synthetic::{generate, generate_scaled, SyntheticConfig};
use crate::workloads::{blocks, LoopKind, Mode, Workload};

/// Trace-generation knobs.
#[derive(Debug, Clone)]
pub struct TrafficConfig {
    /// Tasks in the trace.
    pub tasks: usize,
    /// Master seed: drives arrivals, template choice and template graphs.
    pub seed: u64,
    /// Mean exponential inter-arrival gap (ms of virtual time).
    pub mean_interarrival_ms: f64,
    /// Distinct model templates in the population.
    pub templates: usize,
    /// Iterations served per task (uniform in this inclusive range).
    pub min_iterations: usize,
    pub max_iterations: usize,
    /// Ops per template graph (uniform in this inclusive range).
    pub min_ops: usize,
    pub max_ops: usize,
    /// Shape-polymorphic traffic: templates become shape-scalable
    /// families and every task draws a (batch, seq) from its template's
    /// seeded [`ShapeDist`]. Off (the default), every task carries the
    /// fixed [`TaskShape::default`] and the population is byte-identical
    /// to the static [`build_templates`] one.
    pub dynamic_shapes: bool,
    /// Multi-tenant traffic: tasks draw a tenant id in `0..tenants`
    /// from a dedicated seeded stream, weighted toward low ids (tenant
    /// 0 is the hottest, matching the hot-head template skew). Each
    /// tenant maps to a [`TenantTier`] via [`TenantTier::of`]. With
    /// `0` (the default) every task carries tenant 0 — single-tenant
    /// traffic byte-identical to the pre-tenant trace streams.
    pub tenants: usize,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        TrafficConfig {
            tasks: 1200,
            seed: 0xF1EE7,
            mean_interarrival_ms: 1.5,
            templates: 24,
            min_iterations: 4,
            max_iterations: 24,
            min_ops: 30,
            max_ops: 90,
            dynamic_shapes: false,
            tenants: 0,
        }
    }
}

impl TrafficConfig {
    /// Cluster-scale preset: the shape of the 100k-task production
    /// trace the sharded bench replays. Template count scales with the
    /// trace (one per ~500 tasks, floor 24) so the population keeps the
    /// hot-head/long-tail mix at any size; arrivals come far denser
    /// than the default (a cluster sees a month of traffic
    /// concurrently, not serially); graphs and per-task iteration
    /// counts stay light so a 100k replay is seconds, not hours; and
    /// dynamic shapes are on — shape-polymorphic traffic is the regime
    /// the sharded store's bucket tier exists for.
    pub fn cluster(tasks: usize) -> Self {
        TrafficConfig {
            tasks,
            mean_interarrival_ms: 0.2,
            templates: (tasks / 500).max(24),
            min_iterations: 2,
            max_iterations: 8,
            min_ops: 20,
            max_ops: 50,
            dynamic_shapes: true,
            ..Default::default()
        }
    }
}

/// The (batch, seq) a task wants served. For the synthetic families the
/// instantiated graph scales its leading dimension to
/// `rows() = batch × seq`; the model families thread both through the
/// parameterized `workloads::models::*_with` builders.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TaskShape {
    pub batch: usize,
    pub seq: usize,
}

impl TaskShape {
    /// Flattened row count (the leading dim of the scalable families).
    pub fn rows(&self) -> usize {
        self.batch * self.seq
    }
}

impl Default for TaskShape {
    /// The fixed-shape sentinel static traffic carries.
    fn default() -> Self {
        TaskShape { batch: 1, seq: 1 }
    }
}

/// A tenant's priority tier: the SLA contract admission enforces under
/// compile backpressure. Tenants map to tiers round-robin
/// ([`TenantTier::of`]), so any `tenants >= 3` mix exercises all three.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TenantTier {
    /// Paid/latency-critical traffic: never shed, full FIFO semantics —
    /// identical to the single-tenant admission policy, so all-Premium
    /// traffic decides byte-for-byte like the pre-tenant fleet.
    Premium,
    /// Bulk serving: degrades to the XLA fallback under compile
    /// saturation and sheds when its queue-delay SLA is blown.
    Standard,
    /// Scavenger tier: sheds under any backpressure its SLA cannot
    /// absorb instead of queueing ahead of paid work.
    BestEffort,
}

impl TenantTier {
    /// The tier a tenant id serves under.
    pub fn of(tenant: u32) -> TenantTier {
        match tenant % 3 {
            0 => TenantTier::Premium,
            1 => TenantTier::Standard,
            _ => TenantTier::BestEffort,
        }
    }

    /// Max acceptable queue delay (ms of virtual time) before a task of
    /// this tier is shed rather than served late. Premium's target
    /// equals the admission controller's default `max_queue_delay_ms`,
    /// so a *served* Premium task structurally never violates its SLA —
    /// the report's `sla_violations` counter is an invariant detector,
    /// not a tolerance.
    pub fn sla_ms(&self) -> f64 {
        match self {
            TenantTier::Premium => 250.0,
            TenantTier::Standard => 100.0,
            TenantTier::BestEffort => 25.0,
        }
    }

    /// Stable small code for decision-digest folding.
    pub fn code(&self) -> u64 {
        match self {
            TenantTier::Premium => 0,
            TenantTier::Standard => 1,
            TenantTier::BestEffort => 2,
        }
    }

    /// Stable display name (reports, lifecycle events).
    pub fn name(&self) -> &'static str {
        match self {
            TenantTier::Premium => "premium",
            TenantTier::Standard => "standard",
            TenantTier::BestEffort => "best_effort",
        }
    }
}

/// One task in the trace: an instance of a template model arriving at a
/// virtual time, at a concrete (batch, seq), serving a fixed number of
/// iterations on behalf of a tenant.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetTask {
    pub id: usize,
    pub arrival_ms: f64,
    pub template: usize,
    pub iterations: usize,
    pub shape: TaskShape,
    pub tenant: u32,
}

impl FleetTask {
    /// The priority tier this task is admitted under.
    pub fn tier(&self) -> TenantTier {
        TenantTier::of(self.tenant)
    }
}

/// Per-template shape distribution: the (batch, seq) choice sets one
/// workload's requests draw from. Seeded per (traffic seed, template),
/// so a template's shape mix is stable across replays while different
/// templates get different windows — hot models at big batches, tail
/// models at small ones, exactly the production mix the amortization
/// claim is about.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShapeDist {
    pub batches: Vec<usize>,
    pub seqs: Vec<usize>,
}

/// Batch choices shape distributions window over (powers of two: batch
/// rarely arrives off-pow2 in serving systems that pad).
const BATCH_CHOICES: [usize; 6] = [1, 2, 4, 8, 16, 32];
/// Sequence-length choices: deliberately mixing powers of two with
/// off-pow2 lengths (24/48/96), so sibling shapes land in shared
/// power-of-two buckets and the `BucketHit` tier is exercised.
const SEQ_CHOICES: [usize; 8] = [16, 24, 32, 48, 64, 96, 128, 192];

impl ShapeDist {
    /// The seeded distribution for one template.
    pub fn for_template(cfg: &TrafficConfig, template: usize) -> ShapeDist {
        let mut p = Prng::new(
            cfg.seed ^ 0x5AFE_5EED ^ (template as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        // A contiguous window of at least two choices per axis: every
        // template sees genuine shape variety.
        let b0 = p.below(BATCH_CHOICES.len() - 1);
        let b1 = p.range(b0 + 1, BATCH_CHOICES.len() - 1);
        let s0 = p.below(SEQ_CHOICES.len() - 1);
        let s1 = p.range(s0 + 1, SEQ_CHOICES.len() - 1);
        ShapeDist {
            batches: BATCH_CHOICES[b0..=b1].to_vec(),
            seqs: SEQ_CHOICES[s0..=s1].to_vec(),
        }
    }

    /// Draw one (batch, seq) from the distribution.
    pub fn draw(&self, prng: &mut Prng) -> TaskShape {
        TaskShape { batch: *prng.pick(&self.batches), seq: *prng.pick(&self.seqs) }
    }
}

/// A parameterized paper model usable as a shape-polymorphic template
/// (the `workloads::models::*_with` builders).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelFamily {
    /// BERT encoder (inference config) at (batch, seq) — structure
    /// invariant in both.
    BertInfer,
    /// DIEN (inference) at (batch, seq_len) — only batch variation is
    /// shape-polymorphic (seq changes the unrolled recurrence depth).
    DienInfer,
    /// The Figure-1 layer-norm microbenchmark at rows = batch × seq.
    LayerNorm,
    /// Cross-GEMM stitching probe: x[512,64] × w[64, 32·seq] with a
    /// bias+relu epilogue. The staging tile scales with seq, so sibling
    /// shapes inside one pow2 bucket can disagree on absorption
    /// feasibility — the bucket tier's retune-failure path.
    GemmEpilogueProbe,
    /// Footprint-pruning probe: a layer-norm block (profitably fusible,
    /// so the exploration beats its fallback and publishes) next to a
    /// softmax-style wide tail exp(y[rows, 16384]) → row-sum whose
    /// exp→reduce candidate stages 64 KB per row — over the per-block
    /// shared-memory cap of every device class at every shape. A
    /// deterministic source of footprint-pruned candidates for the
    /// fleet's `footprint_pruned` counter under dynamic-shape traffic.
    FootprintProbe,
}

impl ModelFamily {
    fn build(self, shape: TaskShape) -> Workload {
        match self {
            ModelFamily::BertInfer => models::bert_with(Mode::Infer, shape.batch, shape.seq),
            ModelFamily::DienInfer => models::dien_with(Mode::Infer, shape.batch, shape.seq),
            ModelFamily::LayerNorm => {
                use crate::graph::{DType, Graph, Shape};
                let mut g = Graph::new("LN");
                let x = g.param(Shape::new(vec![shape.rows().max(2), 256]), DType::F32, "x");
                let _ = blocks::layer_norm(&mut g, x, "ln");
                Workload {
                    name: "LN",
                    field: "micro",
                    mode: Mode::Infer,
                    batch: shape.batch,
                    loop_kind: LoopKind::None,
                    graph: g,
                }
            }
            ModelFamily::GemmEpilogueProbe => {
                use crate::graph::{DType, Graph, OpKind, Shape};
                let cols = 32 * shape.seq.max(1);
                let mut g = Graph::new("GEP");
                let x = g.param(Shape::new(vec![512, 64]), DType::F32, "x");
                let w = g.param(Shape::new(vec![64, cols]), DType::F32, "w");
                let mm = g.matmul(x, w, "mm");
                let b = g.param(Shape::new(vec![cols]), DType::F32, "b");
                let bb = g.broadcast(b, Shape::new(vec![512, cols]), "bb");
                let add = g.binary(OpKind::Add, mm, bb, "add");
                let _ = g.unary(OpKind::Relu, add, "relu");
                Workload {
                    name: "GEP",
                    field: "micro",
                    mode: Mode::Infer,
                    batch: shape.batch,
                    loop_kind: LoopKind::None,
                    graph: g,
                }
            }
            ModelFamily::FootprintProbe => {
                use crate::graph::{DType, Graph, OpKind, ReduceOp, Shape};
                let rows = shape.rows().max(2);
                let mut g = Graph::new("FPP");
                let x = g.param(Shape::new(vec![rows, 256]), DType::F32, "x");
                let _ = blocks::layer_norm(&mut g, x, "ln");
                let y = g.param(Shape::new(vec![rows, 16384]), DType::F32, "y");
                let e = g.unary(OpKind::Exp, y, "exp");
                let _ = g.reduce(ReduceOp::Sum, e, vec![1], "rowsum");
                Workload {
                    name: "FPP",
                    field: "micro",
                    mode: Mode::Infer,
                    batch: shape.batch,
                    loop_kind: LoopKind::None,
                    graph: g,
                }
            }
        }
    }
}

/// One template of the (possibly shape-polymorphic) population: a
/// builder the fleet instantiates per requested [`TaskShape`].
/// Instantiations of one family at different shapes share graph
/// *structure* (for the scalable variants), which is what lets the plan
/// store's shape buckets re-serve one exploration across sibling
/// shapes.
#[derive(Debug, Clone)]
pub enum TemplateFamily {
    /// A single fixed-shape workload: `instantiate` ignores the shape.
    /// The static population ([`build_templates`]) wrapped unchanged.
    Fixed(Workload),
    /// Shape-scalable synthetic graph family, instantiated at
    /// rows = batch × seq with a per-family structure seed
    /// ([`generate_scaled`]).
    Synthetic {
        cfg: SyntheticConfig,
        graph_seed: u64,
        loop_kind: LoopKind,
    },
    /// A parameterized paper model.
    Model(ModelFamily),
}

impl TemplateFamily {
    /// Build the workload instance this family serves at `shape`.
    /// Deterministic: one (family, shape) always yields the same graph.
    pub fn instantiate(&self, shape: TaskShape) -> Workload {
        match self {
            TemplateFamily::Fixed(w) => w.clone(),
            TemplateFamily::Synthetic { cfg, graph_seed, loop_kind } => {
                let mut p = Prng::new(*graph_seed);
                let graph = generate_scaled(cfg, &mut p, shape.rows().max(2));
                Workload {
                    name: "task",
                    field: "fleet",
                    mode: Mode::Infer,
                    batch: shape.batch,
                    loop_kind: *loop_kind,
                    graph,
                }
            }
            TemplateFamily::Model(m) => m.build(shape),
        }
    }
}

/// Build the static template population: synthetic graphs spanning the
/// op-mix space (elementwise chains, reduction towers, GEMM sprinkling)
/// with the three runtime loop regimes interleaved, as in the §7.2
/// bench. Byte-stable across PRs: the fixed-shape fleet path depends on
/// this exact population.
pub fn build_templates(cfg: &TrafficConfig) -> Vec<Workload> {
    assert!(cfg.templates > 0, "need at least one template");
    assert!(cfg.min_ops <= cfg.max_ops);
    let mut prng = Prng::new(cfg.seed ^ 0xABCD_EF01_2345_6789);
    (0..cfg.templates)
        .map(|i| {
            let syn = SyntheticConfig {
                num_ops: prng.range(cfg.min_ops, cfg.max_ops),
                p_reduce: 0.05 + prng.f64() * 0.2,
                p_expensive: 0.05 + prng.f64() * 0.25,
                p_gemm: prng.f64() * 0.1,
                ..Default::default()
            };
            let graph = generate(&syn, &mut prng);
            let loop_kind = template_loop_kind(i);
            Workload {
                name: "task",
                field: "fleet",
                mode: Mode::Infer,
                batch: 1,
                loop_kind,
                graph,
            }
        })
        .collect()
}

fn template_loop_kind(i: usize) -> LoopKind {
    match i % 5 {
        0 => LoopKind::DynamicLoop,
        1 => LoopKind::StaticUnrolled,
        _ => LoopKind::None,
    }
}

/// Build the template population as families. With
/// [`TrafficConfig::dynamic_shapes`] off this wraps the static
/// [`build_templates`] population unchanged (every instantiation is the
/// same fixed graph); with it on, each template becomes a shape-scalable
/// synthetic family drawing the same op-mix knobs, instantiated lazily
/// at each requested (batch, seq).
pub fn build_template_families(cfg: &TrafficConfig) -> Vec<TemplateFamily> {
    if !cfg.dynamic_shapes {
        return build_templates(cfg).into_iter().map(TemplateFamily::Fixed).collect();
    }
    assert!(cfg.templates > 0, "need at least one template");
    assert!(cfg.min_ops <= cfg.max_ops);
    let mut prng = Prng::new(cfg.seed ^ 0xABCD_EF01_2345_6789);
    (0..cfg.templates)
        .map(|i| {
            let syn = SyntheticConfig {
                num_ops: prng.range(cfg.min_ops, cfg.max_ops),
                p_reduce: 0.05 + prng.f64() * 0.2,
                p_expensive: 0.05 + prng.f64() * 0.25,
                p_gemm: prng.f64() * 0.1,
                ..Default::default()
            };
            let graph_seed = prng.next_u64();
            TemplateFamily::Synthetic {
                cfg: syn,
                graph_seed,
                loop_kind: template_loop_kind(i),
            }
        })
        .collect()
}

/// Generate the arrival trace (sorted by arrival time by construction).
/// The arrival/template/iteration streams are identical with
/// `dynamic_shapes` on or off and with any tenant count: shape and
/// tenant draws come from *separate* seeded PRNG streams, so flipping
/// either knob changes those fields — not which templates arrive when.
pub fn generate_trace(cfg: &TrafficConfig) -> Vec<FleetTask> {
    assert!(cfg.min_iterations >= 1);
    assert!(cfg.min_iterations <= cfg.max_iterations);
    assert!(cfg.mean_interarrival_ms > 0.0);
    let dists: Option<Vec<ShapeDist>> = if cfg.dynamic_shapes {
        Some((0..cfg.templates).map(|t| ShapeDist::for_template(cfg, t)).collect())
    } else {
        None
    };
    let mut prng = Prng::new(cfg.seed);
    // Dedicated stream for shape draws: the main stream above must stay
    // byte-identical whether or not shapes vary.
    let mut shape_prng = Prng::new(cfg.seed ^ 0x5AFE_CAFE);
    // Dedicated stream for tenant draws, for the same reason.
    let mut tenant_prng = Prng::new(cfg.seed ^ 0x7E7A_A717);
    // Triangular tenant popularity: tenant i carries weight
    // `tenants - i`, so tenant 0 (Premium) is the hottest — production
    // fleets serve a few heavy paid tenants and a long scavenger tail.
    let tenant_weight_total = cfg.tenants * (cfg.tenants + 1) / 2;
    let mut t = 0.0f64;
    (0..cfg.tasks)
        .map(|id| {
            // Exponential inter-arrival: -mean · ln(1 - U), U ∈ [0, 1).
            let u = prng.f64();
            t += -cfg.mean_interarrival_ms * (1.0 - u).ln();
            // Quadratic popularity skew: low-index templates are hot.
            let r = prng.f64();
            let template = ((r * r * cfg.templates as f64) as usize).min(cfg.templates - 1);
            let iterations = prng.range(cfg.min_iterations, cfg.max_iterations);
            let shape = match &dists {
                Some(d) => d[template].draw(&mut shape_prng),
                None => TaskShape::default(),
            };
            let tenant = if cfg.tenants == 0 {
                0
            } else {
                let mut roll = tenant_prng.below(tenant_weight_total);
                let mut chosen = 0;
                for i in 0..cfg.tenants {
                    let w = cfg.tenants - i;
                    if roll < w {
                        chosen = i as u32;
                        break;
                    }
                    roll -= w;
                }
                chosen
            };
            FleetTask { id, arrival_ms: t, template, iterations, shape, tenant }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ShapeClass;

    #[test]
    fn traces_are_deterministic_per_seed() {
        let cfg = TrafficConfig { tasks: 200, ..Default::default() };
        assert_eq!(generate_trace(&cfg), generate_trace(&cfg));
        let other = TrafficConfig { seed: 99, ..cfg };
        assert_ne!(generate_trace(&cfg), generate_trace(&other));
    }

    #[test]
    fn arrivals_are_monotone_and_fields_in_bounds() {
        let cfg = TrafficConfig { tasks: 500, ..Default::default() };
        let trace = generate_trace(&cfg);
        assert_eq!(trace.len(), 500);
        let mut last = 0.0;
        for task in &trace {
            assert!(task.arrival_ms >= last);
            last = task.arrival_ms;
            assert!(task.template < cfg.templates);
            assert!((cfg.min_iterations..=cfg.max_iterations).contains(&task.iterations));
            assert_eq!(task.shape, TaskShape::default(), "static traffic is fixed-shape");
        }
    }

    #[test]
    fn popularity_is_skewed_toward_hot_templates() {
        let cfg = TrafficConfig { tasks: 2000, ..Default::default() };
        let trace = generate_trace(&cfg);
        let hot = trace.iter().filter(|t| t.template < cfg.templates / 4).count();
        // Quadratic skew: the first quartile of templates draws ~half
        // the traffic (sqrt(0.25) = 0.5), far above the uniform 25%.
        assert!(hot as f64 > trace.len() as f64 * 0.35, "hot share {hot}");
    }

    #[test]
    fn cluster_preset_scales_templates_with_trace_size() {
        let big = TrafficConfig::cluster(100_000);
        assert_eq!(big.tasks, 100_000);
        assert_eq!(big.templates, 200);
        assert!(big.dynamic_shapes);
        assert!(big.mean_interarrival_ms < 1.0);
        // Small replays keep the default population floor.
        assert_eq!(TrafficConfig::cluster(1000).templates, 24);
    }

    #[test]
    fn templates_are_deterministic_and_varied() {
        let cfg = TrafficConfig { templates: 8, ..Default::default() };
        let a = build_templates(&cfg);
        let b = build_templates(&cfg);
        assert_eq!(a.len(), 8);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.graph.len(), y.graph.len());
            assert_eq!(x.loop_kind, y.loop_kind);
        }
        // All three loop regimes appear.
        assert!(a.iter().any(|w| w.loop_kind == LoopKind::DynamicLoop));
        assert!(a.iter().any(|w| w.loop_kind == LoopKind::StaticUnrolled));
        assert!(a.iter().any(|w| w.loop_kind == LoopKind::None));
    }

    #[test]
    fn dynamic_shape_streams_match_static_arrivals() {
        // Flipping dynamic_shapes must not perturb which templates
        // arrive when — only the shapes.
        let stat = TrafficConfig { tasks: 300, ..Default::default() };
        let dyn_cfg = TrafficConfig { dynamic_shapes: true, ..stat.clone() };
        let a = generate_trace(&stat);
        let b = generate_trace(&dyn_cfg);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_ms, y.arrival_ms);
            assert_eq!(x.template, y.template);
            assert_eq!(x.iterations, y.iterations);
        }
        assert_eq!(generate_trace(&dyn_cfg), generate_trace(&dyn_cfg));
    }

    #[test]
    fn tenant_stream_does_not_perturb_other_streams() {
        // Flipping tenants on must not change which templates arrive
        // when, what iterations they serve, or what shapes they draw —
        // only the tenant field (same isolation contract as shapes).
        let single = TrafficConfig { tasks: 300, dynamic_shapes: true, ..Default::default() };
        let multi = TrafficConfig { tenants: 6, ..single.clone() };
        let a = generate_trace(&single);
        let b = generate_trace(&multi);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_ms, y.arrival_ms);
            assert_eq!(x.template, y.template);
            assert_eq!(x.iterations, y.iterations);
            assert_eq!(x.shape, y.shape);
            assert_eq!(x.tenant, 0, "single-tenant traffic is all tenant 0");
        }
        assert_eq!(generate_trace(&multi), generate_trace(&multi));
    }

    #[test]
    fn tenant_mix_is_skewed_and_in_bounds() {
        let cfg = TrafficConfig { tasks: 2000, tenants: 6, ..Default::default() };
        let trace = generate_trace(&cfg);
        let mut counts = vec![0usize; cfg.tenants];
        for task in &trace {
            assert!((task.tenant as usize) < cfg.tenants);
            counts[task.tenant as usize] += 1;
        }
        // Triangular weights: tenant 0 carries 6/21 of traffic, tenant 5
        // carries 1/21 — every tenant appears, hottest first.
        assert!(counts.iter().all(|&c| c > 0), "every tenant must appear: {counts:?}");
        assert!(counts[0] > counts[cfg.tenants - 1], "tenant 0 must be hottest: {counts:?}");
    }

    #[test]
    fn tiers_cycle_and_premium_sla_matches_admission_default() {
        assert_eq!(TenantTier::of(0), TenantTier::Premium);
        assert_eq!(TenantTier::of(1), TenantTier::Standard);
        assert_eq!(TenantTier::of(2), TenantTier::BestEffort);
        assert_eq!(TenantTier::of(3), TenantTier::Premium);
        // Premium's SLA equals the admission controller's default queue
        // bound: a served Premium task can never violate it.
        assert_eq!(
            TenantTier::Premium.sla_ms(),
            crate::fleet::AdmissionConfig::default().max_queue_delay_ms
        );
        assert!(TenantTier::Standard.sla_ms() < TenantTier::Premium.sla_ms());
        assert!(TenantTier::BestEffort.sla_ms() < TenantTier::Standard.sla_ms());
    }

    #[test]
    fn shape_dists_have_variety_and_stay_in_choice_sets() {
        let cfg = TrafficConfig { dynamic_shapes: true, ..Default::default() };
        for t in 0..cfg.templates {
            let d = ShapeDist::for_template(&cfg, t);
            assert_eq!(d, ShapeDist::for_template(&cfg, t), "dist must be seeded");
            assert!(d.batches.len() >= 2, "template {t}: {d:?}");
            assert!(d.seqs.len() >= 2, "template {t}: {d:?}");
            assert!(d.batches.iter().all(|b| BATCH_CHOICES.contains(b)));
            assert!(d.seqs.iter().all(|s| SEQ_CHOICES.contains(s)));
        }
        // Tasks actually vary in shape.
        let trace = generate_trace(&TrafficConfig { tasks: 400, ..cfg });
        let distinct: std::collections::HashSet<(usize, TaskShape)> =
            trace.iter().map(|t| (t.template, t.shape)).collect();
        let distinct_templates: std::collections::HashSet<usize> =
            trace.iter().map(|t| t.template).collect();
        assert!(
            distinct.len() > 2 * distinct_templates.len(),
            "expected shape variety: {} instances over {} templates",
            distinct.len(),
            distinct_templates.len()
        );
    }

    #[test]
    fn synthetic_families_instantiate_structure_siblings() {
        let cfg = TrafficConfig { dynamic_shapes: true, templates: 6, ..Default::default() };
        let families = build_template_families(&cfg);
        assert_eq!(families.len(), 6);
        for fam in &families {
            let a = fam.instantiate(TaskShape { batch: 2, seq: 24 });
            let b = fam.instantiate(TaskShape { batch: 2, seq: 32 });
            let c = fam.instantiate(TaskShape { batch: 2, seq: 24 });
            a.graph.validate().unwrap();
            b.graph.validate().unwrap();
            // Same family, same shape → identical graph (deterministic).
            assert_eq!(
                crate::coordinator::GraphKey::of(&a.graph),
                crate::coordinator::GraphKey::of(&c.graph)
            );
            // Sibling shapes share structure, not the exact key; rows 48
            // vs 64 both bucket to 64, so the full shape class matches.
            let (ca, cb) = (ShapeClass::of(&a.graph), ShapeClass::of(&b.graph));
            assert_eq!(ca.structure, cb.structure);
            assert_eq!(ca.bucket, cb.bucket, "rows 48 and 64 share the pow2-64 bucket");
            assert_ne!(
                crate::coordinator::GraphKey::of(&a.graph),
                crate::coordinator::GraphKey::of(&b.graph)
            );
        }
    }

    #[test]
    fn fixed_families_ignore_the_shape() {
        let cfg = TrafficConfig { templates: 3, ..Default::default() };
        let fixed = build_template_families(&cfg);
        let plain = build_templates(&cfg);
        assert_eq!(fixed.len(), plain.len());
        for (fam, w) in fixed.iter().zip(&plain) {
            let a = fam.instantiate(TaskShape::default());
            let b = fam.instantiate(TaskShape { batch: 8, seq: 128 });
            assert_eq!(
                crate::coordinator::GraphKey::of(&a.graph),
                crate::coordinator::GraphKey::of(&b.graph)
            );
            assert_eq!(
                crate::coordinator::GraphKey::of(&a.graph),
                crate::coordinator::GraphKey::of(&w.graph)
            );
        }
    }

    #[test]
    fn model_families_are_shape_polymorphic() {
        // The parameterized models::* builders drive shape-varying
        // requests too: BERT instantiations at sibling seqs share
        // structure, and 24 vs 32 share the pow2-32 bucket.
        let fam = TemplateFamily::Model(ModelFamily::BertInfer);
        let a = fam.instantiate(TaskShape { batch: 2, seq: 24 });
        let b = fam.instantiate(TaskShape { batch: 2, seq: 32 });
        let (ca, cb) = (ShapeClass::of(&a.graph), ShapeClass::of(&b.graph));
        assert_eq!(ca.structure, cb.structure);
        assert_eq!(ca.bucket, cb.bucket);
        assert_ne!(
            crate::coordinator::GraphKey::of(&a.graph),
            crate::coordinator::GraphKey::of(&b.graph)
        );
        // LN micro-family: rows 48 vs 64 — same bucket, distinct keys.
        let ln = TemplateFamily::Model(ModelFamily::LayerNorm);
        let x = ln.instantiate(TaskShape { batch: 1, seq: 48 });
        let y = ln.instantiate(TaskShape { batch: 1, seq: 64 });
        assert_eq!(ShapeClass::of(&x.graph), ShapeClass::of(&y.graph));
        assert_ne!(
            crate::coordinator::GraphKey::of(&x.graph),
            crate::coordinator::GraphKey::of(&y.graph)
        );
    }
}
