//! Deterministic traffic generation for the fleet simulator.
//!
//! §7.2's scale claim ("~30,000 tasks per month") is replayed as a
//! seeded discrete-event trace: exponential inter-arrivals, a skewed
//! template popularity (production fleets serve a few hot models and a
//! long tail), and a bounded iteration count per task. Everything is
//! driven by [`crate::util::Prng`], so a (seed, config) pair always
//! produces byte-identical traces — the reproducibility the bench
//! asserts.

use crate::util::Prng;
use crate::workloads::synthetic::{generate, SyntheticConfig};
use crate::workloads::{LoopKind, Mode, Workload};

/// Trace-generation knobs.
#[derive(Debug, Clone)]
pub struct TrafficConfig {
    /// Tasks in the trace.
    pub tasks: usize,
    /// Master seed: drives arrivals, template choice and template graphs.
    pub seed: u64,
    /// Mean exponential inter-arrival gap (ms of virtual time).
    pub mean_interarrival_ms: f64,
    /// Distinct model templates in the population.
    pub templates: usize,
    /// Iterations served per task (uniform in this inclusive range).
    pub min_iterations: usize,
    pub max_iterations: usize,
    /// Ops per template graph (uniform in this inclusive range).
    pub min_ops: usize,
    pub max_ops: usize,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        TrafficConfig {
            tasks: 1200,
            seed: 0xF1EE7,
            mean_interarrival_ms: 1.5,
            templates: 24,
            min_iterations: 4,
            max_iterations: 24,
            min_ops: 30,
            max_ops: 90,
        }
    }
}

/// One task in the trace: an instance of a template model arriving at a
/// virtual time and serving a fixed number of iterations.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetTask {
    pub id: usize,
    pub arrival_ms: f64,
    pub template: usize,
    pub iterations: usize,
}

/// Build the template population: synthetic graphs spanning the op-mix
/// space (elementwise chains, reduction towers, GEMM sprinkling) with
/// the three runtime loop regimes interleaved, as in the §7.2 bench.
pub fn build_templates(cfg: &TrafficConfig) -> Vec<Workload> {
    assert!(cfg.templates > 0, "need at least one template");
    assert!(cfg.min_ops <= cfg.max_ops);
    let mut prng = Prng::new(cfg.seed ^ 0xABCD_EF01_2345_6789);
    (0..cfg.templates)
        .map(|i| {
            let syn = SyntheticConfig {
                num_ops: prng.range(cfg.min_ops, cfg.max_ops),
                p_reduce: 0.05 + prng.f64() * 0.2,
                p_expensive: 0.05 + prng.f64() * 0.25,
                p_gemm: prng.f64() * 0.1,
                ..Default::default()
            };
            let graph = generate(&syn, &mut prng);
            let loop_kind = match i % 5 {
                0 => LoopKind::DynamicLoop,
                1 => LoopKind::StaticUnrolled,
                _ => LoopKind::None,
            };
            Workload {
                name: "task",
                field: "fleet",
                mode: Mode::Infer,
                batch: 1,
                loop_kind,
                graph,
            }
        })
        .collect()
}

/// Generate the arrival trace (sorted by arrival time by construction).
pub fn generate_trace(cfg: &TrafficConfig) -> Vec<FleetTask> {
    assert!(cfg.min_iterations >= 1 && cfg.min_iterations <= cfg.max_iterations);
    assert!(cfg.mean_interarrival_ms > 0.0);
    let mut prng = Prng::new(cfg.seed);
    let mut t = 0.0f64;
    (0..cfg.tasks)
        .map(|id| {
            // Exponential inter-arrival: -mean · ln(1 - U), U ∈ [0, 1).
            let u = prng.f64();
            t += -cfg.mean_interarrival_ms * (1.0 - u).ln();
            // Quadratic popularity skew: low-index templates are hot.
            let r = prng.f64();
            let template = ((r * r * cfg.templates as f64) as usize).min(cfg.templates - 1);
            let iterations = prng.range(cfg.min_iterations, cfg.max_iterations);
            FleetTask { id, arrival_ms: t, template, iterations }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_are_deterministic_per_seed() {
        let cfg = TrafficConfig { tasks: 200, ..Default::default() };
        assert_eq!(generate_trace(&cfg), generate_trace(&cfg));
        let other = TrafficConfig { seed: 99, ..cfg };
        assert_ne!(generate_trace(&cfg), generate_trace(&other));
    }

    #[test]
    fn arrivals_are_monotone_and_fields_in_bounds() {
        let cfg = TrafficConfig { tasks: 500, ..Default::default() };
        let trace = generate_trace(&cfg);
        assert_eq!(trace.len(), 500);
        let mut last = 0.0;
        for task in &trace {
            assert!(task.arrival_ms >= last);
            last = task.arrival_ms;
            assert!(task.template < cfg.templates);
            assert!((cfg.min_iterations..=cfg.max_iterations).contains(&task.iterations));
        }
    }

    #[test]
    fn popularity_is_skewed_toward_hot_templates() {
        let cfg = TrafficConfig { tasks: 2000, ..Default::default() };
        let trace = generate_trace(&cfg);
        let hot = trace.iter().filter(|t| t.template < cfg.templates / 4).count();
        // Quadratic skew: the first quartile of templates draws ~half
        // the traffic (sqrt(0.25) = 0.5), far above the uniform 25%.
        assert!(hot as f64 > trace.len() as f64 * 0.35, "hot share {hot}");
    }

    #[test]
    fn templates_are_deterministic_and_varied() {
        let cfg = TrafficConfig { templates: 8, ..Default::default() };
        let a = build_templates(&cfg);
        let b = build_templates(&cfg);
        assert_eq!(a.len(), 8);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.graph.len(), y.graph.len());
            assert_eq!(x.loop_kind, y.loop_kind);
        }
        // All three loop regimes appear.
        assert!(a.iter().any(|w| w.loop_kind == LoopKind::DynamicLoop));
        assert!(a.iter().any(|w| w.loop_kind == LoopKind::StaticUnrolled));
        assert!(a.iter().any(|w| w.loop_kind == LoopKind::None));
    }
}
