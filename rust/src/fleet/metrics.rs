//! Fleet-wide report: the §7.2 production numbers, but measured through
//! the coordinator path instead of asserted.

use crate::obs::ObsReport;
use crate::util::{fmt_f, JsonValue, Summary, Table};

/// Per-device utilization line.
#[derive(Debug, Clone)]
pub struct DeviceUtilization {
    pub id: usize,
    pub class: &'static str,
    pub tasks: usize,
    pub busy_ms: f64,
    /// busy / (makespan × capacity).
    pub utilization: f64,
}

/// Everything one trace replay produces. Under the virtual-time
/// executor all quantities are deterministic: two replays of the same
/// (seed, config) are byte-identical, which the production bench
/// asserts. Under the wall-clock executor the decision fields still
/// match the virtual replay's (the equivalence test asserts it), while
/// the measured fields (`served_gpu_ms`, iteration percentiles,
/// `wall_elapsed_ms`, queue accounting) reflect the real thread race.
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Which executor produced this report: "virtual" or "wallclock".
    pub executor: &'static str,
    pub tasks: usize,
    pub admitted: usize,
    pub fallback_only: usize,
    pub rejected: usize,
    pub exact_hits: usize,
    pub port_hits: usize,
    /// Store lookups resolved through the shape-bucket tier: a sibling
    /// shape's plan re-served after a launch-dim-only retune (0 unless
    /// traffic is shape-varying).
    pub bucket_hits: usize,
    pub misses: usize,
    /// Distinct exact graphs the trace touched (template × shape
    /// instances). The amortization headline: full explorations should
    /// be sublinear in this under shape-varying traffic.
    pub distinct_shapes: usize,
    /// Distinct (structure, power-of-two bucket) classes the trace
    /// touched — the reuse granularity of the bucket tier.
    pub distinct_buckets: usize,
    pub explore_jobs: usize,
    pub port_jobs: usize,
    pub port_failures: usize,
    /// Same-class shape-retune compile jobs (one per acted-on bucket
    /// hit).
    pub bucket_retunes: usize,
    /// Shape retunes whose sibling plan could not schedule at the new
    /// shape (fell back to a full exploration).
    pub bucket_failures: usize,
    pub fs_vetoes: usize,
    /// Region-shard compile sub-jobs fanned out by sharded explorations
    /// (0 with `compile_shards == 1` or when no explored graph had more
    /// than one fusible region).
    pub shard_jobs: usize,
    /// Drift-triggered re-exploration compile jobs (0 unless the
    /// calibration loop is on).
    pub reexplore_jobs: usize,
    /// Re-explorations whose plan beat the incumbent (hot-swapped in).
    pub reexplore_improved: usize,
    /// Re-explorations the plan-quality no-worse gate rejected.
    pub reexplore_rejected: usize,
    /// Per-kernel (modeled, measured) pairs the calibrator recorded.
    pub calibration_samples: usize,
    /// Median |predicted − measured| relative kernel-time error under
    /// the default cost constants / under the fitted per-class params
    /// (sample-weighted across classes; `drift_after <= drift_before`
    /// by construction — the fit falls back to the defaults whenever it
    /// would not help).
    pub drift_before: f64,
    pub drift_after: f64,
    /// Per-job compile latency (enqueue → virtual ready; a sharded
    /// exploration counts once, at its join barrier) over every explore
    /// and port job. Derived from the virtual clocks in both executors,
    /// so the percentiles are executor-invariant and deterministic.
    pub compile: Summary,
    /// Tasks whose served GPU time exceeded their fallback GPU time.
    /// The never-negative guard must keep this at zero (§7.2).
    pub regressions: usize,
    /// Compile jobs run by their hash-affinity owner worker vs. taken
    /// by a different (earliest-free) worker. In the virtual-time
    /// replay assignment is immediate, so this measures owner-affinity
    /// misses — not deque backlog relief (see `fleet::queue` docs).
    pub compile_owner_runs: usize,
    pub compile_affinity_misses: usize,
    /// Total GPU time actually spent serving (FS where available).
    pub served_gpu_ms: f64,
    /// GPU time the same trace would have cost on the fallback alone.
    pub fallback_gpu_ms: f64,
    /// Queue-wait distribution (arrival → slot start) over served tasks.
    pub wait: Summary,
    /// Per-iteration device latency percentiles, fleet-wide (aggregated
    /// per-device `ServiceMetrics`).
    pub iter_p50_ms: f64,
    pub iter_p99_ms: f64,
    /// Virtual time at which the last task finished.
    pub makespan_ms: f64,
    /// Real elapsed time of the wall-clock run (0 under virtual time).
    pub wall_elapsed_ms: f64,
    pub per_device: Vec<DeviceUtilization>,
    /// Flight-recorder report (stage-attributed latency + lock
    /// contention); `None` unless `FleetOptions::observe` was on and
    /// the crate was built with the `obs` feature.
    pub observability: Option<ObsReport>,
}

impl FleetReport {
    /// GPU time the fleet saved versus fallback-only serving.
    pub fn saved_gpu_ms(&self) -> f64 {
        self.fallback_gpu_ms - self.served_gpu_ms
    }

    /// Fraction of fallback GPU time saved.
    pub fn saved_frac(&self) -> f64 {
        if self.fallback_gpu_ms <= 0.0 {
            0.0
        } else {
            self.saved_gpu_ms() / self.fallback_gpu_ms
        }
    }

    /// Tasks that were actually served (admitted either way).
    pub fn served_tasks(&self) -> usize {
        self.admitted + self.fallback_only
    }

    /// Project the per-task saving to a monthly task volume, in GPU
    /// hours — the paper's "~7,000 GPU hours for ~30,000 tasks" frame.
    /// The trace's tasks are minutes-scale; the projection scales each
    /// task's saving by `hours_per_task` over its simulated GPU time.
    pub fn projected_gpu_hours_saved(&self, tasks_per_month: f64, hours_per_task: f64) -> f64 {
        if self.served_tasks() == 0 {
            return 0.0;
        }
        tasks_per_month * hours_per_task * self.saved_frac()
    }

    /// JSON snapshot (deterministic field order and values).
    pub fn to_json(&self) -> JsonValue {
        let mut o = JsonValue::obj();
        o.set("executor", self.executor)
            .set("tasks", self.tasks)
            .set("admitted", self.admitted)
            .set("fallback_only", self.fallback_only)
            .set("rejected", self.rejected)
            .set("exact_hits", self.exact_hits)
            .set("port_hits", self.port_hits)
            .set("bucket_hits", self.bucket_hits)
            .set("misses", self.misses)
            .set("distinct_shapes", self.distinct_shapes)
            .set("distinct_buckets", self.distinct_buckets)
            .set("explore_jobs", self.explore_jobs)
            .set("port_jobs", self.port_jobs)
            .set("port_failures", self.port_failures)
            .set("bucket_retunes", self.bucket_retunes)
            .set("bucket_failures", self.bucket_failures)
            .set("fs_vetoes", self.fs_vetoes)
            .set("shard_jobs", self.shard_jobs)
            .set("reexplore_jobs", self.reexplore_jobs)
            .set("reexplore_improved", self.reexplore_improved)
            .set("reexplore_rejected", self.reexplore_rejected)
            .set("calibration_samples", self.calibration_samples)
            .set("drift_before", self.drift_before)
            .set("drift_after", self.drift_after)
            .set("compile_p50_ms", self.compile.p50)
            .set("compile_p99_ms", self.compile.p99)
            .set("compile_max_ms", self.compile.max)
            .set("regressions", self.regressions)
            .set("compile_owner_runs", self.compile_owner_runs)
            .set("compile_affinity_misses", self.compile_affinity_misses)
            .set("served_gpu_ms", self.served_gpu_ms)
            .set("fallback_gpu_ms", self.fallback_gpu_ms)
            .set("saved_gpu_ms", self.saved_gpu_ms())
            .set("saved_frac", self.saved_frac())
            .set("wait_p50_ms", self.wait.p50)
            .set("wait_p99_ms", self.wait.p99)
            .set("wait_max_ms", self.wait.max)
            .set("iter_p50_ms", self.iter_p50_ms)
            .set("iter_p99_ms", self.iter_p99_ms)
            .set("makespan_ms", self.makespan_ms)
            .set("wall_elapsed_ms", self.wall_elapsed_ms);
        if let Some(obs) = &self.observability {
            o.set("observability", obs.to_json());
        }
        let devices: Vec<JsonValue> = self
            .per_device
            .iter()
            .map(|d| {
                let mut dj = JsonValue::obj();
                dj.set("id", d.id)
                    .set("class", d.class)
                    .set("tasks", d.tasks)
                    .set("busy_ms", d.busy_ms)
                    .set("utilization", d.utilization);
                dj
            })
            .collect();
        o.set("devices", JsonValue::Arr(devices));
        o
    }

    /// Human-readable report (tables + headline numbers).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let mut t = Table::new(vec!["metric", "value"]);
        t.row(vec!["executor".to_string(), self.executor.to_string()]);
        t.row(vec!["tasks".to_string(), self.tasks.to_string()]);
        t.row(vec!["admitted".to_string(), self.admitted.to_string()]);
        t.row(vec![
            "admitted fallback-only (backpressure)".to_string(),
            self.fallback_only.to_string(),
        ]);
        t.row(vec!["rejected (admission)".to_string(), self.rejected.to_string()]);
        t.row(vec!["plan-store exact hits".to_string(), self.exact_hits.to_string()]);
        t.row(vec![
            "plan-store portability hits".to_string(),
            self.port_hits.to_string(),
        ]);
        t.row(vec![
            "plan-store shape-bucket hits".to_string(),
            self.bucket_hits.to_string(),
        ]);
        t.row(vec!["plan-store misses".to_string(), self.misses.to_string()]);
        if self.bucket_hits > 0 || self.distinct_shapes > self.misses {
            t.row(vec![
                "distinct shapes / buckets served".to_string(),
                format!("{} / {}", self.distinct_shapes, self.distinct_buckets),
            ]);
            t.row(vec![
                "shape retunes (failed)".to_string(),
                format!("{} ({})", self.bucket_retunes, self.bucket_failures),
            ]);
        }
        t.row(vec!["full explorations".to_string(), self.explore_jobs.to_string()]);
        t.row(vec![
            "region-shard compile sub-jobs".to_string(),
            self.shard_jobs.to_string(),
        ]);
        t.row(vec![
            "compile latency p50/p99".to_string(),
            format!("{} / {} ms", fmt_f(self.compile.p50, 3), fmt_f(self.compile.p99, 3)),
        ]);
        if self.calibration_samples > 0 {
            t.row(vec![
                "calibration samples (kernels)".to_string(),
                self.calibration_samples.to_string(),
            ]);
            t.row(vec![
                "cost-model drift before/after".to_string(),
                format!(
                    "{} / {}",
                    fmt_f(self.drift_before, 4),
                    fmt_f(self.drift_after, 4)
                ),
            ]);
            t.row(vec![
                "drift re-explorations (improved/rejected)".to_string(),
                format!(
                    "{} ({}/{})",
                    self.reexplore_jobs, self.reexplore_improved, self.reexplore_rejected
                ),
            ]);
        }
        t.row(vec!["cross-device ports".to_string(), self.port_jobs.to_string()]);
        t.row(vec!["port failures (re-explored)".to_string(), self.port_failures.to_string()]);
        t.row(vec!["never-negative vetoes".to_string(), self.fs_vetoes.to_string()]);
        t.row(vec!["FS regressions".to_string(), self.regressions.to_string()]);
        t.row(vec![
            "compile jobs owner-run/affinity-miss".to_string(),
            format!("{}/{}", self.compile_owner_runs, self.compile_affinity_misses),
        ]);
        t.row(vec![
            "queue wait p50/p99".to_string(),
            format!("{} / {} ms", fmt_f(self.wait.p50, 3), fmt_f(self.wait.p99, 3)),
        ]);
        t.row(vec![
            "iteration latency p50/p99".to_string(),
            format!("{} / {} ms", fmt_f(self.iter_p50_ms, 3), fmt_f(self.iter_p99_ms, 3)),
        ]);
        t.row(vec![
            "GPU ms served / fallback-only".to_string(),
            format!(
                "{} / {}",
                fmt_f(self.served_gpu_ms, 1),
                fmt_f(self.fallback_gpu_ms, 1)
            ),
        ]);
        t.row(vec![
            "GPU time saved".to_string(),
            format!(
                "{} ms ({}%)",
                fmt_f(self.saved_gpu_ms(), 1),
                fmt_f(self.saved_frac() * 100.0, 1)
            ),
        ]);
        t.row(vec!["makespan".to_string(), format!("{} ms", fmt_f(self.makespan_ms, 1))]);
        if self.wall_elapsed_ms > 0.0 {
            t.row(vec![
                "wall-clock elapsed".to_string(),
                format!("{} ms", fmt_f(self.wall_elapsed_ms, 1)),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');

        let mut d = Table::new(vec!["device", "class", "tasks", "busy ms", "util %"]);
        for dev in &self.per_device {
            d.row(vec![
                format!("dev{}", dev.id),
                dev.class.to_string(),
                dev.tasks.to_string(),
                fmt_f(dev.busy_ms, 1),
                fmt_f(dev.utilization * 100.0, 1),
            ]);
        }
        out.push_str(&d.render());
        if let Some(obs) = &self.observability {
            out.push('\n');
            out.push_str(&obs.render());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> FleetReport {
        FleetReport {
            executor: "virtual",
            tasks: 10,
            admitted: 7,
            fallback_only: 2,
            rejected: 1,
            exact_hits: 4,
            port_hits: 2,
            bucket_hits: 2,
            misses: 3,
            distinct_shapes: 5,
            distinct_buckets: 3,
            explore_jobs: 3,
            port_jobs: 2,
            port_failures: 0,
            bucket_retunes: 2,
            bucket_failures: 0,
            fs_vetoes: 1,
            shard_jobs: 4,
            reexplore_jobs: 2,
            reexplore_improved: 1,
            reexplore_rejected: 1,
            calibration_samples: 64,
            drift_before: 0.3,
            drift_after: 0.05,
            compile: crate::util::summarize(&[12.0, 20.0, 44.0, 16.0, 31.0]),
            regressions: 0,
            compile_owner_runs: 3,
            compile_affinity_misses: 2,
            served_gpu_ms: 60.0,
            fallback_gpu_ms: 100.0,
            wait: crate::util::summarize(&[0.0, 1.0, 2.0]),
            iter_p50_ms: 0.5,
            iter_p99_ms: 1.5,
            makespan_ms: 123.0,
            wall_elapsed_ms: 0.0,
            per_device: vec![DeviceUtilization {
                id: 0,
                class: "V100",
                tasks: 9,
                busy_ms: 61.0,
                utilization: 0.5,
            }],
            observability: None,
        }
    }

    #[test]
    fn savings_math() {
        let r = report();
        assert_eq!(r.saved_gpu_ms(), 40.0);
        assert!((r.saved_frac() - 0.4).abs() < 1e-12);
        assert_eq!(r.served_tasks(), 9);
        // 30k tasks × 2 h × 40% = 24,000 GPU hours.
        let h = r.projected_gpu_hours_saved(30_000.0, 2.0);
        assert!((h - 24_000.0).abs() < 1e-6);
    }

    #[test]
    fn json_has_headline_fields() {
        let j = report().to_json();
        for key in [
            "executor",
            "wall_elapsed_ms",
            "tasks",
            "port_hits",
            "bucket_hits",
            "distinct_shapes",
            "distinct_buckets",
            "bucket_retunes",
            "bucket_failures",
            "regressions",
            "wait_p50_ms",
            "wait_p99_ms",
            "shard_jobs",
            "reexplore_jobs",
            "calibration_samples",
            "drift_before",
            "drift_after",
            "compile_p50_ms",
            "compile_p99_ms",
            "compile_max_ms",
            "saved_frac",
            "devices",
        ] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        assert_eq!(j.get("regressions").and_then(|v| v.as_usize()), Some(0));
        assert_eq!(j.get("shard_jobs").and_then(|v| v.as_usize()), Some(4));
        assert_eq!(j.get("bucket_hits").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(j.get("distinct_shapes").and_then(|v| v.as_usize()), Some(5));
    }

    #[test]
    fn compile_latency_summary_orders() {
        let r = report();
        assert!(r.compile.p50 > 0.0);
        assert!(r.compile.p99 >= r.compile.p50);
        assert!(r.compile.max >= r.compile.p99);
        let text = r.render();
        assert!(text.contains("compile latency p50/p99"));
        assert!(text.contains("region-shard compile sub-jobs"));
    }

    #[test]
    fn observability_section_is_optional_and_ordered() {
        // None: no section in JSON or render.
        let plain = report();
        assert!(plain.to_json().get("observability").is_none());
        assert!(!plain.render().contains("stage attribution"));
        // Some: the section lands between the scalars and `devices`.
        let mut traced = report();
        let mut accum = crate::obs::StageAccum::new(1);
        accum.task(0, 1.0, 4.0, 9.0);
        traced.observability =
            Some(accum.report(vec![crate::obs::LockSnapshot::zero("plan_store")], 3, 0));
        let j = traced.to_json();
        let obs = j.get("observability").expect("observability section");
        assert!(obs.get("stages").is_some());
        assert!(obs.get("locks").is_some());
        let text = traced.render();
        assert!(text.contains("stage attribution"));
        assert!(text.contains("lock contention"));
    }

    #[test]
    fn render_mentions_portability_and_percentiles() {
        let text = report().render();
        assert!(text.contains("portability"));
        assert!(text.contains("shape-bucket hits"));
        assert!(text.contains("distinct shapes / buckets"));
        assert!(text.contains("p50/p99"));
        assert!(text.contains("V100"));
        assert!(text.contains("cost-model drift"));
        assert!(text.contains("drift re-explorations"));
        // Calibration rows disappear when the loop never ran.
        let mut off = report();
        off.calibration_samples = 0;
        assert!(!off.render().contains("cost-model drift"));
    }
}
