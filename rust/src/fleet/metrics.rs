//! Fleet-wide report: the §7.2 production numbers, but measured through
//! the coordinator path instead of asserted.

use crate::obs::{LockSnapshot, ObsReport};
use crate::util::{fmt_f, JsonValue, Summary, Table};

/// Per-device utilization line.
#[derive(Debug, Clone)]
pub struct DeviceUtilization {
    pub id: usize,
    pub class: &'static str,
    pub tasks: usize,
    pub busy_ms: f64,
    /// busy / (makespan × capacity).
    pub utilization: f64,
}

/// Per-tenant QoS line: admission outcomes, SLA verdicts and
/// end-to-end latency for one tenant's slice of the trace. All virtual
/// bookkeeping — identical across executors and replays.
#[derive(Debug, Clone)]
pub struct TenantQos {
    pub tenant: u32,
    /// Priority tier name: "premium", "standard" or "best_effort".
    pub tier: &'static str,
    /// The tier's queue-wait SLA bound in virtual ms.
    pub sla_ms: f64,
    pub tasks: usize,
    pub served: usize,
    /// Tasks shed by QoS load-shedding (lower tiers under pressure).
    pub shed: usize,
    /// Tasks rejected by the tier-blind backlog bound.
    pub rejected: usize,
    /// Served tasks whose queue wait blew the tier's SLA (admission
    /// sheds these pre-serve, so nonzero means a policy bug).
    pub sla_violations: usize,
    /// End-to-end latency (arrival → virtual completion) percentiles.
    pub e2e: Summary,
}

/// Everything one trace replay produces. Under the virtual-time
/// executor all quantities are deterministic: two replays of the same
/// (seed, config) are byte-identical, which the production bench
/// asserts. Under the wall-clock executor the decision fields still
/// match the virtual replay's (the equivalence test asserts it), while
/// the measured fields (`served_gpu_ms`, iteration percentiles,
/// `wall_elapsed_ms`, queue accounting) reflect the real thread race.
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Which executor produced this report: "virtual" or "wallclock".
    pub executor: &'static str,
    pub tasks: usize,
    pub admitted: usize,
    pub fallback_only: usize,
    pub rejected: usize,
    pub exact_hits: usize,
    pub port_hits: usize,
    /// Store lookups resolved through the shape-bucket tier: a sibling
    /// shape's plan re-served after a launch-dim-only retune (0 unless
    /// traffic is shape-varying).
    pub bucket_hits: usize,
    pub misses: usize,
    /// Distinct exact graphs the trace touched (template × shape
    /// instances). The amortization headline: full explorations should
    /// be sublinear in this under shape-varying traffic.
    pub distinct_shapes: usize,
    /// Distinct (structure, power-of-two bucket) classes the trace
    /// touched — the reuse granularity of the bucket tier.
    pub distinct_buckets: usize,
    pub explore_jobs: usize,
    pub port_jobs: usize,
    pub port_failures: usize,
    /// Same-class shape-retune compile jobs (one per acted-on bucket
    /// hit).
    pub bucket_retunes: usize,
    /// Shape retunes whose sibling plan could not schedule at the new
    /// shape (fell back to a full exploration).
    pub bucket_failures: usize,
    pub fs_vetoes: usize,
    /// Region-shard compile sub-jobs fanned out by sharded explorations
    /// (0 with `compile_shards == 1` or when no explored graph had more
    /// than one fusible region).
    pub shard_jobs: usize,
    /// Drift-triggered re-exploration compile jobs (0 unless the
    /// calibration loop is on).
    pub reexplore_jobs: usize,
    /// Re-explorations whose plan beat the incumbent (hot-swapped in).
    pub reexplore_improved: usize,
    /// Re-explorations the plan-quality no-worse gate rejected.
    pub reexplore_rejected: usize,
    /// GEMM boundaries absorbed across every published plan (cross-GEMM
    /// stitching): epilogue/prologue patterns folded into their anchor's
    /// library kernel instead of launching separately.
    pub gemm_absorbed: usize,
    /// Candidate patterns the footprint bound discarded before the beam
    /// across every published plan's exploration (footprint-first
    /// pruning; 0 with `footprint_prune` off or when every candidate
    /// fits the per-block shared-memory cap).
    pub footprint_pruned: usize,
    /// Per-kernel (modeled, measured) pairs the calibrator recorded.
    pub calibration_samples: usize,
    /// Median |predicted − measured| relative kernel-time error under
    /// the default cost constants / under the fitted per-class params
    /// (sample-weighted across classes; `drift_after <= drift_before`
    /// by construction — the fit falls back to the defaults whenever it
    /// would not help).
    pub drift_before: f64,
    pub drift_after: f64,
    /// Per-job compile latency (enqueue → virtual ready; a sharded
    /// exploration counts once, at its join barrier) over every explore
    /// and port job. Derived from the virtual clocks in both executors,
    /// so the percentiles are executor-invariant and deterministic.
    pub compile: Summary,
    /// Tasks whose served GPU time exceeded their fallback GPU time.
    /// The never-negative guard must keep this at zero (§7.2).
    pub regressions: usize,
    /// Compile jobs run by their hash-affinity owner worker vs. taken
    /// by a different (earliest-free) worker. In the virtual-time
    /// replay assignment is immediate, so this measures owner-affinity
    /// misses — not deque backlog relief (see `fleet::queue` docs).
    pub compile_owner_runs: usize,
    pub compile_affinity_misses: usize,
    /// Total GPU time actually spent serving (FS where available).
    pub served_gpu_ms: f64,
    /// GPU time the same trace would have cost on the fallback alone.
    pub fallback_gpu_ms: f64,
    /// Queue-wait distribution (arrival → slot start) over served tasks.
    pub wait: Summary,
    /// Per-iteration device latency percentiles, fleet-wide (aggregated
    /// per-device `ServiceMetrics`).
    pub iter_p50_ms: f64,
    pub iter_p99_ms: f64,
    /// Virtual time at which the last task finished.
    pub makespan_ms: f64,
    /// Real elapsed time of the wall-clock run (0 under virtual time).
    pub wall_elapsed_ms: f64,
    /// Tasks shed by QoS load-shedding (fleet-wide; per-tenant splits
    /// are in `tenants`).
    pub sheds: usize,
    /// Served tasks whose queue wait blew their tier's SLA — the CI
    /// rail holds the top tier at zero.
    pub sla_violations: usize,
    /// In-flight session migrations forced by churn/faults.
    pub migrations: usize,
    /// Migrations whose plan could not follow the session and degraded
    /// to the destination fallback.
    pub migrations_degraded: usize,
    /// Departure/rejoin events in the run's churn schedule.
    pub churn_events: usize,
    /// Injected device kills in the run's churn schedule.
    pub faults: usize,
    /// Per-tenant QoS lines, in tenant id order.
    pub tenants: Vec<TenantQos>,
    pub per_device: Vec<DeviceUtilization>,
    /// Flight-recorder report (stage-attributed latency + lock
    /// contention); `None` unless `FleetOptions::observe` was on and
    /// the crate was built with the `obs` feature.
    pub observability: Option<ObsReport>,
}

impl FleetReport {
    /// GPU time the fleet saved versus fallback-only serving.
    pub fn saved_gpu_ms(&self) -> f64 {
        self.fallback_gpu_ms - self.served_gpu_ms
    }

    /// Fraction of fallback GPU time saved.
    pub fn saved_frac(&self) -> f64 {
        if self.fallback_gpu_ms <= 0.0 {
            0.0
        } else {
            self.saved_gpu_ms() / self.fallback_gpu_ms
        }
    }

    /// Tasks that were actually served (admitted either way).
    pub fn served_tasks(&self) -> usize {
        self.admitted + self.fallback_only
    }

    /// Project the per-task saving to a monthly task volume, in GPU
    /// hours — the paper's "~7,000 GPU hours for ~30,000 tasks" frame.
    /// The trace's tasks are minutes-scale; the projection scales each
    /// task's saving by `hours_per_task` over its simulated GPU time.
    pub fn projected_gpu_hours_saved(&self, tasks_per_month: f64, hours_per_task: f64) -> f64 {
        if self.served_tasks() == 0 {
            return 0.0;
        }
        tasks_per_month * hours_per_task * self.saved_frac()
    }

    /// JSON snapshot (deterministic field order and values).
    pub fn to_json(&self) -> JsonValue {
        let mut o = JsonValue::obj();
        o.set("executor", self.executor)
            .set("tasks", self.tasks)
            .set("admitted", self.admitted)
            .set("fallback_only", self.fallback_only)
            .set("rejected", self.rejected)
            .set("exact_hits", self.exact_hits)
            .set("port_hits", self.port_hits)
            .set("bucket_hits", self.bucket_hits)
            .set("misses", self.misses)
            .set("distinct_shapes", self.distinct_shapes)
            .set("distinct_buckets", self.distinct_buckets)
            .set("explore_jobs", self.explore_jobs)
            .set("port_jobs", self.port_jobs)
            .set("port_failures", self.port_failures)
            .set("bucket_retunes", self.bucket_retunes)
            .set("bucket_failures", self.bucket_failures)
            .set("fs_vetoes", self.fs_vetoes)
            .set("shard_jobs", self.shard_jobs)
            .set("reexplore_jobs", self.reexplore_jobs)
            .set("reexplore_improved", self.reexplore_improved)
            .set("reexplore_rejected", self.reexplore_rejected)
            .set("gemm_absorbed", self.gemm_absorbed)
            .set("footprint_pruned", self.footprint_pruned)
            .set("calibration_samples", self.calibration_samples)
            .set("drift_before", self.drift_before)
            .set("drift_after", self.drift_after)
            .set("compile_p50_ms", self.compile.p50)
            .set("compile_p99_ms", self.compile.p99)
            .set("compile_max_ms", self.compile.max)
            .set("regressions", self.regressions)
            .set("compile_owner_runs", self.compile_owner_runs)
            .set("compile_affinity_misses", self.compile_affinity_misses)
            .set("served_gpu_ms", self.served_gpu_ms)
            .set("fallback_gpu_ms", self.fallback_gpu_ms)
            .set("saved_gpu_ms", self.saved_gpu_ms())
            .set("saved_frac", self.saved_frac())
            .set("wait_p50_ms", self.wait.p50)
            .set("wait_p99_ms", self.wait.p99)
            .set("wait_max_ms", self.wait.max)
            .set("iter_p50_ms", self.iter_p50_ms)
            .set("iter_p99_ms", self.iter_p99_ms)
            .set("makespan_ms", self.makespan_ms)
            .set("wall_elapsed_ms", self.wall_elapsed_ms);
        let mut qos = JsonValue::obj();
        qos.set("sheds", self.sheds)
            .set("sla_violations", self.sla_violations)
            .set("migrations", self.migrations)
            .set("migrations_degraded", self.migrations_degraded)
            .set("churn_events", self.churn_events)
            .set("faults", self.faults);
        let tenants: Vec<JsonValue> = self
            .tenants
            .iter()
            .map(|t| {
                let mut tj = JsonValue::obj();
                tj.set("tenant", t.tenant as u64)
                    .set("tier", t.tier)
                    .set("sla_ms", t.sla_ms)
                    .set("tasks", t.tasks)
                    .set("served", t.served)
                    .set("shed", t.shed)
                    .set("rejected", t.rejected)
                    .set("sla_violations", t.sla_violations)
                    .set("e2e_p50_ms", t.e2e.p50)
                    .set("e2e_p99_ms", t.e2e.p99);
                tj
            })
            .collect();
        qos.set("tenants", JsonValue::Arr(tenants));
        o.set("qos", qos);
        if let Some(obs) = &self.observability {
            o.set("observability", obs.to_json());
        }
        let devices: Vec<JsonValue> = self
            .per_device
            .iter()
            .map(|d| {
                let mut dj = JsonValue::obj();
                dj.set("id", d.id)
                    .set("class", d.class)
                    .set("tasks", d.tasks)
                    .set("busy_ms", d.busy_ms)
                    .set("utilization", d.utilization);
                dj
            })
            .collect();
        o.set("devices", JsonValue::Arr(devices));
        o
    }

    /// Human-readable report (tables + headline numbers).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let mut t = Table::new(vec!["metric", "value"]);
        t.row(vec!["executor".to_string(), self.executor.to_string()]);
        t.row(vec!["tasks".to_string(), self.tasks.to_string()]);
        t.row(vec!["admitted".to_string(), self.admitted.to_string()]);
        t.row(vec![
            "admitted fallback-only (backpressure)".to_string(),
            self.fallback_only.to_string(),
        ]);
        t.row(vec!["rejected (admission)".to_string(), self.rejected.to_string()]);
        t.row(vec!["plan-store exact hits".to_string(), self.exact_hits.to_string()]);
        t.row(vec![
            "plan-store portability hits".to_string(),
            self.port_hits.to_string(),
        ]);
        t.row(vec![
            "plan-store shape-bucket hits".to_string(),
            self.bucket_hits.to_string(),
        ]);
        t.row(vec!["plan-store misses".to_string(), self.misses.to_string()]);
        if self.bucket_hits > 0 || self.distinct_shapes > self.misses {
            t.row(vec![
                "distinct shapes / buckets served".to_string(),
                format!("{} / {}", self.distinct_shapes, self.distinct_buckets),
            ]);
            t.row(vec![
                "shape retunes (failed)".to_string(),
                format!("{} ({})", self.bucket_retunes, self.bucket_failures),
            ]);
        }
        t.row(vec!["full explorations".to_string(), self.explore_jobs.to_string()]);
        t.row(vec![
            "GEMM boundaries absorbed".to_string(),
            self.gemm_absorbed.to_string(),
        ]);
        t.row(vec![
            "footprint-pruned candidates".to_string(),
            self.footprint_pruned.to_string(),
        ]);
        t.row(vec![
            "region-shard compile sub-jobs".to_string(),
            self.shard_jobs.to_string(),
        ]);
        t.row(vec![
            "compile latency p50/p99".to_string(),
            format!("{} / {} ms", fmt_f(self.compile.p50, 3), fmt_f(self.compile.p99, 3)),
        ]);
        if self.calibration_samples > 0 {
            t.row(vec![
                "calibration samples (kernels)".to_string(),
                self.calibration_samples.to_string(),
            ]);
            t.row(vec![
                "cost-model drift before/after".to_string(),
                format!(
                    "{} / {}",
                    fmt_f(self.drift_before, 4),
                    fmt_f(self.drift_after, 4)
                ),
            ]);
            t.row(vec![
                "drift re-explorations (improved/rejected)".to_string(),
                format!(
                    "{} ({}/{})",
                    self.reexplore_jobs, self.reexplore_improved, self.reexplore_rejected
                ),
            ]);
        }
        t.row(vec!["cross-device ports".to_string(), self.port_jobs.to_string()]);
        t.row(vec!["port failures (re-explored)".to_string(), self.port_failures.to_string()]);
        t.row(vec!["never-negative vetoes".to_string(), self.fs_vetoes.to_string()]);
        t.row(vec!["FS regressions".to_string(), self.regressions.to_string()]);
        t.row(vec![
            "compile jobs owner-run/affinity-miss".to_string(),
            format!("{}/{}", self.compile_owner_runs, self.compile_affinity_misses),
        ]);
        t.row(vec![
            "queue wait p50/p99".to_string(),
            format!("{} / {} ms", fmt_f(self.wait.p50, 3), fmt_f(self.wait.p99, 3)),
        ]);
        t.row(vec![
            "iteration latency p50/p99".to_string(),
            format!("{} / {} ms", fmt_f(self.iter_p50_ms, 3), fmt_f(self.iter_p99_ms, 3)),
        ]);
        t.row(vec![
            "GPU ms served / fallback-only".to_string(),
            format!(
                "{} / {}",
                fmt_f(self.served_gpu_ms, 1),
                fmt_f(self.fallback_gpu_ms, 1)
            ),
        ]);
        t.row(vec![
            "GPU time saved".to_string(),
            format!(
                "{} ms ({}%)",
                fmt_f(self.saved_gpu_ms(), 1),
                fmt_f(self.saved_frac() * 100.0, 1)
            ),
        ]);
        if self.sheds > 0 || self.sla_violations > 0 {
            t.row(vec!["QoS sheds".to_string(), self.sheds.to_string()]);
            t.row(vec!["SLA violations".to_string(), self.sla_violations.to_string()]);
        }
        if self.churn_events > 0 || self.faults > 0 {
            t.row(vec![
                "churn events / injected faults".to_string(),
                format!("{} / {}", self.churn_events, self.faults),
            ]);
            t.row(vec![
                "session migrations (degraded)".to_string(),
                format!("{} ({})", self.migrations, self.migrations_degraded),
            ]);
        }
        t.row(vec!["makespan".to_string(), format!("{} ms", fmt_f(self.makespan_ms, 1))]);
        if self.wall_elapsed_ms > 0.0 {
            t.row(vec![
                "wall-clock elapsed".to_string(),
                format!("{} ms", fmt_f(self.wall_elapsed_ms, 1)),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');

        if self.tenants.len() > 1 {
            let mut q = Table::new(vec![
                "tenant", "tier", "sla ms", "tasks", "served", "shed", "rejected", "sla viol",
                "e2e p99",
            ]);
            for t in &self.tenants {
                q.row(vec![
                    t.tenant.to_string(),
                    t.tier.to_string(),
                    fmt_f(t.sla_ms, 0),
                    t.tasks.to_string(),
                    t.served.to_string(),
                    t.shed.to_string(),
                    t.rejected.to_string(),
                    t.sla_violations.to_string(),
                    fmt_f(t.e2e.p99, 2),
                ]);
            }
            out.push_str(&q.render());
            out.push('\n');
        }

        let mut d = Table::new(vec!["device", "class", "tasks", "busy ms", "util %"]);
        for dev in &self.per_device {
            d.row(vec![
                format!("dev{}", dev.id),
                dev.class.to_string(),
                dev.tasks.to_string(),
                fmt_f(dev.busy_ms, 1),
                fmt_f(dev.utilization * 100.0, 1),
            ]);
        }
        out.push_str(&d.render());
        if let Some(obs) = &self.observability {
            out.push('\n');
            out.push_str(&obs.render());
        }
        out
    }
}

/// One shard dispatcher's contribution to a cluster run: its full
/// [`FleetReport`] plus the cluster-level evidence the rollup compares
/// across executors — the arrival-ordered decision digest and the
/// shard's lock-contention rows.
#[derive(Debug, Clone)]
pub struct ShardRollup {
    pub shard: usize,
    pub report: FleetReport,
    /// FNV-1a fold of this shard's decision stream (see
    /// [`super::service::FleetService::decision_digest`]).
    pub decision_digest: u64,
    /// This shard's lock rows (plan store dispatcher/read, compile
    /// queue, publication barrier, service metrics).
    pub locks: Vec<LockSnapshot>,
}

/// What a [`super::cluster::ShardedFleetService`] run produces: one
/// rollup per shard plus the cluster-level throughput measurement.
/// Decision fields aggregate exactly (shards are disjoint); latency
/// percentiles do not and deliberately stay per-shard.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    /// Which executor produced the run: "virtual" or "wallclock".
    pub executor: &'static str,
    pub shards: Vec<ShardRollup>,
    /// Real elapsed time of the whole cluster run (all shards,
    /// including their pool spin-up/teardown under wall clock).
    pub elapsed_ms: f64,
}

impl ClusterReport {
    /// Total tasks routed across every shard.
    pub fn tasks(&self) -> usize {
        self.shards.iter().map(|s| s.report.tasks).sum()
    }

    /// The headline throughput: routed tasks over real elapsed time.
    pub fn tasks_per_sec(&self) -> f64 {
        if self.elapsed_ms <= 0.0 {
            0.0
        } else {
            self.tasks() as f64 / (self.elapsed_ms / 1e3)
        }
    }

    /// Cluster makespan: the slowest shard's virtual makespan (shards
    /// run concurrently).
    pub fn makespan_ms(&self) -> f64 {
        self.shards.iter().fold(0.0, |m, s| m.max(s.report.makespan_ms))
    }

    /// Never-negative regressions across every shard.
    pub fn regressions(&self) -> usize {
        self.shards.iter().map(|s| s.report.regressions).sum()
    }

    /// One lock row per name, merged across shards (e.g. the cluster's
    /// total `plan_store_read` traffic). Row order follows the first
    /// shard's rows.
    pub fn merged_locks(&self) -> Vec<LockSnapshot> {
        let mut out: Vec<LockSnapshot> = Vec::new();
        for shard in &self.shards {
            for row in &shard.locks {
                match out.iter_mut().find(|r| r.name == row.name) {
                    Some(r) => r.merge(row),
                    None => out.push(*row),
                }
            }
        }
        out
    }

    /// Fetch one merged lock row by name.
    pub fn lock(&self, name: &str) -> Option<LockSnapshot> {
        self.merged_locks().into_iter().find(|r| r.name == name)
    }

    /// The per-shard decision digests in shard order — the equivalence
    /// evidence two executors' runs are compared on.
    pub fn decision_digests(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.decision_digest).collect()
    }

    /// JSON snapshot: cluster totals, throughput, merged lock rows and
    /// a compact per-shard table (digests as hex strings — JSON numbers
    /// lose u64 precision past 2^53).
    pub fn to_json(&self) -> JsonValue {
        let mut o = JsonValue::obj();
        let admitted: usize = self.shards.iter().map(|s| s.report.admitted).sum();
        let fallback_only: usize = self.shards.iter().map(|s| s.report.fallback_only).sum();
        let rejected: usize = self.shards.iter().map(|s| s.report.rejected).sum();
        let explore_jobs: usize = self.shards.iter().map(|s| s.report.explore_jobs).sum();
        o.set("executor", self.executor)
            .set("shards", self.shards.len())
            .set("tasks", self.tasks())
            .set("admitted", admitted)
            .set("fallback_only", fallback_only)
            .set("rejected", rejected)
            .set("explore_jobs", explore_jobs)
            .set("regressions", self.regressions())
            .set("makespan_ms", self.makespan_ms())
            .set("elapsed_ms", self.elapsed_ms)
            .set("tasks_per_sec", self.tasks_per_sec());
        let mut locks = JsonValue::obj();
        for row in self.merged_locks() {
            locks.set(row.name, row.to_json());
        }
        o.set("locks", locks);
        let per_shard: Vec<JsonValue> = self
            .shards
            .iter()
            .map(|s| {
                let mut sj = JsonValue::obj();
                sj.set("shard", s.shard)
                    .set("devices", s.report.per_device.len())
                    .set("tasks", s.report.tasks)
                    .set("admitted", s.report.admitted)
                    .set("fallback_only", s.report.fallback_only)
                    .set("rejected", s.report.rejected)
                    .set("exact_hits", s.report.exact_hits)
                    .set("port_hits", s.report.port_hits)
                    .set("bucket_hits", s.report.bucket_hits)
                    .set("misses", s.report.misses)
                    .set("explore_jobs", s.report.explore_jobs)
                    .set("regressions", s.report.regressions)
                    .set("sheds", s.report.sheds)
                    .set("migrations", s.report.migrations)
                    .set("makespan_ms", s.report.makespan_ms)
                    .set("decision_digest", format!("{:#018x}", s.decision_digest));
                let mut lj = JsonValue::obj();
                for row in &s.locks {
                    lj.set(row.name, row.to_json());
                }
                sj.set("locks", lj);
                sj
            })
            .collect();
        o.set("per_shard", JsonValue::Arr(per_shard));
        o
    }

    /// Human-readable cluster summary (one row per shard).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let mut t = Table::new(vec!["metric", "value"]);
        t.row(vec!["executor".to_string(), self.executor.to_string()]);
        t.row(vec!["shards".to_string(), self.shards.len().to_string()]);
        t.row(vec!["tasks".to_string(), self.tasks().to_string()]);
        t.row(vec!["makespan".to_string(), format!("{} ms", fmt_f(self.makespan_ms(), 1))]);
        t.row(vec!["elapsed".to_string(), format!("{} ms", fmt_f(self.elapsed_ms, 1))]);
        t.row(vec![
            "throughput".to_string(),
            format!("{} tasks/s", fmt_f(self.tasks_per_sec(), 1)),
        ]);
        t.row(vec!["regressions".to_string(), self.regressions().to_string()]);
        out.push_str(&t.render());
        out.push('\n');
        let mut s = Table::new(vec!["shard", "devices", "tasks", "admitted", "digest"]);
        for shard in &self.shards {
            s.row(vec![
                shard.shard.to_string(),
                shard.report.per_device.len().to_string(),
                shard.report.tasks.to_string(),
                shard.report.admitted.to_string(),
                format!("{:#018x}", shard.decision_digest),
            ]);
        }
        out.push_str(&s.render());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> FleetReport {
        FleetReport {
            executor: "virtual",
            tasks: 10,
            admitted: 7,
            fallback_only: 2,
            rejected: 1,
            exact_hits: 4,
            port_hits: 2,
            bucket_hits: 2,
            misses: 3,
            distinct_shapes: 5,
            distinct_buckets: 3,
            explore_jobs: 3,
            port_jobs: 2,
            port_failures: 0,
            bucket_retunes: 2,
            bucket_failures: 0,
            fs_vetoes: 1,
            shard_jobs: 4,
            reexplore_jobs: 2,
            reexplore_improved: 1,
            reexplore_rejected: 1,
            gemm_absorbed: 6,
            footprint_pruned: 9,
            calibration_samples: 64,
            drift_before: 0.3,
            drift_after: 0.05,
            compile: crate::util::summarize(&[12.0, 20.0, 44.0, 16.0, 31.0]),
            regressions: 0,
            compile_owner_runs: 3,
            compile_affinity_misses: 2,
            served_gpu_ms: 60.0,
            fallback_gpu_ms: 100.0,
            wait: crate::util::summarize(&[0.0, 1.0, 2.0]),
            iter_p50_ms: 0.5,
            iter_p99_ms: 1.5,
            makespan_ms: 123.0,
            wall_elapsed_ms: 0.0,
            sheds: 1,
            sla_violations: 0,
            migrations: 2,
            migrations_degraded: 1,
            churn_events: 3,
            faults: 1,
            tenants: vec![
                TenantQos {
                    tenant: 0,
                    tier: "premium",
                    sla_ms: 250.0,
                    tasks: 6,
                    served: 6,
                    shed: 0,
                    rejected: 0,
                    sla_violations: 0,
                    e2e: crate::util::summarize(&[1.0, 2.0, 3.0]),
                },
                TenantQos {
                    tenant: 2,
                    tier: "best_effort",
                    sla_ms: 25.0,
                    tasks: 4,
                    served: 3,
                    shed: 1,
                    rejected: 0,
                    sla_violations: 0,
                    e2e: crate::util::summarize(&[1.5, 2.5]),
                },
            ],
            per_device: vec![DeviceUtilization {
                id: 0,
                class: "V100",
                tasks: 9,
                busy_ms: 61.0,
                utilization: 0.5,
            }],
            observability: None,
        }
    }

    #[test]
    fn savings_math() {
        let r = report();
        assert_eq!(r.saved_gpu_ms(), 40.0);
        assert!((r.saved_frac() - 0.4).abs() < 1e-12);
        assert_eq!(r.served_tasks(), 9);
        // 30k tasks × 2 h × 40% = 24,000 GPU hours.
        let h = r.projected_gpu_hours_saved(30_000.0, 2.0);
        assert!((h - 24_000.0).abs() < 1e-6);
    }

    #[test]
    fn json_has_headline_fields() {
        let j = report().to_json();
        for key in [
            "executor",
            "wall_elapsed_ms",
            "tasks",
            "port_hits",
            "bucket_hits",
            "distinct_shapes",
            "distinct_buckets",
            "bucket_retunes",
            "bucket_failures",
            "regressions",
            "wait_p50_ms",
            "wait_p99_ms",
            "shard_jobs",
            "reexplore_jobs",
            "gemm_absorbed",
            "footprint_pruned",
            "calibration_samples",
            "drift_before",
            "drift_after",
            "compile_p50_ms",
            "compile_p99_ms",
            "compile_max_ms",
            "saved_frac",
            "qos",
            "devices",
        ] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        assert_eq!(j.get("regressions").and_then(|v| v.as_usize()), Some(0));
        assert_eq!(j.get("shard_jobs").and_then(|v| v.as_usize()), Some(4));
        assert_eq!(j.get("bucket_hits").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(j.get("distinct_shapes").and_then(|v| v.as_usize()), Some(5));
        assert_eq!(j.get("gemm_absorbed").and_then(|v| v.as_usize()), Some(6));
        assert_eq!(j.get("footprint_pruned").and_then(|v| v.as_usize()), Some(9));
    }

    #[test]
    fn qos_section_carries_tenant_rows_and_counters() {
        let j = report().to_json();
        let qos = j.get("qos").expect("qos section");
        assert_eq!(qos.get("sheds").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(qos.get("sla_violations").and_then(|v| v.as_usize()), Some(0));
        assert_eq!(qos.get("migrations").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(qos.get("churn_events").and_then(|v| v.as_usize()), Some(3));
        assert_eq!(qos.get("faults").and_then(|v| v.as_usize()), Some(1));
        let tenants = match qos.get("tenants") {
            Some(JsonValue::Arr(v)) => v,
            other => panic!("qos.tenants must be an array: {other:?}"),
        };
        assert_eq!(tenants.len(), 2);
        assert_eq!(tenants[0].get("tier").and_then(|v| v.as_str()), Some("premium"));
        assert_eq!(tenants[1].get("shed").and_then(|v| v.as_usize()), Some(1));
        assert!(tenants[0].get("e2e_p99_ms").and_then(|v| v.as_f64()).unwrap() > 0.0);
        // Render shows the tenant table and the churn/QoS rows.
        let text = report().render();
        assert!(text.contains("QoS sheds"));
        assert!(text.contains("churn events / injected faults"));
        assert!(text.contains("best_effort"));
    }

    #[test]
    fn compile_latency_summary_orders() {
        let r = report();
        assert!(r.compile.p50 > 0.0);
        assert!(r.compile.p99 >= r.compile.p50);
        assert!(r.compile.max >= r.compile.p99);
        let text = r.render();
        assert!(text.contains("compile latency p50/p99"));
        assert!(text.contains("region-shard compile sub-jobs"));
    }

    #[test]
    fn observability_section_is_optional_and_ordered() {
        // None: no section in JSON or render.
        let plain = report();
        assert!(plain.to_json().get("observability").is_none());
        assert!(!plain.render().contains("stage attribution"));
        // Some: the section lands between the scalars and `devices`.
        let mut traced = report();
        let mut accum = crate::obs::StageAccum::new(1);
        accum.task(0, 1.0, 4.0, 9.0);
        traced.observability =
            Some(accum.report(vec![crate::obs::LockSnapshot::zero("plan_store")], 3, 0));
        let j = traced.to_json();
        let obs = j.get("observability").expect("observability section");
        assert!(obs.get("stages").is_some());
        assert!(obs.get("locks").is_some());
        let text = traced.render();
        assert!(text.contains("stage attribution"));
        assert!(text.contains("lock contention"));
    }

    #[test]
    fn cluster_rollup_aggregates_shards_and_merges_locks() {
        let shard = |i: usize, digest: u64| ShardRollup {
            shard: i,
            report: report(),
            decision_digest: digest,
            locks: vec![
                LockSnapshot { name: "plan_store", acquisitions: 5, contended: 0, blocked_ms: 0.0 },
                LockSnapshot {
                    name: "plan_store_read",
                    acquisitions: 40,
                    contended: 0,
                    blocked_ms: 0.0,
                },
            ],
        };
        let cluster = ClusterReport {
            executor: "wallclock",
            shards: vec![shard(0, 0x1111), shard(1, 0x2222)],
            elapsed_ms: 500.0,
        };
        assert_eq!(cluster.tasks(), 20);
        assert_eq!(cluster.regressions(), 0);
        assert!((cluster.makespan_ms() - 123.0).abs() < 1e-12);
        assert!((cluster.tasks_per_sec() - 40.0).abs() < 1e-9, "20 tasks / 0.5 s");
        assert_eq!(cluster.decision_digests(), vec![0x1111, 0x2222]);
        let read = cluster.lock("plan_store_read").expect("merged read row");
        assert_eq!(read.acquisitions, 80);
        assert_eq!(read.contended, 0);
        let j = cluster.to_json();
        assert_eq!(j.get("shards").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(j.get("tasks").and_then(|v| v.as_usize()), Some(20));
        assert!(j.get("tasks_per_sec").and_then(|v| v.as_f64()).unwrap() > 0.0);
        let locks = j.get("locks").expect("merged locks object");
        let row = locks.get("plan_store_read").expect("read row");
        assert_eq!(row.get("acquisitions").and_then(|v| v.as_usize()), Some(80));
        let per_shard = match j.get("per_shard") {
            Some(JsonValue::Arr(v)) => v,
            other => panic!("per_shard must be an array: {other:?}"),
        };
        assert_eq!(per_shard.len(), 2);
        let digest = per_shard[0].get("decision_digest").and_then(|v| v.as_str());
        assert_eq!(digest, Some("0x0000000000001111"));
        let text = cluster.render();
        assert!(text.contains("throughput"));
        assert!(text.contains("0x0000000000002222"));
    }

    #[test]
    fn render_mentions_portability_and_percentiles() {
        let text = report().render();
        assert!(text.contains("portability"));
        assert!(text.contains("shape-bucket hits"));
        assert!(text.contains("distinct shapes / buckets"));
        assert!(text.contains("p50/p99"));
        assert!(text.contains("V100"));
        assert!(text.contains("cost-model drift"));
        assert!(text.contains("drift re-explorations"));
        // Calibration rows disappear when the loop never ran.
        let mut off = report();
        off.calibration_samples = 0;
        assert!(!off.render().contains("cost-model drift"));
    }
}
