//! Work-stealing queue for the bounded compile-worker pool.
//!
//! FusionStitching exploration is orders of magnitude more expensive
//! than serving an iteration, so a fleet throttles it through a small
//! worker pool while the XLA fallback serves immediately (§6's
//! async-compilation, fleet-wide). Each worker owns a deque: it pushes
//! and pops its own work LIFO (locality — a template's port jobs tend
//! to land on the owner that explored it), and when idle steals FIFO
//! from the most-backlogged victim, which keeps a hot owner from
//! starving the rest of the fleet's compilations.
//!
//! The implementation is deterministic and single-threaded — the fleet
//! simulator advances virtual time, so lock-free deques would add
//! nondeterminism for nothing. Fairness is what matters and is tested.
//!
//! Integration note: in the virtual-time [`super::service`], a compile
//! job's assignment is a timestamp computation, so jobs route through
//! push/pop immediately and *backlog lives in virtual time* (worker
//! `free_ms` beyond now), not in the deques; the steal counter there
//! measures owner-affinity misses (the earliest-free worker taking
//! another owner's job). The multi-item LIFO/FIFO/longest-victim
//! semantics below are what a wall-clock executor (ROADMAP open item)
//! will drain, and are exercised directly by the unit tests.

use std::collections::VecDeque;

/// Push/pop/steal accounting.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueueStats {
    pub pushes: usize,
    pub local_pops: usize,
    pub steals: usize,
}

/// Per-worker deques with LIFO local pop and FIFO stealing.
#[derive(Debug, Clone)]
pub struct WorkStealingQueue<T> {
    deques: Vec<VecDeque<T>>,
    stats: QueueStats,
}

impl<T> WorkStealingQueue<T> {
    /// Create a queue set for `workers` workers (at least one).
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0, "work-stealing queue needs at least one worker");
        WorkStealingQueue {
            deques: (0..workers).map(|_| VecDeque::new()).collect(),
            stats: QueueStats::default(),
        }
    }

    /// Number of workers.
    pub fn workers(&self) -> usize {
        self.deques.len()
    }

    /// Enqueue an item on `worker`'s deque (index wraps).
    pub fn push(&mut self, worker: usize, item: T) {
        let w = worker % self.deques.len();
        self.deques[w].push_back(item);
        self.stats.pushes += 1;
    }

    /// Dequeue for `worker`: LIFO from its own deque; when empty, steal
    /// FIFO from the victim with the longest backlog (lowest index on
    /// ties, so replay is deterministic). `None` when all deques are
    /// empty.
    pub fn pop(&mut self, worker: usize) -> Option<T> {
        let w = worker % self.deques.len();
        if let Some(item) = self.deques[w].pop_back() {
            self.stats.local_pops += 1;
            return Some(item);
        }
        let mut victim: Option<usize> = None;
        for (i, dq) in self.deques.iter().enumerate() {
            if dq.is_empty() {
                continue;
            }
            match victim {
                Some(v) if self.deques[v].len() >= dq.len() => {}
                _ => victim = Some(i),
            }
        }
        let v = victim?;
        let item = self.deques[v].pop_front();
        if item.is_some() {
            self.stats.steals += 1;
        }
        item
    }

    /// Total queued items across all deques.
    pub fn len(&self) -> usize {
        self.deques.iter().map(|d| d.len()).sum()
    }

    /// True when no work is queued anywhere.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Backlog of one worker's deque.
    pub fn backlog(&self, worker: usize) -> usize {
        self.deques[worker % self.deques.len()].len()
    }

    /// Accounting snapshot.
    pub fn stats(&self) -> QueueStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn own_pops_are_lifo_steals_are_fifo() {
        let mut q = WorkStealingQueue::new(2);
        q.push(0, 1);
        q.push(0, 2);
        q.push(0, 3);
        // Owner pops newest first.
        assert_eq!(q.pop(0), Some(3));
        // Thief steals oldest first.
        assert_eq!(q.pop(1), Some(1));
        assert_eq!(q.pop(1), Some(2));
        assert_eq!(q.pop(0), None);
        assert_eq!(q.stats().local_pops, 1);
        assert_eq!(q.stats().steals, 2);
    }

    #[test]
    fn stealing_spreads_a_hot_owner_evenly() {
        // All 100 jobs land on worker 0; four workers drain round-robin.
        // Fairness: every worker ends up doing an equal share.
        let mut q = WorkStealingQueue::new(4);
        for i in 0..100 {
            q.push(0, i);
        }
        let mut done = [0usize; 4];
        let mut w = 0;
        while !q.is_empty() {
            if q.pop(w).is_some() {
                done[w] += 1;
            }
            w = (w + 1) % 4;
        }
        assert_eq!(done, [25, 25, 25, 25], "unfair drain: {done:?}");
        assert_eq!(q.stats().local_pops, 25);
        assert_eq!(q.stats().steals, 75);
        assert_eq!(q.stats().pushes, 100);
    }

    #[test]
    fn steals_prefer_longest_backlog() {
        let mut q = WorkStealingQueue::new(3);
        q.push(0, 10);
        q.push(1, 20);
        q.push(1, 21);
        // Worker 2 steals from the most backlogged deque (worker 1).
        assert_eq!(q.pop(2), Some(20));
        // Now both have 1; tie resolves to the lowest index (worker 0).
        assert_eq!(q.pop(2), Some(10));
        assert_eq!(q.pop(2), Some(21));
    }

    #[test]
    fn worker_index_wraps() {
        let mut q = WorkStealingQueue::new(2);
        q.push(5, 42); // 5 % 2 == 1
        assert_eq!(q.backlog(1), 1);
        assert_eq!(q.pop(3), Some(42)); // 3 % 2 == 1: own pop
        assert_eq!(q.stats().local_pops, 1);
    }

    #[test]
    fn empty_pop_returns_none() {
        let mut q: WorkStealingQueue<u32> = WorkStealingQueue::new(1);
        assert_eq!(q.pop(0), None);
        assert!(q.is_empty());
    }
}
