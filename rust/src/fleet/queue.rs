//! Work-stealing queue for the bounded compile-worker pool.
//!
//! FusionStitching exploration is orders of magnitude more expensive
//! than serving an iteration, so a fleet throttles it through a small
//! worker pool while the XLA fallback serves immediately (§6's
//! async-compilation, fleet-wide). Each worker owns a deque: it pushes
//! and pops its own work LIFO (locality — a template's port jobs tend
//! to land on the owner that explored it), and when idle steals FIFO
//! from the most-backlogged victim, which keeps a hot owner from
//! starving the rest of the fleet's compilations.
//!
//! The queue is **shareable**: every deque sits behind its own mutex
//! and the accounting is atomic, so the same structure serves both
//! integration points —
//!
//! * the virtual-time [`super::service`] replay drives it
//!   single-threaded (there a compile job's assignment is a timestamp
//!   computation, jobs route through push/pop immediately, *backlog
//!   lives in virtual time* as worker `free_ms` beyond now, and the
//!   steal counter measures owner-affinity misses), and
//! * the wall-clock [`super::executor`] shares one instance across its
//!   real OS compile-worker threads, which drain the multi-item
//!   LIFO/FIFO/longest-victim semantics concurrently.
//!
//! The LIFO-own/FIFO-steal/longest-victim behaviour is exercised
//! single-threaded by the unit tests below (it stays deterministic when
//! only one thread drives the queue); the lost/duplicate-free guarantee
//! under contention is exercised by the multi-threaded stress test.

use crate::obs::{LockSnapshot, LockStats};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Push/pop/steal accounting.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueueStats {
    pub pushes: usize,
    pub local_pops: usize,
    pub steals: usize,
}

/// Per-worker deques with LIFO local pop and FIFO stealing. Shareable:
/// all methods take `&self`, so one instance can sit behind an `Arc`
/// and be driven by many worker threads at once. Deque locks recover
/// from poisoning (via [`LockStats::lock`], which also profiles
/// contention): every critical section is
/// one `VecDeque` operation, so the structure stays consistent, and a
/// worker that panicked mid-job must not stop its peers from draining
/// the queue (the compile pool's publication barrier depends on it).
#[derive(Debug)]
pub struct WorkStealingQueue<T> {
    deques: Vec<Mutex<VecDeque<T>>>,
    pushes: AtomicUsize,
    local_pops: AtomicUsize,
    steals: AtomicUsize,
    /// One contention profile across every deque lock (the
    /// `work_queue` row in the fleet's observability report).
    lock: LockStats,
}

impl<T> WorkStealingQueue<T> {
    /// Create a queue set for `workers` workers (at least one).
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0, "work-stealing queue needs at least one worker");
        WorkStealingQueue {
            deques: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
            pushes: AtomicUsize::new(0),
            local_pops: AtomicUsize::new(0),
            steals: AtomicUsize::new(0),
            lock: LockStats::new("work_queue"),
        }
    }

    /// Contention profile across all deque locks.
    pub fn lock_profile(&self) -> LockSnapshot {
        self.lock.snapshot()
    }

    /// Number of workers.
    pub fn workers(&self) -> usize {
        self.deques.len()
    }

    /// Enqueue an item on `worker`'s deque (index wraps).
    pub fn push(&self, worker: usize, item: T) {
        let w = worker % self.deques.len();
        self.lock.lock(&self.deques[w]).push_back(item);
        self.pushes.fetch_add(1, Ordering::Relaxed);
    }

    /// Dequeue for `worker`: LIFO from its own deque; when empty, steal
    /// FIFO from the victim with the longest backlog (lowest index on
    /// ties, so a single-threaded replay is deterministic). `None` only
    /// when a full scan observed every deque empty.
    pub fn pop(&self, worker: usize) -> Option<T> {
        let w = worker % self.deques.len();
        if let Some(item) = self.lock.lock(&self.deques[w]).pop_back() {
            self.local_pops.fetch_add(1, Ordering::Relaxed);
            return Some(item);
        }
        // Steal loop: the victim chosen from a length snapshot may be
        // drained by a concurrent thief before we lock it, so retry the
        // scan until an item is stolen or everything looks empty.
        loop {
            let mut victim: Option<(usize, usize)> = None; // (index, len)
            for (i, dq) in self.deques.iter().enumerate() {
                let len = self.lock.lock(dq).len();
                if len == 0 {
                    continue;
                }
                match victim {
                    Some((_, best)) if best >= len => {}
                    _ => victim = Some((i, len)),
                }
            }
            let (v, _) = victim?;
            if let Some(item) = self.lock.lock(&self.deques[v]).pop_front() {
                self.steals.fetch_add(1, Ordering::Relaxed);
                return Some(item);
            }
        }
    }

    /// Total queued items across all deques.
    pub fn len(&self) -> usize {
        self.deques.iter().map(|d| self.lock.lock(d).len()).sum()
    }

    /// True when no work is queued anywhere.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Backlog of one worker's deque.
    pub fn backlog(&self, worker: usize) -> usize {
        self.lock.lock(&self.deques[worker % self.deques.len()]).len()
    }

    /// Accounting snapshot. Exact at quiescence (no concurrent pushes
    /// or pops): `pushes == local_pops + steals + len()`.
    pub fn stats(&self) -> QueueStats {
        QueueStats {
            pushes: self.pushes.load(Ordering::Relaxed),
            local_pops: self.local_pops.load(Ordering::Relaxed),
            steals: self.steals.load(Ordering::Relaxed),
        }
    }
}

/// FNV-1a over a graph key and its device-class name: the owner-routing
/// hash for compile jobs. Hashing the class *bytes* (not its length)
/// makes same-length classes ("V100" vs "A100") route differently and
/// lets every byte of short names like "T4" perturb the owner choice.
pub fn owner_hash(key: u64, class: &str) -> u64 {
    use crate::util::hash::{fnv1a_bytes, FNV_OFFSET};
    fnv1a_bytes(fnv1a_bytes(FNV_OFFSET, &key.to_le_bytes()), class.as_bytes())
}

/// Structure-key → shard routing for the sharded dispatcher fleet: all
/// shapes and buckets of one graph structure land on one shard, so a
/// shard's plan store is a clean partition of the cluster's (no
/// cross-shard publication coupling). Built on the same process-stable
/// FNV-1a as compile-job owner routing — never a `RandomState`-seeded
/// hasher, so shard assignment is identical across processes, replays
/// and executors — with a distinct class tag so shard routing stays
/// decorrelated from worker routing within a shard.
pub fn shard_of(structure: u64, shards: usize) -> usize {
    assert!(shards > 0, "shard routing needs at least one shard");
    (owner_hash(structure, "shard") % shards as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn own_pops_are_lifo_steals_are_fifo() {
        let q = WorkStealingQueue::new(2);
        q.push(0, 1);
        q.push(0, 2);
        q.push(0, 3);
        // Owner pops newest first.
        assert_eq!(q.pop(0), Some(3));
        // Thief steals oldest first.
        assert_eq!(q.pop(1), Some(1));
        assert_eq!(q.pop(1), Some(2));
        assert_eq!(q.pop(0), None);
        assert_eq!(q.stats().local_pops, 1);
        assert_eq!(q.stats().steals, 2);
        // Deque locks are profiled; single-threaded use never contends.
        let profile = q.lock_profile();
        assert_eq!(profile.name, "work_queue");
        assert!(profile.acquisitions >= 6, "acquisitions {}", profile.acquisitions);
        assert_eq!(profile.contended, 0);
    }

    #[test]
    fn stealing_spreads_a_hot_owner_evenly() {
        // All 100 jobs land on worker 0; four workers drain round-robin.
        // Fairness: every worker ends up doing an equal share.
        let q = WorkStealingQueue::new(4);
        for i in 0..100 {
            q.push(0, i);
        }
        let mut done = [0usize; 4];
        let mut w = 0;
        while !q.is_empty() {
            if q.pop(w).is_some() {
                done[w] += 1;
            }
            w = (w + 1) % 4;
        }
        assert_eq!(done, [25, 25, 25, 25], "unfair drain: {done:?}");
        assert_eq!(q.stats().local_pops, 25);
        assert_eq!(q.stats().steals, 75);
        assert_eq!(q.stats().pushes, 100);
    }

    #[test]
    fn steals_prefer_longest_backlog() {
        let q = WorkStealingQueue::new(3);
        q.push(0, 10);
        q.push(1, 20);
        q.push(1, 21);
        // Worker 2 steals from the most backlogged deque (worker 1).
        assert_eq!(q.pop(2), Some(20));
        // Now both have 1; tie resolves to the lowest index (worker 0).
        assert_eq!(q.pop(2), Some(10));
        assert_eq!(q.pop(2), Some(21));
    }

    #[test]
    fn worker_index_wraps() {
        let q = WorkStealingQueue::new(2);
        q.push(5, 42); // 5 % 2 == 1
        assert_eq!(q.backlog(1), 1);
        assert_eq!(q.pop(3), Some(42)); // 3 % 2 == 1: own pop
        assert_eq!(q.stats().local_pops, 1);
    }

    #[test]
    fn empty_pop_returns_none() {
        let q: WorkStealingQueue<u32> = WorkStealingQueue::new(1);
        assert_eq!(q.pop(0), None);
        assert!(q.is_empty());
    }

    #[test]
    fn owner_hash_distinguishes_classes_and_keys() {
        // The length-degenerate hash this replaced keyed on the class
        // *length*: "V100"/"A100" (same length) collided entirely and
        // "T4" barely moved the owner. FNV-1a over the bytes must
        // separate all of these for essentially every key.
        let keys: Vec<u64> = (0..64u64).map(|i| i.wrapping_mul(0x9E37_79B9_7F4A_7C15)).collect();
        let differ = |a: &str, b: &str| {
            keys.iter().filter(|&&k| owner_hash(k, a) != owner_hash(k, b)).count()
        };
        assert!(differ("V100", "A100") >= 60, "same-length classes must not collide");
        assert!(differ("V100", "T4") >= 60);
        // And the key itself spreads owners across a small pool.
        let owners: std::collections::HashSet<u64> =
            keys.iter().map(|&k| owner_hash(k, "V100") % 4).collect();
        assert_eq!(owners.len(), 4, "keys must reach every worker");
    }

    #[test]
    fn shard_routing_is_process_stable_fnv() {
        // Shard assignment must survive process restarts and cross-host
        // replays, so `shard_of` may never route through a
        // `RandomState`-seeded hasher. Pin it to an independent inline
        // FNV-1a reimplementation: any switch to a seeded hasher (or a
        // constant change) fails loudly here instead of silently
        // re-sharding the fleet.
        fn fnv(mut h: u64, bytes: &[u8]) -> u64 {
            for &b in bytes {
                h ^= u64::from(b);
                h = h.wrapping_mul(0x0000_0100_0000_01B3);
            }
            h
        }
        for key in [0u64, 1, 0xF1EE7, 0x9E37_79B9_7F4A_7C15, u64::MAX] {
            let expect = fnv(fnv(0xcbf2_9ce4_8422_2325, &key.to_le_bytes()), b"shard");
            assert_eq!(owner_hash(key, "shard"), expect);
            for shards in [1usize, 2, 4, 8] {
                assert_eq!(shard_of(key, shards), (expect % shards as u64) as usize);
            }
        }
        // Every structure of one shard at S shards must stay together:
        // routing is a pure function of (structure, shards).
        assert_eq!(shard_of(42, 4), shard_of(42, 4));
    }

    #[test]
    fn concurrent_hammer_loses_and_duplicates_nothing() {
        // Loom-free stress test: N threads each push a disjoint range of
        // item ids onto their own deque while popping (own-LIFO or
        // stealing) from the shared structure. At quiescence every id
        // must have been seen exactly once and the accounting must
        // close: pushes == local_pops + steals, with nothing left.
        const WORKERS: usize = 4;
        const PER_WORKER: usize = 2_000;
        const TOTAL: usize = WORKERS * PER_WORKER;
        let q: Arc<WorkStealingQueue<usize>> = Arc::new(WorkStealingQueue::new(WORKERS));
        let seen: Arc<Vec<AtomicUsize>> =
            Arc::new((0..TOTAL).map(|_| AtomicUsize::new(0)).collect());
        let popped = Arc::new(AtomicUsize::new(0));

        let handles: Vec<_> = (0..WORKERS)
            .map(|w| {
                let q = Arc::clone(&q);
                let seen = Arc::clone(&seen);
                let popped = Arc::clone(&popped);
                std::thread::spawn(move || {
                    // Interleave pushes with pops so deques stay busy
                    // and thieves race owners on live deques.
                    for i in 0..PER_WORKER {
                        q.push(w, w * PER_WORKER + i);
                        if i % 3 == 0 {
                            if let Some(item) = q.pop(w) {
                                seen[item].fetch_add(1, Ordering::Relaxed);
                                popped.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                    // Drain until the whole population is accounted
                    // for — with a deadline, so a lost item fails the
                    // accounting assertions below instead of hanging
                    // the test run.
                    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
                    while popped.load(Ordering::Relaxed) < TOTAL {
                        if std::time::Instant::now() > deadline {
                            break;
                        }
                        match q.pop(w) {
                            Some(item) => {
                                seen[item].fetch_add(1, Ordering::Relaxed);
                                popped.fetch_add(1, Ordering::Relaxed);
                            }
                            None => std::thread::yield_now(),
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }

        assert!(q.is_empty(), "items left behind");
        for (id, slot) in seen.iter().enumerate() {
            assert_eq!(slot.load(Ordering::Relaxed), 1, "item {id} lost or duplicated");
        }
        let s = q.stats();
        assert_eq!(s.pushes, TOTAL);
        assert_eq!(s.local_pops + s.steals, TOTAL, "accounting must close: {s:?}");
    }
}
