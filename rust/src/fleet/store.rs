//! Shared cross-device plan store.
//!
//! The §7.5 tune-once-run-many economics at fleet scale: exploration
//! runs once per (graph, device-class) — and for a graph already
//! explored on *any* class, other classes skip the explorer entirely
//! and only re-run the §4.2 launch-dimension tuner
//! ([`crate::pipeline::port_program`]). The store tracks, per graph
//! key, the portability *source* program (the first FS exploration
//! result) plus the program each device class actually serves, with
//! the virtual time its producing compile finishes (tasks that arrive
//! earlier hot-swap mid-serve, §6 style).

use crate::coordinator::GraphKey;
use crate::pipeline::{OptimizedProgram, Tech};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Outcome of a lookup for (graph, device class).
#[derive(Debug, Clone)]
pub enum PlanLookup {
    /// A program for this class exists; `ready_ms` is when its compile
    /// finishes in virtual time (may be in the future — serve the
    /// fallback until then, then hot-swap).
    Hit {
        prog: Arc<OptimizedProgram>,
        ready_ms: f64,
    },
    /// No program for this class, but an FS exploration result from
    /// another class exists: port it (launch-dim re-tune only).
    /// `available_ms` is when the source plan exists in virtual time.
    Portable {
        source: Arc<OptimizedProgram>,
        available_ms: f64,
        tuned_on: &'static str,
    },
    /// Never explored anywhere: full exploration required.
    Miss,
}

/// Hit/port/miss accounting. Counted by the fleet service when a task
/// *acts* on a lookup (serves from the store, runs a port, runs a full
/// exploration) — not at lookup time, so rejected/backpressured tasks
/// do not inflate the rates.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreStats {
    pub exact_hits: usize,
    pub port_hits: usize,
    pub misses: usize,
}

#[derive(Debug, Default)]
struct Entry {
    /// First FS exploration result: (program, ready_ms, device class).
    /// Vetoed/fallback programs never become the source — porting an
    /// XLA plan would launder the veto into other classes.
    source: Option<(Arc<OptimizedProgram>, f64, &'static str)>,
    /// Per device class: the program production serves (post-guard),
    /// with its virtual ready time.
    programs: HashMap<&'static str, (Arc<OptimizedProgram>, f64)>,
}

/// Thread-safe shared plan store, keyed by graph structure hash.
#[derive(Debug, Default)]
pub struct SharedPlanStore {
    entries: Mutex<HashMap<u64, Entry>>,
    stats: Mutex<StoreStats>,
}

impl SharedPlanStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Look up the program for (graph, device class). Pure: accounting
    /// happens via the `note_*` methods once the caller acts on the
    /// outcome.
    pub fn lookup(&self, key: GraphKey, device_class: &'static str) -> PlanLookup {
        let entries = self.entries.lock().unwrap();
        match entries.get(&key.0) {
            Some(e) => {
                if let Some((prog, ready_ms)) = e.programs.get(device_class) {
                    PlanLookup::Hit { prog: Arc::clone(prog), ready_ms: *ready_ms }
                } else if let Some((src, avail, class)) = &e.source {
                    PlanLookup::Portable {
                        source: Arc::clone(src),
                        available_ms: *avail,
                        tuned_on: class,
                    }
                } else {
                    PlanLookup::Miss
                }
            }
            None => PlanLookup::Miss,
        }
    }

    /// Record that a task was served from a stored program.
    pub fn note_exact_hit(&self) {
        self.stats.lock().unwrap().exact_hits += 1;
    }

    /// Record that a task triggered a cross-class port of a stored plan.
    pub fn note_port_hit(&self) {
        self.stats.lock().unwrap().port_hits += 1;
    }

    /// Record that a task found nothing and triggered full exploration.
    pub fn note_miss(&self) {
        self.stats.lock().unwrap().misses += 1;
    }

    /// Record the program `device_class` serves for `key`; `ready_ms`
    /// is the virtual completion time of the compile that produced it.
    /// The first *FS* program inserted for a key becomes the
    /// portability source for the other classes.
    pub fn insert(
        &self,
        key: GraphKey,
        device_class: &'static str,
        prog: Arc<OptimizedProgram>,
        ready_ms: f64,
    ) {
        let mut entries = self.entries.lock().unwrap();
        let e = entries.entry(key.0).or_default();
        if e.source.is_none() && prog.tech == Tech::Fs {
            e.source = Some((Arc::clone(&prog), ready_ms, device_class));
        }
        e.programs.insert(device_class, (prog, ready_ms));
    }

    /// Accounting snapshot.
    pub fn stats(&self) -> StoreStats {
        *self.stats.lock().unwrap()
    }

    /// Number of distinct graphs with at least one entry.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// True when nothing is stored.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explorer::ExploreOptions;
    use crate::gpu::DeviceSpec;
    use crate::graph::{DType, Graph, Shape};
    use crate::pipeline::optimize;
    use crate::workloads::{blocks, LoopKind, Mode, Workload};

    fn ln_workload() -> Workload {
        let mut g = Graph::new("LN");
        let x = g.param(Shape::new(vec![1024, 256]), DType::F32, "x");
        let _ = blocks::layer_norm(&mut g, x, "ln");
        Workload {
            name: "LN",
            field: "micro",
            mode: Mode::Infer,
            batch: 1,
            loop_kind: LoopKind::None,
            graph: g,
        }
    }

    #[test]
    fn miss_then_hit_then_port() {
        let store = SharedPlanStore::new();
        let w = ln_workload();
        let key = GraphKey::of(&w.graph);
        let v100 = DeviceSpec::v100();

        assert!(matches!(store.lookup(key, "V100"), PlanLookup::Miss));

        let prog = Arc::new(optimize(
            &w,
            &v100,
            crate::pipeline::Tech::Fs,
            &ExploreOptions::default(),
        ));
        store.insert(key, "V100", Arc::clone(&prog), 10.0);

        match store.lookup(key, "V100") {
            PlanLookup::Hit { ready_ms, .. } => assert_eq!(ready_ms, 10.0),
            other => panic!("expected exact hit, got {other:?}"),
        }
        match store.lookup(key, "T4") {
            PlanLookup::Portable { tuned_on, available_ms, .. } => {
                assert_eq!(tuned_on, "V100");
                assert_eq!(available_ms, 10.0);
            }
            other => panic!("expected portable, got {other:?}"),
        }
        // Accounting is explicit (acted-on outcomes), not lookup-driven.
        assert_eq!(store.stats(), StoreStats::default());
        store.note_miss();
        store.note_exact_hit();
        store.note_port_hit();
        store.note_port_hit();
        assert_eq!(
            store.stats(),
            StoreStats { exact_hits: 1, port_hits: 2, misses: 1 }
        );
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn vetoed_fallback_is_not_a_port_source() {
        // A class that stored its fallback (FS veto) must not offer it
        // for porting: other classes should fully explore instead.
        let store = SharedPlanStore::new();
        let w = ln_workload();
        let key = GraphKey::of(&w.graph);
        let v100 = DeviceSpec::v100();
        let xla_prog = Arc::new(optimize(
            &w,
            &v100,
            crate::pipeline::Tech::Xla,
            &ExploreOptions::default(),
        ));
        store.insert(key, "V100", xla_prog, 5.0);

        assert!(matches!(store.lookup(key, "V100"), PlanLookup::Hit { .. }));
        assert!(matches!(store.lookup(key, "T4"), PlanLookup::Miss));
        // Once an FS program lands (from the T4 exploration), it becomes
        // the source even though V100 inserted first.
        let t4 = DeviceSpec::t4();
        let fs_prog = Arc::new(optimize(
            &w,
            &t4,
            crate::pipeline::Tech::Fs,
            &ExploreOptions::default(),
        ));
        store.insert(key, "T4", fs_prog, 50.0);
        match store.lookup(key, "A100") {
            PlanLookup::Portable { tuned_on, .. } => assert_eq!(tuned_on, "T4"),
            other => panic!("expected portable, got {other:?}"),
        }
    }
}
