//! Shared cross-device, shape-polymorphic plan store, published via
//! epochs.
//!
//! The §7.5 tune-once-run-many economics at fleet scale: exploration
//! runs once per (graph, device-class) — and a graph already explored
//! elsewhere is re-served through one of two cheap launch-dimension
//! retunes instead of a fresh exploration. The store resolves a lookup
//! through three reuse tiers:
//!
//! 1. **Exact hit** — this device class already serves a program for
//!    this exact graph.
//! 2. **Cross-class port** — another class explored this exact graph;
//!    re-run only the §4.2 launch-dimension tuner for the new device
//!    ([`crate::pipeline::port_program`]).
//! 3. **Bucket hit** — the bucket holds an FS plan for a *sibling
//!    shape* of the same structure inside the same power-of-two shape
//!    bucket ([`crate::coordinator::ShapeClass`]) — this class's own
//!    rep when it has one, else the bucket's first FS plan from any
//!    class; re-lower the sibling's plan at the new shape
//!    ([`crate::pipeline::reshape_program`]), again a
//!    launch-dimension-only retune.
//!
//! Only a genuinely new (structure, bucket, class) triple pays a full
//! exploration. Per exact graph key the store tracks the portability
//! *source* program (the first FS exploration result) plus the program
//! each device class actually serves, with the virtual time its
//! producing compile finishes (tasks that arrive earlier hot-swap
//! mid-serve, §6 style); per (structure, bucket, class) it tracks the
//! first FS program published in the bucket — the shape-port
//! representative.
//!
//! **Publication model.** Both indices live in one
//! [`EpochCell`](crate::fleet::epoch::EpochCell) snapshot: a compile
//! worker publishes a plan by cloning the snapshot, inserting into the
//! exact and bucket tiers, and swapping the snapshot pointer in one
//! atomic store — so a lookup can never see an entry without its bucket
//! representative or vice versa, and *readers never take a mutex*.
//! Serve threads (1000 of them at cluster scale, one lookup per
//! hot-swap poll) read through [`SharedPlanStore::lookup_serve`], whose
//! `plan_store_read` profile row is structurally incapable of contended
//! acquisitions; the dispatcher's slower control-plane reads keep the
//! historical `plan_store` row.

use crate::coordinator::{GraphKey, ShapeClass};
use crate::fleet::epoch::EpochCell;
use crate::graph::Graph;
use crate::obs::{LockSnapshot, LockStats};
use crate::pipeline::{OptimizedProgram, Tech};
use crate::util::lock_recover;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Full plan-store identity of a graph: the exact structural hash plus
/// its shape-erased (structure, bucket) class. Carried together through
/// the compile pipeline so publication can index both tiers atomically.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub exact: GraphKey,
    pub shape: ShapeClass,
}

impl PlanKey {
    /// Compute both identities of a graph.
    pub fn of(graph: &Graph) -> Self {
        PlanKey { exact: GraphKey::of(graph), shape: ShapeClass::of(graph) }
    }
}

/// Outcome of a lookup for (graph, device class).
#[derive(Debug, Clone)]
pub enum PlanLookup {
    /// A program for this class exists; `ready_ms` is when its compile
    /// finishes in virtual time (may be in the future — serve the
    /// fallback until then, then hot-swap).
    Hit {
        prog: Arc<OptimizedProgram>,
        ready_ms: f64,
    },
    /// No program for this class, but an FS exploration result from
    /// another class exists: port it (launch-dim re-tune only).
    /// `available_ms` is when the source plan exists in virtual time.
    Portable {
        source: Arc<OptimizedProgram>,
        available_ms: f64,
        tuned_on: &'static str,
    },
    /// No program for this exact graph, but the bucket holds an FS
    /// program for a sibling shape in the same (structure, bucket) —
    /// from this class when it has one, else the bucket's first FS
    /// program from any class: shape-port it (launch-dim re-tune at
    /// the new shape/class only). `tuned_at` is the sibling's exact
    /// key, `available_ms` when the sibling plan exists in virtual
    /// time.
    BucketHit {
        source: Arc<OptimizedProgram>,
        available_ms: f64,
        tuned_at: GraphKey,
    },
    /// Never explored anywhere reusable: full exploration required.
    Miss,
}

/// Hit/bucket/port/miss accounting. Counted by the fleet service when a
/// task *acts* on a lookup (serves from the store, runs a retune, runs
/// a full exploration) — not at lookup time, so rejected/backpressured
/// tasks do not inflate the rates.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreStats {
    pub exact_hits: usize,
    pub port_hits: usize,
    pub bucket_hits: usize,
    pub misses: usize,
}

#[derive(Debug, Default, Clone)]
struct Entry {
    /// First FS exploration result: (program, ready_ms, device class).
    /// Vetoed/fallback programs never become the source — porting an
    /// XLA plan would launder the veto into other classes.
    source: Option<(Arc<OptimizedProgram>, f64, &'static str)>,
    /// Per device class: the program production serves (post-guard),
    /// with its virtual ready time.
    programs: HashMap<&'static str, (Arc<OptimizedProgram>, f64)>,
}

/// An FS program published inside one shape bucket: the representative
/// sibling plans are shape-ported from.
#[derive(Debug, Clone)]
struct BucketRep {
    exact: u64,
    prog: Arc<OptimizedProgram>,
    ready_ms: f64,
}

/// Per (structure, bucket): the shape-port representatives. Same-class
/// reps are preferred (the plan was launch-tuned on this hardware);
/// `first` is the bucket-wide fallback — the first FS program published
/// by *any* class, mirroring the exact tier's cross-class port source,
/// so a class's first touch of a bucket costs a retune, not an
/// exploration, whenever anyone explored the bucket before.
#[derive(Debug, Default, Clone)]
struct BucketEntry {
    first: Option<BucketRep>,
    per_class: HashMap<&'static str, BucketRep>,
}

/// Both indices inside ONE epoch snapshot, so a publication lands in
/// the exact and bucket tiers atomically (a lookup can never see the
/// entry without its bucket representative or vice versa). Cloned per
/// publication — publications are rare (one per compile), entries are
/// `Arc`s, and the copy buys every reader a mutex-free lookup.
#[derive(Debug, Default, Clone)]
struct StoreState {
    /// Exact graph key → per-class programs + port source.
    entries: HashMap<u64, Entry>,
    /// (structure, bucket) → shape-port representatives.
    buckets: HashMap<(u64, u64), BucketEntry>,
}

/// Thread-safe shared plan store, keyed by graph structure hash and
/// shape bucket. Reads are epoch-validated and lock-free; writes are
/// copy-on-write publications serialized behind the epoch cell's
/// poison-recovering writer mutex.
#[derive(Debug)]
pub struct SharedPlanStore {
    state: EpochCell<StoreState>,
    stats: Mutex<StoreStats>,
    /// Access profile of the dispatcher/control-plane path (the
    /// `plan_store` row in the fleet's observability report). With the
    /// epoch store neither path can block: `contended` is structurally
    /// zero. The `stats` lock is a leaf counter touched off the serving
    /// path; it is not profiled.
    lock: LockStats,
    /// Access profile of the serve-thread hot read path (the
    /// `plan_store_read` row) — the lock-free epoch reads this refactor
    /// exists for, reported separately so the zero-contention claim is
    /// checkable per executor in `BENCH_fleet.json`.
    read_lock: LockStats,
}

impl Default for SharedPlanStore {
    fn default() -> Self {
        SharedPlanStore {
            state: EpochCell::new(StoreState::default()),
            stats: Mutex::default(),
            lock: LockStats::new("plan_store"),
            read_lock: LockStats::new("plan_store_read"),
        }
    }
}

/// Resolve a lookup against one epoch snapshot (shared by the
/// dispatcher and serve-thread paths; only the profile row differs).
fn resolve(st: &StoreState, key: PlanKey, device_class: &'static str) -> PlanLookup {
    if let Some(e) = st.entries.get(&key.exact.0) {
        if let Some((prog, ready_ms)) = e.programs.get(device_class) {
            return PlanLookup::Hit { prog: Arc::clone(prog), ready_ms: *ready_ms };
        }
        if let Some((src, avail, class)) = &e.source {
            return PlanLookup::Portable {
                source: Arc::clone(src),
                available_ms: *avail,
                tuned_on: class,
            };
        }
    }
    if let Some(bucket) = st.buckets.get(&(key.shape.structure, key.shape.bucket)) {
        // Prefer the same-class rep (launch-tuned on this hardware);
        // fall back to the bucket's first FS program from any class
        // — the retune re-lowers for this (shape, class) either
        // way. A rep for this exact key would have resolved in the
        // exact tier above; anything else is a sibling shape.
        let rep = bucket
            .per_class
            .get(device_class)
            .or_else(|| bucket.first.as_ref())
            .filter(|rep| rep.exact != key.exact.0);
        if let Some(rep) = rep {
            return PlanLookup::BucketHit {
                source: Arc::clone(&rep.prog),
                available_ms: rep.ready_ms,
                tuned_at: GraphKey(rep.exact),
            };
        }
    }
    PlanLookup::Miss
}

impl SharedPlanStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Access profile of the dispatcher/control-plane path.
    pub fn lock_profile(&self) -> LockSnapshot {
        self.lock.snapshot()
    }

    /// Access profile of the serve-thread epoch-read path. Contended
    /// acquisitions here are structurally impossible — the row exists
    /// so CI can gate on exactly that.
    pub fn read_profile(&self) -> LockSnapshot {
        self.read_lock.snapshot()
    }

    /// Look up the program for (graph, device class) through the three
    /// reuse tiers. Pure: accounting happens via the `note_*` methods
    /// once the caller acts on the outcome.
    pub fn lookup(&self, key: PlanKey, device_class: &'static str) -> PlanLookup {
        self.lock.acquire();
        self.state.read(|st| resolve(st, key, device_class))
    }

    /// The serve-thread hot-swap poll: identical resolution, profiled
    /// on the `plan_store_read` row. One epoch-validated read — no
    /// mutex anywhere on this path.
    pub fn lookup_serve(&self, key: PlanKey, device_class: &'static str) -> PlanLookup {
        self.read_lock.acquire();
        self.state.read(|st| resolve(st, key, device_class))
    }

    /// Record that a task was served from a stored program.
    pub fn note_exact_hit(&self) {
        lock_recover(&self.stats).exact_hits += 1;
    }

    /// Record that a task triggered a cross-class port of a stored plan.
    pub fn note_port_hit(&self) {
        lock_recover(&self.stats).port_hits += 1;
    }

    /// Record that a task triggered a same-class shape retune of a
    /// sibling shape's plan.
    pub fn note_bucket_hit(&self) {
        lock_recover(&self.stats).bucket_hits += 1;
    }

    /// Record that a task found nothing and triggered full exploration.
    pub fn note_miss(&self) {
        lock_recover(&self.stats).misses += 1;
    }

    /// Publish the program `device_class` serves for `key`; `ready_ms`
    /// is the virtual completion time of the compile that produced it.
    /// The first *FS* program inserted for an exact key becomes the
    /// portability source for the other classes, and the first FS
    /// program a class publishes in a (structure, bucket) becomes that
    /// class's shape-port representative for sibling shapes. One epoch
    /// publication: both tiers flip atomically under every reader.
    pub fn insert(
        &self,
        key: PlanKey,
        device_class: &'static str,
        prog: Arc<OptimizedProgram>,
        ready_ms: f64,
    ) {
        self.lock.acquire();
        self.state.publish(|st| {
            let StoreState { entries, buckets } = st;
            let e = entries.entry(key.exact.0).or_default();
            if e.source.is_none() && prog.tech == Tech::Fs {
                e.source = Some((Arc::clone(&prog), ready_ms, device_class));
            }
            if prog.tech == Tech::Fs {
                let bucket = buckets.entry((key.shape.structure, key.shape.bucket)).or_default();
                let rep = BucketRep { exact: key.exact.0, prog: Arc::clone(&prog), ready_ms };
                if bucket.first.is_none() {
                    bucket.first = Some(rep.clone());
                }
                bucket.per_class.entry(device_class).or_insert(rep);
            }
            e.programs.insert(device_class, (prog, ready_ms));
        });
    }

    /// Accounting snapshot.
    pub fn stats(&self) -> StoreStats {
        *lock_recover(&self.stats)
    }

    /// Number of epoch publications so far (equals successful inserts).
    pub fn publications(&self) -> u64 {
        self.state.publications()
    }

    /// Number of distinct exact graphs with at least one entry.
    pub fn len(&self) -> usize {
        self.lock.acquire();
        self.state.read(|st| st.entries.len())
    }

    /// Number of distinct (structure, bucket) classes with at least one
    /// shape-port representative.
    pub fn bucket_len(&self) -> usize {
        self.lock.acquire();
        self.state.read(|st| st.buckets.len())
    }

    /// True when nothing is stored.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explorer::ExploreOptions;
    use crate::gpu::DeviceSpec;
    use crate::graph::{DType, Graph, Shape};
    use crate::pipeline::optimize;
    use crate::workloads::{blocks, LoopKind, Mode, Workload};

    fn ln_workload_rows(rows: usize) -> Workload {
        let mut g = Graph::new("LN");
        let x = g.param(Shape::new(vec![rows, 256]), DType::F32, "x");
        let _ = blocks::layer_norm(&mut g, x, "ln");
        Workload {
            name: "LN",
            field: "micro",
            mode: Mode::Infer,
            batch: 1,
            loop_kind: LoopKind::None,
            graph: g,
        }
    }

    fn ln_workload() -> Workload {
        ln_workload_rows(1024)
    }

    #[test]
    fn miss_then_hit_then_port() {
        let store = SharedPlanStore::new();
        let w = ln_workload();
        let key = PlanKey::of(&w.graph);
        let v100 = DeviceSpec::v100();

        assert!(matches!(store.lookup(key, "V100"), PlanLookup::Miss));

        let prog = Arc::new(optimize(
            &w,
            &v100,
            crate::pipeline::Tech::Fs,
            &ExploreOptions::default(),
        ));
        store.insert(key, "V100", Arc::clone(&prog), 10.0);

        match store.lookup(key, "V100") {
            PlanLookup::Hit { ready_ms, .. } => assert_eq!(ready_ms, 10.0),
            other => panic!("expected exact hit, got {other:?}"),
        }
        match store.lookup(key, "T4") {
            PlanLookup::Portable { tuned_on, available_ms, .. } => {
                assert_eq!(tuned_on, "V100");
                assert_eq!(available_ms, 10.0);
            }
            other => panic!("expected portable, got {other:?}"),
        }
        // Accounting is explicit (acted-on outcomes), not lookup-driven.
        assert_eq!(store.stats(), StoreStats::default());
        store.note_miss();
        store.note_exact_hit();
        store.note_port_hit();
        store.note_port_hit();
        store.note_bucket_hit();
        assert_eq!(
            store.stats(),
            StoreStats { exact_hits: 1, port_hits: 2, bucket_hits: 1, misses: 1 }
        );
        assert_eq!(store.len(), 1);
        assert_eq!(store.bucket_len(), 1);
        // The control-plane path is profiled: every lookup/insert
        // counts, and the epoch store never contends.
        let profile = store.lock_profile();
        assert_eq!(profile.name, "plan_store");
        assert!(profile.acquisitions >= 4, "acquisitions {}", profile.acquisitions);
        assert_eq!(profile.contended, 0);
    }

    #[test]
    fn serve_path_reads_are_epoch_snapshots_profiled_separately() {
        // The serve-thread path must resolve identically to the
        // dispatcher path, count on its own `plan_store_read` row, and
        // never touch the dispatcher row — with zero contended
        // acquisitions by construction.
        let store = SharedPlanStore::new();
        let w = ln_workload();
        let key = PlanKey::of(&w.graph);
        let v100 = DeviceSpec::v100();
        assert!(matches!(store.lookup_serve(key, "V100"), PlanLookup::Miss));

        let prog = Arc::new(optimize(
            &w,
            &v100,
            crate::pipeline::Tech::Fs,
            &ExploreOptions::default(),
        ));
        store.insert(key, "V100", Arc::clone(&prog), 3.0);
        assert_eq!(store.publications(), 1, "one insert = one epoch publication");

        assert!(matches!(
            store.lookup_serve(key, "V100"),
            PlanLookup::Hit { ready_ms, .. } if ready_ms == 3.0
        ));
        assert!(matches!(store.lookup_serve(key, "T4"), PlanLookup::Portable { .. }));

        let read = store.read_profile();
        assert_eq!(read.name, "plan_store_read");
        assert_eq!(read.acquisitions, 3);
        assert_eq!(read.contended, 0, "epoch reads cannot contend");
        assert_eq!(read.blocked_ms, 0.0);
        // Only the insert landed on the dispatcher row.
        assert_eq!(store.lock_profile().acquisitions, 1);
    }

    #[test]
    fn sibling_shape_is_a_bucket_hit_within_and_across_classes() {
        // Explore LN at 1024 rows on V100; the 1000-row sibling (same
        // structure, same power-of-two bucket) must resolve as a
        // BucketHit on V100 — and on T4 too, through the bucket's
        // first-FS cross-class fallback (a first touch of an
        // already-explored bucket costs a retune, never an
        // exploration).
        let store = SharedPlanStore::new();
        let big = ln_workload_rows(1024);
        let sib = ln_workload_rows(1000);
        let key_big = PlanKey::of(&big.graph);
        let key_sib = PlanKey::of(&sib.graph);
        assert_ne!(key_big.exact, key_sib.exact);
        assert_eq!(key_big.shape, key_sib.shape);

        let v100 = DeviceSpec::v100();
        let prog = Arc::new(optimize(
            &big,
            &v100,
            crate::pipeline::Tech::Fs,
            &ExploreOptions::default(),
        ));
        store.insert(key_big, "V100", Arc::clone(&prog), 7.0);

        match store.lookup(key_sib, "V100") {
            PlanLookup::BucketHit { tuned_at, available_ms, .. } => {
                assert_eq!(tuned_at, key_big.exact);
                assert_eq!(available_ms, 7.0);
            }
            other => panic!("expected bucket hit, got {other:?}"),
        }
        assert!(matches!(store.lookup(key_sib, "T4"), PlanLookup::BucketHit { .. }));

        // A shape outside the bucket misses even on V100.
        let far = ln_workload_rows(4096);
        let key_far = PlanKey::of(&far.graph);
        assert_eq!(key_far.shape.structure, key_big.shape.structure);
        assert_ne!(key_far.shape.bucket, key_big.shape.bucket);
        assert!(matches!(store.lookup(key_far, "V100"), PlanLookup::Miss));

        // Exact-tier resolution still wins over the bucket tier: once
        // the sibling publishes its own program the bucket rep is moot.
        let sib_prog = Arc::new(optimize(
            &sib,
            &v100,
            crate::pipeline::Tech::Fs,
            &ExploreOptions::default(),
        ));
        store.insert(key_sib, "V100", sib_prog, 9.0);
        assert!(matches!(
            store.lookup(key_sib, "V100"),
            PlanLookup::Hit { ready_ms, .. } if ready_ms == 9.0
        ));
        // The bucket keeps its first representative (one class, one rep).
        assert_eq!(store.bucket_len(), 1);
    }

    #[test]
    fn vetoed_fallback_is_not_a_port_or_bucket_source() {
        // A class that stored its fallback (FS veto) must not offer it
        // for porting or shape-retuning: other lookups should fully
        // explore instead.
        let store = SharedPlanStore::new();
        let w = ln_workload();
        let key = PlanKey::of(&w.graph);
        let v100 = DeviceSpec::v100();
        let xla_prog = Arc::new(optimize(
            &w,
            &v100,
            crate::pipeline::Tech::Xla,
            &ExploreOptions::default(),
        ));
        store.insert(key, "V100", xla_prog, 5.0);

        assert!(matches!(store.lookup(key, "V100"), PlanLookup::Hit { .. }));
        assert!(matches!(store.lookup(key, "T4"), PlanLookup::Miss));
        // The pinned fallback is not a shape-port rep either.
        let sib = ln_workload_rows(1000);
        let key_sib = PlanKey::of(&sib.graph);
        assert!(matches!(store.lookup(key_sib, "V100"), PlanLookup::Miss));
        assert_eq!(store.bucket_len(), 0);
        // Once an FS program lands (from the T4 exploration), it becomes
        // the source even though V100 inserted first.
        let t4 = DeviceSpec::t4();
        let fs_prog = Arc::new(optimize(
            &w,
            &t4,
            crate::pipeline::Tech::Fs,
            &ExploreOptions::default(),
        ));
        store.insert(key, "T4", fs_prog, 50.0);
        match store.lookup(key, "A100") {
            PlanLookup::Portable { tuned_on, .. } => assert_eq!(tuned_on, "T4"),
            other => panic!("expected portable, got {other:?}"),
        }
        // And it is T4's bucket rep for sibling shapes.
        assert!(matches!(store.lookup(key_sib, "T4"), PlanLookup::BucketHit { .. }));
    }
}
