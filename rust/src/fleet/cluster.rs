//! Cluster-scale control plane: N structure-key-sharded dispatchers.
//!
//! One global dispatcher stops scaling long before 100k tasks / 1000
//! devices: every task funnels through one placement loop, one
//! admission ledger and one publication barrier. The cluster layer
//! splits the fleet into `shards` *complete* dispatchers — each
//! [`FleetService`] owns its slice of the device registry, its own
//! epoch-published plan store, compile pool, admission controller and
//! (under wall clock) publication barrier — and routes every task to
//! one shard by its graph's *structure key* via
//! [`super::queue::shard_of`].
//!
//! Structure keys are shape-erased, so all shapes and power-of-two
//! buckets of one template land on the same shard: the store's
//! cross-shape and cross-class reuse tiers keep their full hit rate
//! inside a shard, and no plan ever needs to migrate between shards.
//! Routing is a pure FNV hash of the key (process-stable, no
//! `RandomState`), so the same trace always shards the same way.
//!
//! The decision-equivalence invariant becomes *per shard*: shard `i`
//! replays its sub-trace through an unmodified dispatcher, so its
//! arrival-ordered decision stream — pinned by
//! [`FleetService::decision_digest`] — is byte-identical between the
//! virtual and wall-clock executors. Cross-shard task interleavings may
//! differ run to run (shards race on real threads); the per-shard
//! digests may not, and [`ClusterReport`] carries them so tests and the
//! bench gate can compare.

use super::metrics::{ClusterReport, FleetReport, ShardRollup};
use super::queue::shard_of;
use super::service::{FleetOptions, FleetService};
use super::sim::{FleetTask, TaskShape, TemplateFamily};
use super::store::PlanKey;
use crate::workloads::Workload;
use std::thread;
use std::time::Instant;

/// N independent shard dispatchers behind one task-routing front.
pub struct ShardedFleetService {
    shards: Vec<FleetService>,
    /// Template index → structure key (shape-erased, so one lookup per
    /// template covers every shape the trace instantiates it at).
    routes: Vec<u64>,
}

impl ShardedFleetService {
    /// Build a sharded fleet over a fixed-shape template population.
    pub fn new(opts: FleetOptions, templates: Vec<Workload>) -> Self {
        Self::with_families(opts, templates.into_iter().map(TemplateFamily::Fixed).collect())
    }

    /// Build a sharded fleet over a (possibly shape-polymorphic)
    /// template family population. `opts.shards` dispatchers are
    /// created, each owning a round-robin slice of `opts.registry`;
    /// the remaining options apply to every shard (per-shard compile
    /// pools of `compile_workers`, per-shard admission ledgers, ...).
    pub fn with_families(opts: FleetOptions, families: Vec<TemplateFamily>) -> Self {
        assert!(opts.shards >= 1, "cluster needs at least one shard");
        let routes = families
            .iter()
            .map(|f| PlanKey::of(&f.instantiate(TaskShape::default()).graph).shape.structure)
            .collect();
        let shards = opts
            .registry
            .partition(opts.shards)
            .into_iter()
            .map(|registry| {
                let shard_opts = FleetOptions { registry, ..opts.clone() };
                FleetService::with_families(shard_opts, families.clone())
            })
            .collect();
        ShardedFleetService { shards, routes }
    }

    /// The shard a template's tasks route to.
    pub fn shard_for_template(&self, template: usize) -> usize {
        shard_of(self.routes[template], self.shards.len())
    }

    /// The shard dispatchers (inspection).
    pub fn shards(&self) -> &[FleetService] {
        &self.shards
    }

    /// Route a trace (sorted by arrival) to the shards, replay every
    /// shard concurrently on its own thread — the wall-clock shards
    /// each spin up their own compile/serve pools, so the cluster runs
    /// as one process-wide fleet — and roll the per-shard reports,
    /// decision digests and lock rows into a [`ClusterReport`].
    pub fn run_trace(&mut self, trace: &[FleetTask]) -> ClusterReport {
        let n = self.shards.len();
        let mut subs: Vec<Vec<FleetTask>> = vec![Vec::new(); n];
        for task in trace {
            // A sub-sequence of a sorted trace is sorted: each shard
            // still sees monotone arrivals.
            subs[shard_of(self.routes[task.template], n)].push(task.clone());
        }
        let t0 = Instant::now();
        let reports: Vec<FleetReport> = thread::scope(|scope| {
            let handles: Vec<_> = self
                .shards
                .iter_mut()
                .zip(&subs)
                .map(|(svc, sub)| scope.spawn(move || svc.run_trace(sub)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("shard dispatcher panicked"))
                .collect()
        });
        let elapsed_ms = t0.elapsed().as_secs_f64() * 1e3;
        let executor = reports[0].executor;
        let shards = reports
            .into_iter()
            .enumerate()
            .map(|(i, report)| ShardRollup {
                shard: i,
                decision_digest: self.shards[i].decision_digest(),
                locks: self.shards[i].lock_rows(),
                report,
            })
            .collect();
        ClusterReport { executor, shards, elapsed_ms }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::executor::ExecutorKind;
    use crate::fleet::registry::DeviceRegistry;
    use crate::fleet::sim::{
        build_template_families, build_templates, generate_trace, TrafficConfig,
    };
    use std::collections::BTreeSet;

    /// The CI-gated tentpole invariant: with the control plane sharded
    /// four ways, batched admission ticking, the calibration loop
    /// closed AND shape-polymorphic traffic, every shard's decision
    /// stream is byte-identical between the virtual and wall-clock
    /// executors.
    #[test]
    fn per_shard_decisions_converge_across_executors_with_calibration_and_dynamic_shapes() {
        let traffic = TrafficConfig {
            tasks: 240,
            templates: 12,
            mean_interarrival_ms: 1.0,
            min_ops: 20,
            max_ops: 40,
            dynamic_shapes: true,
            ..Default::default()
        };
        let families = build_template_families(&traffic);
        let trace = generate_trace(&traffic);
        let base = FleetOptions {
            registry: DeviceRegistry::mixed(4, 4, 2),
            compile_workers: 2,
            calibrate: true,
            shards: 4,
            admission_tick_ms: 5.0,
            ..Default::default()
        };
        let run = |executor: ExecutorKind| {
            let opts = FleetOptions { executor, ..base.clone() };
            let mut svc = ShardedFleetService::with_families(opts, families.clone());
            svc.run_trace(&trace)
        };
        let virt = run(ExecutorKind::VirtualTime);
        let wall = run(ExecutorKind::WallClock { threads: 2 });

        assert_eq!(virt.shards.len(), 4);
        assert_eq!(wall.shards.len(), 4);
        assert_eq!(virt.tasks(), 240, "routing must not drop tasks");
        assert_eq!(wall.tasks(), 240);
        let nonempty = virt.shards.iter().filter(|s| s.report.tasks > 0).count();
        assert!(nonempty >= 2, "structure routing must actually fan out: {nonempty}");
        // The headline: per-shard decision streams are byte-identical
        // across executors (cross-shard interleavings are free to
        // differ — nothing here compares them).
        assert_eq!(virt.decision_digests(), wall.decision_digests());
        for (v, w) in virt.shards.iter().zip(&wall.shards) {
            assert_eq!(v.report.tasks, w.report.tasks, "shard {}", v.shard);
            assert_eq!(v.report.admitted, w.report.admitted, "shard {}", v.shard);
            assert_eq!(v.report.fallback_only, w.report.fallback_only, "shard {}", v.shard);
            assert_eq!(v.report.rejected, w.report.rejected, "shard {}", v.shard);
            assert_eq!(v.report.exact_hits, w.report.exact_hits, "shard {}", v.shard);
            assert_eq!(v.report.bucket_hits, w.report.bucket_hits, "shard {}", v.shard);
            assert_eq!(v.report.misses, w.report.misses, "shard {}", v.shard);
            assert_eq!(v.report.explore_jobs, w.report.explore_jobs, "shard {}", v.shard);
            assert_eq!(v.report.reexplore_jobs, w.report.reexplore_jobs, "shard {}", v.shard);
            assert_eq!(
                v.report.calibration_samples,
                w.report.calibration_samples,
                "shard {}",
                v.shard
            );
            assert_eq!(v.report.makespan_ms, w.report.makespan_ms, "shard {}", v.shard);
            assert_eq!(v.report.regressions, 0);
            assert_eq!(w.report.regressions, 0);
        }
        // Both advertised loops genuinely ran: calibration sampled
        // served programs, and the traffic instantiated more graphs
        // than templates (shape polymorphism).
        let samples: usize = virt.shards.iter().map(|s| s.report.calibration_samples).sum();
        assert!(samples > 0, "calibration must sample on served hits");
        let shapes: usize = virt.shards.iter().map(|s| s.report.distinct_shapes).sum();
        assert!(shapes > 12, "dynamic traffic must vary shapes: {shapes}");
    }

    /// The CI-gated QoS rail: multi-tenant traffic with priority
    /// tiers, seeded device churn AND fault injection, sharded four
    /// ways — and still every shard's decision stream (admission
    /// verdicts including sheds, placements, migration resolutions) is
    /// byte-identical between the virtual and wall-clock executors.
    #[test]
    fn tenant_churn_fault_decisions_converge_across_executors() {
        let traffic = TrafficConfig {
            tasks: 240,
            templates: 12,
            mean_interarrival_ms: 1.0,
            min_ops: 20,
            max_ops: 40,
            dynamic_shapes: true,
            tenants: 6,
            ..Default::default()
        };
        let families = build_template_families(&traffic);
        let trace = generate_trace(&traffic);
        let base = FleetOptions {
            // Four devices per shard: every shard's churn plan has a
            // fault victim plus drain/rejoin candidates.
            registry: DeviceRegistry::mixed(8, 8, 2),
            compile_workers: 2,
            shards: 4,
            admission_tick_ms: 5.0,
            churn: true,
            inject_faults: true,
            ..Default::default()
        };
        let run = |executor: ExecutorKind| {
            let opts = FleetOptions { executor, ..base.clone() };
            let mut svc = ShardedFleetService::with_families(opts, families.clone());
            svc.run_trace(&trace)
        };
        let virt = run(ExecutorKind::VirtualTime);
        let wall = run(ExecutorKind::WallClock { threads: 2 });

        assert_eq!(virt.tasks(), 240, "routing must not drop tasks");
        assert_eq!(wall.tasks(), 240);
        assert_eq!(virt.decision_digests(), wall.decision_digests());
        for (v, w) in virt.shards.iter().zip(&wall.shards) {
            let (vr, wr) = (&v.report, &w.report);
            // Every QoS and churn counter is virtual bookkeeping, so
            // the executors must agree exactly — not approximately.
            assert_eq!(vr.sheds, wr.sheds, "shard {}", v.shard);
            assert_eq!(vr.sla_violations, wr.sla_violations, "shard {}", v.shard);
            assert_eq!(vr.migrations, wr.migrations, "shard {}", v.shard);
            assert_eq!(vr.migrations_degraded, wr.migrations_degraded, "shard {}", v.shard);
            assert_eq!(vr.churn_events, wr.churn_events, "shard {}", v.shard);
            assert_eq!(vr.faults, wr.faults, "shard {}", v.shard);
            assert_eq!(vr.regressions, 0, "shard {}", v.shard);
            assert_eq!(wr.regressions, 0, "shard {}", v.shard);
            assert_eq!(vr.tenants.len(), wr.tenants.len(), "shard {}", v.shard);
            for (vt, wt) in vr.tenants.iter().zip(&wr.tenants) {
                assert_eq!(vt.tenant, wt.tenant);
                assert_eq!(vt.tasks, wt.tasks);
                assert_eq!(vt.served, wt.served);
                assert_eq!(vt.shed, wt.shed);
                assert_eq!(vt.rejected, wt.rejected);
                assert_eq!(vt.sla_violations, wt.sla_violations);
            }
            // Accounting still closes with the shed lane in play.
            assert_eq!(
                vr.admitted + vr.fallback_only + vr.rejected + vr.sheds,
                vr.tasks,
                "shard {}",
                v.shard
            );
            // The tier contract: premium is never shed and never
            // violates its SLA (tier-aware admission sheds pre-serve).
            for t in vr.tenants.iter().filter(|t| t.tier == "premium") {
                assert_eq!(t.shed, 0, "premium is never shed");
                assert_eq!(t.sla_violations, 0, "premium SLA must hold");
            }
        }
        // Fault injection is per shard: every shard's registry slice
        // keeps at least two devices, so each seeded plan kills exactly
        // one. (Whether a given shard's sessions happen to span its
        // seeded boundaries is load-dependent — the guaranteed-migration
        // paths are pinned by the `fleet::service` churn tests.)
        let faults: usize = virt.shards.iter().map(|s| s.report.faults).sum();
        assert_eq!(faults, 4, "every shard's churn plan kills one device");
        let violations: usize = virt.shards.iter().map(|s| s.report.sla_violations).sum();
        assert_eq!(violations, 0, "tier-aware shedding pre-empts every violation");
    }

    /// Satellite: real workload structure keys spread near-uniformly
    /// over 2/4/8 shards. Process stability of the underlying hash is
    /// pinned separately by `queue::tests::shard_routing_is_process_stable_fnv`
    /// (pure FNV, no `RandomState`).
    #[test]
    fn structure_key_routing_spreads_real_workloads_near_uniformly() {
        let traffic = TrafficConfig { templates: 96, dynamic_shapes: true, ..Default::default() };
        let families = build_template_families(&traffic);
        let keys: BTreeSet<u64> = families
            .iter()
            .map(|f| PlanKey::of(&f.instantiate(TaskShape::default()).graph).shape.structure)
            .collect();
        assert!(keys.len() >= 72, "workload structure keys mostly distinct: {}", keys.len());
        for shards in [2usize, 4, 8] {
            let mut counts = vec![0usize; shards];
            for &k in &keys {
                counts[shard_of(k, shards)] += 1;
            }
            let cap = 3 * keys.len() / shards + 3;
            for (i, &c) in counts.iter().enumerate() {
                assert!(c >= 1, "shard {i} of {shards} starved: {counts:?}");
                assert!(c <= cap, "shard {i} of {shards} overloaded (> {cap}): {counts:?}");
            }
        }
    }

    #[test]
    fn single_shard_cluster_matches_the_plain_dispatcher() {
        let traffic = TrafficConfig {
            tasks: 60,
            templates: 4,
            mean_interarrival_ms: 1.0,
            min_ops: 20,
            max_ops: 40,
            ..Default::default()
        };
        let templates = build_templates(&traffic);
        let trace = generate_trace(&traffic);
        let opts = FleetOptions {
            registry: DeviceRegistry::mixed(1, 1, 2),
            compile_workers: 2,
            shards: 1,
            ..Default::default()
        };
        let (plain_json, plain_digest) = {
            let mut svc = FleetService::new(opts.clone(), templates.clone());
            let r = svc.run_trace(&trace);
            (r.to_json().to_string(), svc.decision_digest())
        };
        let mut cluster = ShardedFleetService::new(opts, templates);
        let cr = cluster.run_trace(&trace);
        assert_eq!(cr.shards.len(), 1);
        assert_eq!(cr.tasks(), 60);
        // One shard IS the plain dispatcher: identical report and
        // identical decision digest.
        assert_eq!(cr.shards[0].report.to_json().to_string(), plain_json);
        assert_eq!(cr.shards[0].decision_digest, plain_digest);
        assert!(cr.elapsed_ms > 0.0);
        assert!(cr.tasks_per_sec() > 0.0);
    }
}
