//! Kernel emission: lower a tuned pattern to the [`KernelSpec`] the
//! simulator executes, and render CUDA-like pseudocode for inspection.
//!
//! The paper's implementation emits LLVM IR → PTX → SASS through XLA's
//! backend; our numeric path instead runs through AOT-lowered HLO on
//! PJRT (see `runtime/`), so emission here targets the timing substrate
//! plus a human-readable rendering of the chosen composition schemes.

use super::schedule::SubRootSchedule;
use super::tuner::{tune_pattern, TunedKernel, TunerOptions};
use crate::gpu::{DeviceSpec, KernelClass, KernelSpec};
use crate::graph::{Graph, NodeId, OpClass, OpKind};

/// Emission configuration: which code generator personality to use.
#[derive(Debug, Clone)]
pub struct EmitConfig {
    pub tuner: TunerOptions,
}

impl EmitConfig {
    pub fn fusion_stitching() -> Self {
        EmitConfig { tuner: TunerOptions::fusion_stitching() }
    }
    /// FusionStitching personality under explicit (e.g. calibrated)
    /// cost parameters.
    pub fn fusion_stitching_with(cost: crate::gpu::CostParams) -> Self {
        EmitConfig { tuner: TunerOptions::fusion_stitching_with(cost) }
    }
    pub fn xla() -> Self {
        EmitConfig { tuner: TunerOptions::xla() }
    }
}

/// Emit one memory-intensive kernel for `pattern`. Returns the spec and
/// the tuned strategy, or `None` when the pattern is unschedulable.
pub fn emit_kernel(
    graph: &Graph,
    pattern: &[NodeId],
    name: impl Into<String>,
    device: &DeviceSpec,
    config: &EmitConfig,
) -> Option<(KernelSpec, TunedKernel)> {
    let tuned = tune_pattern(graph, pattern, device, &config.tuner)?;
    let est = &tuned.estimate;
    let spec = KernelSpec {
        name: name.into(),
        class: KernelClass::MemoryIntensive,
        launch: est.launch,
        regs_per_thread: est.regs_per_thread,
        shmem_per_block: est.shmem_per_block,
        bytes_read: est.bytes_read,
        bytes_written: est.bytes_written,
        instrs_per_thread: est.instrs_per_thread,
        avg_cpi: est.avg_cpi,
    };
    Some((spec, tuned))
}

/// Emit the library call for one compute-intensive op (GEMM/conv).
pub fn emit_library_call(graph: &Graph, id: NodeId) -> KernelSpec {
    let node = graph.node(id);
    let flops = match node.kind {
        OpKind::MatMul | OpKind::BatchMatMul => {
            // out = [.., m, n]; the contraction length is whatever input
            // volume the output does not account for.
            let out = node.shape.num_elements() as u64;
            let in0 = graph.node(node.inputs[0]).shape.num_elements() as u64;
            let m_batch = node.shape.outer_elements() as u64; // [.., m]
            let k = (in0 / m_batch.max(1)).max(1);
            2 * out * k
        }
        OpKind::Conv => {
            // 3×3 kernel over the output volume (workload builders use
            // 3×3 filters throughout).
            let out = node.shape.num_elements() as u64;
            2 * out * 9 * 16
        }
        _ => 0,
    };
    let bytes: usize = node
        .inputs
        .iter()
        .map(|&i| graph.node(i).output_bytes())
        .sum::<usize>()
        + node.output_bytes();
    KernelSpec::library(node.name.clone(), flops, bytes)
}

/// Render CUDA-like pseudocode for a tuned kernel — what `fstitch
/// inspect` and `examples/codegen_inspect.rs` print. The structure shows
/// each group under its schedule, with the communication primitive
/// (register / `__shfl_sync` / shared memory) spelled out.
pub fn pseudocode(graph: &Graph, pattern: &[NodeId], tuned: &TunedKernel) -> String {
    let mut out = String::new();
    let est = &tuned.estimate;
    out.push_str(&format!(
        "// fused kernel: {} ops, grid={} block={} regs/t={} shmem/blk={}B occ={:.2}\n",
        pattern.len(),
        est.launch.grid_blocks,
        est.launch.block_threads,
        est.regs_per_thread,
        est.shmem_per_block,
        est.occupancy
    ));
    out.push_str("__global__ void fusion_kernel(...) {\n");
    if est.shmem_per_block > 0 {
        out.push_str(&format!(
            "  __shared__ char smem[{}];\n",
            est.shmem_per_block
        ));
    }
    for (gi, (group, sched)) in tuned
        .grouping
        .groups
        .iter()
        .zip(&tuned.schedules)
        .enumerate()
    {
        let role = if group.is_root { "root" } else { "sub-root" };
        out.push_str(&format!(
            "  // group {gi} [{role}] schedule={} scheme={:?}\n",
            sched.name(),
            sched.scheme()
        ));
        for &m in &group.members {
            let node = graph.node(m);
            let inputs: Vec<String> = node
                .inputs
                .iter()
                .map(|i| format!("v{}", i.0))
                .collect();
            let stmt = match node.kind.class() {
                OpClass::Reduction => format!(
                    "  v{} = {}({});   // row-reduce {}",
                    node.id.0,
                    node.kind.name(),
                    inputs.join(", "),
                    node.shape
                ),
                _ => format!(
                    "  v{} = {}({});   // {}",
                    node.id.0,
                    node.kind.name(),
                    inputs.join(", "),
                    node.shape
                ),
            };
            out.push_str(&stmt);
            out.push('\n');
        }
        if !group.is_root {
            let comm = match sched {
                SubRootSchedule::ThreadLocal => {
                    "  // consumers recompute this group per-thread (thread composition)"
                }
                SubRootSchedule::WarpReuse => {
                    "  // broadcast via __shfl_sync from lane 0 (warp composition)"
                }
                SubRootSchedule::BlockReuse => {
                    "  // stage to smem + __syncthreads() (block composition)"
                }
            };
            out.push_str(comm);
            out.push('\n');
        }
    }
    out.push_str("}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, Shape};
    use crate::workloads::blocks;

    fn ln() -> (Graph, Vec<NodeId>) {
        let mut g = Graph::new("ln");
        let x = g.param(Shape::new(vec![4096, 768]), DType::F32, "x");
        let _ = blocks::layer_norm(&mut g, x, "ln");
        let p: Vec<NodeId> = g
            .nodes()
            .iter()
            .filter(|n| n.kind.is_fusible())
            .map(|n| n.id)
            .collect();
        (g, p)
    }

    #[test]
    fn emit_produces_memory_kernel() {
        let (g, p) = ln();
        let device = DeviceSpec::v100();
        let (spec, _t) =
            emit_kernel(&g, &p, "fusion.0", &device, &EmitConfig::fusion_stitching()).unwrap();
        assert_eq!(spec.class, KernelClass::MemoryIntensive);
        assert!(spec.bytes_read > 0 && spec.bytes_written > 0);
        assert_eq!(spec.name, "fusion.0");
    }

    #[test]
    fn pseudocode_mentions_schemes() {
        let (g, p) = ln();
        let device = DeviceSpec::v100();
        let (_s, tuned) =
            emit_kernel(&g, &p, "fusion.0", &device, &EmitConfig::fusion_stitching()).unwrap();
        let code = pseudocode(&g, &p, &tuned);
        assert!(code.contains("__global__"));
        assert!(code.contains("reduce_sum"));
        assert!(
            code.contains("__shfl_sync") || code.contains("smem"),
            "reuse scheme should appear:\n{code}"
        );
    }

    #[test]
    fn library_call_flops_scale() {
        let mut g = Graph::new("mm");
        let a = g.param(Shape::new(vec![4096, 768]), DType::F32, "a");
        let b = g.param(Shape::new(vec![768, 768]), DType::F32, "b");
        let c = g.matmul(a, b, "c");
        let k = emit_library_call(&g, c);
        match k.class {
            KernelClass::ComputeIntensive { flops } => {
                assert_eq!(flops, 2 * 4096 * 768 * 768);
            }
            _ => panic!("wrong class"),
        }
    }
}
