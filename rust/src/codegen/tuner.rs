//! Schedule/launch tuning (§4.2): enumerate grouping strategies,
//! sub-root schedules and launch dimensions; score each candidate with
//! the latency-evaluator; keep the best.
//!
//! "FusionStitching enumerates grouping strategies, and emulates
//! schedules of every sub-root/root op and launch dimension of the fused
//! kernel. [...] After estimating the performance of each enumeration
//! with latency-evaluator, FusionStitching selects code generation
//! strategy with the best estimated performance."

use super::grouping::{identify_groups, num_enumerable_expensive, Grouping};
use super::latency::{estimate_kernel, pattern_supported, LatencyEstimate, LaunchSpec};
use super::schedule::SubRootSchedule;
use crate::gpu::{CostParams, DeviceSpec};
use crate::graph::{Graph, NodeId};

/// Tuner configuration. The baselines reuse this module with reuse
/// disabled, so XLA-style kernels are costed by the same machinery.
#[derive(Debug, Clone)]
pub struct TunerOptions {
    /// Allow warp/block reuse schedules (FusionStitching). When false,
    /// only thread composition is enumerated (XLA's code generator).
    pub allow_reuse: bool,
    /// Fixed per-thread index-computation overhead in instruction
    /// equivalents. FusionStitching's §4.5 computation-reuse pass (index
    /// CSE across schedules) halves it relative to the baselines.
    pub index_overhead: f64,
    /// Enumerate expensive-op sub-root choices exhaustively up to this
    /// many expensive ops (2^k growth); beyond it, try all-on/all-off.
    pub max_expensive_enum: usize,
    /// Enumerate per-sub-root schedules exhaustively up to this many
    /// internal sub-roots (3^m growth); beyond it, try uniform choices.
    pub max_schedule_enum: usize,
    /// Cost constants the latency-evaluator scores candidates with
    /// (CPI, shuffle/shared-memory instruction costs, bandwidth knee,
    /// calibrated corrections).
    pub cost: CostParams,
}

impl TunerOptions {
    /// FusionStitching's code generator.
    pub fn fusion_stitching() -> Self {
        Self::fusion_stitching_with(CostParams::default())
    }

    /// FusionStitching's code generator under explicit (e.g. calibrated)
    /// cost parameters.
    pub fn fusion_stitching_with(cost: CostParams) -> Self {
        TunerOptions {
            allow_reuse: true,
            index_overhead: 6.0,
            max_expensive_enum: 3,
            max_schedule_enum: 4,
            cost,
        }
    }

    /// XLA's code generator: thread composition only, no index CSE
    /// across schedules. Always costed with the default constants — the
    /// fallback must stay bit-stable under calibration.
    pub fn xla() -> Self {
        TunerOptions {
            allow_reuse: false,
            index_overhead: 12.0,
            max_expensive_enum: 0,
            max_schedule_enum: 0,
            cost: CostParams::default(),
        }
    }
}

/// The chosen code-generation strategy for one pattern.
#[derive(Debug, Clone)]
pub struct TunedKernel {
    pub estimate: LatencyEstimate,
    pub grouping: Grouping,
    pub schedules: Vec<SubRootSchedule>,
    pub launch: LaunchSpec,
}

impl TunedKernel {
    /// Human-readable one-liner: groups, schedules and launch shape —
    /// used by the CLI `inspect` output and the benches.
    pub fn summary(&self) -> String {
        let scheds: Vec<&str> = self
            .schedules
            .iter()
            .map(|s| match s {
                SubRootSchedule::ThreadLocal => "thread",
                SubRootSchedule::WarpReuse => "warp",
                SubRootSchedule::BlockReuse => "block",
            })
            .collect();
        format!(
            "{} groups [{}] @ {} thr/blk x {} rows/blk",
            self.grouping.groups.len(),
            scheds.join(","),
            self.launch.block_threads,
            self.launch.rows_per_block
        )
    }
}

/// Tune one fusion pattern. Returns `None` if the pattern cannot be
/// scheduled at all (unsupported structure or no valid candidate).
pub fn tune_pattern(
    graph: &Graph,
    pattern: &[NodeId],
    device: &DeviceSpec,
    opts: &TunerOptions,
) -> Option<TunedKernel> {
    if pattern.is_empty() || !pattern_supported(graph, pattern) {
        return None;
    }
    // One membership bitset for the whole enumeration below (it can
    // reach hundreds of estimate_kernel calls per pattern).
    let member = super::latency::pattern_membership(graph, pattern);

    let n_exp = num_enumerable_expensive(graph, pattern);
    let masks: Vec<Vec<bool>> = if !opts.allow_reuse {
        vec![vec![false; n_exp]]
    } else if n_exp <= opts.max_expensive_enum {
        (0..(1usize << n_exp))
            .map(|m| (0..n_exp).map(|b| (m >> b) & 1 == 1).collect())
            .collect()
    } else {
        vec![vec![false; n_exp], vec![true; n_exp]]
    };

    let mut best: Option<TunedKernel> = None;
    for mask in &masks {
        let grouping = identify_groups(graph, pattern, mask);
        let internal: Vec<usize> = grouping
            .groups
            .iter()
            .enumerate()
            .filter(|(_, g)| !g.is_root)
            .map(|(i, _)| i)
            .collect();
        let m = internal.len();

        let schedule_sets: Vec<Vec<SubRootSchedule>> = if !opts.allow_reuse || m == 0 {
            vec![vec![SubRootSchedule::ThreadLocal; grouping.groups.len()]]
        } else if m <= opts.max_schedule_enum {
            // Exhaustive 3^m over internal sub-roots.
            let mut sets = Vec::with_capacity(3usize.pow(m as u32));
            let all = SubRootSchedule::all();
            let mut counters = vec![0usize; m];
            loop {
                let mut s = vec![SubRootSchedule::ThreadLocal; grouping.groups.len()];
                for (slot, &gi) in counters.iter().zip(&internal) {
                    s[gi] = all[*slot];
                }
                sets.push(s);
                // Increment odometer.
                let mut k = 0;
                loop {
                    if k == m {
                        break;
                    }
                    counters[k] += 1;
                    if counters[k] < 3 {
                        break;
                    }
                    counters[k] = 0;
                    k += 1;
                }
                if k == m {
                    break;
                }
            }
            sets
        } else {
            // Uniform heuristics for very large patterns.
            SubRootSchedule::all()
                .iter()
                .map(|&s| {
                    let mut v = vec![SubRootSchedule::ThreadLocal; grouping.groups.len()];
                    for &gi in &internal {
                        v[gi] = s;
                    }
                    v
                })
                .collect()
        };

        for schedules in &schedule_sets {
            for launch in LaunchSpec::candidates() {
                if let Some(est) = estimate_kernel(
                    graph,
                    pattern,
                    &grouping,
                    schedules,
                    launch,
                    device,
                    opts.index_overhead,
                    &opts.cost,
                    &member,
                ) {
                    let better = best
                        .as_ref()
                        .map(|b| est.time_us < b.estimate.time_us)
                        .unwrap_or(true);
                    if better {
                        best = Some(TunedKernel {
                            estimate: est,
                            grouping: grouping.clone(),
                            schedules: schedules.clone(),
                            launch,
                        });
                    }
                }
            }
        }
    }
    best
}

/// Re-tune an already-explored fusion plan for a (possibly different)
/// device *or shape*: run only the §4.2 schedule/launch-dimension tuner
/// over each kernel the plan launches, skipping exploration entirely —
/// the codegen-level plan-portability entry point, giving the caller
/// every [`TunedKernel`] (launch dims, schedules, estimates) on the new
/// target. Because a plan stores node *ids*, it applies to any graph
/// sharing the source graph's structure: pass the same graph with a new
/// `device` to port across device classes, or a sibling-shape graph
/// (same builder, different batch/seq) with the same device to port
/// across shapes — either way every kernel's shared-memory and
/// occupancy feasibility is re-checked by the latency evaluator through
/// [`DeviceSpec::occupancy`] at the target's shapes. The fleet's
/// program-level variants are [`crate::pipeline::port_program`] and
/// [`crate::pipeline::reshape_program`], which fold this tuning into
/// lowering so each kernel is tuned once. Returns `None` when any
/// pattern fails to schedule on the target (the caller falls back to a
/// full re-exploration) or when a pattern's node ids do not exist on
/// `graph` (a foreign plan — shape-porting only makes sense between
/// structure siblings).
pub fn retune_plan(
    graph: &Graph,
    plan: &crate::explorer::FusionPlan,
    device: &DeviceSpec,
    opts: &TunerOptions,
) -> Option<Vec<TunedKernel>> {
    let foreign = plan
        .patterns
        .iter()
        .any(|p| p.nodes().iter().any(|n| n.idx() >= graph.len()));
    if foreign {
        return None;
    }
    plan.kernels(graph)
        .iter()
        .map(|p| tune_pattern(graph, p.nodes(), device, opts))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, OpKind, Shape};
    use crate::workloads::blocks;

    fn ln_pattern() -> (Graph, Vec<NodeId>) {
        let mut g = Graph::new("ln");
        let x = g.param(Shape::new(vec![4096, 768]), DType::F32, "x");
        let _ = blocks::layer_norm(&mut g, x, "ln");
        let pattern: Vec<NodeId> = g
            .nodes()
            .iter()
            .filter(|n| n.kind.is_fusible())
            .map(|n| n.id)
            .collect();
        (g, pattern)
    }

    #[test]
    fn fusion_stitching_tunes_whole_layernorm() {
        let (g, pattern) = ln_pattern();
        let device = DeviceSpec::v100();
        let tuned = tune_pattern(&g, &pattern, &device, &TunerOptions::fusion_stitching())
            .expect("LN should be schedulable");
        // The winning config must use reuse for the mid-pattern
        // reductions — thread-local recompute is orders slower.
        let uses_reuse = tuned
            .schedules
            .iter()
            .any(|s| *s != SubRootSchedule::ThreadLocal);
        assert!(uses_reuse, "schedules: {:?}", tuned.schedules);
        assert!(tuned.estimate.time_us < 1000.0);
    }

    #[test]
    fn xla_options_never_produce_reuse() {
        let (g, pattern) = ln_pattern();
        let device = DeviceSpec::v100();
        let tuned = tune_pattern(&g, &pattern, &device, &TunerOptions::xla()).unwrap();
        assert!(tuned
            .schedules
            .iter()
            .all(|s| *s == SubRootSchedule::ThreadLocal));
        // And it is much slower than FS on the same pattern — the Fig. 1
        // argument for why XLA must split LN instead.
        let fs = tune_pattern(&g, &pattern, &device, &TunerOptions::fusion_stitching()).unwrap();
        assert!(fs.estimate.time_us * 2.0 < tuned.estimate.time_us);
    }

    #[test]
    fn single_op_pattern_tunes() {
        let mut g = Graph::new("one");
        let x = g.param(Shape::new(vec![1024, 1024]), DType::F32, "x");
        let y = g.unary(OpKind::Relu, x, "y");
        let device = DeviceSpec::v100();
        let tuned = tune_pattern(&g, &[y], &device, &TunerOptions::xla()).unwrap();
        assert_eq!(tuned.grouping.groups.len(), 1);
        assert!(tuned.estimate.time_us >= device.kernel_floor_us);
    }

    #[test]
    fn gemm_pattern_is_rejected() {
        let mut g = Graph::new("mm");
        let a = g.param(Shape::new(vec![64, 64]), DType::F32, "a");
        let b = g.param(Shape::new(vec![64, 64]), DType::F32, "b");
        let c = g.matmul(a, b, "c");
        let device = DeviceSpec::v100();
        assert!(tune_pattern(&g, &[c], &device, &TunerOptions::fusion_stitching()).is_none());
    }

    #[test]
    fn empty_pattern_rejected() {
        let g = Graph::new("e");
        let device = DeviceSpec::v100();
        assert!(tune_pattern(&g, &[], &device, &TunerOptions::xla()).is_none());
    }

    #[test]
    fn retune_plan_ports_across_shapes() {
        // Explore layer-norm at 4096 rows, then re-tune the same plan
        // against sibling graphs at other row counts (same structure,
        // same device): every kernel must re-schedule, with feasibility
        // re-checked at the new shape — no re-exploration.
        let ln_rows = |rows: usize| {
            let mut g = Graph::new("ln");
            let x = g.param(Shape::new(vec![rows, 768]), DType::F32, "x");
            let _ = blocks::layer_norm(&mut g, x, "ln");
            g
        };
        let device = DeviceSpec::v100();
        let explore_opts = crate::explorer::ExploreOptions::default();
        let big = ln_rows(4096);
        let plan = crate::explorer::explore(&big, &device, &explore_opts);
        let opts = TunerOptions::fusion_stitching();
        let at_big = retune_plan(&big, &plan, &device, &opts).expect("tunes at 4096");
        let small = ln_rows(1024);
        let at_small = retune_plan(&small, &plan, &device, &opts).expect("tunes at 1024");
        assert_eq!(at_big.len(), at_small.len());
        // A quarter of the rows is strictly less work on the same
        // device: the retuned estimate must not get slower.
        let sum = |ks: &[TunedKernel]| ks.iter().map(|k| k.estimate.time_us).sum::<f64>();
        assert!(sum(&at_small) <= sum(&at_big), "{} vs {}", sum(&at_small), sum(&at_big));
    }

    #[test]
    fn retune_plan_rejects_foreign_plans() {
        // A plan whose node ids point past the target graph is not a
        // structure sibling (hash-collision defense): refuse to retune.
        let (g, _) = ln_pattern();
        let device = DeviceSpec::v100();
        let explore_opts = crate::explorer::ExploreOptions::default();
        let plan = crate::explorer::explore(&g, &device, &explore_opts);
        let mut tiny = Graph::new("tiny");
        let _ = tiny.param(Shape::new(vec![8]), DType::F32, "p");
        assert!(retune_plan(&tiny, &plan, &device, &TunerOptions::fusion_stitching()).is_none());
    }

    #[test]
    fn retune_plan_ports_across_devices() {
        // Explore once on V100, then re-tune the plan for T4: every
        // kernel schedules, and the chosen launch configs adapt to the
        // smaller device without re-running the explorer.
        let (g, _) = ln_pattern();
        let v100 = DeviceSpec::v100();
        let explore_opts = crate::explorer::ExploreOptions::default();
        let plan = crate::explorer::explore(&g, &v100, &explore_opts);
        let opts = TunerOptions::fusion_stitching();
        let on_v100 = retune_plan(&g, &plan, &v100, &opts).expect("tunes on V100");
        let on_t4 = retune_plan(&g, &plan, &DeviceSpec::t4(), &opts).expect("tunes on T4");
        assert_eq!(on_v100.len(), on_t4.len());
        assert_eq!(on_v100.len(), plan.kernels(&g).len());
        // T4 has less bandwidth: the same fused work cannot be faster.
        let sum = |ks: &[TunedKernel]| ks.iter().map(|k| k.estimate.time_us).sum::<f64>();
        assert!(sum(&on_t4) >= sum(&on_v100), "{} vs {}", sum(&on_t4), sum(&on_v100));
    }
}
