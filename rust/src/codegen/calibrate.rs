//! Online cost-model calibration: close the predicted-vs-measured loop.
//!
//! The delta evaluator (§5.4) and the latency evaluator (§4.3) predict
//! kernel times from an analytic machine model; the `gpu::simulator` is
//! this repo's ground truth for what a served iteration actually costs
//! (device time *plus* the host runtime's dispatch charges, library
//! efficiency shortfall and memcpy floors — none of which the analytic
//! model sees). Left uncorrected, that gap is exactly the drift the
//! earlier FusionStitching paper's cost-model search and Neptune's
//! measured feedback warn about: the explorer optimizes a number that
//! is systematically wrong.
//!
//! This module records `(modeled, measured)` pairs **per kernel** as
//! the fleet serves, fits a per-device-class affine correction with a
//! simple robust regression (Theil–Sen: median of pairwise slopes,
//! median residual intercept — outlier-safe and deterministic), and
//! exposes the result as corrected [`CostParams`]:
//!
//! * `time_scale`     ← fitted slope (model under/over-estimates device
//!   time multiplicatively, e.g. the library-efficiency shortfall),
//! * `launch_overhead_us` ← fitted intercept (the *real* per-kernel
//!   dispatch charge, replacing the hard-coded 7.0),
//! * `iter_overhead_us`   ← median per-iteration residual at graph
//!   level (the host base cost no per-kernel term can capture).
//!
//! The fitted line is kept only when it shrinks the median
//! |predicted − measured| relative error on the recorded samples —
//! calibration can never make predictions worse than the defaults
//! (the drift-gate the fleet bench asserts). Everything is pure and
//! insertion-ordered (`BTreeMap`, first-N sample caps), so replaying a
//! trace refits byte-identical parameters — the determinism the
//! fleet's executor-equivalence invariant needs.

use crate::gpu::{CostParams, DeviceSpec, KernelClass, KernelSpec, SimConfig, Simulator};
use crate::pipeline::OptimizedProgram;
use crate::util::median;
use crate::workloads::LoopKind;
use std::collections::BTreeMap;

/// One (cost model, ground truth) observation for a single kernel, µs.
#[derive(Debug, Clone, Copy)]
pub struct KernelSample {
    /// Analytic device time under the *default* structural constants
    /// (the regression's x).
    pub modeled_us: f64,
    /// Simulator device time plus the host runtime's per-kernel
    /// dispatch charge (the regression's y).
    pub measured_us: f64,
    /// Shared-memory request as a fraction of the per-block cap
    /// ([`super::shmem::block_cap`]) — the regressor for the
    /// footprint→occupancy interaction: kernels crowding the cap run at
    /// depressed occupancy in ways the affine (a, b) map cannot express.
    pub footprint_frac: f64,
}

/// One whole-program observation (for the per-iteration residual).
#[derive(Debug, Clone, Copy)]
struct GraphSample {
    /// Σ modeled kernel device time, µs.
    modeled_us: f64,
    /// Kernel count of the program.
    kernels: usize,
    /// Simulator end-to-end iteration time, ms.
    measured_ms: f64,
}

/// Analytic device time of one kernel under `params` — the quantity the
/// explorer optimizes: no host runtime, no library-efficiency
/// shortfall, no memcpy floor. Memory-intensive kernels go through the
/// latency-evaluator's own Eq. 1 tail
/// ([`crate::codegen::latency::device_time_us`]), so the calibrator
/// measures drift against exactly the model it corrects.
pub fn model_kernel_us(spec: &DeviceSpec, k: &KernelSpec, params: &CostParams) -> f64 {
    // `time_scale` applies to every class: the fitted slope is one
    // correction over the whole kernel population (the regression's
    // x values span all classes), so the predictor must charge it
    // uniformly or the drift trigger would be biased on programs whose
    // library/memcpy share differs from the fitted mix.
    match k.class {
        KernelClass::Memcpy => {
            k.bytes_read as f64 / (spec.hbm_gbps * 1e3) * params.time_scale
        }
        KernelClass::ComputeIntensive { flops } => {
            flops as f64 / (spec.fp32_tflops * 1e6) * params.time_scale
        }
        KernelClass::MemoryIntensive => {
            let occ = spec.occupancy(k.launch.block_threads, k.regs_per_thread, k.shmem_per_block);
            if occ == 0.0 {
                return 1e12; // unlaunchable — poisoned like the simulator
            }
            let (time_us, _cycles) = super::latency::device_time_us(
                spec,
                params,
                k.launch,
                occ,
                k.instrs_per_thread,
                k.total_bytes(),
            );
            time_us
        }
    }
}

/// Ground-truth per-kernel cost: simulator device time plus the XLA
/// runtime's per-kernel host charge (the per-iteration base is captured
/// separately as `iter_overhead_us`). The charge comes from the
/// simulator's own accounting ([`SimConfig::host_charge_us`]), so the
/// calibrator fits against exactly what `Breakdown` measures.
fn measured_kernel_us(sim: &Simulator, k: &KernelSpec, loop_kind: LoopKind) -> f64 {
    sim.kernel_time_us(k) + sim.config.host_charge_us(&k.class, loop_kind)
}

/// Model-predicted iteration time (ms) of a whole program under
/// `params`: per-kernel analytic time plus the per-launch overhead,
/// plus the calibrated per-iteration base.
pub fn predict_iter_ms(spec: &DeviceSpec, prog: &OptimizedProgram, params: &CostParams) -> f64 {
    let cap = super::shmem::block_cap(spec);
    let kernel_us: f64 = prog
        .kernels
        .iter()
        .map(|k| {
            model_kernel_us(spec, k, params)
                + params.launch_overhead_us
                + params.footprint_pressure_charge_us(k.shmem_per_block, cap)
        })
        .sum();
    (kernel_us + params.iter_overhead_us) / 1e3
}

/// Judge one measured-vs-predicted observation against a symmetric
/// drift bound: returns the measured/predicted ratio and whether it
/// falls outside `[1/bound, bound]` (the re-exploration trigger).
/// Bounds below 1.0 are clamped to 1.0 so the interval is never empty.
pub fn drift_verdict(measured_ms: f64, predicted_ms: f64, bound: f64) -> (f64, bool) {
    let ratio = measured_ms / predicted_ms.max(1e-12);
    let bound = bound.max(1.0);
    (ratio, ratio > bound || ratio * bound < 1.0)
}

/// Per-kernel calibration samples of one published program (x under the
/// default structural constants, y from the simulator + host charges).
/// Unlaunchable kernels (poisoned model time) are excluded.
pub fn program_samples(
    spec: &DeviceSpec,
    prog: &OptimizedProgram,
    loop_kind: LoopKind,
) -> Vec<KernelSample> {
    let base = CostParams::default();
    let sim = Simulator::new(spec.clone(), SimConfig::xla_runtime());
    let cap = super::shmem::block_cap(spec) as f64;
    prog.kernels
        .iter()
        .map(|k| KernelSample {
            modeled_us: model_kernel_us(spec, k, &base),
            measured_us: measured_kernel_us(&sim, k, loop_kind),
            footprint_frac: k.shmem_per_block as f64 / cap.max(1.0),
        })
        .filter(|s| s.modeled_us < 1e11)
        .collect()
}

/// Median |a + b·x + fp·max(0, frac − knee) − y| / y over the samples:
/// the calibration error functional. The footprint surcharge mirrors
/// [`CostParams::footprint_pressure_charge_us`] so the no-worse gate,
/// the fitted pressure term and [`Calibrator::drift`] all judge the
/// same prediction.
fn median_abs_rel_err(
    samples: &[KernelSample],
    intercept: f64,
    slope: f64,
    pressure: f64,
    knee: f64,
) -> f64 {
    let errs: Vec<f64> = samples
        .iter()
        .map(|s| {
            let fp = pressure * (s.footprint_frac - knee).max(0.0);
            (intercept + slope * s.modeled_us + fp - s.measured_us).abs() / s.measured_us.max(1e-9)
        })
        .collect();
    median(&errs)
}

/// Theil–Sen estimator: slope = median of pairwise slopes, intercept =
/// median residual. Robust to the outliers a mixed kernel population
/// produces (floored memcpys, library calls). Samples beyond 256 are
/// thinned by a deterministic stride so the pair enumeration stays
/// bounded.
fn theil_sen(samples: &[KernelSample]) -> (f64, f64) {
    const FIT_CAP: usize = 256;
    let n = samples.len();
    let pick: Vec<KernelSample> = if n > FIT_CAP {
        (0..FIT_CAP).map(|i| samples[i * n / FIT_CAP]).collect()
    } else {
        samples.to_vec()
    };
    let mut slopes = Vec::new();
    for i in 0..pick.len() {
        for j in (i + 1)..pick.len() {
            let dx = pick[j].modeled_us - pick[i].modeled_us;
            if dx.abs() > 1e-9 {
                slopes.push((pick[j].measured_us - pick[i].measured_us) / dx);
            }
        }
    }
    let slope = if slopes.is_empty() { 1.0 } else { median(&slopes) };
    let residuals: Vec<f64> = pick.iter().map(|s| s.measured_us - slope * s.modeled_us).collect();
    (median(&residuals), slope)
}

/// Aggregate drift numbers for reporting: sample-count-weighted average
/// of the per-class median |predicted − measured| relative errors,
/// under the default constants (`before`) and the fitted ones
/// (`after`). The per-class fit keeps the default whenever fitting
/// would not help, so `after <= before` holds by construction.
#[derive(Debug, Clone, Copy, Default)]
pub struct DriftSummary {
    pub samples: usize,
    pub before: f64,
    pub after: f64,
}

#[derive(Debug)]
struct ClassState {
    kernels: Vec<KernelSample>,
    graphs: Vec<GraphSample>,
    params: CostParams,
    fitted: bool,
}

impl Default for ClassState {
    fn default() -> Self {
        ClassState {
            kernels: Vec::new(),
            graphs: Vec::new(),
            params: CostParams::default(),
            fitted: false,
        }
    }
}

/// Per-device-class calibration state: records samples in measurement
/// order, refits on every record, hands out the current best
/// [`CostParams`]. Deterministic: `BTreeMap` keying, first-N caps, no
/// wall-clock anywhere.
#[derive(Debug)]
pub struct Calibrator {
    /// Kernel samples required before a class is fitted at all.
    min_samples: usize,
    /// First-N cap on retained kernel samples per class.
    max_samples: usize,
    classes: BTreeMap<&'static str, ClassState>,
}

impl Calibrator {
    pub fn new(min_samples: usize, max_samples: usize) -> Self {
        Calibrator { min_samples: min_samples.max(2), max_samples, classes: BTreeMap::new() }
    }

    /// Record one published program's observations for `class` and
    /// refit. `samples` are its per-kernel pairs ([`program_samples`]);
    /// `measured_iter_ms` the simulator's end-to-end iteration time.
    pub fn record(
        &mut self,
        class: &'static str,
        samples: Vec<KernelSample>,
        measured_iter_ms: f64,
    ) {
        let state = self.classes.entry(class).or_default();
        if !samples.is_empty() && state.graphs.len() < self.max_samples {
            state.graphs.push(GraphSample {
                modeled_us: samples.iter().map(|s| s.modeled_us).sum(),
                kernels: samples.len(),
                measured_ms: measured_iter_ms,
            });
        }
        let room = self.max_samples.saturating_sub(state.kernels.len());
        state.kernels.extend(samples.into_iter().take(room));
        Self::refit(state, self.min_samples);
    }

    fn refit(state: &mut ClassState, min_samples: usize) {
        if state.kernels.len() < min_samples {
            return;
        }
        let base = CostParams::default();
        let knee = base.footprint_knee;
        let (a_fit, b_fit) = theil_sen(&state.kernels);
        let (a_fit, b_fit) = (a_fit.clamp(0.5, 60.0), b_fit.clamp(0.25, 4.0));
        // Footprint→occupancy interaction: fit the per-excess-fraction
        // surcharge from the above-knee residuals of the affine fit
        // (median residual per unit of cap excess — Theil–Sen-flavored
        // and deterministic like the rest of the fit).
        let hot: Vec<&KernelSample> =
            state.kernels.iter().filter(|s| s.footprint_frac > knee).collect();
        let fp_fit = if hot.is_empty() {
            base.footprint_pressure_us
        } else {
            let per_excess: Vec<f64> = hot
                .iter()
                .map(|s| {
                    (s.measured_us - (a_fit + b_fit * s.modeled_us)) / (s.footprint_frac - knee)
                })
                .collect();
            median(&per_excess).clamp(0.0, 64.0)
        };
        // Keep a fit only when it beats the defaults on the very samples
        // it was fitted from — the no-worse drift gate. The fitted
        // pressure term additionally has to beat the default pressure
        // under the same (a, b), or it is discarded on its own.
        let def_err = median_abs_rel_err(
            &state.kernels,
            base.launch_overhead_us,
            1.0,
            base.footprint_pressure_us,
            knee,
        );
        let fit_err = median_abs_rel_err(&state.kernels, a_fit, b_fit, fp_fit, knee);
        let fit_err_base_fp =
            median_abs_rel_err(&state.kernels, a_fit, b_fit, base.footprint_pressure_us, knee);
        let (a, b, fp) = if fit_err <= def_err && fit_err <= fit_err_base_fp {
            (a_fit, b_fit, fp_fit)
        } else if fit_err_base_fp <= def_err {
            (a_fit, b_fit, base.footprint_pressure_us)
        } else {
            (base.launch_overhead_us, 1.0, base.footprint_pressure_us)
        };
        let mut p = CostParams {
            launch_overhead_us: a,
            time_scale: b,
            footprint_pressure_us: fp,
            ..base
        };
        if !state.graphs.is_empty() {
            let residuals: Vec<f64> = state
                .graphs
                .iter()
                .map(|g| g.measured_ms * 1e3 - (b * g.modeled_us + g.kernels as f64 * a))
                .collect();
            p.iter_overhead_us = median(&residuals).max(0.0);
        }
        state.params = p;
        state.fitted = true;
    }

    /// Current best parameters for a device class (defaults until the
    /// class accumulates `min_samples` kernel pairs).
    pub fn params_for(&self, class: &str) -> CostParams {
        self.classes.get(class).map(|s| s.params).unwrap_or_default()
    }

    /// True once `class` has a fitted correction.
    pub fn is_fitted(&self, class: &str) -> bool {
        self.classes.get(class).map(|s| s.fitted).unwrap_or(false)
    }

    /// Total kernel samples recorded across classes.
    pub fn samples(&self) -> usize {
        self.classes.values().map(|s| s.kernels.len()).sum()
    }

    /// Fleet-wide drift before/after calibration (see [`DriftSummary`]).
    pub fn drift(&self) -> DriftSummary {
        let mut total = 0usize;
        let (mut before, mut after) = (0.0f64, 0.0f64);
        for state in self.classes.values() {
            if state.kernels.is_empty() {
                continue;
            }
            let n = state.kernels.len();
            let base = CostParams::default();
            let b = median_abs_rel_err(
                &state.kernels,
                base.launch_overhead_us,
                1.0,
                base.footprint_pressure_us,
                base.footprint_knee,
            );
            let a = if state.fitted {
                median_abs_rel_err(
                    &state.kernels,
                    state.params.launch_overhead_us,
                    state.params.time_scale,
                    state.params.footprint_pressure_us,
                    state.params.footprint_knee,
                )
            } else {
                b
            };
            total += n;
            before += n as f64 * b;
            after += n as f64 * a;
        }
        if total == 0 {
            return DriftSummary::default();
        }
        DriftSummary {
            samples: total,
            before: before / total as f64,
            after: after / total as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explorer::ExploreOptions;
    use crate::pipeline::{self, Tech};
    use crate::util::Prng;
    use crate::workloads::synthetic::{generate, SyntheticConfig};
    use crate::workloads::{Mode, Workload};

    #[test]
    fn theil_sen_recovers_affine_map_despite_outliers() {
        let mut samples: Vec<KernelSample> = (1..=40)
            .map(|i| {
                let x = i as f64;
                KernelSample { modeled_us: x, measured_us: 3.0 + 1.5 * x, footprint_frac: 0.0 }
            })
            .collect();
        // A few wild outliers must not move the medians.
        samples.push(KernelSample { modeled_us: 10.0, measured_us: 500.0, footprint_frac: 0.0 });
        samples.push(KernelSample { modeled_us: 20.0, measured_us: 0.1, footprint_frac: 0.0 });
        let (a, b) = theil_sen(&samples);
        assert!((b - 1.5).abs() < 0.05, "slope {b}");
        assert!((a - 3.0).abs() < 0.5, "intercept {a}");
    }

    /// The footprint→occupancy interaction fit: a kernel population
    /// whose ground truth carries a surcharge proportional to how far
    /// the shmem request crowds past the knee must come back with the
    /// surcharge in `footprint_pressure_us` — and the affine part of
    /// the fit must not be polluted by it.
    #[test]
    fn calibration_learns_footprint_pressure_from_hot_residuals() {
        let base = CostParams::default();
        let knee = base.footprint_knee;
        // 30 cool samples on y = 2 + x, then 10 hot ones (frac = 1.0)
        // carrying a 20 µs/excess-fraction surcharge: +20·(1.0 − knee).
        let mut samples: Vec<KernelSample> = (1..=30)
            .map(|i| {
                let x = i as f64;
                KernelSample { modeled_us: x, measured_us: 2.0 + x, footprint_frac: 0.2 }
            })
            .collect();
        samples.extend((31..=40).map(|i| {
            let x = i as f64;
            KernelSample {
                modeled_us: x,
                measured_us: 2.0 + x + 20.0 * (1.0 - knee),
                footprint_frac: 1.0,
            }
        }));
        let mut cal = Calibrator::new(8, 4096);
        cal.record("V100", samples, 0.0);
        assert!(cal.is_fitted("V100"));
        let p = cal.params_for("V100");
        assert!((p.time_scale - 1.0).abs() < 0.05, "slope {}", p.time_scale);
        assert!((p.launch_overhead_us - 2.0).abs() < 0.5, "intercept {}", p.launch_overhead_us);
        assert!(
            (p.footprint_pressure_us - 20.0).abs() < 1.0,
            "pressure {}",
            p.footprint_pressure_us
        );
        let d = cal.drift();
        assert!(d.after < d.before, "pressure fit must shrink error: {d:?}");
    }

    #[test]
    fn drift_verdict_is_symmetric_and_clamps_bound() {
        // Inside the band: no drift either direction.
        assert!(!drift_verdict(1.4, 1.0, 1.5).1);
        assert!(!drift_verdict(0.7, 1.0, 1.5).1);
        // Outside the band: both slow and fast drifts trigger.
        let (ratio, drifted) = drift_verdict(2.0, 1.0, 1.5);
        assert!(drifted && (ratio - 2.0).abs() < 1e-12);
        assert!(drift_verdict(0.5, 1.0, 1.5).1);
        // A degenerate bound (< 1.0) clamps to 1.0 rather than
        // flagging every exact match.
        assert!(!drift_verdict(1.0, 1.0, 0.2).1);
        // Zero prediction must not divide by zero.
        assert!(drift_verdict(1.0, 0.0, 1.5).0.is_finite());
    }

    #[test]
    fn unfitted_class_serves_defaults() {
        let cal = Calibrator::new(8, 1024);
        assert_eq!(cal.params_for("V100"), CostParams::default());
        assert!(!cal.is_fitted("V100"));
        assert_eq!(cal.drift().samples, 0);
    }

    /// The satellite acceptance test: on a seeded workload mix, the
    /// fitted per-class `CostParams` must shrink the median
    /// |predicted − measured| kernel-time error versus the hard-coded
    /// defaults.
    #[test]
    fn fitted_params_shrink_median_error_on_seeded_mix() {
        let spec = crate::gpu::DeviceSpec::v100();
        let mut prng = Prng::new(0xCA11B);
        let mut cal = Calibrator::new(8, 4096);
        for i in 0..5 {
            let cfg = SyntheticConfig { num_ops: 30 + i * 8, ..Default::default() };
            let graph = generate(&cfg, &mut prng);
            let w = Workload {
                name: "mix",
                field: "calibrate",
                mode: Mode::Infer,
                batch: 1,
                loop_kind: LoopKind::None,
                graph,
            };
            let prog = pipeline::optimize(&w, &spec, Tech::Fs, &ExploreOptions::default());
            let measured = Simulator::new(spec.clone(), SimConfig::xla_runtime())
                .run(&prog.kernels, w.loop_kind)
                .e2e_ms();
            let samples = program_samples(&spec, &prog, w.loop_kind);
            cal.record(spec.name, samples, measured);
        }
        assert!(cal.is_fitted("V100"));
        let d = cal.drift();
        assert!(d.samples >= 8, "samples {}", d.samples);
        assert!(d.before > 0.0, "defaults must show drift: {d:?}");
        assert!(d.after < d.before, "calibration must shrink error: {d:?}");
        // The fitted per-kernel overhead should land near the runtime's
        // real dispatch charge (4.5 µs), not the hard-coded 7.0.
        let p = cal.params_for("V100");
        assert!(
            (1.0..7.0).contains(&p.launch_overhead_us),
            "launch_overhead {}",
            p.launch_overhead_us
        );
    }

    #[test]
    fn predicted_iteration_time_tracks_measured_after_fit() {
        // After fitting (incl. the per-iteration residual), whole-graph
        // predictions must sit within the fleet's default drift bound of
        // the simulator ground truth — the condition that stops the
        // re-exploration trigger from firing forever.
        let spec = crate::gpu::DeviceSpec::v100();
        let mut prng = Prng::new(0xD1F7);
        let mut cal = Calibrator::new(8, 4096);
        let mut progs = Vec::new();
        for i in 0..4 {
            let cfg = SyntheticConfig { num_ops: 24 + i * 12, ..Default::default() };
            let graph = generate(&cfg, &mut prng);
            let w = Workload {
                name: "mix",
                field: "calibrate",
                mode: Mode::Infer,
                batch: 1,
                loop_kind: LoopKind::None,
                graph,
            };
            let prog = pipeline::optimize(&w, &spec, Tech::Fs, &ExploreOptions::default());
            let measured = Simulator::new(spec.clone(), SimConfig::xla_runtime())
                .run(&prog.kernels, w.loop_kind)
                .e2e_ms();
            cal.record(spec.name, program_samples(&spec, &prog, w.loop_kind), measured);
            progs.push((prog, measured));
        }
        let params = cal.params_for("V100");
        for (prog, measured) in &progs {
            let predicted = predict_iter_ms(&spec, prog, &params);
            let ratio = measured / predicted.max(1e-12);
            assert!(
                (0.6..1.7).contains(&ratio),
                "calibrated ratio {ratio} (predicted {predicted}, measured {measured})"
            );
        }
    }
}
