//! Code generation (§4): lower a fusion pattern to one GPU kernel.
//!
//! The pipeline per pattern:
//!
//! 1. **Grouping** (§4.2, [`grouping`]) — identify *sub-roots*
//!    (reductions always; expensive element-wise ops enumerated both
//!    ways) and partition the pattern into groups, each of which runs one
//!    schedule; schedules of non-sub-roots follow by index propagation.
//! 2. **Schedule & launch tuning** ([`tuner`]) — enumerate the schedule
//!    of every sub-root ({thread-local, warp-reuse, block-reuse} — the
//!    composition schemes of §4.1/Fig. 3), together with launch
//!    dimensions; discard combinations violating data-locality or
//!    resource constraints.
//! 3. **Latency-evaluator** (§4.3, [`latency`]) — estimate cycles for
//!    each candidate (waves × warp latency, occupancy from register
//!    lifetime analysis and shared memory after the §4.4 reuse pass).
//! 4. **Emission** ([`emit`]) — produce the [`crate::gpu::KernelSpec`]
//!    the simulator executes, plus CUDA-like pseudocode for inspection.

pub mod calibrate;
pub mod emit;
pub mod grouping;
pub mod latency;
pub mod schedule;
pub mod shmem;
pub mod tuner;

pub use calibrate::{Calibrator, DriftSummary, KernelSample};
pub use emit::{emit_kernel, emit_library_call, pseudocode, EmitConfig};
pub use grouping::{identify_groups, Group, Grouping};
pub use latency::{estimate_kernel, LatencyEstimate};
pub use schedule::{CompositionScheme, SubRootSchedule};
pub use tuner::{tune_pattern, TunedKernel, TunerOptions};
