//! Composition schemes (§4.1, Fig. 3) and per-sub-root schedules (§4.2).
//!
//! The paper pre-defines schedule *templates* per op kind: a single
//! template for light element-wise ops (kernel packing and thread
//! composition share it), and three templates for expensive element-wise
//! and reduction ops (thread-local / first-lane-register / shared-
//! memory). A schedule choice for every sub-root plus a launch dimension
//! fully determines the generated kernel.

/// The four kernel composition schemes of Fig. 3, plus the anchored
/// cross-GEMM scheme that stitches memory-intensive chains onto a
/// compute-intensive anchor.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CompositionScheme {
    /// Independent ops packed into one launch (no data dependence).
    KernelPacking,
    /// Producer value consumed in-register by the same thread; threads
    /// needing a value produced "elsewhere" recompute it (XLA's scheme).
    ThreadComposition,
    /// Producer value held in the first lane of each warp and moved by
    /// register shuffle (intra-warp reuse).
    WarpComposition,
    /// Producer value staged in shared memory (intra-block reuse) —
    /// unlocks non-homogeneous parallelism in one kernel.
    BlockComposition,
    /// Anchored stitching across the compute boundary: the GEMM/conv
    /// anchor's output tile (or its prologue's input tile) is handed to
    /// the absorbed element-wise/reduce chain through shared memory
    /// instead of an HBM round-trip. One output row per warp at a fixed
    /// 256-thread block; feasible only while the row tile fits the
    /// per-block shared-memory cap ([`crate::codegen::shmem`] staging
    /// helpers) — lowering falls back to the cut plan otherwise.
    GemmEpilogue,
}

impl CompositionScheme {
    /// Short name for reports/pseudocode.
    pub fn name(self) -> &'static str {
        match self {
            CompositionScheme::KernelPacking => "kernel_packing",
            CompositionScheme::ThreadComposition => "thread_composition",
            CompositionScheme::WarpComposition => "warp_composition",
            CompositionScheme::BlockComposition => "block_composition",
            CompositionScheme::GemmEpilogue => "gemm_epilogue",
        }
    }
}

/// Schedule template assigned to one sub-root (§4.2): how its group's
/// output is made available to consumer groups.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SubRootSchedule {
    /// Thread-local registers; consumers outside the thread recompute
    /// (thread composition / kernel packing template).
    ThreadLocal,
    /// Result lives in lane-0 registers of each warp; consumers read it
    /// via `__shfl_sync` (warp composition template).
    WarpReuse,
    /// Result staged to shared memory; consumers read after a barrier
    /// (block composition template).
    BlockReuse,
}

impl SubRootSchedule {
    /// The composition scheme this schedule realizes between the
    /// sub-root's group and its consumer groups.
    pub fn scheme(self) -> CompositionScheme {
        match self {
            SubRootSchedule::ThreadLocal => CompositionScheme::ThreadComposition,
            SubRootSchedule::WarpReuse => CompositionScheme::WarpComposition,
            SubRootSchedule::BlockReuse => CompositionScheme::BlockComposition,
        }
    }

    /// All schedule templates, in enumeration order (cheapest
    /// communication first).
    pub fn all() -> [SubRootSchedule; 3] {
        [
            SubRootSchedule::ThreadLocal,
            SubRootSchedule::WarpReuse,
            SubRootSchedule::BlockReuse,
        ]
    }

    /// Short name for reports/pseudocode.
    pub fn name(self) -> &'static str {
        match self {
            SubRootSchedule::ThreadLocal => "thread_local",
            SubRootSchedule::WarpReuse => "warp_reuse",
            SubRootSchedule::BlockReuse => "block_reuse",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_to_scheme_mapping() {
        assert_eq!(
            SubRootSchedule::ThreadLocal.scheme(),
            CompositionScheme::ThreadComposition
        );
        assert_eq!(
            SubRootSchedule::WarpReuse.scheme(),
            CompositionScheme::WarpComposition
        );
        assert_eq!(
            SubRootSchedule::BlockReuse.scheme(),
            CompositionScheme::BlockComposition
        );
    }

    #[test]
    fn all_lists_three_templates() {
        assert_eq!(SubRootSchedule::all().len(), 3);
        assert_eq!(SubRootSchedule::all()[0], SubRootSchedule::ThreadLocal);
    }

    #[test]
    fn gemm_epilogue_is_not_a_subroot_template() {
        // No SubRootSchedule maps to the anchored scheme: it is chosen
        // by the absorption pass, never by per-sub-root tuning.
        for s in SubRootSchedule::all() {
            assert_ne!(s.scheme(), CompositionScheme::GemmEpilogue);
        }
        assert_eq!(CompositionScheme::GemmEpilogue.name(), "gemm_epilogue");
    }
}
