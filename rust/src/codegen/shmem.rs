//! Shared-memory dataflow sharing (§4.4).
//!
//! Block-composition sub-roots each request a shared-memory staging
//! buffer. Naively summing the requests throttles occupancy, so the
//! paper reuses previously-allocated space whenever dataflow proves two
//! requests' lifetimes cannot overlap: it walks the pattern in
//! topological order and, using a dominance test over the group DAG,
//! lets a later request take over a buffer whose value is already dead
//! (fully consumed along every path reaching the current op).

use crate::graph::{Graph, NodeId};

/// One shared-memory request: `owner` (a block-reuse sub-root) needs
/// `bytes` from its definition until its last in-pattern consumer.
#[derive(Debug, Clone)]
pub struct ShmemRequest {
    pub owner: NodeId,
    pub bytes: usize,
}

/// Result of the allocation pass: per-owner byte offsets and the total
/// block footprint after reuse.
#[derive(Debug, Clone)]
pub struct ShmemAllocation {
    /// (owner, offset, bytes) triples.
    pub slots: Vec<(NodeId, usize, usize)>,
    /// Total shared memory per block after sharing.
    pub total_bytes: usize,
}

/// Allocate shared memory with lifetime-based reuse.
///
/// Lifetime of request r = [def(owner), last consumer of owner within
/// `pattern`] in topological position. Two requests may share space iff
/// their lifetimes do not overlap; we run a simple linear-scan register
/// allocation over the interval list, which is exactly the effect of the
/// paper's dominance-tree walk on series-parallel fusion patterns.
pub fn allocate(graph: &Graph, pattern: &[NodeId], requests: &[ShmemRequest]) -> ShmemAllocation {
    if requests.is_empty() {
        return ShmemAllocation { slots: vec![], total_bytes: 0 };
    }
    // Topological position of each pattern node (pattern ids are already
    // creation-ordered; sort defensively).
    let mut order: Vec<NodeId> = pattern.to_vec();
    order.sort_unstable();
    let pos = |id: NodeId| order.binary_search(&id).unwrap_or(usize::MAX);

    // Build intervals.
    let mut intervals: Vec<(usize, usize, usize)> = Vec::new(); // (start, end, req idx)
    for (i, r) in requests.iter().enumerate() {
        let start = pos(r.owner);
        let end = graph
            .consumers(r.owner)
            .iter()
            .filter(|c| order.binary_search(c).is_ok())
            .map(|&c| pos(c))
            .max()
            .unwrap_or(start);
        intervals.push((start, end, i));
    }
    intervals.sort_by_key(|&(s, ..)| s);

    // Linear scan with a free list of (offset, bytes) holes. We only
    // reuse exact-or-larger holes; fragmentation is acceptable at these
    // request counts (a handful per kernel).
    let mut free: Vec<(usize, usize)> = Vec::new(); // (offset, bytes)
    let mut active: Vec<(usize, usize, usize)> = Vec::new(); // (end, offset, bytes)
    let mut total = 0usize;
    let mut slots = vec![(NodeId(0), 0usize, 0usize); requests.len()];
    for (start, end, ri) in intervals {
        // Expire finished intervals.
        active.retain(|&(aend, off, bytes)| {
            if aend < start {
                free.push((off, bytes));
                false
            } else {
                true
            }
        });
        let need = align(requests[ri].bytes);
        // Find a free hole big enough (best fit).
        let offset = match free
            .iter()
            .enumerate()
            .filter(|(_, &(_, b))| b >= need)
            .min_by_key(|(_, &(_, b))| b)
        {
            Some((fi, &(off, bytes))) => {
                free.swap_remove(fi);
                if bytes > need {
                    free.push((off + need, bytes - need));
                }
                off
            }
            None => {
                let off = total;
                total += need;
                off
            }
        };
        active.push((end, offset, need));
        slots[ri] = (requests[ri].owner, offset, need);
    }
    ShmemAllocation { slots, total_bytes: total }
}

/// Footprint without dataflow sharing: the plain sum of aligned
/// requests (what §4.4 argues *against* — used by the ablation bench
/// to quantify the occupancy the sharing pass buys back).
pub fn naive_total(requests: &[ShmemRequest]) -> usize {
    requests.iter().map(|r| align(r.bytes)).sum()
}

fn align(bytes: usize) -> usize {
    bytes.div_ceil(128) * 128 // 128-byte banks-friendly alignment
}

/// Rows of the boundary tensor the `GemmEpilogue` hand-off stages per
/// block: one row per warp at the scheme's fixed 256-thread block.
pub const EPILOGUE_ROWS_PER_BLOCK: usize = 8;

/// Per-block shared-memory staging of the cross-GEMM hand-off for a
/// boundary tensor of `row_elems` elements per row, `elem_bytes` each:
/// the absorbed chain reads the anchor-side tile from shared memory
/// instead of HBM, so the anchor kernel must hold
/// [`EPILOGUE_ROWS_PER_BLOCK`] rows resident.
pub fn epilogue_staging_bytes(row_elems: usize, elem_bytes: usize) -> usize {
    align(row_elems.max(1) * elem_bytes * EPILOGUE_ROWS_PER_BLOCK)
}

/// Tune-time feasibility of the `GemmEpilogue` hand-off on `device`:
/// the staged tile must respect the per-block shared-memory cap and the
/// combined kernel must still be launchable at the scheme's fixed
/// 256-thread block. When this fails the plan lowers in its cut form.
pub fn epilogue_feasible(device: &crate::gpu::DeviceSpec, staging_bytes: usize) -> bool {
    staging_bytes <= device.shmem_per_block && device.occupancy(256, 32, staging_bytes) > 0.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, Graph, OpKind, Shape};

    /// chain: a -> b -> c -> d, requests on a and c do not overlap
    /// (a dies at b), so they share one slot.
    #[test]
    fn non_overlapping_lifetimes_share_space() {
        let mut g = Graph::new("t");
        let p = g.param(Shape::new(vec![256]), DType::F32, "p");
        let a = g.unary(OpKind::Exp, p, "a");
        let b = g.unary(OpKind::Neg, a, "b");
        let c = g.unary(OpKind::Tanh, b, "c");
        let d = g.unary(OpKind::Abs, c, "d");
        let pattern = vec![a, b, c, d];
        let reqs = vec![
            ShmemRequest { owner: a, bytes: 1024 },
            ShmemRequest { owner: c, bytes: 1024 },
        ];
        let alloc = allocate(&g, &pattern, &reqs);
        assert_eq!(alloc.total_bytes, 1024); // shared, not 2048
        assert_eq!(alloc.slots[0].1, alloc.slots[1].1); // same offset
    }

    /// diamond: a feeds both b and c; a's lifetime spans past b, so the
    /// request on b cannot reuse a's space.
    #[test]
    fn overlapping_lifetimes_get_distinct_space() {
        let mut g = Graph::new("t");
        let p = g.param(Shape::new(vec![256]), DType::F32, "p");
        let a = g.unary(OpKind::Exp, p, "a");
        let b = g.unary(OpKind::Neg, a, "b");
        let c = g.binary(OpKind::Add, a, b, "c");
        let pattern = vec![a, b, c];
        let reqs = vec![
            ShmemRequest { owner: a, bytes: 512 },
            ShmemRequest { owner: b, bytes: 512 },
        ];
        let alloc = allocate(&g, &pattern, &reqs);
        assert_eq!(alloc.total_bytes, 1024);
        assert_ne!(alloc.slots[0].1, alloc.slots[1].1);
    }

    #[test]
    fn empty_requests_zero_footprint() {
        let g = Graph::new("e");
        let alloc = allocate(&g, &[], &[]);
        assert_eq!(alloc.total_bytes, 0);
    }

    #[test]
    fn alignment_rounds_up() {
        let mut g = Graph::new("t");
        let p = g.param(Shape::new(vec![8]), DType::F32, "p");
        let a = g.unary(OpKind::Exp, p, "a");
        let alloc = allocate(
            &g,
            &[a],
            &[ShmemRequest { owner: a, bytes: 100 }],
        );
        assert_eq!(alloc.total_bytes, 128);
    }

    #[test]
    fn epilogue_staging_respects_block_cap() {
        let d = crate::gpu::DeviceSpec::v100();
        // 1024-wide f32 rows: 8 × 4 KB = 32 KB — feasible.
        let ok = epilogue_staging_bytes(1024, 4);
        assert_eq!(ok, 32 * 1024);
        assert!(epilogue_feasible(&d, ok));
        // 2048-wide f32 rows: 64 KB — over the 48 KB per-block cap.
        let too_big = epilogue_staging_bytes(2048, 4);
        assert!(!epilogue_feasible(&d, too_big));
    }

    /// Three sequential requests collapse into one slot; a fourth that
    /// overlaps the third takes a second slot — total is the max
    /// concurrent footprint, not the sum.
    #[test]
    fn total_is_max_concurrent_not_sum() {
        let mut g = Graph::new("t");
        let p = g.param(Shape::new(vec![64]), DType::F32, "p");
        let a = g.unary(OpKind::Exp, p, "a");
        let b = g.unary(OpKind::Neg, a, "b");
        let c = g.unary(OpKind::Tanh, b, "c");
        let d = g.binary(OpKind::Add, c, b, "d"); // b lives until d
        let pattern = vec![a, b, c, d];
        let reqs = vec![
            ShmemRequest { owner: a, bytes: 256 },
            ShmemRequest { owner: b, bytes: 256 },
            ShmemRequest { owner: c, bytes: 256 },
        ];
        let alloc = allocate(&g, &pattern, &reqs);
        // a dies at b; b overlaps c (lives to d). So c reuses a's slot:
        // footprint 512, not 768.
        assert_eq!(alloc.total_bytes, 512);
    }
}
