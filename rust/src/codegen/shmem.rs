//! Shared-memory dataflow sharing (§4.4).
//!
//! Block-composition sub-roots each request a shared-memory staging
//! buffer. Naively summing the requests throttles occupancy, so the
//! paper reuses previously-allocated space whenever dataflow proves two
//! requests' lifetimes cannot overlap: it walks the pattern in
//! topological order and, using a dominance test over the group DAG,
//! lets a later request take over a buffer whose value is already dead
//! (fully consumed along every path reaching the current op).

use crate::gpu::DeviceSpec;
use crate::graph::{Graph, NodeId};
use crate::util::IdMask;

/// One shared-memory request: `owner` (a block-reuse sub-root) needs
/// `bytes` from its definition until its last in-pattern consumer.
#[derive(Debug, Clone)]
pub struct ShmemRequest {
    pub owner: NodeId,
    pub bytes: usize,
}

/// Result of the allocation pass: per-owner byte offsets and the total
/// block footprint after reuse.
#[derive(Debug, Clone)]
pub struct ShmemAllocation {
    /// (owner, offset, bytes) triples.
    pub slots: Vec<(NodeId, usize, usize)>,
    /// Total shared memory per block after sharing.
    pub total_bytes: usize,
}

/// Allocate shared memory with lifetime-based reuse.
///
/// Lifetime of request r = [def(owner), last consumer of owner within
/// `pattern`] in topological position. Two requests may share space iff
/// their lifetimes do not overlap; we run a simple linear-scan register
/// allocation over the interval list, which is exactly the effect of the
/// paper's dominance-tree walk on series-parallel fusion patterns.
pub fn allocate(graph: &Graph, pattern: &[NodeId], requests: &[ShmemRequest]) -> ShmemAllocation {
    if requests.is_empty() {
        return ShmemAllocation { slots: vec![], total_bytes: 0 };
    }
    // Topological position of each pattern node (pattern ids are already
    // creation-ordered; sort defensively).
    let mut order: Vec<NodeId> = pattern.to_vec();
    order.sort_unstable();
    let pos = |id: NodeId| order.binary_search(&id).unwrap_or(usize::MAX);

    // Build intervals.
    let mut intervals: Vec<(usize, usize, usize)> = Vec::new(); // (start, end, req idx)
    for (i, r) in requests.iter().enumerate() {
        let start = pos(r.owner);
        let end = graph
            .consumers(r.owner)
            .iter()
            .filter(|c| order.binary_search(c).is_ok())
            .map(|&c| pos(c))
            .max()
            .unwrap_or(start);
        intervals.push((start, end, i));
    }
    intervals.sort_by_key(|&(s, ..)| s);

    // Linear scan with a free list of (offset, bytes) holes. We only
    // reuse exact-or-larger holes; fragmentation is acceptable at these
    // request counts (a handful per kernel).
    let mut free: Vec<(usize, usize)> = Vec::new(); // (offset, bytes)
    let mut active: Vec<(usize, usize, usize)> = Vec::new(); // (end, offset, bytes)
    let mut total = 0usize;
    let mut slots = vec![(NodeId(0), 0usize, 0usize); requests.len()];
    for (start, end, ri) in intervals {
        // Expire finished intervals.
        active.retain(|&(aend, off, bytes)| {
            if aend < start {
                free.push((off, bytes));
                false
            } else {
                true
            }
        });
        let need = align(requests[ri].bytes);
        // Find a free hole big enough (best fit).
        let offset = match free
            .iter()
            .enumerate()
            .filter(|(_, &(_, b))| b >= need)
            .min_by_key(|(_, &(_, b))| b)
        {
            Some((fi, &(off, bytes))) => {
                free.swap_remove(fi);
                if bytes > need {
                    free.push((off + need, bytes - need));
                }
                off
            }
            None => {
                let off = total;
                total += need;
                off
            }
        };
        active.push((end, offset, need));
        slots[ri] = (requests[ri].owner, offset, need);
    }
    ShmemAllocation { slots, total_bytes: total }
}

/// Footprint without dataflow sharing: the plain sum of aligned
/// requests (what §4.4 argues *against* — used by the ablation bench
/// to quantify the occupancy the sharing pass buys back).
pub fn naive_total(requests: &[ShmemRequest]) -> usize {
    requests.iter().map(|r| align(r.bytes)).sum()
}

fn align(bytes: usize) -> usize {
    bytes.div_ceil(128) * 128 // 128-byte banks-friendly alignment
}

// ---- the footprint engine ----------------------------------------------
//
// Every capacity question in the stack funnels through the three
// functions below: the delta evaluator's candidate pruning, the beam's
// defense-in-depth filter, the tuner's launchability guard and the
// absorption pass's `epilogue_feasible` all consult the same per-block
// cap and the same occupancy model instead of keeping private copies.

/// Per-block shared-memory capacity of `device` — the single source of
/// truth for the hard cap (48 KB on every spec shipped here).
pub fn block_cap(device: &DeviceSpec) -> usize {
    device.shmem_per_block
}

/// True when a `bytes` request respects the per-block hardware cap.
pub fn fits_block_cap(device: &DeviceSpec, bytes: usize) -> bool {
    bytes <= block_cap(device)
}

/// Full launchability of a `bytes` shared-memory footprint at the given
/// launch shape: within the per-block cap *and* the kernel still
/// achieves non-zero occupancy. This is the one predicate both the
/// tuner's guard and [`epilogue_feasible`] reduce to.
pub fn footprint_feasible(
    device: &DeviceSpec,
    threads_per_block: usize,
    regs_per_thread: usize,
    bytes: usize,
) -> bool {
    fits_block_cap(device, bytes)
        && device.occupancy(threads_per_block, regs_per_thread, bytes) > 0.0
}

/// Intermediate-buffer footprint bound of a fusion pattern under the
/// delta evaluator's §5.4 simplifications: every internal expensive
/// producer (reduction / expensive elementwise with an in-pattern
/// consumer) is assumed block-composed and stages one row of its output
/// in shared memory.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PatternFootprint {
    /// Largest single per-row staging request, bytes — the hard-
    /// feasibility bound (matches the delta model's max-single-request
    /// shmem shortcut, so pruning on it is exactly the old occupancy-
    /// zero filter moved before scoring).
    pub max_request_bytes: usize,
    /// Sum of all per-row staging requests, bytes — the soft-pressure
    /// signal (ignores lifetime sharing, so it upper-bounds what
    /// [`allocate`] will pack at tune time).
    pub staged_sum_bytes: usize,
}

impl PatternFootprint {
    /// Hard feasibility against the per-block cap.
    pub fn fits(&self, device: &DeviceSpec) -> bool {
        fits_block_cap(device, self.max_request_bytes)
    }
}

/// Per-row staging bytes of one sub-root's output at `rows` kernel rows
/// (the quantity both the delta evaluator and the tuner's block-reuse
/// request derive from).
pub fn per_row_staging_bytes(graph: &Graph, id: NodeId, rows: usize) -> usize {
    let node = graph.node(id);
    (node.num_elements() / rows.max(1)).max(1) * node.dtype.size_bytes()
}

/// Compute a pattern's [`PatternFootprint`] incrementally from its
/// membership bitset (`member` must cover exactly `pattern`'s ids).
pub fn pattern_footprint(
    graph: &Graph,
    pattern: &[NodeId],
    rows: usize,
    member: &IdMask,
) -> PatternFootprint {
    let mut fp = PatternFootprint::default();
    for &id in pattern {
        let node = graph.node(id);
        if !node.kind.is_expensive_producer() {
            continue;
        }
        let internal = graph.consumers(id).iter().any(|c| member.contains(c.idx()));
        if internal {
            let per_row = per_row_staging_bytes(graph, id, rows);
            fp.max_request_bytes = fp.max_request_bytes.max(per_row);
            fp.staged_sum_bytes += per_row;
        }
    }
    fp
}

/// Rows of the boundary tensor the `GemmEpilogue` hand-off stages per
/// block: one row per warp at the scheme's fixed 256-thread block.
pub const EPILOGUE_ROWS_PER_BLOCK: usize = 8;

/// Per-block shared-memory staging of the cross-GEMM hand-off for a
/// boundary tensor of `row_elems` elements per row, `elem_bytes` each:
/// the absorbed chain reads the anchor-side tile from shared memory
/// instead of HBM, so the anchor kernel must hold
/// [`EPILOGUE_ROWS_PER_BLOCK`] rows resident.
pub fn epilogue_staging_bytes(row_elems: usize, elem_bytes: usize) -> usize {
    align(row_elems.max(1) * elem_bytes * EPILOGUE_ROWS_PER_BLOCK)
}

/// Tune-time feasibility of the `GemmEpilogue` hand-off on `device`:
/// the staged tile must respect the per-block shared-memory cap and the
/// combined kernel must still be launchable at the scheme's fixed
/// 256-thread block (32 registers covering anchor tile + epilogue
/// temps). A thin wrapper over [`footprint_feasible`] so absorption and
/// the tuner agree byte-for-byte at the cap. When this fails the plan
/// lowers in its cut form.
pub fn epilogue_feasible(device: &DeviceSpec, staging_bytes: usize) -> bool {
    footprint_feasible(device, 256, 32, staging_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, Graph, OpKind, Shape};

    /// chain: a -> b -> c -> d, requests on a and c do not overlap
    /// (a dies at b), so they share one slot.
    #[test]
    fn non_overlapping_lifetimes_share_space() {
        let mut g = Graph::new("t");
        let p = g.param(Shape::new(vec![256]), DType::F32, "p");
        let a = g.unary(OpKind::Exp, p, "a");
        let b = g.unary(OpKind::Neg, a, "b");
        let c = g.unary(OpKind::Tanh, b, "c");
        let d = g.unary(OpKind::Abs, c, "d");
        let pattern = vec![a, b, c, d];
        let reqs = vec![
            ShmemRequest { owner: a, bytes: 1024 },
            ShmemRequest { owner: c, bytes: 1024 },
        ];
        let alloc = allocate(&g, &pattern, &reqs);
        assert_eq!(alloc.total_bytes, 1024); // shared, not 2048
        assert_eq!(alloc.slots[0].1, alloc.slots[1].1); // same offset
    }

    /// diamond: a feeds both b and c; a's lifetime spans past b, so the
    /// request on b cannot reuse a's space.
    #[test]
    fn overlapping_lifetimes_get_distinct_space() {
        let mut g = Graph::new("t");
        let p = g.param(Shape::new(vec![256]), DType::F32, "p");
        let a = g.unary(OpKind::Exp, p, "a");
        let b = g.unary(OpKind::Neg, a, "b");
        let c = g.binary(OpKind::Add, a, b, "c");
        let pattern = vec![a, b, c];
        let reqs = vec![
            ShmemRequest { owner: a, bytes: 512 },
            ShmemRequest { owner: b, bytes: 512 },
        ];
        let alloc = allocate(&g, &pattern, &reqs);
        assert_eq!(alloc.total_bytes, 1024);
        assert_ne!(alloc.slots[0].1, alloc.slots[1].1);
    }

    #[test]
    fn empty_requests_zero_footprint() {
        let g = Graph::new("e");
        let alloc = allocate(&g, &[], &[]);
        assert_eq!(alloc.total_bytes, 0);
    }

    #[test]
    fn alignment_rounds_up() {
        let mut g = Graph::new("t");
        let p = g.param(Shape::new(vec![8]), DType::F32, "p");
        let a = g.unary(OpKind::Exp, p, "a");
        let alloc = allocate(
            &g,
            &[a],
            &[ShmemRequest { owner: a, bytes: 100 }],
        );
        assert_eq!(alloc.total_bytes, 128);
    }

    /// Satellite regression: a request at exactly the per-block cap is
    /// treated identically by every caller of the footprint engine —
    /// the absorption pass (`epilogue_feasible`) and the tuner's guard
    /// (`footprint_feasible` at the tuned launch shape) must agree at
    /// the boundary, one byte over must flip both.
    #[test]
    fn exactly_at_cap_is_feasible_for_every_caller() {
        for d in [
            crate::gpu::DeviceSpec::v100(),
            crate::gpu::DeviceSpec::t4(),
            crate::gpu::DeviceSpec::a100(),
        ] {
            let cap = block_cap(&d);
            assert!(fits_block_cap(&d, cap));
            assert!(!fits_block_cap(&d, cap + 1));
            // Absorption's view (fixed 256-thread / 32-reg scheme)...
            assert!(epilogue_feasible(&d, cap), "{}", d.name);
            assert!(!epilogue_feasible(&d, cap + 1), "{}", d.name);
            // ...and the tuner's view at the same launch shape agree.
            assert_eq!(
                epilogue_feasible(&d, cap),
                footprint_feasible(&d, 256, 32, cap),
                "{}",
                d.name
            );
            assert_eq!(
                epilogue_feasible(&d, cap + 1),
                footprint_feasible(&d, 256, 32, cap + 1),
                "{}",
                d.name
            );
            // The delta evaluator's launch shape (256 threads, 16 regs)
            // draws the line at the same byte.
            assert!(footprint_feasible(&d, 256, 16, cap));
            assert!(!footprint_feasible(&d, 256, 16, cap + 1));
        }
    }

    #[test]
    fn pattern_footprint_tracks_internal_expensive_producers() {
        use crate::graph::ReduceOp;
        // exp → reduce → abs: the reduce is an internal expensive
        // producer (its consumer `abs` is in-pattern); exp's consumer is
        // also internal and exp is an ExpensiveElementwise producer.
        let mut g = Graph::new("fp");
        let p = g.param(Shape::new(vec![64, 256]), DType::F32, "p");
        let e = g.unary(OpKind::Exp, p, "e");
        let r = g.reduce(ReduceOp::Sum, e, vec![1], "r");
        let a = g.unary(OpKind::Abs, r, "a");
        let pattern = vec![e, r, a];
        let member =
            IdMask::from_ids(g.len(), pattern.iter().map(|id| id.idx()));
        let (rows, _) = crate::codegen::latency::pattern_rows(&g, &pattern);
        let fp = pattern_footprint(&g, &pattern, rows, &member);
        // e: 64×256 elems / 64 rows = 256 × 4 B = 1024 B per row;
        // r: 64 elems / 64 rows = 1 × 4 B = 4 B per row.
        assert_eq!(fp.max_request_bytes, 1024);
        assert_eq!(fp.staged_sum_bytes, 1024 + 4);
        assert!(fp.fits(&crate::gpu::DeviceSpec::v100()));
        // With the tail consumer excluded the reduce has no in-pattern
        // consumer: only exp stages.
        let pattern2 = vec![e, r];
        let member2 =
            IdMask::from_ids(g.len(), pattern2.iter().map(|id| id.idx()));
        let fp2 = pattern_footprint(&g, &pattern2, rows, &member2);
        assert_eq!(fp2.staged_sum_bytes, 1024);
    }

    #[test]
    fn epilogue_staging_respects_block_cap() {
        let d = crate::gpu::DeviceSpec::v100();
        // 1024-wide f32 rows: 8 × 4 KB = 32 KB — feasible.
        let ok = epilogue_staging_bytes(1024, 4);
        assert_eq!(ok, 32 * 1024);
        assert!(epilogue_feasible(&d, ok));
        // 2048-wide f32 rows: 64 KB — over the 48 KB per-block cap.
        let too_big = epilogue_staging_bytes(2048, 4);
        assert!(!epilogue_feasible(&d, too_big));
    }

    /// Three sequential requests collapse into one slot; a fourth that
    /// overlaps the third takes a second slot — total is the max
    /// concurrent footprint, not the sum.
    #[test]
    fn total_is_max_concurrent_not_sum() {
        let mut g = Graph::new("t");
        let p = g.param(Shape::new(vec![64]), DType::F32, "p");
        let a = g.unary(OpKind::Exp, p, "a");
        let b = g.unary(OpKind::Neg, a, "b");
        let c = g.unary(OpKind::Tanh, b, "c");
        let d = g.binary(OpKind::Add, c, b, "d"); // b lives until d
        let pattern = vec![a, b, c, d];
        let reqs = vec![
            ShmemRequest { owner: a, bytes: 256 },
            ShmemRequest { owner: b, bytes: 256 },
            ShmemRequest { owner: c, bytes: 256 },
        ];
        let alloc = allocate(&g, &pattern, &reqs);
        // a dies at b; b overlaps c (lives to d). So c reuses a's slot:
        // footprint 512, not 768.
        assert_eq!(alloc.total_bytes, 512);
    }
}
