//! Op grouping (§4.2): partition a fusion pattern into groups, one
//! schedule per group.
//!
//! "We call *sub-root* the output op of a group, and *root* the output
//! of the fusion. Reduce ops are always regarded as sub-root. Expensive
//! element-wise ops are enumerated to both sub-roots and non sub-roots.
//! Other ops are neither sub-roots." Each non-sub-root op's schedule is
//! determined from its group's sub-root by tensor index propagation, so
//! only sub-root (and root) schedules need enumeration.

use crate::graph::{Graph, NodeId, OpClass};

/// One schedule group: the cone of ops that computes `sub_root`, cut at
/// other groups' sub-roots and at pattern inputs.
#[derive(Debug, Clone)]
pub struct Group {
    /// The group's output op.
    pub sub_root: NodeId,
    /// All member ops including the sub-root (each pattern op belongs to
    /// exactly one group).
    pub members: Vec<NodeId>,
    /// True when `sub_root` is a pattern output (fusion root) rather than
    /// an internal sub-root.
    pub is_root: bool,
}

/// A complete partition of a pattern into groups.
#[derive(Debug, Clone)]
pub struct Grouping {
    pub groups: Vec<Group>,
}

impl Grouping {
    /// Index of the group that owns `id`, if any.
    pub fn group_of(&self, id: NodeId) -> Option<usize> {
        self.groups
            .iter()
            .position(|g| g.members.contains(&id))
    }

    /// Number of internal (non-root) sub-roots — the values that must be
    /// communicated between groups by warp/block reuse.
    pub fn num_internal_subroots(&self) -> usize {
        self.groups.iter().filter(|g| !g.is_root).count()
    }
}

/// Identify groups for `pattern` given a choice of which expensive
/// element-wise ops act as sub-roots.
///
/// `expensive_as_subroot[i]` corresponds to the i-th expensive
/// element-wise op of the pattern in topological order (only those with
/// in-pattern consumers are counted — a tail expensive op is already a
/// root). Reductions with in-pattern consumers are always sub-roots.
pub fn identify_groups(
    graph: &Graph,
    pattern: &[NodeId],
    expensive_as_subroot: &[bool],
) -> Grouping {
    let in_pattern = |id: NodeId| pattern.contains(&id);
    let outputs = graph.pattern_outputs(pattern);

    // Decide sub-root status per node.
    let mut subroots: Vec<NodeId> = Vec::new();
    let mut exp_idx = 0usize;
    for &id in pattern {
        let node = graph.node(id);
        let has_internal_consumer = graph.consumers(id).iter().any(|&c| in_pattern(c));
        let is_output = outputs.contains(&id);
        match node.kind.class() {
            OpClass::Reduction if has_internal_consumer => subroots.push(id),
            OpClass::ExpensiveElementwise if has_internal_consumer => {
                let chosen = expensive_as_subroot.get(exp_idx).copied().unwrap_or(false);
                exp_idx += 1;
                if chosen {
                    subroots.push(id);
                }
            }
            _ => {}
        }
        if is_output && !subroots.contains(&id) {
            subroots.push(id);
        }
    }

    // Assign each pattern op to the group of the *earliest sub-root that
    // consumes it* (walking the consumer chain downstream until a
    // sub-root is met). Index propagation in the paper's terms: an op's
    // iteration space follows its downstream sub-root's.
    let mut owner: Vec<Option<usize>> = vec![None; graph.len()];
    for (gi, &sr) in subroots.iter().enumerate() {
        owner[sr.idx()] = Some(gi);
    }
    // Upstream propagation in reverse topological order of the pattern.
    let mut pat_sorted: Vec<NodeId> = pattern.to_vec();
    pat_sorted.sort_unstable();
    for &id in pat_sorted.iter().rev() {
        if owner[id.idx()].is_some() {
            continue;
        }
        // Inherit from the first in-pattern consumer that has an owner.
        let inherited = graph
            .consumers(id)
            .iter()
            .filter(|&&c| in_pattern(c))
            .find_map(|&c| owner[c.idx()]);
        owner[id.idx()] = inherited;
    }
    // Orphans (shouldn't happen if outputs are sub-roots, but belt and
    // braces): attach to the last group.
    let fallback = subroots.len().saturating_sub(1);
    let mut members: Vec<Vec<NodeId>> = vec![Vec::new(); subroots.len()];
    for &id in &pat_sorted {
        let gi = owner[id.idx()].unwrap_or(fallback);
        members[gi].push(id);
    }

    let groups = subroots
        .iter()
        .enumerate()
        .map(|(gi, &sr)| Group {
            sub_root: sr,
            members: std::mem::take(&mut members[gi]),
            is_root: outputs.contains(&sr),
        })
        .collect();
    Grouping { groups }
}

/// Count the expensive element-wise ops of `pattern` that have in-pattern
/// consumers (the enumeration dimension for `expensive_as_subroot`).
pub fn num_enumerable_expensive(graph: &Graph, pattern: &[NodeId]) -> usize {
    pattern
        .iter()
        .filter(|&&id| {
            graph.node(id).kind.class() == OpClass::ExpensiveElementwise
                && graph.consumers(id).iter().any(|c| pattern.contains(c))
        })
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, Graph, OpKind, ReduceOp, Shape};
    use crate::workloads::blocks;

    #[test]
    fn layer_norm_grouping_has_two_reduction_subroots() {
        let mut g = Graph::new("ln");
        let x = g.param(Shape::new(vec![64, 256]), DType::F32, "x");
        let out = blocks::layer_norm(&mut g, x, "ln");
        let pattern: Vec<NodeId> = g
            .nodes()
            .iter()
            .filter(|n| n.kind.is_fusible())
            .map(|n| n.id)
            .collect();
        let grouping = identify_groups(&g, &pattern, &[false]);
        // Two reduction sub-roots (sum, var_sum) + root. rsqrt not chosen.
        let n_red = grouping
            .groups
            .iter()
            .filter(|gr| g.node(gr.sub_root).kind.class() == OpClass::Reduction)
            .count();
        assert_eq!(n_red, 2);
        assert!(grouping.groups.iter().any(|gr| gr.sub_root == out && gr.is_root));
        // Every pattern node owned by exactly one group.
        let total: usize = grouping.groups.iter().map(|gr| gr.members.len()).sum();
        assert_eq!(total, pattern.len());
    }

    #[test]
    fn expensive_subroot_enumeration_adds_group() {
        let mut g = Graph::new("e");
        let x = g.param(Shape::new(vec![64, 256]), DType::F32, "x");
        let t = g.unary(OpKind::Tanh, x, "t");
        let y = g.binary(OpKind::Add, t, x, "y");
        let pattern = vec![t, y];
        let g0 = identify_groups(&g, &pattern, &[false]);
        assert_eq!(g0.groups.len(), 1); // tanh inlined into root group
        let g1 = identify_groups(&g, &pattern, &[true]);
        assert_eq!(g1.groups.len(), 2); // tanh gets its own group
        assert_eq!(g1.num_internal_subroots(), 1);
    }

    #[test]
    fn tail_reduction_is_root_not_internal() {
        let mut g = Graph::new("r");
        let x = g.param(Shape::new(vec![64, 256]), DType::F32, "x");
        let s = g.binary(OpKind::Mul, x, x, "sq");
        let r = g.reduce(ReduceOp::Sum, s, vec![1], "sum");
        let pattern = vec![s, r];
        let grouping = identify_groups(&g, &pattern, &[]);
        assert_eq!(grouping.groups.len(), 1);
        assert!(grouping.groups[0].is_root);
        assert_eq!(grouping.groups[0].sub_root, r);
        assert_eq!(grouping.num_internal_subroots(), 0);
    }

    #[test]
    fn enumerable_expensive_counts_only_internal() {
        let mut g = Graph::new("c");
        let x = g.param(Shape::new(vec![8, 8]), DType::F32, "x");
        let t = g.unary(OpKind::Tanh, x, "mid"); // has consumer → counted
        let e = g.unary(OpKind::Exp, t, "tail"); // no consumer → tail, not counted
        assert_eq!(num_enumerable_expensive(&g, &[t, e]), 1);
    }
}
