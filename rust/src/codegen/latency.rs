//! The latency-evaluator (§4.3) — the accurate-but-slower cost model
//! used to tune schedules and launch dimensions for one fusion pattern.
//!
//! `L = N_wave × L_warp`, with `N_wave = N_warp / Occupancy` and
//! `L_warp = N_instruction × CPI` (Eq. 1). Occupancy comes from launch
//! dimensions, estimated register usage (value lifetime analysis) and
//! shared memory after the §4.4 reuse pass. Instruction counts include
//! the **recompute multipliers** of thread composition — the §2.1 cost
//! that makes XLA refuse mid-kernel reductions, and that FusionStitching
//! avoids with warp/block reuse.

use super::grouping::Grouping;
use super::schedule::SubRootSchedule;
use super::shmem::{self, ShmemRequest};
use crate::gpu::{CostParams, DeviceSpec, LaunchDims};
use crate::graph::{Graph, NodeId, OpClass, OpKind};
use crate::util::IdMask;

/// Launch shape for a generated kernel: `block_threads` threads per
/// block, each block covering `rows_per_block` logical rows of the
/// pattern's iteration space.
///
/// * `rows_per_block == warps_per_block` → one row per warp
///   (warp-cooperative reductions; warp-reuse locality).
/// * `rows_per_block == 1` → one row per block (block-cooperative
///   reductions; block-reuse locality; best for very wide rows).
/// * `rows_per_block == block_threads` → one row per thread
///   (serial per-thread reductions; best when rows ≫ width).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaunchSpec {
    pub block_threads: usize,
    pub rows_per_block: usize,
}

impl LaunchSpec {
    /// Candidate launch shapes the tuner enumerates.
    pub fn candidates() -> Vec<LaunchSpec> {
        let mut out = Vec::new();
        for &bt in &[128usize, 256, 512] {
            out.push(LaunchSpec { block_threads: bt, rows_per_block: bt / 32 }); // row/warp
            out.push(LaunchSpec { block_threads: bt, rows_per_block: 1 }); // row/block
            out.push(LaunchSpec { block_threads: bt, rows_per_block: bt }); // row/thread
        }
        out
    }
}

/// Outcome of evaluating one (grouping, schedules, launch) candidate.
#[derive(Debug, Clone)]
pub struct LatencyEstimate {
    /// Estimated kernel wall time in µs on the target device.
    pub time_us: f64,
    /// Eq. 1 cycles (ALU side only; `time_us` takes max with memory).
    pub cycles: f64,
    pub occupancy: f64,
    pub launch: LaunchDims,
    pub regs_per_thread: usize,
    pub shmem_per_block: usize,
    pub instrs_per_thread: f64,
    pub avg_cpi: f64,
    pub bytes_read: usize,
    pub bytes_written: usize,
}

/// Structural cost constants that are not tunable knobs. The tunable
/// instruction costs (CPI, shuffle, shared-memory access — the Volta
/// microbenchmark values) moved to [`crate::gpu::CostParams`], which is
/// threaded through every estimate so the calibration loop can correct
/// them per device class.
mod cost {
    /// Cap on traffic re-read multipliers (L1/L2 bound recompute
    /// re-reads even when the recompute itself is unbounded).
    pub const REREAD_CAP: f64 = 32.0;
}

/// Determine the pattern's logical iteration space: (rows, row_len),
/// taken from the largest tensor produced inside the pattern.
pub fn pattern_rows(graph: &Graph, pattern: &[NodeId]) -> (usize, usize) {
    let biggest = pattern
        .iter()
        .map(|&id| graph.node(id))
        .max_by_key(|n| n.num_elements())
        .expect("empty pattern");
    (
        biggest.shape.outer_elements().max(1),
        biggest.shape.inner_dim().max(1),
    )
}

/// Structural check: can the code generator schedule this pattern at
/// all? (§4.1: no cross-block communication; mid-pattern reductions must
/// be row reductions over the innermost axis.)
pub fn pattern_supported(graph: &Graph, pattern: &[NodeId]) -> bool {
    // Membership bitset: the per-node consumer scan below made this
    // check O(n²) on large regions via `pattern.contains` (hot on the
    // exploration path — every tuner call starts here).
    let member = IdMask::from_ids(graph.len(), pattern.iter().map(|id| id.idx()));
    for &id in pattern {
        let node = graph.node(id);
        if !node.kind.is_fusible() {
            return false;
        }
        let has_internal_consumer = graph
            .consumers(id)
            .iter()
            .any(|c| member.contains(c.idx()));
        if has_internal_consumer {
            if let OpKind::Reduce { axes, .. } = &node.kind {
                let in_rank = graph.node(node.inputs[0]).shape.rank();
                // Mid-pattern reductions must be innermost-axis row
                // reductions (anything else would need cross-block sync).
                if axes.len() != 1 || axes[0] + 1 != in_rank {
                    return false;
                }
            }
        }
    }
    true
}

/// Pattern membership as a node-id bitset — built once per pattern and
/// shared across every `estimate_kernel` candidate the tuner evaluates
/// for it (the enumeration calls it per launch × schedule combination).
pub fn pattern_membership(graph: &Graph, pattern: &[NodeId]) -> IdMask {
    IdMask::from_ids(graph.len(), pattern.iter().map(|id| id.idx()))
}

/// The Eq. 1 + bandwidth-model tail for a fully-specified
/// memory-intensive launch: `(time_us, alu_cycles)` under `params`.
/// The ONE copy of this formula — shared by [`estimate_kernel`] and the
/// calibration model ([`crate::codegen::calibrate::model_kernel_us`]),
/// so the calibrator can never drift from the model it corrects.
pub fn device_time_us(
    device: &DeviceSpec,
    params: &CostParams,
    dims: LaunchDims,
    occupancy: f64,
    instrs_per_thread: f64,
    total_bytes: usize,
) -> (f64, f64) {
    let n_warp = dims.total_warps(device.warp_size) as f64;
    let slots = (device.total_warp_slots() as f64 * occupancy).max(1.0);
    let n_wave = (n_warp / slots).ceil().max(1.0);
    let cycles = n_wave * instrs_per_thread * params.cpi;
    let t_alu_us = cycles / (device.clock_ghz * 1e3);
    let bw = device.effective_bandwidth_at(occupancy, params.bandwidth_knee);
    let t_mem_us = total_bytes as f64 / (bw * 1e3);
    let time_us = (t_alu_us.max(t_mem_us) * params.time_scale).max(device.kernel_floor_us);
    (time_us, cycles)
}

/// Evaluate one fully-specified candidate. Returns `None` when the
/// combination violates a data-locality or resource constraint (§4.2:
/// "schedules that do not match data locality requirement are
/// discarded"). `member` is the pattern's membership bitset
/// ([`pattern_membership`]); callers evaluating many candidates for one
/// pattern build it once.
#[allow(clippy::too_many_arguments)]
pub fn estimate_kernel(
    graph: &Graph,
    pattern: &[NodeId],
    grouping: &Grouping,
    schedules: &[SubRootSchedule],
    launch: LaunchSpec,
    device: &DeviceSpec,
    index_overhead: f64,
    params: &CostParams,
    member: &IdMask,
) -> Option<LatencyEstimate> {
    assert_eq!(schedules.len(), grouping.groups.len());
    let (rows, _row_len) = pattern_rows(graph, pattern);
    let warps_per_block = launch.block_threads / device.warp_size;
    if warps_per_block == 0 {
        return None;
    }
    let grid_blocks = rows.div_ceil(launch.rows_per_block).max(1);
    let dims = LaunchDims { grid_blocks, block_threads: launch.block_threads };
    let total_threads = dims.total_threads() as f64;

    // ---- locality validation -----------------------------------------
    for (g, &sched) in grouping.groups.iter().zip(schedules) {
        if g.is_root {
            continue;
        }
        let sr = graph.node(g.sub_root);
        match sched {
            SubRootSchedule::WarpReuse => {
                // One row per warp required for warp locality.
                if launch.rows_per_block != warps_per_block {
                    return None;
                }
                if !row_local(graph, g.sub_root, rows) {
                    return None;
                }
            }
            SubRootSchedule::BlockReuse => {
                // Row must fit within one block's charge.
                if launch.rows_per_block > warps_per_block {
                    return None;
                }
                if !row_local(graph, g.sub_root, rows) {
                    return None;
                }
            }
            SubRootSchedule::ThreadLocal => {
                // Always schedulable — cost tells the story.
            }
        }
        let _ = sr;
    }

    // ---- per-group work and communication ------------------------------
    let mut total_work = 0.0f64; // dynamic instruction-equivalents, whole kernel
    let mut shmem_requests: Vec<ShmemRequest> = Vec::new();
    for (g, &sched) in grouping.groups.iter().zip(schedules) {
        let mut group_work = 0.0f64;
        for &m in &g.members {
            let node = graph.node(m);
            let per_elem = node.kind.instructions_per_element();
            let work_items = match &node.kind {
                // A reduction touches every *input* element once.
                OpKind::Reduce { .. } => graph.node(node.inputs[0]).num_elements(),
                _ => node.num_elements(),
            } as f64;
            group_work += work_items * per_elem;
        }
        // Reduction combine overhead by computation style (from launch).
        let has_reduction = g
            .members
            .iter()
            .any(|&m| graph.node(m).kind.class() == OpClass::Reduction);
        if has_reduction {
            let combines = if launch.rows_per_block == 1 {
                params.block_combine()
            } else if launch.rows_per_block == warps_per_block {
                params.warp_combine()
            } else {
                0.0 // serial per-thread reduction: no combine stage
            };
            group_work += rows as f64 * combines;
        }

        let sr_out = graph.node(g.sub_root).num_elements() as f64;
        let demand = group_demand(graph, grouping, member, g.sub_root);

        if !g.is_root {
            match sched {
                SubRootSchedule::ThreadLocal => {
                    // Thread composition: every consuming element's thread
                    // recomputes the whole group cone — the §2.1 blowup.
                    let multiplier = (demand / sr_out).max(1.0);
                    group_work *= multiplier;
                }
                SubRootSchedule::WarpReuse => {
                    group_work += (sr_out + demand) * params.shuffle_cost;
                }
                SubRootSchedule::BlockReuse => {
                    group_work += (sr_out + demand) * params.shmem_access_cost;
                    let bytes_per_row = (sr_out as usize / rows.max(1)).max(1)
                        * graph.node(g.sub_root).dtype.size_bytes()
                        * launch.rows_per_block;
                    shmem_requests.push(ShmemRequest { owner: g.sub_root, bytes: bytes_per_row });
                }
            }
        }
        total_work += group_work;
    }

    // ---- resources -----------------------------------------------------
    let shmem_alloc = shmem::allocate(graph, pattern, &shmem_requests);
    let regs = estimate_registers(graph, pattern);
    // One feasibility authority for the whole stack: the same engine
    // predicate the absorption pass (`epilogue_feasible`) and the
    // explorer's footprint pruning consult — per-block cap plus
    // launchability at this schedule's actual launch shape.
    if !shmem::footprint_feasible(device, launch.block_threads, regs, shmem_alloc.total_bytes) {
        return None;
    }
    let occupancy = device.occupancy(launch.block_threads, regs, shmem_alloc.total_bytes);

    // ---- traffic ---------------------------------------------------------
    let mut bytes_read = 0usize;
    for inp in graph.pattern_inputs(pattern) {
        let uses = graph
            .consumers(inp)
            .iter()
            .filter(|c| member.contains(c.idx()))
            .count()
            .max(1);
        // Re-reads caused by recomputation of the consuming groups.
        let mut mult = uses as f64;
        for (g, &sched) in grouping.groups.iter().zip(schedules) {
            if g.is_root || sched != SubRootSchedule::ThreadLocal {
                continue;
            }
            let feeds_group = g
                .members
                .iter()
                .any(|&m| graph.node(m).inputs.contains(&inp));
            if feeds_group {
                let sr_out = graph.node(g.sub_root).num_elements() as f64;
                let demand = group_demand(graph, grouping, member, g.sub_root);
                let rc = (demand / sr_out).max(1.0).min(cost::REREAD_CAP);
                mult = mult.max(rc);
            }
        }
        bytes_read += (graph.node(inp).output_bytes() as f64 * mult) as usize;
    }
    let bytes_written: usize = graph
        .pattern_outputs(pattern)
        .iter()
        .map(|&o| graph.node(o).output_bytes())
        .sum();

    // ---- Eq. 1 -----------------------------------------------------------
    let instrs_per_thread = total_work / total_threads + index_overhead;
    let (time_us, cycles) = device_time_us(
        device,
        params,
        dims,
        occupancy,
        instrs_per_thread,
        bytes_read + bytes_written,
    );

    Some(LatencyEstimate {
        time_us,
        cycles,
        occupancy,
        launch: dims,
        regs_per_thread: regs,
        shmem_per_block: shmem_alloc.total_bytes,
        instrs_per_thread,
        avg_cpi: params.cpi,
        bytes_read,
        bytes_written,
    })
}

/// Demand on a sub-root's value: the iteration-space size of each
/// distinct in-pattern *consuming group*. Under thread composition the
/// producing cone is inlined into every thread of the consuming group —
/// a group whose sub-root computes `[rows, cols]` recomputes a per-row
/// producer `cols` times (the §2.1 blowup) — so demand must be measured
/// at the consuming group's granularity, not the direct consumer op's.
fn group_demand(
    graph: &Graph,
    grouping: &Grouping,
    member: &IdMask,
    sub_root: NodeId,
) -> f64 {
    let mut seen_groups: Vec<usize> = Vec::new();
    let mut demand = 0.0f64;
    for &c in graph.consumers(sub_root) {
        if !member.contains(c.idx()) {
            continue;
        }
        match grouping.group_of(c) {
            Some(gi) if !seen_groups.contains(&gi) => {
                seen_groups.push(gi);
                demand +=
                    graph.node(grouping.groups[gi].sub_root).num_elements() as f64;
            }
            _ => {}
        }
    }
    demand.max(graph.node(sub_root).num_elements() as f64)
}

/// Row locality: the sub-root's value is per-row (its outer dimension
/// matches the pattern's row count), so a warp/block that owns the row
/// can serve all consumers.
fn row_local(graph: &Graph, sub_root: NodeId, rows: usize) -> bool {
    let node = graph.node(sub_root);
    let out_rows = node.shape.num_elements();
    // Per-row scalar (reduction output) or per-row vector.
    out_rows == rows || node.shape.outer_elements() == rows
}

/// Register estimate: value lifetime analysis over the pattern in
/// topological order (the paper's §4.3 "analyze the life time of every
/// intermediate value"). Each live value ≈ 2 registers (data +
/// addressing), plus a fixed base for indices and loop state.
pub fn estimate_registers(graph: &Graph, pattern: &[NodeId]) -> usize {
    let mut order: Vec<NodeId> = pattern.to_vec();
    order.sort_unstable();
    // remaining in-pattern uses per produced value
    let mut uses: Vec<usize> = order
        .iter()
        .map(|&id| {
            graph
                .consumers(id)
                .iter()
                .filter(|c| order.binary_search(c).is_ok())
                .count()
        })
        .collect();
    let idx_of = |id: NodeId, order: &[NodeId]| order.binary_search(&id).ok();

    let mut live = 0usize;
    let mut peak = 0usize;
    for (i, &id) in order.iter().enumerate() {
        // Consume inputs that die here.
        for &inp in &graph.node(id).inputs {
            if let Some(j) = idx_of(inp, &order) {
                uses[j] -= 1;
                if uses[j] == 0 {
                    live = live.saturating_sub(1);
                }
            }
        }
        // Produce this value (if anyone will read it).
        if uses[i] > 0 {
            live += 1;
        }
        peak = peak.max(live);
        let _ = i;
    }
    10 + 2 * peak
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::grouping::identify_groups;
    use crate::graph::{DType, ReduceOp, Shape};
    use crate::workloads::blocks;

    fn ln_pattern() -> (Graph, Vec<NodeId>) {
        let mut g = Graph::new("ln");
        let x = g.param(Shape::new(vec![4096, 768]), DType::F32, "x");
        let _ = blocks::layer_norm(&mut g, x, "ln");
        let pattern: Vec<NodeId> = g
            .nodes()
            .iter()
            .filter(|n| n.kind.is_fusible())
            .map(|n| n.id)
            .collect();
        (g, pattern)
    }

    #[test]
    fn warp_reuse_beats_thread_local_recompute_for_ln() {
        let (g, pattern) = ln_pattern();
        let grouping = identify_groups(&g, &pattern, &[false]);
        let device = DeviceSpec::v100();
        let launch = LaunchSpec { block_threads: 256, rows_per_block: 8 };
        let n = grouping.groups.len();
        let mk = |s: SubRootSchedule| {
            let scheds: Vec<SubRootSchedule> = grouping
                .groups
                .iter()
                .map(|gr| if gr.is_root { SubRootSchedule::ThreadLocal } else { s })
                .collect();
            let cp = CostParams::default();
            let m = pattern_membership(&g, &pattern);
            estimate_kernel(&g, &pattern, &grouping, &scheds, launch, &device, 6.0, &cp, &m)
        };
        let warp = mk(SubRootSchedule::WarpReuse).expect("warp valid");
        let thread = mk(SubRootSchedule::ThreadLocal).expect("thread valid");
        assert!(
            warp.time_us * 3.0 < thread.time_us,
            "warp {} vs thread-recompute {}",
            warp.time_us,
            thread.time_us
        );
        let _ = n;
    }

    #[test]
    fn block_reuse_requests_shared_memory() {
        let (g, pattern) = ln_pattern();
        let grouping = identify_groups(&g, &pattern, &[false]);
        let device = DeviceSpec::v100();
        let launch = LaunchSpec { block_threads: 256, rows_per_block: 1 };
        let scheds: Vec<SubRootSchedule> = grouping
            .groups
            .iter()
            .map(|gr| {
                if gr.is_root {
                    SubRootSchedule::ThreadLocal
                } else {
                    SubRootSchedule::BlockReuse
                }
            })
            .collect();
        let cp = CostParams::default();
        let m = pattern_membership(&g, &pattern);
        let est = estimate_kernel(&g, &pattern, &grouping, &scheds, launch, &device, 6.0, &cp, &m)
            .expect("block valid");
        assert!(est.shmem_per_block > 0);
        assert!(est.occupancy > 0.0);
    }

    #[test]
    fn warp_reuse_requires_row_per_warp_launch() {
        let (g, pattern) = ln_pattern();
        let grouping = identify_groups(&g, &pattern, &[false]);
        let device = DeviceSpec::v100();
        // rows_per_block=1 is block-locality, not warp: warp reuse invalid.
        let launch = LaunchSpec { block_threads: 256, rows_per_block: 1 };
        let scheds: Vec<SubRootSchedule> = grouping
            .groups
            .iter()
            .map(|gr| {
                if gr.is_root {
                    SubRootSchedule::ThreadLocal
                } else {
                    SubRootSchedule::WarpReuse
                }
            })
            .collect();
        let cp = CostParams::default();
        let m = pattern_membership(&g, &pattern);
        let est = estimate_kernel(&g, &pattern, &grouping, &scheds, launch, &device, 6.0, &cp, &m);
        assert!(est.is_none());
    }

    #[test]
    fn unsupported_mid_column_reduction_rejected() {
        let mut g = Graph::new("bad");
        let x = g.param(Shape::new(vec![64, 256]), DType::F32, "x");
        // Reduce over axis 0 (non-innermost) with an in-pattern consumer.
        let r = g.reduce(ReduceOp::Sum, x, vec![0], "col_sum");
        let b = g.broadcast(r, Shape::new(vec![64, 256]), "b");
        let y = g.binary(crate::graph::OpKind::Sub, x, b, "y");
        assert!(!pattern_supported(&g, &[r, b, y]));
        // As a pure tail it is fine.
        assert!(pattern_supported(&g, &[r]));
    }

    #[test]
    fn register_estimate_grows_with_fanout_depth() {
        let mut g = Graph::new("regs");
        let x = g.param(Shape::new(vec![1024]), DType::F32, "x");
        let mut chain = Vec::new();
        let mut cur = x;
        for i in 0..6 {
            cur = g.unary(crate::graph::OpKind::Exp, cur, format!("e{i}"));
            chain.push(cur);
        }
        let narrow = estimate_registers(&g, &chain);
        // Wide: many values all consumed at the very end.
        let mut g2 = Graph::new("wide");
        let x2 = g2.param(Shape::new(vec![1024]), DType::F32, "x");
        let mut vals = Vec::new();
        for i in 0..6 {
            vals.push(g2.unary(crate::graph::OpKind::Exp, x2, format!("e{i}")));
        }
        let mut acc = vals[0];
        let mut all = vals.clone();
        for &v in &vals[1..] {
            acc = g2.binary(crate::graph::OpKind::Add, acc, v, "acc");
            all.push(acc);
        }
        let wide = estimate_registers(&g2, &all);
        assert!(wide > narrow, "wide {wide} vs narrow {narrow}");
    }

    #[test]
    fn traffic_counts_pattern_boundary_only() {
        let (g, pattern) = ln_pattern();
        let grouping = identify_groups(&g, &pattern, &[false]);
        let device = DeviceSpec::v100();
        let launch = LaunchSpec { block_threads: 256, rows_per_block: 8 };
        let scheds: Vec<SubRootSchedule> = grouping
            .groups
            .iter()
            .map(|gr| {
                if gr.is_root {
                    SubRootSchedule::ThreadLocal
                } else {
                    SubRootSchedule::WarpReuse
                }
            })
            .collect();
        let cp = CostParams::default();
        let m = pattern_membership(&g, &pattern);
        let est = estimate_kernel(&g, &pattern, &grouping, &scheds, launch, &device, 6.0, &cp, &m)
            .unwrap();
        let x_bytes = 4096 * 768 * 4;
        // Input x read (a few uses) + gamma/beta; output written once.
        assert!(est.bytes_read >= x_bytes);
        assert!(est.bytes_read < x_bytes * 8);
        assert!(est.bytes_written >= x_bytes);
    }
}
