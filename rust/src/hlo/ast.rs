//! Structured representation of an HLO-text module.
//!
//! This mirrors the grammar `HloModuleProto::from_text_file` accepts —
//! the exact interchange format `python/compile/aot.py` emits into
//! `artifacts/*.hlo.txt` (HLO *text*, not serialized proto: the
//! xla_extension 0.5.1 proto parser rejects jax≥0.5's 64-bit ids).
//!
//! Only the structure the fusion layers need is retained: computations,
//! instructions, shapes, operand wiring and a key/value attribute bag.
//! Layout annotations (`{1,0}`) are parsed and discarded — fusion
//! decisions in this reproduction are layout-oblivious, like the
//! paper's (§4 schedules re-derive indexing from the logical shape).

use std::collections::BTreeMap;

/// Primitive element type as spelled in HLO text (`f32`, `pred`, ...).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum HloPrimitive {
    F16,
    BF16,
    F32,
    F64,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    Pred,
    /// A tuple shape's "element type" placeholder.
    Tuple,
    /// Opaque/token and anything else we don't model.
    Other,
}

impl HloPrimitive {
    /// Parse the leading primitive-type keyword of a shape string.
    pub fn from_keyword(kw: &str) -> HloPrimitive {
        match kw {
            "f16" => HloPrimitive::F16,
            "bf16" => HloPrimitive::BF16,
            "f32" => HloPrimitive::F32,
            "f64" => HloPrimitive::F64,
            "s8" => HloPrimitive::S8,
            "s16" => HloPrimitive::S16,
            "s32" => HloPrimitive::S32,
            "s64" => HloPrimitive::S64,
            "u8" => HloPrimitive::U8,
            "u16" => HloPrimitive::U16,
            "u32" => HloPrimitive::U32,
            "u64" => HloPrimitive::U64,
            "pred" => HloPrimitive::Pred,
            _ => HloPrimitive::Other,
        }
    }

    /// HLO-text spelling.
    pub fn name(self) -> &'static str {
        match self {
            HloPrimitive::F16 => "f16",
            HloPrimitive::BF16 => "bf16",
            HloPrimitive::F32 => "f32",
            HloPrimitive::F64 => "f64",
            HloPrimitive::S8 => "s8",
            HloPrimitive::S16 => "s16",
            HloPrimitive::S32 => "s32",
            HloPrimitive::S64 => "s64",
            HloPrimitive::U8 => "u8",
            HloPrimitive::U16 => "u16",
            HloPrimitive::U32 => "u32",
            HloPrimitive::U64 => "u64",
            HloPrimitive::Pred => "pred",
            HloPrimitive::Tuple => "tuple",
            HloPrimitive::Other => "opaque",
        }
    }
}

/// A (possibly tuple) shape: `f32[128,256]` or `(s32[], f32[4]{0})`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HloShape {
    pub primitive: HloPrimitive,
    pub dims: Vec<usize>,
    /// Non-empty only for tuple shapes.
    pub tuple_elements: Vec<HloShape>,
}

impl HloShape {
    /// Scalar shape of the given primitive.
    pub fn scalar(primitive: HloPrimitive) -> Self {
        HloShape { primitive, dims: Vec::new(), tuple_elements: Vec::new() }
    }

    /// Array shape.
    pub fn array(primitive: HloPrimitive, dims: Vec<usize>) -> Self {
        HloShape { primitive, dims, tuple_elements: Vec::new() }
    }

    /// True if this is a tuple shape.
    pub fn is_tuple(&self) -> bool {
        self.primitive == HloPrimitive::Tuple
    }

    /// Number of elements (1 for scalars, 0 for tuples).
    pub fn num_elements(&self) -> usize {
        if self.is_tuple() {
            0
        } else {
            self.dims.iter().product()
        }
    }
}

impl std::fmt::Display for HloShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_tuple() {
            write!(f, "(")?;
            for (i, e) in self.tuple_elements.iter().enumerate() {
                if i > 0 {
                    write!(f, ", ")?;
                }
                write!(f, "{e}")?;
            }
            write!(f, ")")
        } else {
            write!(f, "{}[", self.primitive.name())?;
            for (i, d) in self.dims.iter().enumerate() {
                if i > 0 {
                    write!(f, ",")?;
                }
                write!(f, "{d}")?;
            }
            write!(f, "]")
        }
    }
}

/// One HLO instruction line:
/// `%name = f32[4,4]{1,0} add(%a, %b), metadata={...}`.
#[derive(Debug, Clone)]
pub struct HloInstruction {
    /// SSA name without the leading `%` (HLO text may omit `%`).
    pub name: String,
    pub shape: HloShape,
    /// Opcode as spelled (`add`, `reduce`, `get-tuple-element`, ...).
    pub opcode: String,
    /// Operand names (without `%`). Literal operands of `constant` are
    /// not operands — they land in `attrs["literal"]`.
    pub operands: Vec<String>,
    /// Raw trailing attributes: `dimensions={1}`, `to_apply=region_1.1`,
    /// `index=0`, `direction=EQ`, ... Values keep their raw spelling.
    pub attrs: BTreeMap<String, String>,
    /// True if the line was marked `ROOT`.
    pub is_root: bool,
}

impl HloInstruction {
    /// Parse `dimensions={1,2}`-style attributes into a usize list.
    pub fn dims_attr(&self, key: &str) -> Option<Vec<usize>> {
        let raw = self.attrs.get(key)?;
        let inner = raw.trim().trim_start_matches('{').trim_end_matches('}');
        if inner.trim().is_empty() {
            return Some(Vec::new());
        }
        inner
            .split(',')
            .map(|t| t.trim().parse::<usize>().ok())
            .collect()
    }
}

/// A named computation (the entry computation or a nested region).
#[derive(Debug, Clone)]
pub struct HloComputation {
    pub name: String,
    pub instructions: Vec<HloInstruction>,
    /// Index into `instructions` of the ROOT (last instruction if no
    /// explicit ROOT marker was present).
    pub root: usize,
}

impl HloComputation {
    /// Look up an instruction by SSA name.
    pub fn find(&self, name: &str) -> Option<&HloInstruction> {
        self.instructions.iter().find(|i| i.name == name)
    }

    /// The ROOT instruction.
    pub fn root_instruction(&self) -> &HloInstruction {
        &self.instructions[self.root]
    }
}

/// A whole `HloModule`.
#[derive(Debug, Clone)]
pub struct HloModule {
    pub name: String,
    pub computations: Vec<HloComputation>,
    /// Index of the entry computation in `computations`. The text format
    /// marks it with `ENTRY`; if absent, the last computation wins (the
    /// convention HLO text printers follow).
    pub entry: usize,
}

impl HloModule {
    /// The entry computation.
    pub fn entry_computation(&self) -> &HloComputation {
        &self.computations[self.entry]
    }

    /// Look up a nested computation by name (for `to_apply=` targets).
    pub fn find_computation(&self, name: &str) -> Option<&HloComputation> {
        self.computations.iter().find(|c| c.name == name)
    }

    /// Total instruction count across all computations.
    pub fn num_instructions(&self) -> usize {
        self.computations.iter().map(|c| c.instructions.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_display_roundtrips() {
        let s = HloShape::array(HloPrimitive::F32, vec![128, 256]);
        assert_eq!(s.to_string(), "f32[128,256]");
        assert_eq!(HloShape::scalar(HloPrimitive::Pred).to_string(), "pred[]");
    }

    #[test]
    fn tuple_shape() {
        let t = HloShape {
            primitive: HloPrimitive::Tuple,
            dims: vec![],
            tuple_elements: vec![
                HloShape::scalar(HloPrimitive::S32),
                HloShape::array(HloPrimitive::F32, vec![4]),
            ],
        };
        assert!(t.is_tuple());
        assert_eq!(t.to_string(), "(s32[], f32[4])");
        assert_eq!(t.num_elements(), 0);
    }

    #[test]
    fn primitive_keywords() {
        assert_eq!(HloPrimitive::from_keyword("f32"), HloPrimitive::F32);
        assert_eq!(HloPrimitive::from_keyword("pred"), HloPrimitive::Pred);
        assert_eq!(HloPrimitive::from_keyword("token"), HloPrimitive::Other);
    }

    #[test]
    fn dims_attr_parses_braced_lists() {
        let mut attrs = BTreeMap::new();
        attrs.insert("dimensions".to_string(), "{1,2}".to_string());
        attrs.insert("empty".to_string(), "{}".to_string());
        let inst = HloInstruction {
            name: "r".into(),
            shape: HloShape::scalar(HloPrimitive::F32),
            opcode: "reduce".into(),
            operands: vec![],
            attrs,
            is_root: false,
        };
        assert_eq!(inst.dims_attr("dimensions"), Some(vec![1, 2]));
        assert_eq!(inst.dims_attr("empty"), Some(vec![]));
        assert_eq!(inst.dims_attr("missing"), None);
    }
}
