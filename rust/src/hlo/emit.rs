//! HLO-text *emitter*: lower a fusion-IR [`Graph`] to HLO text that the
//! `xla` crate (xla_extension 0.5.1) parses and compiles.
//!
//! This closes the loop in the other direction from [`super::convert`]:
//! any graph the workload builders or the synthetic generator produce
//! can be exported as an executable HLO module and run numerically on
//! the PJRT CPU client — e.g. to cross-validate a fusion plan's
//! semantics-preservation, or to serve a hand-built graph through the
//! same runtime the AOT artifacts use.
//!
//! Scope: the straight-line memory-intensive subset plus `dot` — the
//! same subset [`super::convert::to_graph`] accepts, so `emit ∘ parse ∘
//! convert` round-trips. Ops with data-dependent semantics we do not
//! model numerically (gather/slice offsets, pad config) are emitted as
//! shape-correct placeholders (documented per-op below) — byte-traffic
//! equivalent for fusion analysis, not bit-identical.

use crate::graph::{DType, Graph, Node, OpKind, ReduceOp, Shape};
use std::fmt::Write as _;

/// Why a graph cannot be emitted as HLO text.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EmitError {
    pub node: String,
    pub reason: String,
}

impl std::fmt::Display for EmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "cannot emit node {}: {}", self.node, self.reason)
    }
}

impl std::error::Error for EmitError {}

fn dtype_kw(d: DType) -> &'static str {
    match d {
        DType::F32 => "f32",
        DType::F16 => "f16",
        DType::BF16 => "bf16",
        DType::F64 => "f64",
        DType::I32 => "s32",
        DType::I64 => "s64",
        DType::Bool => "pred",
    }
}

fn shape_str(dtype: DType, shape: &Shape) -> String {
    let dims: Vec<String> = shape.dims().iter().map(|d| d.to_string()).collect();
    let layout: Vec<String> = (0..shape.rank()).rev().map(|i| i.to_string()).collect();
    if shape.rank() == 0 {
        format!("{}[]", dtype_kw(dtype))
    } else {
        format!("{}[{}]{{{}}}", dtype_kw(dtype), dims.join(","), layout.join(","))
    }
}

fn ssa(node: &Node) -> String {
    format!("v{}", node.id.0)
}

/// Emit `graph` as a complete `HloModule` in text form. The entry
/// computation takes every `Parameter` in graph order and returns a
/// tuple of the graph's outputs (nodes with no consumers), matching
/// the `return_tuple=True` convention the runtime unwraps.
pub fn emit_module(graph: &Graph) -> Result<String, EmitError> {
    let mut regions = String::new();
    let mut body = String::new();
    let mut region_count = 0usize;

    let mut param_index = 0usize;
    for node in graph.nodes() {
        let line = emit_instruction(
            graph,
            node,
            &mut param_index,
            &mut regions,
            &mut region_count,
        )?;
        let _ = writeln!(body, "  {line}");
    }

    // ROOT tuple over the outputs.
    let outputs = graph.outputs();
    if outputs.is_empty() {
        return Err(EmitError { node: "<module>".into(), reason: "graph has no outputs".into() });
    }
    let tuple_shapes: Vec<String> = outputs
        .iter()
        .map(|&id| {
            let n = graph.node(id);
            shape_str(n.dtype, &n.shape)
        })
        .collect();
    let tuple_args: Vec<String> = outputs.iter().map(|&id| ssa(graph.node(id))).collect();
    let _ = writeln!(
        body,
        "  ROOT out = ({}) tuple({})",
        tuple_shapes.join(", "),
        tuple_args.join(", ")
    );

    let name: String = graph
        .name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect();
    let mut module = String::new();
    let _ = writeln!(module, "HloModule emitted_{name}\n");
    module.push_str(&regions);
    let _ = writeln!(module, "ENTRY main {{");
    module.push_str(&body);
    let _ = writeln!(module, "}}");
    Ok(module)
}

/// Emit a scalar-combine region for a reduction and return its name.
fn emit_region(op: ReduceOp, dtype: DType, regions: &mut String, count: &mut usize) -> String {
    let name = format!("region_{}", *count);
    *count += 1;
    let combine = match op {
        ReduceOp::Sum | ReduceOp::Mean => "add",
        ReduceOp::Max => "maximum",
        ReduceOp::Min => "minimum",
        ReduceOp::Prod => "multiply",
    };
    let d = dtype_kw(dtype);
    let _ = writeln!(
        regions,
        "{name} {{\n  a = {d}[] parameter(0)\n  b = {d}[] parameter(1)\n  ROOT c = {d}[] {combine}(a, b)\n}}\n"
    );
    name
}

fn emit_instruction(
    graph: &Graph,
    node: &Node,
    param_index: &mut usize,
    regions: &mut String,
    region_count: &mut usize,
) -> Result<String, EmitError> {
    let out = ssa(node);
    let sh = shape_str(node.dtype, &node.shape);
    // Arity-defensive operand access: the fusion IR permits nominally
    // binary ops applied to one value (the synthetic generator emits
    // unary `add`s); HLO does not, so missing operands self-apply —
    // `add(x, x)` — which preserves shape, opcode and byte traffic.
    let arg = |i: usize| ssa(graph.node(node.inputs[i.min(node.inputs.len() - 1)]));
    let err = |reason: &str| EmitError { node: node.name.clone(), reason: reason.into() };

    let simple_unary = |opcode: &str| {
        format!("{out} = {sh} {opcode}({})", ssa(graph.node(node.inputs[0])))
    };
    // HLO forbids implicit broadcast: a binary operand whose shape is
    // not the output shape (scalar constants everywhere in LN/dropout)
    // gets an explicit broadcast prelude line.
    let coerced = |i: usize, prelude: &mut Vec<String>| -> String {
        let idx = i.min(node.inputs.len() - 1);
        let operand = graph.node(node.inputs[idx]);
        if operand.shape == node.shape {
            return ssa(operand);
        }
        let dims = broadcast_dims(&operand.shape, &node.shape).unwrap_or_default();
        let d: Vec<String> = dims.iter().map(|x| x.to_string()).collect();
        let b = format!("{out}_b{i}");
        // Broadcast keeps the *operand's* dtype (compare outputs pred
        // while its operands stay float).
        prelude.push(format!(
            "{b} = {} broadcast({}), dimensions={{{}}}",
            shape_str(operand.dtype, &node.shape),
            ssa(operand),
            d.join(",")
        ));
        b
    };
    let simple_binary = |opcode: &str| {
        let mut lines = Vec::new();
        let a = coerced(0, &mut lines);
        let b = coerced(1, &mut lines);
        lines.push(format!("{out} = {sh} {opcode}({a}, {b})"));
        lines.join("\n  ")
    };

    Ok(match &node.kind {
        OpKind::Parameter => {
            let i = *param_index;
            *param_index += 1;
            format!("{out} = {sh} parameter({i})")
        }
        // Constants are emitted as zeros — the numeric placeholder is
        // irrelevant for structural round-trips, and callers that care
        // about numerics build constants as parameters instead.
        OpKind::Constant => {
            if node.shape.rank() == 0 {
                format!("{out} = {sh} constant(0)")
            } else {
                // Broadcast a scalar zero (valid HLO for any shape).
                let scalar = format!("{}[]", dtype_kw(node.dtype));
                let c = format!("{out}_c");
                format!(
                    "{c} = {scalar} constant(0)\n  {out} = {sh} broadcast({c}), dimensions={{}}"
                )
            }
        }
        OpKind::Add => simple_binary("add"),
        OpKind::Sub => simple_binary("subtract"),
        OpKind::Mul => simple_binary("multiply"),
        OpKind::Div => simple_binary("divide"),
        OpKind::Maximum => simple_binary("maximum"),
        OpKind::Minimum => simple_binary("minimum"),
        OpKind::Neg => simple_unary("negate"),
        OpKind::Abs => simple_unary("abs"),
        OpKind::Compare => {
            let mut lines = Vec::new();
            let a = coerced(0, &mut lines);
            let b = coerced(1, &mut lines);
            lines.push(format!("{out} = {sh} compare({a}, {b}), direction=GT"));
            lines.join("\n  ")
        }
        OpKind::Select => {
            let mut lines = Vec::new();
            let p = coerced(0, &mut lines);
            let t = coerced(1, &mut lines);
            let f = coerced(2, &mut lines);
            lines.push(format!("{out} = {sh} select({p}, {t}, {f})"));
            lines.join("\n  ")
        }
        OpKind::Convert => simple_unary("convert"),
        OpKind::Relu => {
            // relu = maximum(x, broadcast(0)).
            let scalar = format!("{}[]", dtype_kw(node.dtype));
            let z = format!("{out}_z");
            let zb = format!("{out}_zb");
            format!(
                "{z} = {scalar} constant(0)\n  {zb} = {sh} broadcast({z}), dimensions={{}}\n  {out} = {sh} maximum({}, {zb})",
                arg(0)
            )
        }
        OpKind::Exp => simple_unary("exponential"),
        OpKind::Log => simple_unary("log"),
        OpKind::Tanh => simple_unary("tanh"),
        OpKind::Sqrt => simple_unary("sqrt"),
        OpKind::Rsqrt => simple_unary("rsqrt"),
        OpKind::Power => simple_binary("power"),
        OpKind::Sigmoid => simple_unary("logistic"),
        // erf/gelu/tan lower via tanh-family placeholders at equal MUFU
        // cost class (xla_extension 0.5.1 has no erf opcode).
        OpKind::Erf | OpKind::Gelu | OpKind::Tan => simple_unary("tanh"),
        OpKind::Reduce { op, axes } => {
            let region = emit_region(*op, node.dtype, regions, region_count);
            let scalar = format!("{}[]", dtype_kw(node.dtype));
            let init = match op {
                ReduceOp::Max => "-inf",
                ReduceOp::Min => "inf",
                ReduceOp::Prod => "1",
                _ => "0",
            };
            // Verify the recorded axes reproduce the output shape; the
            // structural-autodiff graphs carry loose axes (a broadcast
            // gradient records `last` regardless of which axes were
            // expanded), so re-infer from shapes when they disagree:
            // keep the input axes that embed the output dims in order,
            // reduce the rest.
            let in_shape = graph.node(node.inputs[0]).shape.clone();
            let attr_ok = in_shape.reduce(axes) == node.shape;
            let axes = if attr_ok {
                axes.clone()
            } else {
                let keep = broadcast_dims(&node.shape, &in_shape)
                    .ok_or_else(|| err("cannot infer reduce axes from shapes"))?;
                (0..in_shape.rank()).filter(|a| !keep.contains(a)).collect()
            };
            let dims: Vec<String> = axes.iter().map(|a| a.to_string()).collect();
            let z = format!("{out}_init");
            let base = format!(
                "{z} = {scalar} constant({init})\n  {out}{mean_suffix} = {sh} reduce({}, {z}), dimensions={{{}}}, to_apply={region}",
                arg(0),
                dims.join(","),
                mean_suffix = if *op == ReduceOp::Mean { "_sum" } else { "" },
            );
            if *op == ReduceOp::Mean {
                // mean = sum / n.
                let n: usize = axes
                    .iter()
                    .map(|&a| graph.node(node.inputs[0]).shape.dims()[a])
                    .product();
                let c = format!("{out}_n");
                let cb = format!("{out}_nb");
                let scalar = format!("{}[]", dtype_kw(node.dtype));
                format!(
                    "{base}\n  {c} = {scalar} constant({n})\n  {cb} = {sh} broadcast({c}), dimensions={{}}\n  {out} = {sh} divide({out}_sum, {cb})"
                )
            } else {
                base
            }
        }
        OpKind::Broadcast => {
            // Infer the dimension mapping: input dims must embed into the
            // output dims in order (the convention the workload builders
            // and convert.rs use).
            let in_shape = &graph.node(node.inputs[0]).shape;
            let dims = broadcast_dims(in_shape, &node.shape)
                .ok_or_else(|| err("ambiguous broadcast dims"))?;
            let d: Vec<String> = dims.iter().map(|x| x.to_string()).collect();
            format!(
                "{out} = {sh} broadcast({}), dimensions={{{}}}",
                arg(0),
                d.join(",")
            )
        }
        OpKind::Reshape => simple_unary("reshape"),
        OpKind::Transpose { perm } => {
            let d: Vec<String> = perm.iter().map(|x| x.to_string()).collect();
            format!(
                "{out} = {sh} transpose({}), dimensions={{{}}}",
                arg(0),
                d.join(",")
            )
        }
        // Shape-correct placeholders: the fusion layers only use these
        // ops' byte traffic; numeric fidelity is not claimed (§module
        // docs). A leading-corner slice / zero pad is always valid.
        OpKind::Slice => {
            // HLO slice keeps the operand's rank; our IR permits
            // rank-reducing slices (e.g. "first token": [B,S,H]→[B,H]).
            // Emit an input-rank leading-corner slice whose kept extents
            // are the output dims matched in order (unmatched axes
            // collapse to 1), then reshape to the output shape.
            let in_shape = graph.node(node.inputs[0]).shape.clone();
            let out_dims = node.shape.dims();
            let mut limits = Vec::with_capacity(in_shape.rank());
            let mut next_out = 0usize;
            for &d in in_shape.dims() {
                if next_out < out_dims.len() && out_dims[next_out] <= d {
                    limits.push(out_dims[next_out]);
                    next_out += 1;
                } else {
                    limits.push(1);
                }
            }
            if next_out != out_dims.len() {
                // Up-sizing "slice" (structural autodiff mirrors a slice
                // gradient as Slice with a larger output — semantically
                // a pad): shape-correct zero placeholder.
                let scalar = format!("{}[]", dtype_kw(node.dtype));
                let z = format!("{out}_z");
                return Ok(format!(
                    "{z} = {scalar} constant(0)\n  {out} = {sh} broadcast({z}), dimensions={{}}"
                ));
            }
            let spec: Vec<String> = limits.iter().map(|l| format!("[0:{l}:1]")).collect();
            let sliced_shape = Shape::new(limits.clone());
            let mid = shape_str(node.dtype, &sliced_shape);
            if sliced_shape == node.shape {
                format!("{out} = {sh} slice({}), slice={{{}}}", arg(0), spec.join(","))
            } else {
                let tmp = format!("{out}_s");
                format!(
                    "{tmp} = {mid} slice({}), slice={{{}}}\n  {out} = {sh} reshape({tmp})",
                    arg(0),
                    spec.join(",")
                )
            }
        }
        OpKind::Copy => simple_unary("copy"),
        OpKind::MatMul | OpKind::BatchMatMul => {
            let rank = node.shape.rank();
            if rank < 2 {
                return Err(err("dot output must be rank >= 2"));
            }
            let lhs = graph.node(node.inputs[0]).shape.clone();
            let rhs = graph.node(node.inputs[1.min(node.inputs.len() - 1)]).shape.clone();
            let (lr, rr) = (lhs.rank(), rhs.rank());
            if lr < 2 || rr < 2 {
                return Err(err("dot operands must be rank >= 2"));
            }
            // Infer contracting dims from shapes: the structural-
            // autodiff graphs contain transposed-contraction dots
            // (dA = dC·Bᵀ contracts last-with-last), so try every
            // combination of the trailing two axes and keep the one
            // whose free dims reproduce the output's trailing dims.
            let out_dims = node.shape.dims();
            let mut found = None;
            'search: for lc in [lr - 1, lr - 2] {
                for rc in [rr - 1, rr - 2] {
                    if lhs.dims()[lc] != rhs.dims()[rc] {
                        continue;
                    }
                    let lfree = lhs.dims()[if lc == lr - 1 { lr - 2 } else { lr - 1 }];
                    let rfree = rhs.dims()[if rc == rr - 1 { rr - 2 } else { rr - 1 }];
                    if lfree == out_dims[rank - 2] && rfree == out_dims[rank - 1] {
                        found = Some((lc, rc));
                        break 'search;
                    }
                }
            }
            let (lc, rc) = found.ok_or_else(|| err("cannot infer dot contracting dims"))?;
            let batch: Vec<String> = (0..rank - 2).map(|i| i.to_string()).collect();
            let mut attrs =
                format!("lhs_contracting_dims={{{lc}}}, rhs_contracting_dims={{{rc}}}");
            if !batch.is_empty() {
                attrs = format!(
                    "lhs_batch_dims={{{b}}}, rhs_batch_dims={{{b}}}, {attrs}",
                    b = batch.join(",")
                );
            }
            format!("{out} = {sh} dot({}, {}), {attrs}", arg(0), arg(1))
        }
        OpKind::Concat => {
            // Infer the concat axis: the one where input extents sum to
            // the output extent (unique in builder-generated graphs).
            let axis = (0..node.shape.rank())
                .find(|&a| {
                    let sum: usize = node
                        .inputs
                        .iter()
                        .map(|&i| graph.node(i).shape.dims().get(a).copied().unwrap_or(1))
                        .sum();
                    sum == node.shape.dims()[a]
                        && node.inputs.iter().all(|&i| {
                            graph.node(i).shape.rank() == node.shape.rank()
                        })
                })
                .ok_or_else(|| err("cannot infer concat axis"))?;
            let args: Vec<String> = node.inputs.iter().map(|&i| ssa(graph.node(i))).collect();
            format!(
                "{out} = {sh} concatenate({}), dimensions={{{axis}}}",
                args.join(", ")
            )
        }
        OpKind::Iota => {
            format!("{out} = {sh} iota(), iota_dimension=0")
        }
        // Gather/pad carry data-dependent index/config state the fusion
        // IR does not model; they are emitted as shape-correct zero
        // placeholders (module docs: structural/byte-traffic fidelity,
        // not numerics, for these two).
        OpKind::Gather | OpKind::Pad => {
            let scalar = format!("{}[]", dtype_kw(node.dtype));
            let z = format!("{out}_z");
            format!("{z} = {scalar} constant(0)\n  {out} = {sh} broadcast({z}), dimensions={{}}")
        }
        OpKind::Conv => {
            return Err(err("op outside the emitter's executable subset"));
        }
    })
}

/// Infer HLO `broadcast` dimension mapping: which output axes the input
/// axes land on. Matches input dims greedily left-to-right against
/// equal-sized output dims (unique in all builder-generated graphs).
fn broadcast_dims(input: &Shape, output: &Shape) -> Option<Vec<usize>> {
    let mut dims = Vec::with_capacity(input.rank());
    let mut next = 0usize;
    for &d in input.dims() {
        let mut found = None;
        for (j, &od) in output.dims().iter().enumerate().skip(next) {
            if od == d {
                found = Some(j);
                break;
            }
        }
        let j = found?;
        dims.push(j);
        next = j + 1;
    }
    Some(dims)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{NodeId, OpClass};
    use crate::hlo::{module_stats, parse_module, to_graph};
    use crate::workloads::blocks;

    fn ln_graph() -> Graph {
        let mut g = Graph::new("ln");
        let x = g.param(Shape::new(vec![64, 32]), DType::F32, "x");
        let _ = blocks::layer_norm(&mut g, x, "ln");
        g
    }

    #[test]
    fn emitted_module_parses_back() {
        let g = ln_graph();
        let text = emit_module(&g).unwrap();
        let module = parse_module(&text).unwrap();
        assert!(module.num_instructions() > g.len());
        let stats = module_stats(&module);
        assert_eq!(stats.compute_intensive, 0);
    }

    #[test]
    fn roundtrip_preserves_op_census() {
        let g = ln_graph();
        let text = emit_module(&g).unwrap();
        let module = parse_module(&text).unwrap();
        let g2 = to_graph(&module).unwrap();
        g2.validate().unwrap();
        // Same reduction / expensive-op counts (helpers add constants,
        // so totals differ; the fusion-relevant census must not).
        let census = |g: &Graph, c: OpClass| {
            g.nodes().iter().filter(|n| n.kind.class() == c).count()
        };
        assert_eq!(census(&g, OpClass::Reduction), census(&g2, OpClass::Reduction));
        assert_eq!(
            census(&g, OpClass::ExpensiveElementwise),
            census(&g2, OpClass::ExpensiveElementwise)
        );
        // Output shape identical.
        let out1 = g.node(*g.outputs().last().unwrap()).shape.clone();
        let out2 = g2.node(*g2.outputs().last().unwrap()).shape.clone();
        assert_eq!(out1, out2);
    }

    #[test]
    fn matmul_emits_dot_with_contracting_dims() {
        let mut g = Graph::new("mm");
        let a = g.param(Shape::new(vec![8, 16]), DType::F32, "a");
        let b = g.param(Shape::new(vec![16, 4]), DType::F32, "b");
        let _ = g.matmul(a, b, "c");
        let text = emit_module(&g).unwrap();
        assert!(text.contains("dot("));
        assert!(text.contains("lhs_contracting_dims={1}"));
        assert!(text.contains("rhs_contracting_dims={0}"));
    }

    #[test]
    fn mean_reduce_expands_to_sum_div() {
        let mut g = Graph::new("mean");
        let x = g.param(Shape::new(vec![4, 10]), DType::F32, "x");
        let _ = g.reduce(crate::graph::ReduceOp::Mean, x, vec![1], "m");
        let text = emit_module(&g).unwrap();
        assert!(text.contains("reduce("));
        assert!(text.contains("divide("));
        assert!(text.contains("constant(10)"));
    }

    #[test]
    fn broadcast_dims_inference() {
        let s1 = Shape::new(vec![64]);
        let s2 = Shape::new(vec![64, 32]);
        assert_eq!(broadcast_dims(&s1, &s2), Some(vec![0]));
        let s3 = Shape::new(vec![32]);
        assert_eq!(broadcast_dims(&s3, &s2), Some(vec![1]));
        let scalar = Shape::new(vec![]);
        assert_eq!(broadcast_dims(&scalar, &s2), Some(vec![]));
    }

    #[test]
    fn unsupported_ops_are_reported() {
        let mut g = Graph::new("g");
        let x = g.param(Shape::new(vec![1, 8, 8, 3]), DType::F32, "x");
        let w = g.param(Shape::new(vec![3, 3]), DType::F32, "w");
        let _ = g.add(
            OpKind::Conv,
            DType::F32,
            Shape::new(vec![1, 8, 8, 16]),
            vec![x, w],
            "conv",
        );
        let err = emit_module(&g).unwrap_err();
        assert!(err.reason.contains("subset"));
    }

    #[test]
    fn gather_becomes_shape_correct_placeholder() {
        let mut g = Graph::new("g");
        let t = g.param(Shape::new(vec![100, 8]), DType::F32, "t");
        let ids = g.param(Shape::new(vec![4]), DType::I32, "ids");
        let _ = g.add(
            OpKind::Gather,
            DType::F32,
            Shape::new(vec![4, 8]),
            vec![t, ids],
            "gather",
        );
        let text = emit_module(&g).unwrap();
        assert!(text.contains("broadcast(")); // zero placeholder
        assert!(parse_module(&text).is_ok());
    }

    #[test]
    fn relu_lowers_to_maximum_with_zero() {
        let mut g = Graph::new("r");
        let x = g.param(Shape::new(vec![16]), DType::F32, "x");
        let _ = g.unary(OpKind::Relu, x, "relu");
        let text = emit_module(&g).unwrap();
        assert!(text.contains("maximum("));
        assert!(!text.contains(" relu(")); // no such HLO opcode
        // And it parses back into our IR.
        let module = parse_module(&text).unwrap();
        assert!(to_graph(&module).is_ok());
    }

    #[test]
    fn ssa_names_are_unique() {
        let g = ln_graph();
        let text = emit_module(&g).unwrap();
        let mut names: Vec<&str> = text
            .lines()
            .filter_map(|l| l.trim().split(" = ").next())
            .filter(|n| n.starts_with('v') || *n == "ROOT out")
            .collect();
        let before = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(before, names.len());
    }

    #[test]
    fn outputs_collected_into_root_tuple() {
        let mut g = Graph::new("two_out");
        let x = g.param(Shape::new(vec![8]), DType::F32, "x");
        let a = g.unary(OpKind::Neg, x, "a");
        let b = g.unary(OpKind::Abs, x, "b");
        let _ = (a, b);
        let text = emit_module(&g).unwrap();
        assert!(text.contains("ROOT out = (f32[8]{0}, f32[8]{0}) tuple(v1, v2)"));
        let _ = NodeId(0);
    }
}
