//! Line-oriented parser for HLO text.
//!
//! Accepts the dialect `xc.XlaComputation.as_hlo_text()` prints (the
//! format in `artifacts/*.hlo.txt`):
//!
//! ```text
//! HloModule jit_fn, entry_computation_layout={...}
//!
//! region_1.1 {
//!   Arg_0.2 = f32[] parameter(0)
//!   ROOT add.2 = f32[] add(Arg_0.2, Arg_1.2)
//! }
//!
//! ENTRY main.10 {
//!   p = f32[128,256]{1,0} parameter(0)
//!   c = f32[] constant(0)
//!   ROOT r = f32[128]{0} reduce(p, c), dimensions={1}, to_apply=region_1.1
//! }
//! ```
//!
//! The parser is resilient to the attribute soup real modules carry
//! (`metadata={...}`, `sharding=...`, nested braces, `/*index=5*/`
//! comments inside tuple shapes) — everything after the operand list is
//! split into `key=value` pairs at top-level commas.

use super::ast::{HloComputation, HloInstruction, HloModule, HloPrimitive, HloShape};
use std::collections::BTreeMap;

/// Parse error with a line number for diagnostics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "HLO parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a whole HLO-text module.
pub fn parse_module(text: &str) -> Result<HloModule, ParseError> {
    let mut module_name = String::from("module");
    let mut computations: Vec<HloComputation> = Vec::new();
    let mut entry: Option<usize> = None;

    let mut current: Option<(String, Vec<HloInstruction>, Option<usize>, bool)> = None;

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comments(raw);
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("HloModule") {
            // `HloModule jit_fn, entry_computation_layout={...}`
            let rest = rest.trim();
            module_name = rest
                .split(|c: char| c == ',' || c.is_whitespace())
                .next()
                .unwrap_or("module")
                .to_string();
            continue;
        }
        if line == "}" {
            let (name, instructions, root, is_entry) = current.take().ok_or(ParseError {
                line: lineno + 1,
                message: "unmatched '}'".into(),
            })?;
            if instructions.is_empty() {
                return Err(ParseError {
                    line: lineno + 1,
                    message: format!("computation {name} has no instructions"),
                });
            }
            let root = root.unwrap_or(instructions.len() - 1);
            computations.push(HloComputation { name, instructions, root });
            if is_entry {
                entry = Some(computations.len() - 1);
            }
            continue;
        }
        if line.ends_with('{') && current.is_none() {
            // `ENTRY main.10 {` or `region_1.1 {` — possibly with a
            // parameter signature: `%fused (p: f32[4]) -> f32[4] {`.
            let header = line.trim_end_matches('{').trim();
            let is_entry = header.starts_with("ENTRY");
            let header = header.trim_start_matches("ENTRY").trim();
            let name = header
                .split(|c: char| c.is_whitespace() || c == '(')
                .next()
                .unwrap_or("")
                .trim_start_matches('%')
                .to_string();
            if name.is_empty() {
                return Err(ParseError {
                    line: lineno + 1,
                    message: "computation header missing a name".into(),
                });
            }
            current = Some((name, Vec::new(), None, is_entry));
            continue;
        }
        // Instruction line.
        let Some((_, instructions, root, _)) = current.as_mut() else {
            // Stray line outside a computation (layout decls, etc.): skip.
            continue;
        };
        let inst = parse_instruction(line, lineno + 1)?;
        if inst.is_root {
            *root = Some(instructions.len());
        }
        instructions.push(inst);
    }

    if let Some((name, ..)) = current {
        return Err(ParseError {
            line: text.lines().count(),
            message: format!("computation {name} not closed"),
        });
    }
    if computations.is_empty() {
        return Err(ParseError { line: 0, message: "no computations found".into() });
    }
    let entry = entry.unwrap_or(computations.len() - 1);
    Ok(HloModule { name: module_name, computations, entry })
}

/// Remove `/* ... */` comments (HLO prints `/*index=5*/` inside long
/// operand lists) and `//`-to-EOL comments.
fn strip_comments(line: &str) -> String {
    let mut out = String::with_capacity(line.len());
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        if c == '/' && chars.peek() == Some(&'*') {
            chars.next();
            // consume until `*/`
            let mut prev = ' ';
            for c2 in chars.by_ref() {
                if prev == '*' && c2 == '/' {
                    break;
                }
                prev = c2;
            }
            continue;
        }
        if c == '/' && chars.peek() == Some(&'/') {
            break;
        }
        out.push(c);
    }
    out
}

/// Parse one instruction line.
fn parse_instruction(line: &str, lineno: usize) -> Result<HloInstruction, ParseError> {
    let err = |m: String| ParseError { line: lineno, message: m };

    let (is_root, line) = match line.strip_prefix("ROOT ") {
        Some(rest) => (true, rest.trim()),
        None => (false, line),
    };

    let eq = line.find('=').ok_or_else(|| err("missing '='".into()))?;
    let name = line[..eq].trim().trim_start_matches('%').to_string();
    let rhs = line[eq + 1..].trim();

    // Shape: starts with a primitive keyword or '(' for tuples.
    let (shape, rest) = parse_shape_prefix(rhs).map_err(&err)?;
    let rest = rest.trim_start();

    // Opcode runs until '(' (every HLO op has an operand list, possibly
    // empty: `parameter(0)`, `constant(1)`).
    let paren = rest.find('(').ok_or_else(|| err(format!("missing '(' after opcode in: {rest}")))?;
    let opcode = rest[..paren].trim().to_string();
    if opcode.is_empty() {
        return Err(err("empty opcode".into()));
    }

    // Operand list: scan to the matching ')'.
    let (operand_str, after) = take_balanced(&rest[paren..]).map_err(&err)?;
    let operands = split_top_level(operand_str)
        .into_iter()
        .map(|t| t.trim().to_string())
        .filter(|t| !t.is_empty())
        .collect::<Vec<_>>();

    // `parameter(0)` / `constant(3.14)` carry literals, not operand refs.
    let (operands, mut attrs): (Vec<String>, BTreeMap<String, String>) =
        if opcode == "parameter" || opcode == "constant" || opcode == "iota" {
            let mut a = BTreeMap::new();
            if !operands.is_empty() {
                a.insert("literal".to_string(), operands.join(","));
            }
            (Vec::new(), a)
        } else {
            (
                operands
                    .into_iter()
                    .map(|o| {
                        // Operand tokens may be `%name` or `f32[4] %name`
                        // (typed operand syntax) — keep the last token.
                        o.rsplit(|c: char| c.is_whitespace())
                            .next()
                            .unwrap_or("")
                            .trim_start_matches('%')
                            .to_string()
                    })
                    .collect(),
                BTreeMap::new(),
            )
        };

    // Trailing attributes: `, key=value, key={...}, ...`
    for part in split_top_level(after.trim_start_matches(',')) {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some(eqpos) = part.find('=') {
            let key = part[..eqpos].trim().to_string();
            let val = part[eqpos + 1..].trim().to_string();
            attrs.insert(key, val);
        } else {
            attrs.insert(part.to_string(), String::new());
        }
    }

    Ok(HloInstruction { name, shape, opcode, operands, attrs, is_root })
}

/// Parse the shape prefix of an instruction RHS, returning the shape and
/// the remainder of the string. Handles arrays with layouts
/// (`f32[4,4]{1,0}`) and tuple shapes (`(s32[], f32[4]{0})`).
fn parse_shape_prefix(s: &str) -> Result<(HloShape, &str), String> {
    let s = s.trim_start();
    if let Some(stripped) = s.strip_prefix('(') {
        // Tuple shape: find the matching ')' then parse elements.
        let mut depth = 1usize;
        let mut end = None;
        for (i, c) in stripped.char_indices() {
            match c {
                '(' => depth += 1,
                ')' => {
                    depth -= 1;
                    if depth == 0 {
                        end = Some(i);
                        break;
                    }
                }
                _ => {}
            }
        }
        let end = end.ok_or("unterminated tuple shape")?;
        let inner = &stripped[..end];
        let mut elements = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (shape, rest) = parse_shape_prefix(part)?;
            if !rest.trim().is_empty() {
                return Err(format!("trailing tokens in tuple element: {rest}"));
            }
            elements.push(shape);
        }
        let shape = HloShape {
            primitive: HloPrimitive::Tuple,
            dims: Vec::new(),
            tuple_elements: elements,
        };
        return Ok((shape, &stripped[end + 1..]));
    }

    // `f32[128,256]{1,0}` — keyword, bracketed dims, optional layout.
    let kw_end = s
        .find(|c: char| !(c.is_ascii_alphanumeric()))
        .ok_or("shape keyword runs to end of line")?;
    let kw = &s[..kw_end];
    let primitive = HloPrimitive::from_keyword(kw);
    let mut rest = &s[kw_end..];
    let mut dims = Vec::new();
    if let Some(stripped) = rest.strip_prefix('[') {
        let close = stripped.find(']').ok_or("unterminated dims")?;
        let inner = &stripped[..close];
        for tok in inner.split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            // Dynamic dims print as `<=8`; take the bound.
            let tok = tok.trim_start_matches("<=");
            dims.push(tok.parse::<usize>().map_err(|_| format!("bad dim: {tok}"))?);
        }
        rest = &stripped[close + 1..];
    }
    // Optional layout `{1,0}` — skip balanced braces.
    let rest = rest.trim_start();
    let rest = if rest.starts_with('{') {
        let (_, after) = take_balanced_braces(rest)?;
        after
    } else {
        rest
    };
    Ok((HloShape { primitive, dims, tuple_elements: Vec::new() }, rest))
}

/// Given a string starting with `(`, return the contents up to the
/// matching `)` and the remainder after it.
fn take_balanced(s: &str) -> Result<(&str, &str), String> {
    debug_assert!(s.starts_with('('));
    let mut depth = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth == 0 {
                    return Ok((&s[1..i], &s[i + 1..]));
                }
            }
            _ => {}
        }
    }
    Err("unbalanced parentheses".into())
}

/// Given a string starting with `{`, return the contents and remainder.
fn take_balanced_braces(s: &str) -> Result<(&str, &str), String> {
    debug_assert!(s.starts_with('{'));
    let mut depth = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '{' => depth += 1,
            '}' => {
                depth -= 1;
                if depth == 0 {
                    return Ok((&s[1..i], &s[i + 1..]));
                }
            }
            _ => {}
        }
    }
    Err("unbalanced braces".into())
}

/// Split on commas that are not nested inside (), {}, or [].
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0i32;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '(' | '{' | '[' => depth += 1,
            ')' | '}' | ']' => depth -= 1,
            ',' if depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    const SMALL: &str = r#"
HloModule jit_small, entry_computation_layout={(f32[4]{0})->f32[]}

region_0.1 {
  a = f32[] parameter(0)
  b = f32[] parameter(1)
  ROOT s = f32[] add(a, b)
}

ENTRY main.5 {
  p = f32[4]{0} parameter(0)
  z = f32[] constant(0)
  e = f32[4]{0} exponential(p)
  ROOT r = f32[] reduce(e, z), dimensions={0}, to_apply=region_0.1
}
"#;

    #[test]
    fn parses_small_module() {
        let m = parse_module(SMALL).unwrap();
        assert_eq!(m.name, "jit_small");
        assert_eq!(m.computations.len(), 2);
        let entry = m.entry_computation();
        assert_eq!(entry.name, "main.5");
        assert_eq!(entry.instructions.len(), 4);
        let root = entry.root_instruction();
        assert_eq!(root.opcode, "reduce");
        assert_eq!(root.operands, vec!["e", "z"]);
        assert_eq!(root.dims_attr("dimensions"), Some(vec![0]));
        assert_eq!(root.attrs.get("to_apply").unwrap(), "region_0.1");
    }

    #[test]
    fn entry_is_marked_not_last() {
        let text = r#"
ENTRY main.1 {
  ROOT p = f32[2]{0} parameter(0)
}

trailing.1 {
  ROOT q = f32[] parameter(0)
}
"#;
        let m = parse_module(text).unwrap();
        assert_eq!(m.entry_computation().name, "main.1");
    }

    #[test]
    fn tuple_shapes_and_gte() {
        let text = r#"
ENTRY e {
  t = (s32[], f32[4]{0}) parameter(0)
  ROOT g = f32[4]{0} get-tuple-element(t), index=1
}
"#;
        let m = parse_module(text).unwrap();
        let t = &m.entry_computation().instructions[0];
        assert!(t.shape.is_tuple());
        assert_eq!(t.shape.tuple_elements.len(), 2);
        let g = m.entry_computation().root_instruction();
        assert_eq!(g.attrs.get("index").unwrap(), "1");
    }

    #[test]
    fn comments_and_metadata_ignored() {
        let text = r#"
ENTRY e {
  p = f32[8]{0} parameter(0)
  ROOT n = f32[8]{0} negate(p), metadata={op_type="neg" op_name="jit(f)/neg" source_file="x.py" source_line=3}
}
"#;
        let m = parse_module(text).unwrap();
        let n = m.entry_computation().root_instruction();
        assert_eq!(n.opcode, "negate");
        assert!(n.attrs.contains_key("metadata"));
    }

    #[test]
    fn inline_index_comment_in_tuple() {
        let text = r#"
ENTRY e {
  t = (s32[], s32[], f32[4]{0}, f32[4]{0}, f32[4]{0}, /*index=5*/f32[4]{0}) parameter(0)
  ROOT g = f32[4]{0} get-tuple-element(t), index=5
}
"#;
        let m = parse_module(text).unwrap();
        assert_eq!(m.entry_computation().instructions[0].shape.tuple_elements.len(), 6);
    }

    #[test]
    fn constant_literal_is_attr_not_operand() {
        let text = r#"
ENTRY e {
  ROOT c = f32[] constant(3.5)
}
"#;
        let m = parse_module(text).unwrap();
        let c = m.entry_computation().root_instruction();
        assert!(c.operands.is_empty());
        assert_eq!(c.attrs.get("literal").unwrap(), "3.5");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let text = "ENTRY e {\n  broken line without equals\n}\n";
        let e = parse_module(text).unwrap_err();
        assert_eq!(e.line, 2);
    }

    #[test]
    fn unclosed_computation_is_error() {
        let text = "ENTRY e {\n  p = f32[] parameter(0)\n";
        assert!(parse_module(text).is_err());
    }

    #[test]
    fn dynamic_dims_take_bound() {
        let (s, rest) = parse_shape_prefix("f32[<=8,4]{1,0} x").unwrap();
        assert_eq!(s.dims, vec![8, 4]);
        assert_eq!(rest.trim(), "x");
    }

    #[test]
    fn typed_operand_tokens() {
        let text = r#"
ENTRY e {
  a = f32[4]{0} parameter(0)
  b = f32[4]{0} parameter(1)
  ROOT s = f32[4]{0} add(f32[4]{0} %a, f32[4]{0} %b)
}
"#;
        let m = parse_module(text).unwrap();
        assert_eq!(m.entry_computation().root_instruction().operands, vec!["a", "b"]);
    }
}
