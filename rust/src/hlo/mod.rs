//! HLO-text front-end: parse `artifacts/*.hlo.txt` (the AOT interchange
//! format) and lower straight-line modules into the fusion IR.
//!
//! This closes the L2→L3 loop in the reproduction: the same HLO text
//! the [`crate::runtime`] executes numerically on PJRT can be fed to
//! the [`crate::explorer`] for fusion analysis — `fstitch hlo --file
//! artifacts/ln_reference.hlo.txt --explore` runs the paper's search on
//! a real jax-lowered layer-norm and reports the 4-kernels-vs-1 result
//! of Figure 1 on genuine HLO, not a hand-built graph.
//!
//! * [`ast`] — module/computation/instruction structure.
//! * [`parser`] — resilient line-oriented text parser.
//! * [`convert`] — entry-computation → [`crate::graph::Graph`] lowering
//!   plus structural stats for control-flow modules.

pub mod ast;
pub mod convert;
pub mod emit;
pub mod parser;

pub use ast::{HloComputation, HloInstruction, HloModule, HloPrimitive, HloShape};
pub use convert::{module_stats, to_graph, ConvertError, ModuleStats};
pub use emit::{emit_module, EmitError};
pub use parser::{parse_module, ParseError};

/// Parse an HLO text file from disk.
pub fn parse_file(path: impl AsRef<std::path::Path>) -> Result<HloModule, String> {
    let text = std::fs::read_to_string(path.as_ref())
        .map_err(|e| format!("read {}: {e}", path.as_ref().display()))?;
    parse_module(&text).map_err(|e| e.to_string())
}
