//! Lower a parsed [`HloModule`] into the fusion IR ([`crate::graph::Graph`]).
//!
//! This is the L2→L3 bridge: `python/compile/aot.py` lowers JAX
//! functions to HLO text, and this converter turns the *entry
//! computation* of straight-line modules into the op graph the fusion
//! explorer consumes — so the paper's search runs on real jax-lowered
//! programs, not just our hand-built workload graphs.
//!
//! Scope: straight-line entry computations (everything jnp emits for
//! the L2 model functions). Control flow (`while`, `call`,
//! `conditional`) and custom calls — which appear in Pallas
//! `interpret=True` lowerings as grid loops — are *not* convertible;
//! [`to_graph`] reports the offending opcode so callers can fall back
//! to structural analysis of the parsed module. `ROOT tuple(...)` (the
//! `return_tuple=True` convention the runtime relies on) is unwrapped.

use super::ast::{HloComputation, HloInstruction, HloModule, HloPrimitive, HloShape};
use crate::graph::{DType, Graph, NodeId, OpKind, ReduceOp, Shape};
use std::collections::HashMap;

/// Why a module could not be converted into the fusion IR.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ConvertError {
    /// An instruction uses an opcode outside the straight-line subset.
    UnsupportedOpcode { instruction: String, opcode: String },
    /// An operand name did not resolve (malformed module).
    UnknownOperand { instruction: String, operand: String },
    /// Tuple-typed value in a position we cannot unwrap.
    TupleValue { instruction: String },
}

impl std::fmt::Display for ConvertError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConvertError::UnsupportedOpcode { instruction, opcode } => {
                write!(f, "instruction {instruction}: unsupported opcode `{opcode}` (control flow / custom call)")
            }
            ConvertError::UnknownOperand { instruction, operand } => {
                write!(f, "instruction {instruction}: unknown operand `{operand}`")
            }
            ConvertError::TupleValue { instruction } => {
                write!(f, "instruction {instruction}: tuple value outside ROOT position")
            }
        }
    }
}

impl std::error::Error for ConvertError {}

/// Map an HLO primitive to the fusion IR dtype. Unsized/unmodeled
/// integer widths collapse onto i32 (the fusion layers only use dtype
/// for byte accounting; sub-4-byte ints are not in our workloads).
pub fn primitive_dtype(p: HloPrimitive) -> DType {
    match p {
        HloPrimitive::F16 => DType::F16,
        HloPrimitive::BF16 => DType::BF16,
        HloPrimitive::F32 => DType::F32,
        HloPrimitive::F64 => DType::F64,
        HloPrimitive::S64 | HloPrimitive::U64 => DType::I64,
        HloPrimitive::Pred => DType::Bool,
        _ => DType::I32,
    }
}

fn shape_of(s: &HloShape) -> Shape {
    Shape::new(s.dims.clone())
}

/// Decide the reduction combinator from the `to_apply` region: a region
/// whose ROOT is `add` is a sum-reduction, `maximum` a max-reduction...
fn reduce_op_of(module: &HloModule, inst: &HloInstruction) -> ReduceOp {
    let Some(region_name) = inst.attrs.get("to_apply") else {
        return ReduceOp::Sum;
    };
    let Some(region) = module.find_computation(region_name) else {
        return ReduceOp::Sum;
    };
    match region.root_instruction().opcode.as_str() {
        "maximum" => ReduceOp::Max,
        "minimum" => ReduceOp::Min,
        "multiply" => ReduceOp::Prod,
        _ => ReduceOp::Sum,
    }
}

/// Opcode → fusion-IR kind for the straight-line subset. Returns `None`
/// for opcodes handled specially (tuple/GTE) or unsupported ones.
fn simple_kind(opcode: &str) -> Option<OpKind> {
    Some(match opcode {
        "add" => OpKind::Add,
        "subtract" => OpKind::Sub,
        "multiply" => OpKind::Mul,
        "divide" => OpKind::Div,
        "maximum" => OpKind::Maximum,
        "minimum" => OpKind::Minimum,
        "negate" => OpKind::Neg,
        "abs" => OpKind::Abs,
        "compare" => OpKind::Compare,
        "select" => OpKind::Select,
        "convert" | "bitcast-convert" => OpKind::Convert,
        "exponential" | "exponential-minus-one" => OpKind::Exp,
        "log" | "log-plus-one" => OpKind::Log,
        "tanh" => OpKind::Tanh,
        "sqrt" => OpKind::Sqrt,
        "rsqrt" => OpKind::Rsqrt,
        "power" => OpKind::Power,
        "logistic" => OpKind::Sigmoid,
        "erf" => OpKind::Erf,
        "tan" => OpKind::Tan,
        "sine" | "cosine" => OpKind::Tan, // same MUFU cost class
        "broadcast" => OpKind::Broadcast,
        "reshape" | "bitcast" => OpKind::Reshape,
        "slice" => OpKind::Slice,
        "gather" => OpKind::Gather,
        "concatenate" => OpKind::Concat,
        "pad" => OpKind::Pad,
        "copy" | "copy-start" | "copy-done" => OpKind::Copy,
        "iota" => OpKind::Iota,
        "dot" => OpKind::MatMul,
        "convolution" => OpKind::Conv,
        // Dynamic slicing is memory movement with computed offsets; the
        // fusion layers treat it as its static cousin.
        "dynamic-slice" => OpKind::Slice,
        "dynamic-update-slice" => OpKind::Copy,
        "clamp" => OpKind::Maximum,
        "and" | "or" | "xor" | "not" => OpKind::Compare,
        "sign" | "floor" | "ceil" | "round-nearest-afz" | "round-nearest-even" => OpKind::Abs,
        _ => return None,
    })
}

/// Convert the entry computation of `module` into a fusion-IR graph.
pub fn to_graph(module: &HloModule) -> Result<Graph, ConvertError> {
    let entry = module.entry_computation();
    let mut g = Graph::new(module.name.clone());
    let mut env: HashMap<&str, NodeId> = HashMap::new();

    let root_name = &entry.root_instruction().name;

    for inst in &entry.instructions {
        let id = convert_instruction(module, entry, inst, &mut g, &env, root_name)?;
        if let Some(id) = id {
            env.insert(inst.name.as_str(), id);
        }
    }
    Ok(g)
}

fn convert_instruction(
    module: &HloModule,
    _entry: &HloComputation,
    inst: &HloInstruction,
    g: &mut Graph,
    env: &HashMap<&str, NodeId>,
    root_name: &str,
) -> Result<Option<NodeId>, ConvertError> {
    let resolve = |ops: &[String]| -> Result<Vec<NodeId>, ConvertError> {
        ops.iter()
            .map(|o| {
                env.get(o.as_str()).copied().ok_or_else(|| ConvertError::UnknownOperand {
                    instruction: inst.name.clone(),
                    operand: o.clone(),
                })
            })
            .collect()
    };

    match inst.opcode.as_str() {
        "parameter" => {
            if inst.shape.is_tuple() {
                return Err(ConvertError::TupleValue { instruction: inst.name.clone() });
            }
            let dtype = primitive_dtype(inst.shape.primitive);
            Ok(Some(g.param(shape_of(&inst.shape), dtype, inst.name.clone())))
        }
        "constant" => {
            let dtype = primitive_dtype(inst.shape.primitive);
            Ok(Some(g.constant(shape_of(&inst.shape), dtype, inst.name.clone())))
        }
        "reduce" => {
            if inst.shape.is_tuple() {
                // Variadic reduce (e.g. argmax pairs) — out of subset.
                return Err(ConvertError::UnsupportedOpcode {
                    instruction: inst.name.clone(),
                    opcode: "variadic-reduce".into(),
                });
            }
            let inputs = resolve(&inst.operands[..1])?; // drop init value
            let axes = inst.dims_attr("dimensions").unwrap_or_default();
            let op = reduce_op_of(module, inst);
            let dtype = primitive_dtype(inst.shape.primitive);
            Ok(Some(g.add(
                OpKind::Reduce { op, axes },
                dtype,
                shape_of(&inst.shape),
                inputs,
                inst.name.clone(),
            )))
        }
        "transpose" => {
            let inputs = resolve(&inst.operands)?;
            let perm = inst.dims_attr("dimensions").unwrap_or_default();
            let dtype = primitive_dtype(inst.shape.primitive);
            Ok(Some(g.add(
                OpKind::Transpose { perm },
                dtype,
                shape_of(&inst.shape),
                inputs,
                inst.name.clone(),
            )))
        }
        "tuple" => {
            // Only the ROOT tuple wrapper (return_tuple=True) unwraps;
            // interior tuples imply control flow we do not model.
            if inst.name == root_name {
                Ok(None)
            } else {
                Err(ConvertError::TupleValue { instruction: inst.name.clone() })
            }
        }
        "get-tuple-element" => Err(ConvertError::UnsupportedOpcode {
            instruction: inst.name.clone(),
            opcode: inst.opcode.clone(),
        }),
        "while" | "call" | "conditional" | "custom-call" | "fusion" | "rng"
        | "rng-bit-generator" | "sort" | "scatter" | "map" | "all-reduce"
        | "infeed" | "outfeed" | "send" | "recv" => Err(ConvertError::UnsupportedOpcode {
            instruction: inst.name.clone(),
            opcode: inst.opcode.clone(),
        }),
        op => match simple_kind(op) {
            Some(kind) => {
                // Select keeps all 3 operands; pad drops its padding
                // value operand; compare keeps both sides.
                let keep = match &kind {
                    OpKind::Pad => 1,
                    _ => inst.operands.len(),
                };
                let inputs = resolve(&inst.operands[..keep.min(inst.operands.len())])?;
                let dtype = primitive_dtype(inst.shape.primitive);
                Ok(Some(g.add(kind, dtype, shape_of(&inst.shape), inputs, inst.name.clone())))
            }
            None => Err(ConvertError::UnsupportedOpcode {
                instruction: inst.name.clone(),
                opcode: inst.opcode.clone(),
            }),
        },
    }
}

/// Structural statistics of a parsed module — available even when
/// conversion is impossible (control-flow modules): per-opcode counts
/// and the paper's op-class census.
#[derive(Debug, Clone, Default)]
pub struct ModuleStats {
    pub instructions: usize,
    pub computations: usize,
    /// (opcode, count) sorted by descending count.
    pub opcode_histogram: Vec<(String, usize)>,
    /// Memory-intensive instruction count (everything but dot/conv +
    /// parameters/constants), per the paper's §1 definition.
    pub memory_intensive: usize,
    pub compute_intensive: usize,
}

/// Compute [`ModuleStats`] over every computation in the module.
pub fn module_stats(module: &HloModule) -> ModuleStats {
    let mut hist: HashMap<&str, usize> = HashMap::new();
    let mut mem = 0usize;
    let mut math = 0usize;
    for c in &module.computations {
        for i in &c.instructions {
            *hist.entry(i.opcode.as_str()).or_default() += 1;
            match i.opcode.as_str() {
                "dot" | "convolution" => math += 1,
                "parameter" | "constant" | "tuple" | "get-tuple-element" => {}
                _ => mem += 1,
            }
        }
    }
    let mut opcode_histogram: Vec<(String, usize)> =
        hist.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
    opcode_histogram.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    ModuleStats {
        instructions: module.num_instructions(),
        computations: module.computations.len(),
        opcode_histogram,
        memory_intensive: mem,
        compute_intensive: math,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hlo::parser::parse_module;

    const LN_LIKE: &str = r#"
HloModule jit_ln

region_0.1 {
  a = f32[] parameter(0)
  b = f32[] parameter(1)
  ROOT s = f32[] add(a, b)
}

ENTRY main {
  x = f32[128,256]{1,0} parameter(0)
  z = f32[] constant(0)
  sum = f32[128]{0} reduce(x, z), dimensions={1}, to_apply=region_0.1
  n = f32[] constant(256)
  nb = f32[128]{0} broadcast(n), dimensions={}
  mean = f32[128]{0} divide(sum, nb)
  meanb = f32[128,256]{1,0} broadcast(mean), dimensions={0}
  ROOT c = f32[128,256]{1,0} subtract(x, meanb)
}
"#;

    #[test]
    fn converts_ln_like_module() {
        let m = parse_module(LN_LIKE).unwrap();
        let g = to_graph(&m).unwrap();
        g.validate().unwrap();
        assert_eq!(g.len(), 8);
        let reduce = g
            .nodes()
            .iter()
            .find(|n| matches!(n.kind, OpKind::Reduce { .. }))
            .unwrap();
        assert_eq!(reduce.kind, OpKind::Reduce { op: ReduceOp::Sum, axes: vec![1] });
        assert_eq!(reduce.shape, Shape::new(vec![128]));
        // Reduce drops its init-value operand.
        assert_eq!(reduce.inputs.len(), 1);
    }

    #[test]
    fn max_region_becomes_max_reduce() {
        let text = r#"
region_m {
  a = f32[] parameter(0)
  b = f32[] parameter(1)
  ROOT m = f32[] maximum(a, b)
}

ENTRY main {
  x = f32[8,16]{1,0} parameter(0)
  z = f32[] constant(-inf)
  ROOT r = f32[8]{0} reduce(x, z), dimensions={1}, to_apply=region_m
}
"#;
        let m = parse_module(text).unwrap();
        let g = to_graph(&m).unwrap();
        let r = g.nodes().iter().find(|n| matches!(n.kind, OpKind::Reduce { .. })).unwrap();
        assert_eq!(r.kind, OpKind::Reduce { op: ReduceOp::Max, axes: vec![1] });
    }

    #[test]
    fn root_tuple_unwraps() {
        let text = r#"
ENTRY main {
  x = f32[4]{0} parameter(0)
  n = f32[4]{0} negate(x)
  ROOT t = (f32[4]{0}) tuple(n)
}
"#;
        let m = parse_module(text).unwrap();
        let g = to_graph(&m).unwrap();
        assert_eq!(g.len(), 2); // tuple wrapper itself emits no node
        assert_eq!(g.outputs().len(), 1);
    }

    #[test]
    fn while_loop_is_reported_unsupported() {
        let text = r#"
body {
  ROOT p = s32[] parameter(0)
}
cond {
  q = s32[] parameter(0)
  z = s32[] constant(4)
  ROOT c = pred[] compare(q, z), direction=LT
}
ENTRY main {
  i = s32[] parameter(0)
  ROOT w = s32[] while(i), condition=cond, body=body
}
"#;
        let m = parse_module(text).unwrap();
        let err = to_graph(&m).unwrap_err();
        assert!(matches!(
            err,
            ConvertError::UnsupportedOpcode { ref opcode, .. } if opcode == "while"
        ));
    }

    #[test]
    fn dot_maps_to_matmul() {
        let text = r#"
ENTRY main {
  a = f32[8,16]{1,0} parameter(0)
  b = f32[16,4]{1,0} parameter(1)
  ROOT d = f32[8,4]{1,0} dot(a, b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"#;
        let m = parse_module(text).unwrap();
        let g = to_graph(&m).unwrap();
        assert_eq!(g.num_compute_intensive(), 1);
    }

    #[test]
    fn transpose_keeps_permutation() {
        let text = r#"
ENTRY main {
  a = f32[8,16]{1,0} parameter(0)
  ROOT t = f32[16,8]{1,0} transpose(a), dimensions={1,0}
}
"#;
        let m = parse_module(text).unwrap();
        let g = to_graph(&m).unwrap();
        let t = g.nodes().iter().find(|n| matches!(n.kind, OpKind::Transpose { .. })).unwrap();
        assert_eq!(t.kind, OpKind::Transpose { perm: vec![1, 0] });
    }

    #[test]
    fn stats_census() {
        let m = parse_module(LN_LIKE).unwrap();
        let s = module_stats(&m);
        assert_eq!(s.computations, 2);
        assert_eq!(s.compute_intensive, 0);
        assert!(s.memory_intensive >= 5);
        assert_eq!(s.opcode_histogram[0].0, "parameter"); // most frequent here? tied
    }

    #[test]
    fn dtype_mapping() {
        assert_eq!(primitive_dtype(HloPrimitive::F32), DType::F32);
        assert_eq!(primitive_dtype(HloPrimitive::Pred), DType::Bool);
        assert_eq!(primitive_dtype(HloPrimitive::S64), DType::I64);
        assert_eq!(primitive_dtype(HloPrimitive::U8), DType::I32);
    }
}
