//! The operator-graph IR (the paper's §5.1 `G = (V, E)`).
//!
//! FusionStitching operates on an HLO-like dataflow graph: vertices are
//! tensor operators, edges are producer→consumer value flows. The fusion
//! explorer searches for subgraphs (fusion patterns) and the code
//! generator schedules each pattern into one GPU kernel.
//!
//! The IR deliberately mirrors the paper's op taxonomy (§4): *light
//! element-wise*, *expensive element-wise*, *reduction*, data-movement
//! ops (broadcast/transpose/slice/... — the shape "shrink and broaden"
//! the paper calls out in §3.1), and *compute-intensive* ops (GEMM, conv)
//! which fusion never touches but the simulator must still account for.

mod dot;
mod dtype;
#[allow(clippy::module_inception)]
mod graph;
mod op;
mod shape;

pub use dot::to_dot;
pub use dtype::DType;
pub use graph::{Graph, Node, NodeId};
pub use op::{Fusibility, OpClass, OpKind, ReduceOp};
pub use shape::Shape;
