//! Operator kinds and the paper's op taxonomy (§4).
//!
//! FusionStitching classifies memory-intensive ops into three kinds that
//! get distinct schedule templates: **light element-wise** (add, mul, ...),
//! **expensive element-wise** (tanh, exp, ... — ops whose recomputation
//! XLA avoids by never fusing them mid-kernel), and **reduction**. Data
//! movement ops (broadcast/transpose/slice/...) are light from an ALU
//! standpoint but reshape the iteration space, which is what creates the
//! reuse opportunities §3.1 describes. GEMM/conv are compute-intensive and
//! are never fused by either XLA's loop-fusion pass or FusionStitching;
//! they matter only for end-to-end accounting (the `Math` column of
//! Table 2).

/// Reduction combinator (the op applied across the reduced axes).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ReduceOp {
    Sum,
    Max,
    Min,
    Mean,
    Prod,
}

impl ReduceOp {
    /// Short name for labels/pseudocode.
    pub fn name(self) -> &'static str {
        match self {
            ReduceOp::Sum => "sum",
            ReduceOp::Max => "max",
            ReduceOp::Min => "min",
            ReduceOp::Mean => "mean",
            ReduceOp::Prod => "prod",
        }
    }
}

/// The operator set. Mirrors the HLO ops that appear in the paper's
/// workloads; anything exotic is representable as one of these classes.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum OpKind {
    // ---- sources ----------------------------------------------------
    /// Graph input.
    Parameter,
    /// Materialized constant.
    Constant,

    // ---- light element-wise ------------------------------------------
    Add,
    Sub,
    Mul,
    Div,
    Maximum,
    Minimum,
    Neg,
    Abs,
    /// Element-wise comparison producing a bool mask.
    Compare,
    /// `select(pred, on_true, on_false)`.
    Select,
    /// Dtype conversion.
    Convert,
    /// max(x, 0) — common enough to name.
    Relu,

    // ---- expensive element-wise ---------------------------------------
    Exp,
    Log,
    Tanh,
    Sqrt,
    Rsqrt,
    Power,
    Sigmoid,
    Erf,
    /// GELU tail (erf-based); kept distinct for workload realism.
    Gelu,
    Tan,

    // ---- reduction ----------------------------------------------------
    /// Reduce over `axes` with combinator `op`.
    Reduce { op: ReduceOp, axes: Vec<usize> },

    // ---- data movement (shape-changing, memory-bound) -----------------
    /// Broadcast a smaller tensor up to the node's output shape.
    Broadcast,
    Reshape,
    /// Transpose with the given permutation.
    Transpose { perm: Vec<usize> },
    Slice,
    Gather,
    Concat,
    Pad,
    /// Explicit device-to-device copy (models the `Cpy` rows of Table 2).
    Copy,
    /// One-hot / iota style index materialization.
    Iota,

    // ---- compute intensive ---------------------------------------------
    /// Dense matrix multiply (cuBLAS territory; never fused).
    MatMul,
    /// Batched matmul.
    BatchMatMul,
    /// Convolution (cuDNN territory; never fused).
    Conv,
}

/// Fusibility taxonomy: how an op may participate in a stitched region.
///
/// This refines the historical `is_fusible()` boolean. The old cut rule
/// ("everything memory-intensive fuses, GEMM/conv/sources never do")
/// survives as `Fusible` vs. the rest, but compute-intensive ops are now
/// distinguished from sources: a MatMul/Conv is an **anchor** — a region
/// may claim exactly one and absorb the element-wise/reduce chains feeding
/// and following it across the compute boundary (the `GemmEpilogue`
/// composition scheme). Sources remain fully opaque: they never appear
/// inside a kernel and never anchor one.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Fusibility {
    /// Memory-intensive op: may appear anywhere inside a generated kernel.
    Fusible,
    /// Compute-intensive op (GEMM/conv): lowered via a vendor library,
    /// but a region may claim one as its anchor and stitch the adjacent
    /// memory-intensive chains onto it through shared memory.
    Anchor,
    /// Never participates in any kernel (graph inputs/constants).
    Opaque,
}

/// Coarse classification used by schedule templates and cost models.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpClass {
    /// Graph inputs/constants — no kernel of their own.
    Source,
    /// Cheap ALU element-wise (1–2 instructions/element).
    LightElementwise,
    /// Transcendental / multi-instruction element-wise (MUFU-pipe ops).
    ExpensiveElementwise,
    /// Cross-element reductions.
    Reduction,
    /// Layout/movement ops: broadcast, transpose, slice, ...
    DataMovement,
    /// GEMM/conv — handled by vendor libraries, opaque to fusion.
    ComputeIntensive,
}

impl OpKind {
    /// The paper's taxonomy for this op.
    pub fn class(&self) -> OpClass {
        use OpKind::*;
        match self {
            Parameter | Constant => OpClass::Source,
            Add | Sub | Mul | Div | Maximum | Minimum | Neg | Abs | Compare | Select
            | Convert | Relu => OpClass::LightElementwise,
            Exp | Log | Tanh | Sqrt | Rsqrt | Power | Sigmoid | Erf | Gelu | Tan => {
                OpClass::ExpensiveElementwise
            }
            Reduce { .. } => OpClass::Reduction,
            Broadcast | Reshape | Transpose { .. } | Slice | Gather | Concat | Pad | Copy
            | Iota => OpClass::DataMovement,
            MatMul | BatchMatMul | Conv => OpClass::ComputeIntensive,
        }
    }

    /// Where this op sits in the fusibility taxonomy.
    pub fn fusibility(&self) -> Fusibility {
        match self.class() {
            OpClass::ComputeIntensive => Fusibility::Anchor,
            OpClass::Source => Fusibility::Opaque,
            _ => Fusibility::Fusible,
        }
    }

    /// True for ops that fusion may place inside a generated kernel
    /// (everything memory-intensive, i.e. not GEMM/conv/sources).
    /// Equivalent to `fusibility() == Fusibility::Fusible`; anchors are
    /// handled by the dedicated absorption pass, not the pattern DP.
    pub fn is_fusible(&self) -> bool {
        self.fusibility() == Fusibility::Fusible
    }

    /// True for compute-intensive ops a region may claim as its anchor.
    pub fn is_anchor(&self) -> bool {
        self.fusibility() == Fusibility::Anchor
    }

    /// True for ops XLA refuses to fuse as *producers* (mid-kernel):
    /// reductions and expensive element-wise ops, whose recomputation
    /// under thread composition is what §2.1 criticizes.
    pub fn is_expensive_producer(&self) -> bool {
        matches!(
            self.class(),
            OpClass::Reduction | OpClass::ExpensiveElementwise
        )
    }

    /// Approximate ALU instructions needed to produce *one* output
    /// element (per-element loop body size). Feeds `N_instruction` of the
    /// latency-evaluator (Eq. 1). Values follow the Volta/Turing
    /// microbenchmark papers the paper cites [21, 22]: light ALU ops are
    /// single-instruction, transcendentals expand to multi-instruction
    /// MUFU sequences.
    pub fn instructions_per_element(&self) -> f64 {
        use OpKind::*;
        match self {
            Parameter | Constant => 0.0,
            Add | Sub | Mul | Neg | Abs | Maximum | Minimum | Compare | Convert | Relu => 1.0,
            Select => 2.0,
            Div => 5.0,
            Sqrt | Rsqrt => 6.0,
            Exp | Log | Sigmoid => 8.0,
            Tanh | Tan => 12.0,
            Erf | Gelu => 16.0,
            Power => 14.0,
            // Per output element a reduction consumes (in/out) inputs;
            // callers scale by the reduction factor where it matters.
            Reduce { .. } => 1.0,
            Broadcast | Reshape | Slice | Concat | Pad | Copy | Iota => 1.0,
            Gather => 3.0,
            Transpose { .. } => 2.0,
            // Compute-intensive ops are costed by the library model, not
            // per-element instruction counts.
            MatMul | BatchMatMul | Conv => 0.0,
        }
    }

    /// Short mnemonic used in labels, DOT output, and pseudocode.
    pub fn name(&self) -> String {
        use OpKind::*;
        match self {
            Parameter => "param".into(),
            Constant => "const".into(),
            Add => "add".into(),
            Sub => "sub".into(),
            Mul => "mul".into(),
            Div => "div".into(),
            Maximum => "max".into(),
            Minimum => "min".into(),
            Neg => "neg".into(),
            Abs => "abs".into(),
            Compare => "cmp".into(),
            Select => "select".into(),
            Convert => "convert".into(),
            Relu => "relu".into(),
            Exp => "exp".into(),
            Log => "log".into(),
            Tanh => "tanh".into(),
            Sqrt => "sqrt".into(),
            Rsqrt => "rsqrt".into(),
            Power => "pow".into(),
            Sigmoid => "sigmoid".into(),
            Erf => "erf".into(),
            Gelu => "gelu".into(),
            Tan => "tan".into(),
            Reduce { op, .. } => format!("reduce_{}", op.name()),
            Broadcast => "broadcast".into(),
            Reshape => "reshape".into(),
            Transpose { .. } => "transpose".into(),
            Slice => "slice".into(),
            Gather => "gather".into(),
            Concat => "concat".into(),
            Pad => "pad".into(),
            Copy => "copy".into(),
            Iota => "iota".into(),
            MatMul => "matmul".into(),
            BatchMatMul => "batch_matmul".into(),
            Conv => "conv".into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn taxonomy_matches_paper() {
        assert_eq!(OpKind::Add.class(), OpClass::LightElementwise);
        assert_eq!(OpKind::Tanh.class(), OpClass::ExpensiveElementwise);
        assert_eq!(
            OpKind::Reduce { op: ReduceOp::Sum, axes: vec![1] }.class(),
            OpClass::Reduction
        );
        assert_eq!(OpKind::Broadcast.class(), OpClass::DataMovement);
        assert_eq!(OpKind::MatMul.class(), OpClass::ComputeIntensive);
        assert_eq!(OpKind::Parameter.class(), OpClass::Source);
    }

    #[test]
    fn fusibility_excludes_gemm_and_sources() {
        assert!(OpKind::Add.is_fusible());
        assert!(OpKind::Exp.is_fusible());
        assert!(OpKind::Reduce { op: ReduceOp::Max, axes: vec![0] }.is_fusible());
        assert!(!OpKind::MatMul.is_fusible());
        assert!(!OpKind::Conv.is_fusible());
        assert!(!OpKind::Parameter.is_fusible());
    }

    #[test]
    fn taxonomy_refines_the_boolean_cut() {
        // Fusible ↔ the historical `is_fusible()` true set.
        assert_eq!(OpKind::Add.fusibility(), Fusibility::Fusible);
        assert_eq!(OpKind::Gelu.fusibility(), Fusibility::Fusible);
        assert_eq!(
            OpKind::Reduce { op: ReduceOp::Sum, axes: vec![1] }.fusibility(),
            Fusibility::Fusible
        );
        // GEMM/conv are anchors, not opaque: a region may claim one.
        assert_eq!(OpKind::MatMul.fusibility(), Fusibility::Anchor);
        assert_eq!(OpKind::BatchMatMul.fusibility(), Fusibility::Anchor);
        assert_eq!(OpKind::Conv.fusibility(), Fusibility::Anchor);
        assert!(OpKind::MatMul.is_anchor());
        // Sources stay fully opaque — never in a kernel, never an anchor.
        assert_eq!(OpKind::Parameter.fusibility(), Fusibility::Opaque);
        assert_eq!(OpKind::Constant.fusibility(), Fusibility::Opaque);
        assert!(!OpKind::Parameter.is_anchor());
        assert!(!OpKind::Add.is_anchor());
    }

    #[test]
    fn expensive_producer_rule() {
        // The exact ops §2.1 says XLA keeps out of kernel middles.
        assert!(OpKind::Tan.is_expensive_producer());
        assert!(OpKind::Reduce { op: ReduceOp::Sum, axes: vec![0] }.is_expensive_producer());
        assert!(!OpKind::Add.is_expensive_producer());
        assert!(!OpKind::Broadcast.is_expensive_producer());
    }

    #[test]
    fn expensive_ops_cost_more_instructions() {
        assert!(
            OpKind::Tanh.instructions_per_element()
                > OpKind::Add.instructions_per_element()
        );
        assert!(
            OpKind::Gelu.instructions_per_element()
                >= OpKind::Exp.instructions_per_element()
        );
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(OpKind::Add.name(), "add");
        assert_eq!(
            OpKind::Reduce { op: ReduceOp::Mean, axes: vec![2] }.name(),
            "reduce_mean"
        );
        assert_eq!(OpKind::Transpose { perm: vec![1, 0] }.name(), "transpose");
    }
}
