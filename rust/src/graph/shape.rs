//! Tensor shapes (static — the paper's system, like XLA of its era,
//! handles static shapes only; §7.5 notes dynamic shapes as open work).

use super::DType;

/// A dense row-major tensor shape.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Shape {
    dims: Vec<usize>,
}

impl Shape {
    /// Build a shape from dimensions. A rank-0 scalar is `Shape::scalar()`.
    pub fn new(dims: Vec<usize>) -> Self {
        Self { dims }
    }

    /// Rank-0 scalar.
    pub fn scalar() -> Self {
        Self { dims: vec![] }
    }

    /// Dimensions slice.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Rank (number of dimensions).
    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// Total element count (1 for scalars).
    pub fn num_elements(&self) -> usize {
        self.dims.iter().product()
    }

    /// Byte size when stored with element type `dt`.
    pub fn bytes(&self, dt: DType) -> usize {
        self.num_elements() * dt.size_bytes()
    }

    /// Shape after reducing over `axes` (keep_dims=false).
    pub fn reduce(&self, axes: &[usize]) -> Shape {
        let dims = self
            .dims
            .iter()
            .enumerate()
            .filter(|(i, _)| !axes.contains(i))
            .map(|(_, &d)| d)
            .collect();
        Shape::new(dims)
    }

    /// Shape after transposing with permutation `perm`.
    pub fn transpose(&self, perm: &[usize]) -> Shape {
        assert_eq!(perm.len(), self.rank(), "permutation rank mismatch");
        Shape::new(perm.iter().map(|&p| self.dims[p]).collect())
    }

    /// Innermost (fastest-varying) dimension, or 1 for scalars.
    pub fn inner_dim(&self) -> usize {
        self.dims.last().copied().unwrap_or(1)
    }

    /// Product of all but the innermost dimension ("row count" for the
    /// row-wise reductions that dominate LN/softmax patterns).
    pub fn outer_elements(&self) -> usize {
        if self.dims.is_empty() {
            1
        } else {
            self.dims[..self.dims.len() - 1].iter().product()
        }
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "[{}]",
            self.dims
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join(",")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn num_elements_and_bytes() {
        let s = Shape::new(vec![32, 128, 768]);
        assert_eq!(s.num_elements(), 32 * 128 * 768);
        assert_eq!(s.bytes(DType::F32), 32 * 128 * 768 * 4);
    }

    #[test]
    fn scalar_shape() {
        let s = Shape::scalar();
        assert_eq!(s.rank(), 0);
        assert_eq!(s.num_elements(), 1);
        assert_eq!(s.inner_dim(), 1);
        assert_eq!(s.outer_elements(), 1);
    }

    #[test]
    fn reduce_drops_axes() {
        let s = Shape::new(vec![32, 128, 768]);
        assert_eq!(s.reduce(&[2]), Shape::new(vec![32, 128]));
        assert_eq!(s.reduce(&[0, 1]), Shape::new(vec![768]));
        assert_eq!(s.reduce(&[0, 1, 2]), Shape::scalar());
    }

    #[test]
    fn transpose_permutes() {
        let s = Shape::new(vec![2, 3, 4]);
        assert_eq!(s.transpose(&[2, 0, 1]), Shape::new(vec![4, 2, 3]));
    }

    #[test]
    fn inner_outer_split() {
        let s = Shape::new(vec![32, 128, 768]);
        assert_eq!(s.inner_dim(), 768);
        assert_eq!(s.outer_elements(), 32 * 128);
    }

    #[test]
    fn display_format() {
        assert_eq!(Shape::new(vec![4, 5]).to_string(), "[4,5]");
        assert_eq!(Shape::scalar().to_string(), "[]");
    }
}
