//! Element types carried by tensors in the op graph.

/// Tensor element type. Only the types that appear in the paper's
/// workloads (fp32/fp16 activations, int32/int64 indices, bool masks).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F16,
    BF16,
    F64,
    I32,
    I64,
    Bool,
}

impl DType {
    /// Size of one element in bytes (drives memory-traffic accounting).
    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F16 | DType::BF16 => 2,
            DType::F64 | DType::I64 => 8,
            DType::Bool => 1,
        }
    }

    /// True for floating-point types.
    pub fn is_float(self) -> bool {
        matches!(self, DType::F32 | DType::F16 | DType::BF16 | DType::F64)
    }

    /// Short lowercase name (used in DOT labels and kernel pseudocode).
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F16 => "f16",
            DType::BF16 => "bf16",
            DType::F64 => "f64",
            DType::I32 => "i32",
            DType::I64 => "i64",
            DType::Bool => "pred",
        }
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(DType::F32.size_bytes(), 4);
        assert_eq!(DType::F16.size_bytes(), 2);
        assert_eq!(DType::BF16.size_bytes(), 2);
        assert_eq!(DType::I64.size_bytes(), 8);
        assert_eq!(DType::Bool.size_bytes(), 1);
    }

    #[test]
    fn float_classification() {
        assert!(DType::F32.is_float());
        assert!(DType::BF16.is_float());
        assert!(!DType::I32.is_float());
        assert!(!DType::Bool.is_float());
    }

    #[test]
    fn display_names() {
        assert_eq!(DType::F32.to_string(), "f32");
        assert_eq!(DType::Bool.to_string(), "pred");
    }
}
