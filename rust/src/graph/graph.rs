//! The dataflow graph `G = (V, E)` of §5.1, plus the structural queries
//! the explorer and code generator need: topological orders, consumer
//! maps, reachability, and the cyclic-dependence check of Fig. 6.

use super::{DType, OpKind, Shape};

/// Index of a node within its graph (dense, never reused).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

impl NodeId {
    /// Usize index for vector addressing.
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

impl std::fmt::Display for NodeId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "%{}", self.0)
    }
}

/// One operator vertex.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub kind: OpKind,
    pub dtype: DType,
    /// Output shape of this op.
    pub shape: Shape,
    /// Producer operands, in positional order.
    pub inputs: Vec<NodeId>,
    /// Human-readable name (workload builders use structured names like
    /// `ln0/mean` so fusion dumps stay readable).
    pub name: String,
}

impl Node {
    /// Output byte size (drives memory-traffic accounting).
    pub fn output_bytes(&self) -> usize {
        self.shape.bytes(self.dtype)
    }

    /// Output element count.
    pub fn num_elements(&self) -> usize {
        self.shape.num_elements()
    }
}

/// The computation graph. Nodes are appended in topological order
/// (operands must exist before their consumer), so `nodes` itself is a
/// valid schedule; `topo_order` re-derives one for transformed graphs.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    nodes: Vec<Node>,
    /// consumers[i] = ids of nodes that read node i's output.
    consumers: Vec<Vec<NodeId>>,
    /// Optional model/workload name for reports.
    pub name: String,
}

impl Graph {
    /// Empty graph with a display name.
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            nodes: Vec::new(),
            consumers: Vec::new(),
            name: name.into(),
        }
    }

    // ---- construction ------------------------------------------------

    /// Append a node. Panics if an input id does not exist yet (keeps the
    /// node list topologically ordered by construction).
    pub fn add(
        &mut self,
        kind: OpKind,
        dtype: DType,
        shape: Shape,
        inputs: Vec<NodeId>,
        name: impl Into<String>,
    ) -> NodeId {
        let id = NodeId(self.nodes.len() as u32);
        for &inp in &inputs {
            assert!(
                inp.idx() < self.nodes.len(),
                "input {inp} of new node {id} does not exist"
            );
            self.consumers[inp.idx()].push(id);
        }
        self.nodes.push(Node {
            id,
            kind,
            dtype,
            shape,
            inputs,
            name: name.into(),
        });
        self.consumers.push(Vec::new());
        id
    }

    /// Graph input of the given shape.
    pub fn param(&mut self, shape: Shape, dtype: DType, name: impl Into<String>) -> NodeId {
        self.add(OpKind::Parameter, dtype, shape, vec![], name)
    }

    /// Constant of the given shape.
    pub fn constant(&mut self, shape: Shape, dtype: DType, name: impl Into<String>) -> NodeId {
        self.add(OpKind::Constant, dtype, shape, vec![], name)
    }

    /// Element-wise unary op (same shape/dtype as input unless Convert).
    pub fn unary(&mut self, kind: OpKind, x: NodeId, name: impl Into<String>) -> NodeId {
        let (shape, dtype) = {
            let n = self.node(x);
            (n.shape.clone(), n.dtype)
        };
        self.add(kind, dtype, shape, vec![x], name)
    }

    /// Element-wise binary op. Shapes must match exactly or one side must
    /// be scalar (workload builders insert explicit `Broadcast` nodes for
    /// everything else, mirroring HLO).
    pub fn binary(
        &mut self,
        kind: OpKind,
        a: NodeId,
        b: NodeId,
        name: impl Into<String>,
    ) -> NodeId {
        let (sa, da) = {
            let n = self.node(a);
            (n.shape.clone(), n.dtype)
        };
        let sb = self.node(b).shape.clone();
        let shape = if sa.num_elements() >= sb.num_elements() { sa.clone() } else { sb.clone() };
        assert!(
            sa == sb || sa.rank() == 0 || sb.rank() == 0,
            "binary {:?} shape mismatch {sa} vs {sb} (insert Broadcast)",
            kind
        );
        let dtype = if kind == OpKind::Compare { DType::Bool } else { da };
        self.add(kind, dtype, shape, vec![a, b], name)
    }

    /// Reduction over `axes` of `x`.
    pub fn reduce(
        &mut self,
        op: super::ReduceOp,
        x: NodeId,
        axes: Vec<usize>,
        name: impl Into<String>,
    ) -> NodeId {
        let (shape, dtype) = {
            let n = self.node(x);
            (n.shape.reduce(&axes), n.dtype)
        };
        self.add(OpKind::Reduce { op, axes }, dtype, shape, vec![x], name)
    }

    /// Broadcast `x` up to `shape`.
    pub fn broadcast(&mut self, x: NodeId, shape: Shape, name: impl Into<String>) -> NodeId {
        let dtype = self.node(x).dtype;
        self.add(OpKind::Broadcast, dtype, shape, vec![x], name)
    }

    /// Dense matmul `[.., m, k] x [.., k, n] -> [.., m, n]`.
    pub fn matmul(&mut self, a: NodeId, b: NodeId, name: impl Into<String>) -> NodeId {
        let sa = self.node(a).shape.clone();
        let sb = self.node(b).shape.clone();
        let dtype = self.node(a).dtype;
        assert!(sa.rank() >= 2 && sb.rank() >= 2, "matmul needs rank>=2");
        let m = sa.dims()[sa.rank() - 2];
        let k = sa.dims()[sa.rank() - 1];
        let k2 = sb.dims()[sb.rank() - 2];
        let n = sb.dims()[sb.rank() - 1];
        assert_eq!(k, k2, "matmul contraction mismatch");
        let mut dims: Vec<usize> = sa.dims()[..sa.rank() - 2].to_vec();
        dims.push(m);
        dims.push(n);
        let kind = if sa.rank() > 2 { OpKind::BatchMatMul } else { OpKind::MatMul };
        self.add(kind, dtype, Shape::new(dims), vec![a, b], name)
    }

    // ---- queries -------------------------------------------------------

    /// Node by id.
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.idx()]
    }

    /// All nodes in insertion (topological) order.
    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    /// Node count `V`.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True when the graph has no nodes.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Edge count `E`.
    pub fn num_edges(&self) -> usize {
        self.nodes.iter().map(|n| n.inputs.len()).sum()
    }

    /// Consumers of `id` (nodes reading its output).
    pub fn consumers(&self, id: NodeId) -> &[NodeId] {
        &self.consumers[id.idx()]
    }

    /// Ids of nodes with no consumers (graph outputs).
    pub fn outputs(&self) -> Vec<NodeId> {
        self.nodes
            .iter()
            .filter(|n| self.consumers[n.id.idx()].is_empty())
            .map(|n| n.id)
            .collect()
    }

    /// A topological order (Kahn). The insertion order already is one, but
    /// transformation passes use this to re-validate.
    pub fn topo_order(&self) -> Vec<NodeId> {
        let n = self.nodes.len();
        let mut indeg: Vec<usize> = self.nodes.iter().map(|nd| nd.inputs.len()).collect();
        let mut queue: std::collections::VecDeque<NodeId> = (0..n)
            .filter(|&i| indeg[i] == 0)
            .map(|i| NodeId(i as u32))
            .collect();
        let mut order = Vec::with_capacity(n);
        while let Some(id) = queue.pop_front() {
            order.push(id);
            for &c in self.consumers(id) {
                indeg[c.idx()] -= 1;
                if indeg[c.idx()] == 0 {
                    queue.push_back(c);
                }
            }
        }
        assert_eq!(order.len(), n, "graph contains a cycle");
        order
    }

    /// Post-order over the topological order (last vertex first) — the
    /// traversal direction §5.2 uses to generate candidate patterns "from
    /// the last vertex to the first vertex".
    pub fn post_order(&self) -> Vec<NodeId> {
        let mut order = self.topo_order();
        order.reverse();
        order
    }

    /// Validate structural invariants (input existence, consumer symmetry,
    /// acyclicity). Used by tests and by transformation passes in debug.
    pub fn validate(&self) -> Result<(), String> {
        for node in &self.nodes {
            for &inp in &node.inputs {
                if inp.idx() >= self.nodes.len() {
                    return Err(format!("node {} has dangling input {}", node.id, inp));
                }
                if inp >= node.id {
                    return Err(format!(
                        "node {} consumes later/equal node {} (not topo-ordered)",
                        node.id, inp
                    ));
                }
                if !self.consumers[inp.idx()].contains(&node.id) {
                    return Err(format!(
                        "consumer map out of sync: {} -> {}",
                        inp, node.id
                    ));
                }
            }
        }
        let topo = self.topo_order();
        if topo.len() != self.nodes.len() {
            return Err("cycle detected".to_string());
        }
        Ok(())
    }

    // ---- fusion-specific structural queries -----------------------------

    /// Check whether fusing the node set `pattern` (given as a sorted or
    /// unsorted slice) would create a **cyclic dependence** (Fig. 6): a
    /// path that leaves the pattern and re-enters it. Such a pattern
    /// cannot be scheduled as a single kernel.
    ///
    /// Method: walk forward (consumer direction) from every edge that
    /// exits the pattern, staying *outside* the pattern; if any walk can
    /// reach a node whose consumer is inside the pattern, the fused node
    /// would both feed and depend on external work ⇒ cycle.
    ///
    /// Pruning: node ids are topologically ordered by construction
    /// (every consumer has a higher id than its producers), so a path
    /// can only re-enter the pattern through nodes with id below the
    /// pattern's maximum id. External nodes above that bound are never
    /// expanded, which keeps the check local to the pattern's span
    /// instead of O(V) — essential for the 10k+-op recurrent graphs.
    pub fn fusion_creates_cycle(&self, pattern: &[NodeId]) -> bool {
        // Epoch-marked thread-local scratch: this check runs tens of
        // thousands of times per exploration on big graphs (every XLA
        // merge attempt, every candidate validity check); allocating
        // span-sized mark vectors per call dominated the profile
        // (EXPERIMENTS.md §Perf). Marks compare against the current
        // epoch, so "clearing" is one counter bump.
        thread_local! {
            static SCRATCH: std::cell::RefCell<CycleScratch> =
                std::cell::RefCell::new(CycleScratch::default());
        }
        let max_idx = match pattern.iter().map(|id| id.idx()).max() {
            Some(m) => m,
            None => return false,
        };
        SCRATCH.with(|s| {
            let mut s = s.borrow_mut();
            let s = &mut *s;
            s.begin(max_idx + 1);
            let epoch = s.epoch;
            for &id in pattern {
                s.in_pat[id.idx()] = epoch;
            }
            // Seed: external consumers of pattern outputs (bounded by
            // span — ids are topological, so only nodes below the
            // pattern's max id can lead back in).
            s.stack.clear();
            for &id in pattern {
                for &c in self.consumers(id) {
                    if c.idx() >= max_idx {
                        continue; // cannot lead back into the pattern
                    }
                    if s.in_pat[c.idx()] != epoch && s.visited[c.idx()] != epoch {
                        s.visited[c.idx()] = epoch;
                        s.stack.push(c);
                    }
                }
            }
            // DFS outside the pattern; reaching a pattern node = re-entry.
            while let Some(id) = s.stack.pop() {
                for &c in self.consumers(id) {
                    if c.idx() > max_idx {
                        continue;
                    }
                    if s.in_pat[c.idx()] == epoch {
                        return true;
                    }
                    if s.visited[c.idx()] != epoch {
                        s.visited[c.idx()] = epoch;
                        s.stack.push(c);
                    }
                }
            }
            false
        })
    }

    // (CycleScratch lives at module scope below.)

    /// Nodes of `pattern` whose outputs escape the pattern (read by an
    /// external consumer or graph outputs) — these must be written to
    /// global memory by the generated kernel.
    pub fn pattern_outputs(&self, pattern: &[NodeId]) -> Vec<NodeId> {
        let mut in_pat = vec![false; self.nodes.len()];
        for &id in pattern {
            in_pat[id.idx()] = true;
        }
        pattern
            .iter()
            .copied()
            .filter(|&id| {
                let cons = self.consumers(id);
                cons.is_empty() || cons.iter().any(|c| !in_pat[c.idx()])
            })
            .collect()
    }

    /// External producers read by the pattern (kernel inputs).
    pub fn pattern_inputs(&self, pattern: &[NodeId]) -> Vec<NodeId> {
        let mut in_pat = vec![false; self.nodes.len()];
        for &id in pattern {
            in_pat[id.idx()] = true;
        }
        let mut seen = vec![false; self.nodes.len()];
        let mut out = Vec::new();
        for &id in pattern {
            for &inp in &self.node(id).inputs {
                if !in_pat[inp.idx()] && !seen[inp.idx()] {
                    seen[inp.idx()] = true;
                    out.push(inp);
                }
            }
        }
        out
    }

    /// Count of memory-intensive (fusible-class) ops — the population the
    /// paper's `Mem` kernel counts draw from.
    pub fn num_memory_intensive(&self) -> usize {
        self.nodes.iter().filter(|n| n.kind.is_fusible()).count()
    }

    /// Count of compute-intensive ops (the `Math` column).
    pub fn num_compute_intensive(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| n.kind.class() == super::OpClass::ComputeIntensive)
            .count()
    }
}

/// Reusable scratch for [`Graph::fusion_creates_cycle`]: epoch-marked
/// membership/visited arrays + a DFS stack, grown on demand and never
/// re-zeroed (a mark is "set" iff it equals the current epoch).
#[derive(Default)]
struct CycleScratch {
    epoch: u32,
    in_pat: Vec<u32>,
    visited: Vec<u32>,
    stack: Vec<NodeId>,
}

impl CycleScratch {
    fn begin(&mut self, span: usize) {
        if self.in_pat.len() < span {
            self.in_pat.resize(span, 0);
            self.visited.resize(span, 0);
        }
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            // Wrapped: stale marks could alias epoch 0 — hard reset.
            self.in_pat.iter_mut().for_each(|m| *m = u32::MAX);
            self.visited.iter_mut().for_each(|m| *m = u32::MAX);
            self.epoch = 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ReduceOp;

    fn diamond() -> (Graph, NodeId, NodeId, NodeId, NodeId) {
        // p -> a -> b,c -> d   (classic diamond)
        let mut g = Graph::new("diamond");
        let p = g.param(Shape::new(vec![4, 8]), DType::F32, "p");
        let a = g.unary(OpKind::Exp, p, "a");
        let b = g.unary(OpKind::Neg, a, "b");
        let c = g.unary(OpKind::Abs, a, "c");
        let d = g.binary(OpKind::Add, b, c, "d");
        (g, a, b, c, d)
    }

    #[test]
    fn construction_and_queries() {
        let (g, a, b, c, d) = diamond();
        assert_eq!(g.len(), 5);
        assert_eq!(g.num_edges(), 5);
        assert_eq!(g.consumers(a), &[b, c]);
        assert_eq!(g.outputs(), vec![d]);
        g.validate().unwrap();
    }

    #[test]
    fn topo_and_post_order() {
        let (g, ..) = diamond();
        let topo = g.topo_order();
        assert_eq!(topo.len(), 5);
        // every edge respects the order
        let pos: Vec<usize> = {
            let mut p = vec![0; g.len()];
            for (i, id) in topo.iter().enumerate() {
                p[id.idx()] = i;
            }
            p
        };
        for n in g.nodes() {
            for &inp in &n.inputs {
                assert!(pos[inp.idx()] < pos[n.id.idx()]);
            }
        }
        let post = g.post_order();
        assert_eq!(post[0], *topo.last().unwrap());
    }

    #[test]
    fn cyclic_dependence_detected_like_fig6() {
        // Fig. 6: A -> B -> C and A -> C. Fusing {A, C} leaves B outside
        // on a path A -> B -> C that re-enters ⇒ cycle.
        let mut g = Graph::new("fig6");
        let p = g.param(Shape::new(vec![8]), DType::F32, "p");
        let a = g.unary(OpKind::Exp, p, "A");
        let b = g.unary(OpKind::Neg, a, "B");
        let c = g.binary(OpKind::Add, a, b, "C");
        assert!(g.fusion_creates_cycle(&[a, c]));
        assert!(!g.fusion_creates_cycle(&[a, b, c]));
        assert!(!g.fusion_creates_cycle(&[b, c]));
        assert!(!g.fusion_creates_cycle(&[a, b]));
    }

    #[test]
    fn pattern_io_identification() {
        let (g, a, b, c, _d) = diamond();
        // Fuse {b, c}: input is a, outputs are b and c (read by d).
        let ins = g.pattern_inputs(&[b, c]);
        assert_eq!(ins, vec![a]);
        let outs = g.pattern_outputs(&[b, c]);
        assert_eq!(outs.len(), 2);
    }

    #[test]
    fn pattern_outputs_internalized_when_consumer_in_pattern() {
        let (g, a, b, c, d) = diamond();
        let outs = g.pattern_outputs(&[a, b, c, d]);
        assert_eq!(outs, vec![d]); // only the root escapes
    }

    #[test]
    fn reduce_builder_shapes() {
        let mut g = Graph::new("r");
        let p = g.param(Shape::new(vec![32, 128, 768]), DType::F32, "p");
        let r = g.reduce(ReduceOp::Sum, p, vec![2], "sum");
        assert_eq!(g.node(r).shape, Shape::new(vec![32, 128]));
    }

    #[test]
    fn matmul_builder_shapes() {
        let mut g = Graph::new("mm");
        let a = g.param(Shape::new(vec![32, 64]), DType::F32, "a");
        let b = g.param(Shape::new(vec![64, 16]), DType::F32, "b");
        let c = g.matmul(a, b, "c");
        assert_eq!(g.node(c).shape, Shape::new(vec![32, 16]));
        assert_eq!(g.node(c).kind, OpKind::MatMul);
        let x = g.param(Shape::new(vec![4, 32, 64]), DType::F32, "x");
        let y = g.param(Shape::new(vec![4, 64, 16]), DType::F32, "y");
        let z = g.matmul(x, y, "z");
        assert_eq!(g.node(z).kind, OpKind::BatchMatMul);
        assert_eq!(g.node(z).shape, Shape::new(vec![4, 32, 16]));
    }

    #[test]
    fn scalar_binary_broadcasts() {
        let mut g = Graph::new("s");
        let p = g.param(Shape::new(vec![16]), DType::F32, "p");
        let s = g.constant(Shape::scalar(), DType::F32, "eps");
        let q = g.binary(OpKind::Add, p, s, "q");
        assert_eq!(g.node(q).shape, Shape::new(vec![16]));
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn mismatched_binary_panics() {
        let mut g = Graph::new("bad");
        let a = g.param(Shape::new(vec![4]), DType::F32, "a");
        let b = g.param(Shape::new(vec![5]), DType::F32, "b");
        g.binary(OpKind::Add, a, b, "c");
    }

    #[test]
    fn intensity_counts() {
        let (g, ..) = diamond();
        assert_eq!(g.num_memory_intensive(), 4);
        assert_eq!(g.num_compute_intensive(), 0);
    }

    #[test]
    fn compare_yields_bool() {
        let mut g = Graph::new("cmp");
        let a = g.param(Shape::new(vec![4]), DType::F32, "a");
        let b = g.param(Shape::new(vec![4]), DType::F32, "b");
        let c = g.binary(OpKind::Compare, a, b, "c");
        assert_eq!(g.node(c).dtype, DType::Bool);
    }
}
