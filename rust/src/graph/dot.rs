//! Graphviz DOT export for debugging fusion decisions.
//!
//! `fstitch inspect --dot` and the examples use this to visualize which
//! nodes each fusion pattern swallowed (patterns become colored clusters,
//! mirroring the presentation of the paper's Figure 1).

use super::{Graph, NodeId, OpClass};

/// Render `graph` as DOT. `clusters` optionally groups node sets into
/// labeled subgraphs (one per fusion pattern).
pub fn to_dot(graph: &Graph, clusters: &[(String, Vec<NodeId>)]) -> String {
    let mut out = String::new();
    out.push_str("digraph G {\n  rankdir=TB;\n  node [shape=box, fontsize=10];\n");
    let mut clustered = vec![false; graph.len()];
    for (ci, (label, members)) in clusters.iter().enumerate() {
        out.push_str(&format!(
            "  subgraph cluster_{ci} {{\n    label=\"{label}\";\n    style=filled;\n    color=\"{}\";\n",
            palette(ci)
        ));
        for &id in members {
            clustered[id.idx()] = true;
            out.push_str(&format!("    n{};\n", id.0));
        }
        out.push_str("  }\n");
    }
    for node in graph.nodes() {
        let color = match node.kind.class() {
            OpClass::Source => "gray90",
            OpClass::LightElementwise => "white",
            OpClass::ExpensiveElementwise => "lightsalmon",
            OpClass::Reduction => "lightblue",
            OpClass::DataMovement => "lightyellow",
            OpClass::ComputeIntensive => "plum",
        };
        out.push_str(&format!(
            "  n{} [label=\"{}\\n{} {}\", fillcolor={}, style=filled];\n",
            node.id.0,
            node.name,
            node.kind.name(),
            node.shape,
            color
        ));
    }
    for node in graph.nodes() {
        for &inp in &node.inputs {
            out.push_str(&format!("  n{} -> n{};\n", inp.0, node.id.0));
        }
    }
    out.push_str("}\n");
    out
}

fn palette(i: usize) -> &'static str {
    const COLORS: [&str; 6] = [
        "azure2", "honeydew2", "lavender", "mistyrose", "lightcyan", "seashell2",
    ];
    COLORS[i % COLORS.len()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, OpKind, Shape};

    #[test]
    fn dot_contains_nodes_edges_clusters() {
        let mut g = Graph::new("t");
        let p = g.param(Shape::new(vec![4]), DType::F32, "p");
        let a = g.unary(OpKind::Exp, p, "a");
        let b = g.unary(OpKind::Neg, a, "b");
        let dot = to_dot(&g, &[("fusion.0".to_string(), vec![a, b])]);
        assert!(dot.starts_with("digraph G {"));
        assert!(dot.contains("subgraph cluster_0"));
        assert!(dot.contains("fusion.0"));
        assert!(dot.contains("n0 -> n1"));
        assert!(dot.contains("n1 -> n2"));
        assert!(dot.trim_end().ends_with('}'));
    }

    #[test]
    fn class_colors_assigned() {
        let mut g = Graph::new("c");
        let p = g.param(Shape::new(vec![4, 4]), DType::F32, "p");
        let e = g.unary(OpKind::Tanh, p, "e");
        let _ = g.matmul(p, e, "m");
        let dot = to_dot(&g, &[]);
        assert!(dot.contains("lightsalmon")); // expensive elementwise
        assert!(dot.contains("plum")); // compute intensive
    }
}
