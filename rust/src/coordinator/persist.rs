//! Persistent plan cache: tuned fusion plans survive process restarts.
//!
//! The paper's deployment story (§7.5) is "tune-once-run-many-times":
//! a training job tunes in its first iteration and reuses the result
//! for days. A production service restarting should not pay the tuning
//! time again, so the coordinator can snapshot its compilation cache —
//! per graph-hash, the technique and every fusion pattern's node list —
//! to a JSON file, and warm-start from it: the plan is re-validated
//! against the (re-built) graph and re-lowered to kernels, which is
//! orders of magnitude cheaper than re-running the explorer.

use super::cache::GraphKey;
use crate::explorer::{AbsorbedAnchor, FusionPattern, FusionPlan};
use crate::gpu::DeviceSpec;
use crate::graph::{Graph, NodeId};
use crate::pipeline::{lower, OptimizedProgram, Tech};
use crate::util::json::JsonValue;
use crate::workloads::Workload;
use std::collections::HashMap;
use std::path::Path;

/// A persisted plan: the graph fingerprint it was tuned for + the
/// pattern node lists. Node ids are stable because workload builders
/// are deterministic; `restore` re-validates before trusting them.
#[derive(Debug, Clone)]
pub struct PersistedPlan {
    pub key: GraphKey,
    pub graph_len: usize,
    pub tech: Tech,
    pub patterns: Vec<Vec<u32>>,
    /// Absorbed GEMM boundaries as `(anchor, epilogue, prologue)` node
    /// ids (pattern `min_id`s for the sides); restoring without these
    /// would silently re-lower an absorbed plan in its cut form.
    pub absorbed: Vec<(u32, Option<u32>, Option<u32>)>,
}

/// On-disk snapshot of tuned plans, keyed by graph hash.
#[derive(Debug, Clone, Default)]
pub struct PlanStore {
    plans: HashMap<u64, PersistedPlan>,
}

impl PlanStore {
    /// Empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of persisted plans.
    pub fn len(&self) -> usize {
        self.plans.len()
    }

    /// True when no plans are stored.
    pub fn is_empty(&self) -> bool {
        self.plans.is_empty()
    }

    /// Record a tuned program for a workload graph.
    pub fn insert(&mut self, graph: &Graph, prog: &OptimizedProgram) {
        let key = GraphKey::of(graph);
        self.plans.insert(
            key.0,
            PersistedPlan {
                key,
                graph_len: graph.len(),
                tech: prog.tech,
                patterns: prog
                    .plan
                    .patterns
                    .iter()
                    .map(|p| p.nodes().iter().map(|n| n.idx() as u32).collect())
                    .collect(),
                absorbed: prog
                    .plan
                    .absorbed
                    .iter()
                    .map(|a| {
                        (
                            a.anchor.idx() as u32,
                            a.epilogue.map(|n| n.idx() as u32),
                            a.prologue.map(|n| n.idx() as u32),
                        )
                    })
                    .collect(),
            },
        );
    }

    /// Look up a persisted plan by graph hash.
    pub fn get(&self, graph: &Graph) -> Option<&PersistedPlan> {
        self.plans.get(&GraphKey::of(graph).0)
    }

    /// Re-materialize an [`OptimizedProgram`] for `workload` from a
    /// persisted plan: validate every pattern against the live graph
    /// (ids in range, disjoint, acyclic) and re-lower to kernels.
    /// Returns `None` when no plan matches or validation fails (stale
    /// snapshot after a model change — the caller re-tunes).
    pub fn restore(
        &self,
        workload: &Workload,
        device: &DeviceSpec,
    ) -> Option<OptimizedProgram> {
        let graph = &workload.graph;
        let saved = self.get(graph)?;
        if saved.graph_len != graph.len() {
            return None;
        }
        let patterns: Vec<FusionPattern> = saved
            .patterns
            .iter()
            .map(|nodes| {
                FusionPattern::new(nodes.iter().map(|&i| NodeId(i)).collect())
            })
            .collect();
        // Validate: ids in range and every pattern still legal.
        for p in &patterns {
            if p.nodes().iter().any(|n| n.idx() >= graph.len()) || !p.is_valid(graph) {
                return None;
            }
        }
        let absorbed: Vec<AbsorbedAnchor> = saved
            .absorbed
            .iter()
            .map(|&(anchor, ep, pro)| AbsorbedAnchor {
                anchor: NodeId(anchor),
                epilogue: ep.map(NodeId),
                prologue: pro.map(NodeId),
            })
            .collect();
        for a in &absorbed {
            let ids = [Some(a.anchor), a.epilogue, a.prologue];
            if ids.iter().flatten().any(|n| n.idx() >= graph.len()) {
                return None;
            }
        }
        let plan = FusionPlan { patterns, absorbed, footprint_pruned: 0 };
        if !plan.is_disjoint() {
            return None;
        }
        let kernels = lower(graph, &plan, device, saved.tech, workload.loop_kind);
        Some(OptimizedProgram { tech: saved.tech, plan, kernels })
    }

    /// Serialize to JSON.
    pub fn to_json(&self) -> JsonValue {
        let mut entries: Vec<&PersistedPlan> = self.plans.values().collect();
        entries.sort_by_key(|p| p.key.0);
        let arr = entries
            .into_iter()
            .map(|p| {
                let mut o = JsonValue::obj();
                // Hex string: u64 hashes exceed f64's 53-bit integer
                // range, so a numeric key would corrupt on roundtrip.
                o.set("key", format!("{:016x}", p.key.0))
                    .set("graph_len", p.graph_len)
                    .set("tech", p.tech.name())
                    .set(
                        "patterns",
                        JsonValue::Arr(
                            p.patterns
                                .iter()
                                .map(|pat| {
                                    JsonValue::Arr(
                                        pat.iter().map(|&n| JsonValue::Num(n as f64)).collect(),
                                    )
                                })
                                .collect(),
                        ),
                    )
                    // `[anchor, epilogue, prologue]` triples; -1 marks
                    // an unabsorbed side. Absent in version-1 snapshots
                    // written before cross-GEMM stitching → empty.
                    .set(
                        "absorbed",
                        JsonValue::Arr(
                            p.absorbed
                                .iter()
                                .map(|&(a, ep, pro)| {
                                    let side = |v: Option<u32>| {
                                        JsonValue::Num(v.map_or(-1.0, |x| x as f64))
                                    };
                                    JsonValue::Arr(vec![
                                        JsonValue::Num(a as f64),
                                        side(ep),
                                        side(pro),
                                    ])
                                })
                                .collect(),
                        ),
                    );
                o
            })
            .collect();
        let mut root = JsonValue::obj();
        root.set("version", 1usize).set("plans", JsonValue::Arr(arr));
        root
    }

    /// Deserialize from JSON (inverse of [`Self::to_json`]).
    pub fn from_json(v: &JsonValue) -> Result<Self, String> {
        if v.get("version").and_then(|x| x.as_usize()) != Some(1) {
            return Err("unsupported plan-store version".into());
        }
        let mut store = PlanStore::new();
        for p in v.get("plans").map(|x| x.items()).unwrap_or(&[]) {
            let key = p
                .get("key")
                .and_then(|x| x.as_str())
                .and_then(|s| u64::from_str_radix(s, 16).ok())
                .ok_or("plan missing key")?;
            let graph_len = p
                .get("graph_len")
                .and_then(|x| x.as_usize())
                .ok_or("plan missing graph_len")?;
            let tech = match p.get("tech").and_then(|x| x.as_str()) {
                Some("TF") => Tech::Tf,
                Some("XLA") => Tech::Xla,
                Some("FS") => Tech::Fs,
                other => return Err(format!("bad tech {other:?}")),
            };
            let patterns = p
                .get("patterns")
                .map(|x| {
                    x.items()
                        .iter()
                        .map(|pat| {
                            pat.items()
                                .iter()
                                .filter_map(|n| n.as_f64().map(|f| f as u32))
                                .collect::<Vec<u32>>()
                        })
                        .collect::<Vec<_>>()
                })
                .unwrap_or_default();
            let absorbed = p
                .get("absorbed")
                .map(|x| {
                    x.items()
                        .iter()
                        .filter_map(|t| {
                            let nums: Vec<f64> =
                                t.items().iter().filter_map(|n| n.as_f64()).collect();
                            let side = |f: f64| (f >= 0.0).then_some(f as u32);
                            match nums.as_slice() {
                                [a, ep, pro] if *a >= 0.0 => {
                                    Some((*a as u32, side(*ep), side(*pro)))
                                }
                                _ => None,
                            }
                        })
                        .collect::<Vec<_>>()
                })
                .unwrap_or_default();
            store.plans.insert(
                key,
                PersistedPlan { key: GraphKey(key), graph_len, tech, patterns, absorbed },
            );
        }
        Ok(store)
    }

    /// Write the store to disk (pretty JSON).
    pub fn save(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_pretty())
    }

    /// Load a store from disk.
    pub fn load(path: impl AsRef<Path>) -> Result<Self, String> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| format!("read {}: {e}", path.as_ref().display()))?;
        let v = JsonValue::parse(&text)?;
        Self::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explorer::ExploreOptions;
    use crate::graph::{DType, OpKind, Shape};
    use crate::pipeline::optimize;
    use crate::workloads::{blocks, LoopKind, Mode};

    fn ln_workload() -> Workload {
        let mut g = Graph::new("LN");
        let x = g.param(Shape::new(vec![4096, 768]), DType::F32, "x");
        let _ = blocks::layer_norm(&mut g, x, "ln");
        Workload {
            name: "LN",
            field: "micro",
            mode: Mode::Infer,
            batch: 32,
            loop_kind: LoopKind::None,
            graph: g,
        }
    }

    #[test]
    fn roundtrip_restores_identical_plan() {
        let w = ln_workload();
        let device = DeviceSpec::v100();
        let prog = optimize(&w, &device, Tech::Fs, &ExploreOptions::default());
        let mut store = PlanStore::new();
        store.insert(&w.graph, &prog);

        let json = store.to_json().to_pretty();
        let loaded = PlanStore::from_json(&JsonValue::parse(&json).unwrap()).unwrap();
        let restored = loaded.restore(&w, &device).expect("plan restores");
        assert_eq!(restored.tech, Tech::Fs);
        assert_eq!(restored.plan.patterns.len(), prog.plan.patterns.len());
        assert_eq!(restored.kernels.len(), prog.kernels.len());
    }

    #[test]
    fn roundtrip_preserves_absorbed_boundaries() {
        // A GEMM with a bias+relu epilogue absorbs its boundary; a
        // restored plan must re-lower to the same merged kernel set,
        // not silently fall back to the cut form.
        let mut g = Graph::new("GE");
        let x = g.param(Shape::new(vec![512, 64]), DType::F32, "x");
        let wt = g.param(Shape::new(vec![64, 256]), DType::F32, "w");
        let mm = g.matmul(x, wt, "mm");
        let b = g.param(Shape::new(vec![256]), DType::F32, "b");
        let bb = g.add(
            OpKind::Broadcast,
            DType::F32,
            Shape::new(vec![512, 256]),
            vec![b],
            "bb",
        );
        let add = g.binary(OpKind::Add, mm, bb, "add");
        let _ = g.unary(OpKind::Relu, add, "relu");
        let w = Workload {
            name: "GE",
            field: "micro",
            mode: Mode::Infer,
            batch: 1,
            loop_kind: LoopKind::None,
            graph: g,
        };
        let device = DeviceSpec::v100();
        let prog = optimize(&w, &device, Tech::Fs, &ExploreOptions::default());
        assert!(prog.plan.absorbed_boundaries() > 0, "probe must absorb");

        let mut store = PlanStore::new();
        store.insert(&w.graph, &prog);
        let json = store.to_json().to_pretty();
        let loaded = PlanStore::from_json(&JsonValue::parse(&json).unwrap()).unwrap();
        let restored = loaded.restore(&w, &device).expect("plan restores");
        assert_eq!(restored.plan.absorbed, prog.plan.absorbed);
        assert_eq!(restored.kernels.len(), prog.kernels.len());
        let kernels = &restored.kernels;
        assert!(kernels.iter().any(|k| k.name.starts_with("fs.gemm_epilogue.")));
    }

    #[test]
    fn stale_snapshot_rejected_on_graph_change() {
        let w = ln_workload();
        let device = DeviceSpec::v100();
        let prog = optimize(&w, &device, Tech::Fs, &ExploreOptions::default());
        let mut store = PlanStore::new();
        store.insert(&w.graph, &prog);

        // "Model change": a grown graph has a different hash → miss.
        let mut w2 = ln_workload();
        let extra = w2.graph.param(Shape::new(vec![4]), DType::F32, "p2");
        let _ = w2.graph.unary(OpKind::Neg, extra, "n2");
        assert!(store.restore(&w2, &device).is_none());
    }

    #[test]
    fn corrupted_pattern_rejected() {
        let w = ln_workload();
        let device = DeviceSpec::v100();
        let prog = optimize(&w, &device, Tech::Fs, &ExploreOptions::default());
        let mut store = PlanStore::new();
        store.insert(&w.graph, &prog);
        // Corrupt: out-of-range node id.
        let key = GraphKey::of(&w.graph).0;
        store.plans.get_mut(&key).unwrap().patterns[0][0] = 9999;
        assert!(store.restore(&w, &device).is_none());
    }

    #[test]
    fn save_and_load_via_disk() {
        let w = ln_workload();
        let device = DeviceSpec::v100();
        let prog = optimize(&w, &device, Tech::Fs, &ExploreOptions::default());
        let mut store = PlanStore::new();
        store.insert(&w.graph, &prog);
        let path = std::env::temp_dir().join("fstitch_plan_store_test.json");
        store.save(&path).unwrap();
        let loaded = PlanStore::load(&path).unwrap();
        assert_eq!(loaded.len(), 1);
        assert!(loaded.restore(&w, &device).is_some());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bad_version_rejected() {
        let v = JsonValue::parse(r#"{"version": 2, "plans": []}"#).unwrap();
        assert!(PlanStore::from_json(&v).is_err());
    }
}
