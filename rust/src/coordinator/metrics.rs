//! Serving metrics: iteration latencies, throughput, optimization
//! status transitions (used by the e2e example and the fleet bench).

use crate::util::JsonValue;
use std::sync::Mutex;

/// Accumulated service metrics. Interior-mutable so the service can
/// record from its serving loop while holding only `&self`.
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default, Clone)]
struct Inner {
    /// Per-iteration simulated latency (ms), in execution order.
    latencies_ms: Vec<f64>,
    /// Iteration index at which the optimized program was hot-swapped in
    /// (None while still running the fallback).
    swap_iteration: Option<usize>,
    /// Background optimization wall time, ms.
    optimize_wall_ms: Option<f64>,
}

impl ServiceMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one served iteration.
    pub fn record_iteration(&self, latency_ms: f64) {
        self.inner.lock().unwrap().latencies_ms.push(latency_ms);
    }

    /// Record that the optimized program took over at iteration `it`.
    pub fn record_swap(&self, it: usize, optimize_wall_ms: f64) {
        let mut inner = self.inner.lock().unwrap();
        inner.swap_iteration = Some(it);
        inner.optimize_wall_ms = Some(optimize_wall_ms);
    }

    /// Iterations served so far.
    pub fn iterations(&self) -> usize {
        self.inner.lock().unwrap().latencies_ms.len()
    }

    /// Iteration index of the hot swap.
    pub fn swap_iteration(&self) -> Option<usize> {
        self.inner.lock().unwrap().swap_iteration
    }

    /// Mean latency before/after the swap (ms); after is None until the
    /// swap happened.
    pub fn mean_before_after(&self) -> (f64, Option<f64>) {
        let inner = self.inner.lock().unwrap();
        let swap = inner.swap_iteration.unwrap_or(inner.latencies_ms.len());
        let mean = |xs: &[f64]| {
            if xs.is_empty() {
                0.0
            } else {
                xs.iter().sum::<f64>() / xs.len() as f64
            }
        };
        let before = mean(&inner.latencies_ms[..swap.min(inner.latencies_ms.len())]);
        let after = if swap < inner.latencies_ms.len() {
            Some(mean(&inner.latencies_ms[swap..]))
        } else {
            None
        };
        (before, after)
    }

    /// JSON snapshot for reports.
    pub fn to_json(&self) -> JsonValue {
        let (before, after) = self.mean_before_after();
        let inner = self.inner.lock().unwrap();
        let mut o = JsonValue::obj();
        o.set("iterations", inner.latencies_ms.len());
        o.set("mean_before_ms", before);
        match after {
            Some(a) => o.set("mean_after_ms", a),
            None => o.set("mean_after_ms", JsonValue::Null),
        };
        match inner.swap_iteration {
            Some(s) => o.set("swap_iteration", s),
            None => o.set("swap_iteration", JsonValue::Null),
        };
        match inner.optimize_wall_ms {
            Some(m) => o.set("optimize_wall_ms", m),
            None => o.set("optimize_wall_ms", JsonValue::Null),
        };
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn before_after_split() {
        let m = ServiceMetrics::new();
        for _ in 0..5 {
            m.record_iteration(10.0);
        }
        m.record_swap(5, 123.0);
        for _ in 0..5 {
            m.record_iteration(6.0);
        }
        let (before, after) = m.mean_before_after();
        assert!((before - 10.0).abs() < 1e-9);
        assert!((after.unwrap() - 6.0).abs() < 1e-9);
        assert_eq!(m.iterations(), 10);
        assert_eq!(m.swap_iteration(), Some(5));
    }

    #[test]
    fn no_swap_yet() {
        let m = ServiceMetrics::new();
        m.record_iteration(4.0);
        let (before, after) = m.mean_before_after();
        assert!((before - 4.0).abs() < 1e-9);
        assert!(after.is_none());
    }

    #[test]
    fn json_snapshot_fields() {
        let m = ServiceMetrics::new();
        m.record_iteration(1.0);
        let j = m.to_json();
        assert!(j.get("iterations").is_some());
        assert!(j.get("mean_before_ms").is_some());
    }
}
