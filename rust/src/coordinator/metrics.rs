//! Serving metrics: iteration latencies, throughput, optimization
//! status transitions (used by the e2e example and the fleet bench).

use crate::obs::{LockSnapshot, LockStats};
use crate::util::{summarize_owned, JsonValue, Summary};
use std::sync::Mutex;

/// Accumulated service metrics. Interior-mutable so the service can
/// record from its serving loop while holding only `&self`.
#[derive(Debug)]
pub struct ServiceMetrics {
    inner: Mutex<Inner>,
    /// Contention profile of `inner` (the `service_metrics` row in the
    /// fleet's observability report).
    lock: LockStats,
}

impl Default for ServiceMetrics {
    fn default() -> Self {
        ServiceMetrics { inner: Mutex::default(), lock: LockStats::new("service_metrics") }
    }
}

/// O(1) running latency summary, maintained incrementally on every
/// recorded iteration — snapshots never clone the sample vector.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IterStats {
    pub count: usize,
    pub sum_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
}

impl IterStats {
    pub fn mean_ms(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ms / self.count as f64
        }
    }
}

#[derive(Debug, Clone)]
struct Inner {
    /// Per-iteration simulated latency (ms), in execution order.
    latencies_ms: Vec<f64>,
    /// Running count/sum/min/max over `latencies_ms`.
    stats: IterStats,
    /// Iteration index at which the optimized program was hot-swapped in
    /// (None while still running the fallback).
    swap_iteration: Option<usize>,
    /// Background optimization wall time, ms.
    optimize_wall_ms: Option<f64>,
}

impl Default for Inner {
    fn default() -> Self {
        Inner {
            latencies_ms: Vec::new(),
            // min starts at +inf so the first sample always takes it;
            // `iter_stats` normalizes the empty case back to 0.0.
            stats: IterStats { count: 0, sum_ms: 0.0, min_ms: f64::INFINITY, max_ms: 0.0 },
            swap_iteration: None,
            optimize_wall_ms: None,
        }
    }
}

impl ServiceMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one served iteration.
    pub fn record_iteration(&self, latency_ms: f64) {
        let mut inner = self.lock.lock(&self.inner);
        inner.latencies_ms.push(latency_ms);
        inner.stats.count += 1;
        inner.stats.sum_ms += latency_ms;
        inner.stats.min_ms = inner.stats.min_ms.min(latency_ms);
        inner.stats.max_ms = inner.stats.max_ms.max(latency_ms);
    }

    /// Record that the optimized program took over at iteration `it`.
    pub fn record_swap(&self, it: usize, optimize_wall_ms: f64) {
        let mut inner = self.lock.lock(&self.inner);
        inner.swap_iteration = Some(it);
        inner.optimize_wall_ms = Some(optimize_wall_ms);
    }

    /// Iterations served so far.
    pub fn iterations(&self) -> usize {
        self.lock.lock(&self.inner).latencies_ms.len()
    }

    /// Iteration index of the hot swap.
    pub fn swap_iteration(&self) -> Option<usize> {
        self.lock.lock(&self.inner).swap_iteration
    }

    /// Snapshot of the recorded per-iteration latencies (ms). This
    /// clones the full series — report paths that only need summary
    /// statistics should use [`Self::iter_stats`] (O(1)) or
    /// [`Self::merged_summary`] (one pass) instead.
    pub fn latencies(&self) -> Vec<f64> {
        self.lock.lock(&self.inner).latencies_ms.clone()
    }

    /// The incrementally maintained count/sum/min/max snapshot.
    pub fn iter_stats(&self) -> IterStats {
        let mut s = self.lock.lock(&self.inner).stats;
        if s.count == 0 {
            s.min_ms = 0.0;
        }
        s
    }

    /// Contention profile of this object's mutex.
    pub fn lock_profile(&self) -> LockSnapshot {
        self.lock.snapshot()
    }

    /// Fleet-wide latency summary over many per-device metrics in one
    /// pass: a single concatenation plus one in-place sort, replacing
    /// the aggregate-then-`latencies()` path that copied every sample
    /// twice per report.
    pub fn merged_summary<'a>(parts: impl IntoIterator<Item = &'a ServiceMetrics>) -> Summary {
        let mut all: Vec<f64> = Vec::new();
        for m in parts {
            let inner = m.lock.lock(&m.inner);
            all.extend_from_slice(&inner.latencies_ms);
        }
        summarize_owned(all)
    }

    /// Latency percentile over all recorded iterations (`q` in [0, 1]);
    /// `None` until at least one iteration was recorded. For several
    /// quantiles of the same series use [`Self::latency_percentiles`],
    /// which sorts once.
    pub fn latency_percentile(&self, q: f64) -> Option<f64> {
        self.latency_percentiles(&[q]).map(|v| v[0])
    }

    /// Sort-once batch of latency percentiles (`None` until at least one
    /// iteration was recorded) — the report paths ask for p50/p95/p99 of
    /// series with tens of thousands of samples, and one clone + sort
    /// serves the whole batch.
    pub fn latency_percentiles(&self, qs: &[f64]) -> Option<Vec<f64>> {
        let inner = self.lock.lock(&self.inner);
        if inner.latencies_ms.is_empty() {
            None
        } else {
            Some(crate::util::percentiles(&inner.latencies_ms, qs))
        }
    }

    /// Fold another metrics object's samples into this one — the fleet
    /// layer aggregates per-device `ServiceMetrics` into one fleet-wide
    /// view this way. Optimization wall times sum; the swap marker is
    /// dropped: it is an index into one session's latency sequence, and
    /// any index into the concatenation would misattribute samples
    /// around it (`mean_before_after` on an aggregate would lie).
    pub fn absorb(&self, other: &ServiceMetrics) {
        let o = other.lock.lock(&other.inner).clone();
        let mut inner = self.lock.lock(&self.inner);
        inner.latencies_ms.extend_from_slice(&o.latencies_ms);
        inner.stats.count += o.stats.count;
        inner.stats.sum_ms += o.stats.sum_ms;
        inner.stats.min_ms = inner.stats.min_ms.min(o.stats.min_ms);
        inner.stats.max_ms = inner.stats.max_ms.max(o.stats.max_ms);
        inner.swap_iteration = None;
        if let Some(w) = o.optimize_wall_ms {
            inner.optimize_wall_ms = Some(inner.optimize_wall_ms.unwrap_or(0.0) + w);
        }
    }

    /// Aggregate many metrics objects into a fresh fleet-wide one.
    pub fn aggregate<'a>(parts: impl IntoIterator<Item = &'a ServiceMetrics>) -> ServiceMetrics {
        let total = ServiceMetrics::new();
        for m in parts {
            total.absorb(m);
        }
        total
    }

    /// Mean latency before/after the swap (ms); after is None until the
    /// swap happened.
    pub fn mean_before_after(&self) -> (f64, Option<f64>) {
        let inner = self.lock.lock(&self.inner);
        let swap = inner.swap_iteration.unwrap_or(inner.latencies_ms.len());
        let mean = |xs: &[f64]| {
            if xs.is_empty() {
                0.0
            } else {
                xs.iter().sum::<f64>() / xs.len() as f64
            }
        };
        let before = mean(&inner.latencies_ms[..swap.min(inner.latencies_ms.len())]);
        let after = if swap < inner.latencies_ms.len() {
            Some(mean(&inner.latencies_ms[swap..]))
        } else {
            None
        };
        (before, after)
    }

    /// JSON snapshot for reports.
    pub fn to_json(&self) -> JsonValue {
        let (before, after) = self.mean_before_after();
        let inner = self.lock.lock(&self.inner);
        let mut o = JsonValue::obj();
        o.set("iterations", inner.latencies_ms.len());
        o.set("mean_before_ms", before);
        match after {
            Some(a) => o.set("mean_after_ms", a),
            None => o.set("mean_after_ms", JsonValue::Null),
        };
        match inner.swap_iteration {
            Some(s) => o.set("swap_iteration", s),
            None => o.set("swap_iteration", JsonValue::Null),
        };
        match inner.optimize_wall_ms {
            Some(m) => o.set("optimize_wall_ms", m),
            None => o.set("optimize_wall_ms", JsonValue::Null),
        };
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn before_after_split() {
        let m = ServiceMetrics::new();
        for _ in 0..5 {
            m.record_iteration(10.0);
        }
        m.record_swap(5, 123.0);
        for _ in 0..5 {
            m.record_iteration(6.0);
        }
        let (before, after) = m.mean_before_after();
        assert!((before - 10.0).abs() < 1e-9);
        assert!((after.unwrap() - 6.0).abs() < 1e-9);
        assert_eq!(m.iterations(), 10);
        assert_eq!(m.swap_iteration(), Some(5));
    }

    #[test]
    fn no_swap_yet() {
        let m = ServiceMetrics::new();
        m.record_iteration(4.0);
        let (before, after) = m.mean_before_after();
        assert!((before - 4.0).abs() < 1e-9);
        assert!(after.is_none());
    }

    #[test]
    fn json_snapshot_fields() {
        let m = ServiceMetrics::new();
        m.record_iteration(1.0);
        let j = m.to_json();
        assert!(j.get("iterations").is_some());
        assert!(j.get("mean_before_ms").is_some());
    }

    #[test]
    fn latency_percentiles() {
        let m = ServiceMetrics::new();
        assert!(m.latency_percentile(0.5).is_none());
        for i in 1..=100 {
            m.record_iteration(i as f64);
        }
        let p50 = m.latency_percentile(0.5).unwrap();
        assert!((49.0..=51.0).contains(&p50), "p50={p50}");
        let p99 = m.latency_percentile(0.99).unwrap();
        assert!(p99 >= 98.0, "p99={p99}");
        // Batch form sorts once and agrees with the per-call form.
        let batch = m.latency_percentiles(&[0.5, 0.99]).unwrap();
        assert_eq!(batch, vec![p50, p99]);
        assert!(ServiceMetrics::new().latency_percentiles(&[0.5]).is_none());
        assert_eq!(m.latencies().len(), 100);
    }

    #[test]
    fn incremental_stats_track_the_sample_vector() {
        let m = ServiceMetrics::new();
        let empty = m.iter_stats();
        assert_eq!((empty.count, empty.min_ms, empty.max_ms), (0, 0.0, 0.0));
        assert_eq!(empty.mean_ms(), 0.0);
        for v in [4.0, 2.0, 9.0] {
            m.record_iteration(v);
        }
        let s = m.iter_stats();
        assert_eq!(s.count, 3);
        assert!((s.sum_ms - 15.0).abs() < 1e-12);
        assert_eq!((s.min_ms, s.max_ms), (2.0, 9.0));
        assert!((s.mean_ms() - 5.0).abs() < 1e-12);
        // absorb folds the incremental stats, not just the vector.
        let other = ServiceMetrics::new();
        other.record_iteration(1.0);
        m.absorb(&other);
        let s = m.iter_stats();
        assert_eq!((s.count, s.min_ms, s.max_ms), (4, 1.0, 9.0));
        // The lock profile counts every recorded iteration.
        assert!(m.lock_profile().acquisitions >= 6);
        assert_eq!(m.lock_profile().name, "service_metrics");
    }

    #[test]
    fn merged_summary_matches_aggregate_path() {
        let a = ServiceMetrics::new();
        let b = ServiceMetrics::new();
        for i in 1..=50 {
            a.record_iteration(i as f64);
            b.record_iteration((i + 50) as f64);
        }
        let merged = ServiceMetrics::merged_summary([&a, &b]);
        let old = crate::util::summarize(&ServiceMetrics::aggregate([&a, &b]).latencies());
        assert_eq!(merged, old, "one-pass summary must equal the clone-twice path");
        assert_eq!(merged.n, 100);
        assert_eq!((merged.min, merged.max), (1.0, 100.0));
    }

    #[test]
    fn aggregate_merges_samples_sums_wall_and_drops_swap_markers() {
        let a = ServiceMetrics::new();
        let b = ServiceMetrics::new();
        for _ in 0..4 {
            a.record_iteration(10.0);
            b.record_iteration(20.0);
        }
        a.record_swap(7, 100.0);
        b.record_swap(3, 50.0);
        let total = ServiceMetrics::aggregate([&a, &b]);
        assert_eq!(total.iterations(), 8);
        // Swap indices are per-session positions: meaningless in the
        // concatenation, so the aggregate drops them...
        assert_eq!(total.swap_iteration(), None);
        // ...which keeps mean_before_after honest (all samples count
        // as one population instead of splitting at a bogus index).
        let (before, after) = total.mean_before_after();
        assert!((before - 15.0).abs() < 1e-9);
        assert!(after.is_none());
        // Optimization wall time sums.
        let j = total.to_json();
        assert_eq!(j.get("optimize_wall_ms").and_then(|v| v.as_f64()), Some(150.0));
    }
}
