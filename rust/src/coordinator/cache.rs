//! Compilation cache: tune-once-run-many (§7.5).
//!
//! Deep learning workloads re-execute the same graph thousands of times;
//! FusionStitching (like XLA) compiles on first sight and caches by
//! graph identity. The key hashes the graph *structure* (op kinds,
//! shapes, edges), so retracing the same model hits the cache.

use crate::graph::Graph;
use crate::pipeline::OptimizedProgram;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Structural hash of a graph (FNV-1a over kinds/shapes/edges).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GraphKey(pub u64);

impl GraphKey {
    /// Hash a graph's structure.
    pub fn of(graph: &Graph) -> Self {
        use crate::util::hash::{fnv1a_u64, FNV_OFFSET};
        let mut h = FNV_OFFSET;
        let mut mix = |v: u64| h = fnv1a_u64(h, v);
        mix(graph.len() as u64);
        for node in graph.nodes() {
            mix(kind_tag(&node.kind));
            mix(node.dtype.size_bytes() as u64);
            for &d in node.shape.dims() {
                mix(d as u64 + 1);
            }
            for &inp in &node.inputs {
                mix(inp.0 as u64 + 0x9E37);
            }
        }
        GraphKey(h)
    }
}

/// Shape-polymorphic identity of a graph: a shape-erased *structure*
/// key plus a power-of-two *shape bucket*.
///
/// [`GraphKey`] hashes exact shapes, so realistic traffic with varied
/// batch sizes and sequence lengths would pay a full exploration per
/// distinct shape. `ShapeClass` splits that identity in two:
///
/// * `structure` — FNV-1a over op kinds (including `Transpose{perm}` /
///   `Reduce{op, axes}` payloads, positionally — the PR 3 collision
///   class), dtypes, ranks and edges, but **no dimension values**. Two
///   instantiations of one parameterized builder at different
///   (batch, seq) share it; any structural difference separates it.
/// * `bucket` — FNV-1a over every dimension rounded up to its power of
///   two. Sibling shapes inside one bucket are close enough that a plan
///   explored at one serves the others after a launch-dimension-only
///   retune (`pipeline::reshape_program`); crossing a power-of-two
///   boundary changes the bucket and forces a fresh exploration.
///
/// Graphs with equal [`GraphKey`] always have equal `ShapeClass`; the
/// converse direction (same class, different exact key) is exactly the
/// fleet store's `BucketHit` reuse tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ShapeClass {
    /// Shape-erased structure hash.
    pub structure: u64,
    /// Power-of-two bucket hash over all dimension values.
    pub bucket: u64,
}

impl ShapeClass {
    /// Bucket a single dimension: the next power of two at or above it
    /// (zero-sized dims bucket as 1, like scalars).
    pub fn bucket_dim(d: usize) -> u64 {
        d.max(1).next_power_of_two() as u64
    }

    /// Compute both halves in one graph walk.
    pub fn of(graph: &Graph) -> Self {
        use crate::util::hash::{fnv1a_u64, FNV_OFFSET};
        let mut s = FNV_OFFSET;
        let mut b = FNV_OFFSET;
        s = fnv1a_u64(s, graph.len() as u64);
        for node in graph.nodes() {
            s = fnv1a_u64(s, kind_tag(&node.kind));
            s = fnv1a_u64(s, node.dtype.size_bytes() as u64);
            // Rank stays in the structure (it changes the generated
            // kernel's loop nest); the dim values only feed the bucket.
            s = fnv1a_u64(s, node.shape.rank() as u64 + 1);
            for &inp in &node.inputs {
                s = fnv1a_u64(s, inp.0 as u64 + 0x9E37);
            }
            for &d in node.shape.dims() {
                b = fnv1a_u64(b, Self::bucket_dim(d));
            }
        }
        ShapeClass { structure: s, bucket: b }
    }
}

fn kind_tag(kind: &crate::graph::OpKind) -> u64 {
    use crate::graph::OpKind::*;
    use crate::util::hash::{fnv1a_u64, FNV_OFFSET};
    // A stable discriminant (mem::discriminant has no portable value).
    let base = match kind {
        Parameter => 1,
        Constant => 2,
        Add => 3,
        Sub => 4,
        Mul => 5,
        Div => 6,
        Maximum => 7,
        Minimum => 8,
        Neg => 9,
        Abs => 10,
        Compare => 11,
        Select => 12,
        Convert => 13,
        Relu => 14,
        Exp => 15,
        Log => 16,
        Tanh => 17,
        Sqrt => 18,
        Rsqrt => 19,
        Power => 20,
        Sigmoid => 21,
        Erf => 22,
        Gelu => 23,
        Tan => 24,
        Reduce { op, axes } => {
            // Positional FNV-1a mix: the old order-insensitive element
            // *sum* collided axes splits like {0,3} vs {1,2}, so graphs
            // differing only there hashed to one key and the cache
            // could serve the wrong program.
            let mut h = fnv1a_u64(FNV_OFFSET, 25);
            h = fnv1a_u64(h, *op as u64 + 1);
            for &a in axes {
                h = fnv1a_u64(h, a as u64 + 1);
            }
            return h;
        }
        Broadcast => 26,
        Reshape => 27,
        Transpose { perm } => {
            // Positional mix: permutations are rearrangements of the
            // same elements, so any order-insensitive fold (the old
            // sum) collided *every* pair of same-rank permutations,
            // e.g. [0,2,1] vs [1,0,2].
            let mut h = fnv1a_u64(FNV_OFFSET, 28);
            for &p in perm {
                h = fnv1a_u64(h, p as u64 + 1);
            }
            return h;
        }
        Slice => 29,
        Gather => 30,
        Concat => 31,
        Pad => 32,
        Copy => 33,
        Iota => 34,
        MatMul => 35,
        BatchMatMul => 36,
        Conv => 37,
    };
    base
}

/// Map + counters under ONE lock. The counters used to live behind two
/// further mutexes, so a concurrent `stats()` could observe a *torn*
/// snapshot (a lookup's map access done but its counter bump pending —
/// hits + misses ≠ completed lookups). One lock makes every lookup
/// atomic with its accounting.
#[derive(Debug, Default)]
struct CacheState {
    map: HashMap<GraphKey, Arc<OptimizedProgram>>,
    hits: u64,
    misses: u64,
}

/// Thread-safe program cache with hit/miss accounting.
#[derive(Debug, Default)]
pub struct CompilationCache {
    state: Mutex<CacheState>,
}

impl CompilationCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Lookup; updates hit/miss counters atomically with the access.
    pub fn get(&self, key: GraphKey) -> Option<Arc<OptimizedProgram>> {
        let mut st = self.state.lock().unwrap();
        let found = st.map.get(&key).cloned();
        match &found {
            Some(_) => st.hits += 1,
            None => st.misses += 1,
        }
        found
    }

    /// Insert a compiled program.
    pub fn put(&self, key: GraphKey, prog: Arc<OptimizedProgram>) {
        self.state.lock().unwrap().map.insert(key, prog);
    }

    /// (hits, misses) — a consistent snapshot: both counters are read
    /// under the same lock every lookup updates them under.
    pub fn stats(&self) -> (u64, u64) {
        let st = self.state.lock().unwrap();
        (st.hits, st.misses)
    }

    /// Entry count.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().map.len()
    }

    /// True when the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, OpKind, Shape};

    fn tiny(n: usize) -> Graph {
        let mut g = Graph::new("t");
        let mut cur = g.param(Shape::new(vec![8]), DType::F32, "p");
        for i in 0..n {
            cur = g.unary(OpKind::Relu, cur, format!("r{i}"));
        }
        g
    }

    #[test]
    fn same_structure_same_key() {
        assert_eq!(GraphKey::of(&tiny(3)), GraphKey::of(&tiny(3)));
    }

    #[test]
    fn different_structure_different_key() {
        assert_ne!(GraphKey::of(&tiny(3)), GraphKey::of(&tiny(4)));
        // Same node count, different op.
        let mut g = Graph::new("t");
        let p = g.param(Shape::new(vec![8]), DType::F32, "p");
        let _ = g.unary(OpKind::Exp, p, "e");
        let mut g2 = Graph::new("t");
        let p2 = g2.param(Shape::new(vec![8]), DType::F32, "p");
        let _ = g2.unary(OpKind::Tanh, p2, "t");
        assert_ne!(GraphKey::of(&g), GraphKey::of(&g2));
    }

    #[test]
    fn shape_changes_key() {
        let mut g = Graph::new("a");
        g.param(Shape::new(vec![8]), DType::F32, "p");
        let mut g2 = Graph::new("a");
        g2.param(Shape::new(vec![16]), DType::F32, "p");
        assert_ne!(GraphKey::of(&g), GraphKey::of(&g2));
    }

    #[test]
    fn transpose_perm_is_order_sensitive() {
        // Cube shape: every permutation of [4,4,4] preserves the output
        // shape, so only the perm itself can separate the keys — the
        // old sum-based tag collided (1+3+2 == 2+1+3).
        let build = |perm: Vec<usize>| {
            let mut g = Graph::new("t");
            let p = g.param(Shape::new(vec![4, 4, 4]), DType::F32, "p");
            let _ = g.add(
                OpKind::Transpose { perm },
                DType::F32,
                Shape::new(vec![4, 4, 4]),
                vec![p],
                "t",
            );
            GraphKey::of(&g)
        };
        assert_ne!(build(vec![0, 2, 1]), build(vec![1, 0, 2]));
        assert_eq!(build(vec![0, 2, 1]), build(vec![0, 2, 1]));
    }

    #[test]
    fn reduce_axes_split_changes_key() {
        use crate::graph::ReduceOp;
        // [2,2,2,2] reduced over {0,3} vs {1,2}: same output shape
        // [2,2], same combinator — the old sum-based tag collided
        // ((1+4) == (2+3)), so the cache could serve the wrong program.
        let build = |axes: Vec<usize>| {
            let mut g = Graph::new("r");
            let p = g.param(Shape::new(vec![2, 2, 2, 2]), DType::F32, "p");
            let _ = g.reduce(ReduceOp::Sum, p, axes, "r");
            GraphKey::of(&g)
        };
        assert_ne!(build(vec![0, 3]), build(vec![1, 2]));
        assert_eq!(build(vec![0, 3]), build(vec![0, 3]));
    }

    #[test]
    fn shape_class_erases_dims_but_keeps_structure() {
        // Same chain at different leading dims: exact keys differ, the
        // structure half matches, and the buckets differ across a
        // power-of-two boundary.
        let build = |rows: usize| {
            let mut g = Graph::new("t");
            let mut cur = g.param(Shape::new(vec![rows, 256]), DType::F32, "p");
            for i in 0..3 {
                cur = g.unary(OpKind::Relu, cur, format!("r{i}"));
            }
            (GraphKey::of(&g), ShapeClass::of(&g))
        };
        let (k64, c64) = build(64);
        let (k48, c48) = build(48);
        let (k128, c128) = build(128);
        assert_ne!(k64, k48);
        assert_eq!(c64.structure, c48.structure);
        assert_eq!(c64.structure, c128.structure);
        // 48 rounds up to 64: same bucket as 64, different from 128.
        assert_eq!(c48.bucket, c64.bucket);
        assert_ne!(c64.bucket, c128.bucket);
    }

    #[test]
    fn shape_class_structure_separates_op_payloads() {
        // Echo of PR 3's GraphKey collision class: permutations and
        // axes splits are *structure*, not shape — erasing dims must
        // not merge them back together.
        let transpose = |perm: Vec<usize>| {
            let mut g = Graph::new("t");
            let p = g.param(Shape::new(vec![4, 4, 4]), DType::F32, "p");
            let _ = g.add(
                OpKind::Transpose { perm },
                DType::F32,
                Shape::new(vec![4, 4, 4]),
                vec![p],
                "t",
            );
            ShapeClass::of(&g)
        };
        assert_ne!(
            transpose(vec![0, 2, 1]).structure,
            transpose(vec![1, 0, 2]).structure
        );
        use crate::graph::ReduceOp;
        let reduce = |axes: Vec<usize>| {
            let mut g = Graph::new("r");
            let p = g.param(Shape::new(vec![2, 2, 2, 2]), DType::F32, "p");
            let _ = g.reduce(ReduceOp::Sum, p, axes, "r");
            ShapeClass::of(&g)
        };
        assert_ne!(reduce(vec![0, 3]).structure, reduce(vec![1, 2]).structure);
        // Distinct op kinds separate too.
        let unary = |kind: OpKind| {
            let mut g = Graph::new("u");
            let p = g.param(Shape::new(vec![8, 8]), DType::F32, "p");
            let _ = g.unary(kind, p, "u");
            ShapeClass::of(&g)
        };
        assert_ne!(unary(OpKind::Exp).structure, unary(OpKind::Tanh).structure);
    }

    #[test]
    fn shape_class_pairs_never_collide_across_buckets_or_structures() {
        // Sweep a family of (structure, shape) pairs: every pair of
        // graphs must agree on (structure, bucket) exactly when they
        // have the same op chain and their dims round to the same
        // powers of two.
        use std::collections::HashMap;
        let build = |ops: usize, rows: usize| {
            let mut g = Graph::new("t");
            let mut cur = g.param(Shape::new(vec![rows, 128]), DType::F32, "p");
            for i in 0..ops {
                cur = g.unary(OpKind::Relu, cur, format!("r{i}"));
                if i == 0 {
                    let r = g.reduce(crate::graph::ReduceOp::Sum, cur, vec![1], "red");
                    cur = g.broadcast(r, Shape::new(vec![rows, 128]), "bc");
                }
            }
            ShapeClass::of(&g)
        };
        let mut seen: HashMap<(u64, u64), (usize, u64)> = HashMap::new();
        for ops in [2usize, 3, 4, 5] {
            for rows in [5usize, 17, 31, 32, 33, 48, 64, 65, 100, 128, 200, 256, 2000] {
                let c = build(ops, rows);
                let fingerprint = (ops, ShapeClass::bucket_dim(rows));
                match seen.get(&(c.structure, c.bucket)) {
                    Some(&prev) => assert_eq!(
                        prev, fingerprint,
                        "(structure, bucket) collided across distinct classes"
                    ),
                    None => {
                        seen.insert((c.structure, c.bucket), fingerprint);
                    }
                }
            }
        }
        // 4 structures × 6 distinct row buckets (the 13 row values
        // round up to {8, 32, 64, 128, 256, 2048} — off-pow2 values
        // deliberately merge into their pow2 neighbours) = 24 classes.
        assert_eq!(seen.len(), 24, "expected 24 distinct classes, got {}", seen.len());
    }

    #[test]
    fn graph_key_equality_implies_shape_class_equality() {
        let mk = || {
            let mut g = Graph::new("t");
            let p = g.param(Shape::new(vec![33, 65]), DType::F32, "p");
            let _ = g.unary(OpKind::Sigmoid, p, "s");
            g
        };
        let (a, b) = (mk(), mk());
        assert_eq!(GraphKey::of(&a), GraphKey::of(&b));
        assert_eq!(ShapeClass::of(&a), ShapeClass::of(&b));
        assert_eq!(ShapeClass::bucket_dim(33), 64);
        assert_eq!(ShapeClass::bucket_dim(64), 64);
        assert_eq!(ShapeClass::bucket_dim(65), 128);
        assert_eq!(ShapeClass::bucket_dim(0), 1);
        assert_eq!(ShapeClass::bucket_dim(1), 1);
    }

    #[test]
    fn cache_hit_miss_accounting() {
        use crate::explorer::FusionPlan;
        use crate::pipeline::{OptimizedProgram, Tech};
        let cache = CompilationCache::new();
        let key = GraphKey::of(&tiny(2));
        assert!(cache.get(key).is_none());
        cache.put(
            key,
            Arc::new(OptimizedProgram {
                tech: Tech::Fs,
                plan: FusionPlan::default(),
                kernels: vec![],
            }),
        );
        assert!(cache.get(key).is_some());
        assert_eq!(cache.stats(), (1, 1));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn concurrent_stats_are_never_torn() {
        // Multi-threaded executor shape: worker threads hammer lookups
        // while a reader snapshots stats. With the counters under the
        // map lock, every snapshot's hits+misses equals the number of
        // completed lookups at that instant — monotone mid-flight, and
        // exactly (hits, misses) = (HITS, MISSES) at quiescence. The
        // old three-mutex layout could tear (hits + misses ≠ lookups).
        use crate::explorer::FusionPlan;
        use crate::pipeline::{OptimizedProgram, Tech};
        use std::sync::atomic::{AtomicBool, Ordering};

        const THREADS: usize = 4;
        const PER_THREAD: usize = 2_000; // half hits, half misses
        let cache = Arc::new(CompilationCache::new());
        let hit_key = GraphKey::of(&tiny(2));
        let miss_key = GraphKey::of(&tiny(5));
        cache.put(
            hit_key,
            Arc::new(OptimizedProgram {
                tech: Tech::Fs,
                plan: FusionPlan::default(),
                kernels: vec![],
            }),
        );

        let stop = Arc::new(AtomicBool::new(false));
        let reader = {
            let cache = Arc::clone(&cache);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut last_total = 0u64;
                while !stop.load(Ordering::Acquire) {
                    let (h, m) = cache.stats();
                    let total = h + m;
                    assert!(
                        total >= last_total,
                        "torn stats: total went {last_total} -> {total}"
                    );
                    assert!(total <= (THREADS * PER_THREAD) as u64);
                    last_total = total;
                }
            })
        };
        let workers: Vec<_> = (0..THREADS)
            .map(|_| {
                let cache = Arc::clone(&cache);
                std::thread::spawn(move || {
                    for i in 0..PER_THREAD {
                        let key = if i % 2 == 0 { hit_key } else { miss_key };
                        let _ = cache.get(key);
                    }
                })
            })
            .collect();
        for w in workers {
            w.join().unwrap();
        }
        stop.store(true, Ordering::Release);
        reader.join().unwrap();

        let (h, m) = cache.stats();
        assert_eq!(h + m, (THREADS * PER_THREAD) as u64, "hits + misses ≠ lookups");
        assert_eq!(h, (THREADS * PER_THREAD / 2) as u64);
        assert_eq!(m, (THREADS * PER_THREAD / 2) as u64);
    }
}
