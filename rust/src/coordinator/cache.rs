//! Compilation cache: tune-once-run-many (§7.5).
//!
//! Deep learning workloads re-execute the same graph thousands of times;
//! FusionStitching (like XLA) compiles on first sight and caches by
//! graph identity. The key hashes the graph *structure* (op kinds,
//! shapes, edges), so retracing the same model hits the cache.

use crate::graph::Graph;
use crate::pipeline::OptimizedProgram;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Structural hash of a graph (FNV-1a over kinds/shapes/edges).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GraphKey(pub u64);

impl GraphKey {
    /// Hash a graph's structure.
    pub fn of(graph: &Graph) -> Self {
        use crate::util::hash::{fnv1a_u64, FNV_OFFSET};
        let mut h = FNV_OFFSET;
        let mut mix = |v: u64| h = fnv1a_u64(h, v);
        mix(graph.len() as u64);
        for node in graph.nodes() {
            mix(kind_tag(&node.kind));
            mix(node.dtype.size_bytes() as u64);
            for &d in node.shape.dims() {
                mix(d as u64 + 1);
            }
            for &inp in &node.inputs {
                mix(inp.0 as u64 + 0x9E37);
            }
        }
        GraphKey(h)
    }
}

fn kind_tag(kind: &crate::graph::OpKind) -> u64 {
    use crate::graph::OpKind::*;
    // A stable discriminant (mem::discriminant has no portable value).
    let base = match kind {
        Parameter => 1,
        Constant => 2,
        Add => 3,
        Sub => 4,
        Mul => 5,
        Div => 6,
        Maximum => 7,
        Minimum => 8,
        Neg => 9,
        Abs => 10,
        Compare => 11,
        Select => 12,
        Convert => 13,
        Relu => 14,
        Exp => 15,
        Log => 16,
        Tanh => 17,
        Sqrt => 18,
        Rsqrt => 19,
        Power => 20,
        Sigmoid => 21,
        Erf => 22,
        Gelu => 23,
        Tan => 24,
        Reduce { op, axes } => {
            return 25 + *op as u64 * 8 + axes.iter().map(|&a| a as u64 + 1).sum::<u64>() * 64;
        }
        Broadcast => 26,
        Reshape => 27,
        Transpose { perm } => {
            return 28 + perm.iter().map(|&p| p as u64 + 1).sum::<u64>() * 64;
        }
        Slice => 29,
        Gather => 30,
        Concat => 31,
        Pad => 32,
        Copy => 33,
        Iota => 34,
        MatMul => 35,
        BatchMatMul => 36,
        Conv => 37,
    };
    base
}

/// Thread-safe program cache with hit/miss accounting.
#[derive(Debug, Default)]
pub struct CompilationCache {
    map: Mutex<HashMap<GraphKey, Arc<OptimizedProgram>>>,
    hits: Mutex<u64>,
    misses: Mutex<u64>,
}

impl CompilationCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Lookup; updates hit/miss counters.
    pub fn get(&self, key: GraphKey) -> Option<Arc<OptimizedProgram>> {
        let found = self.map.lock().unwrap().get(&key).cloned();
        match &found {
            Some(_) => *self.hits.lock().unwrap() += 1,
            None => *self.misses.lock().unwrap() += 1,
        }
        found
    }

    /// Insert a compiled program.
    pub fn put(&self, key: GraphKey, prog: Arc<OptimizedProgram>) {
        self.map.lock().unwrap().insert(key, prog);
    }

    /// (hits, misses).
    pub fn stats(&self) -> (u64, u64) {
        (*self.hits.lock().unwrap(), *self.misses.lock().unwrap())
    }

    /// Entry count.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    /// True when the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, OpKind, Shape};

    fn tiny(n: usize) -> Graph {
        let mut g = Graph::new("t");
        let mut cur = g.param(Shape::new(vec![8]), DType::F32, "p");
        for i in 0..n {
            cur = g.unary(OpKind::Relu, cur, format!("r{i}"));
        }
        g
    }

    #[test]
    fn same_structure_same_key() {
        assert_eq!(GraphKey::of(&tiny(3)), GraphKey::of(&tiny(3)));
    }

    #[test]
    fn different_structure_different_key() {
        assert_ne!(GraphKey::of(&tiny(3)), GraphKey::of(&tiny(4)));
        // Same node count, different op.
        let mut g = Graph::new("t");
        let p = g.param(Shape::new(vec![8]), DType::F32, "p");
        let _ = g.unary(OpKind::Exp, p, "e");
        let mut g2 = Graph::new("t");
        let p2 = g2.param(Shape::new(vec![8]), DType::F32, "p");
        let _ = g2.unary(OpKind::Tanh, p2, "t");
        assert_ne!(GraphKey::of(&g), GraphKey::of(&g2));
    }

    #[test]
    fn shape_changes_key() {
        let mut g = Graph::new("a");
        g.param(Shape::new(vec![8]), DType::F32, "p");
        let mut g2 = Graph::new("a");
        g2.param(Shape::new(vec![16]), DType::F32, "p");
        assert_ne!(GraphKey::of(&g), GraphKey::of(&g2));
    }

    #[test]
    fn cache_hit_miss_accounting() {
        use crate::explorer::FusionPlan;
        use crate::pipeline::{OptimizedProgram, Tech};
        let cache = CompilationCache::new();
        let key = GraphKey::of(&tiny(2));
        assert!(cache.get(key).is_none());
        cache.put(
            key,
            Arc::new(OptimizedProgram {
                tech: Tech::Fs,
                plan: FusionPlan::default(),
                kernels: vec![],
            }),
        );
        assert!(cache.get(key).is_some());
        assert_eq!(cache.stats(), (1, 1));
        assert_eq!(cache.len(), 1);
    }
}
