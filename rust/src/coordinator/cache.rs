//! Compilation cache: tune-once-run-many (§7.5).
//!
//! Deep learning workloads re-execute the same graph thousands of times;
//! FusionStitching (like XLA) compiles on first sight and caches by
//! graph identity. The key hashes the graph *structure* (op kinds,
//! shapes, edges), so retracing the same model hits the cache.

use crate::graph::Graph;
use crate::pipeline::OptimizedProgram;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Structural hash of a graph (FNV-1a over kinds/shapes/edges).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GraphKey(pub u64);

impl GraphKey {
    /// Hash a graph's structure.
    pub fn of(graph: &Graph) -> Self {
        use crate::util::hash::{fnv1a_u64, FNV_OFFSET};
        let mut h = FNV_OFFSET;
        let mut mix = |v: u64| h = fnv1a_u64(h, v);
        mix(graph.len() as u64);
        for node in graph.nodes() {
            mix(kind_tag(&node.kind));
            mix(node.dtype.size_bytes() as u64);
            for &d in node.shape.dims() {
                mix(d as u64 + 1);
            }
            for &inp in &node.inputs {
                mix(inp.0 as u64 + 0x9E37);
            }
        }
        GraphKey(h)
    }
}

fn kind_tag(kind: &crate::graph::OpKind) -> u64 {
    use crate::graph::OpKind::*;
    use crate::util::hash::{fnv1a_u64, FNV_OFFSET};
    // A stable discriminant (mem::discriminant has no portable value).
    let base = match kind {
        Parameter => 1,
        Constant => 2,
        Add => 3,
        Sub => 4,
        Mul => 5,
        Div => 6,
        Maximum => 7,
        Minimum => 8,
        Neg => 9,
        Abs => 10,
        Compare => 11,
        Select => 12,
        Convert => 13,
        Relu => 14,
        Exp => 15,
        Log => 16,
        Tanh => 17,
        Sqrt => 18,
        Rsqrt => 19,
        Power => 20,
        Sigmoid => 21,
        Erf => 22,
        Gelu => 23,
        Tan => 24,
        Reduce { op, axes } => {
            // Positional FNV-1a mix: the old order-insensitive element
            // *sum* collided axes splits like {0,3} vs {1,2}, so graphs
            // differing only there hashed to one key and the cache
            // could serve the wrong program.
            let mut h = fnv1a_u64(FNV_OFFSET, 25);
            h = fnv1a_u64(h, *op as u64 + 1);
            for &a in axes {
                h = fnv1a_u64(h, a as u64 + 1);
            }
            return h;
        }
        Broadcast => 26,
        Reshape => 27,
        Transpose { perm } => {
            // Positional mix: permutations are rearrangements of the
            // same elements, so any order-insensitive fold (the old
            // sum) collided *every* pair of same-rank permutations,
            // e.g. [0,2,1] vs [1,0,2].
            let mut h = fnv1a_u64(FNV_OFFSET, 28);
            for &p in perm {
                h = fnv1a_u64(h, p as u64 + 1);
            }
            return h;
        }
        Slice => 29,
        Gather => 30,
        Concat => 31,
        Pad => 32,
        Copy => 33,
        Iota => 34,
        MatMul => 35,
        BatchMatMul => 36,
        Conv => 37,
    };
    base
}

/// Map + counters under ONE lock. The counters used to live behind two
/// further mutexes, so a concurrent `stats()` could observe a *torn*
/// snapshot (a lookup's map access done but its counter bump pending —
/// hits + misses ≠ completed lookups). One lock makes every lookup
/// atomic with its accounting.
#[derive(Debug, Default)]
struct CacheState {
    map: HashMap<GraphKey, Arc<OptimizedProgram>>,
    hits: u64,
    misses: u64,
}

/// Thread-safe program cache with hit/miss accounting.
#[derive(Debug, Default)]
pub struct CompilationCache {
    state: Mutex<CacheState>,
}

impl CompilationCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Lookup; updates hit/miss counters atomically with the access.
    pub fn get(&self, key: GraphKey) -> Option<Arc<OptimizedProgram>> {
        let mut st = self.state.lock().unwrap();
        let found = st.map.get(&key).cloned();
        match &found {
            Some(_) => st.hits += 1,
            None => st.misses += 1,
        }
        found
    }

    /// Insert a compiled program.
    pub fn put(&self, key: GraphKey, prog: Arc<OptimizedProgram>) {
        self.state.lock().unwrap().map.insert(key, prog);
    }

    /// (hits, misses) — a consistent snapshot: both counters are read
    /// under the same lock every lookup updates them under.
    pub fn stats(&self) -> (u64, u64) {
        let st = self.state.lock().unwrap();
        (st.hits, st.misses)
    }

    /// Entry count.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().map.len()
    }

    /// True when the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, OpKind, Shape};

    fn tiny(n: usize) -> Graph {
        let mut g = Graph::new("t");
        let mut cur = g.param(Shape::new(vec![8]), DType::F32, "p");
        for i in 0..n {
            cur = g.unary(OpKind::Relu, cur, format!("r{i}"));
        }
        g
    }

    #[test]
    fn same_structure_same_key() {
        assert_eq!(GraphKey::of(&tiny(3)), GraphKey::of(&tiny(3)));
    }

    #[test]
    fn different_structure_different_key() {
        assert_ne!(GraphKey::of(&tiny(3)), GraphKey::of(&tiny(4)));
        // Same node count, different op.
        let mut g = Graph::new("t");
        let p = g.param(Shape::new(vec![8]), DType::F32, "p");
        let _ = g.unary(OpKind::Exp, p, "e");
        let mut g2 = Graph::new("t");
        let p2 = g2.param(Shape::new(vec![8]), DType::F32, "p");
        let _ = g2.unary(OpKind::Tanh, p2, "t");
        assert_ne!(GraphKey::of(&g), GraphKey::of(&g2));
    }

    #[test]
    fn shape_changes_key() {
        let mut g = Graph::new("a");
        g.param(Shape::new(vec![8]), DType::F32, "p");
        let mut g2 = Graph::new("a");
        g2.param(Shape::new(vec![16]), DType::F32, "p");
        assert_ne!(GraphKey::of(&g), GraphKey::of(&g2));
    }

    #[test]
    fn transpose_perm_is_order_sensitive() {
        // Cube shape: every permutation of [4,4,4] preserves the output
        // shape, so only the perm itself can separate the keys — the
        // old sum-based tag collided (1+3+2 == 2+1+3).
        let build = |perm: Vec<usize>| {
            let mut g = Graph::new("t");
            let p = g.param(Shape::new(vec![4, 4, 4]), DType::F32, "p");
            let _ = g.add(
                OpKind::Transpose { perm },
                DType::F32,
                Shape::new(vec![4, 4, 4]),
                vec![p],
                "t",
            );
            GraphKey::of(&g)
        };
        assert_ne!(build(vec![0, 2, 1]), build(vec![1, 0, 2]));
        assert_eq!(build(vec![0, 2, 1]), build(vec![0, 2, 1]));
    }

    #[test]
    fn reduce_axes_split_changes_key() {
        use crate::graph::ReduceOp;
        // [2,2,2,2] reduced over {0,3} vs {1,2}: same output shape
        // [2,2], same combinator — the old sum-based tag collided
        // ((1+4) == (2+3)), so the cache could serve the wrong program.
        let build = |axes: Vec<usize>| {
            let mut g = Graph::new("r");
            let p = g.param(Shape::new(vec![2, 2, 2, 2]), DType::F32, "p");
            let _ = g.reduce(ReduceOp::Sum, p, axes, "r");
            GraphKey::of(&g)
        };
        assert_ne!(build(vec![0, 3]), build(vec![1, 2]));
        assert_eq!(build(vec![0, 3]), build(vec![0, 3]));
    }

    #[test]
    fn cache_hit_miss_accounting() {
        use crate::explorer::FusionPlan;
        use crate::pipeline::{OptimizedProgram, Tech};
        let cache = CompilationCache::new();
        let key = GraphKey::of(&tiny(2));
        assert!(cache.get(key).is_none());
        cache.put(
            key,
            Arc::new(OptimizedProgram {
                tech: Tech::Fs,
                plan: FusionPlan::default(),
                kernels: vec![],
            }),
        );
        assert!(cache.get(key).is_some());
        assert_eq!(cache.stats(), (1, 1));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn concurrent_stats_are_never_torn() {
        // Multi-threaded executor shape: worker threads hammer lookups
        // while a reader snapshots stats. With the counters under the
        // map lock, every snapshot's hits+misses equals the number of
        // completed lookups at that instant — monotone mid-flight, and
        // exactly (hits, misses) = (HITS, MISSES) at quiescence. The
        // old three-mutex layout could tear (hits + misses ≠ lookups).
        use crate::explorer::FusionPlan;
        use crate::pipeline::{OptimizedProgram, Tech};
        use std::sync::atomic::{AtomicBool, Ordering};

        const THREADS: usize = 4;
        const PER_THREAD: usize = 2_000; // half hits, half misses
        let cache = Arc::new(CompilationCache::new());
        let hit_key = GraphKey::of(&tiny(2));
        let miss_key = GraphKey::of(&tiny(5));
        cache.put(
            hit_key,
            Arc::new(OptimizedProgram {
                tech: Tech::Fs,
                plan: FusionPlan::default(),
                kernels: vec![],
            }),
        );

        let stop = Arc::new(AtomicBool::new(false));
        let reader = {
            let cache = Arc::clone(&cache);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut last_total = 0u64;
                while !stop.load(Ordering::Acquire) {
                    let (h, m) = cache.stats();
                    let total = h + m;
                    assert!(
                        total >= last_total,
                        "torn stats: total went {last_total} -> {total}"
                    );
                    assert!(total <= (THREADS * PER_THREAD) as u64);
                    last_total = total;
                }
            })
        };
        let workers: Vec<_> = (0..THREADS)
            .map(|_| {
                let cache = Arc::clone(&cache);
                std::thread::spawn(move || {
                    for i in 0..PER_THREAD {
                        let key = if i % 2 == 0 { hit_key } else { miss_key };
                        let _ = cache.get(key);
                    }
                })
            })
            .collect();
        for w in workers {
            w.join().unwrap();
        }
        stop.store(true, Ordering::Release);
        reader.join().unwrap();

        let (h, m) = cache.stats();
        assert_eq!(h + m, (THREADS * PER_THREAD) as u64, "hits + misses ≠ lookups");
        assert_eq!(h, (THREADS * PER_THREAD / 2) as u64);
        assert_eq!(m, (THREADS * PER_THREAD / 2) as u64);
    }
}
