//! The JIT coordinator (§6): sessions, compilation cache, async
//! compilation with hot swap, and the serving loop.

pub mod cache;
pub mod metrics;
pub mod persist;
pub mod service;

pub use cache::{CompilationCache, GraphKey, ShapeClass};
pub use metrics::{IterStats, ServiceMetrics};
pub use persist::{PersistedPlan, PlanStore};
pub use service::{guard_never_negative, tune_with_guards, JitService, ServiceOptions, Session};
